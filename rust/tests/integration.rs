//! Cross-module integration tests: the full stack (engine + fabric +
//! algorithms + data planes) exercised together, including the XLA
//! three-layer path against built artifacts.

use std::rc::Rc;

use nanosort::algo::mergemin::{run_mergemin, MergeMinConfig};
use nanosort::algo::millisort::{run_millisort, MilliSortConfig};
use nanosort::algo::nanosort::{run_nanosort, NanoSortConfig};
use nanosort::compute::{LocalCompute, NativeCompute, XlaCompute};
use nanosort::coordinator::{Args, ComputeChoice};
use nanosort::net::NetConfig;
use nanosort::runtime::XlaEngine;

fn xla_or_skip() -> Option<Rc<dyn LocalCompute>> {
    match XlaCompute::open_default() {
        Ok(x) => Some(Rc::new(x)),
        Err(e) => {
            eprintln!("skipping XLA integration (run `make artifacts`): {e:#}");
            None
        }
    }
}

/// The headline path in miniature: NanoSort with GraySort value phase,
/// node-local compute through the AOT Pallas/JAX artifacts via PJRT.
#[test]
fn nanosort_end_to_end_through_xla() {
    let Some(compute) = xla_or_skip() else { return };
    let cfg = NanoSortConfig {
        nodes: 64,
        keys_per_node: 16,
        buckets: 8,
        median_incast: 8,
        shuffle_values: true,
        seed: 11,
        ..Default::default()
    };
    let r = run_nanosort(&cfg, compute);
    assert!(r.validation.ok(), "{:?}", r.validation);
    assert!(r.validation.values_intact);
}

/// The two data planes must be *observationally identical*: same final
/// sorted output, same simulated timing (timing comes from the cost
/// model, not the data plane).
#[test]
fn xla_and_native_data_planes_agree_exactly() {
    let Some(xla) = xla_or_skip() else { return };
    let cfg = NanoSortConfig {
        nodes: 64,
        keys_per_node: 16,
        buckets: 8,
        median_incast: 8,
        shuffle_values: false,
        seed: 21,
        ..Default::default()
    };
    let a = run_nanosort(&cfg, Rc::new(NativeCompute));
    let b = run_nanosort(&cfg, xla);
    assert_eq!(a.runtime(), b.runtime(), "timing must not depend on data plane");
    assert_eq!(a.summary.net.msgs_sent, b.summary.net.msgs_sent);
    assert_eq!(a.validation.node_counts, b.validation.node_counts);
    assert!(a.validation.ok() && b.validation.ok());
}

#[test]
fn millisort_through_xla() {
    let Some(compute) = xla_or_skip() else { return };
    let cfg = MilliSortConfig { cores: 16, total_keys: 512, seed: 3, ..Default::default() };
    let r = run_millisort(&cfg, compute);
    assert!(r.validation.ok(), "{:?}", r.validation);
}

#[test]
fn mergemin_through_xla() {
    let Some(compute) = xla_or_skip() else { return };
    let cfg = MergeMinConfig {
        cores: 32,
        values_per_core: 64,
        incast: 8,
        seed: 5,
        ..Default::default()
    };
    let r = run_mergemin(&cfg, compute);
    assert!(r.correct());
}

/// Every artifact in the manifest loads, compiles, and executes.
#[test]
fn all_artifacts_compile_and_execute() {
    let Ok(engine) = XlaEngine::open_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    for spec in engine.manifest().artifacts.clone() {
        let art = engine.load(&spec.name).expect(&spec.name);
        let inputs: Vec<Vec<u64>> = spec
            .inputs
            .iter()
            .map(|t| (0..t.elements() as u64).map(|i| i.wrapping_mul(2_654_435_761)).collect())
            .collect();
        let refs: Vec<&[u64]> = inputs.iter().map(|v| v.as_slice()).collect();
        // bucketize's pivot input must be sorted; regenerate sorted inputs
        // for artifacts with a second operand.
        if spec.inputs.len() == 2 {
            let mut pivots = inputs[1].clone();
            pivots.sort_unstable();
            let refs2: Vec<&[u64]> = vec![&inputs[0], &pivots];
            art.run_mixed(&refs2).expect(&spec.name);
        } else {
            art.run_mixed(&refs).expect(&spec.name);
        }
    }
    assert_eq!(engine.cached_count(), engine.manifest().artifacts.len());
}

/// Paper-shape regression: the three headline comparisons the reproduction
/// must preserve (who wins, direction of effects).
#[test]
fn paper_shape_regressions() {
    let native: Rc<dyn LocalCompute> = Rc::new(NativeCompute);

    // 1. NanoSort at 4,096 cores sorts 64 K keys an order of magnitude
    //    faster than MilliSort sorts 4 K keys on 256 cores.
    let ns = run_nanosort(
        &NanoSortConfig { nodes: 4096, keys_per_node: 16, seed: 1, ..Default::default() },
        native.clone(),
    );
    let ms = run_millisort(
        &MilliSortConfig { cores: 256, total_keys: 4096, seed: 1, ..Default::default() },
        native.clone(),
    );
    assert!(ns.validation.ok() && ms.validation.ok());
    assert!(
        ns.runtime().as_us_f64() * 2.0 < ms.runtime().as_us_f64(),
        "NanoSort {:.1}µs should beat MilliSort {:.1}µs clearly",
        ns.runtime().as_us_f64(),
        ms.runtime().as_us_f64()
    );

    // 2. Multicast off slows NanoSort down (§6.2.3 direction).
    let mut no_mcast =
        NanoSortConfig { nodes: 256, keys_per_node: 16, seed: 1, ..Default::default() };
    no_mcast.net = NetConfig { multicast: false, ..Default::default() };
    let without = run_nanosort(&no_mcast, native.clone());
    let mut with = no_mcast.clone();
    with.net.multicast = true;
    let with_r = run_nanosort(&with, native);
    assert!(with_r.runtime() < without.runtime());
}

/// CLI plumbing: ComputeChoice + Args work end to end.
#[test]
fn cli_arg_plumbing() {
    let mut a = Args::from_vec(
        ["run", "nanosort", "--nodes", "64", "--xla"].iter().map(|s| s.to_string()).collect(),
    );
    assert_eq!(a.positional().as_deref(), Some("run"));
    assert_eq!(a.positional().as_deref(), Some("nanosort"));
    assert_eq!(a.num::<usize>("nodes"), Some(64));
    let opts = a.run_options().unwrap();
    assert_eq!(opts.compute, ComputeChoice::Xla);
}
