//! Cross-module integration tests: the full stack (engine + fabric +
//! algorithms + data planes) exercised together, including the XLA
//! three-layer path against built artifacts (skipped on builds without
//! the `pjrt` feature / without `make artifacts`).

use std::sync::Arc;

use nanosort::algo::millisort::MilliSort;
use nanosort::algo::nanosort::NanoSort;
use nanosort::compute::{LocalCompute, NativeCompute, XlaCompute};
use nanosort::coordinator::{Args, ComputeChoice};
use nanosort::net::NetConfig;
use nanosort::runtime::XlaEngine;
use nanosort::scenario::{RunReport, Scenario};

fn xla_or_skip() -> Option<Arc<dyn LocalCompute>> {
    match XlaCompute::open_default() {
        Ok(x) => Some(Arc::new(x)),
        Err(e) => {
            eprintln!("skipping XLA integration (run `make artifacts`): {e:#}");
            None
        }
    }
}

fn nanosort_64(values: bool, seed: u64) -> Scenario {
    Scenario::new(NanoSort {
        keys_per_node: 16,
        buckets: 8,
        median_incast: 8,
        shuffle_values: values,
        ..Default::default()
    })
    .nodes(64)
    .seed(seed)
}

/// The headline path in miniature: NanoSort with GraySort value phase,
/// node-local compute through the AOT Pallas/JAX artifacts via PJRT.
#[test]
fn nanosort_end_to_end_through_xla() {
    let Some(compute) = xla_or_skip() else { return };
    let r = nanosort_64(true, 11).compute_with(compute).run().unwrap();
    let v = r.validation.sort.as_ref().unwrap();
    assert!(v.ok(), "{v:?}");
    assert!(v.values_intact);
}

/// The two data planes must be *observationally identical*: same final
/// sorted output, same simulated timing (timing comes from the cost
/// model, not the data plane).
#[test]
fn xla_and_native_data_planes_agree_exactly() {
    let Some(xla) = xla_or_skip() else { return };
    let a = nanosort_64(false, 21).compute_with(Arc::new(NativeCompute)).run().unwrap();
    let b = nanosort_64(false, 21).compute_with(xla).run().unwrap();
    assert_eq!(a.runtime(), b.runtime(), "timing must not depend on data plane");
    assert_eq!(a.summary.net.msgs_sent, b.summary.net.msgs_sent);
    assert_eq!(
        a.validation.sort.as_ref().unwrap().node_counts,
        b.validation.sort.as_ref().unwrap().node_counts
    );
    assert!(a.validation.ok() && b.validation.ok());
}

#[test]
fn millisort_through_xla() {
    let Some(compute) = xla_or_skip() else { return };
    let r = Scenario::new(MilliSort { total_keys: 512, ..Default::default() })
        .nodes(16)
        .seed(3)
        .compute_with(compute)
        .run()
        .unwrap();
    assert!(r.validation.ok(), "{}", r.validation.detail);
}

#[test]
fn mergemin_through_xla() {
    let Some(compute) = xla_or_skip() else { return };
    let r = Scenario::new(nanosort::algo::mergemin::MergeMin {
        values_per_core: 64,
        incast: 8,
    })
    .nodes(32)
    .seed(5)
    .compute_with(compute)
    .run()
    .unwrap();
    assert!(r.validation.ok(), "{}", r.validation.detail);
}

/// Every artifact in the manifest loads, compiles, and executes.
#[test]
fn all_artifacts_compile_and_execute() {
    let Ok(engine) = XlaEngine::open_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    for spec in engine.manifest().artifacts.clone() {
        let art = engine.load(&spec.name).expect(&spec.name);
        let inputs: Vec<Vec<u64>> = spec
            .inputs
            .iter()
            .map(|t| (0..t.elements() as u64).map(|i| i.wrapping_mul(2_654_435_761)).collect())
            .collect();
        let refs: Vec<&[u64]> = inputs.iter().map(|v| v.as_slice()).collect();
        // bucketize's pivot input must be sorted; regenerate sorted inputs
        // for artifacts with a second operand.
        if spec.inputs.len() == 2 {
            let mut pivots = inputs[1].clone();
            pivots.sort_unstable();
            let refs2: Vec<&[u64]> = vec![&inputs[0], &pivots];
            art.run_mixed(&refs2).expect(&spec.name);
        } else {
            art.run_mixed(&refs).expect(&spec.name);
        }
    }
    assert_eq!(engine.cached_count(), engine.manifest().artifacts.len());
}

/// Paper-shape regression: the three headline comparisons the reproduction
/// must preserve (who wins, direction of effects).
#[test]
fn paper_shape_regressions() {
    // 1. NanoSort at 4,096 cores sorts 64 K keys an order of magnitude
    //    faster than MilliSort sorts 4 K keys on 256 cores.
    let ns: RunReport =
        Scenario::new(NanoSort::default()).nodes(4096).seed(1).run().unwrap();
    let ms = Scenario::new(MilliSort::default()).nodes(256).seed(1).run().unwrap();
    assert!(ns.validation.ok() && ms.validation.ok());
    assert!(
        ns.runtime().as_us_f64() * 2.0 < ms.runtime().as_us_f64(),
        "NanoSort {:.1}µs should beat MilliSort {:.1}µs clearly",
        ns.runtime().as_us_f64(),
        ms.runtime().as_us_f64()
    );

    // 2. Multicast off slows NanoSort down (§6.2.3 direction).
    let base = || Scenario::new(NanoSort::default()).nodes(256).seed(1);
    let without = base()
        .net(NetConfig { multicast: false, ..Default::default() })
        .run()
        .unwrap();
    let with_r = base().run().unwrap();
    assert!(with_r.runtime() < without.runtime());
}

/// CLI plumbing: ComputeChoice + Args work end to end.
#[test]
fn cli_arg_plumbing() {
    let mut a = Args::from_vec(
        ["run", "nanosort", "--nodes", "64", "--xla"].iter().map(|s| s.to_string()).collect(),
    );
    assert_eq!(a.positional().as_deref(), Some("run"));
    assert_eq!(a.positional().as_deref(), Some("nanosort"));
    assert_eq!(a.num::<usize>("nodes"), Some(64));
    let opts = a.run_options().unwrap();
    assert_eq!(opts.compute, ComputeChoice::Xla);
}
