//! Invariant suite for the distributed sort: for a seeded sweep of
//! (nodes, keys-per-node, buckets) shapes, the NanoSort output must be
//! globally sorted, conserve every key across the shuffle (none lost,
//! none duplicated), be deterministic across runs, and — since node
//! `i`'s input is a pure per-node stream of (seed, i, keys-per-node) —
//! be identical whether the fleet's keys were generated all at once or
//! one node at a time.

use nanosort::algo::nanosort::NanoSort;
use nanosort::graysort::KeyGen;
use nanosort::scenario::{RunReport, Scenario};
use nanosort::sim::Time;

/// One seeded NanoSort run through the Scenario API.
fn run(nodes: usize, kpn: usize, buckets: usize, seed: u64, values: bool) -> RunReport {
    Scenario::new(NanoSort {
        keys_per_node: kpn,
        buckets,
        median_incast: buckets,
        shuffle_values: values,
        ..Default::default()
    })
    .nodes(nodes)
    .seed(seed)
    .run()
    .unwrap_or_else(|e| panic!("nodes={nodes} kpn={kpn} b={buckets} seed={seed}: {e:#}"))
}

/// The seeded sweep: every shape is `nodes = buckets^r`, covering one to
/// four recursion levels and 2–16-way bucketing.
const SHAPES: &[(usize, usize, usize)] = &[
    (8, 8, 2),
    (16, 16, 4),
    (16, 8, 16),
    (64, 8, 4),
    (64, 16, 8),
    (256, 16, 16),
    (81, 8, 3),
];

#[test]
fn sortedness_and_key_conservation_across_shapes() {
    for &(nodes, kpn, buckets) in SHAPES {
        for seed in [1u64, 7, 42] {
            let r = run(nodes, kpn, buckets, seed, false);
            let v = r.validation.sort.as_ref().expect("sort validation");
            assert!(
                v.globally_sorted,
                "nodes={nodes} kpn={kpn} b={buckets} seed={seed}: output not sorted"
            );
            assert!(
                v.is_permutation,
                "nodes={nodes} kpn={kpn} b={buckets} seed={seed}: keys lost or duplicated"
            );
            assert_eq!(
                v.total_keys,
                nodes * kpn,
                "nodes={nodes} kpn={kpn} b={buckets} seed={seed}: key count drifted"
            );
            assert_eq!(
                v.node_counts.iter().sum::<usize>(),
                nodes * kpn,
                "node counts must conserve the total"
            );
            assert!(r.runtime() > Time::ZERO);
        }
    }
}

#[test]
fn value_phase_conserves_and_matches_origin_values() {
    for &(nodes, kpn, buckets) in &[(16usize, 8usize, 4usize), (64, 8, 8)] {
        let r = run(nodes, kpn, buckets, 9, true);
        let v = r.validation.sort.as_ref().unwrap();
        assert!(v.ok(), "nodes={nodes}: {v:?}");
        assert!(v.values_intact, "nodes={nodes}: values corrupted in flight");
    }
}

#[test]
fn determinism_across_two_runs() {
    for &(nodes, kpn, buckets) in &[(16usize, 8usize, 4usize), (64, 16, 8)] {
        for seed in [3u64, 11] {
            let a = run(nodes, kpn, buckets, seed, false);
            let b = run(nodes, kpn, buckets, seed, false);
            assert_eq!(a.runtime(), b.runtime(), "nodes={nodes} seed={seed}");
            assert_eq!(a.summary.events, b.summary.events);
            assert_eq!(a.summary.net.msgs_sent, b.summary.net.msgs_sent);
            assert_eq!(a.render(), b.render(), "byte-for-byte report");
            assert_eq!(
                a.validation.sort.as_ref().unwrap().node_counts,
                b.validation.sort.as_ref().unwrap().node_counts
            );
        }
    }
}

/// Stream independence: node `i`'s input is `KeyGen(seed).node_keys(i,
/// kpn)` whether the fleet is generated all at once or one node at a
/// time — the per-node streams are the definition, the materialized
/// array just their concatenation. Every shape must then fully validate:
/// sorted + permutation-of-input ⇒ output == sorted(input), which the
/// generator check pins to the per-node streams.
#[test]
fn sorted_output_matches_per_node_streams() {
    let seed = 5u64;
    let total = 1024usize;
    // 1024 keys as 16×64, 64×16, and 256×4 (buckets chosen so nodes is an
    // exact power).
    let shapes: &[(usize, usize, usize)] = &[(16, 64, 4), (64, 16, 8), (256, 4, 16)];

    for &(nodes, kpn, buckets) in shapes {
        assert_eq!(nodes * kpn, total);
        let materialized = KeyGen::new(seed).generate(total, nodes);
        let kg = KeyGen::new(seed);
        for (i, part) in materialized.iter().enumerate() {
            assert_eq!(
                &kg.node_keys(i, kpn),
                part,
                "nodes={nodes}: node {i} stream diverged from the materialized path"
            );
        }

        let r = run(nodes, kpn, buckets, seed, false);
        let v = r.validation.sort.as_ref().unwrap();
        assert!(v.globally_sorted && v.is_permutation, "nodes={nodes}: {v:?}");
        assert_eq!(v.total_keys, total);
    }
}
