//! Cross-workload integration tests for the `Scenario`/`Workload` API and
//! the registry-driven CLI path: every registry entry runs at CI-small
//! sizes, validates, and produces byte-for-byte deterministic reports —
//! at any executor thread count.

use nanosort::algo::mergemin::MergeMin;
use nanosort::algo::nanosort::NanoSort;
use nanosort::coordinator::Args;
use nanosort::net::NetConfig;
use nanosort::scenario::{registry, RunReport, Scenario};
use nanosort::sim::Time;

/// Run one registry entry at its CI-small smoke size.
fn run_smoke(spec: &registry::WorkloadSpec, seed: u64) -> RunReport {
    run_smoke_threads(spec, seed, 1)
}

fn run_smoke_threads(spec: &registry::WorkloadSpec, seed: u64, threads: usize) -> RunReport {
    let params = registry::params_from_pairs(spec, spec.smoke)
        .unwrap_or_else(|e| panic!("{}: smoke params: {e:#}", spec.name));
    let workload =
        (spec.build)(&params).unwrap_or_else(|e| panic!("{}: build: {e:#}", spec.name));
    let nodes = params.u64(spec.nodes_param.name).unwrap() as usize;
    Scenario::from_dyn(workload)
        .nodes(nodes)
        .seed(seed)
        .threads(threads)
        .run()
        .unwrap_or_else(|e| panic!("{}: run: {e:#}", spec.name))
}

/// Every workload in the registry runs through `Scenario` and validates.
#[test]
fn every_registry_entry_runs_and_validates() {
    assert!(registry::WORKLOADS.len() >= 4, "all four workloads registered");
    for spec in registry::WORKLOADS {
        let r = run_smoke(spec, 1);
        assert_eq!(r.workload, spec.name, "report is tagged with the registry name");
        assert!(r.validation.ok(), "{}: {}", spec.name, r.validation.detail);
        assert!(r.runtime() > Time::ZERO, "{}", spec.name);
        assert!(r.summary.net.msgs_sent > 0, "{}", spec.name);
        assert!(!r.stages.is_empty(), "{}", spec.name);
    }
}

/// Fixed seed => byte-for-byte identical `RunReport` rendering across two
/// independent runs, for every workload.
#[test]
fn reports_are_byte_for_byte_deterministic() {
    for spec in registry::WORKLOADS {
        let a = run_smoke(spec, 7);
        let b = run_smoke(spec, 7);
        assert_eq!(a.render(), b.render(), "workload {}", spec.name);
        assert_eq!(a.runtime(), b.runtime(), "workload {}", spec.name);
        assert_eq!(
            a.summary.net.msgs_sent, b.summary.net.msgs_sent,
            "workload {}",
            spec.name
        );
    }
}

/// The `threads` knob changes wall-clock scheduling only: rendered
/// reports are byte-identical between the sequential and the sharded
/// backend for every registry workload. (The full digest matrix —
/// tiers × perturbations — lives in tests/exec.rs.)
#[test]
fn thread_count_never_changes_the_report() {
    for spec in registry::WORKLOADS {
        let seq = run_smoke_threads(spec, 7, 1);
        let par = run_smoke_threads(spec, 7, 4);
        assert_eq!(seq.render(), par.render(), "workload {}", spec.name);
        assert_eq!(seq.summary.events, par.summary.events, "workload {}", spec.name);
    }
}

/// The CLI parse path (`Args` -> registry descriptors -> workload) accepts
/// the documented flags end to end.
#[test]
fn registry_cli_path_end_to_end() {
    let spec = registry::find("nanosort").unwrap();
    let mut args = Args::from_vec(
        ["--nodes", "16", "--kpn", "8", "--buckets", "4", "--values"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    );
    let params = registry::parse_args(spec, &mut args).unwrap();
    assert!(args.rest().is_empty());
    assert_eq!(params.u64("incast").unwrap(), 4, "incast follows buckets");
    let workload = (spec.build)(&params).unwrap();
    let report = Scenario::from_dyn(workload).nodes(16).seed(3).run().unwrap();
    assert!(report.validation.ok());
    assert!(
        report.validation.sort.as_ref().unwrap().values_intact,
        "--values runs the GraySort value phase"
    );
}

#[test]
fn unknown_workload_and_bad_params_error_cleanly() {
    let err = registry::find("quantumsort").unwrap_err().to_string();
    assert!(err.contains("unknown workload"), "{err}");
    assert!(err.contains("mergemin"), "error lists known workloads: {err}");

    let spec = registry::find("millisort").unwrap();
    let mut args =
        Args::from_vec(["--keys", "eleventy"].iter().map(|s| s.to_string()).collect());
    assert!(registry::parse_args(spec, &mut args).is_err());
}

/// Typed workloads through `Scenario::new` and type-erased ones through
/// the registry are the same code path: identical simulated results.
#[test]
fn typed_and_registry_paths_agree() {
    let spec = registry::find("nanosort").unwrap();
    let params = registry::params_from_pairs(
        spec,
        &[("nodes", 16), ("kpn", 8), ("buckets", 4)],
    )
    .unwrap();
    let via_registry = Scenario::from_dyn((spec.build)(&params).unwrap())
        .nodes(16)
        .seed(11)
        .run()
        .unwrap();
    let typed = Scenario::new(NanoSort {
        keys_per_node: 8,
        buckets: 4,
        median_incast: 4,
        ..Default::default()
    })
    .nodes(16)
    .seed(11)
    .run()
    .unwrap();
    assert_eq!(typed.runtime(), via_registry.runtime());
    assert_eq!(typed.summary.net.msgs_sent, via_registry.summary.net.msgs_sent);
    assert_eq!(
        typed.validation.sort.as_ref().unwrap().node_counts,
        via_registry.validation.sort.as_ref().unwrap().node_counts
    );

    let spec = registry::find("mergemin").unwrap();
    let params =
        registry::params_from_pairs(spec, &[("cores", 8), ("vpc", 16), ("incast", 4)])
            .unwrap();
    let via_registry =
        Scenario::from_dyn((spec.build)(&params).unwrap()).nodes(8).seed(11).run().unwrap();
    let typed = Scenario::new(MergeMin { values_per_core: 16, incast: 4 })
        .nodes(8)
        .seed(11)
        .run()
        .unwrap();
    assert_eq!(typed.summary.makespan, via_registry.summary.makespan);
    assert_eq!(typed.metric_u64("found_min"), via_registry.metric_u64("found_min"));
}

/// Scenario-level environment knobs reach the fabric for every workload.
#[test]
fn scenario_net_knobs_apply_across_workloads() {
    for spec in registry::WORKLOADS {
        let params = registry::params_from_pairs(spec, spec.smoke).unwrap();
        let nodes = params.u64(spec.nodes_param.name).unwrap() as usize;
        let slow = NetConfig { switch_latency_ns: 2000, ..NetConfig::default() };
        let fast = Scenario::from_dyn((spec.build)(&params).unwrap())
            .nodes(nodes)
            .seed(2)
            .run()
            .unwrap();
        let slowed = Scenario::from_dyn((spec.build)(&params).unwrap())
            .nodes(nodes)
            .net(slow)
            .seed(2)
            .run()
            .unwrap();
        assert!(
            slowed.runtime() > fast.runtime(),
            "{}: higher switch latency must slow the run",
            spec.name
        );
    }
}
