//! Service-layer integration gates (DESIGN.md §9).
//!
//! - the service digest is byte-identical across executor backends and
//!   thread counts, per scheduler;
//! - FIFO and SJF make observably different admission decisions on a
//!   crafted size mix (head-of-line blocking vs smallest-first);
//! - no scheduler ever overlaps the node ranges of concurrently running
//!   jobs, and the reserve policy stays leaf-aligned;
//! - a zero-arrival run quiesces to the same empty digest on both
//!   executors;
//! - perturbation isolation: admitting a second job — concurrently or
//!   after node reuse — cannot shift an earlier job's record, even with
//!   tail injection, packet loss, and stragglers enabled (the
//!   per-job-salted draw streams this PR pins).

use nanosort::algo::nanosort::NanoSort;
use nanosort::perturb::apply_env_setting;
use nanosort::service::{
    run_service, run_service_trace, service_digest, ArrivalConfig, JobKind, JobSpec, Mix,
    SchedPolicy, ServiceConfig, SizeClass, LEAF_RADIX,
};
use nanosort::sim::Time;

/// A crafted NanoSort job of one of the generator's size classes
/// (4/16/64 nodes — the same shapes `arrivals::job_kind` emits).
fn ns_job(id: u32, arrival_ns: u64, class: SizeClass) -> JobSpec {
    let nodes = match class {
        SizeClass::Small => 4,
        SizeClass::Medium => 16,
        SizeClass::Large => 64,
    };
    JobSpec {
        id,
        arrival: Time::from_ns(arrival_ns),
        nodes,
        class,
        kind: JobKind::NanoSort(NanoSort {
            keys_per_node: 8,
            buckets: 4,
            median_incast: 4,
            ..Default::default()
        }),
        seed: 0x5eed_0000 + id as u64,
    }
}

fn small_fleet(policy: SchedPolicy) -> ServiceConfig {
    let arrivals = ArrivalConfig {
        jobs: 8,
        mean_iat_ns: 1_000,
        mix: Mix::Nanosort,
        ..Default::default()
    };
    ServiceConfig::new(128, arrivals, policy).unwrap()
}

#[test]
fn service_digest_is_executor_and_thread_invariant_per_scheduler() {
    for policy in SchedPolicy::ALL {
        let seq = run_service(&small_fleet(policy), 7).unwrap();
        let mut par_cfg = small_fleet(policy);
        par_cfg.threads = 4;
        let par = run_service(&par_cfg, 7).unwrap();
        assert_eq!(
            service_digest(&seq, "smoke"),
            service_digest(&par, "smoke"),
            "{}: SeqExecutor vs ParExecutor(4)",
            policy.name()
        );
    }
}

#[test]
fn sjf_admits_small_jobs_ahead_of_a_blocking_large_job() {
    // One fleet-filling large job and two small ones, all due at the
    // same coordinator tick of a 64-worker fleet.
    let trace = || {
        vec![
            ns_job(0, 100, SizeClass::Large),
            ns_job(1, 100, SizeClass::Small),
            ns_job(2, 100, SizeClass::Small),
        ]
    };
    let cfg_of = |policy| {
        let arrivals = ArrivalConfig { jobs: 3, ..Default::default() };
        ServiceConfig::new(64, arrivals, policy).unwrap()
    };

    // FIFO: strict arrival order — the large job grabs the whole fleet
    // and head-of-line blocks both small ones behind it.
    let fifo = run_service_trace(&cfg_of(SchedPolicy::Fifo), 7, trace()).unwrap();
    let rec = |r: &nanosort::service::JobRecord| (r.admit_seq, r.start);
    let f: Vec<_> = fifo.jobs.iter().map(|j| rec(&j.record)).collect();
    assert_eq!(f[0].0, 0, "fifo admits the large job first");
    assert!(f[1].1 >= fifo.jobs[0].record.finish, "small job waits out the large one");

    // SJF: both small jobs jump the queue; the large job runs last.
    let sjf = run_service_trace(&cfg_of(SchedPolicy::Sjf), 7, trace()).unwrap();
    let s: Vec<_> = sjf.jobs.iter().map(|j| rec(&j.record)).collect();
    assert_eq!(s[0].0, 2, "sjf admits the large job last");
    assert_eq!((s[1].0, s[2].0), (0, 1), "small jobs keep arrival order among themselves");
    assert!(s[1].1 < s[0].1, "a small job starts before the large one");

    // The decision difference is visible in the conformance digest.
    assert_ne!(service_digest(&fifo, "smoke"), service_digest(&sjf, "smoke"));
}

#[test]
fn no_scheduler_overlaps_concurrent_node_ranges() {
    for policy in SchedPolicy::ALL {
        let r = run_service(&small_fleet(policy), 11).unwrap();
        let recs: Vec<_> = r.jobs.iter().map(|j| j.record.clone()).collect();
        for a in &recs {
            assert!(a.base + policy.footprint(a.nodes) <= r.workers, "{}", policy.name());
            if policy == SchedPolicy::Reserve {
                assert_eq!(a.base % LEAF_RADIX, 0, "reserve base must be leaf-aligned");
            }
            for b in &recs {
                if a.job == b.job {
                    continue;
                }
                // Concurrent in time ⇒ disjoint in node space.
                let concurrent = a.start < b.finish && b.start < a.finish;
                let (af, bf) = (policy.footprint(a.nodes), policy.footprint(b.nodes));
                let disjoint = a.base + af <= b.base || b.base + bf <= a.base;
                assert!(
                    !concurrent || disjoint,
                    "{}: jobs {} and {} overlap in time and space",
                    policy.name(),
                    a.job,
                    b.job
                );
            }
        }
    }
}

#[test]
fn zero_arrival_run_is_byte_identical_to_the_empty_digest_on_both_executors() {
    let mut cfg = small_fleet(SchedPolicy::Fifo);
    cfg.arrivals.jobs = 0;
    let seq = run_service(&cfg, 7).unwrap();
    cfg.threads = 4;
    let par = run_service(&cfg, 7).unwrap();
    let d = service_digest(&seq, "smoke");
    assert_eq!(d, service_digest(&par, "smoke"));
    assert!(d.contains("\"jobs\": 0") && d.contains("\"makespan_units\": 0"));
    assert!(!d.contains("\"job0\""));
}

/// Enable the full perturbation gauntlet on a service config: tail
/// injection, packet loss + retransmit, and straggler cores.
fn perturbed(mut cfg: ServiceConfig, loss: bool) -> ServiceConfig {
    let mut knobs = cfg.perturb.clone();
    apply_env_setting("tail", "100", &mut cfg.net, &mut knobs).unwrap();
    if loss {
        apply_env_setting("loss", "20", &mut cfg.net, &mut knobs).unwrap();
    }
    apply_env_setting("stragglers", "6", &mut cfg.net, &mut knobs).unwrap();
    apply_env_setting("straggler-factor", "4", &mut cfg.net, &mut knobs).unwrap();
    cfg.perturb = knobs;
    cfg
}

#[test]
fn a_concurrent_second_job_cannot_shift_the_first_jobs_record() {
    // Satellite bugfix pin: perturbation draws are per-job-salted, so a
    // second live job must not consume (and thereby shift) any RNG
    // stream the first job's timing depends on. Tail + stragglers on;
    // loss off so the concurrency witness stays sharp.
    let cfg_of = |jobs| {
        let arrivals = ArrivalConfig { jobs, ..Default::default() };
        perturbed(ServiceConfig::new(128, arrivals, SchedPolicy::Fifo).unwrap(), false)
    };
    let job0 = || ns_job(0, 100, SizeClass::Medium);
    let solo = run_service_trace(&cfg_of(1), 7, vec![job0()]).unwrap();
    let duo = run_service_trace(
        &cfg_of(2),
        7,
        vec![job0(), ns_job(1, 200, SizeClass::Large)],
    )
    .unwrap();
    // The two jobs really did share the fabric concurrently…
    assert!(
        duo.jobs[1].record.start < duo.jobs[0].record.finish,
        "expected overlap: job1 starts at {} but job0 already finished at {}",
        duo.jobs[1].record.start.0,
        duo.jobs[0].record.finish.0
    );
    // …yet job 0's entire lifecycle is bit-identical to its solo run.
    assert_eq!(solo.jobs[0].record, duo.jobs[0].record);
}

#[test]
fn node_reuse_by_a_later_job_cannot_shift_the_first_jobs_record() {
    // Same pin, sequential flavor: job 1 arrives long after job 0
    // completed and first-fit hands it the *same* node range; with loss
    // and stragglers enabled its draws must still come from its own
    // streams, leaving job 0's record untouched.
    let cfg_of = |jobs| {
        let arrivals = ArrivalConfig { jobs, ..Default::default() };
        perturbed(ServiceConfig::new(64, arrivals, SchedPolicy::Fifo).unwrap(), true)
    };
    let job0 = || ns_job(0, 100, SizeClass::Medium);
    let solo = run_service_trace(&cfg_of(1), 7, vec![job0()]).unwrap();
    let duo = run_service_trace(
        &cfg_of(2),
        7,
        vec![job0(), ns_job(1, 500_000, SizeClass::Medium)],
    )
    .unwrap();
    assert!(duo.jobs[1].record.start >= duo.jobs[0].record.finish, "strictly sequential");
    assert_eq!(
        duo.jobs[1].record.base, duo.jobs[0].record.base,
        "first-fit reuses the freed range"
    );
    assert_eq!(solo.jobs[0].record, duo.jobs[0].record);
}
