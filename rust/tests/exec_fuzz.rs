//! Randomized executor-equivalence campaigns: the fuzzing layer that
//! gates the optimistic backend (`--exec opt`) and the topology-aware
//! adaptive windows behind one property — **every** backend
//! configuration reproduces the sequential reference digest byte for
//! byte.
//!
//! Each case draws a workload at a random tier-sized shape, composes a
//! random perturbation set (skew, loss + RTO, injected tails,
//! stragglers, oversubscription — the last only on leaf-multiple
//! fleets), then runs it under a random backend configuration
//! ({`par`, `opt`} × threads × `window_batch` × an occasional forced
//! rollback cadence × a coin-flipped forced kernel family, the
//! `NANOSORT_TUNER` equivalent) and compares conformance digests and rendered
//! reports against the sequential run of the same scenario. The case
//! generator is seeded, so a failure reproduces by case index.
//!
//! `NANOSORT_FUZZ_CASES` scales the campaign (default 64; CI pins 32 in
//! the release-profile leg; soak runs can set 1000+).

use std::sync::Arc;

use nanosort::compute::{RadixCompute, TunerOverride};
use nanosort::conformance::{digest_json, Tier, CONFORMANCE_SEED};
use nanosort::net::NetConfig;
use nanosort::pool::WorkerPool;
use nanosort::perturb::{KeyDistribution, Perturbations, StragglerConfig};
use nanosort::scenario::{registry, RunReport, Scenario};
use nanosort::service::{self, Mix, SchedPolicy, ServiceConfig};
use nanosort::sim::{ExecKind, SplitMix64};

fn fuzz_cases() -> usize {
    match std::env::var("NANOSORT_FUZZ_CASES") {
        Ok(raw) => raw
            .parse()
            .unwrap_or_else(|_| panic!("NANOSORT_FUZZ_CASES must be a number, got {raw:?}")),
        Err(_) => 64,
    }
}

/// Leaf radix of the paper topology: oversubscription forces
/// leaf-aligned shards, so the oversub knob only composes onto fleets
/// that span multiple whole leaves.
const LEAF: usize = 64;

/// One drawn case: a workload shape, an environment, and a backend
/// configuration. Everything derives from the campaign RNG, so the
/// whole case replays from its index.
struct Case {
    spec: &'static registry::WorkloadSpec,
    pairs: Vec<(&'static str, u64)>,
    nodes: usize,
    net: NetConfig,
    knobs: Perturbations,
    seed: u64,
    exec: ExecKind,
    threads: usize,
    window_batch: Option<usize>,
    force_every: Option<u64>,
    /// Forced kernel family for the backend run (`None` = auto tuner).
    /// The sequential reference always runs the auto tuner, so every
    /// drawn override doubles as a tuner-invariance check.
    tuner: Option<TunerOverride>,
}

impl Case {
    fn draw(rng: &mut SplitMix64) -> Case {
        let spec = &registry::WORKLOADS[rng.index(registry::WORKLOADS.len())];
        // Tier-sized shapes per workload, keeping data-size parameters
        // consistent with the drawn fleet size.
        let (pairs, nodes): (Vec<(&'static str, u64)>, usize) = match spec.name {
            "nanosort" => {
                let nodes = [16usize, 32, 64, 128, 192][rng.index(5)];
                let kpn = [4u64, 8, 16][rng.index(3)];
                let buckets = [4u64, 8, 16][rng.index(3)].min(nodes as u64);
                let values = rng.chance(1, 3) as u64;
                (
                    vec![
                        ("nodes", nodes as u64),
                        ("kpn", kpn),
                        ("buckets", buckets),
                        ("values", values),
                    ],
                    nodes,
                )
            }
            "millisort" => {
                let cores = [8usize, 16, 32, 64][rng.index(4)];
                let keys = cores as u64 * [16u64, 32, 64][rng.index(3)];
                (vec![("cores", cores as u64), ("keys", keys)], cores)
            }
            "mergemin" => {
                let cores = [8usize, 48, 64, 128, 192][rng.index(5)];
                let vpc = [8u64, 16, 32][rng.index(3)];
                let incast = [1u64, 2, 4, 8][rng.index(4)];
                (
                    vec![("cores", cores as u64), ("vpc", vpc), ("incast", incast)],
                    cores,
                )
            }
            _ => {
                let cores = [8usize, 64, 128][rng.index(3)];
                let lists = [2u64, 3, 4][rng.index(3)];
                let ids = [16u64, 32, 64][rng.index(3)];
                (
                    vec![("cores", cores as u64), ("lists", lists), ("ids", ids)],
                    cores,
                )
            }
        };

        // Perturbation composite: each knob joins independently.
        let mut net = NetConfig::default();
        let mut knobs = Perturbations::default();
        if rng.chance(1, 3) {
            knobs.dist = KeyDistribution::ALL[rng.index(KeyDistribution::ALL.len())];
        }
        if rng.chance(1, 3) {
            net.loss_prob = (200 + rng.next_u64() % 1800, 10_000);
            net.rto_ns = 3_000 + rng.next_u64() % 5_000;
        }
        if rng.chance(1, 4) {
            net.tail_prob = (1, 20 + rng.next_u64() % 80);
            net.tail_extra_ns = 500 + rng.next_u64() % 3_500;
        }
        if rng.chance(1, 4) {
            knobs.stragglers = StragglerConfig {
                count: 1 + rng.index(3),
                factor: 2 + (rng.next_u64() % 7) as u32,
            };
        }
        if rng.chance(1, 4) && nodes >= 2 * LEAF && nodes % LEAF == 0 {
            net.oversub = [4u64, 16, 64][rng.index(3)];
        }
        if rng.chance(1, 8) {
            net.multicast = false;
        }

        // Backend configuration under test.
        let exec = if rng.chance(1, 2) { ExecKind::Opt } else { ExecKind::Par };
        let threads = [2usize, 3, 4, 8][rng.index(4)];
        let window_batch = match rng.index(4) {
            0 => None,
            1 => Some(1),
            2 => Some(4),
            _ => Some(32),
        };
        let force_every = (exec == ExecKind::Opt && rng.chance(1, 4))
            .then(|| 1 + rng.next_u64() % 4);
        let tuner = rng
            .chance(1, 2)
            .then(|| TunerOverride::ALL[rng.index(TunerOverride::ALL.len())]);

        Case {
            spec,
            pairs,
            nodes,
            net,
            knobs,
            seed: rng.next_u64(),
            exec,
            threads,
            window_batch,
            force_every,
            tuner,
        }
    }

    fn label(&self) -> String {
        format!(
            "{} {:?} nodes={} exec={} threads={} wb={:?} force={:?} tuner={} oversub={} \
             loss={:?} stragglers={} dist={} seed={:#x}",
            self.spec.name,
            self.pairs,
            self.nodes,
            self.exec.name(),
            self.threads,
            self.window_batch,
            self.force_every,
            self.tuner.map(TunerOverride::name).unwrap_or("auto"),
            self.net.oversub,
            self.net.loss_prob,
            self.knobs.stragglers.count,
            self.knobs.dist.name(),
            self.seed
        )
    }

    /// Run this case's scenario under an explicit backend configuration.
    fn run(
        &self,
        exec: ExecKind,
        threads: usize,
        window_batch: Option<usize>,
        force_every: Option<u64>,
        tuner: Option<TunerOverride>,
    ) -> RunReport {
        let params = registry::params_from_pairs(self.spec, &self.pairs).unwrap();
        let mut scenario = Scenario::from_dyn((self.spec.build)(&params).unwrap())
            .nodes(self.nodes)
            .net(self.net.clone())
            .perturb(self.knobs.clone())
            .seed(self.seed)
            .threads(threads)
            .exec(exec);
        if let Some(k) = window_batch {
            scenario = scenario.window_batch(k);
        }
        if let Some(n) = force_every {
            scenario = scenario.force_rollback_every(n);
        }
        if let Some(t) = tuner {
            // Share one budget between shard workers and kernel tiles,
            // exactly as `repro --threads N` would.
            let pool = Arc::new(WorkerPool::new(threads));
            scenario = scenario
                .pool(pool.clone())
                .compute_with(Arc::new(RadixCompute::forced(Some(t), pool)));
        }
        scenario
            .run()
            .unwrap_or_else(|e| panic!("{}: {e:#}", self.label()))
    }
}

fn assert_case_identical(case_no: usize, label: &str, seq: &RunReport, got: &RunReport) {
    assert_eq!(
        digest_json(seq, "fuzz"),
        digest_json(got, "fuzz"),
        "case {case_no} [{label}]: digest diverged from SeqExecutor"
    );
    assert_eq!(
        seq.summary.node_stats, got.summary.node_stats,
        "case {case_no} [{label}]: per-node stats diverged"
    );
    assert_eq!(
        seq.summary.net, got.summary.net,
        "case {case_no} [{label}]: net counters diverged"
    );
    assert_eq!(seq.render(), got.render(), "case {case_no} [{label}]: render diverged");
}

/// The campaign: every drawn (scenario, backend) configuration must
/// reproduce the sequential digest byte for byte.
#[test]
fn randomized_configs_reproduce_the_sequential_digest() {
    let cases = fuzz_cases();
    let mut rng = SplitMix64::new(0x4655_5A5A_4E53_5254); // "FUZZ NSRT"
    let mut opt_cases = 0usize;
    for case_no in 0..cases {
        let case = Case::draw(&mut rng);
        // The reference runs the auto tuner at threads 1, so a drawn
        // override must also be digest-invisible, not just exec-invariant.
        let seq = case.run(ExecKind::Seq, 1, None, None, None);
        let got =
            case.run(case.exec, case.threads, case.window_batch, case.force_every, case.tuner);
        assert_case_identical(case_no, &case.label(), &seq, &got);
        if case.exec == ExecKind::Opt {
            opt_cases += 1;
            let p = &got.summary.profile;
            assert_eq!(
                p.speculated,
                p.committed + p.rollbacks,
                "case {case_no} [{}]: every speculative burst must resolve exactly once",
                case.label()
            );
        }
    }
    // The exec draw is a fair coin; a campaign that never exercised the
    // optimistic backend tests nothing new.
    assert!(opt_cases > 0, "campaign of {cases} cases never drew --exec opt");
}

/// Forced-rollback property: with `force_rollback_every(1)` every
/// speculative burst is rolled back and re-executed conservatively, and
/// the result must still be byte-identical — including under loss + RTO
/// and stragglers, where re-execution replays retransmit timers and
/// slowdown factors.
#[test]
fn forced_rollbacks_are_result_invisible() {
    let knob_sets: &[(&str, NetConfig, Perturbations)] = &[
        ("clean", NetConfig::default(), Perturbations::default()),
        (
            "loss+rto",
            NetConfig { loss_prob: (1000, 10_000), rto_ns: 5_000, ..NetConfig::default() },
            Perturbations::default(),
        ),
        (
            "stragglers",
            NetConfig::default(),
            Perturbations {
                stragglers: StragglerConfig { count: 2, factor: 8 },
                ..Default::default()
            },
        ),
    ];
    for spec in registry::WORKLOADS {
        for (label, net, knobs) in knob_sets {
            let run = |exec: ExecKind, threads: usize, force: Option<u64>| {
                let params = registry::params_from_pairs(spec, spec.smoke).unwrap();
                let nodes = params.u64(spec.nodes_param.name).unwrap() as usize;
                let mut scenario = Scenario::from_dyn((spec.build)(&params).unwrap())
                    .nodes(nodes)
                    .net(net.clone())
                    .perturb(knobs.clone())
                    .seed(CONFORMANCE_SEED)
                    .threads(threads)
                    .exec(exec);
                if let Some(n) = force {
                    scenario = scenario.force_rollback_every(n);
                }
                scenario.run().unwrap_or_else(|e| panic!("{} [{label}]: {e:#}", spec.name))
            };
            let seq = run(ExecKind::Seq, 1, None);
            let forced = run(ExecKind::Opt, 3, Some(1));
            assert_case_identical(0, &format!("{} {label} force=1", spec.name), &seq, &forced);
            let p = &forced.summary.profile;
            assert_eq!(
                p.committed, 0,
                "{} [{label}]: force=1 must roll back every burst",
                spec.name
            );
            assert_eq!(p.rollbacks, p.speculated, "{} [{label}]", spec.name);
        }
    }
}

/// The service opts out of speculation (`speculation_safe() == false`:
/// destructive worker-slot handoff + `Arc`-shared scheduler state), so
/// `--exec opt` must take the conservative path — zero speculative
/// bursts — and stay byte-identical to the sequential reference.
#[test]
fn service_smoke_under_opt_is_byte_identical_without_speculation() {
    let (workers, arrivals) = service::service_tier(Tier::Smoke, Mix::Nanosort);
    let run = |exec: ExecKind, threads: usize| {
        let mut cfg = ServiceConfig::new(workers, arrivals.clone(), SchedPolicy::Fifo)
            .expect("service config");
        cfg.threads = threads;
        cfg.exec = exec;
        service::run_service(&cfg, CONFORMANCE_SEED).expect("service run")
    };
    let seq = run(ExecKind::Seq, 1);
    let opt = run(ExecKind::Opt, 3);
    assert_eq!(
        service::service_digest(&seq, "fuzz"),
        service::service_digest(&opt, "fuzz"),
        "service digest must be executor-invariant"
    );
}
