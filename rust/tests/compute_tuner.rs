//! Kernel property battery for the tuner-dispatched radix plane
//! (DESIGN.md §8): every forced `Algorithm` family × every perturbation
//! distribution × sizes straddling each tuner threshold, byte-compared
//! to the `NativeCompute` oracle, plus the forced-tuner conformance
//! matrix proving the tuner is digest-invisible and the shared-pool
//! contention pins (live workers never exceed the `--threads` budget).

use std::sync::Arc;

use nanosort::algo::millisort::MilliSort;
use nanosort::algo::nanosort::NanoSort;
use nanosort::compute::{
    LocalCompute, NativeCompute, RadixCompute, StandardTuner, TunerOverride, DEFAULT_CROSSOVER,
};
use nanosort::conformance::{digest_json, CONFORMANCE_SEED};
use nanosort::perturb::KeyDistribution;
use nanosort::pool::WorkerPool;
use nanosort::scenario::{RunReport, Scenario};
use nanosort::sim::ExecKind;

/// Every dispatch the tuner can make: `auto` (the `StandardTuner`
/// policy) plus each forced family. `Par` resolves to `Regions` for
/// bare keys and `MtOop` for pairs, so both parallel kernels run.
fn forces() -> Vec<(&'static str, Option<TunerOverride>)> {
    let mut f: Vec<(&'static str, Option<TunerOverride>)> = vec![("auto", None)];
    for o in TunerOverride::ALL {
        f.push((o.name(), Some(o)));
    }
    f
}

fn plane(force: Option<TunerOverride>, budget: usize) -> RadixCompute {
    RadixCompute::forced(force, Arc::new(WorkerPool::new(budget)))
}

/// Sizes one below, at, and one above every `StandardTuner` threshold,
/// so a fencepost slip in any comparison flips at least one cell.
fn threshold_sizes() -> Vec<usize> {
    vec![
        1,
        2,
        DEFAULT_CROSSOVER - 1,
        DEFAULT_CROSSOVER,
        DEFAULT_CROSSOVER + 1,
        StandardTuner::SKA_MIN - 1,
        StandardTuner::SKA_MIN,
        StandardTuner::SKA_MIN + 1,
        StandardTuner::PAR_MIN - 1,
        StandardTuner::PAR_MIN,
        10_000,
    ]
}

/// Edge shapes sized past `SKA_MIN`/`PAR_MIN` so the degenerate inputs
/// reach the recursive and parallel kernels, not just the crossover
/// fallback.
fn edge_blocks() -> Vec<(&'static str, Vec<u64>)> {
    vec![
        ("empty", vec![]),
        ("single", vec![42]),
        ("single-max", vec![u64::MAX]),
        ("all-equal", vec![7; 10_000]),
        (
            "max-boundary",
            (0..9_000u64).map(|i| u64::MAX - (i * 37) % 5).collect(),
        ),
        (
            "duplicate-heavy",
            (0..10_000u64).map(|i| (i * 0x9E37_79B9) % 3).collect(),
        ),
    ]
}

fn keys_for(dist: KeyDistribution, n: usize) -> Vec<u64> {
    dist.partitioned_keys(0xC0FFEE ^ n as u64, n, 1).into_iter().next().unwrap()
}

/// Satellite 1, core cell: every forced family sorts every distribution
/// at every threshold-straddling size byte-identically to the oracle —
/// keys and pairs both, so the unstable kernels are proven to never
/// leak into the stable `sort_pairs` path.
#[test]
fn every_family_matches_the_oracle_across_distributions_and_thresholds() {
    for (fname, force) in forces() {
        for budget in [1usize, 4] {
            let rc = plane(force, budget);
            for dist in KeyDistribution::ALL {
                for n in threshold_sizes() {
                    let block = keys_for(dist, n);
                    let mut a = block.clone();
                    let mut b = block.clone();
                    NativeCompute.sort(&mut a);
                    rc.sort(&mut b);
                    assert_eq!(
                        a, b,
                        "sort diverged: tuner={fname} budget={budget} dist={} n={n}",
                        dist.name()
                    );
                    let pairs: Vec<(u64, u64)> =
                        block.into_iter().enumerate().map(|(i, k)| (k, i as u64)).collect();
                    let mut a = pairs.clone();
                    let mut b = pairs;
                    NativeCompute.sort_pairs(&mut a);
                    rc.sort_pairs(&mut b);
                    assert_eq!(
                        a, b,
                        "sort_pairs diverged: tuner={fname} budget={budget} dist={} n={n}",
                        dist.name()
                    );
                }
            }
        }
    }
}

/// Satellite 1: degenerate shapes through every family. All-equal and
/// duplicate-heavy inputs exercise the trivial-digit skip; the
/// `u64::MAX` boundary exercises the top bucket of every histogram.
#[test]
fn every_family_matches_the_oracle_on_edge_shapes() {
    for (fname, force) in forces() {
        for budget in [1usize, 4] {
            let rc = plane(force, budget);
            for (label, block) in edge_blocks() {
                let mut a = block.clone();
                let mut b = block.clone();
                NativeCompute.sort(&mut a);
                rc.sort(&mut b);
                assert_eq!(a, b, "sort diverged: tuner={fname} budget={budget} shape={label}");
                let pairs: Vec<(u64, u64)> =
                    block.into_iter().enumerate().map(|(i, k)| (k, i as u64)).collect();
                let mut a = pairs.clone();
                let mut b = pairs;
                NativeCompute.sort_pairs(&mut a);
                rc.sort_pairs(&mut b);
                assert_eq!(
                    a, b,
                    "sort_pairs diverged: tuner={fname} budget={budget} shape={label}"
                );
            }
        }
    }
}

/// §8 stability contract, pinned independently of the oracle: with
/// payload = input position, `sort_pairs` must equal a std stable sort
/// by key alone under every forced family.
#[test]
fn sort_pairs_is_stable_under_every_family() {
    let pairs: Vec<(u64, u64)> = (0..10_000u64).map(|i| ((i * 31) % 7, i)).collect();
    let mut expect = pairs.clone();
    expect.sort_by_key(|&(k, _)| k);
    for (fname, force) in forces() {
        for budget in [1usize, 4] {
            let mut got = pairs.clone();
            plane(force, budget).sort_pairs(&mut got);
            assert_eq!(got, expect, "stability broken: tuner={fname} budget={budget}");
        }
    }
}

/// Satellite 4: the comparative crossover is a `TuningParams` field,
/// not a buried constant — exact at the default boundary (95/96/97)
/// and at a custom `with_crossover(10)` boundary (9/10/11).
#[test]
fn crossover_is_configurable_and_exact_at_the_boundary() {
    assert_eq!(DEFAULT_CROSSOVER, 96, "§8 documents the default crossover");
    for (crossover, rc) in [
        (DEFAULT_CROSSOVER, plane(None, 1)),
        (10, plane(None, 1).with_crossover(10)),
    ] {
        for n in [crossover - 1, crossover, crossover + 1] {
            let block = keys_for(KeyDistribution::Uniform, n);
            let mut a = block.clone();
            let mut b = block;
            NativeCompute.sort(&mut a);
            rc.sort(&mut b);
            assert_eq!(a, b, "crossover={crossover} n={n} diverged from the oracle");
        }
    }
}

fn smoke_report(force: Option<TunerOverride>, threads: usize, exec: ExecKind) -> RunReport {
    let pool = Arc::new(WorkerPool::new(threads));
    Scenario::new(NanoSort {
        keys_per_node: 16,
        buckets: 8,
        median_incast: 4,
        shuffle_values: true,
        ..Default::default()
    })
    .nodes(64)
    .dist(KeyDistribution::Zipfian)
    .seed(CONFORMANCE_SEED)
    .threads(threads)
    .exec(exec)
    .pool(pool.clone())
    .compute_with(Arc::new(RadixCompute::forced(force, pool)))
    .run()
    .expect("smoke scenario")
}

/// Satellite 1, matrix cell: a forced `NANOSORT_TUNER` is invisible in
/// the conformance digest across every (family × threads × executor)
/// combination — the tuner may only change *how* a slice gets sorted,
/// never *what* the simulation observes.
#[test]
fn forced_tuner_matrix_is_digest_invisible() {
    let baseline = digest_json(&smoke_report(None, 1, ExecKind::Seq), "tuner");
    for (fname, force) in forces() {
        for threads in [1usize, 4] {
            for exec in [ExecKind::Seq, ExecKind::Par, ExecKind::Opt] {
                let got = digest_json(&smoke_report(force, threads, exec), "tuner");
                assert_eq!(
                    baseline, got,
                    "digest diverged: tuner={fname} threads={threads} exec={exec:?}"
                );
            }
        }
    }
}

fn millisort_report(
    force: Option<TunerOverride>,
    pool: Arc<WorkerPool>,
    threads: usize,
    exec: ExecKind,
) -> RunReport {
    Scenario::new(MilliSort { total_keys: 65_536, ..Default::default() })
        .nodes(8)
        .seed(CONFORMANCE_SEED)
        .threads(threads)
        .exec(exec)
        .pool(pool.clone())
        .compute_with(Arc::new(RadixCompute::forced(force, pool)))
        .run()
        .expect("millisort scenario")
}

/// Satellite 2: shard workers and kernel tiles draw from ONE budget.
/// 8192 keys/core clears `PAR_MIN`, so the forced-Par plane fans out
/// inside Par/Opt shard workers at `--threads 4`; the digest must match
/// seq@1, and the pool's high-water mark must never exceed the budget
/// (the pool also hard-asserts this on every `enter`).
#[test]
fn executors_and_kernels_respect_one_thread_budget() {
    let seq_pool = Arc::new(WorkerPool::new(1));
    let baseline =
        digest_json(&millisort_report(None, seq_pool, 1, ExecKind::Seq), "contention");
    for exec in [ExecKind::Par, ExecKind::Opt] {
        let pool = Arc::new(WorkerPool::new(4));
        let report = millisort_report(Some(TunerOverride::Par), pool.clone(), 4, exec);
        assert_eq!(
            baseline,
            digest_json(&report, "contention"),
            "parallel kernels under {exec:?}@4 diverged from seq@1"
        );
        assert!(
            pool.max_live() <= 4,
            "live workers ({}) exceeded the --threads budget",
            pool.max_live()
        );
    }
}

/// Satellite 2, positive signal: a forced-Par kernel on a budget-4 pool
/// actually borrows workers (the sharing is real, not a no-op) while
/// staying within budget and byte-identical to the oracle.
#[test]
fn parallel_kernels_borrow_from_the_shared_pool() {
    let pool = Arc::new(WorkerPool::new(4));
    let rc = RadixCompute::forced(Some(TunerOverride::Par), pool.clone());
    let block = keys_for(KeyDistribution::Uniform, 65_536);
    let mut oracle = block.clone();
    let mut got = block;
    NativeCompute.sort(&mut oracle);
    rc.sort(&mut got);
    assert_eq!(got, oracle);
    assert!(pool.max_live() >= 1, "parallel kernel never borrowed a pool worker");
    assert!(
        pool.max_live() <= 4,
        "live workers ({}) exceeded the pool budget",
        pool.max_live()
    );
}
