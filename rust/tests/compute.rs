//! Data-plane differential suite: every kernel of every offline backend
//! against the `NativeCompute` oracle (DESIGN.md §8).
//!
//! The §8 contract is *byte-identical outputs* — including tie-breaks —
//! so a radix run and a native run produce the same conformance digest.
//! This suite pins the kernels directly: across every input distribution
//! the perturbation layer can generate (uniform, zipfian, sorted,
//! few-distinct, adversarial-bucket), across edge shapes (empty, single
//! key, all-equal, `u64::MAX` boundary), and across the small-input
//! crossover where the radix backend falls back to comparison sorts.

use nanosort::compute::{LocalCompute, NativeCompute, RadixCompute};
use nanosort::perturb::KeyDistribution;
use nanosort::scenario::Scenario;
use nanosort::sim::SplitMix64;

/// Key blocks in the shapes the simulated cores actually sort: per-node
/// slices of every perturbation-layer distribution, at sizes spanning
/// the radix crossover.
fn distribution_blocks() -> Vec<(String, Vec<u64>)> {
    let mut blocks = Vec::new();
    for d in KeyDistribution::ALL {
        for (cores, total) in [(8usize, 64usize), (4, 512), (2, 8192)] {
            for (i, part) in d.partitioned_keys(0xC0FFEE, total, cores).into_iter().enumerate()
            {
                if i < 2 {
                    blocks.push((format!("{}/{total}k/core{i}", d.name()), part));
                }
            }
        }
    }
    blocks
}

/// Edge shapes: empty, singleton, all-equal, and the u64 boundary. The
/// kernels are total functions over u64 even though the simulator's
/// generator keeps keys `< u64::MAX`.
fn edge_blocks() -> Vec<(String, Vec<u64>)> {
    vec![
        ("empty".into(), vec![]),
        ("single".into(), vec![42]),
        ("single-max".into(), vec![u64::MAX]),
        ("all-equal".into(), vec![7; 300]),
        ("two".into(), vec![9, 3]),
        (
            "max-boundary".into(),
            (0..400u64).map(|i| u64::MAX - (i * 37) % 5).collect(),
        ),
        ("zero-heavy".into(), {
            let mut v = vec![0u64; 200];
            v.extend([u64::MAX, 1, 0, u64::MAX - 1]);
            v
        }),
    ]
}

fn all_blocks() -> Vec<(String, Vec<u64>)> {
    let mut blocks = distribution_blocks();
    blocks.extend(edge_blocks());
    blocks
}

/// Pivot lists exercising both the short (branchless-scan) and long
/// (binary-search) tagging paths, including duplicate pivots.
fn pivot_lists(rng: &mut SplitMix64) -> Vec<Vec<u64>> {
    let mut lists = vec![
        vec![],
        vec![1u64 << 32],
        vec![0, 0, u64::MAX - 1],
    ];
    for p in [3usize, 15, 63, 255] {
        let mut pivots: Vec<u64> = (0..p).map(|_| rng.next_u64()).collect();
        pivots.sort_unstable();
        lists.push(pivots);
    }
    lists
}

#[test]
fn sort_matches_oracle_on_every_distribution_and_edge() {
    let (native, radix) = (NativeCompute, RadixCompute::default());
    for (label, block) in all_blocks() {
        let mut a = block.clone();
        let mut b = block;
        native.sort(&mut a);
        radix.sort(&mut b);
        assert_eq!(a, b, "sort diverged on {label}");
    }
}

#[test]
fn sort_pairs_matches_oracle_including_tie_order() {
    let (native, radix) = (NativeCompute, RadixCompute::default());
    for (label, block) in all_blocks() {
        // Payload = input position, so any tie-break difference between
        // the planes shows up as a payload mismatch.
        let pairs: Vec<(u64, u64)> =
            block.into_iter().enumerate().map(|(i, k)| (k, i as u64)).collect();
        let mut a = pairs.clone();
        let mut b = pairs;
        native.sort_pairs(&mut a);
        radix.sort_pairs(&mut b);
        assert_eq!(a, b, "sort_pairs diverged on {label}");
    }
}

#[test]
fn bucketize_and_partition_match_oracle() {
    let (native, radix) = (NativeCompute, RadixCompute::default());
    let mut rng = SplitMix64::new(0xBEEF);
    let pivot_sets = pivot_lists(&mut rng);
    for (label, block) in all_blocks() {
        for (pi, pivots) in pivot_sets.iter().enumerate() {
            assert_eq!(
                native.bucketize(&block, pivots),
                radix.bucketize(&block, pivots),
                "bucketize diverged on {label} pivots#{pi}"
            );
            let a = native.partition(&block, pivots);
            let b = radix.partition(&block, pivots);
            assert_eq!(a, b, "partition diverged on {label} pivots#{pi}");
            assert_eq!(a.len(), pivots.len() + 1, "{label}: bucket count");
            assert_eq!(
                a.iter().map(Vec::len).sum::<usize>(),
                block.len(),
                "{label}: partition must conserve keys"
            );
            let pairs: Vec<(u64, u64)> =
                block.iter().enumerate().map(|(i, &k)| (k, i as u64)).collect();
            assert_eq!(
                native.partition_pairs(&pairs, pivots),
                radix.partition_pairs(&pairs, pivots),
                "partition_pairs diverged on {label} pivots#{pi}"
            );
        }
    }
}

#[test]
fn min_and_median_combine_match_oracle() {
    let (native, radix) = (NativeCompute, RadixCompute::default());
    for (label, block) in all_blocks() {
        assert_eq!(native.min(&block), radix.min(&block), "min diverged on {label}");
    }
    let mut rng = SplitMix64::new(0xD0E);
    for (m, p) in [(1usize, 5usize), (2, 15), (7, 15), (16, 3), (5, 1)] {
        let owned: Vec<Vec<u64>> =
            (0..m).map(|_| (0..p).map(|_| rng.next_u64()).collect()).collect();
        let rows: Vec<&[u64]> = owned.iter().map(|r| r.as_slice()).collect();
        assert_eq!(
            native.median_combine(&rows),
            radix.median_combine(&rows),
            "median_combine diverged at m={m} p={p}"
        );
    }
}

/// End to end: the same seeded NanoSort scenario — duplicate-heavy
/// distributions included, where the stable tie-break contract is
/// load-bearing for the value phase — renders identically on both
/// planes (same makespan, counters, validation, and metrics).
#[test]
fn nanosort_scenario_is_plane_invariant_under_every_distribution() {
    use nanosort::algo::nanosort::NanoSort;
    use nanosort::coordinator::ComputeChoice;
    for d in KeyDistribution::ALL {
        let run = |choice: ComputeChoice| {
            Scenario::new(NanoSort {
                keys_per_node: 8,
                buckets: 4,
                median_incast: 4,
                shuffle_values: true,
                ..Default::default()
            })
            .nodes(16)
            .dist(d)
            .compute(choice)
            .seed(0xC0FFEE)
            .run()
            .unwrap()
        };
        let native = run(ComputeChoice::Native);
        let radix = run(ComputeChoice::Radix);
        assert!(native.validation.ok(), "{}: {}", d.name(), native.validation.detail);
        assert!(radix.validation.ok(), "{}: {}", d.name(), radix.validation.detail);
        // Everything but the plane name must match; compare the rendered
        // reports with the name normalized away.
        assert_eq!(
            native.render().replace("compute=native", "compute=<plane>"),
            radix.render().replace("compute=radix", "compute=<plane>"),
            "{}: radix scenario diverged from the native oracle",
            d.name()
        );
    }
}

/// MilliSort drives the long-pivot-list (cores-1 boundaries) partition
/// path; cross-check it end to end as well.
#[test]
fn millisort_scenario_is_plane_invariant() {
    use nanosort::algo::millisort::MilliSort;
    use nanosort::coordinator::ComputeChoice;
    let run = |choice: ComputeChoice| {
        Scenario::new(MilliSort::default())
            .nodes(64)
            .compute(choice)
            .seed(0xC0FFEE)
            .run()
            .unwrap()
    };
    let native = run(ComputeChoice::Native);
    let radix = run(ComputeChoice::Radix);
    assert!(native.validation.ok() && radix.validation.ok());
    assert_eq!(
        native.render().replace("compute=native", "compute=<plane>"),
        radix.render().replace("compute=radix", "compute=<plane>"),
        "millisort: radix scenario diverged from the native oracle"
    );
}
