//! Executor equivalence suite: the determinism contract of the pluggable
//! execution backends (`nanosort::sim::exec`), pinned end to end.
//!
//! For every workload, tier, and perturbation knob, `SeqExecutor`
//! (threads = 1) and `ParExecutor` (threads > 1, and `0` = all cores)
//! must produce **byte-identical** conformance digests — the same
//! property `repro paper --threads N` gates on, and the reason the
//! goldens and sweep fingerprints stay authoritative under parallel
//! simulation. Window-barrier edge cases (zero lookahead, single-node
//! shards, more threads than nodes/leaves) ride along.

use nanosort::algo::nanosort::NanoSort;
use nanosort::conformance::{digest_json, run_tier, Tier, CONFORMANCE_SEED};
use nanosort::coordinator::ComputeChoice;
use nanosort::net::NetConfig;
use nanosort::perturb::{KeyDistribution, Perturbations, StragglerConfig};
use nanosort::scenario::{registry, RunReport, Scenario};

/// Run one registry workload at its smoke shape with explicit knobs.
fn run_knobs(
    spec: &registry::WorkloadSpec,
    net: NetConfig,
    perturb: Perturbations,
    threads: usize,
) -> RunReport {
    let params = registry::params_from_pairs(spec, spec.smoke).unwrap();
    let nodes = params.u64(spec.nodes_param.name).unwrap() as usize;
    Scenario::from_dyn((spec.build)(&params).unwrap())
        .nodes(nodes)
        .net(net)
        .perturb(perturb)
        .seed(CONFORMANCE_SEED)
        .threads(threads)
        .run()
        .unwrap_or_else(|e| panic!("{} (threads={threads}): {e:#}", spec.name))
}

fn assert_digests_match(spec_name: &str, label: &str, seq: &RunReport, par: &RunReport) {
    assert_eq!(
        digest_json(seq, "exec"),
        digest_json(par, "exec"),
        "{spec_name} [{label}]: ParExecutor digest diverged from SeqExecutor"
    );
    // The digest already covers makespan/counters/stage sums; rendered
    // reports add the human-facing surface.
    assert_eq!(seq.render(), par.render(), "{spec_name} [{label}] render");
}

/// Every workload, smoke tier, unperturbed: seq == par at several thread
/// counts including "all cores".
#[test]
fn all_workloads_smoke_tier_digest_equality() {
    for spec in registry::WORKLOADS {
        let seq = run_knobs(spec, NetConfig::default(), Perturbations::default(), 1);
        for threads in [2usize, 3, 4, 0] {
            let par = run_knobs(spec, NetConfig::default(), Perturbations::default(), threads);
            assert_digests_match(spec.name, &format!("threads={threads}"), &seq, &par);
        }
    }
}

/// Every workload at the mid tier (4,096-core class shapes). Sized for
/// the release profile; CI runs with `--include-ignored`.
#[test]
#[ignore = "release-profile scale test; CI runs it via --include-ignored"]
fn all_workloads_mid_tier_digest_equality() {
    for spec in registry::WORKLOADS {
        let (seq, _) = run_tier(spec, Tier::Mid, ComputeChoice::Native, 1).unwrap();
        let (par, _) = run_tier(spec, Tier::Mid, ComputeChoice::Native, 4).unwrap();
        assert_digests_match(spec.name, "mid", &seq, &par);
    }
}

/// Each perturbation knob, on its own, across every workload: the
/// per-node RNG streams and destination-side contention must keep the
/// parallel backend exact even when the knobs are live.
#[test]
fn each_perturbation_knob_stays_exact_in_parallel() {
    let knob_sets: &[(&str, NetConfig, Perturbations)] = &[
        (
            "skew=zipfian",
            NetConfig::default(),
            Perturbations { dist: KeyDistribution::Zipfian, ..Default::default() },
        ),
        (
            "loss+rto",
            NetConfig { loss_prob: (1000, 10_000), rto_ns: 5_000, ..NetConfig::default() },
            Perturbations::default(),
        ),
        (
            "stragglers",
            NetConfig::default(),
            Perturbations {
                stragglers: StragglerConfig { count: 2, factor: 8 },
                ..Default::default()
            },
        ),
        (
            "tail",
            NetConfig { tail_prob: (1, 20), tail_extra_ns: 2_000, ..NetConfig::default() },
            Perturbations::default(),
        ),
    ];
    for spec in registry::WORKLOADS {
        for (label, net, perturb) in knob_sets {
            let seq = run_knobs(spec, net.clone(), perturb.clone(), 1);
            let par = run_knobs(spec, net.clone(), perturb.clone(), 3);
            assert_digests_match(spec.name, label, &seq, &par);
        }
    }
}

/// Oversubscription forces leaf-aligned shards (per-leaf spine downlink
/// registers). A multi-leaf fleet must still shard exactly; a
/// single-leaf fleet degrades to the sequential backend.
#[test]
fn oversubscription_shards_leaf_aligned_and_stays_exact() {
    let net = NetConfig { oversub: 64, ..NetConfig::default() };
    let run = |threads: usize| {
        Scenario::new(NanoSort { keys_per_node: 8, buckets: 4, median_incast: 4, ..Default::default() })
            .nodes(256) // 4 leaves
            .net(net.clone())
            .seed(CONFORMANCE_SEED)
            .threads(threads)
            .run()
            .unwrap()
    };
    let seq = run(1);
    for threads in [2usize, 4, 16] {
        let par = run(threads);
        assert_digests_match("nanosort", &format!("oversub threads={threads}"), &seq, &par);
    }
    // Single-leaf fleet (16 nodes) + oversub: only one leaf-aligned shard
    // exists; the parallel entry point must fall back, not wedge.
    let spec = registry::find("nanosort").unwrap();
    let seq = run_knobs(spec, net.clone(), Perturbations::default(), 1);
    let par = run_knobs(spec, net, Perturbations::default(), 8);
    assert_digests_match("nanosort", "oversub single-leaf fallback", &seq, &par);
}

/// All knobs composed at once — the hardest determinism case (skewed
/// inputs + loss + tails + stragglers + oversub on a multi-leaf fleet).
#[test]
fn composed_perturbations_stay_exact_in_parallel() {
    let net = NetConfig {
        loss_prob: (500, 10_000),
        rto_ns: 5_000,
        tail_prob: (1, 50),
        tail_extra_ns: 2_000,
        oversub: 16,
        ..NetConfig::default()
    };
    let knobs = Perturbations {
        dist: KeyDistribution::Zipfian,
        stragglers: StragglerConfig { count: 3, factor: 4 },
    };
    let run = |threads: usize| {
        Scenario::new(NanoSort { keys_per_node: 8, buckets: 4, median_incast: 4, ..Default::default() })
            .nodes(256)
            .net(net.clone())
            .perturb(knobs.clone())
            .seed(CONFORMANCE_SEED)
            .threads(threads)
            .run()
            .unwrap()
    };
    let seq = run(1);
    let par = run(4);
    assert!(seq.validation.ok(), "{}", seq.validation.detail);
    assert_digests_match("nanosort", "composed", &seq, &par);
}

/// Window-barrier edge cases.
#[test]
fn window_barrier_edge_cases() {
    // Zero lookahead (degenerate fabric: no NIC overhead, no headers):
    // the parallel backend must fall back to sequential semantics.
    let degenerate = NetConfig { nic_overhead_ns: 0, header_bytes: 0, ..NetConfig::default() };
    let spec = registry::find("mergemin").unwrap();
    let seq = run_knobs(spec, degenerate.clone(), Perturbations::default(), 1);
    let par = run_knobs(spec, degenerate, Perturbations::default(), 4);
    assert_digests_match("mergemin", "zero lookahead", &seq, &par);

    // Single-node shards: a 2-core fleet on 2 threads (one node each).
    let two = |threads: usize| {
        Scenario::new(nanosort::algo::mergemin::MergeMin { values_per_core: 8, incast: 2 })
            .nodes(2)
            .seed(CONFORMANCE_SEED)
            .threads(threads)
            .run()
            .unwrap()
    };
    assert_digests_match("mergemin", "single-node shards", &two(1), &two(2));

    // More threads than nodes: shard count clamps, no empty shard wedges.
    assert_digests_match("mergemin", "threads > nodes", &two(1), &two(64));
}

/// Small-message inlining is digest-invisible: forcing every
/// `SmallWords` payload onto the boxed (heap) representation must
/// reproduce the inline reference digests byte-for-byte, across every
/// workload and all three backends. The flag only changes the in-memory
/// representation — wire-byte accounting reads the logical length — so
/// any divergence here means the inline path leaked into semantics.
#[test]
fn inline_and_boxed_small_messages_share_digests() {
    use nanosort::nanopu::force_boxed_small_words;
    use nanosort::sim::ExecKind;

    let run = |spec: &registry::WorkloadSpec, exec: ExecKind, threads: usize| {
        let params = registry::params_from_pairs(spec, spec.smoke).unwrap();
        let nodes = params.u64(spec.nodes_param.name).unwrap() as usize;
        Scenario::from_dyn((spec.build)(&params).unwrap())
            .nodes(nodes)
            .seed(CONFORMANCE_SEED)
            .exec(exec)
            .threads(threads)
            .run()
            .unwrap_or_else(|e| {
                panic!("{} ({} threads={threads}): {e:#}", spec.name, exec.name())
            })
    };
    for spec in registry::WORKLOADS {
        force_boxed_small_words(false);
        let inline = run(spec, ExecKind::Seq, 1);
        force_boxed_small_words(true);
        let boxed_seq = run(spec, ExecKind::Seq, 1);
        let boxed_par = run(spec, ExecKind::Par, 3);
        let boxed_opt = run(spec, ExecKind::Opt, 4);
        force_boxed_small_words(false);
        assert_digests_match(spec.name, "boxed seq", &inline, &boxed_seq);
        assert_digests_match(spec.name, "boxed par threads=3", &inline, &boxed_par);
        assert_digests_match(spec.name, "boxed opt threads=4", &inline, &boxed_opt);
    }
}

/// Different seeds still disagree with each other under the parallel
/// backend (it must not collapse seed sensitivity while being exact).
#[test]
fn parallel_backend_keeps_seed_sensitivity() {
    let run = |seed: u64| {
        Scenario::new(NanoSort { keys_per_node: 8, buckets: 4, median_incast: 4, ..Default::default() })
            .nodes(16)
            .seed(seed)
            .threads(4)
            .run()
            .unwrap()
    };
    assert_ne!(
        digest_json(&run(7), "exec"),
        digest_json(&run(8), "exec"),
        "different seeds must produce different digests in parallel too"
    );
}
