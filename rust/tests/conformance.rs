//! Golden-report conformance gate: one seeded smoke-tier run per
//! registered workload, digested canonically and compared against the
//! checked-in goldens under `rust/conformance/golden/`.
//!
//! - A missing golden (fresh workload, fresh checkout) is created and
//!   reported — commit it to pin the result.
//! - Any seeded-result drift fails with a line diff. Intentional changes
//!   are accepted with `BLESS_GOLDEN=1 cargo test -q --test conformance`.
//! - Because this iterates the registry, adding a workload without a
//!   passing smoke config — or without a committed golden — shows up in
//!   CI automatically.

use nanosort::conformance::{self, GoldenOutcome, Tier};
use nanosort::coordinator::ComputeChoice;
use nanosort::scenario::registry;
use nanosort::sim::Time;

/// Every registry smoke config must be executable: build from the
/// spec's smoke tuple, run through `Scenario`, and validate. A workload
/// registered with a broken (or absent) smoke tuple fails here.
#[test]
fn every_registry_smoke_config_runs_and_validates() {
    assert!(registry::WORKLOADS.len() >= 4, "all four workloads registered");
    for spec in registry::WORKLOADS {
        assert!(
            !spec.smoke.is_empty(),
            "{}: workloads must declare a CI-small smoke tuple",
            spec.name
        );
        let (report, _) = conformance::run_tier(spec, Tier::Smoke, ComputeChoice::Native, 1)
            .unwrap_or_else(|e| panic!("{}: smoke run: {e:#}", spec.name));
        assert!(
            report.validation.ok(),
            "{}: smoke validation failed: {}",
            spec.name,
            report.validation.detail
        );
        assert!(report.runtime() > Time::ZERO, "{}", spec.name);
        assert!(report.summary.events > 0, "{}", spec.name);
    }
}

/// The golden snapshot gate: seeded smoke digests for all four workloads
/// vs `rust/conformance/golden/<workload>.json`.
#[test]
fn golden_digests_match_for_every_workload() {
    let mut blessed = Vec::new();
    for spec in registry::WORKLOADS {
        let (report, _) = conformance::run_tier(spec, Tier::Smoke, ComputeChoice::Native, 1)
            .unwrap_or_else(|e| panic!("{}: smoke run: {e:#}", spec.name));
        let digest = conformance::digest_json(&report, Tier::Smoke.name());
        // One name per (workload, tier), shared with `repro paper`:
        // blessing either path updates the same file.
        let name = format!("{}_{}", spec.name, Tier::Smoke.name());
        match conformance::check_golden(&name, &digest, false)
            .unwrap_or_else(|e| panic!("{}: golden io: {e:#}", spec.name))
        {
            GoldenOutcome::Matched => {}
            GoldenOutcome::Blessed { path, created } => {
                eprintln!(
                    "golden {}: {} {} — commit it to pin this result",
                    spec.name,
                    if created { "created" } else { "re-blessed" },
                    path.display()
                );
                blessed.push(spec.name);
            }
            GoldenOutcome::Mismatch { path, diff } => panic!(
                "{}: seeded-result drift vs {}:\n{}\naccept intentional changes with \
                 BLESS_GOLDEN=1 cargo test -q --test conformance (or `repro paper --bless` \
                 for the paper-command goldens)",
                spec.name,
                path.display(),
                diff
            ),
        }
    }
    if !blessed.is_empty() {
        eprintln!("note: goldens written for {blessed:?}; they gate from the next run on");
    }
}

/// The digest itself must be a pure function of the seeded run — if this
/// flakes, golden comparisons are meaningless.
#[test]
fn digests_are_deterministic_per_workload() {
    for spec in registry::WORKLOADS {
        let (a, _) =
            conformance::run_tier(spec, Tier::Smoke, ComputeChoice::Native, 1).unwrap();
        let (b, _) =
            conformance::run_tier(spec, Tier::Smoke, ComputeChoice::Native, 1).unwrap();
        assert_eq!(
            conformance::digest_json(&a, "smoke"),
            conformance::digest_json(&b, "smoke"),
            "{}: digest not deterministic",
            spec.name
        );
    }
}

/// Mid tier stays runnable (the paper tier is covered by `repro paper`;
/// at 65,536 cores it is too heavy for `cargo test`). Ignored by default:
/// 4,096 cores × 64 K keys is sized for the release profile, and CI runs
/// this suite with `--release -- --include-ignored`.
#[test]
#[ignore = "release-profile scale test; CI runs it via --include-ignored"]
fn mid_tier_validates_for_nanosort() {
    let spec = registry::find("nanosort").unwrap();
    let (report, _) = conformance::run_tier(spec, Tier::Mid, ComputeChoice::Native, 1).unwrap();
    assert!(report.validation.ok(), "{}", report.validation.detail);
    assert_eq!(report.nodes, 4096);
    let sort = report.validation.sort.as_ref().unwrap();
    assert_eq!(sort.total_keys, 65_536);
    assert!(sort.values_intact, "mid tier runs the GraySort value phase");
}
