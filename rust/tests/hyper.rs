//! Hyper-tier memory-path contract tests (DESIGN.md §11).
//!
//! The memory diet ships three observable switches — per-node streamed
//! input generation, disk-spilled output sinks, and the hyper scale
//! tiers that force the first — and one contract covers all of them:
//! every switch is **digest-invisible**. A run's canonical conformance
//! digest is a pure function of `(workload, tier, seed)`; whether the
//! input was materialized or streamed, whether the output detoured
//! through spill bins, and which executor backend drove the simulation
//! must not change a byte of it.
//!
//! Frame-level spill round-trips (empty runs, single-node, duplicate-
//! heavy blocks, out-of-order rejection) live next to the implementation
//! in `rust/src/graysort/spill.rs`; this file pins the end-to-end
//! scenario contract.

use std::path::{Path, PathBuf};

use nanosort::conformance::{self, digest_json, tier_params, Tier};
use nanosort::coordinator::ComputeChoice;
use nanosort::graysort::take_bytes_spilled;
use nanosort::perturb::{KeyDistribution, Perturbations};
use nanosort::scenario::registry::{self, WorkloadSpec};
use nanosort::scenario::{RunReport, Scenario};
use nanosort::sim::ExecKind;

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("nanosort_hyper_test_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// One tier run through the single `Scenario` code path with the memory
/// knobs under test. Everything else matches `conformance::run_tier`.
fn run_shaped(
    spec: &'static WorkloadSpec,
    tier: Tier,
    stream: bool,
    spill: Option<&Path>,
    dist: KeyDistribution,
    threads: usize,
    exec: ExecKind,
) -> RunReport {
    let params = registry::params_from_pairs(spec, &tier_params(spec, tier)).unwrap();
    let workload = (spec.build)(&params).unwrap();
    let nodes = params.u64(spec.nodes_param.name).unwrap() as usize;
    let mut s = Scenario::from_dyn(workload)
        .nodes(nodes)
        .compute(ComputeChoice::Native)
        .perturb(Perturbations { dist, ..Default::default() })
        .seed(conformance::CONFORMANCE_SEED)
        .threads(threads)
        .exec(exec);
    if stream {
        s = s.stream_input();
    }
    if let Some(dir) = spill {
        s = s.spill_dir(dir);
    }
    let report = s.run().unwrap();
    assert!(report.validation.ok(), "{}: {}", spec.name, report.validation.detail);
    report
}

fn digests_at(spec: &'static WorkloadSpec, tier: Tier) -> (String, String) {
    let base = run_shaped(
        spec, tier, false, None, KeyDistribution::Uniform, 1, ExecKind::Seq,
    );
    let streamed = run_shaped(
        spec, tier, true, None, KeyDistribution::Uniform, 1, ExecKind::Seq,
    );
    (digest_json(&base, tier.name()), digest_json(&streamed, tier.name()))
}

/// Streamed input generation is byte-identical to the materialized path
/// for every registered workload: the per-node `SplitMix64::derive`
/// streams reproduce exactly the keys the bulk generator would have
/// handed each node (workloads with no streamable distribution fall
/// back to materializing — trivially identical, still pinned here).
#[test]
fn streamed_digests_match_materialized_for_every_workload_smoke() {
    for spec in registry::WORKLOADS {
        let (base, streamed) = digests_at(spec, Tier::Smoke);
        assert_eq!(base, streamed, "{}: streamed input drifted", spec.name);
    }
}

/// Mid-tier variant of the same identity (seconds of wall-clock —
/// `cargo test -- --ignored` territory, and the CI conformance matrix's
/// mid legs cover the same scale).
#[test]
#[ignore]
fn streamed_digests_match_materialized_for_every_workload_mid() {
    for spec in registry::WORKLOADS {
        let (base, streamed) = digests_at(spec, Tier::Mid);
        assert_eq!(base, streamed, "{}: streamed input drifted at mid", spec.name);
    }
}

/// Spill is digest-invisible across every executor backend: the same
/// nanosort tier run with {spill on, off} × {Seq, Par, Opt} produces one
/// digest. The spill runs also stream input — the full hyper-tier
/// configuration — and must actually write bins (the detour ran).
#[test]
fn spill_is_digest_invisible_across_backends() {
    let spec = registry::find("nanosort").unwrap();
    let base = run_shaped(
        spec, Tier::Smoke, false, None, KeyDistribution::Uniform, 1, ExecKind::Seq,
    );
    let expect = digest_json(&base, "smoke");
    for (tag, threads, exec) in
        [("seq", 1usize, ExecKind::Seq), ("par", 4, ExecKind::Par), ("opt", 4, ExecKind::Opt)]
    {
        let dir = scratch(&format!("backend_{tag}"));
        let spilled = run_shaped(
            spec, Tier::Smoke, true, Some(&dir), KeyDistribution::Uniform, threads, exec,
        );
        assert_eq!(
            expect,
            digest_json(&spilled, "smoke"),
            "spill+stream digest drifted on the {tag} backend"
        );
        assert!(
            std::fs::read_dir(&dir).map(|d| d.count() > 0).unwrap_or(false),
            "{tag}: spill dir has no bins — the detour never ran"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

/// Duplicate-heavy and adversarial inputs through the spill detour:
/// skewed distributions produce wildly uneven per-node blocks (empty
/// buckets next to overfull ones), exactly the shapes the framed bins
/// must round-trip. Digests must match the unspilled run per
/// distribution.
#[test]
fn spill_round_trips_skewed_distributions() {
    let spec = registry::find("nanosort").unwrap();
    for dist in [KeyDistribution::FewDistinct, KeyDistribution::AdversarialBucket] {
        let base = run_shaped(spec, Tier::Smoke, false, None, dist, 1, ExecKind::Seq);
        let dir = scratch(&format!("skew_{dist:?}"));
        let spilled =
            run_shaped(spec, Tier::Smoke, false, Some(&dir), dist, 1, ExecKind::Seq);
        assert_eq!(
            digest_json(&base, "smoke"),
            digest_json(&spilled, "smoke"),
            "{dist:?}: spill drifted"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

/// The bytes-spilled side channel reports the detour's traffic without
/// touching the report: drain, run with spill, and the counter moved.
/// (This is the only test in this binary that drains the process-global
/// counter, so the assertion cannot race a sibling.)
#[test]
fn bytes_spilled_side_channel_reports_the_detour() {
    let spec = registry::find("nanosort").unwrap();
    let dir = scratch("bytes");
    let _ = take_bytes_spilled();
    run_shaped(
        spec, Tier::Smoke, false, Some(&dir), KeyDistribution::Uniform, 1, ExecKind::Seq,
    );
    assert!(take_bytes_spilled() > 0, "spill ran but reported zero bytes");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The hyper tiers force streamed input through `run_tier` itself (the
/// path `repro paper --tier hyper-smoke` takes). The full 2^17-node run
/// is CI's memory-ceiling leg; here the tier machinery is pinned:
/// parameters resolve, `is_hyper` gates streaming, and the tier names
/// round-trip through the CLI parser.
#[test]
fn hyper_tier_machinery_resolves() {
    for tier in [Tier::HyperSmoke, Tier::Hyper] {
        assert!(tier.is_hyper());
        assert_eq!(Tier::parse(tier.name()).unwrap(), tier);
        for spec in registry::WORKLOADS {
            let params =
                registry::params_from_pairs(spec, &tier_params(spec, tier)).unwrap();
            (spec.build)(&params)
                .unwrap_or_else(|e| panic!("{} {}: {e:#}", spec.name, tier.name()));
        }
    }
}

/// The hyper-smoke conformance run end to end — 2^17 nodes with
/// streamed input, the exact leg CI's memory ceiling gates. Ignored by
/// default (tens of seconds); `cargo test --release -- --ignored` or the
/// CI hyper-smoke leg runs it.
#[test]
#[ignore]
fn hyper_smoke_runs_and_validates() {
    let spec = registry::find("nanosort").unwrap();
    let (report, _wall) =
        conformance::run_tier(spec, Tier::HyperSmoke, ComputeChoice::Radix, 1).unwrap();
    assert!(report.validation.ok(), "{}", report.validation.detail);
    assert_eq!(report.nodes, conformance::HYPER_SMOKE_NODES);
}
