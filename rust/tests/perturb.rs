//! Integration suite for the perturbation layer: every knob must (a) be
//! bit-identical to the unperturbed path when off, (b) replay
//! deterministically from its seed when on, and (c) never break
//! correctness — perturbations slow runs down, they don't corrupt them.

use nanosort::algo::nanosort::NanoSort;
use nanosort::conformance::{digest_json, CONFORMANCE_SEED};
use nanosort::net::NetConfig;
use nanosort::perturb::{KeyDistribution, Perturbations, StragglerConfig};
use nanosort::scenario::{RunReport, Scenario};

fn smoke_nanosort() -> NanoSort {
    NanoSort { keys_per_node: 8, buckets: 4, median_incast: 4, ..Default::default() }
}

fn run(net: NetConfig, perturb: Perturbations, seed: u64) -> RunReport {
    Scenario::new(smoke_nanosort())
        .nodes(16)
        .net(net)
        .perturb(perturb)
        .seed(seed)
        .run()
        .unwrap()
}

/// All perturbations at their defaults must produce a digest identical
/// to a scenario that never touched the perturbation API — the gate that
/// keeps the committed goldens valid.
#[test]
fn default_perturbations_leave_the_digest_untouched() {
    let plain = Scenario::new(smoke_nanosort())
        .nodes(16)
        .seed(CONFORMANCE_SEED)
        .run()
        .unwrap();
    let explicit = run(NetConfig::default(), Perturbations::default(), CONFORMANCE_SEED);
    assert_eq!(
        digest_json(&plain, "smoke"),
        digest_json(&explicit, "smoke"),
        "explicit default perturbations must be bit-identical"
    );
    assert_eq!(plain.summary.net.retransmits, 0);
}

/// Same seed + same loss rate ⇒ identical makespan (and full digest)
/// across two runs: the retransmission schedule is a pure function of
/// the seed.
#[test]
fn retransmission_is_deterministic_per_seed() {
    let lossy = || NetConfig { loss_prob: (1000, 10_000), rto_ns: 5_000, ..NetConfig::default() };
    let a = run(lossy(), Perturbations::default(), 7);
    let b = run(lossy(), Perturbations::default(), 7);
    assert_eq!(a.runtime(), b.runtime(), "same seed + loss rate must replay");
    assert_eq!(a.summary.net.retransmits, b.summary.net.retransmits);
    assert_eq!(digest_json(&a, "smoke"), digest_json(&b, "smoke"));
    assert!(a.summary.net.retransmits > 0, "10% loss must drop something");
    assert!(a.validation.ok(), "loss must not break the sort");
    // A different seed reshuffles the drop pattern.
    let c = run(lossy(), Perturbations::default(), 8);
    assert!(c.validation.ok());
    assert_ne!(
        (a.runtime(), a.summary.net.retransmits),
        (c.runtime(), c.summary.net.retransmits),
        "loss schedule must depend on the seed"
    );
}

/// Loss slows the run down relative to the lossless baseline and scales
/// with the retransmit timeout.
#[test]
fn loss_and_rto_stretch_the_makespan() {
    let base = run(NetConfig::default(), Perturbations::default(), 7);
    let slow = run(
        NetConfig { loss_prob: (1000, 10_000), rto_ns: 5_000, ..NetConfig::default() },
        Perturbations::default(),
        7,
    );
    let slower = run(
        NetConfig { loss_prob: (1000, 10_000), rto_ns: 50_000, ..NetConfig::default() },
        Perturbations::default(),
        7,
    );
    assert!(slow.runtime() > base.runtime());
    assert!(slower.runtime() > slow.runtime(), "10x RTO must hurt more");
}

/// Straggler cores stretch the makespan; the knob is deterministic and
/// off by default.
#[test]
fn stragglers_stretch_the_makespan_deterministically() {
    let perturbed = || Perturbations {
        stragglers: StragglerConfig { count: 2, factor: 8 },
        ..Default::default()
    };
    let base = run(NetConfig::default(), Perturbations::default(), 7);
    let a = run(NetConfig::default(), perturbed(), 7);
    let b = run(NetConfig::default(), perturbed(), 7);
    assert!(a.runtime() > base.runtime(), "8x-slow cores must show up in the makespan");
    assert_eq!(a.runtime(), b.runtime());
    assert!(a.validation.ok());
}

/// Core oversubscription queues cross-leaf traffic: a fleet spanning
/// several leaves slows down when the spine set shrinks 64-fold.
#[test]
fn oversubscription_slows_multi_leaf_fleets() {
    let workload =
        || NanoSort { keys_per_node: 16, buckets: 16, median_incast: 16, ..Default::default() };
    let run256 = |net: NetConfig| {
        Scenario::new(workload()).nodes(256).net(net).seed(7).run().unwrap()
    };
    let base = run256(NetConfig::default());
    let over = run256(NetConfig { oversub: 64, ..NetConfig::default() });
    assert!(
        over.runtime() > base.runtime(),
        "single-spine fabric {} !> full bisection {}",
        over.runtime().as_us_f64(),
        base.runtime().as_us_f64()
    );
    assert!(over.validation.ok());
}

/// Every key distribution sorts correctly on every sort workload, and
/// the aggregation workloads stay correct under load skew.
#[test]
fn all_distributions_validate_across_workloads() {
    use nanosort::conformance::{run_tier, Tier};
    use nanosort::coordinator::ComputeChoice;
    use nanosort::scenario::registry;
    // Direct scenario checks for each distribution on each workload's
    // smoke shape (the registry smoke tuple, via the tier machinery,
    // only covers Uniform — here we bend the inputs).
    for spec in registry::WORKLOADS {
        let (base, _) = run_tier(spec, Tier::Smoke, ComputeChoice::Native, 1).unwrap();
        assert!(base.validation.ok(), "{}", spec.name);
    }
    for dist in KeyDistribution::ALL {
        for spec in registry::WORKLOADS {
            let params = registry::params_from_pairs(spec, spec.smoke).unwrap();
            let workload = (spec.build)(&params).unwrap();
            let nodes = params.u64(spec.nodes_param.name).unwrap() as usize;
            let r = Scenario::from_dyn(workload)
                .nodes(nodes)
                .dist(dist)
                .seed(CONFORMANCE_SEED)
                .run()
                .unwrap_or_else(|e| panic!("{} under {}: {e:#}", spec.name, dist.name()));
            assert!(
                r.validation.ok(),
                "{} under {}: {}",
                spec.name,
                dist.name(),
                r.validation.detail
            );
        }
    }
}

/// The acceptance-criterion pair, stated directly: at the smoke shape
/// with the conformance seed, zipfian inputs produce strictly more
/// bucket skew than uniform inputs.
#[test]
fn zipfian_bucket_skew_strictly_exceeds_uniform() {
    let skew_of = |dist: KeyDistribution| {
        let r = Scenario::new(smoke_nanosort())
            .nodes(16)
            .dist(dist)
            .seed(CONFORMANCE_SEED)
            .run()
            .unwrap();
        assert!(r.validation.ok(), "{}", dist.name());
        r.metric_f64("skew").unwrap()
    };
    let uniform = skew_of(KeyDistribution::Uniform);
    let zipfian = skew_of(KeyDistribution::Zipfian);
    assert!(zipfian > uniform, "zipfian {zipfian} !> uniform {uniform}");
}

/// Perturbations compose: skewed input + loss + stragglers in one run,
/// still correct, still deterministic.
#[test]
fn composed_perturbations_stay_correct_and_deterministic() {
    let net = || NetConfig {
        loss_prob: (500, 10_000),
        tail_prob: (1, 100),
        tail_extra_ns: 2_000,
        oversub: 8,
        ..NetConfig::default()
    };
    let knobs = || Perturbations {
        dist: KeyDistribution::Zipfian,
        stragglers: StragglerConfig { count: 2, factor: 4 },
    };
    let a = run(net(), knobs(), CONFORMANCE_SEED);
    let b = run(net(), knobs(), CONFORMANCE_SEED);
    assert!(a.validation.ok(), "{}", a.validation.detail);
    assert_eq!(digest_json(&a, "smoke"), digest_json(&b, "smoke"));
}
