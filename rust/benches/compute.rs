//! Data-plane kernel microbenchmarks: `sort` / `sort_pairs` / `partition`
//! on the native (comparison) plane and on each radix kernel family the
//! tuner can dispatch (`lsb`, `ska`, the parallel pair, and the `auto`
//! policy itself) at 2^10 .. 2^20 keys, so the per-kernel win — and the
//! tuner's choice quality — is visible independent of the simulator.
//! (Criterion-style output from the in-repo harness — the offline
//! registry has no criterion; see DESIGN.md "Dependency substitutions".)
//!
//! Run: `cargo bench --bench compute [-- --quick]` (quick caps at 2^16).

#[path = "common.rs"]
mod common;

use std::sync::Arc;

use common::{fmt_t, section, Bench};
use nanosort::compute::{LocalCompute, NativeCompute, RadixCompute, TunerOverride};
use nanosort::pool::WorkerPool;
use nanosort::sim::exec::resolve_threads;
use nanosort::sim::SplitMix64;

fn keys(n: usize) -> Vec<u64> {
    let mut rng = SplitMix64::new(0xC0FFEE ^ n as u64);
    (0..n).map(|_| rng.next_u64() % (u64::MAX - 1)).collect()
}

fn label(kernel: &str, plane: &str, n: usize) -> &'static str {
    Box::leak(format!("{kernel}/{plane}/n=2^{}", n.trailing_zeros()).into_boxed_str())
}

/// The benched planes: the oracle, the auto tuner, and each forced
/// radix family. `par` gets the host's full budget; the sequential
/// families run on a budget-1 pool so their numbers are pure kernel.
fn planes() -> Vec<(&'static str, Arc<dyn LocalCompute>)> {
    let solo = || Arc::new(WorkerPool::new(1));
    vec![
        ("native", Arc::new(NativeCompute)),
        ("auto", Arc::new(RadixCompute::forced(None, solo()))),
        ("lsb", Arc::new(RadixCompute::forced(Some(TunerOverride::Lsb), solo()))),
        ("ska", Arc::new(RadixCompute::forced(Some(TunerOverride::Ska), solo()))),
        (
            "par",
            Arc::new(RadixCompute::forced(
                Some(TunerOverride::Par),
                Arc::new(WorkerPool::new(resolve_threads(0))),
            )),
        ),
    ]
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let max_pow = if quick { 16 } else { 20 };
    let sizes: Vec<usize> = (10..=max_pow).step_by(2).map(|p| 1usize << p).collect();
    let planes = planes();

    for &n in &sizes {
        let samples = if n >= 1 << 18 { 5 } else { 10 };
        let base = keys(n);

        section(&format!("sort — {n} keys"));
        let mut means = Vec::new();
        for (name, plane) in &planes {
            let mean = Bench::new(label("sort", name, n)).samples(samples).run(|| {
                let mut k = base.clone();
                plane.sort(&mut k);
                k[0]
            });
            means.push((*name, mean));
        }
        speedup_line(&means);

        section(&format!("sort_pairs — {n} (key, origin) pairs"));
        let pairs: Vec<(u64, u64)> =
            base.iter().enumerate().map(|(i, &k)| (k, i as u64)).collect();
        let mut means = Vec::new();
        for (name, plane) in &planes {
            let mean = Bench::new(label("sort_pairs", name, n)).samples(samples).run(|| {
                let mut p = pairs.clone();
                plane.sort_pairs(&mut p);
                p[0].1
            });
            means.push((*name, mean));
        }
        speedup_line(&means);

        // Partition has one radix implementation — no per-family rows.
        section(&format!("partition — {n} keys, 15 pivots (NanoSort shuffle shape)"));
        let mut pivots = keys(15);
        pivots.sort_unstable();
        let mut means = Vec::new();
        for (name, plane) in planes.iter().take(2) {
            let mean = Bench::new(label("partition", name, n)).samples(samples).run(|| {
                plane.partition(&base, &pivots).len()
            });
            means.push((*name, mean));
        }
        speedup_line(&means);
    }
}

/// Speedups of every plane relative to the first (the native oracle).
fn speedup_line(means: &[(&str, f64)]) {
    if let Some(((base, tb), rest)) = means.split_first() {
        let cells: Vec<String> = rest
            .iter()
            .map(|(name, t)| format!("{name} {} ({:.2}x)", fmt_t(*t), tb / t.max(1e-12)))
            .collect();
        println!("    -> {base} {} vs {}", fmt_t(*tb), cells.join(", "));
    }
}
