//! Data-plane kernel microbenchmarks: `sort` / `sort_pairs` / `partition`
//! on the native (comparison) and radix (count-then-scatter) planes at
//! 2^10 .. 2^20 keys, so the kernel win is visible independent of the
//! simulator. (Criterion-style output from the in-repo harness — the
//! offline registry has no criterion; see DESIGN.md "Dependency
//! substitutions".)
//!
//! Run: `cargo bench --bench compute [-- --quick]` (quick caps at 2^16).

#[path = "common.rs"]
mod common;

use common::{fmt_t, section, Bench};
use nanosort::compute::{LocalCompute, NativeCompute, RadixCompute};
use nanosort::sim::SplitMix64;

fn keys(n: usize) -> Vec<u64> {
    let mut rng = SplitMix64::new(0xC0FFEE ^ n as u64);
    (0..n).map(|_| rng.next_u64() % (u64::MAX - 1)).collect()
}

fn label(kernel: &str, plane: &str, n: usize) -> &'static str {
    Box::leak(format!("{kernel}/{plane}/n=2^{}", n.trailing_zeros()).into_boxed_str())
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let max_pow = if quick { 16 } else { 20 };
    let sizes: Vec<usize> = (10..=max_pow).step_by(2).map(|p| 1usize << p).collect();
    let native = NativeCompute;
    let radix = RadixCompute;
    let planes: [(&str, &dyn LocalCompute); 2] = [("native", &native), ("radix", &radix)];

    for &n in &sizes {
        let samples = if n >= 1 << 18 { 5 } else { 10 };
        let base = keys(n);

        section(&format!("sort — {n} keys"));
        let mut means = Vec::new();
        for (name, plane) in planes {
            let mean = Bench::new(label("sort", name, n)).samples(samples).run(|| {
                let mut k = base.clone();
                plane.sort(&mut k);
                k[0]
            });
            means.push((name, mean));
        }
        speedup_line(&means);

        section(&format!("sort_pairs — {n} (key, origin) pairs"));
        let pairs: Vec<(u64, u64)> =
            base.iter().enumerate().map(|(i, &k)| (k, i as u64)).collect();
        let mut means = Vec::new();
        for (name, plane) in planes {
            let mean = Bench::new(label("sort_pairs", name, n)).samples(samples).run(|| {
                let mut p = pairs.clone();
                plane.sort_pairs(&mut p);
                p[0].1
            });
            means.push((name, mean));
        }
        speedup_line(&means);

        section(&format!("partition — {n} keys, 15 pivots (NanoSort shuffle shape)"));
        let mut pivots = keys(15);
        pivots.sort_unstable();
        let mut means = Vec::new();
        for (name, plane) in planes {
            let mean = Bench::new(label("partition", name, n)).samples(samples).run(|| {
                plane.partition(&base, &pivots).len()
            });
            means.push((name, mean));
        }
        speedup_line(&means);
    }
}

fn speedup_line(means: &[(&str, f64)]) {
    if let [(a, ta), (b, tb)] = means {
        println!("    -> {a} {} vs {b} {} ({:.2}x)", fmt_t(*ta), fmt_t(*tb), ta / tb.max(1e-12));
    }
}
