//! Substrate microbenchmarks: the simulator's own hot paths (§Perf
//! targets) and the XLA data-plane call overhead.

#[path = "common.rs"]
mod common;

use common::{section, Bench};
use nanosort::algo::nanosort::pivot::pivot_select;
use nanosort::compute::{LocalCompute, NativeCompute, XlaCompute};
use nanosort::mem::thread_alloc_count;
use nanosort::nanopu::SmallWords;
use nanosort::net::{Fabric, NetConfig, Topology};
use nanosort::sim::exec::queue_churn_allocs;
use nanosort::sim::{SplitMix64, Time};

fn main() {
    section("Event queue — steady-state churn (allocs asserted)");
    // Timed row: one push/pop round trip through the timing wheel.
    Bench::new("wheel/push_pop_x100k").samples(20).run(|| queue_churn_allocs(100_000));
    // The asserted row: steady state must allocate exactly zero (the
    // ISSUE 10 contract — the wheel recycles every bucket and slot).
    let allocs = queue_churn_allocs(100_000);
    assert_eq!(allocs, 0, "timing wheel allocated {allocs}× in steady state");
    println!("    -> wheel steady-state allocs per 100k events: {allocs} (asserted 0)");

    section("Message path — small-payload construction (allocs asserted)");
    let words = [3u64, 1, 2];
    Bench::new("small_words/inline3_x1M").samples(20).run(|| {
        let mut acc = 0u64;
        for i in 0..1_000_000u64 {
            let s = SmallWords::from_slice(std::hint::black_box(&words));
            acc ^= s.as_slice()[(i % 3) as usize];
        }
        acc
    });
    let before = thread_alloc_count();
    for _ in 0..10_000u64 {
        let s = SmallWords::from_slice(std::hint::black_box(&words));
        std::hint::black_box(s.as_slice()[0]);
    }
    let allocs = thread_alloc_count() - before;
    assert_eq!(allocs, 0, "inline small-message path allocated {allocs}×");
    println!("    -> inline small-message allocs per 10k constructions: {allocs} (asserted 0)");

    section("Fabric — per-message routing cost");
    let mut fabric = Fabric::new(Topology::paper(65_536), NetConfig::default(), 1);
    let mut i = 0usize;
    Bench::new("fabric/unicast_x100k (65,536-node topo)").samples(20).run(|| {
        let mut acc = 0u64;
        for _ in 0..100_000 {
            i = (i.wrapping_mul(2654435761).wrapping_add(1)) & 0xFFFF;
            acc ^= fabric.unicast(i, (i * 7 + 13) & 0xFFFF, 16, Time(acc & 0xFFFF)).0;
        }
        acc
    });

    section("RNG + PivotSelect");
    let mut rng = SplitMix64::new(2);
    Bench::new("rng/next_u64_x1M").samples(20).run(|| {
        let mut acc = 0u64;
        for _ in 0..1_000_000 {
            acc ^= rng.next_u64();
        }
        acc
    });
    let mut keys: Vec<u64> = (0..64u64).map(|i| i * 977).collect();
    keys.sort_unstable();
    Bench::new("pivot_select/n64_b16_x10k").samples(20).run(|| {
        let mut acc = 0u64;
        for _ in 0..10_000 {
            acc ^= pivot_select(&keys, 16, &mut rng)[7];
        }
        acc
    });

    section("Native data plane");
    let native = NativeCompute;
    let base: Vec<u64> = (0..64u64).map(|i| i.wrapping_mul(0x9E3779B97F4A7C15)).collect();
    Bench::new("native/sort64_x10k").samples(20).run(|| {
        let mut acc = 0u64;
        for _ in 0..10_000 {
            let mut k = base.clone();
            native.sort(&mut k);
            acc ^= k[0];
        }
        acc
    });

    section("XLA data plane (three-layer path)");
    match XlaCompute::open_default() {
        Ok(xla) => {
            Bench::new("xla/sort64 (per call)").samples(10).run(|| {
                let mut k = base.clone();
                xla.sort(&mut k);
                k[0]
            });
            let mut pivots: Vec<u64> = base[..15].to_vec();
            pivots.sort_unstable();
            Bench::new("xla/bucketize64_p15 (per call)")
                .samples(10)
                .run(|| xla.bucketize(&base, &pivots)[0]);
            Bench::new("xla/merge_min64 (per call)").samples(10).run(|| xla.min(&base));
            println!(
                "    -> {} xla calls, {} fallbacks",
                xla.counters.xla_calls.load(std::sync::atomic::Ordering::Relaxed),
                xla.counters.native_fallbacks.load(std::sync::atomic::Ordering::Relaxed)
            );
        }
        Err(e) => println!("xla benches skipped (run `make artifacts`): {e:#}"),
    }
}
