//! Minimal benchmark harness (the offline registry has no criterion; see
//! DESIGN.md "Dependency substitutions"). Criterion-style output: warmup,
//! N timed iterations, mean ± std, min/max.

use std::time::Instant;

pub struct Bench {
    name: &'static str,
    samples: usize,
}

#[allow(dead_code)]
impl Bench {
    pub fn new(name: &'static str) -> Self {
        Bench { name, samples: 10 }
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n;
        self
    }

    /// Time `f` over warmup + samples; print a criterion-style line.
    /// Returns the mean seconds per iteration.
    pub fn run<T, F: FnMut() -> T>(self, mut f: F) -> f64 {
        // Warmup.
        std::hint::black_box(f());
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let n = times.len() as f64;
        let mean = times.iter().sum::<f64>() / n;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
        let std = var.sqrt();
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = times.iter().cloned().fold(0.0, f64::max);
        println!(
            "{:<56} {:>12} ± {:>10}  [{} .. {}]  ({} samples)",
            self.name,
            fmt_t(mean),
            fmt_t(std),
            fmt_t(min),
            fmt_t(max),
            self.samples
        );
        mean
    }
}

pub fn fmt_t(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
