//! MergeMin benchmarks (paper Figs 2/4): single-core scan cost model and
//! the full incast sweep.

#[path = "common.rs"]
mod common;

use std::rc::Rc;

use common::{section, Bench};
use nanosort::algo::mergemin::{run_mergemin, single_core_scan, MergeMinConfig};
use nanosort::compute::NativeCompute;

fn main() {
    section("Fig 2 — single-core min scan (cost model evaluation)");
    Bench::new("cost_model/scan_sweep_64..8192").samples(50).run(|| {
        let mut acc = 0u64;
        let mut n = 64;
        while n <= 8192 {
            acc ^= single_core_scan(n).0 .0;
            n *= 2;
        }
        acc
    });
    for n in [64usize, 1024, 8192] {
        let (t, miss) = single_core_scan(n);
        println!("    -> {n} values: {:.2} µs (miss rate {miss:.3})", t.as_us_f64());
    }

    section("Fig 4 — MergeMin end-to-end per incast (64 cores, 128 v/core)");
    let compute = Rc::new(NativeCompute);
    for incast in [1usize, 8, 64] {
        let cfg = MergeMinConfig { incast, ..Default::default() };
        let c2 = compute.clone();
        let mut sim_ns = 0.0;
        Bench::new(Box::leak(format!("mergemin/incast={incast}").into_boxed_str()))
            .samples(20)
            .run(|| {
                let r = run_mergemin(&cfg, c2.clone());
                sim_ns = r.summary.makespan.as_ns_f64();
                r
            });
        println!("    -> simulated: {sim_ns:.0} ns");
    }

    section("Scale — MergeMin at larger fleets (incast 8)");
    for cores in [256usize, 1024, 4096] {
        let cfg = MergeMinConfig { cores, incast: 8, ..Default::default() };
        let c2 = compute.clone();
        Bench::new(Box::leak(format!("mergemin/cores={cores}").into_boxed_str()))
            .samples(5)
            .run(|| run_mergemin(&cfg, c2.clone()));
    }
}
