//! MergeMin benchmarks (paper Figs 2/4): single-core scan cost model and
//! the full incast sweep, driven through the `Scenario` API.

#[path = "common.rs"]
mod common;

use common::{section, Bench};
use nanosort::algo::mergemin::{single_core_scan, MergeMin};
use nanosort::scenario::Scenario;

fn main() {
    section("Fig 2 — single-core min scan (cost model evaluation)");
    Bench::new("cost_model/scan_sweep_64..8192").samples(50).run(|| {
        let mut acc = 0u64;
        let mut n = 64;
        while n <= 8192 {
            acc ^= single_core_scan(n).0 .0;
            n *= 2;
        }
        acc
    });
    for n in [64usize, 1024, 8192] {
        let (t, miss) = single_core_scan(n);
        println!("    -> {n} values: {:.2} µs (miss rate {miss:.3})", t.as_us_f64());
    }

    section("Fig 4 — MergeMin end-to-end per incast (64 cores, 128 v/core)");
    for incast in [1usize, 8, 64] {
        let mut sim_ns = 0.0;
        Bench::new(Box::leak(format!("mergemin/incast={incast}").into_boxed_str()))
            .samples(20)
            .run(|| {
                let r = Scenario::new(MergeMin { incast, ..Default::default() })
                    .run()
                    .expect("mergemin scenario");
                sim_ns = r.summary.makespan.as_ns_f64();
                r
            });
        println!("    -> simulated: {sim_ns:.0} ns");
    }

    section("Scale — MergeMin at larger fleets (incast 8)");
    for cores in [256usize, 1024, 4096] {
        Bench::new(Box::leak(format!("mergemin/cores={cores}").into_boxed_str()))
            .samples(5)
            .run(|| {
                Scenario::new(MergeMin::default())
                    .nodes(cores)
                    .run()
                    .expect("mergemin scenario")
            });
    }
}
