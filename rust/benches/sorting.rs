//! End-to-end sorting benchmarks — one per paper table/figure that
//! involves a full distributed sort. Reports both the *simulated* runtime
//! (the paper's metric) and the *wall-clock* cost of producing it (the
//! simulator's own speed, which the §Perf pass optimizes). All runs go
//! through the unified `Scenario` API.

#[path = "common.rs"]
mod common;

use common::{section, Bench};
use nanosort::algo::millisort::MilliSort;
use nanosort::algo::nanosort::NanoSort;
use nanosort::scenario::Scenario;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");

    section("Fig 9 — MilliSort vs cores (4,096 keys, rf 4)");
    for cores in [16usize, 64, 256] {
        let mut sim_us = 0.0;
        Bench::new(Box::leak(format!("millisort/cores={cores}").into_boxed_str()))
            .samples(5)
            .run(|| {
                let r = Scenario::new(MilliSort::default())
                    .nodes(cores)
                    .run()
                    .expect("millisort scenario");
                sim_us = r.runtime().as_us_f64();
                r
            });
        println!("    -> simulated: {sim_us:.1} µs");
    }

    section("Fig 11 — NanoSort vs buckets (4,096 cores, 32 keys/core)");
    for b in [4usize, 8, 16] {
        let mut sim_us = 0.0;
        Bench::new(Box::leak(format!("nanosort/buckets={b}").into_boxed_str()))
            .samples(3)
            .run(|| {
                let r = Scenario::new(NanoSort {
                    keys_per_node: 32,
                    buckets: b,
                    median_incast: b,
                    ..Default::default()
                })
                .nodes(4096)
                .run()
                .expect("nanosort scenario");
                sim_us = r.runtime().as_us_f64();
                r
            });
        println!("    -> simulated: {sim_us:.1} µs");
    }

    section("Fig 12 — NanoSort vs keys (4,096 cores)");
    for kpn in [4usize, 16, 64] {
        let mut sim_us = 0.0;
        Bench::new(Box::leak(format!("nanosort/kpn={kpn}").into_boxed_str()))
            .samples(3)
            .run(|| {
                let r = Scenario::new(NanoSort { keys_per_node: kpn, ..Default::default() })
                    .nodes(4096)
                    .run()
                    .expect("nanosort scenario");
                sim_us = r.runtime().as_us_f64();
                r
            });
        println!("    -> simulated: {sim_us:.1} µs");
    }

    if !quick {
        section("§6.3 headline — 1M keys on 65,536 cores (1 sample)");
        let mut sim_us = 0.0;
        Bench::new("nanosort/headline-65536c-1M").samples(1).run(|| {
            let r = Scenario::new(NanoSort { shuffle_values: true, ..Default::default() })
                .nodes(65_536)
                .run()
                .expect("headline scenario");
            sim_us = r.runtime().as_us_f64();
            assert!(r.validation.ok());
            r
        });
        println!("    -> simulated: {sim_us:.1} µs (paper: 68 µs)");
    }
}
