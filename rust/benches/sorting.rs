//! End-to-end sorting benchmarks — one per paper table/figure that
//! involves a full distributed sort. Reports both the *simulated* runtime
//! (the paper's metric) and the *wall-clock* cost of producing it (the
//! simulator's own speed, which the §Perf pass optimizes).

#[path = "common.rs"]
mod common;

use std::rc::Rc;

use common::{section, Bench};
use nanosort::algo::millisort::{run_millisort, MilliSortConfig};
use nanosort::algo::nanosort::{run_nanosort, NanoSortConfig};
use nanosort::compute::NativeCompute;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let compute = Rc::new(NativeCompute);

    section("Fig 9 — MilliSort vs cores (4,096 keys, rf 4)");
    for cores in [16usize, 64, 256] {
        let cfg = MilliSortConfig { cores, total_keys: 4096, ..Default::default() };
        let c2 = compute.clone();
        let mut sim_us = 0.0;
        Bench::new(Box::leak(format!("millisort/cores={cores}").into_boxed_str()))
            .samples(5)
            .run(|| {
                let r = run_millisort(&cfg, c2.clone());
                sim_us = r.runtime().as_us_f64();
                r
            });
        println!("    -> simulated: {sim_us:.1} µs");
    }

    section("Fig 11 — NanoSort vs buckets (4,096 cores, 32 keys/core)");
    for b in [4usize, 8, 16] {
        let cfg = NanoSortConfig {
            nodes: 4096,
            keys_per_node: 32,
            buckets: b,
            median_incast: b,
            ..Default::default()
        };
        let c2 = compute.clone();
        let mut sim_us = 0.0;
        Bench::new(Box::leak(format!("nanosort/buckets={b}").into_boxed_str()))
            .samples(3)
            .run(|| {
                let r = run_nanosort(&cfg, c2.clone());
                sim_us = r.runtime().as_us_f64();
                r
            });
        println!("    -> simulated: {sim_us:.1} µs");
    }

    section("Fig 12 — NanoSort vs keys (4,096 cores)");
    for kpn in [4usize, 16, 64] {
        let cfg = NanoSortConfig { nodes: 4096, keys_per_node: kpn, ..Default::default() };
        let c2 = compute.clone();
        let mut sim_us = 0.0;
        Bench::new(Box::leak(format!("nanosort/kpn={kpn}").into_boxed_str()))
            .samples(3)
            .run(|| {
                let r = run_nanosort(&cfg, c2.clone());
                sim_us = r.runtime().as_us_f64();
                r
            });
        println!("    -> simulated: {sim_us:.1} µs");
    }

    if !quick {
        section("§6.3 headline — 1M keys on 65,536 cores (1 sample)");
        let cfg = NanoSortConfig {
            nodes: 65_536,
            keys_per_node: 16,
            shuffle_values: true,
            ..Default::default()
        };
        let c2 = compute.clone();
        let mut sim_us = 0.0;
        Bench::new("nanosort/headline-65536c-1M").samples(1).run(|| {
            let r = run_nanosort(&cfg, c2.clone());
            sim_us = r.runtime().as_us_f64();
            assert!(r.validation.ok());
            r
        });
        println!("    -> simulated: {sim_us:.1} µs (paper: 68 µs)");
    }
}
