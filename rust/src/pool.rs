//! Shared fixed-budget host worker pool (no work stealing).
//!
//! One `--threads N` budget covers *everything* the host parallelizes in
//! a run: the executor's shard workers ([`crate::sim::exec`]) and the
//! data plane's parallel kernel tiles ([`crate::compute::RadixCompute`]).
//! Without a shared budget the two layers compose multiplicatively — S
//! shard threads × K kernel threads oversubscribes the machine exactly
//! when both are busiest. The pool makes the budget a single accountable
//! quantity:
//!
//! - **Claims** reserve *extra* (spawned) worker slots ahead of use:
//!   [`WorkerPool::claim_exact`] is all-or-nothing (the executor takes
//!   `shards - 1` up front), [`WorkerPool::claim_up_to`] is best-effort
//!   (kernel tiles take whatever is left, possibly zero — then they run
//!   inline on the calling thread). A [`Claim`] releases its slots on
//!   drop, so a finished kernel immediately returns capacity to the next.
//! - **Live accounting**: every spawned worker registers through
//!   [`WorkerPool::enter`] for its lifetime. `live > budget` is a bug by
//!   construction and asserts — the regression gate the contention tests
//!   pin ([`WorkerPool::max_live`] never exceeds the budget).
//! - **No stealing, no queues between claims**: [`WorkerPool::run_jobs`]
//!   fans a job list over the claimed extras plus the calling thread and
//!   joins before returning. Kernel outputs are scheduling-independent
//!   (disjoint slices, deterministic per-job results), so the pool never
//!   touches determinism — only wall-clock.
//!
//! The caller's own thread is an implicit slot: a claim may reserve at
//! most `budget - 1` extras, so `spawned extras + the caller ≤ budget`
//! holds on every path. Shard workers double as kernel callers — a
//! kernel invoked from a registered shard worker claims extras from the
//! same ledger the executor already drew from, which is what keeps the
//! two layers from compounding.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Fixed-budget worker pool. See the module docs for the accounting
/// model. Cheap to share (`Arc`); all state is atomic.
#[derive(Debug)]
pub struct WorkerPool {
    /// Total thread budget, including the calling thread (≥ 1).
    budget: usize,
    /// Currently claimed extra-worker slots (≤ budget - 1).
    extras: AtomicUsize,
    /// Currently registered live spawned workers.
    live: AtomicUsize,
    /// High-water mark of `live` (the contention-test assertion target).
    max_live: AtomicUsize,
}

impl WorkerPool {
    /// A pool with `budget` total threads (clamped to ≥ 1; the calling
    /// thread always counts as one).
    pub fn new(budget: usize) -> Self {
        WorkerPool {
            budget: budget.max(1),
            extras: AtomicUsize::new(0),
            live: AtomicUsize::new(0),
            max_live: AtomicUsize::new(0),
        }
    }

    /// Total thread budget (callers size their tiling to this).
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// High-water mark of concurrently live spawned workers. Bounded by
    /// `budget` — the invariant the pool asserts and tests pin.
    pub fn max_live(&self) -> usize {
        self.max_live.load(Ordering::Relaxed)
    }

    /// Reserve up to `want` extra-worker slots (best-effort; may return
    /// an empty claim). At most `budget - 1` extras exist in total.
    pub fn claim_up_to(&self, want: usize) -> Claim<'_> {
        let cap = self.budget - 1;
        let mut cur = self.extras.load(Ordering::Relaxed);
        loop {
            let grant = want.min(cap.saturating_sub(cur));
            if grant == 0 {
                return Claim { pool: self, workers: 0 };
            }
            match self.extras.compare_exchange_weak(
                cur,
                cur + grant,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Claim { pool: self, workers: grant },
                Err(seen) => cur = seen,
            }
        }
    }

    /// Reserve exactly `n` extra-worker slots, or nothing (`None`) if
    /// fewer are free. The executor's all-or-nothing shard claim.
    pub fn claim_exact(&self, n: usize) -> Option<Claim<'_>> {
        if n == 0 {
            return Some(Claim { pool: self, workers: 0 });
        }
        let cap = self.budget - 1;
        let mut cur = self.extras.load(Ordering::Relaxed);
        loop {
            if cur + n > cap {
                return None;
            }
            match self.extras.compare_exchange_weak(
                cur,
                cur + n,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => return Some(Claim { pool: self, workers: n }),
                Err(seen) => cur = seen,
            }
        }
    }

    /// Register the current (spawned) thread as a live worker for the
    /// guard's lifetime. Panics if registration would exceed the budget:
    /// that is an accounting bug, never load.
    pub fn enter(&self) -> LiveGuard<'_> {
        let now = self.live.fetch_add(1, Ordering::AcqRel) + 1;
        assert!(
            now <= self.budget,
            "worker pool oversubscribed: {now} live workers > budget {}",
            self.budget
        );
        self.max_live.fetch_max(now, Ordering::AcqRel);
        LiveGuard { pool: self }
    }

    /// Run every job in `jobs`, fanning across however many extra
    /// workers the pool can grant right now (possibly zero → the calling
    /// thread runs everything inline). Blocks until all jobs finished.
    ///
    /// The caller participates without registering: it either already
    /// holds a slot (a shard worker draining kernel tiles) or is the
    /// implicit caller slot every claim leaves free. Job pickup order is
    /// scheduling-dependent, so jobs must be order-independent —
    /// disjoint `&mut` slices with deterministic per-job results, which
    /// is exactly what the kernel callers pass.
    pub fn run_jobs<I: Send>(&self, jobs: Vec<I>, f: impl Fn(I) + Sync) {
        if jobs.len() <= 1 {
            for job in jobs {
                f(job);
            }
            return;
        }
        let claim = self.claim_up_to(jobs.len() - 1);
        if claim.workers() == 0 {
            for job in jobs {
                f(job);
            }
            return;
        }
        let queue = Mutex::new(jobs);
        let drain = |register: bool| {
            let _guard = register.then(|| self.enter());
            loop {
                let job = queue.lock().expect("worker pool job queue").pop();
                match job {
                    Some(job) => f(job),
                    None => break,
                }
            }
        };
        std::thread::scope(|scope| {
            for _ in 0..claim.workers() {
                scope.spawn(|| drain(true));
            }
            drain(false);
        });
    }
}

/// RAII reservation of extra-worker slots; releases them on drop.
#[must_use = "dropping a claim immediately releases its worker slots"]
pub struct Claim<'a> {
    pool: &'a WorkerPool,
    workers: usize,
}

impl Claim<'_> {
    /// How many extra workers this claim actually reserved.
    pub fn workers(&self) -> usize {
        self.workers
    }
}

impl Drop for Claim<'_> {
    fn drop(&mut self) {
        if self.workers > 0 {
            self.pool.extras.fetch_sub(self.workers, Ordering::AcqRel);
        }
    }
}

/// RAII live-worker registration (see [`WorkerPool::enter`]).
pub struct LiveGuard<'a> {
    pool: &'a WorkerPool,
}

impl Drop for LiveGuard<'_> {
    fn drop(&mut self) {
        self.pool.live.fetch_sub(1, Ordering::AcqRel);
    }
}

/// A pool shared across layers: convenience alias used in signatures.
pub type SharedPool = Arc<WorkerPool>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn budget_clamps_to_one() {
        assert_eq!(WorkerPool::new(0).budget(), 1);
        assert_eq!(WorkerPool::new(1).budget(), 1);
        assert_eq!(WorkerPool::new(8).budget(), 8);
    }

    #[test]
    fn claims_never_exceed_budget_minus_one() {
        let pool = WorkerPool::new(4);
        let a = pool.claim_up_to(10);
        assert_eq!(a.workers(), 3, "budget 4 = caller + 3 extras");
        let b = pool.claim_up_to(1);
        assert_eq!(b.workers(), 0, "pool exhausted");
        assert!(pool.claim_exact(1).is_none());
        drop(a);
        let c = pool.claim_exact(2).expect("slots released on drop");
        assert_eq!(c.workers(), 2);
        assert_eq!(pool.claim_up_to(5).workers(), 1, "one slot left");
    }

    #[test]
    fn claim_exact_is_all_or_nothing() {
        let pool = WorkerPool::new(3);
        assert!(pool.claim_exact(3).is_none(), "3 extras > budget-1");
        let claim = pool.claim_exact(2).unwrap();
        assert_eq!(claim.workers(), 2);
        assert!(pool.claim_exact(1).is_none());
        assert_eq!(pool.claim_exact(0).unwrap().workers(), 0, "empty claim always succeeds");
    }

    #[test]
    fn run_jobs_runs_every_job_at_any_budget() {
        for budget in [1usize, 2, 4, 8] {
            let pool = WorkerPool::new(budget);
            let sum = AtomicU64::new(0);
            pool.run_jobs((1u64..=100).collect(), |j| {
                sum.fetch_add(j, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 5050, "budget {budget}");
            assert!(pool.max_live() <= budget, "budget {budget}");
        }
    }

    #[test]
    fn run_jobs_inline_paths_spawn_nothing() {
        let pool = WorkerPool::new(1);
        pool.run_jobs(vec![1, 2, 3], |_| {});
        assert_eq!(pool.max_live(), 0, "budget 1 always runs inline");
        let pool = WorkerPool::new(8);
        pool.run_jobs(vec![42], |_| {});
        assert_eq!(pool.max_live(), 0, "a single job never spawns");
    }

    #[test]
    fn live_accounting_tracks_enter_and_release() {
        let pool = WorkerPool::new(2);
        {
            let _g = pool.enter();
            assert_eq!(pool.max_live(), 1);
        }
        let _g1 = pool.enter();
        let _g2 = pool.enter();
        assert_eq!(pool.max_live(), 2);
    }

    #[test]
    #[should_panic(expected = "oversubscribed")]
    fn entering_past_the_budget_panics() {
        let pool = WorkerPool::new(1);
        let _a = pool.enter();
        let _b = pool.enter();
    }
}
