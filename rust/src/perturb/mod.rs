//! Perturbation layer: everything that bends a run away from the paper's
//! happy path.
//!
//! The headline claim (1M keys in 68 µs on 65,536 cores) assumes a
//! uniform key distribution, a non-blocking full-bisection core, lossless
//! links, and homogeneous cores. Each assumption gets a knob here:
//!
//! - **Input skew** — [`KeyDistribution`] generalizes workload input
//!   generation (uniform / zipfian / sorted / few-distinct /
//!   adversarial-bucket). Key-space workloads (NanoSort, MilliSort) draw
//!   their *key values* from the distribution; aggregation workloads
//!   (MergeMin, set algebra) map it onto *per-core load* instead
//!   ([`KeyDistribution::per_core_counts`]), so every registered workload
//!   responds to the same `--skew` axis.
//! - **Packet loss** — `NetConfig::loss_prob` + `NetConfig::rto_ns`
//!   (see [`crate::net::NetConfig`]): each lost transmission attempt costs
//!   one retransmit timeout before the packet goes back on the wire,
//!   deterministically seeded through the fabric's `SplitMix64` stream.
//! - **Core oversubscription** — `NetConfig::oversub`: instead of the
//!   paper's non-blocking full-bisection core, cross-leaf packets contend
//!   for `leaf_radix / oversub` spine busy-until registers.
//! - **Stragglers** — [`StragglerConfig`]: a seeded subset of cores runs
//!   all compute (RX, handler cycles, TX issue offsets) slower by an
//!   integer factor, applied in the engine's cycle-to-time conversion.
//!
//! All knobs default **off** and are gated so the unperturbed event and
//! RNG streams are bit-identical to a build without this module — the
//! conformance goldens (`rust/conformance/golden/`) pin that.
//!
//! [`sweep`] is the grid driver behind `repro sweep <workload> --axis
//! <param>=a,b,c`: it runs the cartesian product of axis values over the
//! tier's base configuration, reuses the conformance digest machinery for
//! per-cell determinism, and reports makespan/p99 against the unperturbed
//! baseline.

pub mod sweep;

use anyhow::{bail, Result};

use crate::graysort::KeyGen;
use crate::sim::SplitMix64;

/// Zipf exponent used by [`KeyDistribution::Zipfian`]. Deliberately on
/// the aggressive side (YCSB uses 0.99) so the hot key's bucket is
/// unambiguously overfull even at CI-small smoke shapes.
pub const ZIPF_THETA: f64 = 1.2;

/// Distinct values used by [`KeyDistribution::FewDistinct`].
pub const FEW_DISTINCT_VALUES: usize = 16;

/// Seed salt for the straggler-core selection stream (shared by the
/// single-job scenario path and the multi-job service layer).
pub const STRAGGLER_SALT: u64 = 0x7374_7261_6767_6c65; // "straggle"

const ZIPF_SALT: u64 = 0x7a69_7066_6b65_7973; // "zipfkeys"
const RANK_SALT: u64 = 0x7261_6e6b_6d61_7073; // "rankmaps"
const FEW_SALT: u64 = 0x6665_7764_6973_7431; // "fewdist1"
const ADV_SALT: u64 = 0x6164_7662_7563_6b31; // "advbuck1"
const SHUF_SALT: u64 = 0x7065_7274_7368_7566; // "pertshuf"

/// How workload inputs are distributed across the key space (and, for
/// aggregation workloads, across cores).
///
/// `Uniform` is byte-for-byte the pre-perturbation input path (the
/// GraySort [`KeyGen`]); everything else models a named failure mode of
/// bucket sorts at scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KeyDistribution {
    /// Distinct uniform random keys — the paper's assumption.
    #[default]
    Uniform,
    /// Zipf-popularity keys (θ = [`ZIPF_THETA`]): heavy duplication of a
    /// few hot keys. Duplicates cannot be split by pivots, so the hot
    /// key's final bucket is overfull — the classic skew that breaks
    /// bucket sorts (PGX.D's motivating case).
    Zipfian,
    /// Globally pre-sorted input, assigned to cores in contiguous
    /// chunks: per-node pivot proposals come from disjoint narrow
    /// ranges, stressing the median-of-proposals correction.
    Sorted,
    /// Only [`FEW_DISTINCT_VALUES`] distinct key values: pivots cannot
    /// subdivide beyond the value count, so at most that many final
    /// buckets carry keys.
    FewDistinct,
    /// Half of all keys are one hot value — the adversarial bound for
    /// any pivot-bucketed sort (one final bucket must hold ≥ half the
    /// input).
    AdversarialBucket,
}

impl KeyDistribution {
    pub const ALL: [KeyDistribution; 5] = [
        KeyDistribution::Uniform,
        KeyDistribution::Zipfian,
        KeyDistribution::Sorted,
        KeyDistribution::FewDistinct,
        KeyDistribution::AdversarialBucket,
    ];

    pub fn name(self) -> &'static str {
        match self {
            KeyDistribution::Uniform => "uniform",
            KeyDistribution::Zipfian => "zipfian",
            KeyDistribution::Sorted => "sorted",
            KeyDistribution::FewDistinct => "few-distinct",
            KeyDistribution::AdversarialBucket => "adversarial",
        }
    }

    pub fn parse(s: &str) -> Result<KeyDistribution> {
        match s {
            "uniform" => Ok(KeyDistribution::Uniform),
            "zipfian" | "zipf" => Ok(KeyDistribution::Zipfian),
            "sorted" => Ok(KeyDistribution::Sorted),
            "few-distinct" | "fewdistinct" => Ok(KeyDistribution::FewDistinct),
            "adversarial" | "adversarial-bucket" => Ok(KeyDistribution::AdversarialBucket),
            other => bail!(
                "unknown key distribution {other:?} (known: uniform|zipfian|sorted|\
                 few-distinct|adversarial)"
            ),
        }
    }

    /// `total` keys split evenly across `cores` (total must divide), all
    /// `< u64::MAX` (the XLA padding sentinel).
    ///
    /// The `Uniform` arm routes through the exact pre-perturbation
    /// [`KeyGen`] path, so default-config runs stay bit-identical to the
    /// committed goldens.
    pub fn partitioned_keys(self, seed: u64, total: usize, cores: usize) -> Vec<Vec<u64>> {
        assert!(cores > 0 && total % cores == 0, "keys must divide evenly across cores");
        match self {
            KeyDistribution::Uniform => KeyGen::new(seed).generate(total, cores),
            KeyDistribution::Sorted => {
                let mut keys = KeyGen::new(seed).distinct_keys(total);
                keys.sort_unstable();
                chunk(keys, cores)
            }
            KeyDistribution::Zipfian => chunk(zipf_keys(seed, total), cores),
            KeyDistribution::FewDistinct => {
                let k = FEW_DISTINCT_VALUES.min(total.max(1));
                let pool = KeyGen::new(seed ^ FEW_SALT).distinct_keys(k);
                let mut rng = SplitMix64::new(seed ^ FEW_SALT.rotate_left(7));
                let keys = (0..total).map(|_| pool[rng.index(k)]).collect();
                chunk(keys, cores)
            }
            KeyDistribution::AdversarialBucket => {
                // `total - total/2` distinct keys, then `total/2` extra
                // copies of the first one, shuffled so every core holds
                // copies of the hot key.
                let mut keys = KeyGen::new(seed ^ ADV_SALT).distinct_keys(total - total / 2);
                let hot = keys[0];
                keys.extend(std::iter::repeat(hot).take(total / 2));
                SplitMix64::new(seed ^ SHUF_SALT).shuffle(&mut keys);
                chunk(keys, cores)
            }
        }
    }

    /// Streamed unit of [`KeyDistribution::partitioned_keys`]: node
    /// `node`'s `per`-key share, generated without materializing any
    /// other node's input. `Some` only where the distribution is defined
    /// per node (`Uniform` — the [`KeyGen::node_keys`] stream); the
    /// skewed shapes are global constructions (a sort over all keys, a
    /// fleet-wide shuffle) and return `None`, telling the caller to fall
    /// back to the materialized path. Where `Some`, the result is
    /// byte-identical to `partitioned_keys(..)[node]`.
    pub fn node_keys(self, seed: u64, node: usize, per: usize) -> Option<Vec<u64>> {
        match self {
            KeyDistribution::Uniform => Some(KeyGen::new(seed).node_keys(node, per)),
            _ => None,
        }
    }

    /// Per-core element counts for workloads whose input is local load
    /// rather than a shared key space (MergeMin values, set-algebra
    /// shards). `Uniform` is every core at `base`; the other shapes
    /// redistribute roughly `base × cores` elements unevenly (every core
    /// keeps at least one element so reduction trees stay well-formed).
    pub fn per_core_counts(self, base: usize, cores: usize) -> Vec<usize> {
        assert!(cores > 0);
        let base = base.max(1);
        let total = base * cores;
        match self {
            KeyDistribution::Uniform => vec![base; cores],
            KeyDistribution::Sorted => {
                // Linear ramp, mean ≈ base.
                (0..cores)
                    .map(|c| (2 * base * (c + 1) / (cores + 1)).max(1))
                    .collect()
            }
            KeyDistribution::Zipfian => {
                let w: Vec<f64> =
                    (0..cores).map(|c| 1.0 / ((c + 1) as f64).powf(ZIPF_THETA)).collect();
                let sum: f64 = w.iter().sum();
                w.iter().map(|x| ((total as f64 * x / sum) as usize).max(1)).collect()
            }
            KeyDistribution::FewDistinct => {
                // All load on the first FEW_DISTINCT_VALUES cores.
                let k = FEW_DISTINCT_VALUES.min(cores);
                (0..cores).map(|c| if c < k { (total / k).max(1) } else { 1 }).collect()
            }
            KeyDistribution::AdversarialBucket => {
                // One hot core carries half the cluster's load.
                (0..cores).map(|c| if c == 0 { (total / 2).max(1) } else { base / 2 + 1 }).collect()
            }
        }
    }
}

/// Zipf-popularity keys: ranks via the truncated inverse CDF
/// (`P(rank ≤ r) = (r^(1-θ) - 1) / (U^(1-θ) - 1)`, θ = [`ZIPF_THETA`],
/// universe `U = total`), each rank mapped to a fixed pseudo-random key so
/// hot keys are scattered across the key space rather than clustered.
fn zipf_keys(seed: u64, total: usize) -> Vec<u64> {
    let u = total.max(2) as f64;
    let e = 1.0 - ZIPF_THETA;
    let norm = u.powf(e) - 1.0;
    let mut rng = SplitMix64::new(seed ^ ZIPF_SALT);
    (0..total)
        .map(|_| {
            let x = rng.next_f64();
            let r = (norm * x + 1.0).powf(1.0 / e);
            key_of_rank((r as u64).clamp(1, total as u64))
        })
        .collect()
}

/// Deterministic key value of a zipf rank (`< u64::MAX`).
fn key_of_rank(rank: u64) -> u64 {
    let k = SplitMix64::new(rank ^ RANK_SALT).next_u64();
    if k == u64::MAX {
        RANK_SALT
    } else {
        k
    }
}

fn chunk(keys: Vec<u64>, cores: usize) -> Vec<Vec<u64>> {
    let per = keys.len() / cores;
    keys.chunks(per).map(|c| c.to_vec()).collect()
}

/// Straggler cores: `count` seeded-random cores run all compute slower by
/// `factor` (applied in the engine's cycle-to-time conversion). Default
/// off (`count = 0`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StragglerConfig {
    /// Number of straggler cores (clamped to the fleet size).
    pub count: usize,
    /// Integer slowdown factor (1 = no effect).
    pub factor: u32,
}

impl Default for StragglerConfig {
    fn default() -> Self {
        StragglerConfig { count: 0, factor: 4 }
    }
}

impl StragglerConfig {
    pub fn enabled(&self) -> bool {
        self.count > 0 && self.factor > 1
    }

    /// The straggler node indices for one job: a pure function of
    /// `(seed, job, nodes)`, drawn from a per-job derived stream
    /// ([`SplitMix64::derive`] on [`job_salt`]). Because each job gets its
    /// own stream, admitting a second concurrent job can never shift the
    /// straggler picks (or any downstream RNG state) of the first — the
    /// isolation the service digest relies on. Solo scenario runs are
    /// job 0. Returned sorted ascending; indices are relative to the
    /// job's own `nodes`-wide range.
    pub fn picks(&self, seed: u64, job: u64, nodes: usize) -> Vec<usize> {
        if !self.enabled() || nodes == 0 {
            return Vec::new();
        }
        let mut rng = SplitMix64::new(seed ^ STRAGGLER_SALT).derive(job_salt(job));
        rng.sample_indices(nodes, self.count.min(nodes))
    }
}

/// Per-job stream selector for perturbation draws. Job 0 is the solo
/// scenario path; the service layer passes each admitted job's id so
/// concurrent jobs draw from disjoint streams.
pub fn job_salt(job: u64) -> u64 {
    job
}

/// The scenario-level perturbations (network knobs live on
/// [`crate::net::NetConfig`] directly). Defaults are the unperturbed
/// paper assumptions.
#[derive(Debug, Clone, Default)]
pub struct Perturbations {
    /// Workload input distribution.
    pub dist: KeyDistribution,
    /// Straggler cores.
    pub stragglers: StragglerConfig,
}

/// Environment axis names shared by `repro sweep --axis`, the `repro run`
/// flags, and `repro run <name> --help`; every name not in a workload's
/// registry descriptors must match one of these.
pub const ENV_AXES: &[(&str, &str)] = &[
    ("skew", "key distribution: uniform|zipfian|sorted|few-distinct|adversarial"),
    ("loss", "packet loss per 10,000 deliveries (timeout + retransmit)"),
    ("rto", "retransmit timeout in ns (used when loss > 0; default 10000)"),
    ("tail", "extra ns injected on 1% of deliveries (Fig 14's knob)"),
    ("oversub", "core oversubscription factor (0 = non-blocking full bisection)"),
    ("stragglers", "number of straggler cores (slowed by straggler-factor)"),
    ("straggler-factor", "straggler compute slowdown factor (default 4)"),
];

/// True when `name` is an environment knob rather than a workload
/// parameter.
pub fn is_env_axis(name: &str) -> bool {
    ENV_AXES.iter().any(|(n, _)| *n == name)
}

/// Apply one environment knob (`name = value`) to the run's network
/// config and perturbation set. Errors on unknown names or malformed
/// values, so sweeps and CLI flags fail loudly instead of silently
/// running the happy path.
pub fn apply_env_setting(
    name: &str,
    value: &str,
    net: &mut crate::net::NetConfig,
    knobs: &mut Perturbations,
) -> Result<()> {
    fn num(name: &str, value: &str) -> Result<u64> {
        value
            .parse()
            .map_err(|_| anyhow::anyhow!("--{name} expects a number, got {value:?}"))
    }
    match name {
        "skew" => knobs.dist = KeyDistribution::parse(value)?,
        "loss" => {
            let n = num(name, value)?;
            anyhow::ensure!(n < 10_000, "--loss is per 10,000 and must be < 10000");
            net.loss_prob = (n, 10_000);
        }
        "rto" => net.rto_ns = num(name, value)?,
        "tail" => {
            let ns = num(name, value)?;
            net.tail_extra_ns = ns;
            // tail = 0 keeps the injection disabled so the fabric's RNG
            // stream (and thus the digest) is baseline-identical.
            net.tail_prob = if ns > 0 { (1, 100) } else { (0, 100) };
        }
        "oversub" => net.oversub = num(name, value)?,
        "stragglers" => knobs.stragglers.count = num(name, value)? as usize,
        "straggler-factor" => {
            knobs.stragglers.factor = num(name, value)?.max(1) as u32;
        }
        other => {
            let known: Vec<&str> = ENV_AXES.iter().map(|(n, _)| *n).collect();
            bail!("unknown environment knob {other:?} (known: {})", known.join("|"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_parse_round_trip() {
        for d in KeyDistribution::ALL {
            assert_eq!(KeyDistribution::parse(d.name()).unwrap(), d);
        }
        assert!(KeyDistribution::parse("gaussian").is_err());
        assert_eq!(
            KeyDistribution::parse("adversarial-bucket").unwrap(),
            KeyDistribution::AdversarialBucket
        );
    }

    #[test]
    fn uniform_is_bit_identical_to_keygen() {
        let a = KeyDistribution::Uniform.partitioned_keys(7, 256, 16);
        let b = KeyGen::new(7).generate(256, 16);
        assert_eq!(a, b, "default distribution must not disturb goldens");
    }

    /// The streamed path is defined exactly where it is byte-identical to
    /// the materialized slices; global constructions opt out with `None`.
    #[test]
    fn node_keys_match_materialized_where_defined() {
        for d in KeyDistribution::ALL {
            let parts = d.partitioned_keys(7, 256, 16);
            match d {
                KeyDistribution::Uniform => {
                    for (node, part) in parts.iter().enumerate() {
                        assert_eq!(
                            d.node_keys(7, node, 16).as_ref(),
                            Some(part),
                            "uniform node {node} stream drifted"
                        );
                    }
                }
                _ => assert_eq!(
                    d.node_keys(7, 0, 16),
                    None,
                    "{}: global construction must fall back",
                    d.name()
                ),
            }
        }
    }

    #[test]
    fn every_distribution_partitions_evenly_and_avoids_sentinel() {
        for d in KeyDistribution::ALL {
            let parts = d.partitioned_keys(0xC0FFEE, 512, 32);
            assert_eq!(parts.len(), 32, "{}", d.name());
            assert!(parts.iter().all(|p| p.len() == 16), "{}", d.name());
            assert!(
                parts.iter().flatten().all(|&k| k < u64::MAX),
                "{}: sentinel key produced",
                d.name()
            );
        }
    }

    #[test]
    fn generation_is_deterministic_per_distribution() {
        for d in KeyDistribution::ALL {
            let a = d.partitioned_keys(42, 128, 8);
            let b = d.partitioned_keys(42, 128, 8);
            assert_eq!(a, b, "{}", d.name());
        }
    }

    #[test]
    fn zipfian_duplicates_a_hot_key() {
        let keys: Vec<u64> = KeyDistribution::Zipfian
            .partitioned_keys(1, 512, 8)
            .into_iter()
            .flatten()
            .collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        let mut best = 1;
        let mut run = 1;
        for w in sorted.windows(2) {
            if w[0] == w[1] {
                run += 1;
                best = best.max(run);
            } else {
                run = 1;
            }
        }
        // θ = 1.2 puts >10% of draws on rank 1.
        assert!(best > 512 / 10, "hottest key appears {best} times");
    }

    #[test]
    fn sorted_is_globally_sorted_across_cores() {
        let parts = KeyDistribution::Sorted.partitioned_keys(3, 256, 16);
        let flat: Vec<u64> = parts.into_iter().flatten().collect();
        assert!(flat.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn few_distinct_bounds_the_value_count() {
        let parts = KeyDistribution::FewDistinct.partitioned_keys(9, 1024, 32);
        let mut vals: Vec<u64> = parts.into_iter().flatten().collect();
        vals.sort_unstable();
        vals.dedup();
        assert!(vals.len() <= FEW_DISTINCT_VALUES, "{} distinct", vals.len());
        assert!(vals.len() > 1);
    }

    #[test]
    fn adversarial_hot_key_holds_half_the_input() {
        let parts = KeyDistribution::AdversarialBucket.partitioned_keys(5, 256, 16);
        let keys: Vec<u64> = parts.iter().flatten().copied().collect();
        let mut counts = std::collections::HashMap::new();
        for k in &keys {
            *counts.entry(*k).or_insert(0usize) += 1;
        }
        let hot = counts.values().max().unwrap();
        assert!(*hot > 128, "hot key count = {hot}");
        // Shuffling spreads the hot key over many cores.
        let cores_with_hot = parts
            .iter()
            .filter(|p| p.iter().any(|k| counts[k] > 128))
            .count();
        assert!(cores_with_hot > 8, "hot key on {cores_with_hot} cores");
    }

    #[test]
    fn per_core_counts_shapes() {
        let uni = KeyDistribution::Uniform.per_core_counts(128, 64);
        assert_eq!(uni, vec![128; 64]);
        for d in KeyDistribution::ALL {
            let c = d.per_core_counts(128, 64);
            assert_eq!(c.len(), 64, "{}", d.name());
            assert!(c.iter().all(|&n| n >= 1), "{}: empty core", d.name());
            let total: usize = c.iter().sum();
            assert!(
                total >= 64 && total <= 3 * 128 * 64,
                "{}: total {total} out of range",
                d.name()
            );
        }
        let adv = KeyDistribution::AdversarialBucket.per_core_counts(128, 64);
        assert!(adv[0] > 10 * adv[1], "hot core dominates");
        let zipf = KeyDistribution::Zipfian.per_core_counts(128, 64);
        assert!(zipf[0] > zipf[63]);
    }

    #[test]
    fn env_settings_apply_and_reject_garbage() {
        let mut net = crate::net::NetConfig::default();
        let mut knobs = Perturbations::default();
        apply_env_setting("skew", "zipfian", &mut net, &mut knobs).unwrap();
        assert_eq!(knobs.dist, KeyDistribution::Zipfian);
        apply_env_setting("loss", "100", &mut net, &mut knobs).unwrap();
        assert_eq!(net.loss_prob, (100, 10_000));
        apply_env_setting("tail", "4000", &mut net, &mut knobs).unwrap();
        assert_eq!(net.tail_prob, (1, 100));
        assert_eq!(net.tail_extra_ns, 4000);
        apply_env_setting("tail", "0", &mut net, &mut knobs).unwrap();
        assert_eq!(net.tail_prob, (0, 100), "tail=0 keeps the RNG stream untouched");
        apply_env_setting("oversub", "8", &mut net, &mut knobs).unwrap();
        assert_eq!(net.oversub, 8);
        apply_env_setting("stragglers", "4", &mut net, &mut knobs).unwrap();
        apply_env_setting("straggler-factor", "6", &mut net, &mut knobs).unwrap();
        assert_eq!(knobs.stragglers, StragglerConfig { count: 4, factor: 6 });
        assert!(knobs.stragglers.enabled());

        assert!(apply_env_setting("loss", "10000", &mut net, &mut knobs).is_err());
        assert!(apply_env_setting("loss", "banana", &mut net, &mut knobs).is_err());
        assert!(apply_env_setting("warp", "9", &mut net, &mut knobs).is_err());
    }

    #[test]
    fn env_axis_names_are_consistent() {
        for &(name, _) in ENV_AXES {
            assert!(is_env_axis(name));
        }
        assert!(!is_env_axis("kpn"));
    }

    #[test]
    fn stragglers_default_off() {
        assert!(!StragglerConfig::default().enabled());
        assert!(!StragglerConfig { count: 3, factor: 1 }.enabled());
    }

    #[test]
    fn straggler_picks_are_a_pure_function_of_seed_job_nodes() {
        let st = StragglerConfig { count: 4, factor: 4 };
        let a = st.picks(7, 0, 64);
        let b = st.picks(7, 0, 64);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "sorted, distinct");
        assert!(a.iter().all(|&n| n < 64));
        // Different jobs draw from disjoint streams under the same seed.
        assert_ne!(st.picks(7, 0, 64), st.picks(7, 1, 64));
        // Disabled configs draw nothing.
        assert!(StragglerConfig::default().picks(7, 0, 64).is_empty());
        assert!(StragglerConfig { count: 2, factor: 1 }.picks(7, 0, 64).is_empty());
    }
}
