//! Deterministic perturbation sweeps: the grid driver behind
//! `repro sweep <workload> --axis <param>=a,b,c` and the named
//! `skewsweep` / `tailsweep` figures.
//!
//! A sweep takes a workload's conformance-tier base configuration, runs
//! the cartesian product of one or more axes over it, and reports every
//! cell against the unperturbed baseline. An axis is either a registry
//! workload parameter (`kpn=8,16`) or an environment knob
//! ([`super::ENV_AXES`]: `skew`, `loss`, `tail`, `oversub`,
//! `stragglers`, ...). Every cell:
//!
//! - runs through the one [`Scenario`] code path with the conformance
//!   seed by default, so a cell is a pure function of
//!   `(workload, tier, axis values, seed)`;
//! - is digested by the conformance machinery ([`digest_json`]) and
//!   fingerprinted (FNV-1a of the digest), so two runs of the same sweep
//!   can be compared line-by-line for drift exactly like goldens;
//! - must still *validate* — a perturbation may slow a run down
//!   arbitrarily, but correctness regressions fail the sweep.
//!
//! Output is one JSON line per cell (machine-diffable trajectory) plus a
//! rendered table with makespan, slowdown vs baseline, p99 per-node
//! completion time, and the workload's bucket-skew metric.

use anyhow::{bail, Context, Result};

use crate::conformance::{self, digest_json, Tier};
use crate::coordinator::{f, ComputeChoice, RunOptions, Table};
use crate::net::NetConfig;
use crate::scenario::registry::{self, ParamKind, WorkloadSpec};
use crate::scenario::{RunReport, Scenario};
use crate::sim::ExecKind;
use crate::stats::Summary;

use super::{apply_env_setting, is_env_axis, KeyDistribution, Perturbations};
use crate::conformance::digest::esc;

/// One sweep axis: a parameter name and the values it takes.
pub type Axis = (String, Vec<String>);

/// Parse `name=v1,v2,...` into an [`Axis`].
pub fn parse_axis(raw: &str) -> Result<Axis> {
    let (name, values) = raw
        .split_once('=')
        .with_context(|| format!("--axis expects name=v1,v2,... (got {raw:?})"))?;
    let values: Vec<String> =
        values.split(',').filter(|v| !v.is_empty()).map(str::to_string).collect();
    anyhow::ensure!(!name.is_empty() && !values.is_empty(), "--axis {raw:?} has no values");
    Ok((name.to_string(), values))
}

/// One completed sweep cell.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// Axis assignments in axis order; empty for the baseline.
    pub assignments: Vec<(String, String)>,
    pub makespan_us: f64,
    /// p99 of per-node completion times (`last_active`), µs.
    pub p99_node_us: f64,
    pub msgs_sent: u64,
    pub retransmits: u64,
    /// The workload's `skew` metric (bucket max/mean), if it reports one.
    pub bucket_skew: Option<f64>,
    pub validated: bool,
    /// FNV-1a fingerprint of the cell's canonical conformance digest.
    pub digest_fnv: u64,
}

impl SweepCell {
    /// Human label: `baseline` or `skew=zipfian loss=100`.
    pub fn label(&self) -> String {
        if self.assignments.is_empty() {
            "baseline".into()
        } else {
            self.assignments
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(" ")
        }
    }

    /// One line of JSON (the sweep's machine-readable trajectory record).
    pub fn json_line(&self, workload: &str, tier: &str, seed: u64) -> String {
        let mut cell = String::from("{");
        for (i, (k, v)) in self.assignments.iter().enumerate() {
            if i > 0 {
                cell.push(',');
            }
            cell += &format!("\"{}\": \"{}\"", esc(k), esc(v));
        }
        cell.push('}');
        let skew = match self.bucket_skew {
            Some(s) => format!(", \"bucket_skew\": \"{s:.6}\""),
            None => String::new(),
        };
        format!(
            "{{\"workload\": \"{}\", \"tier\": \"{}\", \"seed\": {}, \"cell\": {}, \
             \"makespan_us\": \"{:.6}\", \"p99_node_us\": \"{:.6}\", \"msgs_sent\": {}, \
             \"retransmits\": {}{}, \"validated\": {}, \"digest_fnv\": \"{:#018x}\"}}",
            esc(workload),
            esc(tier),
            seed,
            cell,
            self.makespan_us,
            self.p99_node_us,
            self.msgs_sent,
            self.retransmits,
            skew,
            self.validated,
            self.digest_fnv
        )
    }
}

/// Outcome of one sweep: the baseline-first cell records and the
/// rendered comparison table.
pub struct SweepOutcome {
    pub workload: &'static str,
    pub tier: Tier,
    pub seed: u64,
    /// Baseline first, then grid cells in axis-major order.
    pub cells: Vec<SweepCell>,
    pub table: Table,
}

impl SweepOutcome {
    /// All cells as JSON lines (baseline first).
    pub fn json_lines(&self) -> Vec<String> {
        self.cells
            .iter()
            .map(|c| c.json_line(self.workload, self.tier.name(), self.seed))
            .collect()
    }
}

/// Resolve the shared `--threads` convention (`0` = all host cores) —
/// one definition for the whole crate, re-exported here for the sweep
/// and CLI call sites.
pub use crate::sim::exec::resolve_threads;

/// Run the cartesian product of `axes` over `spec`'s `tier` base
/// configuration. The unperturbed baseline always runs first in the
/// output; every cell must validate.
///
/// `threads` > 1 dispatches independent grid cells across a worker pool
/// (each cell is a pure function of `(workload, tier, assignment, seed)`,
/// so cell-level parallelism cannot change any result — the cells
/// themselves run on the sequential backend). `0` = all host cores.
///
/// `exec` picks the executor backend *inside* each cell: `None` (the
/// default) keeps cells on the single-threaded sequential path;
/// `Some(kind)` runs every cell through `kind` on two sim worker
/// threads (one for `seq`) — enough to engage the sharded backends
/// without oversubscribing the cell pool. Digests are backend-invariant
/// by the executor contract, so every fingerprint in the output is
/// identical across `exec` settings; a differing cell is a determinism
/// bug, not a perturbation effect.
pub fn run_sweep(
    spec: &'static WorkloadSpec,
    tier: Tier,
    axes: &[Axis],
    compute: ComputeChoice,
    seed: u64,
    threads: usize,
    exec: Option<ExecKind>,
) -> Result<SweepOutcome> {
    run_sweep_with(spec, tier, axes, compute, seed, threads, exec, &|_, _| {})
}

/// [`run_sweep`] with a per-cell emitter: `emit(index, cell)` is called
/// exactly once per completed cell **in grid order** (baseline first) as
/// results become available — the serial path emits each cell the moment
/// it finishes; the pooled path drains an ordered cursor as slots fill.
/// This is how the CLI streams one JSON line per cell to stdout instead
/// of materializing the whole grid's records before printing a byte (at
/// the 4,096-cell grid cap the buffered variant held every record —
/// and, before the cells were slimmed, every full `RunReport` — until
/// the end of the run).
#[allow(clippy::too_many_arguments)]
pub fn run_sweep_with(
    spec: &'static WorkloadSpec,
    tier: Tier,
    axes: &[Axis],
    compute: ComputeChoice,
    seed: u64,
    threads: usize,
    exec: Option<ExecKind>,
    emit: &(dyn Fn(usize, &SweepCell) + Sync),
) -> Result<SweepOutcome> {
    // Validate axis names up front so a typo fails before any run.
    for (name, values) in axes {
        anyhow::ensure!(!values.is_empty(), "axis {name:?} has no values");
        let is_param = spec.all_params().any(|p| p.name == name.as_str());
        if !is_param && !is_env_axis(name) {
            let params: Vec<&str> = spec.all_params().map(|p| p.name).collect();
            let env: Vec<&str> = super::ENV_AXES.iter().map(|(n, _)| *n).collect();
            bail!(
                "unknown sweep axis {name:?} for workload {} (workload params: {}; \
                 environment knobs: {})",
                spec.name,
                params.join("|"),
                env.join("|")
            );
        }
    }
    let cells_total: usize = axes.iter().map(|(_, v)| v.len()).product();
    anyhow::ensure!(cells_total <= 4096, "sweep grid has {cells_total} cells (max 4096)");

    // The work list: baseline first, then grid cells in axis-major order.
    let mut assignments: Vec<Vec<(String, String)>> = Vec::with_capacity(cells_total + 1);
    assignments.push(Vec::new());
    for idx in Grid::new(axes) {
        assignments.push(
            idx.iter()
                .enumerate()
                .map(|(a, &i)| (axes[a].0.clone(), axes[a].1[i].clone()))
                .collect(),
        );
    }

    let workers = resolve_threads(threads).min(assignments.len()).max(1);
    let cells: Vec<SweepCell> = if workers <= 1 {
        let mut cells = Vec::with_capacity(assignments.len());
        for (i, a) in assignments.iter().enumerate() {
            let cell = run_cell(spec, tier, a, compute, seed, exec)?;
            emit(i, &cell);
            cells.push(cell);
        }
        cells
    } else {
        run_cells_pooled(spec, tier, &assignments, compute, seed, workers, exec, emit)?
    };

    let table = render_table(spec.name, tier, &cells);
    Ok(SweepOutcome { workload: spec.name, tier, seed, cells, table })
}

/// Dispatch cells across `workers` threads via an atomic work queue;
/// results land in their slot, so the output order (and every digest) is
/// identical to the serial path. The first error (in cell order) wins.
/// After landing a result, each worker advances the shared emission
/// cursor over the contiguous prefix of completed slots, so `emit` fires
/// in grid order while later cells are still running (an `Err` slot
/// halts emission; the error surfaces from the ordered drain below).
#[allow(clippy::too_many_arguments)]
fn run_cells_pooled(
    spec: &'static WorkloadSpec,
    tier: Tier,
    assignments: &[Vec<(String, String)>],
    compute: ComputeChoice,
    seed: u64,
    workers: usize,
    exec: Option<ExecKind>,
    emit: &(dyn Fn(usize, &SweepCell) + Sync),
) -> Result<Vec<SweepCell>> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    type CellSlot = Mutex<Option<Result<SweepCell>>>;
    let next = AtomicUsize::new(0);
    let cursor = Mutex::new(0usize);
    let slots: Vec<CellSlot> = assignments.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= assignments.len() {
                    return;
                }
                let cell = run_cell(spec, tier, &assignments[i], compute, seed, exec);
                *slots[i].lock().expect("cell slot") = Some(cell);
                // Drain the contiguous completed prefix in grid order.
                // Holding the cursor lock serializes emission, so no
                // two workers can emit the same index or reorder lines.
                let mut done = cursor.lock().expect("emit cursor");
                while *done < slots.len() {
                    let slot = slots[*done].lock().expect("cell slot");
                    match slot.as_ref() {
                        Some(Ok(cell)) => emit(*done, cell),
                        _ => break,
                    }
                    drop(slot);
                    *done += 1;
                }
            });
        }
    });
    let mut cells = Vec::with_capacity(assignments.len());
    for slot in slots {
        cells.push(slot.into_inner().expect("cell slot").expect("cell completed")?);
    }
    Ok(cells)
}

/// Run one cell: tier base params + axis overrides, one `Scenario`.
fn run_cell(
    spec: &'static WorkloadSpec,
    tier: Tier,
    assignment: &[(String, String)],
    compute: ComputeChoice,
    seed: u64,
    exec: Option<ExecKind>,
) -> Result<SweepCell> {
    let mut pairs = conformance::tier_params(spec, tier);
    let mut net = NetConfig::default();
    let mut knobs = Perturbations::default();
    for (name, value) in assignment {
        if let Some(p) = spec.all_params().find(|p| p.name == name.as_str()) {
            let v = match p.kind {
                ParamKind::U64 => value
                    .parse::<u64>()
                    .with_context(|| format!("axis {name}={value}: expected a number"))?,
                ParamKind::Flag => match value.as_str() {
                    "1" | "true" | "on" => 1,
                    "0" | "false" | "off" => 0,
                    other => bail!("axis {name}={other}: flags take 0/1"),
                },
            };
            pairs.retain(|(n, _)| *n != p.name);
            pairs.push((p.name, v));
        } else {
            apply_env_setting(name, value, &mut net, &mut knobs)
                .with_context(|| format!("axis {name}={value}"))?;
        }
    }

    let params = registry::params_from_pairs(spec, &pairs)
        .with_context(|| format!("{} {} cell params", spec.name, tier.name()))?;
    let workload = (spec.build)(&params)?;
    let nodes = params.u64(spec.nodes_param.name)? as usize;
    let (kind, cell_threads) = match exec {
        Some(ExecKind::Seq) | None => (ExecKind::default(), 1),
        Some(kind) => (kind, 2),
    };
    let report = Scenario::from_dyn(workload)
        .nodes(nodes)
        .net(net)
        .perturb(knobs)
        .compute(compute)
        .seed(seed)
        .exec(kind)
        .threads(cell_threads)
        .run()?;
    anyhow::ensure!(
        report.validation.ok(),
        "{} {} cell [{}]: perturbed run failed validation: {}",
        spec.name,
        tier.name(),
        assignment.iter().map(|(k, v)| format!("{k}={v}")).collect::<Vec<_>>().join(" "),
        report.validation.detail
    );
    Ok(cell_of(assignment.to_vec(), &report, tier))
}

fn cell_of(assignments: Vec<(String, String)>, report: &RunReport, tier: Tier) -> SweepCell {
    let completion: Vec<f64> =
        report.summary.node_stats.iter().map(|s| s.last_active.as_us_f64()).collect();
    SweepCell {
        assignments,
        makespan_us: report.runtime().as_us_f64(),
        p99_node_us: Summary::of(&completion).p99,
        msgs_sent: report.summary.net.msgs_sent,
        retransmits: report.summary.net.retransmits,
        bucket_skew: report.metric_f64("skew"),
        validated: report.validation.ok(),
        digest_fnv: fnv64(&digest_json(report, tier.name())),
    }
}

fn render_table(workload: &str, tier: Tier, cells: &[SweepCell]) -> Table {
    let mut t = Table::new(
        format!("sweep — {workload} @ {} tier vs unperturbed baseline", tier.name()),
        &["cell", "makespan_us", "vs_base", "p99_node_us", "bucket_skew", "retx", "valid"],
    );
    let base = cells.first().map(|c| c.makespan_us).unwrap_or(f64::NAN);
    for c in cells {
        t.row(vec![
            c.label(),
            f(c.makespan_us),
            format!("{:.2}x", c.makespan_us / base),
            f(c.p99_node_us),
            c.bucket_skew.map(f).unwrap_or_else(|| "-".into()),
            c.retransmits.to_string(),
            c.validated.to_string(),
        ]);
    }
    t.note("baseline = the tier's conformance configuration, no perturbations");
    t.note("digest_fnv in the JSON lines fingerprints each cell's canonical digest");
    t
}

/// Cartesian-product index iterator over axis value lists.
struct Grid {
    lens: Vec<usize>,
    next: Option<Vec<usize>>,
}

impl Grid {
    fn new(axes: &[Axis]) -> Grid {
        let lens: Vec<usize> = axes.iter().map(|(_, v)| v.len()).collect();
        let next =
            if axes.is_empty() || lens.iter().any(|&l| l == 0) { None } else { Some(vec![0; lens.len()]) };
        Grid { lens, next }
    }
}

impl Iterator for Grid {
    type Item = Vec<usize>;
    fn next(&mut self) -> Option<Vec<usize>> {
        let cur = self.next.clone()?;
        // Odometer increment, last axis fastest.
        let mut idx = cur.clone();
        let mut done = true;
        for a in (0..idx.len()).rev() {
            idx[a] += 1;
            if idx[a] < self.lens[a] {
                done = false;
                break;
            }
            idx[a] = 0;
        }
        self.next = if done { None } else { Some(idx) };
        Some(cur)
    }
}

/// FNV-1a over the digest bytes: a compact per-cell fingerprint for the
/// line-JSON trajectory.
fn fnv64(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Named figure: the skew-sensitivity study — NanoSort across every
/// [`KeyDistribution`] (the PGX.D observation: input skew is what breaks
/// bucket sorts at scale). Smoke tier under `--quick`, mid otherwise.
pub fn skew_sweep_figure(opts: &RunOptions) -> Result<Table> {
    let spec = registry::find("nanosort")?;
    let tier = if opts.quick { Tier::Smoke } else { Tier::Mid };
    let axes = vec![(
        "skew".to_string(),
        KeyDistribution::ALL.iter().map(|d| d.name().to_string()).collect(),
    )];
    let mut out = run_sweep(spec, tier, &axes, opts.compute, opts.seed, 1, None)?;
    out.table.note(
        "skew study: zipfian/few-distinct/adversarial inputs vs the paper's uniform assumption",
    );
    Ok(out.table)
}

/// Named figure: the Fig 14-style tail-sensitivity study rebuilt on the
/// sweep driver — injected p99 latency from 0 to 4,000 ns.
pub fn tail_sweep_figure(opts: &RunOptions) -> Result<Table> {
    let spec = registry::find("nanosort")?;
    let tier = if opts.quick { Tier::Smoke } else { Tier::Mid };
    let axes = vec![(
        "tail".to_string(),
        ["0", "500", "1000", "2000", "4000"].iter().map(|s| s.to_string()).collect(),
    )];
    let mut out = run_sweep(spec, tier, &axes, opts.compute, opts.seed, 1, None)?;
    out.table.note("Fig 14-style: paper sees 2x runtime at 4,000 ns injected p99");
    Ok(out.table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conformance::CONFORMANCE_SEED;

    #[test]
    fn axis_parsing() {
        let (name, values) = parse_axis("skew=uniform,zipfian").unwrap();
        assert_eq!(name, "skew");
        assert_eq!(values, ["uniform", "zipfian"]);
        let (name, values) = parse_axis("kpn=8").unwrap();
        assert_eq!((name.as_str(), values.len()), ("kpn", 1));
        assert!(parse_axis("skew").is_err());
        assert!(parse_axis("skew=").is_err());
        assert!(parse_axis("=a,b").is_err());
    }

    #[test]
    fn grid_is_the_cartesian_product() {
        let axes: Vec<Axis> = vec![
            ("a".into(), vec!["1".into(), "2".into()]),
            ("b".into(), vec!["x".into(), "y".into(), "z".into()]),
        ];
        let idx: Vec<Vec<usize>> = Grid::new(&axes).collect();
        assert_eq!(idx.len(), 6);
        assert_eq!(idx[0], vec![0, 0]);
        assert_eq!(idx[1], vec![0, 1]);
        assert_eq!(idx[5], vec![1, 2]);
        assert_eq!(Grid::new(&[]).count(), 0);
    }

    #[test]
    fn unknown_axis_is_an_error() {
        let spec = registry::find("nanosort").unwrap();
        let axes = vec![("warp".to_string(), vec!["9".to_string()])];
        let err = run_sweep(spec, Tier::Smoke, &axes, ComputeChoice::Native, 1, 1, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown sweep axis"), "{err}");
        assert!(err.contains("skew"), "error lists env knobs: {err}");
    }

    #[test]
    fn workload_param_axis_overrides_tier_base() {
        let spec = registry::find("mergemin").unwrap();
        let axes = vec![("incast".to_string(), vec!["2".to_string(), "8".to_string()])];
        let out =
            run_sweep(spec, Tier::Smoke, &axes, ComputeChoice::Native, CONFORMANCE_SEED, 1, None)
                .unwrap();
        assert_eq!(out.cells.len(), 3, "baseline + 2 cells");
        assert_eq!(out.cells[0].label(), "baseline");
        assert_eq!(out.cells[1].label(), "incast=2");
        // Different incast => different digest fingerprint.
        assert_ne!(out.cells[1].digest_fnv, out.cells[2].digest_fnv);
        assert!(out.cells.iter().all(|c| c.validated));
        assert_eq!(out.table.rows.len(), 3);
    }

    /// The acceptance sweep: `repro sweep nanosort --axis
    /// skew=uniform,zipfian` at smoke tier is deterministic and the
    /// zipfian cell's bucket skew strictly exceeds the uniform cell's.
    #[test]
    fn skew_axis_zipfian_exceeds_uniform_and_replays_identically() {
        let spec = registry::find("nanosort").unwrap();
        let axes =
            vec![("skew".to_string(), vec!["uniform".to_string(), "zipfian".to_string()])];
        let run = || {
            run_sweep(spec, Tier::Smoke, &axes, ComputeChoice::Native, CONFORMANCE_SEED, 1, None)
                .unwrap()
        };
        let a = run();
        let uniform = a.cells[1].bucket_skew.expect("nanosort reports skew");
        let zipfian = a.cells[2].bucket_skew.expect("nanosort reports skew");
        assert!(
            zipfian > uniform,
            "zipfian bucket skew {zipfian} must exceed uniform {uniform}"
        );
        // The uniform cell is the baseline configuration spelled out.
        assert_eq!(a.cells[0].digest_fnv, a.cells[1].digest_fnv);
        // Determinism: a second sweep replays every fingerprint.
        let b = run();
        let fa: Vec<u64> = a.cells.iter().map(|c| c.digest_fnv).collect();
        let fb: Vec<u64> = b.cells.iter().map(|c| c.digest_fnv).collect();
        assert_eq!(fa, fb);
        // And the JSON lines are stable, machine-diffable records.
        assert_eq!(a.json_lines(), b.json_lines());
        assert!(a.json_lines()[2].contains("\"skew\": \"zipfian\""));
    }

    /// Cell-level parallelism is a pure scheduling choice: the pooled
    /// sweep must reproduce the serial sweep's JSON lines byte for byte
    /// (cells land in their slots regardless of completion order).
    #[test]
    fn pooled_sweep_matches_serial_byte_for_byte() {
        let spec = registry::find("mergemin").unwrap();
        let axes = vec![
            ("incast".to_string(), vec!["2".into(), "4".into(), "8".into()]),
            ("vpc".to_string(), vec!["8".into(), "16".into()]),
        ];
        let run = |threads| {
            run_sweep(
                spec,
                Tier::Smoke,
                &axes,
                ComputeChoice::Native,
                CONFORMANCE_SEED,
                threads,
                None,
            )
            .unwrap()
        };
        let serial = run(1);
        let pooled = run(4);
        assert_eq!(serial.cells.len(), 7, "baseline + 3x2 grid");
        assert_eq!(serial.json_lines(), pooled.json_lines());
        assert_eq!(serial.table.render(), pooled.table.render());
        // `0` = all host cores, same contract.
        assert_eq!(run(0).json_lines(), serial.json_lines());
    }

    /// The executor contract at the sweep boundary: running every cell
    /// through the sharded or optimistic backend reproduces the
    /// sequential sweep's JSON lines byte for byte — including under a
    /// perturbation axis, where speculation actually has stragglers and
    /// retransmits to mis-speculate against.
    #[test]
    fn sweep_cells_are_executor_invariant() {
        let spec = registry::find("mergemin").unwrap();
        let axes = vec![
            ("incast".to_string(), vec!["2".into(), "8".into()]),
            ("loss".to_string(), vec!["0".into(), "1000".into()]),
        ];
        let run = |exec| {
            run_sweep(spec, Tier::Smoke, &axes, ComputeChoice::Native, CONFORMANCE_SEED, 1, exec)
                .unwrap()
        };
        let seq = run(None);
        for kind in [ExecKind::Par, ExecKind::Opt] {
            assert_eq!(
                seq.json_lines(),
                run(Some(kind)).json_lines(),
                "{} backend diverged in a sweep cell",
                kind.name()
            );
        }
    }

    #[test]
    fn resolve_threads_contract() {
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(7), 7);
        assert!(resolve_threads(0) >= 1, "0 resolves to the host core count");
    }

    #[test]
    fn loss_axis_reports_retransmits_and_slows_the_run() {
        let spec = registry::find("nanosort").unwrap();
        let axes = vec![("loss".to_string(), vec!["2000".to_string()])];
        let out =
            run_sweep(spec, Tier::Smoke, &axes, ComputeChoice::Native, CONFORMANCE_SEED, 1, None)
                .unwrap();
        let base = &out.cells[0];
        let lossy = &out.cells[1];
        assert_eq!(base.retransmits, 0);
        assert!(lossy.retransmits > 0, "20% loss must retransmit");
        assert!(lossy.makespan_us > base.makespan_us);
        assert!(lossy.validated, "loss must not break correctness");
    }

    /// The streaming emitter fires exactly once per cell, in grid order,
    /// with the same records the outcome carries — serial and pooled.
    #[test]
    fn emitter_streams_cells_in_grid_order() {
        use std::sync::Mutex;
        let spec = registry::find("mergemin").unwrap();
        let axes = vec![
            ("incast".to_string(), vec!["2".into(), "4".into(), "8".into()]),
            ("vpc".to_string(), vec!["8".into(), "16".into()]),
        ];
        for threads in [1usize, 4] {
            let seen: Mutex<Vec<(usize, String)>> = Mutex::new(Vec::new());
            let out = run_sweep_with(
                spec,
                Tier::Smoke,
                &axes,
                ComputeChoice::Native,
                CONFORMANCE_SEED,
                threads,
                None,
                &|i, c| seen.lock().unwrap().push((i, c.label())),
            )
            .unwrap();
            let seen = seen.into_inner().unwrap();
            assert_eq!(seen.len(), out.cells.len(), "threads={threads}");
            for (slot, (i, label)) in seen.iter().enumerate() {
                assert_eq!(slot, *i, "grid order (threads={threads})");
                assert_eq!(label, &out.cells[slot].label(), "threads={threads}");
            }
        }
    }

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv64(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64("a"), fnv64("a"));
        assert_ne!(fnv64("a"), fnv64("b"));
    }
}
