//! Conformance & regression harness: named scale tiers, canonical
//! run-report digests, golden-file comparison, and `BENCH_*.json`
//! perf-trajectory records.
//!
//! The paper's headline claim — 1M keys across 65,536 nanoPU cores in
//! 68 µs — is a *configuration*, and this module makes configurations
//! first-class: every registered workload can run at a named [`Tier`]
//! (`smoke`/`mid`/`paper`) with a fixed seed, its [`RunReport`] collapses
//! to a canonical JSON digest ([`digest`]), and the digest is compared
//! against checked-in goldens under `rust/conformance/golden/`
//! ([`golden`]). Any seeded-result drift — a timing change, a message-count
//! change, a validation regression — fails the comparison with a line
//! diff; intentional changes are re-blessed (`--bless` /
//! `BLESS_GOLDEN=1`).
//!
//! Entry points: `repro paper [--tier T] [--bless]` (CLI),
//! `repro fig paperscale` (figure), and `rust/tests/conformance.rs`
//! (the CI gate, smoke tier).

pub mod digest;
pub mod golden;

pub use digest::digest_json;
pub use golden::{check_golden, golden_dir, GoldenOutcome};

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::compute::LocalCompute;
use crate::coordinator::ComputeChoice;
use crate::pool::WorkerPool;
use crate::scenario::registry::{self, WorkloadSpec};
use crate::scenario::{RunReport, Scenario};
use crate::sim::{ExecKind, ExecProfile};

/// The paper's headline runtime (mean over 10 runs, §6.3).
pub const PAPER_RUNTIME_US: f64 = 68.0;
/// The paper's headline fleet size.
pub const PAPER_NODES: usize = 65_536;
/// Keys per core in the headline configuration (re-exported by
/// `benchfig` as `HEADLINE_KEYS_PER_NODE` — one definition for the
/// headline shape, shared by the figure and the tier ladder).
pub const PAPER_KEYS_PER_NODE: usize = 16;
/// The paper's headline key count (16 per core × 65,536 cores = 1M).
pub const PAPER_KEYS: usize = PAPER_NODES * PAPER_KEYS_PER_NODE;
/// Mid-tier fleet size (the `--quick` headline scale).
pub const MID_NODES: usize = 4096;
/// Hyper-smoke fleet size: 2^17 cores — past the paper's headline, small
/// enough for a CI leg with a hard memory ceiling.
pub const HYPER_SMOKE_NODES: usize = 131_072;
/// Hyper fleet size: 2^20 cores (= 16^5, so the bucket fan-out stays 16).
pub const HYPER_NODES: usize = 1_048_576;
/// Keys per core at the hyper tier: 96 × 2^20 ≈ 100.7M keys — the
/// 100×-headline run the memory diet exists for.
pub const HYPER_KEYS_PER_NODE: usize = 96;

/// Fixed seed for every conformance run: goldens are a function of
/// (workload, tier, seed), and pinning the seed makes them a function of
/// (workload, tier) alone.
pub const CONFORMANCE_SEED: u64 = 0x00C0_FFEE;

/// Named scale tier of a conformance run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// CI-small (the registry's per-workload smoke tuple; milliseconds).
    Smoke,
    /// The `--quick` figure scale (e.g. NanoSort at 4,096 cores; <1 s).
    Mid,
    /// The paper's published configuration (NanoSort: 65,536 cores ×
    /// 1M keys with the GraySort value phase; seconds of wall-clock).
    Paper,
    /// Memory-gated scale probe at 2^17 cores: streamed input is forced
    /// on and the CI leg enforces a peak-RSS ceiling. Key-only (the
    /// value phase doubles the footprint without exercising anything the
    /// memory diet doesn't already cover).
    HyperSmoke,
    /// The 1M+-core tier: 2^20 cores × 96 keys ≈ 100.7M keys, streamed
    /// input forced on. Minutes of wall-clock; run locally with
    /// `--spill` when host RAM is tight.
    Hyper,
}

impl Tier {
    pub const ALL: [Tier; 5] =
        [Tier::Smoke, Tier::Mid, Tier::Paper, Tier::HyperSmoke, Tier::Hyper];

    pub fn name(self) -> &'static str {
        match self {
            Tier::Smoke => "smoke",
            Tier::Mid => "mid",
            Tier::Paper => "paper",
            Tier::HyperSmoke => "hyper-smoke",
            Tier::Hyper => "hyper",
        }
    }

    pub fn parse(s: &str) -> Result<Tier> {
        match s {
            "smoke" => Ok(Tier::Smoke),
            "mid" => Ok(Tier::Mid),
            "paper" => Ok(Tier::Paper),
            "hyper-smoke" => Ok(Tier::HyperSmoke),
            "hyper" => Ok(Tier::Hyper),
            other => {
                bail!("unknown tier {other:?} (known: smoke|mid|paper|hyper-smoke|hyper)")
            }
        }
    }

    /// Hyper tiers run with per-node streamed input generation forced on
    /// (the whole point is that the full key array never exists on
    /// host); every other tier leaves the default materialized path.
    pub fn is_hyper(self) -> bool {
        matches!(self, Tier::HyperSmoke | Tier::Hyper)
    }
}

/// Parameter tuple for `spec` at `tier`. Smoke comes straight from the
/// registry row; mid/paper are the scale-up ladders per workload (flag
/// parameters use 0/1, see [`registry::params_from_pairs`]).
pub fn tier_params(spec: &WorkloadSpec, tier: Tier) -> Vec<(&'static str, u64)> {
    match tier {
        Tier::Smoke => spec.smoke.to_vec(),
        Tier::Mid => match spec.name {
            // The `--quick` headline shape: 64 K keys, three levels.
            "nanosort" => vec![
                ("nodes", MID_NODES as u64),
                ("kpn", PAPER_KEYS_PER_NODE as u64),
                ("buckets", 16),
                ("values", 1),
            ],
            "millisort" => vec![("cores", 128), ("keys", 8192)],
            "mergemin" => vec![("cores", MID_NODES as u64), ("vpc", 16), ("incast", 16)],
            "setalgebra" => vec![("cores", 256), ("ids", 128)],
            _ => spec.smoke.to_vec(),
        },
        Tier::Paper => match spec.name {
            // §6.3 headline: 1M keys / 65,536 cores, GraySort value phase.
            "nanosort" => vec![
                ("nodes", PAPER_NODES as u64),
                ("kpn", PAPER_KEYS_PER_NODE as u64),
                ("buckets", 16),
                ("values", 1),
            ],
            "millisort" => vec![("cores", 256), ("keys", 32_768)],
            // Fig 3's design-space probe at 1M values.
            "mergemin" => vec![("cores", PAPER_NODES as u64), ("vpc", 16), ("incast", 16)],
            "setalgebra" => vec![("cores", 4096), ("ids", 256)],
            _ => spec.smoke.to_vec(),
        },
        Tier::HyperSmoke => match spec.name {
            // 2^17 nodes forces buckets = 2 (depth 17: nodes must be an
            // exact bucket power); key-only keeps the CI leg's RSS
            // ceiling about nodes, not payload.
            "nanosort" => vec![
                ("nodes", HYPER_SMOKE_NODES as u64),
                ("kpn", 8),
                ("buckets", 2),
                ("values", 0),
            ],
            "millisort" => vec![("cores", 512), ("keys", 65_536)],
            "mergemin" => {
                vec![("cores", HYPER_SMOKE_NODES as u64), ("vpc", 8), ("incast", 16)]
            }
            "setalgebra" => vec![("cores", 1024), ("ids", 256)],
            _ => spec.smoke.to_vec(),
        },
        Tier::Hyper => match spec.name {
            // 2^20 = 16^5 nodes × 96 keys ≈ 100.7M keys: the sublinear-
            // in-keys, tight-in-nodes footprint claim at full stretch.
            "nanosort" => vec![
                ("nodes", HYPER_NODES as u64),
                ("kpn", HYPER_KEYS_PER_NODE as u64),
                ("buckets", 16),
                ("values", 0),
            ],
            "millisort" => vec![("cores", 1024), ("keys", 131_072)],
            "mergemin" => vec![("cores", HYPER_NODES as u64), ("vpc", 8), ("incast", 16)],
            "setalgebra" => vec![("cores", 8192), ("ids", 512)],
            _ => spec.smoke.to_vec(),
        },
    }
}

/// Run `spec` at `tier` with the conformance seed through the one
/// [`Scenario`] code path, on `threads` executor worker threads (`1` =
/// sequential reference backend, `0` = all host cores; the digest is
/// identical at every setting). Returns the report plus wall-clock
/// seconds (the host-time half of the perf trajectory).
pub fn run_tier(
    spec: &WorkloadSpec,
    tier: Tier,
    compute: ComputeChoice,
    threads: usize,
) -> Result<(RunReport, f64)> {
    run_tier_exec(spec, tier, compute, threads, ExecKind::default())
}

/// [`run_tier`] with an explicit executor backend. `exec` only matters
/// when `threads != 1`: `par` is the conservative adaptive-window
/// backend, `opt` adds speculation past the window bound with rollback
/// on mis-speculation. The digest is identical across every
/// (exec, threads) combination — that invariance is the contract CI
/// enforces (`rust/tests/exec.rs`, `rust/tests/exec_fuzz.rs`).
pub fn run_tier_exec(
    spec: &WorkloadSpec,
    tier: Tier,
    compute: ComputeChoice,
    threads: usize,
    exec: ExecKind,
) -> Result<(RunReport, f64)> {
    let params = registry::params_from_pairs(spec, &tier_params(spec, tier))
        .with_context(|| format!("{} {} tier params", spec.name, tier.name()))?;
    let workload = (spec.build)(&params)?;
    let nodes = params.u64(spec.nodes_param.name)? as usize;
    let start = std::time::Instant::now();
    let mut scenario = Scenario::from_dyn(workload)
        .nodes(nodes)
        .compute(compute)
        .seed(CONFORMANCE_SEED)
        .threads(threads)
        .exec(exec);
    if tier.is_hyper() {
        scenario = scenario.stream_input();
    }
    let report = scenario.run()?;
    Ok((report, start.elapsed().as_secs_f64()))
}

/// [`run_tier_exec`] with an already-built data plane and an explicit
/// shared worker pool — the entry point `repro paper` uses so the same
/// plane instance can be interrogated afterwards for its BENCH `tuner` /
/// `kernel_histogram` fields, and so plane kernels and executor shards
/// provably share one `--threads` budget ([`crate::pool`]).
pub fn run_tier_with(
    spec: &WorkloadSpec,
    tier: Tier,
    plane: Arc<dyn LocalCompute>,
    pool: Arc<WorkerPool>,
    threads: usize,
    exec: ExecKind,
) -> Result<(RunReport, f64)> {
    let params = registry::params_from_pairs(spec, &tier_params(spec, tier))
        .with_context(|| format!("{} {} tier params", spec.name, tier.name()))?;
    let workload = (spec.build)(&params)?;
    let nodes = params.u64(spec.nodes_param.name)? as usize;
    let start = std::time::Instant::now();
    let mut scenario = Scenario::from_dyn(workload)
        .nodes(nodes)
        .compute_with(plane)
        .pool(pool)
        .seed(CONFORMANCE_SEED)
        .threads(threads)
        .exec(exec);
    if tier.is_hyper() {
        scenario = scenario.stream_input();
    }
    let report = scenario.run()?;
    Ok((report, start.elapsed().as_secs_f64()))
}

/// One `BENCH_<workload>.json` record: the simulated result next to the
/// wall-clock cost of producing it, so the perf trajectory across PRs is
/// measurable on both axes. `wall_clock_s` is always the sequential
/// (`threads = 1`) backend on the primary data plane, broken down into
/// host phases (`input_gen_s`/`sim_s`/`validate_s`). Two optional
/// comparison measurements ride along — the digests are identical by
/// contract in both cases, only the host time differs:
///
/// - `threads`/`wall_clock_par_s`/`speedup`: the parallel backend;
/// - `wall_clock_native_s`/`compute_speedup`: the `NativeCompute` oracle
///   plane (the radix-kernel before/after).
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub workload: String,
    pub tier: &'static str,
    pub nodes: usize,
    pub keys: usize,
    /// Data plane of the primary measurement (`radix` by default).
    pub compute: &'static str,
    pub makespan_us: f64,
    /// Sequential-backend wall clock (threads = 1), primary plane.
    pub wall_clock_s: f64,
    /// Host-phase breakdown of the primary run (wall-clock seconds).
    /// Public so determinism tests can zero the measured values.
    pub phases: crate::scenario::PhaseWallClock,
    /// Parallel-backend measurement, when taken: (worker threads,
    /// wall-clock seconds). The digest is identical by contract.
    pub parallel: Option<(usize, f64)>,
    /// Executor backend of the parallel comparison leg (`"par"` or
    /// `"opt"`); `"seq"` when no comparison leg was taken.
    pub exec: &'static str,
    /// Rollback count from the optimistic backend's comparison leg
    /// (`--exec opt` only; mis-speculated bursts that were undone and
    /// re-executed conservatively).
    pub rollbacks: Option<u64>,
    /// Mean committed speculative burst span in sim ticks (`--exec opt`
    /// only): how far past the conservative window bound speculation
    /// actually paid off, averaged over committed bursts.
    pub committed_window_avg: Option<f64>,
    /// Oracle-plane (native) sequential wall clock, when measured.
    pub native_wall_clock_s: Option<f64>,
    /// Kernel-tuner mode of the primary plane (`"auto"` or the forced
    /// `NANOSORT_TUNER` family), when the plane reports one.
    pub tuner: Option<&'static str>,
    /// Per-kernel dispatch counts from the primary run, in canonical
    /// algorithm order (radix plane only; digest-invisible telemetry).
    pub kernel_histogram: Option<Vec<(&'static str, u64)>>,
    /// Process peak RSS in MiB after the primary run
    /// ([`crate::mem::peak_rss_mb`]); `None` off Linux. The CI
    /// memory-ceiling gate reads this field from the hyper-smoke BENCH
    /// sidecar.
    pub peak_rss_mb: Option<u64>,
    /// Bytes routed through the spill sinks during the primary run
    /// ([`crate::graysort::take_bytes_spilled`]); 0 when spill is off.
    pub bytes_spilled: u64,
    /// Heap allocations during the primary run
    /// ([`crate::mem::alloc_count`] delta) — the churn proxy next to
    /// peak RSS.
    pub alloc_count: u64,
    /// `alloc_count / events`: allocation churn normalized per simulated
    /// event, so records at different tiers are comparable and the CI
    /// alloc-churn gate has a scale-free figure to ceiling-check
    /// (computed by [`BenchRecord::with_mem`]; 0 until then).
    pub allocs_per_event: f64,
    pub events: u64,
    pub msgs_sent: u64,
    pub validated: bool,
}

impl BenchRecord {
    pub fn from_report(report: &RunReport, tier: Tier, wall_clock_s: f64) -> BenchRecord {
        let keys = report
            .validation
            .sort
            .as_ref()
            .map(|s| s.total_keys)
            .unwrap_or(0);
        BenchRecord {
            workload: report.workload.to_string(),
            tier: tier.name(),
            nodes: report.nodes,
            keys,
            compute: report.compute,
            makespan_us: report.runtime().as_us_f64(),
            wall_clock_s,
            phases: report.phases,
            parallel: None,
            exec: ExecKind::Seq.name(),
            rollbacks: None,
            committed_window_avg: None,
            native_wall_clock_s: None,
            tuner: None,
            kernel_histogram: None,
            peak_rss_mb: None,
            bytes_spilled: 0,
            alloc_count: 0,
            allocs_per_event: 0.0,
            events: report.summary.events,
            msgs_sent: report.summary.net.msgs_sent,
            validated: report.validation.ok(),
        }
    }

    /// Attach a parallel-backend wall-clock measurement.
    pub fn with_parallel(mut self, threads: usize, wall_clock_s: f64) -> BenchRecord {
        self.parallel = Some((threads, wall_clock_s));
        if self.exec == ExecKind::Seq.name() {
            self.exec = ExecKind::Par.name();
        }
        self
    }

    /// Record which executor backend drove the comparison leg, plus the
    /// optimistic backend's speculation counters when `kind` is
    /// [`ExecKind::Opt`].
    pub fn with_exec(mut self, kind: ExecKind, profile: &ExecProfile) -> BenchRecord {
        self.exec = kind.name();
        if kind == ExecKind::Opt {
            self.rollbacks = Some(profile.rollbacks);
            self.committed_window_avg = Some(if profile.committed > 0 {
                profile.committed_span as f64 / profile.committed as f64
            } else {
                0.0
            });
        }
        self
    }

    /// Attach the oracle-plane (native) sequential wall clock.
    pub fn with_native_baseline(mut self, wall_clock_s: f64) -> BenchRecord {
        self.native_wall_clock_s = Some(wall_clock_s);
        self
    }

    /// Attach the primary plane's kernel-tuner telemetry: the tuner mode
    /// and the per-kernel dispatch histogram
    /// (`RadixCompute::tuner_mode` / `kernel_histogram`).
    pub fn with_tuner(
        mut self,
        mode: &'static str,
        histogram: Vec<(&'static str, u64)>,
    ) -> BenchRecord {
        self.tuner = Some(mode);
        self.kernel_histogram = Some(histogram);
        self
    }

    /// Attach the host memory measurements: peak RSS (`None` off
    /// Linux), bytes routed through spill sinks (0 when spill is off),
    /// and the heap-allocation delta across the primary run. These are
    /// measurements like wall-clock, never digest material.
    pub fn with_mem(
        mut self,
        peak_rss_mb: Option<u64>,
        bytes_spilled: u64,
        alloc_count: u64,
    ) -> BenchRecord {
        self.peak_rss_mb = peak_rss_mb;
        self.bytes_spilled = bytes_spilled;
        self.alloc_count = alloc_count;
        self.allocs_per_event = alloc_count as f64 / self.events.max(1) as f64;
        self
    }

    pub fn to_json(&self) -> String {
        let parallel = match self.parallel {
            Some((threads, wall)) => format!(
                "\n  \"threads\": {threads},\n  \"wall_clock_par_s\": {wall:.3},\n  \
                 \"speedup\": {:.2},",
                self.wall_clock_s / wall.max(1e-9)
            ),
            None => String::new(),
        };
        let mut opt = String::new();
        if let Some(rollbacks) = self.rollbacks {
            opt.push_str(&format!("\n  \"rollbacks\": {rollbacks},"));
        }
        if let Some(avg) = self.committed_window_avg {
            opt.push_str(&format!("\n  \"committed_window_avg\": {avg:.1},"));
        }
        let native = match self.native_wall_clock_s {
            Some(wall) => format!(
                "\n  \"wall_clock_native_s\": {wall:.3},\n  \"compute_speedup\": {:.2},",
                wall / self.wall_clock_s.max(1e-9)
            ),
            None => String::new(),
        };
        let tuner = match (&self.tuner, &self.kernel_histogram) {
            (Some(mode), Some(hist)) => {
                let cells: Vec<String> =
                    hist.iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
                format!(
                    "\n  \"tuner\": \"{mode}\",\n  \"kernel_histogram\": {{{}}},",
                    cells.join(", ")
                )
            }
            _ => String::new(),
        };
        // Memory section: present once `with_mem` attached a
        // measurement (any real run allocates, so alloc_count > 0
        // whenever the measurement was taken).
        let mem = if self.peak_rss_mb.is_some() || self.alloc_count > 0 {
            let rss = match self.peak_rss_mb {
                Some(mb) => format!("\n  \"peak_rss_mb\": {mb},"),
                None => String::new(),
            };
            format!(
                "{rss}\n  \"bytes_spilled\": {},\n  \"alloc_count\": {},\n  \
                 \"allocs_per_event\": {:.3},",
                self.bytes_spilled, self.alloc_count, self.allocs_per_event
            )
        } else {
            String::new()
        };
        format!(
            "{{\n  \"workload\": \"{}\",\n  \"tier\": \"{}\",\n  \"nodes\": {},\n  \
             \"keys\": {},\n  \"compute\": \"{}\",\n  \"exec\": \"{}\",\n  \
             \"makespan_us\": {:.3},\n  \
             \"paper_makespan_us\": {:.1},\n  \"wall_clock_s\": {:.3},\n  \
             \"input_gen_s\": {:.3},\n  \"sim_s\": {:.3},\n  \"validate_s\": {:.3},{}{}{}{}{}\n  \
             \"events\": {},\n  \"msgs_sent\": {},\n  \"validated\": {}\n}}\n",
            self.workload,
            self.tier,
            self.nodes,
            self.keys,
            self.compute,
            self.exec,
            self.makespan_us,
            PAPER_RUNTIME_US,
            self.wall_clock_s,
            self.phases.input_gen_s,
            self.phases.sim_s,
            self.phases.validate_s,
            parallel,
            opt,
            native,
            tuner,
            mem,
            self.events,
            self.msgs_sent,
            self.validated
        )
    }
}

/// Where a bench record lands: the repo root (the crate manifest dir
/// when cargo provides it, else the current directory). The paper tier
/// owns the canonical `BENCH_<workload>.json` name — the cross-PR perf
/// trajectory — while other tiers get `BENCH_<workload>_<tier>.json`,
/// so a CI smoke run never overwrites a paper-tier record.
pub fn bench_path(workload: &str, tier: &str) -> PathBuf {
    let root = match std::env::var_os("CARGO_MANIFEST_DIR") {
        Some(d) => PathBuf::from(d),
        None => PathBuf::from("."),
    };
    if tier == Tier::Paper.name() {
        root.join(format!("BENCH_{workload}.json"))
    } else {
        root.join(format!("BENCH_{workload}_{tier}.json"))
    }
}

/// Write the bench record; returns the path written.
pub fn write_bench(record: &BenchRecord) -> Result<PathBuf> {
    let path = bench_path(&record.workload, record.tier);
    std::fs::write(&path, record.to_json())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Time;

    #[test]
    fn tier_names_round_trip() {
        for tier in Tier::ALL {
            assert_eq!(Tier::parse(tier.name()).unwrap(), tier);
        }
        assert!(Tier::parse("galactic").is_err());
    }

    #[test]
    fn tier_params_resolve_for_every_workload_and_tier() {
        for spec in registry::WORKLOADS {
            for tier in Tier::ALL {
                let params =
                    registry::params_from_pairs(spec, &tier_params(spec, tier))
                        .unwrap_or_else(|e| panic!("{} {}: {e:#}", spec.name, tier.name()));
                (spec.build)(&params)
                    .unwrap_or_else(|e| panic!("{} {}: {e:#}", spec.name, tier.name()));
            }
        }
    }

    /// Hyper tiers must keep node counts exact bucket powers (the tree
    /// depth math requires it) and stay key-only; both force streamed
    /// input.
    #[test]
    fn hyper_tiers_are_bucket_exact_and_key_only() {
        let spec = registry::find("nanosort").unwrap();
        for (tier, nodes, buckets) in [
            (Tier::HyperSmoke, HYPER_SMOKE_NODES as u64, 2u64),
            (Tier::Hyper, HYPER_NODES as u64, 16),
        ] {
            assert!(tier.is_hyper());
            let p = registry::params_from_pairs(spec, &tier_params(spec, tier)).unwrap();
            assert_eq!(p.u64("nodes").unwrap(), nodes);
            assert_eq!(p.u64("buckets").unwrap(), buckets);
            assert!(buckets.pow(nodes.ilog(buckets)) == nodes, "exact bucket power");
            assert!(!p.flag("values"), "hyper tiers are key-only");
        }
        assert!(!Tier::Paper.is_hyper());
        // ~100.7M keys at the hyper tier — the 100×-headline claim.
        assert!(HYPER_NODES * HYPER_KEYS_PER_NODE > 100_000_000);
    }

    #[test]
    fn paper_tier_is_the_headline_configuration() {
        let spec = registry::find("nanosort").unwrap();
        let p = registry::params_from_pairs(spec, &tier_params(spec, Tier::Paper)).unwrap();
        assert_eq!(p.u64("nodes").unwrap() as usize, PAPER_NODES);
        let keys = p.u64("nodes").unwrap() * p.u64("kpn").unwrap();
        assert_eq!(keys as usize, PAPER_KEYS, "1M keys");
        assert!(p.flag("values"), "headline includes the GraySort value phase");
    }

    #[test]
    fn smoke_tier_runs_and_digests() {
        let spec = registry::find("mergemin").unwrap();
        let (report, wall) =
            run_tier(spec, Tier::Smoke, ComputeChoice::Native, 1).unwrap();
        assert!(report.validation.ok());
        assert!(report.runtime() > Time::ZERO);
        assert!(wall >= 0.0);
        let record = BenchRecord::from_report(&report, Tier::Smoke, wall);
        let json = record.to_json();
        assert!(json.contains("\"workload\": \"mergemin\""));
        assert!(json.contains("\"tier\": \"smoke\""));
        assert!(json.contains("\"validated\": true"));
    }

    #[test]
    fn bench_record_carries_both_backend_wall_clocks() {
        let spec = registry::find("mergemin").unwrap();
        let (report, wall) = run_tier(spec, Tier::Smoke, ComputeChoice::Native, 1).unwrap();
        let record = BenchRecord::from_report(&report, Tier::Smoke, wall);
        assert!(!record.to_json().contains("wall_clock_par_s"), "seq-only record");
        let both = record.with_parallel(4, 0.5);
        let json = both.to_json();
        assert!(json.contains("\"threads\": 4"), "{json}");
        assert!(json.contains("\"wall_clock_par_s\": 0.500"), "{json}");
        assert!(json.contains("\"speedup\": "), "{json}");
        assert!(json.contains("\"exec\": \"par\""), "{json}");
    }

    /// The opt comparison leg stamps the backend name plus its
    /// speculation counters; the par leg carries neither counter.
    #[test]
    fn bench_record_carries_opt_counters() {
        let spec = registry::find("mergemin").unwrap();
        let (report, wall) =
            run_tier_exec(spec, Tier::Smoke, ComputeChoice::Native, 4, ExecKind::Opt)
                .unwrap();
        let record = BenchRecord::from_report(&report, Tier::Smoke, wall);
        assert!(!record.to_json().contains("\"rollbacks\""), "seq record is counter-free");
        let json = record
            .with_parallel(4, 0.5)
            .with_exec(ExecKind::Opt, &report.summary.profile)
            .to_json();
        assert!(json.contains("\"exec\": \"opt\""), "{json}");
        assert!(json.contains("\"rollbacks\": "), "{json}");
        assert!(json.contains("\"committed_window_avg\": "), "{json}");
    }

    /// The record carries the per-phase host breakdown and, when
    /// measured, the oracle-plane baseline with its speedup ratio.
    #[test]
    fn bench_record_carries_phases_and_compute_baseline() {
        let spec = registry::find("mergemin").unwrap();
        let (report, wall) = run_tier(spec, Tier::Smoke, ComputeChoice::Radix, 1).unwrap();
        let record = BenchRecord::from_report(&report, Tier::Smoke, wall);
        let json = record.to_json();
        assert!(json.contains("\"compute\": \"radix\""), "{json}");
        for key in ["input_gen_s", "sim_s", "validate_s"] {
            assert!(json.contains(&format!("\"{key}\": ")), "{key} missing: {json}");
        }
        assert!(!json.contains("wall_clock_native_s"), "baseline only when measured");
        let json = record.with_native_baseline(0.25).to_json();
        assert!(json.contains("\"wall_clock_native_s\": 0.250"), "{json}");
        assert!(json.contains("\"compute_speedup\": "), "{json}");
    }

    /// The tuner telemetry section appears only when attached, and
    /// serializes the histogram as a canonical-order JSON object.
    #[test]
    fn bench_record_carries_tuner_and_kernel_histogram() {
        let spec = registry::find("mergemin").unwrap();
        let (report, wall) = run_tier(spec, Tier::Smoke, ComputeChoice::Radix, 1).unwrap();
        let record = BenchRecord::from_report(&report, Tier::Smoke, wall);
        assert!(!record.to_json().contains("\"tuner\""), "tuner only when attached");
        let json = record
            .with_tuner("auto", vec![("comparative", 12), ("lsb", 3)])
            .to_json();
        assert!(json.contains("\"tuner\": \"auto\""), "{json}");
        assert!(
            json.contains("\"kernel_histogram\": {\"comparative\": 12, \"lsb\": 3}"),
            "{json}"
        );
    }

    /// The memory section appears only once `with_mem` attaches a
    /// measurement, and the optional peak-RSS field degrades gracefully
    /// off Linux.
    #[test]
    fn bench_record_carries_memory_measurements() {
        let spec = registry::find("mergemin").unwrap();
        let (report, wall) = run_tier(spec, Tier::Smoke, ComputeChoice::Native, 1).unwrap();
        let record = BenchRecord::from_report(&report, Tier::Smoke, wall);
        let json = record.to_json();
        assert!(!json.contains("\"peak_rss_mb\""), "mem only when attached: {json}");
        assert!(!json.contains("\"alloc_count\""), "mem only when attached: {json}");
        let with_mem = record.clone().with_mem(Some(123), 4096, 77);
        let json = with_mem.to_json();
        assert!(json.contains("\"peak_rss_mb\": 123"), "{json}");
        assert!(json.contains("\"bytes_spilled\": 4096"), "{json}");
        assert!(json.contains("\"alloc_count\": 77"), "{json}");
        assert!(json.contains("\"allocs_per_event\""), "{json}");
        // allocs_per_event = alloc_count / events, never NaN/inf.
        let expect = 77.0 / with_mem.events.max(1) as f64;
        assert!((with_mem.allocs_per_event - expect).abs() < 1e-12);
        assert!(with_mem.allocs_per_event.is_finite());
        let json = record.with_mem(None, 0, 77).to_json();
        assert!(!json.contains("\"peak_rss_mb\""), "optional off Linux: {json}");
        assert!(json.contains("\"bytes_spilled\": 0"), "{json}");
    }

    /// `run_tier_with` (explicit plane + pool) matches the
    /// `ComputeChoice` path digest-for-digest — the contract that lets
    /// `repro paper` keep a handle on the plane for BENCH telemetry.
    #[test]
    fn run_tier_with_matches_the_choice_path() {
        let spec = registry::find("mergemin").unwrap();
        let (by_choice, _) = run_tier(spec, Tier::Smoke, ComputeChoice::Radix, 1).unwrap();
        let pool = Arc::new(WorkerPool::new(1));
        let plane = Arc::new(crate::compute::RadixCompute::with_pool(pool.clone()));
        let (by_plane, _) =
            run_tier_with(spec, Tier::Smoke, plane, pool, 1, ExecKind::default()).unwrap();
        assert_eq!(digest_json(&by_choice, "smoke"), digest_json(&by_plane, "smoke"));
    }

    #[test]
    fn run_tier_digest_is_thread_count_invariant() {
        let spec = registry::find("nanosort").unwrap();
        let (seq, _) = run_tier(spec, Tier::Smoke, ComputeChoice::Native, 1).unwrap();
        let (par, _) = run_tier(spec, Tier::Smoke, ComputeChoice::Native, 4).unwrap();
        assert_eq!(
            digest_json(&seq, "smoke"),
            digest_json(&par, "smoke"),
            "conformance digests must not depend on the executor backend"
        );
        let (opt, _) =
            run_tier_exec(spec, Tier::Smoke, ComputeChoice::Native, 4, ExecKind::Opt)
                .unwrap();
        assert_eq!(
            digest_json(&seq, "smoke"),
            digest_json(&opt, "smoke"),
            "the optimistic backend must be digest-invisible"
        );
    }

    /// The §8 data-plane contract at the conformance boundary: for every
    /// workload, the smoke-tier digest is identical on the oracle and
    /// radix planes, at both thread counts CI exercises.
    #[test]
    fn run_tier_digest_is_compute_plane_invariant() {
        for spec in registry::WORKLOADS {
            let (native, _) =
                run_tier(spec, Tier::Smoke, ComputeChoice::Native, 1).unwrap();
            let expect = digest_json(&native, "smoke");
            for threads in [1usize, 4] {
                let (radix, _) =
                    run_tier(spec, Tier::Smoke, ComputeChoice::Radix, threads).unwrap();
                assert_eq!(
                    expect,
                    digest_json(&radix, "smoke"),
                    "{}: radix plane (threads={threads}) diverged from the oracle",
                    spec.name
                );
            }
        }
    }

    #[test]
    fn bench_paths_are_tier_scoped_except_paper() {
        assert!(bench_path("nanosort", "paper").ends_with("BENCH_nanosort.json"));
        assert!(bench_path("nanosort", "smoke").ends_with("BENCH_nanosort_smoke.json"));
        assert!(bench_path("mergemin", "mid").ends_with("BENCH_mergemin_mid.json"));
    }

    #[test]
    fn bench_json_is_deterministic_modulo_wall_clock() {
        let spec = registry::find("mergemin").unwrap();
        let (a, _) = run_tier(spec, Tier::Smoke, ComputeChoice::Native, 1).unwrap();
        let (b, _) = run_tier(spec, Tier::Smoke, ComputeChoice::Native, 1).unwrap();
        let mut ra = BenchRecord::from_report(&a, Tier::Smoke, 0.0);
        let mut rb = BenchRecord::from_report(&b, Tier::Smoke, 0.0);
        // Host-phase clocks are measurements, not results — zero them.
        ra.phases = Default::default();
        rb.phases = Default::default();
        assert_eq!(ra.to_json(), rb.to_json());
    }
}
