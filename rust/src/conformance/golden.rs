//! Golden-file comparison: checked-in canonical digests under
//! `rust/conformance/golden/`, compared byte-for-byte with a line diff on
//! mismatch and a bless path for intentional changes.
//!
//! Bless workflow:
//! - a *missing* golden is created in place (first run on a fresh
//!   checkout / newly added workload) and reported as `Blessed` — commit
//!   the generated file to turn it into a regression gate;
//! - an *intentional* change is accepted with `repro paper --bless` or
//!   `BLESS_GOLDEN=1 cargo test -q --test conformance`;
//! - anything else is a `Mismatch`, which the callers turn into a test
//!   failure / non-zero exit with the diff below.

use std::path::PathBuf;

use anyhow::{Context, Result};

/// Outcome of one golden comparison.
#[derive(Debug)]
pub enum GoldenOutcome {
    /// Digest matches the checked-in golden byte-for-byte.
    Matched,
    /// Golden written (missing before, or bless requested).
    Blessed { path: PathBuf, created: bool },
    /// Seeded-result drift: the digest differs from the golden.
    Mismatch { path: PathBuf, diff: String },
}

/// Directory holding the golden digests (anchored at the crate manifest
/// so tests and the CLI agree regardless of working directory).
pub fn golden_dir() -> PathBuf {
    let root = match std::env::var_os("CARGO_MANIFEST_DIR") {
        Some(d) => PathBuf::from(d),
        None => PathBuf::from("."),
    };
    root.join("rust").join("conformance").join("golden")
}

/// True when the `BLESS_GOLDEN` env var requests blessing. Empty and
/// `"0"` count as *unset* so a stale `BLESS_GOLDEN=0`/`BLESS_GOLDEN=`
/// in the environment cannot silently disarm the drift gate.
pub fn bless_requested_by_env() -> bool {
    match std::env::var("BLESS_GOLDEN") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

/// Compare `actual` against the golden named `name` (file
/// `<golden_dir>/<name>.json`). `bless` — or `BLESS_GOLDEN` set to a
/// non-empty, non-`0` value — accepts the new digest by overwriting the
/// file.
pub fn check_golden(name: &str, actual: &str, bless: bool) -> Result<GoldenOutcome> {
    let bless = bless || bless_requested_by_env();
    let dir = golden_dir();
    let path = dir.join(format!("{name}.json"));
    if !path.exists() {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        std::fs::write(&path, actual)
            .with_context(|| format!("writing {}", path.display()))?;
        return Ok(GoldenOutcome::Blessed { path, created: true });
    }
    let golden = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    if golden.trim_end() == actual.trim_end() {
        return Ok(GoldenOutcome::Matched);
    }
    if bless {
        std::fs::write(&path, actual)
            .with_context(|| format!("writing {}", path.display()))?;
        return Ok(GoldenOutcome::Blessed { path, created: false });
    }
    Ok(GoldenOutcome::Mismatch { path, diff: line_diff(&golden, actual) })
}

/// Line-oriented diff of two digests: every differing line rendered as
/// `- golden` / `+ actual`, prefixed with its 1-based line number.
pub fn line_diff(golden: &str, actual: &str) -> String {
    let g: Vec<&str> = golden.trim_end().lines().collect();
    let a: Vec<&str> = actual.trim_end().lines().collect();
    let mut out = String::new();
    for i in 0..g.len().max(a.len()) {
        let gl = g.get(i).copied();
        let al = a.get(i).copied();
        if gl != al {
            out.push_str(&format!("line {}:\n", i + 1));
            if let Some(gl) = gl {
                out.push_str(&format!("  - {gl}\n"));
            }
            if let Some(al) = al {
                out.push_str(&format!("  + {al}\n"));
            }
        }
    }
    if out.is_empty() {
        out.push_str("(no line-level difference; trailing whitespace only)\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_diff_pinpoints_changes() {
        let d = line_diff("a\nb\nc\n", "a\nB\nc\n");
        assert!(d.contains("line 2:"), "{d}");
        assert!(d.contains("- b") && d.contains("+ B"), "{d}");
        assert!(!d.contains("line 1:") && !d.contains("line 3:"), "{d}");
    }

    #[test]
    fn line_diff_handles_length_mismatch() {
        let d = line_diff("a\n", "a\nb\n");
        assert!(d.contains("line 2:") && d.contains("+ b"), "{d}");
        let d = line_diff("a\nb\n", "a\n");
        assert!(d.contains("line 2:") && d.contains("- b"), "{d}");
    }

    #[test]
    fn golden_dir_is_under_the_crate() {
        let dir = golden_dir();
        assert!(dir.ends_with("rust/conformance/golden"), "{}", dir.display());
    }

    /// Full cycle against a temp name (cleaned up afterwards): missing →
    /// blessed/created, same → matched, changed → mismatch with diff,
    /// bless → accepted.
    #[test]
    fn bless_env_contract() {
        // Pure contract check of the parse rule (no env mutation — tests
        // run multithreaded): unset/empty/"0" must not bless.
        assert!(!bless_requested_by_env() || {
            let v = std::env::var("BLESS_GOLDEN").unwrap_or_default();
            !v.is_empty() && v != "0"
        });
    }

    #[test]
    fn check_golden_lifecycle() {
        if bless_requested_by_env() {
            return; // bless-everything runs can't observe a mismatch
        }
        let name = "zz_selftest_lifecycle";
        let path = golden_dir().join(format!("{name}.json"));
        let _ = std::fs::remove_file(&path);

        match check_golden(name, "{\n  \"k\": 1\n}\n", false).unwrap() {
            GoldenOutcome::Blessed { created: true, .. } => {}
            other => panic!("expected created bless, got {other:?}"),
        }
        assert!(matches!(
            check_golden(name, "{\n  \"k\": 1\n}\n", false).unwrap(),
            GoldenOutcome::Matched
        ));
        match check_golden(name, "{\n  \"k\": 2\n}\n", false).unwrap() {
            GoldenOutcome::Mismatch { diff, .. } => {
                assert!(diff.contains("\"k\": 1") && diff.contains("\"k\": 2"), "{diff}");
            }
            other => panic!("expected mismatch, got {other:?}"),
        }
        match check_golden(name, "{\n  \"k\": 2\n}\n", true).unwrap() {
            GoldenOutcome::Blessed { created: false, .. } => {}
            other => panic!("expected bless, got {other:?}"),
        }
        assert!(matches!(
            check_golden(name, "{\n  \"k\": 2\n}\n", false).unwrap(),
            GoldenOutcome::Matched
        ));
        let _ = std::fs::remove_file(&path);
    }
}
