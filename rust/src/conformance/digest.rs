//! Canonical digest of a [`RunReport`]: a flat, line-oriented JSON
//! document capturing every *simulated* result a run produces — makespan
//! (exact integer time units), event/message/byte counters, validation,
//! workload metrics, and per-stage busy/idle sums.
//!
//! Design rules, so goldens diff cleanly and never flake:
//! - one key per line → golden mismatches reduce to a line diff;
//! - exact integers wherever the simulator is exact (time units, counts);
//! - floats only for derived/display values, always fixed-precision
//!   (`{:.6}`) — f64 arithmetic here is sums/divides, which IEEE 754
//!   makes bit-identical across platforms.

use crate::scenario::{MetricValue, RunReport};
use crate::sim::Time;

/// Escape a string for a JSON value (the digests only carry short ASCII
/// detail lines, but be correct anyway). Shared with the sweep driver's
/// line-JSON records.
pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render the canonical digest of `report` (tagged with the tier it ran
/// at). The output is the exact byte content of a golden file.
pub fn digest_json(report: &RunReport, tier: &str) -> String {
    let mut lines: Vec<String> = Vec::new();
    lines.push(format!("\"workload\": \"{}\"", esc(report.workload)));
    lines.push(format!("\"tier\": \"{}\"", esc(tier)));
    lines.push(format!("\"nodes\": {}", report.nodes));
    lines.push(format!("\"seed\": {}", report.seed));
    lines.push(format!("\"makespan_units\": {}", report.summary.makespan.0));
    lines.push(format!("\"makespan_us\": \"{:.6}\"", report.summary.makespan.as_us_f64()));
    lines.push(format!("\"events\": {}", report.summary.events));
    let net = &report.summary.net;
    lines.push(format!("\"msgs_sent\": {}", net.msgs_sent));
    lines.push(format!("\"msgs_delivered\": {}", net.msgs_delivered));
    lines.push(format!("\"payload_bytes\": {}", net.payload_bytes));
    lines.push(format!("\"wire_bytes\": {}", net.wire_bytes));
    lines.push(format!("\"multicasts\": {}", net.multicasts));
    lines.push(format!("\"tail_hits\": {}", net.tail_hits));
    lines.push(format!("\"retransmits\": {}", net.retransmits));
    lines.push(format!("\"validation_ok\": {}", report.validation.ok()));
    lines.push(format!("\"validation\": \"{}\"", esc(&report.validation.detail)));
    if let Some(sort) = &report.validation.sort {
        lines.push(format!("\"total_keys\": {}", sort.total_keys));
    }
    for m in &report.metrics {
        let value = match m.value {
            MetricValue::U64(v) => format!("{v}"),
            MetricValue::F64(v) => format!("\"{v:.6}\""),
            MetricValue::Bool(v) => format!("{v}"),
        };
        lines.push(format!("\"metric.{}\": {}", esc(m.name), value));
    }
    // Per-stage busy/idle totals across nodes, in exact integer units.
    for row in &report.stages {
        let stage = row.stage;
        let busy: Time =
            Time(report.summary.node_stats.iter().map(|s| s.busy[stage].0).sum());
        let idle: Time =
            Time(report.summary.node_stats.iter().map(|s| s.idle[stage].0).sum());
        lines.push(format!("\"stage{stage}_busy_units\": {}", busy.0));
        lines.push(format!("\"stage{stage}_idle_units\": {}", idle.0));
    }

    let mut out = String::from("{\n");
    let n = lines.len();
    for (i, line) in lines.into_iter().enumerate() {
        out.push_str("  ");
        out.push_str(&line);
        if i + 1 < n {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::mergemin::MergeMin;
    use crate::algo::nanosort::NanoSort;
    use crate::scenario::Scenario;

    #[test]
    fn digest_is_valid_flat_json_shape() {
        let r = Scenario::new(MergeMin::default()).nodes(8).seed(5).run().unwrap();
        let d = digest_json(&r, "smoke");
        assert!(d.starts_with("{\n") && d.ends_with("}\n"));
        assert!(d.contains("\"workload\": \"mergemin\""));
        assert!(d.contains("\"tier\": \"smoke\""));
        assert!(d.contains("\"makespan_units\": "));
        assert!(d.contains("\"validation_ok\": true"));
        assert!(d.contains("\"retransmits\": 0"), "lossless runs pin zero retransmits");
        assert!(d.contains("\"metric.found_min\": "));
        assert!(d.contains("\"stage0_busy_units\": "));
        // Every body line but the last ends with a comma.
        let body: Vec<&str> = d.lines().collect();
        for line in &body[1..body.len() - 2] {
            assert!(line.ends_with(','), "{line}");
        }
        assert!(!body[body.len() - 2].ends_with(','));
    }

    #[test]
    fn digest_is_deterministic_and_seed_sensitive() {
        let run = |seed| {
            Scenario::new(NanoSort { keys_per_node: 8, buckets: 4, median_incast: 4, ..Default::default() })
                .nodes(16)
                .seed(seed)
                .run()
                .unwrap()
        };
        let a = digest_json(&run(7), "smoke");
        let b = digest_json(&run(7), "smoke");
        let c = digest_json(&run(8), "smoke");
        assert_eq!(a, b, "same seed, same digest");
        assert_ne!(a, c, "digest must be sensitive to the seeded result");
    }

    #[test]
    fn sort_workloads_record_total_keys() {
        let r = Scenario::new(NanoSort { keys_per_node: 8, buckets: 4, median_incast: 4, ..Default::default() })
            .nodes(16)
            .seed(1)
            .run()
            .unwrap();
        let d = digest_json(&r, "smoke");
        assert!(d.contains("\"total_keys\": 128"), "{d}");
    }

    #[test]
    fn escape_covers_quotes_and_control_chars() {
        assert_eq!(esc("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(esc("x\ny"), "x\\ny");
        assert_eq!(esc("\u{1}"), "\\u0001");
    }
}
