//! The nanoPU programming model (paper §2.1, §5.2).
//!
//! Node programs are event-driven state machines over the register-file
//! message interface: [`Program::on_start`] fires once at t=0,
//! [`Program::on_message`] per delivered message. All sends are
//! fire-and-forget (§3.2 "asynchronous communication"); synchronization is
//! built into the algorithms.
//!
//! Because cores do not progress in lockstep, a core may receive messages
//! for a *future* algorithm step; the engine implements the paper's §5.2
//! software **reorder buffer**: messages whose [`WireMsg::step`] exceeds
//! the program's [`Program::step`] are buffered (paying RX + a store) and
//! re-delivered when the program reaches that step.

#[cfg(test)]
mod tests;

use crate::cpu::CoreModel;
use crate::sim::{SplitMix64, Time};

/// Node identifier (dense, `0..nodes`).
pub type NodeId = usize;

/// Multicast group identifier (registered with the engine before a run).
pub type GroupId = usize;

/// Members of a multicast group.
///
/// NanoSort's groups are contiguous id ranges; at the paper scale
/// (65,536 cores, 4,369 groups) storing them as explicit lists costs
/// megabytes and a Vec allocation per group, so ranges are first-class.
#[derive(Debug, Clone)]
pub enum Group {
    /// Contiguous node ids `start..end` (O(1) storage).
    Range { start: NodeId, end: NodeId },
    /// Explicit member list (for irregular groups).
    List(Vec<NodeId>),
}

impl Group {
    pub fn len(&self) -> usize {
        match self {
            Group::Range { start, end } => end.saturating_sub(*start),
            Group::List(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn iter(&self) -> GroupIter<'_> {
        match self {
            Group::Range { start, end } => GroupIter::Range(*start..*end),
            Group::List(v) => GroupIter::List(v.iter()),
        }
    }
}

impl From<Vec<NodeId>> for Group {
    fn from(v: Vec<NodeId>) -> Group {
        Group::List(v)
    }
}

impl From<std::ops::Range<NodeId>> for Group {
    fn from(r: std::ops::Range<NodeId>) -> Group {
        Group::Range { start: r.start, end: r.end }
    }
}

/// Iterator over a [`Group`]'s members (no allocation either way).
pub enum GroupIter<'a> {
    Range(std::ops::Range<NodeId>),
    List(std::slice::Iter<'a, NodeId>),
}

impl Iterator for GroupIter<'_> {
    type Item = NodeId;
    fn next(&mut self) -> Option<NodeId> {
        match self {
            GroupIter::Range(r) => r.next(),
            GroupIter::List(it) => it.next().copied(),
        }
    }
}

/// Wire-level view of an algorithm message.
///
/// Messages are `Send` so node programs can run on any executor backend
/// ([`crate::sim::exec`]), and `Clone` because multicast delivers one
/// logical message to many members. §Perf: the engine clones a message
/// once per multicast member, so bulky payloads (pivot vectors, splitter
/// lists) should be pooled behind `Arc` — the clone is then a pointer
/// bump instead of a per-member buffer allocation, which is what keeps
/// the 65,536-member level-0 broadcasts off the allocator in the
/// executor hot path.
pub trait WireMsg: Clone + Send {
    /// Payload bytes on the wire (headers are added by the fabric).
    fn wire_bytes(&self) -> u64;
    /// Algorithm step this message belongs to (reorder-buffer key).
    /// Messages are delivered to the program only when its current step
    /// is >= this value.
    fn step(&self) -> u32 {
        0
    }
}

/// Words of payload a [`SmallWords`] stores inline (24 bytes — the
/// Kick/Probe/Done/counts class that dominates message counts stays at
/// or under this at the paper fanout).
pub const INLINE_WORDS: usize = 3;

/// A small-message payload: up to [`INLINE_WORDS`] `u64`s stored inline
/// in the message itself, spilling to a heap `Vec` only beyond that.
///
/// §Perf: the nanoPU's premise is that per-message overhead bounds
/// granularity, and most NanoSort control messages carry ≤ 3 words
/// (a cumulative count, a pivot pair, a round tag). Storing them inline
/// means a unicast small message is `memcpy`'d through the event queue
/// and inboxes without ever touching the allocator — the heap variant
/// survives only for genuinely bulky payloads (full splitter lists at
/// high fanout). The enum is 32 bytes either way, so the inline arm
/// costs nothing in event-queue footprint.
///
/// Digest-invisible by construction: [`SmallWords::as_slice`] yields the
/// same words for both representations, and wire-byte accounting is
/// `8 * len` regardless of where the words live (DESIGN.md §7, §12).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SmallWords {
    /// Up to [`INLINE_WORDS`] words stored in the message body.
    Inline { len: u8, words: [u64; INLINE_WORDS] },
    /// Heap spill for payloads beyond the inline threshold.
    Heap(Vec<u64>),
}

/// Test hook: force every [`SmallWords`] onto the heap arm so digest
/// tests can byte-compare inline vs boxed runs (see `tests/exec.rs`).
static FORCE_BOXED: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// Globally disable the inline arm (test-only; affects subsequently
/// constructed payloads). The two representations must produce identical
/// digests — this hook lets a test pin that.
pub fn force_boxed_small_words(on: bool) {
    FORCE_BOXED.store(on, std::sync::atomic::Ordering::SeqCst);
}

fn force_boxed() -> bool {
    FORCE_BOXED.load(std::sync::atomic::Ordering::Relaxed)
}

impl SmallWords {
    /// Build from a slice, inlining when it fits.
    pub fn from_slice(words: &[u64]) -> SmallWords {
        if words.len() <= INLINE_WORDS && !force_boxed() {
            let mut buf = [0u64; INLINE_WORDS];
            buf[..words.len()].copy_from_slice(words);
            SmallWords::Inline { len: words.len() as u8, words: buf }
        } else {
            SmallWords::Heap(words.to_vec())
        }
    }

    /// The payload as a word slice, representation-independent.
    pub fn as_slice(&self) -> &[u64] {
        match self {
            SmallWords::Inline { len, words } => &words[..*len as usize],
            SmallWords::Heap(v) => v,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            SmallWords::Inline { len, .. } => *len as usize,
            SmallWords::Heap(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for SmallWords {
    /// Empty inline payload (no allocation).
    fn default() -> SmallWords {
        SmallWords::Inline { len: 0, words: [0; INLINE_WORDS] }
    }
}

impl From<Vec<u64>> for SmallWords {
    /// Moves the Vec when it exceeds the inline threshold (no copy), and
    /// inlines + drops it otherwise.
    fn from(v: Vec<u64>) -> SmallWords {
        if v.len() <= INLINE_WORDS && !force_boxed() {
            SmallWords::from_slice(&v)
        } else {
            SmallWords::Heap(v)
        }
    }
}

impl std::ops::Deref for SmallWords {
    type Target = [u64];
    fn deref(&self) -> &[u64] {
        self.as_slice()
    }
}

/// A node program (one per simulated core).
pub trait Program {
    type Msg: WireMsg;

    /// Invoked once at simulation start.
    fn on_start(&mut self, ctx: &mut Ctx<Self::Msg>);

    /// Invoked per delivered message (after reorder-buffer gating).
    fn on_message(&mut self, ctx: &mut Ctx<Self::Msg>, src: NodeId, msg: Self::Msg);

    /// The step the program is currently willing to accept (see
    /// [`WireMsg::step`]).
    fn step(&self) -> u32 {
        0
    }

    /// Whether the optimistic executor may run this program inside
    /// speculative bursts. A program must opt out when `Clone` cannot
    /// capture all of its event-visible state — e.g. state behind shared
    /// `Arc`s mutated destructively per message — because rollback
    /// restores a node from its clone (DESIGN.md §10). Opted-out programs
    /// still run under `--exec opt`, just conservatively (adaptive
    /// windows, zero speculation).
    fn speculation_safe(&self) -> bool {
        true
    }
}

/// One queued outbound operation recorded by a handler.
pub(crate) enum SendOp<M> {
    Unicast { dst: NodeId, msg: M },
    Multicast { group: GroupId, msg: M },
    /// Local timer: re-deliver `msg` to the issuing node after `delay`.
    /// Never touches the fabric (no egress, no RNG draw, no net stats) —
    /// it models a core-local timer interrupt, e.g. a coordinator's
    /// arrival clock.
    Timer { delay: Time, msg: M },
}

/// Handler-side API: accumulates compute cycles and outbound messages;
/// the engine turns them into timed events when the handler returns.
///
/// Timing semantics: within one handler invocation, compute and sends are
/// sequential in call order — a `send` departs after all cycles charged
/// *before* it (plus its own TX cost), exactly like straight-line code on
/// the real core.
pub struct Ctx<'a, M> {
    pub(crate) node: NodeId,
    pub(crate) core: &'a CoreModel,
    pub(crate) rng: &'a mut SplitMix64,
    /// Local time at handler entry (after queueing + RX charge).
    pub(crate) entry: Time,
    /// Cycles accumulated so far in this handler.
    pub(crate) cycles: u64,
    pub(crate) ops: Vec<(u64, SendOp<M>)>, // (cycles-offset at send, op)
    pub(crate) stage: &'a mut u8,
    pub(crate) finished: &'a mut bool,
    pub(crate) mcast_supported: bool,
}

impl<'a, M: WireMsg> Ctx<'a, M> {
    /// This node's id.
    pub fn node(&self) -> NodeId {
        self.node
    }

    /// Local time at handler entry.
    pub fn now(&self) -> Time {
        self.entry
    }

    /// The core cost model (for algorithms to price their own compute).
    pub fn core(&self) -> &CoreModel {
        self.core
    }

    /// Deterministic per-node RNG stream.
    pub fn rng(&mut self) -> &mut SplitMix64 {
        self.rng
    }

    /// Charge `cycles` of local compute.
    pub fn compute(&mut self, cycles: u64) {
        self.cycles += cycles;
    }

    /// Fire-and-forget unicast. TX cost is charged here; delivery time is
    /// decided by the fabric.
    pub fn send(&mut self, dst: NodeId, msg: M) {
        self.cycles += self.core.tx_cycles(msg.wire_bytes());
        self.ops.push((self.cycles, SendOp::Unicast { dst, msg }));
    }

    /// Schedule `msg` for re-delivery to *this* node after `delay` of
    /// local time (measured from the issue point, i.e. after all cycles
    /// charged so far in this handler). Timers bypass the fabric entirely:
    /// no egress serialization, no propagation, no loss/tail draws, no
    /// traffic counters — only the delivery-side RX charge applies when
    /// the timer fires. Delivery order still follows the canonical
    /// `(at, src, ctr)` event key, sharing the source's flight counter.
    pub fn timer(&mut self, delay: Time, msg: M) {
        self.ops.push((self.cycles, SendOp::Timer { delay, msg }));
    }

    /// True if the fabric supports switch-replicated multicast (§5.3).
    pub fn multicast_supported(&self) -> bool {
        self.mcast_supported
    }

    /// Multicast to a registered group. Panics if unsupported — use
    /// [`Ctx::broadcast`] to degrade gracefully.
    pub fn multicast(&mut self, group: GroupId, msg: M) {
        assert!(self.mcast_supported, "multicast not supported by fabric");
        self.cycles += self.core.tx_cycles(msg.wire_bytes());
        self.ops.push((self.cycles, SendOp::Multicast { group, msg }));
    }

    /// Send to every node in `members` (excluding self): one multicast if
    /// the fabric supports it, otherwise a unicast loop — the exact
    /// degradation measured by the paper's §6.2.3 multicast experiment.
    pub fn broadcast(&mut self, group: GroupId, members: &[NodeId], msg: M) {
        self.broadcast_to(group, members.iter().copied(), msg);
    }

    /// [`Ctx::broadcast`] over any member iterator (e.g. a contiguous id
    /// range), so callers with range-shaped groups never materialize a
    /// member list just to describe the degraded-unicast fallback.
    pub fn broadcast_to(
        &mut self,
        group: GroupId,
        members: impl IntoIterator<Item = NodeId>,
        msg: M,
    ) {
        if self.mcast_supported {
            self.multicast(group, msg);
        } else {
            for dst in members {
                if dst != self.node {
                    self.send(dst, msg.clone());
                }
            }
        }
    }

    /// Tag subsequent busy/idle time with an execution stage (Fig 16).
    pub fn set_stage(&mut self, stage: u8) {
        *self.stage = stage;
    }

    /// Mark this node's work complete (stats only; the run ends at global
    /// quiescence).
    pub fn finish(&mut self) {
        *self.finished = true;
    }
}
