//! Unit tests for the nanoPU handler API (Ctx semantics).

use super::*;
use crate::sim::Time;

#[derive(Clone)]
struct M(u64);
impl WireMsg for M {
    fn wire_bytes(&self) -> u64 {
        self.0
    }
}

fn make_ctx<'a>(
    core: &'a CoreModel,
    rng: &'a mut SplitMix64,
    stage: &'a mut u8,
    finished: &'a mut bool,
    mcast: bool,
) -> Ctx<'a, M> {
    Ctx {
        node: 3,
        core,
        rng,
        entry: Time::from_ns(100),
        cycles: 0,
        ops: Vec::new(),
        stage,
        finished,
        mcast_supported: mcast,
    }
}

#[test]
fn send_charges_tx_and_orders_ops() {
    let core = CoreModel::default();
    let mut rng = SplitMix64::new(1);
    let (mut stage, mut fin) = (0u8, false);
    let mut ctx = make_ctx(&core, &mut rng, &mut stage, &mut fin, true);
    ctx.compute(100);
    ctx.send(1, M(16));
    let after_first = ctx.cycles;
    assert_eq!(after_first, 100 + core.tx_cycles(16));
    ctx.send(2, M(16));
    assert_eq!(ctx.cycles, after_first + core.tx_cycles(16));
    // Ops carry their issue offsets in order.
    assert_eq!(ctx.ops.len(), 2);
    assert!(ctx.ops[0].0 < ctx.ops[1].0);
}

#[test]
fn broadcast_degrades_to_unicast_without_mcast() {
    let core = CoreModel::default();
    let mut rng = SplitMix64::new(1);
    let (mut stage, mut fin) = (0u8, false);
    let mut ctx = make_ctx(&core, &mut rng, &mut stage, &mut fin, false);
    assert!(!ctx.multicast_supported());
    ctx.broadcast(0, &[1, 2, 3, 4], M(8));
    // Excludes self (node 3): 3 unicasts.
    assert_eq!(ctx.ops.len(), 3);
    let tx3 = 3 * core.tx_cycles(8);
    assert_eq!(ctx.cycles, tx3);
}

#[test]
fn broadcast_uses_single_multicast_when_supported() {
    let core = CoreModel::default();
    let mut rng = SplitMix64::new(1);
    let (mut stage, mut fin) = (0u8, false);
    let mut ctx = make_ctx(&core, &mut rng, &mut stage, &mut fin, true);
    ctx.broadcast(0, &[1, 2, 3, 4], M(8));
    assert_eq!(ctx.ops.len(), 1);
    assert_eq!(ctx.cycles, core.tx_cycles(8));
}

#[test]
#[should_panic(expected = "multicast not supported")]
fn multicast_panics_without_fabric_support() {
    let core = CoreModel::default();
    let mut rng = SplitMix64::new(1);
    let (mut stage, mut fin) = (0u8, false);
    let mut ctx = make_ctx(&core, &mut rng, &mut stage, &mut fin, false);
    ctx.multicast(0, M(8));
}

#[test]
fn stage_and_finish_propagate() {
    let core = CoreModel::default();
    let mut rng = SplitMix64::new(1);
    let (mut stage, mut fin) = (0u8, false);
    {
        let mut ctx = make_ctx(&core, &mut rng, &mut stage, &mut fin, true);
        ctx.set_stage(5);
        ctx.finish();
        assert_eq!(ctx.now(), Time::from_ns(100));
        assert_eq!(ctx.node(), 3);
    }
    assert_eq!(stage, 5);
    assert!(fin);
}

#[test]
fn default_wire_msg_step_is_zero() {
    assert_eq!(M(8).step(), 0);
}

#[test]
fn group_range_and_list_agree() {
    let range = Group::from(3..8);
    let list = Group::from(vec![3usize, 4, 5, 6, 7]);
    assert_eq!(range.len(), 5);
    assert_eq!(list.len(), 5);
    assert!(!range.is_empty());
    assert!(Group::from(4..4).is_empty());
    let from_range: Vec<NodeId> = range.iter().collect();
    let from_list: Vec<NodeId> = list.iter().collect();
    assert_eq!(from_range, from_list);
}

#[test]
fn broadcast_to_range_degrades_to_unicast_without_mcast() {
    let core = CoreModel::default();
    let mut rng = SplitMix64::new(1);
    let (mut stage, mut fin) = (0u8, false);
    let mut ctx = make_ctx(&core, &mut rng, &mut stage, &mut fin, false);
    // Range 0..6 includes self (node 3): 5 unicasts.
    ctx.broadcast_to(0, 0..6, M(8));
    assert_eq!(ctx.ops.len(), 5);
    assert_eq!(ctx.cycles, 5 * core.tx_cycles(8));
}

#[test]
fn broadcast_to_uses_single_multicast_when_supported() {
    let core = CoreModel::default();
    let mut rng = SplitMix64::new(1);
    let (mut stage, mut fin) = (0u8, false);
    let mut ctx = make_ctx(&core, &mut rng, &mut stage, &mut fin, true);
    ctx.broadcast_to(0, 0..6, M(8));
    assert_eq!(ctx.ops.len(), 1);
    assert_eq!(ctx.cycles, core.tx_cycles(8));
}
