//! Unit tests for the nanoPU handler API (Ctx semantics).

use super::*;
use crate::sim::Time;

#[derive(Clone)]
struct M(u64);
impl WireMsg for M {
    fn wire_bytes(&self) -> u64 {
        self.0
    }
}

fn make_ctx<'a>(
    core: &'a CoreModel,
    rng: &'a mut SplitMix64,
    stage: &'a mut u8,
    finished: &'a mut bool,
    mcast: bool,
) -> Ctx<'a, M> {
    Ctx {
        node: 3,
        core,
        rng,
        entry: Time::from_ns(100),
        cycles: 0,
        ops: Vec::new(),
        stage,
        finished,
        mcast_supported: mcast,
    }
}

#[test]
fn send_charges_tx_and_orders_ops() {
    let core = CoreModel::default();
    let mut rng = SplitMix64::new(1);
    let (mut stage, mut fin) = (0u8, false);
    let mut ctx = make_ctx(&core, &mut rng, &mut stage, &mut fin, true);
    ctx.compute(100);
    ctx.send(1, M(16));
    let after_first = ctx.cycles;
    assert_eq!(after_first, 100 + core.tx_cycles(16));
    ctx.send(2, M(16));
    assert_eq!(ctx.cycles, after_first + core.tx_cycles(16));
    // Ops carry their issue offsets in order.
    assert_eq!(ctx.ops.len(), 2);
    assert!(ctx.ops[0].0 < ctx.ops[1].0);
}

#[test]
fn broadcast_degrades_to_unicast_without_mcast() {
    let core = CoreModel::default();
    let mut rng = SplitMix64::new(1);
    let (mut stage, mut fin) = (0u8, false);
    let mut ctx = make_ctx(&core, &mut rng, &mut stage, &mut fin, false);
    assert!(!ctx.multicast_supported());
    ctx.broadcast(0, &[1, 2, 3, 4], M(8));
    // Excludes self (node 3): 3 unicasts.
    assert_eq!(ctx.ops.len(), 3);
    let tx3 = 3 * core.tx_cycles(8);
    assert_eq!(ctx.cycles, tx3);
}

#[test]
fn broadcast_uses_single_multicast_when_supported() {
    let core = CoreModel::default();
    let mut rng = SplitMix64::new(1);
    let (mut stage, mut fin) = (0u8, false);
    let mut ctx = make_ctx(&core, &mut rng, &mut stage, &mut fin, true);
    ctx.broadcast(0, &[1, 2, 3, 4], M(8));
    assert_eq!(ctx.ops.len(), 1);
    assert_eq!(ctx.cycles, core.tx_cycles(8));
}

#[test]
#[should_panic(expected = "multicast not supported")]
fn multicast_panics_without_fabric_support() {
    let core = CoreModel::default();
    let mut rng = SplitMix64::new(1);
    let (mut stage, mut fin) = (0u8, false);
    let mut ctx = make_ctx(&core, &mut rng, &mut stage, &mut fin, false);
    ctx.multicast(0, M(8));
}

#[test]
fn stage_and_finish_propagate() {
    let core = CoreModel::default();
    let mut rng = SplitMix64::new(1);
    let (mut stage, mut fin) = (0u8, false);
    {
        let mut ctx = make_ctx(&core, &mut rng, &mut stage, &mut fin, true);
        ctx.set_stage(5);
        ctx.finish();
        assert_eq!(ctx.now(), Time::from_ns(100));
        assert_eq!(ctx.node(), 3);
    }
    assert_eq!(stage, 5);
    assert!(fin);
}

#[test]
fn default_wire_msg_step_is_zero() {
    assert_eq!(M(8).step(), 0);
}

#[test]
fn group_range_and_list_agree() {
    let range = Group::from(3..8);
    let list = Group::from(vec![3usize, 4, 5, 6, 7]);
    assert_eq!(range.len(), 5);
    assert_eq!(list.len(), 5);
    assert!(!range.is_empty());
    assert!(Group::from(4..4).is_empty());
    let from_range: Vec<NodeId> = range.iter().collect();
    let from_list: Vec<NodeId> = list.iter().collect();
    assert_eq!(from_range, from_list);
}

#[test]
fn broadcast_to_range_degrades_to_unicast_without_mcast() {
    let core = CoreModel::default();
    let mut rng = SplitMix64::new(1);
    let (mut stage, mut fin) = (0u8, false);
    let mut ctx = make_ctx(&core, &mut rng, &mut stage, &mut fin, false);
    // Range 0..6 includes self (node 3): 5 unicasts.
    ctx.broadcast_to(0, 0..6, M(8));
    assert_eq!(ctx.ops.len(), 5);
    assert_eq!(ctx.cycles, 5 * core.tx_cycles(8));
}

#[test]
fn broadcast_to_uses_single_multicast_when_supported() {
    let core = CoreModel::default();
    let mut rng = SplitMix64::new(1);
    let (mut stage, mut fin) = (0u8, false);
    let mut ctx = make_ctx(&core, &mut rng, &mut stage, &mut fin, true);
    ctx.broadcast_to(0, 0..6, M(8));
    assert_eq!(ctx.ops.len(), 1);
    assert_eq!(ctx.cycles, core.tx_cycles(8));
}

#[test]
fn small_words_inlines_at_or_below_threshold() {
    for n in 0..=INLINE_WORDS {
        let words: Vec<u64> = (0..n as u64).map(|i| i * 7 + 1).collect();
        let s = SmallWords::from_slice(&words);
        assert!(matches!(s, SmallWords::Inline { .. }), "{n} words should inline");
        assert_eq!(s.as_slice(), &words[..]);
        assert_eq!(s.len(), n);
        assert_eq!(s.is_empty(), n == 0);
    }
    let big: Vec<u64> = (0..INLINE_WORDS as u64 + 1).collect();
    let s = SmallWords::from_slice(&big);
    assert!(matches!(s, SmallWords::Heap(_)));
    assert_eq!(s.as_slice(), &big[..]);
}

#[test]
fn small_words_from_vec_matches_from_slice() {
    for n in [0usize, 1, INLINE_WORDS, INLINE_WORDS + 1, 16] {
        let words: Vec<u64> = (0..n as u64).map(|i| i.wrapping_mul(0x9E37)).collect();
        let a = SmallWords::from_slice(&words);
        let b = SmallWords::from(words.clone());
        assert_eq!(a, b);
        assert_eq!(&b[..], &words[..]); // Deref surface
    }
    assert_eq!(SmallWords::default().as_slice(), &[] as &[u64]);
    assert!(matches!(SmallWords::default(), SmallWords::Inline { len: 0, .. }));
}

#[test]
fn small_words_representations_are_interchangeable() {
    // The digest contract (DESIGN.md §12): inline and heap forms of the
    // same words are observationally identical through the slice view.
    let words = [3u64, 1, 2];
    let inline = SmallWords::from_slice(&words);
    let heap = SmallWords::Heap(words.to_vec());
    assert!(matches!(inline, SmallWords::Inline { .. }));
    assert_eq!(inline.as_slice(), heap.as_slice());
    assert_eq!(inline.len(), heap.len());
}
