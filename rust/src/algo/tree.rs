//! k-ary aggregation-tree arithmetic shared by MergeMin's merge tree,
//! NanoSort's median- and count-trees, and MilliSort's pivot gather.
//!
//! Positions `0..size` aggregate bottom-up in rounds: at round `t`
//! (1-based), positions divisible by `incast^t` receive from the positions
//! `pos + j·incast^(t-1)` (j = 1..incast-1... incast) that still exist.
//! The tree root is position 0; the number of rounds is
//! `ceil(log_incast(size))` — the paper's width/depth trade-off dial
//! (§3.1, Fig 3/4).

/// An `incast`-way aggregation tree over `size` positions.
#[derive(Debug, Clone, Copy)]
pub struct AggTree {
    pub size: usize,
    pub incast: usize,
}

impl AggTree {
    pub fn new(size: usize, incast: usize) -> Self {
        assert!(size >= 1, "empty tree");
        assert!(incast >= 2, "incast must be >= 2 (chains are special-cased)");
        AggTree { size, incast }
    }

    /// Number of aggregation rounds: smallest R with incast^R >= size.
    pub fn rounds(&self) -> u32 {
        let mut r = 0;
        let mut span: u128 = 1;
        while span < self.size as u128 {
            span *= self.incast as u128;
            r += 1;
        }
        r
    }

    fn pow(&self, t: u32) -> u128 {
        (self.incast as u128).pow(t)
    }

    /// Does `pos` aggregate (receive) at round `t`?
    pub fn aggregates_at(&self, pos: usize, t: u32) -> bool {
        t >= 1 && t <= self.rounds() && (pos as u128) % self.pow(t) == 0
    }

    /// The round at which `pos` sends to its parent and stops (0 = root
    /// never sends).
    pub fn exit_round(&self, pos: usize) -> u32 {
        if pos == 0 {
            return 0;
        }
        let mut t = 1;
        while (pos as u128) % self.pow(t) == 0 {
            t += 1;
        }
        t
    }

    /// Parent of `pos` at its exit round.
    pub fn parent(&self, pos: usize) -> usize {
        let t = self.exit_round(pos);
        assert!(t > 0, "root has no parent");
        (pos as u128 - (pos as u128) % self.pow(t)) as usize
    }

    /// Children that send to aggregator `pos` at round `t`.
    pub fn children(&self, pos: usize, t: u32) -> Vec<usize> {
        debug_assert!(self.aggregates_at(pos, t));
        let step = self.pow(t - 1);
        (1..self.incast as u128)
            .map(|j| pos as u128 + j * step)
            .filter(|&c| c < self.size as u128)
            .map(|c| c as usize)
            .collect()
    }

    /// Number of messages aggregator `pos` expects at round `t`.
    pub fn expected(&self, pos: usize, t: u32) -> usize {
        self.children(pos, t).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounds_match_paper_examples() {
        // Fig 4: 64 cores, incast 8 => two levels; incast 64 => one level.
        assert_eq!(AggTree::new(64, 8).rounds(), 2);
        assert_eq!(AggTree::new(64, 64).rounds(), 1);
        assert_eq!(AggTree::new(64, 2).rounds(), 6);
        assert_eq!(AggTree::new(1, 8).rounds(), 0);
        assert_eq!(AggTree::new(65, 8).rounds(), 3); // ragged
    }

    #[test]
    fn exit_rounds_and_parents() {
        let t = AggTree::new(64, 8);
        assert_eq!(t.exit_round(0), 0);
        assert_eq!(t.exit_round(1), 1);
        assert_eq!(t.exit_round(7), 1);
        assert_eq!(t.exit_round(8), 2);
        assert_eq!(t.exit_round(56), 2);
        assert_eq!(t.parent(3), 0);
        assert_eq!(t.parent(11), 8);
        assert_eq!(t.parent(8), 0);
    }

    #[test]
    fn children_inverse_of_parent() {
        for &(size, incast) in &[(64usize, 8usize), (100, 4), (16, 16), (27, 3)] {
            let tree = AggTree::new(size, incast);
            for t in 1..=tree.rounds() {
                for pos in 0..size {
                    if tree.aggregates_at(pos, t) {
                        for c in tree.children(pos, t) {
                            assert_eq!(tree.exit_round(c), t, "size={size} f={incast} c={c}");
                            assert_eq!(tree.parent(c), pos);
                        }
                    }
                }
            }
        }
    }

    /// Property: every non-root position sends exactly once, and all
    /// values funnel to the root (count conservation).
    #[test]
    fn every_position_reaches_root() {
        for &(size, incast) in &[(64usize, 8usize), (37, 4), (256, 16), (9, 3), (5, 2)] {
            let tree = AggTree::new(size, incast);
            // Simulate the aggregation: value count per position.
            let mut counts = vec![1u64; size];
            for t in 1..=tree.rounds() {
                for pos in 0..size {
                    if tree.aggregates_at(pos, t) {
                        for c in tree.children(pos, t) {
                            counts[pos] += counts[c];
                            counts[c] = 0;
                        }
                    }
                }
            }
            assert_eq!(counts[0], size as u64, "size={size} incast={incast}");
            assert!(counts[1..].iter().all(|&c| c == 0));
        }
    }

    #[test]
    fn expected_counts() {
        let t = AggTree::new(64, 8);
        assert_eq!(t.expected(0, 1), 7);
        assert_eq!(t.expected(0, 2), 7);
        let ragged = AggTree::new(10, 8);
        assert_eq!(ragged.expected(0, 1), 7);
        assert_eq!(ragged.expected(8, 1), 1); // only pos 9 exists
        assert_eq!(ragged.expected(0, 2), 1); // pos 8
    }
}
