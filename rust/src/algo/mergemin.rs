//! MergeMin (paper §3.1, Figs 2/3/4): find the global minimum of values
//! spread across cores via a k-ary merge tree — the design-space probe for
//! the incast (tree width) vs depth trade-off.
//!
//! Each core scans its local values (cold, like Fig 2), then minima flow up
//! an [`AggTree`] with the configured incast; `incast == 1` degenerates to
//! the paper's "straight line" chain (Fig 3 left).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::compute::LocalCompute;
use crate::cpu::{CoreModel, Temp};
use crate::nanopu::{Ctx, NodeId, Program, WireMsg};
use crate::scenario::{
    Built, Finish, MetricValue, RunReport, ScenarioEnv, Validation, Workload,
};
use crate::sim::{SplitMix64, Time};

use super::tree::AggTree;

/// Tree-round message carrying a partial minimum.
#[derive(Debug, Clone)]
pub struct MinMsg {
    pub round: u32,
    pub value: u64,
}

impl WireMsg for MinMsg {
    fn wire_bytes(&self) -> u64 {
        16 // value + round tag (the paper's 16 B messages, Fig 6)
    }
    fn step(&self) -> u32 {
        self.round
    }
}

/// Per-core MergeMin program.
#[derive(Clone)]
pub struct MergeMinNode {
    id: NodeId,
    cfg_incast: usize,
    cores: usize,
    values: Vec<u64>,
    compute: Arc<dyn LocalCompute>,
    current_min: u64,
    round: u32,
    got: usize,
    /// Root's final answer (for validation). Atomic: only the root ever
    /// stores it, but programs run on executor worker threads.
    pub result: Arc<AtomicU64>,
}

impl MergeMinNode {
    fn tree(&self) -> AggTree {
        AggTree::new(self.cores, self.cfg_incast.max(2))
    }

    fn is_chain(&self) -> bool {
        self.cfg_incast <= 1
    }

    /// Advance through aggregation rounds where this node expects no
    /// children (ragged trees), sending/terminating as appropriate.
    fn advance(&mut self, ctx: &mut Ctx<MinMsg>) {
        if self.is_chain() {
            return; // chain logic lives in on_start/on_message directly
        }
        let tree = self.tree();
        let rounds = tree.rounds();
        loop {
            let next = self.round + 1;
            if next > rounds {
                if self.id == 0 {
                    self.result.store(self.current_min, Ordering::Relaxed);
                    ctx.finish();
                }
                return;
            }
            if tree.aggregates_at(self.id, next) {
                let expect = tree.expected(self.id, next);
                if self.got < expect {
                    return; // wait for children of round `next`
                }
                // All children already merged; move on.
                self.got = 0;
                self.round = next;
            } else {
                // Exit: send the partial min to the parent and stop.
                ctx.send(
                    tree.parent(self.id),
                    MinMsg { round: next, value: self.current_min },
                );
                self.round = rounds + 1; // accept nothing further
                ctx.finish();
                return;
            }
        }
    }
}

impl Program for MergeMinNode {
    type Msg = MinMsg;

    fn on_start(&mut self, ctx: &mut Ctx<MinMsg>) {
        // Local scan (cold cache, like Fig 2's measurement). An empty
        // value list contributes the identity (`u64::MAX` — real values
        // are strictly below it), so load-perturbed cores with nothing to
        // scan degrade gracefully instead of panicking.
        let n = self.values.len() as u64;
        ctx.compute(ctx.core().scan_min_cycles(n, Temp::Cold));
        self.current_min = self.compute.min(&self.values).unwrap_or(u64::MAX);
        if self.is_chain() {
            // Straight line: the last core starts the relay.
            if self.id == self.cores - 1 {
                if self.cores == 1 {
                    self.result.store(self.current_min, Ordering::Relaxed);
                    ctx.finish();
                } else {
                    // Chain relays always use round tag 1: every node
                    // receives exactly one message, immediately.
                    ctx.send(self.id - 1, MinMsg { round: 1, value: self.current_min });
                    ctx.finish();
                }
            }
            return;
        }
        self.advance(ctx);
    }

    fn on_message(&mut self, ctx: &mut Ctx<MinMsg>, _src: NodeId, msg: MinMsg) {
        ctx.compute(ctx.core().merge_cycles(1));
        self.current_min =
            self.compute.min(&[self.current_min, msg.value]).expect("two values");
        if self.is_chain() {
            if self.id == 0 {
                self.result.store(self.current_min, Ordering::Relaxed);
                ctx.finish();
            } else {
                ctx.send(self.id - 1, MinMsg { round: 1, value: self.current_min });
                ctx.finish();
            }
            return;
        }
        self.got += 1;
        self.advance(ctx);
    }

    fn step(&self) -> u32 {
        // Accept messages for the next round we are waiting on.
        self.round + 1
    }
}

/// MergeMin as a [`Workload`]: the scenario supplies fleet size, network,
/// data plane, and seed; these are the workload-specific dials.
#[derive(Debug, Clone)]
pub struct MergeMin {
    pub values_per_core: usize,
    /// Merge-tree incast (1 = chain).
    pub incast: usize,
}

impl Default for MergeMin {
    fn default() -> Self {
        // Fig 4's setting: 128 values per core, incast 8.
        MergeMin { values_per_core: 128, incast: 8 }
    }
}

impl Workload for MergeMin {
    type Prog = MergeMinNode;

    fn name(&self) -> &'static str {
        "mergemin"
    }

    fn default_nodes(&self) -> usize {
        64
    }

    fn build(&self, env: &ScenarioEnv) -> Result<Built<MergeMinNode>> {
        let mut rng = SplitMix64::new(env.seed ^ 0x6d65_7267_656d_696e);
        // MergeMin's input is local load, so the scenario's input
        // distribution shapes *per-core value counts* (`Uniform` keeps
        // every core at `values_per_core`, byte-identical to the
        // pre-perturbation stream).
        let counts = env.perturb.dist.per_core_counts(self.values_per_core, env.nodes);
        let mut true_min = u64::MAX;
        let result = Arc::new(AtomicU64::new(u64::MAX));
        let programs: Vec<MergeMinNode> = (0..env.nodes)
            .map(|id| {
                let values: Vec<u64> = (0..counts[id])
                    .map(|_| rng.next_u64() % (u64::MAX - 1))
                    .collect();
                true_min = true_min.min(values.iter().copied().min().unwrap_or(u64::MAX));
                MergeMinNode {
                    id,
                    cfg_incast: self.incast,
                    cores: env.nodes,
                    values,
                    compute: env.compute.clone(),
                    current_min: u64::MAX,
                    round: 0,
                    got: 0,
                    result: result.clone(),
                }
            })
            .collect();
        let finish: Finish = Box::new(move |env, summary| {
            let found = result.load(Ordering::Relaxed);
            let validation = Validation::check(
                found == true_min,
                format!("found min {found} == true min {true_min}"),
            );
            RunReport::new("mergemin", env, summary, validation)
                .with_metric("found_min", MetricValue::U64(found))
                .with_metric("true_min", MetricValue::U64(true_min))
        });
        Ok(Built { programs, groups: Vec::new(), finish })
    }
}

/// Single-core scan time for Fig 2 (pure cost-model evaluation).
pub fn single_core_scan(values: usize) -> (Time, f64) {
    let core = CoreModel::default();
    let cycles = core.scan_min_cycles(values as u64, Temp::Cold);
    let miss_rate = core.cache.stream_miss_rate(values as u64 * 8, true);
    (Time::from_cycles(cycles), miss_rate)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{RunReport, Scenario};

    fn run(cores: usize, vpc: usize, incast: usize) -> RunReport {
        Scenario::new(MergeMin { values_per_core: vpc, incast })
            .nodes(cores)
            .run()
            .expect("mergemin scenario")
    }

    fn mins(r: &RunReport) -> (u64, u64) {
        (r.metric_u64("found_min").unwrap(), r.metric_u64("true_min").unwrap())
    }

    #[test]
    fn finds_min_across_incasts() {
        for incast in [1usize, 2, 4, 8, 16, 64] {
            let r = run(64, 16, incast);
            let (found, expect) = mins(&r);
            assert!(r.validation.ok(), "incast={incast}: {found} != {expect}");
        }
    }

    #[test]
    fn finds_min_on_ragged_sizes() {
        for cores in [1usize, 2, 3, 7, 65, 100] {
            let r = run(cores, 8, 8);
            assert!(r.validation.ok(), "cores={cores}");
        }
    }

    #[test]
    fn fig4_shape_sweet_spot_beats_extremes() {
        // Fig 4: incast 8 beats both incast 1 (chain) and incast 64
        // (single-level) at 64 cores / 128 values per core.
        let chain = run(64, 128, 1).summary.makespan;
        let sweet = run(64, 128, 8).summary.makespan;
        let flat = run(64, 128, 64).summary.makespan;
        assert!(sweet < chain, "sweet {sweet} !< chain {chain}");
        assert!(sweet < flat, "sweet {sweet} !< flat {flat}");
    }

    #[test]
    fn fig4_sweet_spot_magnitude() {
        // Paper: incast 8 finds the min in ~750 ns (64 cores, 128 v/core,
        // after the local scan which dominates at small value counts).
        // Our model includes the local cold scan (~{128 vals} = small);
        // total should land well under 5 µs and over 0.3 µs.
        let r = run(64, 128, 8);
        let us = r.summary.makespan.as_us_f64();
        assert!((0.3..5.0).contains(&us), "makespan = {us} µs");
    }

    #[test]
    fn deeper_trees_send_fewer_messages_per_level_but_more_total() {
        let chain = run(64, 16, 1);
        let flat = run(64, 16, 64);
        // Chain: 63 relay messages; flat: 63 direct messages — equal sends,
        // but the chain's critical path is much longer.
        assert_eq!(chain.summary.net.msgs_sent, 63);
        assert_eq!(flat.summary.net.msgs_sent, 63);
        assert!(chain.summary.makespan > flat.summary.makespan);
    }

    #[test]
    fn single_core_fig2_scaling() {
        let (t_small, _) = single_core_scan(64);
        let (t_big, miss_big) = single_core_scan(8192);
        assert!(t_big > t_small);
        assert!((16.0..20.0).contains(&t_big.as_us_f64()), "{}", t_big.as_us_f64());
        assert!(miss_big > 0.1); // streaming miss rate ~ 1/8
    }

    #[test]
    fn deterministic() {
        let a = run(64, 32, 8);
        let b = run(64, 32, 8);
        assert_eq!(a.summary.makespan, b.summary.makespan);
        assert_eq!(mins(&a).0, mins(&b).0);
    }
}
