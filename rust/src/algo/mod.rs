//! Distributed algorithms running on the simulated nanoPU cluster:
//!
//! - [`nanosort`] — the paper's contribution (recursive pivot/shuffle sort);
//! - [`millisort`] — the state-of-the-art baseline it compares against;
//! - [`mergemin`] — the §3.1 design-space probe (incast vs depth);
//! - [`setalgebra`] — distributed posting-list intersection (§3.2);
//! - [`tree`] — shared k-ary aggregation-tree arithmetic.
//!
//! Each algorithm implements [`crate::scenario::Workload`] and runs
//! through [`crate::scenario::Scenario`] — the single engine/fabric
//! wiring path (the deprecated `run_xxx(cfg, compute)` shims from the
//! pre-Scenario era have been removed). Node programs are `Send`, so
//! every workload runs unchanged on the sequential or the sharded
//! executor backend ([`crate::sim::exec`]).

pub mod mergemin;
pub mod millisort;
pub mod nanosort;
pub mod setalgebra;
pub mod tree;
