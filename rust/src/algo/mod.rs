//! Distributed algorithms running on the simulated nanoPU cluster:
//!
//! - [`nanosort`] — the paper's contribution (recursive pivot/shuffle sort);
//! - [`millisort`] — the state-of-the-art baseline it compares against;
//! - [`mergemin`] — the §3.1 design-space probe (incast vs depth);
//! - [`tree`] — shared k-ary aggregation-tree arithmetic.

pub mod mergemin;
pub mod millisort;
pub mod nanosort;
pub mod setalgebra;
pub mod tree;
