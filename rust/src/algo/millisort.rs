//! MilliSort baseline (paper §4, §6.2.2; Li et al., NSDI'21), re-hosted on
//! the nanoPU substrate exactly as the paper's Figs 9/10 do.
//!
//! Two phases:
//!
//! 1. **Partitioning** — MilliSort picks `cores-1` splitters (one final
//!    bucket per core) by *iterative probing*: the root scatters candidate
//!    splitters down a tree of branching `reduction_factor` (the paper's
//!    "incast" knob, Fig 10); every core answers with its local cumulative
//!    counts at the candidates; internal "pivot sorters" element-wise sum
//!    the count vectors on the way up; the root bisects each splitter's
//!    interval toward its target rank and repeats. Both the candidate and
//!    the count messages carry `cores-1` words — message size and per-hop
//!    processing grow linearly with the core count, which is exactly why
//!    MilliSort's partition time blows up with scale (Fig 9: "the more
//!    cores, the more bucket boundaries to pick").
//! 2. **Shuffle** — every node routes each key to its bucket's owner core
//!    (deterministic owner = bucket index, unlike NanoSort's randomized
//!    partition), with count-tree termination detection (same scheme as
//!    NanoSort), then sorts the received keys.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::algo::tree::AggTree;
use crate::compute::LocalCompute;
use crate::cpu::Temp;
use crate::graysort::validate_sorted_output;
use crate::nanopu::{Ctx, NodeId, Program, SmallWords, WireMsg};
use crate::scenario::{
    Built, Finish, NodeSlots, RunReport, ScenarioEnv, Validation, Workload,
};

/// Cycles per splitter for a local rank lookup (binary search on the
/// sorted local keys).
const RANK_LOOKUP_CYCLES: u64 = 8;
/// Cycles per element to sum one child's count vector.
const COUNT_SUM_CYCLES: u64 = 2;
/// Cycles per splitter for the root's bisection update.
const BISECT_CYCLES: u64 = 4;
/// Cycles to fold a termination-count message.
const COUNT_FOLD_CYCLES: u64 = 6;
/// Cycles to append a received key.
const KEY_APPEND_CYCLES: u64 = 4;

/// Protocol steps (reorder-buffer tags).
const STEP_PARTITION: u32 = 0;
const STEP_SHUFFLE: u32 = 1;
const STEP_DONE: u32 = 2;

#[derive(Debug, Clone)]
pub enum MsMsg {
    /// Candidate splitters scattered down the tree (cores-1 words),
    /// `Arc`-pooled so each scatter hop clones a pointer, not the list
    /// (§Perf, [`WireMsg`] payload-pooling note).
    Probe { round: u16, candidates: Arc<Vec<u64>> },
    /// Local/aggregated cumulative counts at the candidates (cores-1
    /// words). [`SmallWords`]: at small core counts the vector rides
    /// inline through the event queue; bigger fleets spill to the heap
    /// arm with identical observable behavior (DESIGN.md §12).
    Counts { round: u16, cum: SmallWords },
    /// Final boundary list scattered down the tree (`Arc`-pooled).
    Boundaries { boundaries: Arc<Vec<u64>> },
    /// One shuffled key.
    Key { key: u64, origin: u32 },
    /// Count-tree termination detection (same scheme as NanoSort).
    CountUp { round: u8, epoch: u16, sent: u64, received: u64 },
    Done { epoch: u16, complete: bool },
}

impl WireMsg for MsMsg {
    fn wire_bytes(&self) -> u64 {
        match self {
            MsMsg::Probe { candidates, .. } => 8 + 8 * candidates.len() as u64,
            MsMsg::Counts { cum, .. } => 8 + 8 * cum.len() as u64,
            MsMsg::Boundaries { boundaries } => 8 + 8 * boundaries.len() as u64,
            MsMsg::Key { .. } => 16,
            MsMsg::CountUp { .. } => 24,
            MsMsg::Done { .. } => 8,
        }
    }

    fn step(&self) -> u32 {
        match self {
            MsMsg::Probe { .. } | MsMsg::Counts { .. } | MsMsg::Boundaries { .. } => {
                STEP_PARTITION
            }
            MsMsg::Key { .. } | MsMsg::CountUp { .. } | MsMsg::Done { .. } => STEP_SHUFFLE,
        }
    }
}

struct MsShared {
    cores: usize,
    reduction_factor: usize,
    probe_rounds: u32,
    /// Per-node output sink: contention-free slots (each node writes
    /// only its own), overwrite-safe under optimistic rollback
    /// re-execution.
    outputs: NodeSlots<Vec<u64>>,
}

#[derive(Clone)]
pub struct MilliSortNode {
    id: NodeId,
    shared: Arc<MsShared>,
    compute: Arc<dyn LocalCompute>,

    step: u32,
    keys: Vec<u64>,
    received_keys: Vec<u64>,

    // Probe state (root keeps the bisection intervals; aggregators keep
    // per-round partial sums).
    lo: Vec<u64>,
    hi: Vec<u64>,
    probe_pending: HashMap<u16, (Vec<u64>, usize)>,
    probe_sent_own: HashMap<u16, bool>,

    // Termination count-tree state.
    sent: u64,
    received: u64,
    ct_epoch: u16,
    ct_round: u32,
    ct_sum: (u64, u64),
    ct_pending: HashMap<(u16, u32), (u64, u64, usize)>,
}

impl MilliSortNode {
    fn tree(&self) -> AggTree {
        AggTree::new(self.shared.cores, self.shared.reduction_factor.max(2))
    }

    /// Local cumulative counts: for each candidate c_j, how many of my
    /// keys are < c_j. Keys are sorted, so each is a binary search.
    fn local_cum(&self, ctx: &mut Ctx<MsMsg>, candidates: &[u64]) -> Vec<u64> {
        ctx.compute(RANK_LOOKUP_CYCLES * candidates.len() as u64);
        candidates
            .iter()
            .map(|&c| self.keys.partition_point(|&k| k < c) as u64)
            .collect()
    }

    /// Scatter a message to this node's subtree children.
    fn scatter<F: Fn() -> MsMsg>(&self, ctx: &mut Ctx<MsMsg>, make: F) {
        let tree = self.tree();
        for t in (1..=tree.rounds()).rev() {
            if tree.aggregates_at(self.id, t) {
                for child in tree.children(self.id, t) {
                    ctx.send(child, make());
                }
            }
        }
    }

    /// Handle one probe round: add own counts, and if this node has all
    /// its children's vectors, push the sum up (or conclude, at the root).
    fn probe_contribute(&mut self, ctx: &mut Ctx<MsMsg>, round: u16, candidates: &[u64]) {
        let own = self.local_cum(ctx, candidates);
        self.probe_fold(ctx, round, &own, true);
    }

    fn probe_fold(&mut self, ctx: &mut Ctx<MsMsg>, round: u16, cum: &[u64], is_own: bool) {
        let tree = self.tree();
        // Expected children = all subtree children across rounds (the
        // whole subtree reports through this node).
        let expected: usize = (1..=tree.rounds())
            .filter(|&t| tree.aggregates_at(self.id, t))
            .map(|t| tree.expected(self.id, t))
            .sum();
        let entry = self
            .probe_pending
            .entry(round)
            .or_insert_with(|| (vec![0u64; self.shared.cores - 1], 0));
        ctx.compute(COUNT_SUM_CYCLES * cum.len() as u64);
        for (a, b) in entry.0.iter_mut().zip(cum) {
            *a += b;
        }
        if is_own {
            self.probe_sent_own.insert(round, true);
        } else {
            entry.1 += 1;
        }
        let have = self.probe_pending.get(&round).expect("entry just touched").1;
        let own_done = self.probe_sent_own.get(&round).copied().unwrap_or(false);
        if have < expected || !own_done {
            return;
        }
        // §Perf: move the accumulated sum out of the map (it is dead
        // there) instead of cloning the full vector per fold.
        let (sum, _) = self.probe_pending.remove(&round).expect("entry just touched");
        if self.id == 0 {
            self.root_advance_probe(ctx, round, &sum);
        } else {
            ctx.send(self.tree().parent(self.id), MsMsg::Counts { round, cum: sum.into() });
        }
    }

    /// Root: bisect each splitter toward its target rank; next round or
    /// finish.
    fn root_advance_probe(&mut self, ctx: &mut Ctx<MsMsg>, round: u16, cum: &[u64]) {
        let cores = self.shared.cores;
        ctx.compute(BISECT_CYCLES * (cores as u64 - 1));
        // Target rank of splitter j is (j+1) * total / cores; `total` is
        // known statically (keys divide evenly at load time, §5.2).
        let candidates = self.current_candidates();
        for j in 0..cores - 1 {
            let target = ((j + 1) as u64) * self.target_total() / cores as u64;
            if cum[j] < target {
                self.lo[j] = candidates[j];
            } else {
                self.hi[j] = candidates[j];
            }
        }
        if (round as u32) + 1 < self.shared.probe_rounds {
            let next = Arc::new(self.current_candidates());
            self.scatter(ctx, || MsMsg::Probe { round: round + 1, candidates: next.clone() });
            self.probe_contribute(ctx, round + 1, &next);
        } else {
            let boundaries = Arc::new(self.current_candidates());
            self.scatter(ctx, || MsMsg::Boundaries { boundaries: boundaries.clone() });
            self.start_shuffle(ctx, &boundaries);
        }
    }

    fn target_total(&self) -> u64 {
        // Total keys = cores × keys-per-node (even pre-load, §5.2).
        (self.shared.cores * self.initial_keys_per_node()) as u64
    }
    fn initial_keys_per_node(&self) -> usize {
        // Recorded at construction via lo/hi capacity trick? No — keys are
        // still held until the shuffle, so keys.len() is the initial count.
        self.keys.len()
    }

    fn current_candidates(&self) -> Vec<u64> {
        self.lo
            .iter()
            .zip(&self.hi)
            .map(|(&l, &h)| l + (h - l) / 2)
            .collect()
    }

    fn start_shuffle(&mut self, ctx: &mut Ctx<MsMsg>, boundaries: &[u64]) {
        self.step = STEP_SHUFFLE;
        if !self.keys.is_empty() {
            ctx.compute(
                ctx.core()
                    .bucketize_cycles(self.keys.len() as u64, boundaries.len() as u64),
            );
            // Fused data-plane kernel: counting pass + direct scatter
            // (bucket = destination core). The local keys are sorted, so
            // bucket-major iteration preserves the old send order.
            let keys = std::mem::take(&mut self.keys);
            for (bucket, members) in
                self.compute.partition(&keys, boundaries).into_iter().enumerate()
            {
                for key in members {
                    self.sent += 1;
                    ctx.send(bucket, MsMsg::Key { key, origin: self.id as u32 });
                }
            }
        }
        self.ct_sum = (self.sent, self.received);
        self.ct_round = 0;
        self.advance_count_tree(ctx);
    }

    fn advance_count_tree(&mut self, ctx: &mut Ctx<MsMsg>) {
        let tree = self.tree();
        let rounds = tree.rounds();
        let pos = self.id;
        let epoch = self.ct_epoch;
        loop {
            let next = self.ct_round + 1;
            if next > rounds {
                let complete = self.ct_sum.0 == self.ct_sum.1;
                for dst in 1..self.shared.cores {
                    ctx.send(dst, MsMsg::Done { epoch, complete });
                }
                self.handle_done(ctx, complete);
                return;
            }
            if tree.aggregates_at(pos, next) {
                let key = (epoch, next);
                let (s, r, cnt) = self.ct_pending.get(&key).copied().unwrap_or((0, 0, 0));
                if cnt < tree.expected(pos, next) {
                    return;
                }
                ctx.compute(COUNT_FOLD_CYCLES * cnt as u64);
                self.ct_sum.0 += s;
                self.ct_sum.1 += r;
                self.ct_pending.remove(&key);
                self.ct_round = next;
            } else {
                ctx.send(
                    tree.parent(pos),
                    MsMsg::CountUp {
                        round: next as u8,
                        epoch,
                        sent: self.ct_sum.0,
                        received: self.ct_sum.1,
                    },
                );
                self.ct_round = rounds + 1;
                return;
            }
        }
    }

    fn handle_done(&mut self, ctx: &mut Ctx<MsMsg>, complete: bool) {
        if complete {
            self.step = STEP_DONE;
            let n = self.received_keys.len() as u64;
            ctx.compute(ctx.core().sort_cycles(n, Temp::Warm));
            let mut keys = std::mem::take(&mut self.received_keys);
            self.compute.sort(&mut keys);
            self.shared.outputs.set(self.id, keys);
            ctx.finish();
        } else {
            self.ct_epoch += 1;
            self.ct_round = 0;
            self.ct_sum = (self.sent, self.received);
            self.advance_count_tree(ctx);
        }
    }
}

impl Program for MilliSortNode {
    type Msg = MsMsg;

    fn on_start(&mut self, ctx: &mut Ctx<MsMsg>) {
        // Local sort (cold: the pre-loaded records stream from DRAM).
        let n = self.keys.len() as u64;
        ctx.compute(ctx.core().sort_cycles(n, Temp::Cold));
        self.compute.sort(&mut self.keys);
        if self.id == 0 {
            if self.shared.cores == 1 {
                // Degenerate single-core run.
                self.received_keys = std::mem::take(&mut self.keys);
                self.handle_done(ctx, true);
                return;
            }
            let candidates = Arc::new(self.current_candidates());
            self.scatter(ctx, || MsMsg::Probe { round: 0, candidates: candidates.clone() });
            self.probe_contribute(ctx, 0, &candidates);
        }
    }

    fn on_message(&mut self, ctx: &mut Ctx<MsMsg>, _src: NodeId, msg: MsMsg) {
        match msg {
            MsMsg::Probe { round, candidates } => {
                self.scatter(ctx, || MsMsg::Probe { round, candidates: candidates.clone() });
                self.probe_contribute(ctx, round, &candidates);
            }
            MsMsg::Counts { round, cum } => {
                self.probe_fold(ctx, round, &cum, false);
            }
            MsMsg::Boundaries { boundaries } => {
                self.scatter(ctx, || MsMsg::Boundaries { boundaries: boundaries.clone() });
                self.start_shuffle(ctx, &boundaries);
            }
            MsMsg::Key { key, .. } => {
                ctx.compute(KEY_APPEND_CYCLES);
                self.received_keys.push(key);
                self.received += 1;
            }
            MsMsg::CountUp { round, epoch, sent, received } => {
                let e = self.ct_pending.entry((epoch, round as u32)).or_insert((0, 0, 0));
                e.0 += sent;
                e.1 += received;
                e.2 += 1;
                if self.step == STEP_SHUFFLE {
                    self.advance_count_tree(ctx);
                }
            }
            MsMsg::Done { complete, .. } => self.handle_done(ctx, complete),
        }
    }

    fn step(&self) -> u32 {
        self.step
    }
}

/// MilliSort as a [`Workload`]: the scenario supplies fleet size,
/// network, data plane, and seed; these are the workload-specific dials.
#[derive(Debug, Clone)]
pub struct MilliSort {
    pub total_keys: usize,
    /// Probe rounds; `None` = enough to bisect to ~single-key precision.
    pub probe_rounds: Option<u32>,
    /// Gather/scatter tree branching (Fig 10's knob).
    pub reduction_factor: usize,
}

impl Default for MilliSort {
    fn default() -> Self {
        MilliSort { total_keys: 4096, probe_rounds: None, reduction_factor: 4 }
    }
}

impl MilliSort {
    fn rounds(&self) -> u32 {
        self.probe_rounds
            .unwrap_or_else(|| (usize::BITS - (self.total_keys - 1).leading_zeros()) + 2)
    }
}

impl Workload for MilliSort {
    type Prog = MilliSortNode;

    fn name(&self) -> &'static str {
        "millisort"
    }

    fn default_nodes(&self) -> usize {
        64
    }

    fn build(&self, env: &ScenarioEnv) -> Result<Built<MilliSortNode>> {
        anyhow::ensure!(
            self.total_keys % env.nodes == 0,
            "keys ({}) must divide across cores ({})",
            self.total_keys,
            env.nodes
        );
        let shared = Arc::new(MsShared {
            cores: env.nodes,
            reduction_factor: self.reduction_factor,
            probe_rounds: self.rounds(),
            outputs: NodeSlots::new(env.nodes),
        });
        // Key values come from the scenario's input distribution
        // (`Uniform` = the exact pre-perturbation KeyGen path).
        let per_node = env.perturb.dist.partitioned_keys(env.seed, self.total_keys, env.nodes);
        let input: Vec<u64> = per_node.iter().flatten().copied().collect();

        let programs: Vec<MilliSortNode> = (0..env.nodes)
            .map(|id| MilliSortNode {
                id,
                shared: shared.clone(),
                compute: env.compute.clone(),
                step: STEP_PARTITION,
                keys: per_node[id].clone(),
                received_keys: Vec::new(),
                lo: vec![0; env.nodes.saturating_sub(1)],
                hi: vec![u64::MAX; env.nodes.saturating_sub(1)],
                probe_pending: HashMap::new(),
                probe_sent_own: HashMap::new(),
                sent: 0,
                received: 0,
                ct_epoch: 0,
                ct_round: 0,
                ct_sum: (0, 0),
                ct_pending: HashMap::new(),
            })
            .collect();

        let finish: Finish = Box::new(move |env, summary| {
            let outputs = shared.outputs.take_vecs();
            let validation = validate_sorted_output(&input, &outputs, None);
            RunReport::new("millisort", env, summary, Validation::from_sort(validation))
        });
        Ok(Built { programs, groups: Vec::new(), finish })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;

    fn run(cores: usize, keys: usize, rf: usize) -> RunReport {
        Scenario::new(MilliSort {
            total_keys: keys,
            reduction_factor: rf,
            probe_rounds: None,
        })
        .nodes(cores)
        .run()
        .expect("millisort scenario")
    }

    #[test]
    fn sorts_correctly() {
        for cores in [4usize, 16, 64] {
            let r = run(cores, 4096, 4);
            assert!(r.validation.ok(), "cores={cores}: {:?}", r.validation);
        }
    }

    #[test]
    fn sorts_with_various_reduction_factors() {
        for rf in [2usize, 4, 8, 16, 32] {
            let r = run(64, 4096, rf);
            assert!(r.validation.ok(), "rf={rf}");
        }
    }

    #[test]
    fn ragged_core_counts() {
        for cores in [2usize, 3, 10, 48, 100] {
            let r = run(cores, cores * 16, 4);
            assert!(r.validation.ok(), "cores={cores}: {:?}", r.validation);
        }
    }

    #[test]
    fn single_core_degenerates() {
        let r = run(1, 64, 4);
        assert!(r.validation.ok());
    }

    #[test]
    fn fig9_shape_partition_cost_grows_superlinearly() {
        // Fig 9: runtime grows steeply with cores (61 µs @64 -> 400 µs
        // @256 in the paper, fixed 4,096 keys). Check super-linear growth.
        let t64 = run(64, 4096, 4).runtime().as_us_f64();
        let t256 = run(256, 4096, 4).runtime().as_us_f64();
        assert!(t256 > 2.0 * t64, "t64={t64} t256={t256}");
    }

    #[test]
    fn fig10_shape_bigger_incast_slower() {
        // Fig 10: increasing the reduction factor slows MilliSort down
        // (128 cores, 4,096 keys).
        let t4 = run(128, 4096, 4).runtime().as_us_f64();
        let t32 = run(128, 4096, 32).runtime().as_us_f64();
        assert!(t32 > t4, "t4={t4} t32={t32}");
    }

    #[test]
    fn balanced_buckets_on_uniform_keys() {
        // The probing converges to near-balanced buckets for uniform keys.
        let r = run(64, 4096, 4);
        let counts = &r.validation.sort.as_ref().expect("sort validation").node_counts;
        let skew = crate::graysort::bucket_skew(counts);
        assert!(skew < 2.5, "skew = {skew}");
    }

    #[test]
    fn deterministic() {
        let a = run(64, 4096, 4);
        let b = run(64, 4096, 4);
        assert_eq!(a.runtime(), b.runtime());
    }
}
