//! Granular set-algebra (extension): distributed posting-list
//! intersection, the paper's other motivating nanoTask workload (Fig 1:
//! "perform 4 set algebra intersections" per µs; §3.2: web search).
//!
//! Demonstrates the framework's generality beyond sorting: a query names
//! q posting lists, each sharded across all cores (sorted u64 doc-id
//! segments). Every core intersects its local shards (a nanoTask —
//! doc-id-range sharding means no cross-core data dependency), then
//! result *counts* reduce up an aggregation tree, exactly like MergeMin;
//! the root reports the global intersection cardinality.
//!
//! The incast knob exposes the same width/depth trade-off as Fig 4.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::algo::tree::AggTree;
use crate::compute::LocalCompute;
use crate::nanopu::{Ctx, NodeId, Program, WireMsg};
use crate::scenario::{
    Built, Finish, MetricValue, RunReport, ScenarioEnv, Validation, Workload,
};
use crate::sim::SplitMix64;

#[derive(Debug, Clone)]
pub struct CountMsg {
    pub round: u32,
    pub count: u64,
}

impl WireMsg for CountMsg {
    fn wire_bytes(&self) -> u64 {
        16
    }
    fn step(&self) -> u32 {
        self.round
    }
}

/// Per-core program: intersect local shards, then reduce counts up-tree.
#[derive(Clone)]
pub struct SetAlgebraNode {
    id: NodeId,
    cores: usize,
    incast: usize,
    /// Local shards, one sorted id list per posting list.
    shards: Vec<Vec<u64>>,
    /// Data plane handle (the leapfrog intersection has no compiled XLA
    /// artifact yet, so this extension's data plane is native-only; kept
    /// so the API matches the other algorithms).
    _compute: Arc<dyn LocalCompute>,
    count: u64,
    round: u32,
    got: usize,
    /// Root's final answer (atomic: programs run on executor threads).
    pub result: Arc<AtomicU64>,
}

impl SetAlgebraNode {
    fn tree(&self) -> AggTree {
        AggTree::new(self.cores, self.incast.max(2))
    }

    /// q-way sorted intersection via leapfrog merge; cost ≈ 2 cycles per
    /// id visited (Fig 1: 4 intersections per µs over small lists).
    fn intersect_local(&mut self, ctx: &mut Ctx<CountMsg>) -> u64 {
        let visited: u64 = self.shards.iter().map(|s| s.len() as u64).sum();
        ctx.compute(2 * visited + 20);
        // Data plane: merge-count ids present in all q shards. Shards are
        // sorted; walk the first and binary-search the rest.
        let (first, rest) = match self.shards.split_first() {
            Some(x) => x,
            None => return 0,
        };
        first
            .iter()
            .filter(|&&id| rest.iter().all(|s| s.binary_search(&id).is_ok()))
            .count() as u64
    }

    fn advance(&mut self, ctx: &mut Ctx<CountMsg>) {
        let tree = self.tree();
        let rounds = tree.rounds();
        loop {
            let next = self.round + 1;
            if next > rounds {
                if self.id == 0 {
                    self.result.store(self.count, Ordering::Relaxed);
                    ctx.finish();
                }
                return;
            }
            if tree.aggregates_at(self.id, next) {
                if self.got < tree.expected(self.id, next) {
                    return;
                }
                self.got = 0;
                self.round = next;
            } else {
                ctx.send(
                    tree.parent(self.id),
                    CountMsg { round: next, count: self.count },
                );
                self.round = rounds + 1;
                ctx.finish();
                return;
            }
        }
    }
}

impl Program for SetAlgebraNode {
    type Msg = CountMsg;

    fn on_start(&mut self, ctx: &mut Ctx<CountMsg>) {
        self.count = self.intersect_local(ctx);
        if self.cores == 1 {
            self.result.store(self.count, Ordering::Relaxed);
            ctx.finish();
            return;
        }
        self.advance(ctx);
    }

    fn on_message(&mut self, ctx: &mut Ctx<CountMsg>, _src: NodeId, msg: CountMsg) {
        ctx.compute(ctx.core().merge_cycles(1));
        self.count += msg.count;
        self.got += 1;
        self.advance(ctx);
    }

    fn step(&self) -> u32 {
        self.round + 1
    }
}

/// Set algebra as a [`Workload`]: the scenario supplies fleet size,
/// network, data plane, and seed; these are the workload-specific dials.
#[derive(Debug, Clone)]
pub struct SetAlgebra {
    /// Posting lists per query (q-way intersection).
    pub lists: usize,
    /// Doc ids per list per core (local shard size).
    pub ids_per_core: usize,
    /// Probability (num/den) that a doc id appears in every list.
    pub hit_prob: (u64, u64),
    /// Reduce-tree incast.
    pub incast: usize,
}

impl Default for SetAlgebra {
    fn default() -> Self {
        SetAlgebra { lists: 4, ids_per_core: 128, hit_prob: (1, 8), incast: 8 }
    }
}

impl Workload for SetAlgebra {
    type Prog = SetAlgebraNode;

    fn name(&self) -> &'static str {
        "setalgebra"
    }

    fn default_nodes(&self) -> usize {
        64
    }

    fn build(&self, env: &ScenarioEnv) -> Result<Built<SetAlgebraNode>> {
        let mut rng = SplitMix64::new(env.seed ^ 0x7365_7461_6c67);
        // Set algebra's input is local posting-list shards, so the
        // scenario's input distribution shapes *per-core shard sizes*
        // (`Uniform` keeps every core at `ids_per_core`, byte-identical
        // to the pre-perturbation stream).
        let counts = env.perturb.dist.per_core_counts(self.ids_per_core, env.nodes);
        let result = Arc::new(AtomicU64::new(u64::MAX));
        let mut expected = 0u64;
        let programs: Vec<SetAlgebraNode> = (0..env.nodes)
            .map(|id| {
                // Doc-id-range sharding: core c owns ids with high bits = c.
                let base = (id as u64) << 32;
                let mut shards: Vec<Vec<u64>> = vec![Vec::new(); self.lists];
                for i in 0..counts[id] {
                    let id64 = base + i as u64;
                    if rng.chance(self.hit_prob.0, self.hit_prob.1) {
                        // Common doc: appears in every list.
                        for s in shards.iter_mut() {
                            s.push(id64);
                        }
                        expected += 1;
                    } else {
                        // Appears in a strict subset of lists.
                        let skip = rng.index(self.lists);
                        for (j, s) in shards.iter_mut().enumerate() {
                            if j != skip {
                                s.push(id64);
                            }
                        }
                    }
                }
                SetAlgebraNode {
                    id,
                    cores: env.nodes,
                    incast: self.incast,
                    shards,
                    _compute: env.compute.clone(),
                    count: 0,
                    round: 0,
                    got: 0,
                    result: result.clone(),
                }
            })
            .collect();
        let finish: Finish = Box::new(move |env, summary| {
            let found = result.load(Ordering::Relaxed);
            let validation = Validation::check(
                found == expected,
                format!("intersection cardinality {found} == expected {expected}"),
            );
            RunReport::new("setalgebra", env, summary, validation)
                .with_metric("found", MetricValue::U64(found))
                .with_metric("expected", MetricValue::U64(expected))
        });
        Ok(Built { programs, groups: Vec::new(), finish })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{RunReport, Scenario};

    fn run_cfg(workload: SetAlgebra, cores: usize) -> RunReport {
        Scenario::new(workload).nodes(cores).run().expect("setalgebra scenario")
    }

    fn run(cores: usize, lists: usize, incast: usize) -> RunReport {
        run_cfg(SetAlgebra { lists, incast, ..Default::default() }, cores)
    }

    #[test]
    fn intersects_correctly() {
        for cores in [1usize, 8, 64, 100] {
            let r = run(cores, 4, 8);
            assert!(r.validation.ok(), "cores={cores}: {}", r.validation.detail);
        }
    }

    #[test]
    fn q_way_variants() {
        for lists in [2usize, 3, 4, 8] {
            let r = run(64, lists, 8);
            assert!(r.validation.ok(), "lists={lists}");
        }
    }

    #[test]
    fn incast_tradeoff_same_shape_as_mergemin() {
        let deep = run(64, 4, 2).summary.makespan;
        let sweet = run(64, 4, 8).summary.makespan;
        let flat = run(64, 4, 64).summary.makespan;
        assert!(sweet <= deep, "sweet {sweet} > deep {deep}");
        assert!(sweet <= flat, "sweet {sweet} > flat {flat}");
    }

    #[test]
    fn fig1_rate_anchor() {
        // Fig 1: ~4 set-algebra intersections per µs on one core. One
        // local q=4 intersection over small (16-id) shards should cost
        // well under 1 µs of simulated core time.
        let r = run_cfg(SetAlgebra { ids_per_core: 16, ..Default::default() }, 1);
        assert!(r.validation.ok());
        let us = r.summary.makespan.as_us_f64();
        assert!(us < 0.25, "one 4-way intersection = {us} µs");
    }

    #[test]
    fn deterministic() {
        let a = run(64, 4, 8);
        let b = run(64, 4, 8);
        assert_eq!(a.metric_u64("found"), b.metric_u64("found"));
        assert_eq!(a.summary.makespan, b.summary.makespan);
    }
}
