//! NanoSort (paper §4/§5): recursive, quicksort-like distributed sort for
//! extreme granularity.
//!
//! Per recursion level, within each node group:
//!  1. every node sorts its keys and proposes b-1 local pivots via
//!     [`pivot::pivot_select`] (probability-corrected, §4.2);
//!  2. b-1 median-trees (one per pivot position, sharing one physical
//!     tree of incast `median_incast`) aggregate per-position medians;
//!  3. the group root broadcasts the final pivots (multicast if the
//!     fabric supports it — §5.3/§6.2.3);
//!  4. every node routes each key to a uniformly random node of the
//!     key's bucket partition (the b equal slices of the group);
//!  5. a count-tree termination protocol (sent vs received totals, with
//!     retry rounds) detects shuffle completion and triggers recursion.
//!
//! After the last level each node sorts its final keys locally; the
//! optional GraySort value phase then pulls each key's 96 B value from its
//! origin core (§5.2).

mod node;
pub mod pivot;

pub use node::{depth_of, LevelBreakdown, NanoSort, NsMsg, PivotMode};
