//! PivotSelect (paper §4.2): probability-corrected local pivot selection.
//!
//! The subtlety the paper fixes: with N nodes each proposing local pivots
//! and a median-tree aggregating them, the *median* of the per-node
//! quantile distribution — not its expectation — determines the bucket
//! boundaries. Naive uniform selection puts the median of the smallest-key
//! quantile at ≈7.5% instead of 10% (for b=10), shrinking the first
//! bucket ~25% and compounding per recursion level. PivotSelect mixes
//! strategies so the median of each pivot's quantile lands on i/b.
//!
//! The 16-bucket instantiation is implemented verbatim from the paper's
//! box; other bucket counts (Fig 11 uses b ∈ {4, 8, 16}) use the same
//! construction generalized (documented per case).

use crate::sim::SplitMix64;

/// Select `b-1` pivots from this node's sorted keys.
///
/// `sorted` must be ascending. Returns an ascending pivot list of length
/// `b-1`. Panics if `sorted` is empty or `b < 2`.
pub fn pivot_select(sorted: &[u64], b: usize, rng: &mut SplitMix64) -> Vec<u64> {
    assert!(b >= 2, "need at least 2 buckets");
    let n = sorted.len();
    assert!(n > 0, "pivot_select on empty keys");
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]));

    if n == b {
        select_eq(sorted, b, rng)
    } else if n < b {
        // Paper case n < 16: duplicate uniformly-chosen keys up to b keys,
        // then run the n == b protocol.
        let mut padded = sorted.to_vec();
        while padded.len() < b {
            padded.push(sorted[rng.index(n)]);
        }
        padded.sort_unstable();
        select_eq(&padded, b, rng)
    } else if n < 2 * b {
        // Paper case 17..=31: uniform subset of b keys, n == b protocol.
        let subset = sample_sorted(sorted, b, rng);
        select_eq(&subset, b, rng)
    } else if n == 2 * b {
        select_2b(sorted, b, rng)
    } else {
        // Paper case n > 32: uniform subset of 2b keys, n == 2b protocol.
        let subset = sample_sorted(sorted, 2 * b, rng);
        select_2b(&subset, b, rng)
    }
}

/// The naive strawman for the whole-system ablation: `b-1` pivots drawn
/// uniformly without replacement (with duplication when keys are scarce).
/// Correct expectation, bad *median* — the bucket-skew compounds per
/// recursion level (paper §4.2).
pub fn naive_select(sorted: &[u64], b: usize, rng: &mut SplitMix64) -> Vec<u64> {
    assert!(!sorted.is_empty());
    if sorted.len() >= b - 1 {
        sample_sorted(sorted, b - 1, rng)
    } else {
        let mut out: Vec<u64> = sorted.to_vec();
        while out.len() < b - 1 {
            out.push(sorted[rng.index(sorted.len())]);
        }
        out.sort_unstable();
        out
    }
}

/// Uniform subset of `k` keys (result stays sorted).
fn sample_sorted(sorted: &[u64], k: usize, rng: &mut SplitMix64) -> Vec<u64> {
    rng.sample_indices(sorted.len(), k)
        .into_iter()
        .map(|i| sorted[i])
        .collect()
}

/// The n == b case. Paper (b=16): with probability 1/4 select 15 pivots
/// uniformly without replacement; with probability 3/8 return k_1..k_15;
/// with probability 3/8 return k_2..k_16.
fn select_eq(sorted: &[u64], b: usize, rng: &mut SplitMix64) -> Vec<u64> {
    debug_assert_eq!(sorted.len(), b);
    if rng.chance(1, 4) {
        sample_sorted(sorted, b - 1, rng)
    } else if rng.chance(1, 2) {
        sorted[..b - 1].to_vec()
    } else {
        sorted[1..].to_vec()
    }
}

/// The paper's exact index sets for n = 32, b = 16 (1-based in the paper).
const LOW_32: [usize; 15] = [1, 3, 6, 8, 10, 12, 14, 16, 18, 20, 22, 24, 26, 27, 29];
const HIGH_32: [usize; 15] = [4, 6, 7, 9, 11, 13, 15, 17, 19, 21, 23, 25, 27, 30, 32];

/// The n == 2b case: with probability 1/2 a "low" index set, else a "high"
/// set (its mirror). For b == 16 the paper's exact sets; for other b the
/// generalized evenly-spaced construction low_i = 2i-1 / high_i = 2i+2
/// (which reproduces the paper sets' endpoints and spacing).
fn select_2b(sorted: &[u64], b: usize, rng: &mut SplitMix64) -> Vec<u64> {
    debug_assert_eq!(sorted.len(), 2 * b);
    let low = rng.chance(1, 2);
    if b == 16 {
        let idx: &[usize; 15] = if low { &LOW_32 } else { &HIGH_32 };
        return idx.iter().map(|&i| sorted[i - 1]).collect();
    }
    (1..b)
        .map(|i| {
            let pos = if low { 2 * i - 1 } else { (2 * i + 2).min(2 * b) };
            sorted[pos - 1]
        })
        .collect()
}

// ---------------------------------------------------------------------
// Fig 5: the three strategies compared by the paper (b = 8, n = 8).
// ---------------------------------------------------------------------

/// Pivot selection strategies of Fig 5.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Select b-1 pivots uniformly without replacement.
    Naive,
    /// With probability 1/2 return k_1..k_{b-1}, else k_2..k_b.
    Shifted,
    /// With probability 1/4 Naive, else Shifted (the PivotSelect mix).
    Mixed,
}

/// Apply a Fig 5 strategy to exactly `b` sorted keys.
pub fn strategy_select(sorted: &[u64], strategy: Strategy, rng: &mut SplitMix64) -> Vec<u64> {
    let b = sorted.len();
    match strategy {
        Strategy::Naive => sample_sorted(sorted, b - 1, rng),
        Strategy::Shifted => {
            if rng.chance(1, 2) {
                sorted[..b - 1].to_vec()
            } else {
                sorted[1..].to_vec()
            }
        }
        Strategy::Mixed => {
            if rng.chance(1, 4) {
                strategy_select(sorted, Strategy::Naive, rng)
            } else {
                strategy_select(sorted, Strategy::Shifted, rng)
            }
        }
    }
}

/// Monte-Carlo estimate of Fig 5: expected bucket-size *fractions* when
/// `nodes` nodes each receive `b` uniform keys, apply `strategy`, and the
/// per-position median of their pivots defines the buckets.
pub fn expected_bucket_fractions(
    strategy: Strategy,
    b: usize,
    nodes: usize,
    trials: usize,
    seed: u64,
) -> Vec<f64> {
    let mut rng = SplitMix64::new(seed ^ 0x6669_6735);
    let mut acc = vec![0.0f64; b];
    for _ in 0..trials {
        // Per node: b uniform keys in [0, 1) represented as u64 quantiles.
        let mut per_position: Vec<Vec<u64>> = vec![Vec::with_capacity(nodes); b - 1];
        for _ in 0..nodes {
            let mut keys: Vec<u64> = (0..b).map(|_| rng.next_u64() >> 1).collect();
            keys.sort_unstable();
            let pivots = strategy_select(&keys, strategy, &mut rng);
            for (j, &p) in pivots.iter().enumerate() {
                per_position[j].push(p);
            }
        }
        // Median per pivot position.
        let mut final_pivots: Vec<u64> = per_position
            .iter_mut()
            .map(|v| {
                v.sort_unstable();
                v[(v.len() - 1) / 2]
            })
            .collect();
        final_pivots.sort_unstable();
        // Bucket fractions = quantile gaps (keys are uniform, so the
        // fraction of keyspace below p is p / 2^63).
        let scale = (1u64 << 63) as f64;
        let mut prev = 0.0;
        for (j, &p) in final_pivots.iter().enumerate() {
            let q = p as f64 / scale;
            acc[j] += q - prev;
            prev = q;
        }
        acc[b - 1] += 1.0 - prev;
    }
    acc.iter().map(|a| a / trials as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize, seed: u64) -> Vec<u64> {
        let mut rng = SplitMix64::new(seed);
        let mut k: Vec<u64> = (0..n).map(|_| rng.next_u64() % (u64::MAX - 1)).collect();
        k.sort_unstable();
        k
    }

    fn check_valid(pivots: &[u64], b: usize, keys: &[u64]) {
        assert_eq!(pivots.len(), b - 1);
        assert!(pivots.windows(2).all(|w| w[0] <= w[1]), "pivots sorted");
        for p in pivots {
            assert!(keys.contains(p), "pivot must come from the keys");
        }
    }

    #[test]
    fn all_paper_cases_produce_valid_pivots() {
        let mut rng = SplitMix64::new(42);
        let b = 16;
        for n in [4usize, 8, 15, 16, 17, 24, 31, 32, 33, 64, 100] {
            let ks = keys(n, n as u64);
            for _ in 0..20 {
                let pv = pivot_select(&ks, b, &mut rng);
                check_valid(&pv, b, &ks);
            }
        }
    }

    #[test]
    fn other_bucket_counts() {
        let mut rng = SplitMix64::new(43);
        for b in [2usize, 4, 8] {
            for n in [b - 1, b, b + 1, 2 * b, 2 * b + 5, 10 * b] {
                let n = n.max(1);
                let ks = keys(n, (b * 1000 + n) as u64);
                let pv = pivot_select(&ks, b, &mut rng);
                check_valid(&pv, b, &ks);
            }
        }
    }

    #[test]
    fn n32_b16_uses_paper_index_sets() {
        // With a fixed key set 0..32, pivots must be one of the two paper
        // index sets (values = index - 1 since keys are 0-based idents).
        let ks: Vec<u64> = (0..32).collect();
        let mut rng = SplitMix64::new(7);
        let low: Vec<u64> = LOW_32.iter().map(|&i| (i - 1) as u64).collect();
        let high: Vec<u64> = HIGH_32.iter().map(|&i| (i - 1) as u64).collect();
        let mut seen_low = false;
        let mut seen_high = false;
        for _ in 0..50 {
            let pv = pivot_select(&ks, 16, &mut rng);
            if pv == low {
                seen_low = true;
            } else if pv == high {
                seen_high = true;
            } else {
                panic!("unexpected pivot set {pv:?}");
            }
        }
        assert!(seen_low && seen_high, "both sets should appear");
    }

    #[test]
    fn n16_b16_mixture_probabilities() {
        // 3/8 low-shift, 3/8 high-shift, 1/4 uniform.
        let ks: Vec<u64> = (0..16).collect();
        let mut rng = SplitMix64::new(11);
        let (mut low, mut high, mut other) = (0, 0, 0);
        let trials = 8000;
        for _ in 0..trials {
            let pv = pivot_select(&ks, 16, &mut rng);
            if pv == ks[..15] {
                low += 1;
            } else if pv == ks[1..] {
                high += 1;
            } else {
                other += 1;
            }
        }
        let f = |c: i32| c as f64 / trials as f64;
        // Note: the uniform branch occasionally reproduces a shifted set
        // (prob ~2·1/16 of 1/4), so bounds are loose.
        assert!((f(low) - 0.39).abs() < 0.05, "low = {}", f(low));
        assert!((f(high) - 0.39).abs() < 0.05, "high = {}", f(high));
        assert!((f(other) - 0.22).abs() < 0.05, "other = {}", f(other));
    }

    /// Fig 5's headline: the naive strategy under-sizes the first bucket
    /// (median of the min-key quantile ≈ 8% < 12.5% for b=8), while the
    /// mixed strategy is close to uniform.
    #[test]
    fn fig5_mixed_beats_naive_on_first_bucket() {
        let b = 8;
        let naive = expected_bucket_fractions(Strategy::Naive, b, 101, 300, 1);
        let mixed = expected_bucket_fractions(Strategy::Mixed, b, 101, 300, 1);
        let target = 1.0 / b as f64;
        assert!(
            naive[0] < 0.105,
            "naive first bucket should shrink: {}",
            naive[0]
        );
        assert!(
            (mixed[0] - target).abs() < 0.02,
            "mixed first bucket ≈ 1/8: {}",
            mixed[0]
        );
        // Every strategy's fractions sum to 1.
        for fr in [&naive, &mixed] {
            let s: f64 = fr.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }
}
