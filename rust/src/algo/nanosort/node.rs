//! The per-core NanoSort program and run driver.

use std::sync::Arc;

use anyhow::Result;

use crate::algo::tree::AggTree;
use crate::compute::LocalCompute;
use crate::cpu::Temp;
use crate::graysort::{
    validate_sorted_output, value_of_key, MultisetHash, SpillWriter, StreamingValidator,
    ValidationReport, DEFAULT_SPILL_BINS,
};
use crate::nanopu::{Ctx, Group, GroupId, NodeId, Program, SmallWords, WireMsg};
use crate::scenario::{
    Built, Finish, MetricValue, NodeSlots, RunReport, ScenarioEnv, Validation, Workload,
};
use crate::sim::MAX_STAGES;

/// Per-level stage summary (kept as an alias of the scenario layer's
/// generalized breakdown; Fig 16 reads the same shape for every workload).
pub use crate::scenario::StageBreakdown as LevelBreakdown;

/// Cycles charged for the PivotSelect index arithmetic (the sort itself is
/// priced separately).
const PIVOT_SELECT_CYCLES: u64 = 60;
/// Cycles to append one received key to the next-level buffer.
const KEY_APPEND_CYCLES: u64 = 4;
/// Cycles to fold one CountUp into the running sums.
const COUNT_FOLD_CYCLES: u64 = 6;
/// Cycles for the level-entry bookkeeping.
const LEVEL_ENTRY_CYCLES: u64 = 20;
/// Cycles to serve one value request (record lookup).
const VALUE_LOOKUP_CYCLES: u64 = 30;

/// Which local pivot proposal the nodes use (ablation of the paper's
/// §4.2 probability correction; Fig 5 studies it in isolation, this knob
/// studies it end-to-end).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PivotMode {
    /// The paper's PivotSelect routine (median-corrected mixture).
    #[default]
    Paper,
    /// Naive uniform-without-replacement selection — the strawman the
    /// paper shows compounds skew multiplicatively per level.
    Naive,
}

/// Recursion depth r with `nodes = buckets^r`, or an error when the fleet
/// size is not an exact power.
pub fn depth_of(nodes: usize, buckets: usize) -> Result<u32> {
    anyhow::ensure!(buckets >= 2, "need at least 2 buckets, got {buckets}");
    let mut r = 0;
    let mut n: u128 = 1;
    while n < nodes as u128 {
        n *= buckets as u128;
        r += 1;
    }
    anyhow::ensure!(
        n == nodes as u128,
        "nodes ({nodes}) must be buckets^r for buckets = {buckets}"
    );
    anyhow::ensure!(r >= 1, "need at least one level (nodes = {nodes})");
    Ok(r)
}

/// Wire messages. Step tags: level `l` uses `2l` for the pivot phase and
/// `2l + 1` for the shuffle/termination phase; the final local sort and
/// value phase run at `2r`.
#[derive(Debug, Clone)]
pub enum NsMsg {
    /// Median-tree contribution (empty pivots = abstain: node had no
    /// keys). The payload is a [`SmallWords`]: at the paper's bucket
    /// count the pivot vector fits inline, so the dominant unicast of the
    /// pivot phase never allocates (§Perf, DESIGN.md §12).
    PivotUp { level: u8, round: u8, pivots: SmallWords },
    /// Final pivots broadcast by the group root. The vector is shared
    /// behind `Arc`: the engine clones the message once per multicast
    /// member (65,536 at level 0 of the paper tier), and a pooled payload
    /// turns each clone into a pointer bump instead of a buffer
    /// allocation (§Perf, [`WireMsg`] payload-pooling note).
    Pivots { level: u8, pivots: Arc<Vec<u64>> },
    /// One shuffled key (+ origin core, paper §5.2).
    Key { level: u8, key: u64, origin: u32 },
    /// Count-tree contribution for termination detection.
    CountUp { level: u8, round: u8, epoch: u16, sent: u64, received: u64 },
    /// Root verdict: `complete` advances the level, else retry counts.
    Done { level: u8, epoch: u16, complete: bool },
    /// GraySort value phase: ask the origin core for a key's value.
    ValueReq { key: u64, requester: u32, final_step: u32 },
    /// The 96 B value (modeled by its first word).
    ValueResp { key: u64, value: u64, final_step: u32 },
}

impl WireMsg for NsMsg {
    fn wire_bytes(&self) -> u64 {
        match self {
            NsMsg::PivotUp { pivots, .. } => 8 + 8 * pivots.len() as u64,
            NsMsg::Pivots { pivots, .. } => 8 + 8 * pivots.len() as u64,
            NsMsg::Key { .. } => 16,
            NsMsg::CountUp { .. } => 24,
            NsMsg::Done { .. } => 8,
            NsMsg::ValueReq { .. } => 16,
            NsMsg::ValueResp { .. } => 104, // 8 B key + 96 B value
        }
    }

    fn step(&self) -> u32 {
        match self {
            NsMsg::PivotUp { level, .. } => 2 * *level as u32,
            NsMsg::Pivots { level, .. } => 2 * *level as u32,
            NsMsg::Key { level, .. } => 2 * *level as u32 + 1,
            NsMsg::CountUp { level, .. } => 2 * *level as u32 + 1,
            NsMsg::Done { level, .. } => 2 * *level as u32 + 1,
            NsMsg::ValueReq { final_step, .. } => *final_step,
            NsMsg::ValueResp { final_step, .. } => *final_step,
        }
    }
}

/// Static run context shared by all node programs.
struct Shared {
    buckets: usize,
    depth: u32,
    median_incast: usize,
    shuffle_values: bool,
    pivot_mode: PivotMode,
    /// Engine multicast-group id offsets per level (groups are registered
    /// level-major, group-index-minor).
    group_offsets: Vec<usize>,
    /// Cross-node result sinks, written from executor worker threads.
    /// Per-node slots (§Perf: one shared `Mutex` here was a 2×-per-node
    /// acquisition burst at the end of a 65,536-core run under
    /// `--threads N`; the slots are contention-free), written at each
    /// node's own finishing event so results are order-independent —
    /// and overwrite-safe under optimistic rollback re-execution.
    final_keys: NodeSlots<Vec<u64>>,
    final_values: NodeSlots<Vec<u64>>,
    /// Highest termination-detection epoch each node observed as a group
    /// root (0 = every count-tree pass it rooted found sent ==
    /// received). Folded to the fleet max at finish. A shared atomic
    /// `fetch_max` here would be monotone-polluting under discarded
    /// speculation (a rolled-back root verdict's max sticks); the
    /// per-node slot is written at final-sort entry from checkpointed
    /// program state, so rollback restores it exactly.
    retry_epochs: NodeSlots<u64>,
}

impl Shared {
    fn group_size(&self, level: u32) -> usize {
        // b^(depth-level)
        (self.buckets as u128).pow(self.depth - level) as usize
    }
    fn group_base(&self, id: NodeId, level: u32) -> usize {
        id - id % self.group_size(level)
    }
    fn group_id(&self, id: NodeId, level: u32) -> GroupId {
        self.group_offsets[level as usize] + id / self.group_size(level)
    }
}

/// One pending count-tree aggregation cell (keyed by (epoch, round); at
/// most a couple are live at a time).
#[derive(Debug, Clone, Copy)]
struct CtCell {
    epoch: u16,
    round: u32,
    sent: u64,
    received: u64,
    got: usize,
}

/// Per-level phase of a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Median tree in progress.
    PivotTree,
    /// Keys sent; termination detection in progress.
    Shuffle,
    /// Final local sort done; value phase (or finished).
    Final,
}

#[derive(Clone)]
pub struct NanoSortNode {
    id: NodeId,
    shared: Arc<Shared>,
    compute: Arc<dyn LocalCompute>,

    level: u32,
    phase: Phase,
    step: u32,

    /// Current level's keys (+ origin core of each).
    keys: Vec<u64>,
    origins: Vec<u32>,
    /// Keys received for the next level.
    next_keys: Vec<u64>,
    next_origins: Vec<u32>,

    // Median-tree state.
    my_pivots: Vec<u64>,
    mt_round: u32,
    /// Child pivot vectors received per round: `(round, pivots)` in
    /// arrival order. Live entries are incast-bounded, so a flat vec
    /// beats a HashMap (§Scale: two maps per node was 2 × 65,536 heap
    /// tables at paper scale).
    mt_pending: Vec<(u32, SmallWords)>,

    // Count-tree state.
    sent_this_level: u64,
    received_next: u64,
    ct_epoch: u16,
    ct_round: u32,
    /// Running (sent, received) sums folded so far this epoch.
    ct_sum: (u64, u64),
    /// Pending count-tree cells keyed by (epoch, round); same flat-vec
    /// rationale as `mt_pending`.
    ct_pending: Vec<CtCell>,

    // Value phase.
    initial_keys: Vec<u64>, // sorted, for origin-side validation
    values_by_slot: Vec<u64>,
    values_received: usize,

    /// Highest termination-detection epoch this node saw as a group root
    /// (see [`Shared::retry_epochs`]).
    max_retry_epoch: u64,

    /// Reused pivot-broadcast buffer (§Scale): the previous level's
    /// `Arc<Vec<u64>>` payload is retained here by the group root; if
    /// every receiver has dropped its clone by the next mint,
    /// `Arc::try_unwrap` reclaims the allocation instead of reallocating
    /// one per level per group. Under optimistic rollback the checkpoint
    /// clone shares the Arc, `try_unwrap` fails, and the mint falls back
    /// to a fresh allocation — same bytes either way, so this is
    /// digest-invisible by construction.
    pivot_pool: Option<Arc<Vec<u64>>>,
}

impl NanoSortNode {
    fn group_tree(&self) -> AggTree {
        AggTree::new(self.shared.group_size(self.level), self.shared.median_incast.max(2))
    }
    fn pos(&self) -> usize {
        self.id - self.shared.group_base(self.id, self.level)
    }
    /// This node's group at the current level, as a contiguous id range
    /// (never materialized as a list — §Scale).
    fn group_range(&self) -> std::ops::Range<NodeId> {
        let base = self.shared.group_base(self.id, self.level);
        base..base + self.shared.group_size(self.level)
    }

    // ----------------------------------------------------------- level entry
    fn enter_level(&mut self, ctx: &mut Ctx<NsMsg>, level: u32) {
        self.level = level;
        self.phase = Phase::PivotTree;
        ctx.set_stage((level as usize).min(MAX_STAGES - 1) as u8);
        ctx.compute(LEVEL_ENTRY_CYCLES);
        // Promote the shuffled-in keys.
        self.keys = std::mem::take(&mut self.next_keys);
        self.origins = std::mem::take(&mut self.next_origins);
        self.sent_this_level = 0;
        self.received_next = 0;
        self.ct_epoch = 0;
        self.ct_round = 0;
        self.ct_sum = (0, 0);
        self.ct_pending.clear();
        self.mt_round = 0;
        self.mt_pending.clear();

        if level == self.shared.depth {
            self.final_sort(ctx);
            return;
        }
        self.step = 2 * level;

        // Sort + PivotSelect (paper step 2a).
        let n = self.keys.len() as u64;
        let temp = if level == 0 { Temp::Cold } else { Temp::Warm };
        ctx.compute(ctx.core().sort_cycles(n, temp));
        self.sort_keys_with_origins();
        ctx.compute(PIVOT_SELECT_CYCLES);
        self.my_pivots = if self.keys.is_empty() {
            Vec::new() // abstain
        } else {
            match self.shared.pivot_mode {
                PivotMode::Paper => {
                    super::pivot::pivot_select(&self.keys, self.shared.buckets, ctx.rng())
                }
                PivotMode::Naive => {
                    super::pivot::naive_select(&self.keys, self.shared.buckets, ctx.rng())
                }
            }
        };
        self.advance_median_tree(ctx);
    }

    fn sort_keys_with_origins(&mut self) {
        // Fused data-plane kernel: sort (key, origin) in one pass instead
        // of argsort-then-permute (which cost an index vector, a permuted
        // copy of the origins, and a second full sort of the keys). Ties
        // keep input order — the backend-independent contract (DESIGN.md
        // §8), so every plane produces the same origin permutation.
        let mut pairs: Vec<(u64, u64)> = self
            .keys
            .iter()
            .copied()
            .zip(self.origins.iter().map(|&o| o as u64))
            .collect();
        self.compute.sort_pairs(&mut pairs);
        for (i, (key, origin)) in pairs.into_iter().enumerate() {
            self.keys[i] = key;
            self.origins[i] = origin as u32;
        }
    }

    // --------------------------------------------------------- median tree
    fn advance_median_tree(&mut self, ctx: &mut Ctx<NsMsg>) {
        let tree = self.group_tree();
        let rounds = tree.rounds();
        let pos = self.pos();
        loop {
            let next = self.mt_round + 1;
            if next > rounds {
                // Root holds the final pivots. The payload buffer comes
                // from the pool when the previous level's broadcast has
                // fully drained (see `pivot_pool`).
                debug_assert_eq!(pos, 0);
                let mut buf = self
                    .pivot_pool
                    .take()
                    .and_then(|a| Arc::try_unwrap(a).ok())
                    .unwrap_or_default();
                buf.clear();
                if self.my_pivots.is_empty() {
                    // Entire group abstained (no keys anywhere): synthesize
                    // even pivots; routing is vacuous.
                    buf.extend(evenly_spaced_pivots(self.shared.buckets));
                } else {
                    buf.extend_from_slice(&self.my_pivots);
                }
                let pivots = Arc::new(buf);
                let gid = self.shared.group_id(self.id, self.level);
                ctx.broadcast_to(
                    gid,
                    self.group_range(),
                    NsMsg::Pivots { level: self.level as u8, pivots: pivots.clone() },
                );
                // Root applies the pivots locally, too.
                self.start_shuffle(ctx, &pivots);
                self.pivot_pool = Some(pivots);
                return;
            }
            if tree.aggregates_at(pos, next) {
                let expect = tree.expected(pos, next);
                let have = self.mt_pending.iter().filter(|(r, _)| *r == next).count();
                if have < expect {
                    return; // wait for this round's children
                }
                // Combine: element-wise median over own + non-abstaining
                // child vectors (paper: median-of-medians per position).
                // §Perf: the rows are borrowed in place — no per-combine
                // clone of the child vectors or of `my_pivots`.
                let my = std::mem::take(&mut self.my_pivots);
                let mut rows: Vec<&[u64]> = Vec::with_capacity(have + 1);
                for (r, pivots) in &self.mt_pending {
                    if *r == next && !pivots.is_empty() {
                        rows.push(pivots.as_slice());
                    }
                }
                if !my.is_empty() {
                    rows.push(&my);
                }
                if rows.is_empty() {
                    self.my_pivots = my; // whole subtree abstained
                } else {
                    ctx.compute(ctx.core().median_combine_cycles(
                        rows.len() as u64,
                        (self.shared.buckets - 1) as u64,
                    ));
                    self.my_pivots = self.compute.median_combine(&rows);
                }
                self.mt_pending.retain(|(r, _)| *r != next);
                self.mt_round = next;
            } else {
                // Leaf/exit: contribute upward once, then wait for Pivots.
                let base = self.shared.group_base(self.id, self.level);
                let parent = base + tree.parent(pos);
                ctx.send(
                    parent,
                    NsMsg::PivotUp {
                        level: self.level as u8,
                        round: next as u8,
                        pivots: SmallWords::from_slice(&self.my_pivots),
                    },
                );
                self.mt_round = rounds + 1;
                return;
            }
        }
    }

    // -------------------------------------------------------------- shuffle
    fn start_shuffle(&mut self, ctx: &mut Ctx<NsMsg>, pivots: &[u64]) {
        self.phase = Phase::Shuffle;
        self.step = 2 * self.level + 1;
        let b = self.shared.buckets;
        let g = self.shared.group_size(self.level);
        let base = self.shared.group_base(self.id, self.level);
        let part = g / b;

        if !self.keys.is_empty() {
            ctx.compute(ctx.core().bucketize_cycles(self.keys.len() as u64, (b - 1) as u64));
            // Fused data-plane kernel: one counting pass + direct scatter
            // into per-bucket buffers replaces the per-key bucketize +
            // caller-side routing loop. The keys are sorted at this point,
            // so bucket-major iteration here IS input order — the RNG draw
            // and send sequences are unchanged.
            let keys = std::mem::take(&mut self.keys);
            let origins = std::mem::take(&mut self.origins);
            let pairs: Vec<(u64, u64)> =
                keys.into_iter().zip(origins.into_iter().map(u64::from)).collect();
            for (bucket, members) in
                self.compute.partition_pairs(&pairs, pivots).into_iter().enumerate()
            {
                for (key, origin) in members {
                    // Uniformly random node within the bucket's partition
                    // (paper §4 step 2c).
                    let dst = base + bucket * part + ctx.rng().index(part);
                    self.sent_this_level += 1;
                    ctx.send(
                        dst,
                        NsMsg::Key { level: self.level as u8, key, origin: origin as u32 },
                    );
                }
            }
        }
        // Open this epoch's running sums with our own (current) counters.
        self.ct_sum = (self.sent_this_level, self.received_next);
        self.ct_round = 0;
        self.advance_count_tree(ctx);
    }

    // ----------------------------------------------- termination detection
    fn advance_count_tree(&mut self, ctx: &mut Ctx<NsMsg>) {
        let tree = self.group_tree();
        let rounds = tree.rounds();
        let pos = self.pos();
        let epoch = self.ct_epoch;
        loop {
            let next = self.ct_round + 1;
            if next > rounds {
                debug_assert_eq!(pos, 0);
                // Root verdict. `sent` is the group's key total, constant
                // across epochs; `received` catches up as deliveries land.
                let complete = self.ct_sum.0 == self.ct_sum.1;
                if complete {
                    // Node-local max (checkpointable program state);
                    // published per node at final-sort entry and folded
                    // at finish, so it stays order-independent.
                    self.max_retry_epoch = self.max_retry_epoch.max(epoch as u64);
                }
                let gid = self.shared.group_id(self.id, self.level);
                ctx.broadcast_to(
                    gid,
                    self.group_range(),
                    NsMsg::Done { level: self.level as u8, epoch, complete },
                );
                self.handle_done(ctx, complete);
                return;
            }
            if tree.aggregates_at(pos, next) {
                let cell = self
                    .ct_pending
                    .iter()
                    .position(|c| c.epoch == epoch && c.round == next);
                let (s, r, cnt) = match cell {
                    Some(i) => {
                        let c = &self.ct_pending[i];
                        (c.sent, c.received, c.got)
                    }
                    None => (0, 0, 0),
                };
                if cnt < tree.expected(pos, next) {
                    return; // wait for this round's children
                }
                ctx.compute(COUNT_FOLD_CYCLES * cnt as u64);
                self.ct_sum.0 += s;
                self.ct_sum.1 += r;
                if let Some(i) = cell {
                    self.ct_pending.swap_remove(i);
                }
                self.ct_round = next;
            } else {
                let base = self.shared.group_base(self.id, self.level);
                let parent = base + tree.parent(pos);
                ctx.send(
                    parent,
                    NsMsg::CountUp {
                        level: self.level as u8,
                        round: next as u8,
                        epoch,
                        sent: self.ct_sum.0,
                        received: self.ct_sum.1,
                    },
                );
                self.ct_round = rounds + 1;
                return;
            }
        }
    }

    fn handle_done(&mut self, ctx: &mut Ctx<NsMsg>, complete: bool) {
        if complete {
            self.enter_level(ctx, self.level + 1);
        } else {
            // Retry with refreshed counts (in-flight keys land over time).
            self.ct_epoch += 1;
            self.ct_round = 0;
            self.ct_sum = (self.sent_this_level, self.received_next);
            self.advance_count_tree(ctx);
        }
    }

    // ------------------------------------------------------------- final
    fn final_sort(&mut self, ctx: &mut Ctx<NsMsg>) {
        self.phase = Phase::Final;
        self.step = 2 * self.shared.depth;
        ctx.set_stage((self.shared.depth as usize).min(MAX_STAGES - 1) as u8);
        let n = self.keys.len() as u64;
        ctx.compute(ctx.core().sort_cycles(n, Temp::Warm));
        self.sort_keys_with_origins();
        self.shared.final_keys.set(self.id, self.keys.clone());
        self.shared.retry_epochs.set(self.id, self.max_retry_epoch);

        if !self.shared.shuffle_values {
            ctx.finish();
            return;
        }
        // GraySort value phase: pull each key's 96 B value from its origin.
        self.values_by_slot = vec![0; self.keys.len()];
        self.values_received = 0;
        if self.keys.is_empty() {
            self.shared.final_values.set(self.id, Vec::new());
            ctx.finish();
            return;
        }
        let reqs: Vec<(u64, u32)> =
            self.keys.iter().copied().zip(self.origins.iter().copied()).collect();
        for (key, origin) in reqs {
            ctx.send(
                origin as NodeId,
                NsMsg::ValueReq {
                    key,
                    requester: self.id as u32,
                    final_step: 2 * self.shared.depth,
                },
            );
        }
    }

    fn handle_value_req(&mut self, ctx: &mut Ctx<NsMsg>, key: u64, requester: u32) {
        // Origin-side sanity: the requested key must be one we pre-loaded.
        debug_assert!(
            self.initial_keys.binary_search(&key).is_ok(),
            "value request for a key node {} never owned",
            self.id
        );
        ctx.compute(VALUE_LOOKUP_CYCLES);
        ctx.send(
            requester as NodeId,
            NsMsg::ValueResp {
                key,
                value: value_of_key(key),
                final_step: 2 * self.shared.depth,
            },
        );
    }

    fn handle_value_resp(&mut self, ctx: &mut Ctx<NsMsg>, key: u64, value: u64) {
        ctx.compute(KEY_APPEND_CYCLES);
        // Skewed distributions produce duplicate keys; duplicates share
        // one deterministic value, so the first response fills the whole
        // equal range and later ones are O(1) no-ops (host-time guard:
        // per-response range fills would be O(R^2) in the duplicate
        // count; a slot already holding `value` means the range is done —
        // and if `value` happens to equal the 0 initializer, skipping
        // still leaves every slot correct).
        let lo = self.keys.partition_point(|&k| k < key);
        if self.values_by_slot[lo] != value {
            let hi = self.keys.partition_point(|&k| k <= key);
            for slot in lo..hi {
                self.values_by_slot[slot] = value;
            }
        }
        self.values_received += 1;
        if self.values_received == self.keys.len() {
            self.shared.final_values.set(self.id, self.values_by_slot.clone());
            ctx.finish();
        }
    }
}

impl Program for NanoSortNode {
    type Msg = NsMsg;

    fn on_start(&mut self, ctx: &mut Ctx<NsMsg>) {
        self.enter_level(ctx, 0);
    }

    fn on_message(&mut self, ctx: &mut Ctx<NsMsg>, _src: NodeId, msg: NsMsg) {
        match msg {
            NsMsg::PivotUp { round, pivots, .. } => {
                self.mt_pending.push((round as u32, pivots));
                self.advance_median_tree(ctx);
            }
            NsMsg::Pivots { pivots, .. } => {
                // Non-root nodes start their shuffle on pivot receipt.
                debug_assert_eq!(self.phase, Phase::PivotTree);
                self.start_shuffle(ctx, &pivots);
            }
            NsMsg::Key { key, origin, .. } => {
                ctx.compute(KEY_APPEND_CYCLES);
                self.next_keys.push(key);
                self.next_origins.push(origin);
                self.received_next += 1;
            }
            NsMsg::CountUp { round, epoch, sent, received, .. } => {
                let round = round as u32;
                let cell = match self
                    .ct_pending
                    .iter_mut()
                    .find(|c| c.epoch == epoch && c.round == round)
                {
                    Some(c) => c,
                    None => {
                        self.ct_pending.push(CtCell {
                            epoch,
                            round,
                            sent: 0,
                            received: 0,
                            got: 0,
                        });
                        self.ct_pending.last_mut().expect("just pushed")
                    }
                };
                cell.sent += sent;
                cell.received += received;
                cell.got += 1;
                // Only advance if we're in this epoch (stale-epoch messages
                // cannot exist by protocol, but be defensive).
                if epoch == self.ct_epoch && self.phase == Phase::Shuffle {
                    self.advance_count_tree(ctx);
                }
            }
            NsMsg::Done { complete, .. } => {
                self.handle_done(ctx, complete);
            }
            NsMsg::ValueReq { key, requester, .. } => {
                self.handle_value_req(ctx, key, requester);
            }
            NsMsg::ValueResp { key, value, .. } => {
                self.handle_value_resp(ctx, key, value);
            }
        }
    }

    fn step(&self) -> u32 {
        self.step
    }
}

fn evenly_spaced_pivots(b: usize) -> Vec<u64> {
    (1..b).map(|i| (u64::MAX / b as u64) * i as u64).collect()
}

/// NanoSort as a [`Workload`]: the scenario supplies fleet size, network,
/// data plane, and seed; these are the paper's §6.2.3 knobs.
#[derive(Debug, Clone)]
pub struct NanoSort {
    /// Keys pre-loaded per core (paper headline: 16).
    pub keys_per_node: usize,
    /// Buckets per recursion level (fleet size must be `buckets^r`).
    pub buckets: usize,
    /// Median-tree (and count-tree) incast.
    pub median_incast: usize,
    /// Run the GraySort value-redistribution phase (§5.2).
    pub shuffle_values: bool,
    /// Pivot-proposal ablation (default: the paper's PivotSelect).
    pub pivot_mode: PivotMode,
}

impl Default for NanoSort {
    fn default() -> Self {
        NanoSort {
            keys_per_node: 16,
            buckets: 16,
            median_incast: 16,
            shuffle_values: false,
            pivot_mode: PivotMode::Paper,
        }
    }
}

impl Workload for NanoSort {
    type Prog = NanoSortNode;

    fn name(&self) -> &'static str {
        "nanosort"
    }

    fn default_nodes(&self) -> usize {
        256
    }

    fn build(&self, env: &ScenarioEnv) -> Result<Built<NanoSortNode>> {
        let depth = depth_of(env.nodes, self.buckets)?;
        let b = self.buckets;

        // Multicast groups: one per (level, group index), level-major.
        let mut group_offsets = Vec::with_capacity(depth as usize);
        let mut off = 0usize;
        for l in 0..depth {
            group_offsets.push(off);
            off += (b as u128).pow(l) as usize;
        }
        let shared = Arc::new(Shared {
            buckets: b,
            depth,
            median_incast: self.median_incast,
            shuffle_values: self.shuffle_values,
            pivot_mode: self.pivot_mode,
            group_offsets,
            final_keys: NodeSlots::new(env.nodes),
            final_values: NodeSlots::new(env.nodes),
            retry_epochs: NodeSlots::new(env.nodes),
        });

        // Pre-load the cluster (paper §5.2: records loaded before the
        // clock). The key values come from the scenario's input
        // distribution; `Uniform` (the default) is the exact GraySort
        // KeyGen path the goldens pin.
        //
        // §Scale: under `env.stream_input` (the hyper tiers) each node's
        // share is drawn from its own derived stream at construction time
        // and folded into an order-independent [`MultisetHash`] — the
        // flat input array never exists on the host. Only per-node-pure
        // distributions stream ([`crate::perturb::KeyDistribution::node_keys`]);
        // global constructions fall back to the materialized path. Key
        // content is identical either way (the materialized path is the
        // concatenation of the same streams), so run digests are
        // byte-identical — pinned by `rust/tests/hyper.rs`.
        let kpn = self.keys_per_node;
        let streamed =
            env.stream_input && env.perturb.dist.node_keys(env.seed, 0, 0).is_some();
        let (per_node, input) = if streamed {
            (None, None)
        } else {
            let per_node =
                env.perturb.dist.partitioned_keys(env.seed, env.nodes * kpn, env.nodes);
            let input: Vec<u64> = per_node.iter().flatten().copied().collect();
            (Some(per_node), Some(input))
        };

        let mut input_summary = MultisetHash::default();
        let programs: Vec<NanoSortNode> = (0..env.nodes)
            .map(|id| {
                let keys = match &per_node {
                    Some(p) => p[id].clone(),
                    None => {
                        let k = env
                            .perturb
                            .dist
                            .node_keys(env.seed, id, kpn)
                            .expect("streamed build requires a per-node distribution");
                        input_summary.add_all(&k);
                        k
                    }
                };
                let mut initial = keys.clone();
                initial.sort_unstable();
                NanoSortNode {
                    id,
                    shared: shared.clone(),
                    compute: env.compute.clone(),
                    level: 0,
                    phase: Phase::PivotTree,
                    step: 0,
                    keys: Vec::new(),
                    origins: Vec::new(),
                    next_origins: vec![id as u32; keys.len()],
                    next_keys: keys,
                    my_pivots: Vec::new(),
                    mt_round: 0,
                    mt_pending: Vec::new(),
                    sent_this_level: 0,
                    received_next: 0,
                    ct_epoch: 0,
                    ct_round: 0,
                    ct_sum: (0, 0),
                    ct_pending: Vec::new(),
                    initial_keys: initial,
                    values_by_slot: Vec::new(),
                    values_received: 0,
                    max_retry_epoch: 0,
                    pivot_pool: None,
                }
            })
            .collect();

        // Registration order must match `Shared::group_id` (level-major).
        // Groups are contiguous id ranges, registered as such — at the
        // paper scale that is 4,369 groups covering 262,144 member slots,
        // which an explicit-list encoding would pay ~2 MB for (§Scale).
        let mut groups: Vec<Group> = Vec::new();
        for l in 0..depth {
            let gsize = shared.group_size(l);
            for gi in 0..env.nodes / gsize {
                let base = gi * gsize;
                groups.push((base..base + gsize).into());
            }
        }

        let shuffle_values = self.shuffle_values;
        let spill_dir = env.spill_dir.clone();
        let finish: Finish = Box::new(move |env, summary| {
            let validation = validate_final_output(
                &shared,
                input.as_deref(),
                streamed.then_some(input_summary),
                shuffle_values,
                spill_dir.as_deref(),
            );
            let skew = crate::graysort::bucket_skew(&validation.node_counts);
            let max_retry_epoch =
                shared.retry_epochs.take_vecs().into_iter().max().unwrap_or(0);
            RunReport::new("nanosort", env, summary, Validation::from_sort(validation))
                .with_metric("skew", MetricValue::F64(skew))
                .with_metric("depth", MetricValue::U64(depth as u64))
                .with_metric("max_retry_epoch", MetricValue::U64(max_retry_epoch))
        });
        Ok(Built { programs, groups, finish })
    }
}

/// Collect and validate the final output, choosing among three routes
/// that all produce identical [`ValidationReport`]s on passing runs:
///
/// - **exact** (materialized input, no spill): the original path — pull
///   every block out of the slots and run the element-wise oracle;
/// - **streamed** (per-node input summary): take blocks out one node at
///   a time, feed the [`StreamingValidator`], drop each before the next —
///   O(block) live memory;
/// - **spill detour** (`--spill` / `NANOSORT_SPILL_DIR`): stream the
///   blocks through the binned [`SpillWriter`] first, then validate from
///   the clustered read-back — the output arrays leave RAM entirely.
///
/// Spill runs at finish time only — after quiescence, so no speculative
/// burst can roll back a block that already hit disk. Spill I/O failure
/// (disk full, unwritable dir) panics: the run's outputs are already
/// consumed from the slots, so there is no clean fallback, and a
/// half-spilled benchmark run should die loudly, not validate partially.
fn validate_final_output(
    shared: &Shared,
    exact_input: Option<&[u64]>,
    input_summary: Option<MultisetHash>,
    shuffle_values: bool,
    spill_dir: Option<&std::path::Path>,
) -> ValidationReport {
    let nodes = shared.final_keys.len();
    // Streaming-validator oracle: from generation time on the streamed
    // path, from one cheap extra pass on the materialized path.
    let summarize = || {
        input_summary.unwrap_or_else(|| {
            let mut s = MultisetHash::default();
            s.add_all(exact_input.expect("one input oracle always exists"));
            s
        })
    };
    if let Some(dir) = spill_dir {
        let mut w =
            SpillWriter::create(dir, DEFAULT_SPILL_BINS).expect("creating spill sink");
        for id in 0..nodes {
            let keys = shared.final_keys.take(id);
            let values =
                if shuffle_values { shared.final_values.take(id) } else { Vec::new() };
            w.push_node(id, &keys, &values).expect("spilling output block");
        }
        let mut r = w.into_reader().expect("opening spill read-back");
        let mut sv = StreamingValidator::new(summarize());
        while let Some(block) = r.next().expect("reading spilled block") {
            sv.push_node(&block.keys, shuffle_values.then_some(block.values.as_slice()));
        }
        return sv.finish();
    }
    match exact_input {
        Some(input) => {
            // Per-node slots merge in canonical order by construction:
            // `take_vecs` is index order, clone-free.
            let final_keys = shared.final_keys.take_vecs();
            let final_values = shared.final_values.take_vecs();
            validate_sorted_output(
                input,
                &final_keys,
                shuffle_values.then_some(final_values.as_slice()),
            )
        }
        None => {
            let mut sv = StreamingValidator::new(summarize());
            for id in 0..nodes {
                let keys = shared.final_keys.take(id);
                let values = shuffle_values.then(|| shared.final_values.take(id));
                sv.push_node(&keys, values.as_deref());
            }
            sv.finish()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graysort::ValidationReport;
    use crate::net::NetConfig;
    use crate::scenario::Scenario;
    use crate::sim::Time;

    /// One seeded run through the Scenario API (the only run path since
    /// the deprecated `run_nanosort` shim was removed).
    struct Cfg {
        nodes: usize,
        workload: NanoSort,
        net: NetConfig,
        seed: u64,
    }

    fn cfg(nodes: usize, kpn: usize, b: usize) -> Cfg {
        Cfg {
            nodes,
            workload: NanoSort {
                keys_per_node: kpn,
                buckets: b,
                median_incast: b,
                ..Default::default()
            },
            net: NetConfig::default(),
            seed: 1,
        }
    }

    fn run(c: &Cfg) -> RunReport {
        Scenario::new(c.workload.clone())
            .nodes(c.nodes)
            .net(c.net.clone())
            .seed(c.seed)
            .run()
            .expect("nanosort scenario")
    }

    fn sort_validation(r: &RunReport) -> &ValidationReport {
        r.validation.sort.as_ref().expect("nanosort reports carry sort validation")
    }

    #[test]
    fn sorts_small_cluster() {
        let r = run(&cfg(16, 16, 16)); // one level
        let v = sort_validation(&r);
        assert!(v.ok(), "{v:?}");
        assert_eq!(v.total_keys, 256);
    }

    #[test]
    fn sorts_two_levels() {
        let r = run(&cfg(256, 16, 16));
        assert!(r.validation.ok(), "{:?}", r.validation);
        assert!(r.runtime() > Time::ZERO);
    }

    #[test]
    fn sorts_with_small_buckets() {
        for (nodes, b) in [(16usize, 4usize), (64, 4), (64, 8), (8, 2)] {
            let r = run(&cfg(nodes, 8, b));
            assert!(r.validation.ok(), "nodes={nodes} b={b}: {:?}", r.validation);
        }
    }

    #[test]
    fn sorts_with_value_phase() {
        let mut c = cfg(64, 8, 8);
        c.workload.shuffle_values = true;
        let r = run(&c);
        let v = sort_validation(&r);
        assert!(v.ok(), "{v:?}");
        assert!(v.values_intact);
    }

    #[test]
    fn sorts_without_multicast() {
        let mut c = cfg(64, 8, 8);
        c.net.multicast = false;
        let r = run(&c);
        assert!(r.validation.ok());
    }

    #[test]
    fn multicast_reduces_sends_and_runtime() {
        let mut with = cfg(256, 16, 16);
        with.net.multicast = true;
        let mut without = cfg(256, 16, 16);
        without.net.multicast = false;
        let a = run(&with);
        let b = run(&without);
        assert!(a.validation.ok() && b.validation.ok());
        assert!(
            a.summary.net.msgs_sent < b.summary.net.msgs_sent,
            "mcast sends {} !< unicast sends {}",
            a.summary.net.msgs_sent,
            b.summary.net.msgs_sent
        );
        assert!(a.runtime() < b.runtime());
    }

    #[test]
    fn median_incast_knob_works() {
        for f in [2usize, 4, 8, 16] {
            let mut c = cfg(256, 16, 16);
            c.workload.median_incast = f;
            let r = run(&c);
            assert!(r.validation.ok(), "incast {f}");
        }
    }

    #[test]
    fn deterministic() {
        let a = run(&cfg(64, 8, 8));
        let b = run(&cfg(64, 8, 8));
        assert_eq!(a.runtime(), b.runtime());
        assert_eq!(a.summary.net.msgs_sent, b.summary.net.msgs_sent);
    }

    #[test]
    fn seeds_change_runtime_but_not_correctness() {
        for seed in [2u64, 3, 4, 5] {
            let mut c = cfg(64, 16, 8);
            c.seed = seed;
            let r = run(&c);
            assert!(r.validation.ok(), "seed {seed}");
        }
    }

    /// Property-style sweep: many random configs all sort correctly.
    #[test]
    fn property_random_configs_all_sort() {
        let mut rng = crate::sim::SplitMix64::new(0xA11);
        for _ in 0..8 {
            let b = [2usize, 4, 8, 16][rng.index(4)];
            let r_depth = 1 + rng.index(2);
            let nodes = b.pow(r_depth as u32);
            let kpn = [4usize, 8, 16, 32][rng.index(4)];
            let mut c = cfg(nodes, kpn, b);
            c.seed = rng.next_u64();
            c.workload.shuffle_values = rng.chance(1, 2);
            let r = run(&c);
            assert!(
                r.validation.ok(),
                "nodes={nodes} b={b} kpn={kpn}: {:?}",
                r.validation
            );
        }
    }

    /// Every input distribution — including the duplicate-heavy ones —
    /// must still produce a correct sort, with the value phase intact
    /// (duplicate keys share one deterministic value).
    #[test]
    fn sorts_under_every_key_distribution() {
        use crate::perturb::KeyDistribution;
        for d in KeyDistribution::ALL {
            let r = Scenario::new(NanoSort {
                keys_per_node: 8,
                buckets: 4,
                median_incast: 4,
                shuffle_values: true,
                ..Default::default()
            })
            .nodes(16)
            .dist(d)
            .seed(11)
            .run()
            .unwrap();
            assert!(r.validation.ok(), "{}: {}", d.name(), r.validation.detail);
            let v = sort_validation(&r);
            assert_eq!(v.total_keys, 128, "{}", d.name());
            assert!(v.values_intact, "{}", d.name());
        }
    }

    #[test]
    fn skew_reported_reasonably() {
        let r = run(&cfg(256, 32, 16));
        let skew = r.metric_f64("skew").expect("nanosort reports skew");
        assert!((1.0..8.0).contains(&skew), "skew = {skew}");
    }

    /// Stress the termination-detection retry path: injecting huge tail
    /// latencies on 20% of messages makes the Done broadcast race ahead
    /// of straggling key deliveries, forcing count-tree retries — the
    /// sort must still be correct.
    #[test]
    fn termination_detection_survives_extreme_tails() {
        let mut c = cfg(256, 16, 16);
        c.net.tail_prob = (20, 100);
        c.net.tail_extra_ns = 20_000;
        c.workload.shuffle_values = true;
        let r = run(&c);
        assert!(r.validation.ok(), "{:?}", r.validation);
        // With 20% of messages delayed 20 µs, at least one group root
        // should have needed a retry epoch.
        let epoch = r.metric_u64("max_retry_epoch").unwrap();
        assert!(epoch >= 1, "expected retries under extreme tails (got epoch {epoch})");
    }

    /// Without tail injection the first count-tree pass may or may not
    /// suffice, but the counter must exist and the run must be clean.
    #[test]
    fn retry_epoch_reported() {
        let r = run(&cfg(64, 8, 8));
        assert!(r.validation.ok());
        assert!(r.metric_u64("max_retry_epoch").unwrap() < 100, "runaway retries");
    }
}
