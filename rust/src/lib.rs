//! # NanoSort — extreme-granularity distributed sorting (paper reproduction)
//!
//! Reproduction of *"From Sand to Flour: The Next Leap in Granular Computing
//! with NanoSort"* (Jepsen, Ibanez, Valiant, McKeown; 2022).
//!
//! The paper sorts 1M keys in 68 µs on 65,536 cycle-simulated nanoPU cores.
//! This crate rebuilds the full stack the paper depends on:
//!
//! - [`sim`] — deterministic discrete-event engine (virtual ns clock)
//!   with pluggable execution backends ([`sim::exec`]): the sequential
//!   reference ([`sim::exec::SeqExecutor`]) and a deterministic sharded
//!   backend ([`sim::exec::ParExecutor`]) that simulates the fleet
//!   across host threads in conservative time windows — byte-identical
//!   results at any thread count (DESIGN.md §7; `--threads` on every
//!   CLI entry point).
//! - [`cpu`] — cycle-calibrated RISC-V Rocket cost model + cache hierarchy.
//! - [`net`] — two-layer full-bisection fabric, reliable multicast, tail
//!   latency injection (the paper's §5.1/§5.3 network).
//! - [`nanopu`] — the nanoPU programming model: register-interface messages,
//!   software reorder buffer, fire-and-forget sends (§5.2).
//! - [`compute`] — node-local data plane: [`compute::RadixCompute`]
//!   (tuner-dispatched radix kernels, the default; DESIGN.md §8 — a
//!   [`compute::Tuner`] picks comparison/LSD/ska/parallel per block,
//!   forceable via `NANOSORT_TUNER`), [`compute::NativeCompute`] (the
//!   pure-Rust differential oracle), and [`compute::XlaCompute`] (the
//!   three-layer path: Pallas → JAX → HLO text → PJRT, loaded by
//!   [`runtime::XlaEngine`]). Selected with `--compute
//!   native|radix|xla`; digests are plane- and tuner-invariant.
//! - [`pool`] — the fixed-budget worker pool shared by the parallel
//!   executors and the parallel compute kernels, so one `--threads N`
//!   budget covers both layers without oversubscribing the host.
//! - [`algo`] — NanoSort (the paper's contribution), MilliSort (the
//!   baseline), MergeMin (the §3.1 design-space probe), set algebra (the
//!   §3.2 nanoTask workload).
//! - [`graysort`] — GraySort 1M benchmark harness + output validation,
//!   including the streaming multiset validator and the disk-spill
//!   output sinks behind the hyper tiers.
//! - [`mem`] — host memory accounting (peak RSS via `VmHWM`, heap
//!   allocation count via the counting global allocator) for the
//!   `BENCH_*.json` perf trajectory and the CI memory ceiling.
//! - [`coordinator`] — CLI argument cursor, data-plane selection, and
//!   figure-style reports.
//! - [`scenario`] — the unified run API: every algorithm is a
//!   [`scenario::Workload`] executed through a [`scenario::Scenario`]
//!   (fleet size, network, core model, data plane, seed, executor
//!   threads) and reported as a [`scenario::RunReport`];
//!   [`scenario::registry`] maps workload names to typed parameter
//!   descriptors for the data-driven CLI.
//! - [`conformance`] — scale tiers (`smoke`/`mid`/`paper` up to the
//!   65,536-core × 1M-key headline, plus the memory-gated
//!   `hyper-smoke`/`hyper` tiers at 2^17 and 2^20 cores with streamed
//!   input), canonical run-report digests,
//!   golden-file regression comparison (`rust/conformance/golden/`), and
//!   `BENCH_*.json` perf-trajectory records. Driven by `repro paper
//!   [--tier T] [--bless]` and the `rust/tests/conformance.rs` CI gate.
//! - [`perturb`] — the perturbation layer: input skew
//!   ([`perturb::KeyDistribution`]: uniform/zipfian/sorted/few-distinct/
//!   adversarial), packet loss with timeout + retransmit, core
//!   oversubscription (spine busy-until registers), and straggler cores —
//!   all default-off and bit-identical when off — plus the deterministic
//!   grid driver behind `repro sweep <workload> --axis <param>=a,b,c`
//!   ([`perturb::sweep`]).
//! - [`service`] — sorting as a service (DESIGN.md §9): deterministic
//!   open Poisson job arrivals over a zipf workload mix, coordinator-level
//!   admission schedulers ([`service::SchedPolicy`]: `fifo`/`sjf`/
//!   `reserve`) placing jobs onto disjoint contiguous ranges of one
//!   shared fabric, per-job node-id namespacing and output validation,
//!   and tail-JCT reporting ([`service::ServiceReport`]: offered vs
//!   achieved load, queueing delay, p50/p95/p99 JCT per size class).
//!   Driven by `repro serve <mix>` with its own conformance digest, and
//!   the `loadsweep` figure.
//! - [`benchfig`] — regenerates every table and figure in the paper's
//!   evaluation (see DESIGN.md §4 for the index), plus `paperscale`
//!   (the simulated headline next to the paper's 68 µs, per tier), the
//!   sweep-driven `skewsweep`/`tailsweep` sensitivity studies, and the
//!   service-layer `loadsweep` (offered load × scheduler).
//!
//! Quickstart: `cargo run --release --example quickstart`.

pub mod algo;
pub mod benchfig;
pub mod compute;
pub mod conformance;
pub mod coordinator;
pub mod cpu;
pub mod graysort;
pub mod mem;
pub mod nanopu;
pub mod net;
pub mod perturb;
pub mod pool;
pub mod runtime;
pub mod scenario;
pub mod service;
pub mod sim;
pub mod stats;

/// Counting allocator (see [`mem`]): BENCH records carry the process
/// allocation count next to peak RSS so reallocation churn regressions
/// are visible in the perf trajectory. One relaxed atomic add per
/// allocation — measurement noise next to the allocation itself.
#[global_allocator]
static GLOBAL_ALLOC: mem::CountingAlloc = mem::CountingAlloc;
