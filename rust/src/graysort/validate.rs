//! Output validation for the distributed sorts: global sortedness,
//! permutation preservation, value integrity, bucket skew (Fig 13), and
//! throughput accounting (Table 2).

use crate::sim::Time;

use super::records::{value_of_key, RECORD_BYTES};

/// Result of validating a distributed sort's output.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    pub total_keys: usize,
    pub globally_sorted: bool,
    pub is_permutation: bool,
    pub values_intact: bool,
    /// Final keys per node (for skew reporting).
    pub node_counts: Vec<usize>,
}

impl ValidationReport {
    pub fn ok(&self) -> bool {
        self.globally_sorted && self.is_permutation && self.values_intact
    }
}

/// Validate the output of a distributed sort.
///
/// `outputs[node]` is the final (locally sorted) key list at each node, in
/// node order; concatenated they must equal the sorted `input` multiset.
/// `values[node]` (same shape) carries the first value word that traveled
/// with each key, or `None` if the run did not shuffle values.
///
/// Generic over the per-node block representation (`Vec<u64>` or a
/// borrowed `&[u64]`), so workload finish hooks can hand in views of
/// their output sinks without cloning every key.
pub fn validate_sorted_output<K: AsRef<[u64]>>(
    input: &[u64],
    outputs: &[K],
    values: Option<&[K]>,
) -> ValidationReport {
    let node_counts: Vec<usize> = outputs.iter().map(|o| o.as_ref().len()).collect();
    let flat: Vec<u64> = outputs.iter().flat_map(|o| o.as_ref().iter().copied()).collect();

    let globally_sorted = flat.windows(2).all(|w| w[0] <= w[1]);

    let mut want = input.to_vec();
    want.sort_unstable();
    let is_permutation = flat.len() == want.len() && {
        let mut got = flat.clone();
        got.sort_unstable();
        got == want
    };

    let values_intact = match values {
        None => true,
        Some(vals) => outputs.iter().zip(vals).all(|(keys, vs)| {
            let (keys, vs) = (keys.as_ref(), vs.as_ref());
            keys.len() == vs.len()
                && keys.iter().zip(vs).all(|(&k, &v)| value_of_key(k) == v)
        }),
    };

    ValidationReport {
        total_keys: flat.len(),
        globally_sorted,
        is_permutation,
        values_intact,
        node_counts,
    }
}

/// Order-independent multiset summary: element count, wrapping sum, and
/// xor of a 64-bit hash of each key. Two key sequences are the same
/// multiset iff (modulo an engineered-collision probability of ~2⁻⁶⁴ per
/// check — far below the simulator's own cosmic-ray floor) their
/// summaries are equal, regardless of order.
///
/// This is the streaming replacement for the materialized permutation
/// check in [`validate_sorted_output`]: the hyper tiers summarize each
/// node's input at generation time and each node's output at read-back,
/// so the full key array never exists on the host. The materialized path
/// remains the exact oracle; `rust/tests/hyper.rs` cross-checks the two
/// at tiers small enough to hold both.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MultisetHash {
    count: u64,
    sum: u64,
    xor: u64,
}

impl MultisetHash {
    /// SplitMix64 finalizer: hashing keys before summing keeps crafted
    /// key sets (e.g. arithmetic progressions) from cancelling in the
    /// sum/xor lanes.
    fn mix(k: u64) -> u64 {
        let mut z = k.wrapping_add(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    pub fn add(&mut self, key: u64) {
        let h = Self::mix(key);
        self.count += 1;
        self.sum = self.sum.wrapping_add(h);
        self.xor ^= h;
    }

    pub fn add_all(&mut self, keys: &[u64]) {
        for &k in keys {
            self.add(k);
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }
}

/// Streaming counterpart of [`validate_sorted_output`]: blocks arrive one
/// node at a time in canonical node order, each is checked and summarized,
/// then dropped — O(nodes) state instead of O(keys).
///
/// On a passing run the resulting [`ValidationReport`] is field-for-field
/// identical to the materialized validator's (both digest-visible paths
/// agree byte-for-byte); on a failing run the same flags trip, only the
/// permutation check is the multiset-hash comparison rather than an
/// element-wise sorted compare.
pub struct StreamingValidator {
    input: MultisetHash,
    output: MultisetHash,
    last: Option<u64>,
    globally_sorted: bool,
    values_intact: bool,
    node_counts: Vec<usize>,
}

impl StreamingValidator {
    /// `input` is the summary of the full input multiset, accumulated at
    /// generation time (per node, in any order).
    pub fn new(input: MultisetHash) -> Self {
        StreamingValidator {
            input,
            output: MultisetHash::default(),
            last: None,
            globally_sorted: true,
            values_intact: true,
            node_counts: Vec::new(),
        }
    }

    /// Feed the next node's final block (canonical node order; sortedness
    /// is checked across node boundaries too). `values` carries the value
    /// words that traveled with the keys, or `None` for key-only runs.
    pub fn push_node(&mut self, keys: &[u64], values: Option<&[u64]>) {
        self.node_counts.push(keys.len());
        for &k in keys {
            if self.last.is_some_and(|prev| prev > k) {
                self.globally_sorted = false;
            }
            self.last = Some(k);
            self.output.add(k);
        }
        match values {
            None => {}
            Some(vs) => {
                self.values_intact &= keys.len() == vs.len()
                    && keys.iter().zip(vs).all(|(&k, &v)| value_of_key(k) == v);
            }
        }
    }

    pub fn finish(self) -> ValidationReport {
        ValidationReport {
            total_keys: self.output.count as usize,
            globally_sorted: self.globally_sorted,
            is_permutation: self.output == self.input,
            values_intact: self.values_intact,
            node_counts: self.node_counts,
        }
    }
}

/// Max/mean skew of final bucket sizes (Fig 13's metric: how unbalanced
/// the final partitions are; 1.0 = perfectly balanced).
///
/// Degenerate inputs are defined as perfectly balanced: an empty node
/// list, a single node, and an all-empty cluster (mean 0) all yield 1.0.
pub fn bucket_skew(node_counts: &[usize]) -> f64 {
    if node_counts.is_empty() {
        return 1.0;
    }
    let max = *node_counts.iter().max().expect("non-empty") as f64;
    let mean = node_counts.iter().sum::<usize>() as f64 / node_counts.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

/// Table 2 throughput accounting.
#[derive(Debug, Clone)]
pub struct Throughput {
    pub records: usize,
    pub cores: usize,
    pub runtime: Time,
}

impl Throughput {
    /// Records per millisecond per core (Table 2's metric).
    pub fn records_per_ms_per_core(&self) -> f64 {
        let ms = self.runtime.as_ns_f64() / 1e6;
        if ms == 0.0 {
            return 0.0;
        }
        self.records as f64 / ms / self.cores as f64
    }

    /// Aggregate sort bandwidth in GB/s (records × 104 B / runtime).
    pub fn gb_per_s(&self) -> f64 {
        let s = self.runtime.as_ns_f64() / 1e9;
        if s == 0.0 {
            return 0.0;
        }
        (self.records as u64 * RECORD_BYTES) as f64 / 1e9 / s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_valid_output() {
        let input = vec![5u64, 3, 9, 1, 7, 2];
        let outputs = vec![vec![1u64, 2], vec![3, 5], vec![7, 9]];
        let values: Vec<Vec<u64>> = outputs
            .iter()
            .map(|ks| ks.iter().map(|&k| value_of_key(k)).collect())
            .collect();
        let r = validate_sorted_output(&input, &outputs, Some(&values));
        assert!(r.ok(), "{r:?}");
        assert_eq!(r.total_keys, 6);
        assert_eq!(r.node_counts, vec![2, 2, 2]);
    }

    #[test]
    fn rejects_unsorted() {
        let input = vec![1u64, 2, 3];
        let outputs = vec![vec![2u64], vec![1], vec![3]];
        let r = validate_sorted_output(&input, &outputs, None);
        assert!(!r.globally_sorted);
        assert!(r.is_permutation);
        assert!(!r.ok());
    }

    #[test]
    fn rejects_lost_keys() {
        let input = vec![1u64, 2, 3];
        let outputs = vec![vec![1u64], vec![2]];
        let r = validate_sorted_output(&input, &outputs, None);
        assert!(!r.is_permutation);
    }

    #[test]
    fn rejects_duplicated_keys() {
        let input = vec![1u64, 2, 3];
        let outputs = vec![vec![1u64, 2], vec![2, 3]];
        let r = validate_sorted_output(&input, &outputs, None);
        assert!(!r.is_permutation);
    }

    #[test]
    fn rejects_corrupt_values() {
        let input = vec![1u64, 2];
        let outputs = vec![vec![1u64, 2]];
        let values = vec![vec![value_of_key(1), value_of_key(2) ^ 1]];
        let r = validate_sorted_output(&input, &outputs, Some(&values));
        assert!(!r.values_intact);
    }

    #[test]
    fn empty_nodes_allowed() {
        let input = vec![4u64, 8];
        let outputs = vec![vec![], vec![4u64, 8], vec![]];
        let r = validate_sorted_output(&input, &outputs, None);
        assert!(r.ok());
    }

    #[test]
    fn skew_metric() {
        assert!((bucket_skew(&[10, 10, 10, 10]) - 1.0).abs() < 1e-12);
        assert!((bucket_skew(&[20, 10, 10, 0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn skew_degenerate_inputs_are_balanced() {
        // Empty node list, single node, and all-empty cluster: 1.0, never
        // NaN/inf/panic.
        assert_eq!(bucket_skew(&[]), 1.0);
        assert_eq!(bucket_skew(&[5]), 1.0);
        assert_eq!(bucket_skew(&[0]), 1.0);
        assert_eq!(bucket_skew(&[0, 0, 0]), 1.0);
    }

    #[test]
    fn validate_empty_input_and_outputs() {
        // Zero-key sort: vacuously sorted and a (trivial) permutation.
        let r = validate_sorted_output(&[], &[vec![], vec![]], None);
        assert!(r.ok(), "{r:?}");
        assert_eq!(r.total_keys, 0);
        assert_eq!(r.node_counts, vec![0, 0]);
        // And with an (empty) value check.
        let vals: Vec<Vec<u64>> = vec![vec![], vec![]];
        let r = validate_sorted_output(&[], &[vec![], vec![]], Some(&vals));
        assert!(r.values_intact);
    }

    /// Drive both validators over the same blocks and require
    /// field-for-field agreement (the streaming path must be
    /// digest-invisible).
    fn cross_check(input: &[u64], outputs: &[Vec<u64>], values: Option<&[Vec<u64>]>) {
        let exact = validate_sorted_output(input, outputs, values);
        let mut summary = MultisetHash::default();
        summary.add_all(input);
        let mut sv = StreamingValidator::new(summary);
        for (i, keys) in outputs.iter().enumerate() {
            sv.push_node(keys, values.map(|vs| vs[i].as_slice()));
        }
        let streamed = sv.finish();
        assert_eq!(streamed.total_keys, exact.total_keys);
        assert_eq!(streamed.globally_sorted, exact.globally_sorted);
        assert_eq!(streamed.is_permutation, exact.is_permutation);
        assert_eq!(streamed.values_intact, exact.values_intact);
        assert_eq!(streamed.node_counts, exact.node_counts);
    }

    #[test]
    fn streaming_validator_matches_exact_oracle() {
        // Clean run, with values.
        let input = vec![5u64, 3, 9, 1, 7, 2];
        let outputs = vec![vec![1u64, 2], vec![3, 5], vec![7, 9]];
        let values: Vec<Vec<u64>> = outputs
            .iter()
            .map(|ks| ks.iter().map(|&k| value_of_key(k)).collect())
            .collect();
        cross_check(&input, &outputs, Some(&values));
        // Unsorted across a node boundary.
        cross_check(&[1u64, 2, 3], &[vec![2u64], vec![1], vec![3]], None);
        // Lost and duplicated keys.
        cross_check(&[1u64, 2, 3], &[vec![1u64], vec![2]], None);
        cross_check(&[1u64, 2, 3], &[vec![1u64, 2], vec![2, 3]], None);
        // Corrupt value word.
        let vals = vec![vec![value_of_key(1), value_of_key(2) ^ 1]];
        cross_check(&[1u64, 2], &[vec![1u64, 2]], Some(&vals));
        // Empty nodes and the zero-key sort.
        cross_check(&[4u64, 8], &[vec![], vec![4u64, 8], vec![]], None);
        cross_check(&[], &[vec![], vec![]], None);
        // Duplicate-heavy multiset (hash lanes must not cancel).
        cross_check(
            &[7u64, 7, 7, 7, 2, 2],
            &[vec![2u64, 2], vec![7, 7, 7, 7]],
            None,
        );
    }

    #[test]
    fn multiset_hash_is_order_independent_but_multiplicity_sensitive() {
        let mut a = MultisetHash::default();
        a.add_all(&[3u64, 1, 2]);
        let mut b = MultisetHash::default();
        b.add_all(&[1u64, 2, 3]);
        assert_eq!(a, b);
        let mut c = MultisetHash::default();
        c.add_all(&[1u64, 2, 3, 3]);
        assert_ne!(a, c, "extra copy must change the summary");
        let mut d = MultisetHash::default();
        d.add_all(&[1u64, 2, 4]);
        assert_ne!(a, d);
        assert_eq!(a.count(), 3);
    }

    #[test]
    fn throughput_table2_shape() {
        // Paper: NanoSort 1M records, 65,536 cores, 68 µs => 224 rec/ms/core.
        let t = Throughput {
            records: 1_000_000,
            cores: 65_536,
            runtime: Time::from_ns(68_000),
        };
        let tput = t.records_per_ms_per_core();
        assert!((200.0..260.0).contains(&tput), "tput = {tput}");
        assert!(t.gb_per_s() > 1000.0); // ~1.5 TB/s aggregate
    }
}
