//! Output validation for the distributed sorts: global sortedness,
//! permutation preservation, value integrity, bucket skew (Fig 13), and
//! throughput accounting (Table 2).

use crate::sim::Time;

use super::records::{value_of_key, RECORD_BYTES};

/// Result of validating a distributed sort's output.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    pub total_keys: usize,
    pub globally_sorted: bool,
    pub is_permutation: bool,
    pub values_intact: bool,
    /// Final keys per node (for skew reporting).
    pub node_counts: Vec<usize>,
}

impl ValidationReport {
    pub fn ok(&self) -> bool {
        self.globally_sorted && self.is_permutation && self.values_intact
    }
}

/// Validate the output of a distributed sort.
///
/// `outputs[node]` is the final (locally sorted) key list at each node, in
/// node order; concatenated they must equal the sorted `input` multiset.
/// `values[node]` (same shape) carries the first value word that traveled
/// with each key, or `None` if the run did not shuffle values.
///
/// Generic over the per-node block representation (`Vec<u64>` or a
/// borrowed `&[u64]`), so workload finish hooks can hand in views of
/// their output sinks without cloning every key.
pub fn validate_sorted_output<K: AsRef<[u64]>>(
    input: &[u64],
    outputs: &[K],
    values: Option<&[K]>,
) -> ValidationReport {
    let node_counts: Vec<usize> = outputs.iter().map(|o| o.as_ref().len()).collect();
    let flat: Vec<u64> = outputs.iter().flat_map(|o| o.as_ref().iter().copied()).collect();

    let globally_sorted = flat.windows(2).all(|w| w[0] <= w[1]);

    let mut want = input.to_vec();
    want.sort_unstable();
    let is_permutation = flat.len() == want.len() && {
        let mut got = flat.clone();
        got.sort_unstable();
        got == want
    };

    let values_intact = match values {
        None => true,
        Some(vals) => outputs.iter().zip(vals).all(|(keys, vs)| {
            let (keys, vs) = (keys.as_ref(), vs.as_ref());
            keys.len() == vs.len()
                && keys.iter().zip(vs).all(|(&k, &v)| value_of_key(k) == v)
        }),
    };

    ValidationReport {
        total_keys: flat.len(),
        globally_sorted,
        is_permutation,
        values_intact,
        node_counts,
    }
}

/// Max/mean skew of final bucket sizes (Fig 13's metric: how unbalanced
/// the final partitions are; 1.0 = perfectly balanced).
///
/// Degenerate inputs are defined as perfectly balanced: an empty node
/// list, a single node, and an all-empty cluster (mean 0) all yield 1.0.
pub fn bucket_skew(node_counts: &[usize]) -> f64 {
    if node_counts.is_empty() {
        return 1.0;
    }
    let max = *node_counts.iter().max().expect("non-empty") as f64;
    let mean = node_counts.iter().sum::<usize>() as f64 / node_counts.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

/// Table 2 throughput accounting.
#[derive(Debug, Clone)]
pub struct Throughput {
    pub records: usize,
    pub cores: usize,
    pub runtime: Time,
}

impl Throughput {
    /// Records per millisecond per core (Table 2's metric).
    pub fn records_per_ms_per_core(&self) -> f64 {
        let ms = self.runtime.as_ns_f64() / 1e6;
        if ms == 0.0 {
            return 0.0;
        }
        self.records as f64 / ms / self.cores as f64
    }

    /// Aggregate sort bandwidth in GB/s (records × 104 B / runtime).
    pub fn gb_per_s(&self) -> f64 {
        let s = self.runtime.as_ns_f64() / 1e9;
        if s == 0.0 {
            return 0.0;
        }
        (self.records as u64 * RECORD_BYTES) as f64 / 1e9 / s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_valid_output() {
        let input = vec![5u64, 3, 9, 1, 7, 2];
        let outputs = vec![vec![1u64, 2], vec![3, 5], vec![7, 9]];
        let values: Vec<Vec<u64>> = outputs
            .iter()
            .map(|ks| ks.iter().map(|&k| value_of_key(k)).collect())
            .collect();
        let r = validate_sorted_output(&input, &outputs, Some(&values));
        assert!(r.ok(), "{r:?}");
        assert_eq!(r.total_keys, 6);
        assert_eq!(r.node_counts, vec![2, 2, 2]);
    }

    #[test]
    fn rejects_unsorted() {
        let input = vec![1u64, 2, 3];
        let outputs = vec![vec![2u64], vec![1], vec![3]];
        let r = validate_sorted_output(&input, &outputs, None);
        assert!(!r.globally_sorted);
        assert!(r.is_permutation);
        assert!(!r.ok());
    }

    #[test]
    fn rejects_lost_keys() {
        let input = vec![1u64, 2, 3];
        let outputs = vec![vec![1u64], vec![2]];
        let r = validate_sorted_output(&input, &outputs, None);
        assert!(!r.is_permutation);
    }

    #[test]
    fn rejects_duplicated_keys() {
        let input = vec![1u64, 2, 3];
        let outputs = vec![vec![1u64, 2], vec![2, 3]];
        let r = validate_sorted_output(&input, &outputs, None);
        assert!(!r.is_permutation);
    }

    #[test]
    fn rejects_corrupt_values() {
        let input = vec![1u64, 2];
        let outputs = vec![vec![1u64, 2]];
        let values = vec![vec![value_of_key(1), value_of_key(2) ^ 1]];
        let r = validate_sorted_output(&input, &outputs, Some(&values));
        assert!(!r.values_intact);
    }

    #[test]
    fn empty_nodes_allowed() {
        let input = vec![4u64, 8];
        let outputs = vec![vec![], vec![4u64, 8], vec![]];
        let r = validate_sorted_output(&input, &outputs, None);
        assert!(r.ok());
    }

    #[test]
    fn skew_metric() {
        assert!((bucket_skew(&[10, 10, 10, 10]) - 1.0).abs() < 1e-12);
        assert!((bucket_skew(&[20, 10, 10, 0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn skew_degenerate_inputs_are_balanced() {
        // Empty node list, single node, and all-empty cluster: 1.0, never
        // NaN/inf/panic.
        assert_eq!(bucket_skew(&[]), 1.0);
        assert_eq!(bucket_skew(&[5]), 1.0);
        assert_eq!(bucket_skew(&[0]), 1.0);
        assert_eq!(bucket_skew(&[0, 0, 0]), 1.0);
    }

    #[test]
    fn validate_empty_input_and_outputs() {
        // Zero-key sort: vacuously sorted and a (trivial) permutation.
        let r = validate_sorted_output(&[], &[vec![], vec![]], None);
        assert!(r.ok(), "{r:?}");
        assert_eq!(r.total_keys, 0);
        assert_eq!(r.node_counts, vec![0, 0]);
        // And with an (empty) value check.
        let vals: Vec<Vec<u64>> = vec![vec![], vec![]];
        let r = validate_sorted_output(&[], &[vec![], vec![]], Some(&vals));
        assert!(r.values_intact);
    }

    #[test]
    fn throughput_table2_shape() {
        // Paper: NanoSort 1M records, 65,536 cores, 68 µs => 224 rec/ms/core.
        let t = Throughput {
            records: 1_000_000,
            cores: 65_536,
            runtime: Time::from_ns(68_000),
        };
        let tput = t.records_per_ms_per_core();
        assert!((200.0..260.0).contains(&tput), "tput = {tput}");
        assert!(t.gb_per_s() > 1000.0); // ~1.5 TB/s aggregate
    }
}
