//! GraySort 1M benchmark harness (paper §5.2 "Sort benchmark").
//!
//! The benchmark sorts fixed-size records; the paper deviates slightly from
//! the 100 B GraySort spec and uses 104 B records — an 8 B key plus a 96 B
//! value — so everything is 8-byte aligned for RISC-V. We model the same:
//! keys are distinct `u64 < u64::MAX`, values are a deterministic 96 B
//! function of the key (so value integrity can be validated without
//! storing 96 MB of payload). The cluster is pre-loaded before the clock
//! starts, exactly like MilliSort's setup.

mod records;
mod spill;
mod validate;

pub use records::{value_of_key, KeyGen, Record, KEY_BYTES, RECORD_BYTES, VALUE_BYTES};
pub use spill::{
    take_bytes_spilled, SpillBlock, SpillReader, SpillWriter, DEFAULT_SPILL_BINS,
};
pub use validate::{
    bucket_skew, validate_sorted_output, MultisetHash, StreamingValidator, Throughput,
    ValidationReport,
};
