//! Synthetic GraySort records: distinct u64 keys + derived 96 B values.

use crate::sim::SplitMix64;

/// Bytes per key (paper: 8, deviating from the 10 B GraySort spec for
/// RISC-V alignment).
pub const KEY_BYTES: u64 = 8;
/// Bytes per value.
pub const VALUE_BYTES: u64 = 96;
/// Bytes per record (104 in the paper).
pub const RECORD_BYTES: u64 = KEY_BYTES + VALUE_BYTES;

/// One sorting record. The value is never materialized in bulk — it is a
/// pure function of the key ([`value_of_key`]) so validation can check
/// value integrity at the destination without 96 B × 1M of state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Record {
    pub key: u64,
    /// Core that held this record before the sort (travels with the key
    /// during the shuffle, paper §5.2).
    pub origin: u32,
}

/// First 8 bytes of the deterministic 96 B value of `key` (the remaining
/// 88 bytes are defined as further SplitMix64 outputs; one word is enough
/// to detect corruption).
pub fn value_of_key(key: u64) -> u64 {
    SplitMix64::new(key ^ 0x9604_5375_0937_0a93u64.rotate_left(9)).next_u64()
}

/// Generator of distinct random keys, pre-partitioned across cores.
pub struct KeyGen {
    rng: SplitMix64,
}

impl KeyGen {
    pub fn new(seed: u64) -> Self {
        KeyGen { rng: SplitMix64::new(seed ^ 0x6772_6179_736f_7274) }
    }

    /// `total` distinct keys split evenly across `cores` (total must be a
    /// multiple of cores — the paper pre-loads an equal share per core).
    pub fn generate(&mut self, total: usize, cores: usize) -> Vec<Vec<u64>> {
        assert!(total % cores == 0, "keys must divide evenly across cores");
        let keys = self.distinct_keys(total);
        let per = total / cores;
        keys.chunks(per).map(|c| c.to_vec()).collect()
    }

    /// `n` distinct keys, all `< u64::MAX` (padding-sentinel safe).
    pub fn distinct_keys(&mut self, n: usize) -> Vec<u64> {
        let mut keys = Vec::with_capacity(n);
        let mut seen = std::collections::HashSet::with_capacity(n * 2);
        while keys.len() < n {
            let k = self.rng.next_u64();
            if k != u64::MAX && seen.insert(k) {
                keys.push(k);
            }
        }
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_distinct_and_partitioned() {
        let mut kg = KeyGen::new(1);
        let parts = kg.generate(1024, 64);
        assert_eq!(parts.len(), 64);
        assert!(parts.iter().all(|p| p.len() == 16));
        let mut all: Vec<u64> = parts.iter().flatten().copied().collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "keys must be distinct");
        assert!(all.iter().all(|&k| k < u64::MAX));
    }

    #[test]
    fn generation_is_deterministic() {
        let a = KeyGen::new(7).generate(256, 16);
        let b = KeyGen::new(7).generate(256, 16);
        assert_eq!(a, b);
        let c = KeyGen::new(8).generate(256, 16);
        assert_ne!(a, c);
    }

    #[test]
    fn value_function_is_stable_and_spread() {
        assert_eq!(value_of_key(42), value_of_key(42));
        assert_ne!(value_of_key(42), value_of_key(43));
        // Spot-check spread: 1000 keys -> 1000 distinct values.
        let mut vals: Vec<u64> = (0..1000).map(value_of_key).collect();
        vals.sort_unstable();
        vals.dedup();
        assert_eq!(vals.len(), 1000);
    }

    #[test]
    #[should_panic(expected = "divide evenly")]
    fn uneven_partition_panics() {
        KeyGen::new(1).generate(100, 64);
    }
}
