//! Synthetic GraySort records: distinct u64 keys + derived 96 B values.

use crate::sim::SplitMix64;

/// Bytes per key (paper: 8, deviating from the 10 B GraySort spec for
/// RISC-V alignment).
pub const KEY_BYTES: u64 = 8;
/// Bytes per value.
pub const VALUE_BYTES: u64 = 96;
/// Bytes per record (104 in the paper).
pub const RECORD_BYTES: u64 = KEY_BYTES + VALUE_BYTES;

/// One sorting record. The value is never materialized in bulk — it is a
/// pure function of the key ([`value_of_key`]) so validation can check
/// value integrity at the destination without 96 B × 1M of state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Record {
    pub key: u64,
    /// Core that held this record before the sort (travels with the key
    /// during the shuffle, paper §5.2).
    pub origin: u32,
}

/// First 8 bytes of the deterministic 96 B value of `key` (the remaining
/// 88 bytes are defined as further SplitMix64 outputs; one word is enough
/// to detect corruption).
pub fn value_of_key(key: u64) -> u64 {
    SplitMix64::new(key ^ 0x9604_5375_0937_0a93u64.rotate_left(9)).next_u64()
}

/// Generator of random keys, pre-partitioned across cores.
///
/// # Per-node streams (§Scale)
///
/// Node `i`'s share is a pure function of `(seed, i, per)` through
/// [`SplitMix64::derive`] — [`KeyGen::node_keys`] is the unit of
/// generation, and [`KeyGen::generate`] is just its concatenation over
/// the fleet. This is what lets the hyper tiers build each node's input
/// at program-construction time and never hold the full key array on the
/// host: the streamed and materialized paths are byte-identical by
/// construction (pinned by the digest-identity tests in
/// `rust/tests/hyper.rs`).
///
/// Distinctness is **per node**, not global: each node dedups within its
/// own stream. A cross-node collision needs two of `n` uniform u64 draws
/// to land on one value (~n²/2⁶⁵ — about 3×10⁻⁸ even at 10⁹ keys), and
/// is harmless anyway: the sort and its multiset permutation check are
/// duplicate-correct, only the "distinct" flavor text weakens.
pub struct KeyGen {
    rng: SplitMix64,
}

impl KeyGen {
    pub fn new(seed: u64) -> Self {
        KeyGen { rng: SplitMix64::new(seed ^ 0x6772_6179_736f_7274) }
    }

    /// `total` keys split evenly across `cores` (total must be a multiple
    /// of cores — the paper pre-loads an equal share per core). Defined
    /// as the concatenation of every core's [`KeyGen::node_keys`] stream.
    pub fn generate(&mut self, total: usize, cores: usize) -> Vec<Vec<u64>> {
        assert!(total % cores == 0, "keys must divide evenly across cores");
        let per = total / cores;
        (0..cores).map(|node| self.node_keys(node, per)).collect()
    }

    /// Node `node`'s `per` keys — the streamed unit of generation. Keys
    /// are node-locally distinct and `< u64::MAX` (padding-sentinel
    /// safe). Pure in `(seed, node, per)`: calling this for one node
    /// neither touches nor depends on any other node's stream.
    pub fn node_keys(&self, node: usize, per: usize) -> Vec<u64> {
        let mut rng = self.rng.derive(node as u64);
        let mut keys = Vec::with_capacity(per);
        let mut seen = std::collections::HashSet::with_capacity(per * 2);
        while keys.len() < per {
            let k = rng.next_u64();
            if k != u64::MAX && seen.insert(k) {
                keys.push(k);
            }
        }
        keys
    }

    /// `n` distinct keys from the generator's own (non-derived) stream,
    /// all `< u64::MAX`. The skewed perturbation distributions build on
    /// this global path; the uniform/default path is per-node.
    pub fn distinct_keys(&mut self, n: usize) -> Vec<u64> {
        let mut keys = Vec::with_capacity(n);
        let mut seen = std::collections::HashSet::with_capacity(n * 2);
        while keys.len() < n {
            let k = self.rng.next_u64();
            if k != u64::MAX && seen.insert(k) {
                keys.push(k);
            }
        }
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_node_distinct_and_partitioned() {
        let mut kg = KeyGen::new(1);
        let parts = kg.generate(1024, 64);
        assert_eq!(parts.len(), 64);
        assert!(parts.iter().all(|p| p.len() == 16));
        for p in &parts {
            let mut node = p.clone();
            let n = node.len();
            node.sort_unstable();
            node.dedup();
            assert_eq!(node.len(), n, "keys must be distinct within a node");
            assert!(node.iter().all(|&k| k < u64::MAX));
        }
    }

    /// The streamed contract: `generate` is exactly the concatenation of
    /// per-node streams, and each stream is pure in `(seed, node, per)` —
    /// generating node 37 alone yields the same keys as generating the
    /// whole fleet and slicing.
    #[test]
    fn node_streams_match_materialized_partitions() {
        let parts = KeyGen::new(9).generate(1024, 64);
        let kg = KeyGen::new(9);
        for (node, part) in parts.iter().enumerate() {
            assert_eq!(&kg.node_keys(node, 16), part, "node {node} stream drifted");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = KeyGen::new(7).generate(256, 16);
        let b = KeyGen::new(7).generate(256, 16);
        assert_eq!(a, b);
        let c = KeyGen::new(8).generate(256, 16);
        assert_ne!(a, c);
    }

    #[test]
    fn value_function_is_stable_and_spread() {
        assert_eq!(value_of_key(42), value_of_key(42));
        assert_ne!(value_of_key(42), value_of_key(43));
        // Spot-check spread: 1000 keys -> 1000 distinct values.
        let mut vals: Vec<u64> = (0..1000).map(value_of_key).collect();
        vals.sort_unstable();
        vals.dedup();
        assert_eq!(vals.len(), 1000);
    }

    #[test]
    #[should_panic(expected = "divide evenly")]
    fn uneven_partition_panics() {
        KeyGen::new(1).generate(100, 64);
    }
}
