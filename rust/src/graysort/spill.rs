//! Disk-spill output sinks for the hyper tiers (GraySort style).
//!
//! At 2^20 nodes × 96 keys the final output alone is ~800 MB of u64 —
//! holding every node's sorted block in RAM until validation defeats the
//! point of streaming the input. This module spills cold per-node output
//! blocks to disk the way external sorts bin their runs:
//!
//! - [`SpillWriter`] hashes each node into one of `bins` shard files
//!   round-robin (`bin = node % bins`), appending a small framed segment
//!   per node. Round-robin binning means every bin file holds nodes in
//!   ascending node order — no index, no sort on read-back.
//! - [`SpillReader`] walks the bins with one buffered cursor each,
//!   yielding segments **clustered back into canonical node order** by
//!   strict round-robin rotation over the cursors. Validation therefore
//!   streams the spilled output exactly as it would have streamed the
//!   in-memory slots — same order, same blocks, same digest.
//!
//! Spill is digest-invisible by contract: every byte written is read back
//! verbatim, the clustered iterator visits nodes in the same canonical
//! order as [`crate::scenario::NodeSlots::take_each`], and nothing about
//! the simulated run (event order, metrics, validation flags) depends on
//! whether blocks detoured through disk. `bytes_spilled` is reported via
//! a process-wide side channel ([`take_bytes_spilled`]) precisely so the
//! figure never enters a `RunReport` — reports stay byte-identical with
//! spill on or off.
//!
//! Writes happen only from workload finish paths (after quiescence) or
//! from FINISH-stage node handlers — never from inside a speculative
//! burst, which could be rolled back and leave a duplicate segment.
//!
//! # Segment framing
//!
//! Little-endian, self-delimiting, append-only:
//!
//! ```text
//! [node: u64][klen: u64][vlen: u64][keys: klen × u64][values: vlen × u64]
//! ```
//!
//! Empty blocks are written too (klen = vlen = 0) so the reader can rely
//! on every node appearing exactly once in its bin.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

/// Process-wide spill byte counter. A static side channel rather than a
/// `RunReport` metric: reports are digest material and must not change
/// when spill is toggled, but BENCH records (wall-clock territory) want
/// the figure. Monotone within a run; [`take_bytes_spilled`] drains it.
static BYTES_SPILLED: AtomicU64 = AtomicU64::new(0);

/// Drain and return the bytes spilled since the last call (0 when spill
/// never ran). The CLI calls this once per run for the BENCH record.
pub fn take_bytes_spilled() -> u64 {
    BYTES_SPILLED.swap(0, Ordering::Relaxed)
}

/// Default shard-file count: enough that each bin stays a sequential
/// append stream of reasonable size, few enough that read-back holds one
/// buffered cursor per bin without pressure.
pub const DEFAULT_SPILL_BINS: usize = 16;

/// Round-robin binned writer: node `i`'s block is appended to shard file
/// `i % bins`. Blocks MUST arrive in ascending node order (the canonical
/// finish order) — that is what makes each bin internally ordered and
/// the clustered read-back a zero-index merge.
pub struct SpillWriter {
    dir: PathBuf,
    bins: Vec<BufWriter<File>>,
    next_node: usize,
}

impl SpillWriter {
    /// Create `bins` empty shard files under `dir` (created if absent;
    /// pre-existing shard files are truncated — a spill dir is scratch).
    pub fn create(dir: impl AsRef<Path>, bins: usize) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        anyhow::ensure!(bins > 0, "spill needs at least one bin");
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating spill dir {}", dir.display()))?;
        let bins = (0..bins)
            .map(|b| {
                let path = bin_path(&dir, b);
                File::create(&path)
                    .map(BufWriter::new)
                    .with_context(|| format!("creating spill bin {}", path.display()))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(SpillWriter { dir, bins, next_node: 0 })
    }

    /// Append node `node`'s block. Nodes must be pushed exactly once, in
    /// ascending order starting at 0; `values` may be empty for key-only
    /// runs (it is framed as vlen = 0 either way).
    pub fn push_node(&mut self, node: usize, keys: &[u64], values: &[u64]) -> Result<()> {
        anyhow::ensure!(
            node == self.next_node,
            "spill blocks must arrive in canonical node order (got {node}, want {})",
            self.next_node
        );
        self.next_node += 1;
        let w = &mut self.bins[node % self.bins.len()];
        let mut bytes = 0u64;
        for word in [node as u64, keys.len() as u64, values.len() as u64] {
            w.write_all(&word.to_le_bytes())?;
            bytes += 8;
        }
        for &k in keys {
            w.write_all(&k.to_le_bytes())?;
            bytes += 8;
        }
        for &v in values {
            w.write_all(&v.to_le_bytes())?;
            bytes += 8;
        }
        BYTES_SPILLED.fetch_add(bytes, Ordering::Relaxed);
        Ok(())
    }

    /// Flush every bin and hand back a clustered reader over the same
    /// directory. `nodes` written so far is carried over so the reader
    /// knows when the rotation is exhausted.
    pub fn into_reader(mut self) -> Result<SpillReader> {
        let bins = self.bins.len();
        for w in &mut self.bins {
            w.flush().context("flushing spill bin")?;
        }
        let nodes = self.next_node;
        let dir = self.dir;
        drop(self.bins);
        SpillReader::open(&dir, bins, nodes)
    }
}

/// One decoded spill segment: the node id and its key/value blocks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpillBlock {
    pub node: usize,
    pub keys: Vec<u64>,
    pub values: Vec<u64>,
}

/// Clustered read-back: strict round-robin over the bin cursors yields
/// nodes 0, 1, 2, … in canonical order, each bin read strictly forward
/// (sequential I/O, no seeks, one buffer per bin).
pub struct SpillReader {
    bins: Vec<BufReader<File>>,
    nodes: usize,
    next_node: usize,
}

impl SpillReader {
    /// Open the `bins` shard files under `dir` holding `nodes` segments
    /// total (what the paired writer pushed).
    pub fn open(dir: impl AsRef<Path>, bins: usize, nodes: usize) -> Result<Self> {
        let dir = dir.as_ref();
        anyhow::ensure!(bins > 0, "spill needs at least one bin");
        let bins = (0..bins)
            .map(|b| {
                let path = bin_path(dir, b);
                File::open(&path)
                    .map(BufReader::new)
                    .with_context(|| format!("opening spill bin {}", path.display()))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(SpillReader { bins, nodes, next_node: 0 })
    }

    /// Next node's block in canonical order, `None` after the last.
    #[allow(clippy::should_implement_trait)] // fallible iteration, anyhow-flavored
    pub fn next(&mut self) -> Result<Option<SpillBlock>> {
        if self.next_node >= self.nodes {
            return Ok(None);
        }
        let want = self.next_node;
        self.next_node += 1;
        let n = self.bins.len();
        let r = &mut self.bins[want % n];
        let node = read_u64(r)? as usize;
        if node != want {
            bail!("spill bin out of order: read node {node}, expected {want}");
        }
        let klen = read_u64(r)? as usize;
        let vlen = read_u64(r)? as usize;
        let mut keys = Vec::with_capacity(klen);
        for _ in 0..klen {
            keys.push(read_u64(r)?);
        }
        let mut values = Vec::with_capacity(vlen);
        for _ in 0..vlen {
            values.push(read_u64(r)?);
        }
        Ok(Some(SpillBlock { node: want, keys, values }))
    }
}

fn bin_path(dir: &Path, bin: usize) -> PathBuf {
    dir.join(format!("spill_bin_{bin:04}.dat"))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf).context("truncated spill segment")?;
    Ok(u64::from_le_bytes(buf))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("nanosort_spill_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn round_trip(tag: &str, blocks: &[(Vec<u64>, Vec<u64>)], bins: usize) {
        let dir = scratch(tag);
        let mut w = SpillWriter::create(&dir, bins).unwrap();
        for (node, (keys, values)) in blocks.iter().enumerate() {
            w.push_node(node, keys, values).unwrap();
        }
        let mut r = w.into_reader().unwrap();
        for (node, (keys, values)) in blocks.iter().enumerate() {
            let b = r.next().unwrap().expect("segment present");
            assert_eq!(b.node, node);
            assert_eq!(&b.keys, keys, "node {node} keys");
            assert_eq!(&b.values, values, "node {node} values");
        }
        assert!(r.next().unwrap().is_none(), "reader exhausted");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn round_trips_typical_blocks() {
        let blocks: Vec<(Vec<u64>, Vec<u64>)> = (0..37)
            .map(|i| {
                let keys: Vec<u64> = (0..(i % 5 + 1) as u64).map(|k| k * 31 + i as u64).collect();
                let values: Vec<u64> = keys.iter().map(|&k| k ^ 0xabcd).collect();
                (keys, values)
            })
            .collect();
        // More bins than nodes, fewer bins than nodes, one bin.
        round_trip("typical_many", &blocks, 64);
        round_trip("typical_few", &blocks, 4);
        round_trip("typical_one", &blocks, 1);
    }

    #[test]
    fn round_trips_empty_run() {
        round_trip("empty", &[], DEFAULT_SPILL_BINS);
    }

    #[test]
    fn round_trips_single_node_and_empty_blocks() {
        round_trip("single", &[(vec![42u64, 43], vec![])], 3);
        // Interleaved empty blocks: every node still appears once.
        round_trip(
            "holes",
            &[(vec![], vec![]), (vec![7u64], vec![9u64]), (vec![], vec![])],
            2,
        );
    }

    #[test]
    fn round_trips_duplicate_heavy_blocks() {
        let hot = vec![0xdead_beefu64; 97];
        round_trip(
            "dups",
            &[(hot.clone(), vec![]), (hot.clone(), vec![]), (hot, vec![])],
            2,
        );
    }

    #[test]
    fn out_of_order_writes_are_rejected() {
        let dir = scratch("order");
        let mut w = SpillWriter::create(&dir, 2).unwrap();
        w.push_node(0, &[1], &[]).unwrap();
        assert!(w.push_node(2, &[1], &[]).is_err(), "skipping a node must fail");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bytes_spilled_side_channel_counts_frames() {
        let dir = scratch("bytes");
        let _ = take_bytes_spilled(); // drain whatever ran before
        let mut w = SpillWriter::create(&dir, 1).unwrap();
        w.push_node(0, &[1, 2, 3], &[4, 5]).unwrap();
        // 3 header words + 3 keys + 2 values = 8 × 8 bytes. The counter
        // is process-global and sibling spill tests run in parallel, so
        // assert a floor, not equality.
        assert!(take_bytes_spilled() >= 64, "frame bytes not accounted");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
