//! Deterministic discrete-event simulation engine.
//!
//! Replaces the paper's FireSim/Verilator cycle-exact RTL simulation
//! (DESIGN.md §1, hardware substitution). [`engine::Engine`] drives node
//! programs ([`crate::nanopu::Program`]) over the network fabric
//! ([`crate::net::Fabric`]) with per-node busy/idle accounting on an exact
//! integer time grid ([`Time`]).

mod engine;
mod rng;
mod time;

pub use engine::{Engine, NodeStats, RunSummary, MAX_STAGES};
pub use rng::SplitMix64;
pub use time::{Time, CLOCK_HZ, UNITS_PER_CYCLE, UNITS_PER_NS};
