//! Deterministic discrete-event simulation engine.
//!
//! Replaces the paper's FireSim/Verilator cycle-exact RTL simulation
//! (DESIGN.md §1, hardware substitution). [`Engine`] configures
//! node programs ([`crate::nanopu::Program`]) over the network fabric
//! ([`crate::net::Fabric`]) with per-node busy/idle accounting on an exact
//! integer time grid ([`Time`]); the event loop itself is a pluggable
//! [`exec::Executor`] backend — sequential ([`exec::SeqExecutor`]),
//! deterministic sharded across host threads ([`exec::ParExecutor`]), or
//! optimistic with speculative rollback ([`exec::OptExecutor`]) — all
//! byte-identical by construction (DESIGN.md §7, §10).

mod engine;
pub mod exec;
mod rng;
mod time;

pub use engine::Engine;
pub use exec::{ExecKind, ExecProfile, NodeStats, RunSummary, MAX_STAGES};
pub use rng::SplitMix64;
pub use time::{Time, CLOCK_HZ, UNITS_PER_CYCLE, UNITS_PER_NS};
