//! The optimistic (Time-Warp-style) backend: shards advance through the
//! same topology-aware conservative windows as `exec::par`, then
//! **speculate past the horizon** — optimistically processing events the
//! conservative bound cannot yet prove safe — and roll back when a
//! straggler transit proves the speculation wrong.
//!
//! # Protocol (one barrier round per shard)
//!
//! 1. Swap the inbox. If a burst is pending and any inbound transit keys
//!    **before** the deepest speculated event — a straggler — roll the
//!    burst back (the test-only forced hook rolls back here too).
//! 2. Publish the shard's event minimum: the pending burst's *first*
//!    event time while speculation is in flight (the most conservative
//!    claim — the rest of the fleet never trusts uncommitted work), the
//!    queue minimum otherwise. Barrier.
//! 3. Compute the adaptive horizon exactly as `exec::par` (per-pair
//!    [`BoundMatrix`] closure). All shards idle → done.
//! 4. Resolve the pending burst: **commit** iff the deepest speculated
//!    event now lies strictly below the horizon — every transit any
//!    other shard can still produce keys after it, and step 1 already
//!    cleared the in-flight ones. On commit the burst's buffered
//!    emissions are released (own-shard sends into the local queue,
//!    cross-shard sends into this round's outboxes); otherwise roll
//!    back — a partially covered burst retries conservatively instead of
//!    waiting, so speculation can never livelock the fleet.
//! 5. Queue the inbound transits (after resolution, so a rollback's
//!    cursor rewind happens first) and drain the conservative window —
//!    identical to `exec::par`, and automatically the in-order
//!    re-execution of anything a rollback undid: the rolled-back pops
//!    were re-pushed, their emissions were never visible, so there are
//!    no duplicates and no anti-messages (DESIGN.md §10).
//! 6. Speculate: open an undo-journaled burst ([`SpecLog`]) and pop past
//!    the conservative bound up to `batch` extra minimum-latency windows,
//!    capped by the queue's rewind fence. *Every* emission is buffered —
//!    cross-shard sends stay invisible (rollback stays shard-local), and
//!    an own-shard emission tightens the live burst bound to its arrival
//!    (it is buffered too, so popping past it would jump the canonical
//!    order). Flush, barrier.
//!
//! # Why rollback cannot be observed
//!
//! A burst mutates only shard-local state (per-node backups + wholesale
//! fabric-register/counter snapshots restore it exactly), publishes only
//! its *first* event time (valid whether or not it commits), and emits
//! nothing. Commit releases buffered sends whose arrivals all lie at or
//! beyond `first + W[this][dst]` — at or beyond every receiver's current
//! bound, so a released send can at worst trigger the receiver's *own*
//! straggler rollback, never corrupt committed work. Digests are
//! therefore byte-identical to the sequential backend; `rust/tests/
//! exec_fuzz.rs` fuzzes this and the forced-rollback hook pins it.

use std::cell::Cell;
use std::sync::atomic::Ordering;

use crate::nanopu::Program;
use crate::net::Fabric;

use super::core::{
    merge_shards, ExecProfile, RunSummary, Shard, SharedCtx, SpecLog, Transit,
};
use super::par::{
    carve_shards, flush, resolve_window_batch, shard_of, shard_ranges, BoundMatrix,
    WindowSync,
};
use super::seq::run_seq;
use super::EngineParts;
use crate::sim::Time;

/// One in-flight speculative burst, between the round that ran it and the
/// round that resolves it.
struct PendingBurst<M> {
    /// Canonical key of the deepest speculated event.
    last_key: (Time, u32, u64),
    /// Time of the first speculated event (the published minimum).
    first_at: Time,
    /// Buffered own-shard emissions, released into the queue on commit.
    local: Vec<Transit<M>>,
    /// Buffered cross-shard emissions per destination shard, released
    /// into the outboxes on commit.
    cross: Vec<Vec<Transit<M>>>,
}

/// Run `parts` optimistically on `threads` workers. Falls back to the
/// sequential backend exactly like `exec::par`; runs conservatively
/// (adaptive windows, zero speculation) when any program opts out via
/// [`Program::speculation_safe`]. `force_every` is the test-only hook:
/// every nth burst is rolled back unconditionally at its resolution
/// round, regardless of coverage.
pub fn run_opt<P: Program + Send + Clone>(
    parts: EngineParts<P>,
    threads: usize,
    window_batch: Option<usize>,
    force_every: Option<u64>,
) -> RunSummary {
    let lookahead = parts.fabric.min_latency();
    let leaf_aligned = parts.fabric.cfg.oversub > 0;
    let ranges = shard_ranges(
        parts.programs.len(),
        parts.fabric.topo.leaf_radix,
        leaf_aligned,
        threads,
    );
    if ranges.len() <= 1 || lookahead == Time::ZERO {
        return run_seq(parts);
    }
    let batch = resolve_window_batch(window_batch);
    let force_every = force_every.map(|n| n.max(1));
    let bounds = BoundMatrix::new(&parts.fabric, &ranges);
    let speculate = parts.programs.iter().all(|p| p.speculation_safe());

    let EngineParts { programs, slow, fabric, core, groups, seed, pool } = parts;
    // Same shared-budget accounting as `run_par` (see there): undersized
    // default pools are replaced, then the shard workers are claimed
    // all-or-nothing for the run.
    let pool = if pool.budget() >= ranges.len() {
        pool
    } else {
        std::sync::Arc::new(crate::pool::WorkerPool::new(ranges.len()))
    };
    let shard_claim =
        pool.claim_exact(ranges.len() - 1).expect("shard workers exceed the pool budget");
    let shards = carve_shards(&ranges, programs, slow, &fabric, seed);
    let sync = WindowSync::new(shards.len());
    let starts: Vec<usize> = ranges.iter().map(|r| r.start).collect();

    let results: Vec<(Shard<P>, ExecProfile)> = std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .into_iter()
            .enumerate()
            .map(|(idx, mut shard)| {
                let sync = &sync;
                let starts = &starts;
                let bounds = &bounds;
                let fabric: &Fabric = &fabric;
                let core = &core;
                let groups = &groups;
                let pool = &pool;
                scope.spawn(move || {
                    let _live = pool.enter();
                    let sx = SharedCtx { fabric, core, groups: groups.as_slice() };
                    let profile = worker(
                        &mut shard, idx, &sx, sync, starts, bounds, batch, speculate,
                        force_every,
                    );
                    (shard, profile)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
    });
    drop(shard_claim);

    let mut profile = ExecProfile::default();
    let mut shards = Vec::with_capacity(results.len());
    for (shard, p) in results {
        profile.merge(&p);
        shards.push(shard);
    }
    let mut summary = merge_shards(shards);
    summary.profile = profile;
    summary
}

#[allow(clippy::too_many_arguments)]
fn worker<P: Program + Clone>(
    shard: &mut Shard<P>,
    idx: usize,
    sx: &SharedCtx<'_>,
    sync: &WindowSync<P::Msg>,
    starts: &[usize],
    bounds: &BoundMatrix,
    batch: u64,
    speculate: bool,
    force_every: Option<u64>,
) -> ExecProfile {
    let n = starts.len();
    let mut profile = ExecProfile::default();
    let mut out: Vec<Vec<Transit<P::Msg>>> = (0..n).map(|_| Vec::new()).collect();
    let mut inbox: Vec<Transit<P::Msg>> = Vec::new();
    let mut log: SpecLog<P> = SpecLog::new(shard.range.len());
    let mut pending: Option<PendingBurst<P::Msg>> = None;
    let mut bursts = 0u64;
    // Recycled burst buffers (§Perf): every resolved burst hands its
    // emission Vecs back here, so steady-state speculation allocates
    // nothing — the buffers ping-pong between the spares and the one
    // in-flight [`PendingBurst`].
    let mut spare_local: Vec<Transit<P::Msg>> = Vec::new();
    let mut spare_cross: Vec<Vec<Transit<P::Msg>>> = (0..n).map(|_| Vec::new()).collect();

    // Round 0: fire every on_start and exchange the initial transits.
    {
        let mut emit = |t: Transit<P::Msg>| out[shard_of(starts, t.flight.dst as usize)].push(t);
        shard.start(sx, &mut emit);
    }
    flush(&mut out, sync, idx);
    sync.barrier.wait();

    loop {
        profile.rounds += 1;
        std::mem::swap(&mut *sync.inboxes[idx].lock().expect("inbox"), &mut inbox);
        inbox.sort_unstable_by_key(|t| (t.flight.at, t.flight.src, t.flight.ctr));

        // Straggler detection: cross-shard latency is strictly positive,
        // so an inbound transit keying before the deepest speculated
        // event means the sequential order would have processed it first
        // — the burst is wrong. (Equal keys cannot occur: `(at, src,
        // ctr)` is unique.) The forced hook fails every nth burst here.
        let must_roll = pending.as_ref().is_some_and(|p| {
            inbox
                .first()
                .is_some_and(|t| (t.flight.at, t.flight.src, t.flight.ctr) < p.last_key)
                || force_every.is_some_and(|k| bursts % k == 0)
        });
        if must_roll {
            let mut p = pending.take().expect("checked pending");
            shard.rollback_burst(&mut log);
            profile.rollbacks += 1;
            p.local.clear();
            for buf in &mut p.cross {
                buf.clear();
            }
            spare_local = p.local;
            spare_cross = p.cross;
        }

        // Publish the event minimum. The inbox is not queued yet (its
        // placement must follow a possible resolution rollback), so fold
        // it in by hand; while a burst is pending its first event is the
        // floor — straggler-checked inbound keys at or after the last
        // speculated event, which is at or after the first.
        let own = match &pending {
            Some(p) => p.first_at.0,
            None => shard
                .peek_at()
                .map(|t| t.0)
                .unwrap_or(u64::MAX)
                .min(inbox.first().map(|t| t.flight.at.0).unwrap_or(u64::MAX)),
        };
        sync.mins[idx].store(own, Ordering::SeqCst);
        sync.barrier.wait();

        let mut horizon = u64::MAX;
        let mut all_idle = true;
        for (j, m) in sync.mins.iter().enumerate() {
            let v = m.load(Ordering::SeqCst);
            if v != u64::MAX {
                all_idle = false;
                if j != idx {
                    horizon = horizon.min(v.saturating_add(bounds.get(j, idx)));
                }
            }
        }
        if all_idle {
            debug_assert!(pending.is_none(), "pending burst publishes a finite minimum");
            return profile;
        }

        // Resolve the pending burst against the fresh horizon. The undo
        // journal and the pending handoff always agree: the journal holds
        // redo entries exactly while a burst awaits resolution.
        debug_assert_eq!(log.is_pending(), pending.is_some());
        if let Some(mut p) = pending.take() {
            if p.last_key.0 .0 < horizon {
                // Commit: every speculated event is provably final. The
                // buffered own-shard sends re-enter the queue (their
                // arrivals all key after the burst's pops); cross-shard
                // sends ride this round's outboxes — each arrival is at
                // or beyond its receiver's current bound, so at worst it
                // triggers the receiver's own straggler rollback.
                profile.committed += 1;
                profile.committed_span += p.last_key.0 .0 - p.first_at.0;
                log.resolve();
                for t in p.local.drain(..) {
                    shard.push(t);
                }
                for (d, buf) in p.cross.iter_mut().enumerate() {
                    out[d].append(buf);
                }
            } else {
                // Not fully covered: retry conservatively rather than
                // idling on an uncommitted burst (livelock prevention —
                // the conservative drain below always makes progress).
                shard.rollback_burst(&mut log);
                profile.rollbacks += 1;
                p.local.clear();
                for buf in &mut p.cross {
                    buf.clear();
                }
            }
            // Either way the emptied buffers go back to the spares.
            spare_local = p.local;
            spare_cross = p.cross;
        }

        // Inbound transits enter the queue only now, after any rollback
        // rewound the cursor — their ring/far placement must be computed
        // against the rewound position.
        for t in inbox.drain(..) {
            shard.push(t);
        }

        // Conservative window, identical to exec::par (and automatically
        // the in-order re-execution of anything a rollback undid).
        let own_cap = own.saturating_add(bounds.min().saturating_mul(batch));
        let drained_to = {
            let guard = Cell::new(horizon.min(own_cap));
            let mut emit = |t: Transit<P::Msg>| {
                let d = shard_of(starts, t.flight.dst as usize);
                guard.set(guard.get().min(t.flight.at.0.saturating_add(bounds.get(d, idx))));
                out[d].push(t);
            };
            shard.run_window_dyn(sx, &|| Time(guard.get()), &mut emit);
            guard.get()
        };

        // Speculate past the conservative bound: up to `batch` extra
        // minimum-latency windows, hard-capped by the queue's rewind
        // fence (the burst must stay undoable).
        if speculate {
            let cap = drained_to
                .saturating_add(bounds.min().saturating_mul(batch))
                .min(shard.spec_fence().0);
            if cap > drained_to && shard.peek_at().is_some_and(|t| t.0 < cap) {
                bursts += 1;
                shard.begin_burst(&mut log);
                let spec_bound = Cell::new(cap);
                let mut local: Vec<Transit<P::Msg>> = std::mem::take(&mut spare_local);
                let mut cross: Vec<Vec<Transit<P::Msg>>> = std::mem::take(&mut spare_cross);
                debug_assert_eq!(cross.len(), n, "spare burst buffers out of shape");
                {
                    let mut emit = |t: Transit<P::Msg>| {
                        let d = shard_of(starts, t.flight.dst as usize);
                        if d == idx {
                            // Buffered until commit, so the burst must
                            // not pop past its arrival: anything later in
                            // the queue would jump the canonical order.
                            spec_bound.set(spec_bound.get().min(t.flight.at.0));
                            local.push(t);
                        } else {
                            cross[d].push(t);
                        }
                    };
                    shard.run_window_spec(sx, &|| Time(spec_bound.get()), &mut emit, &mut log);
                }
                if let Some(last_key) = log.last_key() {
                    profile.speculated += 1;
                    let first_at = log.first_at().expect("non-empty burst");
                    pending = Some(PendingBurst { last_key, first_at, local, cross });
                } else {
                    debug_assert!(local.is_empty(), "emissions without pops");
                    debug_assert!(cross.iter().all(Vec::is_empty), "emissions without pops");
                    spare_local = local;
                    spare_cross = cross;
                }
            }
        }

        flush(&mut out, sync, idx);
        sync.barrier.wait();
    }
}
