//! The sequential backend: one shard covering every node, drained to
//! quiescence in canonical `(at, src, ctr)` order on the calling thread.
//!
//! This is the reference semantics — the parallel backend is defined (and
//! tested) to be byte-identical to it.

use crate::nanopu::Program;

use super::core::{merge_shards, RunSummary, Shard, SharedCtx};
use super::EngineParts;
use crate::sim::Time;

/// Run `parts` to quiescence sequentially.
pub fn run_seq<P: Program>(parts: EngineParts<P>) -> RunSummary {
    let EngineParts { programs, slow, fabric, core, groups, seed, pool: _ } = parts;
    let n = programs.len();
    let mut shard = Shard::new(0..n, programs, slow, &fabric, seed);
    let sx = SharedCtx { fabric: &fabric, core: &core, groups: &groups };
    // A single shard owns every node, so nothing can ever cross shards.
    let mut no_emit = |_| unreachable!("single shard owns all nodes");
    shard.start(&sx, &mut no_emit);
    shard.run_window(&sx, Time(u64::MAX), &mut no_emit);
    debug_assert!(shard.is_idle());
    merge_shards(vec![shard])
}
