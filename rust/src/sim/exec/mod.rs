//! Pluggable execution backends for the discrete-event engine.
//!
//! The engine core (`core.rs`: event slab, timing-wheel event queue,
//! hot-node arena, reorder buffer, stats arena) is decoupled from the
//! *scheduling policy* behind the [`Executor`] trait, with two backends:
//!
//! - [`SeqExecutor`] — the reference semantics: one shard covering every
//!   node, drained to quiescence on the calling thread.
//! - [`ParExecutor`] — deterministic sharded simulation: nodes partition
//!   into contiguous ranges (one worker thread each) that advance in
//!   conservative time windows derived from the topology-aware per-pair
//!   bound matrix (`sim::exec::par::BoundMatrix` — same-leaf shard pairs
//!   get a far wider window than the global worst case) and the other
//!   shards' published event minima (so a shard running alone coalesces
//!   up to `NANOSORT_WINDOW_BATCH` windows per barrier round), exchange
//!   cross-shard sends at window barriers, and merge per-shard stats in
//!   canonical node order.
//! - [`OptExecutor`] — the optimistic backend: conservative windows as
//!   above, plus Time-Warp-style speculation past the bound with
//!   shard-local rollback (cross-shard sends are buffered until a burst
//!   commits, so no anti-messages exist — `sim::exec::opt` module docs
//!   and DESIGN.md §10).
//!
//! # Determinism contract (DESIGN.md §7)
//!
//! Both backends produce **byte-identical** [`RunSummary`]s (and thus
//! identical `RunReport`s and conformance digests) for the same engine
//! configuration, at any thread count, because:
//!
//! 1. every event orders by the canonical key `(arrival, src, per-source
//!    send counter)` — no scheduling-order-dependent tie-breaks;
//! 2. all randomness (per-node program streams, per-source loss/RTO and
//!    tail draws) comes from streams derived from the run seed and an
//!    absolute node id — never from a shared draw order;
//! 3. destination-side contention (ingress store-and-forward, per-leaf
//!    oversubscribed-spine registers) is resolved when the destination
//!    pops the event, in canonical order, not when the sender issued it;
//! 4. the window rule (`new events land ≥ one minimum-latency beyond the
//!    emitting shard's published minimum`) closes each window's event set
//!    before it runs — at any window-coalescing factor (`sim::exec::par`
//!    module docs walk the closure argument).
//!
//! `rust/tests/exec.rs` pins the contract across every workload, tier,
//! and perturbation knob; `rust/tests/exec_fuzz.rs` fuzzes it over
//! randomized scenario × perturbation × backend × sharding composites.

pub(crate) mod core;
mod opt;
mod par;
mod seq;

pub use self::core::{queue_churn_allocs, ExecProfile, NodeStats, RunSummary, MAX_STAGES};

use std::sync::Arc;

use crate::cpu::CoreModel;
use crate::nanopu::{Group, Program};
use crate::net::Fabric;
use crate::pool::WorkerPool;

pub(crate) use seq::run_seq as run_seq_inner;

/// Everything an executor needs to run one simulation: the node programs
/// (index = node id), per-node slowdown factors, the fabric, the core
/// cost model, the registered multicast groups, the run seed, and the
/// shared host worker pool.
pub struct EngineParts<P: Program> {
    pub programs: Vec<P>,
    pub slow: Vec<u32>,
    pub fabric: Fabric,
    pub core: CoreModel,
    pub groups: Vec<Group>,
    pub seed: u64,
    /// The `--threads` budget, shared between shard workers and parallel
    /// compute kernels ([`crate::pool`]): the parallel executors claim
    /// their `shards - 1` extra slots from it and register every worker,
    /// so sim threads and kernel tiles can never oversubscribe the host.
    pub pool: Arc<WorkerPool>,
}

/// Resolve the crate-wide `--threads` convention: `0` means all
/// available host cores (the single definition behind
/// [`ParExecutor::resolved_threads`], the sweep pool, and the CLI).
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// A scheduling policy for the engine core. `P: Send + Clone` bounds the
/// trait method so one trait serves every backend (`Clone` feeds the
/// optimistic backend's per-node checkpoints; every program is a plain
/// value type); the sequential path is also reachable without either
/// bound through [`crate::sim::Engine::run`].
pub trait Executor {
    /// Backend name (reports/diagnostics).
    fn name(&self) -> &'static str;

    /// Run `parts` to global quiescence.
    fn run<P: Program + Send + Clone>(&self, parts: EngineParts<P>) -> RunSummary;
}

/// The exact reference semantics, single-threaded.
#[derive(Debug, Clone, Copy, Default)]
pub struct SeqExecutor;

impl Executor for SeqExecutor {
    fn name(&self) -> &'static str {
        "seq"
    }

    fn run<P: Program + Send + Clone>(&self, parts: EngineParts<P>) -> RunSummary {
        seq::run_seq(parts)
    }
}

/// Deterministic sharded execution across `threads` worker threads
/// (`0` = all available host cores). Falls back to the sequential
/// backend when sharding cannot help (single effective shard, zero
/// fabric lookahead).
#[derive(Debug, Clone, Copy)]
pub struct ParExecutor {
    pub threads: usize,
    /// Window-coalescing factor `k`: how many fabric-lookahead windows a
    /// shard may drain per barrier round when no other shard could
    /// interleave a transit (see the `sim::exec::par` module docs).
    /// `None` resolves the `NANOSORT_WINDOW_BATCH` environment knob
    /// (default 4). Results are byte-identical at every value; `k = 1`
    /// reproduces the pre-coalescing one-window-per-round schedule.
    pub window_batch: Option<usize>,
}

impl ParExecutor {
    /// `threads` workers, coalescing factor from the environment knob.
    pub fn new(threads: usize) -> Self {
        ParExecutor { threads, window_batch: None }
    }

    /// Resolve the `0 = available_parallelism` convention.
    pub fn resolved_threads(&self) -> usize {
        resolve_threads(self.threads)
    }
}

impl Executor for ParExecutor {
    fn name(&self) -> &'static str {
        "par"
    }

    fn run<P: Program + Send + Clone>(&self, parts: EngineParts<P>) -> RunSummary {
        par::run_par(parts, self.resolved_threads(), self.window_batch)
    }
}

/// Optimistic sharded execution: the same deterministic windows as
/// [`ParExecutor`], plus speculative bursts past the conservative bound
/// with shard-local rollback (`sim::exec::opt`). Identical digests to
/// both other backends; [`RunSummary::profile`] additionally reports
/// burst/commit/rollback counters.
#[derive(Debug, Clone, Copy)]
pub struct OptExecutor {
    pub threads: usize,
    /// See [`ParExecutor::window_batch`]; also bounds how far a
    /// speculative burst may run past the conservative bound.
    pub window_batch: Option<usize>,
    /// Test-only fault hook: unconditionally roll back every `n`-th
    /// speculative burst at its resolution round, exercising the recovery
    /// path on every workload. `None` (the default) rolls back only on
    /// real stragglers.
    pub force_rollback_every: Option<u64>,
}

impl OptExecutor {
    /// `threads` workers, coalescing factor from the environment knob,
    /// no forced rollbacks.
    pub fn new(threads: usize) -> Self {
        OptExecutor { threads, window_batch: None, force_rollback_every: None }
    }

    /// Enable the forced-rollback fault hook (tests only; `n` is clamped
    /// to ≥ 1, i.e. "every burst").
    pub fn force_rollback_every(mut self, n: u64) -> Self {
        self.force_rollback_every = Some(n.max(1));
        self
    }

    /// Resolve the `0 = available_parallelism` convention.
    pub fn resolved_threads(&self) -> usize {
        resolve_threads(self.threads)
    }
}

impl Executor for OptExecutor {
    fn name(&self) -> &'static str {
        "opt"
    }

    fn run<P: Program + Send + Clone>(&self, parts: EngineParts<P>) -> RunSummary {
        opt::run_opt(parts, self.resolved_threads(), self.window_batch, self.force_rollback_every)
    }
}

/// CLI-facing backend selector (`--exec seq|par|opt`). [`ExecKind::Par`]
/// is the default everywhere: `--threads 1` collapses it to the
/// sequential path, so prior CLI behavior is unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecKind {
    Seq,
    #[default]
    Par,
    Opt,
}

impl ExecKind {
    /// Parse the `--exec` operand.
    pub fn parse(raw: &str) -> Option<Self> {
        match raw {
            "seq" => Some(ExecKind::Seq),
            "par" => Some(ExecKind::Par),
            "opt" => Some(ExecKind::Opt),
            _ => None,
        }
    }

    /// Canonical name (reports, bench records, `--exec` operand).
    pub fn name(self) -> &'static str {
        match self {
            ExecKind::Seq => "seq",
            ExecKind::Par => "par",
            ExecKind::Opt => "opt",
        }
    }
}
