//! Pluggable execution backends for the discrete-event engine.
//!
//! The engine core (`core.rs`: event slab, calendar queue, hot-node
//! arena, reorder buffer, stats arena) is decoupled from the *scheduling
//! policy* behind the [`Executor`] trait, with two backends:
//!
//! - [`SeqExecutor`] — the reference semantics: one shard covering every
//!   node, drained to quiescence on the calling thread.
//! - [`ParExecutor`] — deterministic sharded simulation: nodes partition
//!   into contiguous ranges (one worker thread each) that advance in
//!   conservative time windows derived from the fabric's minimum latency
//!   ([`crate::net::Fabric::min_latency`]) and the other shards' published
//!   event minima (so a shard running alone coalesces up to
//!   `NANOSORT_WINDOW_BATCH` windows per barrier round), exchange
//!   cross-shard sends at window barriers, and merge per-shard stats in
//!   canonical node order.
//!
//! # Determinism contract (DESIGN.md §7)
//!
//! Both backends produce **byte-identical** [`RunSummary`]s (and thus
//! identical `RunReport`s and conformance digests) for the same engine
//! configuration, at any thread count, because:
//!
//! 1. every event orders by the canonical key `(arrival, src, per-source
//!    send counter)` — no scheduling-order-dependent tie-breaks;
//! 2. all randomness (per-node program streams, per-source loss/RTO and
//!    tail draws) comes from streams derived from the run seed and an
//!    absolute node id — never from a shared draw order;
//! 3. destination-side contention (ingress store-and-forward, per-leaf
//!    oversubscribed-spine registers) is resolved when the destination
//!    pops the event, in canonical order, not when the sender issued it;
//! 4. the window rule (`new events land ≥ one minimum-latency beyond the
//!    emitting shard's published minimum`) closes each window's event set
//!    before it runs — at any window-coalescing factor (`sim::exec::par`
//!    module docs walk the closure argument).
//!
//! `rust/tests/exec.rs` pins the contract across every workload, tier,
//! and perturbation knob.

pub(crate) mod core;
mod par;
mod seq;

pub use self::core::{NodeStats, RunSummary, MAX_STAGES};

use crate::cpu::CoreModel;
use crate::nanopu::{Group, Program};
use crate::net::Fabric;

pub(crate) use seq::run_seq as run_seq_inner;

/// Everything an executor needs to run one simulation: the node programs
/// (index = node id), per-node slowdown factors, the fabric, the core
/// cost model, the registered multicast groups, and the run seed.
pub struct EngineParts<P: Program> {
    pub programs: Vec<P>,
    pub slow: Vec<u32>,
    pub fabric: Fabric,
    pub core: CoreModel,
    pub groups: Vec<Group>,
    pub seed: u64,
}

/// Resolve the crate-wide `--threads` convention: `0` means all
/// available host cores (the single definition behind
/// [`ParExecutor::resolved_threads`], the sweep pool, and the CLI).
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// A scheduling policy for the engine core. `P: Send` bounds the trait
/// method so one trait serves both backends; the sequential path is also
/// reachable without `Send` through [`crate::sim::Engine::run`].
pub trait Executor {
    /// Backend name (reports/diagnostics).
    fn name(&self) -> &'static str;

    /// Run `parts` to global quiescence.
    fn run<P: Program + Send>(&self, parts: EngineParts<P>) -> RunSummary;
}

/// The exact reference semantics, single-threaded.
#[derive(Debug, Clone, Copy, Default)]
pub struct SeqExecutor;

impl Executor for SeqExecutor {
    fn name(&self) -> &'static str {
        "seq"
    }

    fn run<P: Program + Send>(&self, parts: EngineParts<P>) -> RunSummary {
        seq::run_seq(parts)
    }
}

/// Deterministic sharded execution across `threads` worker threads
/// (`0` = all available host cores). Falls back to the sequential
/// backend when sharding cannot help (single effective shard, zero
/// fabric lookahead).
#[derive(Debug, Clone, Copy)]
pub struct ParExecutor {
    pub threads: usize,
    /// Window-coalescing factor `k`: how many fabric-lookahead windows a
    /// shard may drain per barrier round when no other shard could
    /// interleave a transit (see the `sim::exec::par` module docs).
    /// `None` resolves the `NANOSORT_WINDOW_BATCH` environment knob
    /// (default 4). Results are byte-identical at every value; `k = 1`
    /// reproduces the pre-coalescing one-window-per-round schedule.
    pub window_batch: Option<usize>,
}

impl ParExecutor {
    /// `threads` workers, coalescing factor from the environment knob.
    pub fn new(threads: usize) -> Self {
        ParExecutor { threads, window_batch: None }
    }

    /// Resolve the `0 = available_parallelism` convention.
    pub fn resolved_threads(&self) -> usize {
        resolve_threads(self.threads)
    }
}

impl Executor for ParExecutor {
    fn name(&self) -> &'static str {
        "par"
    }

    fn run<P: Program + Send>(&self, parts: EngineParts<P>) -> RunSummary {
        par::run_par(parts, self.resolved_threads(), self.window_batch)
    }
}
