//! The deterministic sharded backend: nodes are partitioned into
//! contiguous ranges, one worker thread per shard, advancing together in
//! conservative time windows bounded by the fabric's minimum latency.
//!
//! # Why this is byte-identical to the sequential backend
//!
//! Every piece of mutable run state is owned by exactly one shard —
//! program/hot/stats arenas and the ingress/spine registers by the
//! *destination* node's shard, egress registers, RNG streams, and send
//! counters by the *source* node's shard. Shards only interact through
//! [`Transit`] values ordered by the canonical `(at, src, ctr)` key, and
//! the window rule guarantees a shard has **every** transit with
//! `at < bound` in hand before it processes that window:
//!
//! - window `k` processes events in `[min_k, min_k + L)` where `L` is
//!   [`crate::net::Fabric::min_latency`] and `min_k` the global earliest
//!   pending event;
//! - any event processed at `t ≥ min_k` can only produce transits with
//!   `at ≥ t + L ≥ min_k + L` — i.e. beyond the current window — so the
//!   window's event set is closed before it starts;
//! - transits are exchanged at the barrier after each window, before the
//!   next bound is computed.
//!
//! Per-shard state therefore evolves through exactly the same sequence of
//! mutations as in the sequential backend (which is the same state
//! machine restricted to one all-covering shard), and the final merge
//! (node order, summed counters) is canonical. Stats/digest outputs match
//! byte for byte — `rust/tests/exec.rs` pins this for every workload,
//! tier, and perturbation knob.
//!
//! Fallbacks: a zero lookahead (degenerate fabric config), a single
//! effective shard, or an oversubscribed fabric too small to split on a
//! leaf boundary all degrade to [`super::seq::run_seq`] — same results,
//! no windowing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};

use crate::nanopu::Program;
use crate::net::Fabric;

use super::core::{merge_shards, RunSummary, Shard, SharedCtx, Transit};
use super::seq::run_seq;
use super::EngineParts;
use crate::sim::Time;

/// Sentinel bound meaning "no events anywhere: stop".
const DONE: u64 = u64::MAX;

/// Split `nodes` into up to `threads` contiguous shard ranges. When the
/// core is oversubscribed the per-leaf spine registers force shard
/// boundaries onto leaf boundaries; otherwise any node split works.
pub(crate) fn shard_ranges(
    nodes: usize,
    leaf_radix: usize,
    leaf_aligned: bool,
    threads: usize,
) -> Vec<std::ops::Range<usize>> {
    if nodes == 0 {
        return vec![0..0];
    }
    if leaf_aligned {
        let leaves = nodes.div_ceil(leaf_radix);
        let shards = threads.clamp(1, leaves);
        (0..shards)
            .map(|k| {
                let lo = (k * leaves / shards) * leaf_radix;
                let hi = (((k + 1) * leaves / shards) * leaf_radix).min(nodes);
                lo..hi
            })
            .collect()
    } else {
        let shards = threads.clamp(1, nodes);
        (0..shards).map(|k| k * nodes / shards..(k + 1) * nodes / shards).collect()
    }
}

/// Window-barrier synchronization state shared by the workers.
struct WindowSync<M> {
    barrier: Barrier,
    /// Per-shard earliest pending event time (u64::MAX = idle).
    mins: Vec<AtomicU64>,
    /// This round's exclusive window bound ([`DONE`] = quiescent).
    bound: AtomicU64,
    /// Per-destination-shard mailboxes, drained between windows.
    inboxes: Vec<Mutex<Vec<Transit<M>>>>,
}

/// Run `parts` on `threads` worker threads (resolved and > 1), falling
/// back to the sequential backend when sharding cannot help.
pub fn run_par<P: Program + Send>(parts: EngineParts<P>, threads: usize) -> RunSummary {
    let lookahead = parts.fabric.min_latency();
    let leaf_aligned = parts.fabric.cfg.oversub > 0;
    let ranges = shard_ranges(
        parts.programs.len(),
        parts.fabric.topo.leaf_radix,
        leaf_aligned,
        threads,
    );
    if ranges.len() <= 1 || lookahead == Time::ZERO {
        // Zero lookahead (degenerate config) or nothing to split:
        // conservative windows cannot make progress / cannot help.
        return run_seq(parts);
    }

    let EngineParts { programs, slow, fabric, core, groups, seed } = parts;
    let mut programs = programs;
    let mut slow = slow;
    // Carve the per-node vectors into shards, back to front so the
    // splits are O(shards) rather than O(nodes · shards).
    let mut shards: Vec<Shard<P>> = Vec::with_capacity(ranges.len());
    for range in ranges.iter().rev() {
        let progs = programs.split_off(range.start);
        let slows = slow.split_off(range.start);
        shards.push(Shard::new(range.clone(), progs, slows, &fabric, seed));
    }
    shards.reverse();

    let sync = WindowSync {
        barrier: Barrier::new(shards.len()),
        mins: (0..shards.len()).map(|_| AtomicU64::new(u64::MAX)).collect(),
        bound: AtomicU64::new(0),
        inboxes: (0..shards.len()).map(|_| Mutex::new(Vec::new())).collect(),
    };
    let starts: Vec<usize> = ranges.iter().map(|r| r.start).collect();

    let shards: Vec<Shard<P>> = std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .into_iter()
            .enumerate()
            .map(|(idx, mut shard)| {
                let sync = &sync;
                let starts = &starts;
                let fabric: &Fabric = &fabric;
                let core = &core;
                let groups = &groups;
                scope.spawn(move || {
                    let sx = SharedCtx { fabric, core, groups: groups.as_slice() };
                    worker(&mut shard, idx, &sx, sync, starts, lookahead);
                    shard
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
    });

    merge_shards(shards)
}

/// Index of the shard owning `node` (ranges are contiguous + ascending).
fn shard_of(starts: &[usize], node: usize) -> usize {
    starts.partition_point(|&s| s <= node) - 1
}

fn worker<P: Program>(
    shard: &mut Shard<P>,
    idx: usize,
    sx: &SharedCtx<'_>,
    sync: &WindowSync<P::Msg>,
    starts: &[usize],
    lookahead: Time,
) {
    // Per-destination-shard outboxes, flushed under one short lock each
    // at the end of every window.
    let mut out: Vec<Vec<Transit<P::Msg>>> = (0..starts.len()).map(|_| Vec::new()).collect();

    // Round 0: fire every on_start and exchange the initial transits.
    {
        let mut emit =
            |t: Transit<P::Msg>| out[shard_of(starts, t.flight.dst)].push(t);
        shard.start(sx, &mut emit);
    }
    flush(&mut out, sync, idx);
    sync.barrier.wait();

    loop {
        // Merge inbound transits (canonical-order queues make the merge
        // order irrelevant, but sort anyway so the insertion path is
        // deterministic bucket by bucket).
        let mut inbox = std::mem::take(&mut *sync.inboxes[idx].lock().expect("inbox"));
        inbox.sort_unstable_by_key(|t| (t.flight.at, t.flight.src, t.flight.ctr));
        for t in inbox {
            shard.push(t);
        }

        // Publish the earliest pending event; the barrier leader turns
        // the global minimum into this round's window bound.
        let min = shard.peek_at().map(|t| t.0).unwrap_or(u64::MAX);
        sync.mins[idx].store(min, Ordering::SeqCst);
        if sync.barrier.wait().is_leader() {
            let global = sync.mins.iter().map(|m| m.load(Ordering::SeqCst)).min().unwrap();
            let bound = if global == u64::MAX {
                DONE
            } else {
                global.saturating_add(lookahead.0)
            };
            sync.bound.store(bound, Ordering::SeqCst);
        }
        sync.barrier.wait();

        let bound = sync.bound.load(Ordering::SeqCst);
        if bound == DONE {
            return;
        }
        {
            let mut emit =
                |t: Transit<P::Msg>| out[shard_of(starts, t.flight.dst)].push(t);
            shard.run_window(sx, Time(bound), &mut emit);
        }
        flush(&mut out, sync, idx);
        sync.barrier.wait();
    }
}

/// Hand this window's cross-shard transits to their destination inboxes.
fn flush<M>(out: &mut [Vec<Transit<M>>], sync: &WindowSync<M>, own: usize) {
    for (j, buf) in out.iter_mut().enumerate() {
        debug_assert!(j != own || buf.is_empty(), "own-shard transit routed via outbox");
        if !buf.is_empty() {
            sync.inboxes[j].lock().expect("inbox").append(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_exactly_and_in_order() {
        for (nodes, threads, aligned) in
            [(100usize, 3usize, false), (2, 8, false), (256, 4, true), (65_536, 12, true)]
        {
            let ranges = shard_ranges(nodes, 64, aligned, threads);
            assert!(ranges.len() <= threads.max(1));
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, nodes);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "contiguous");
            }
            assert!(ranges.iter().all(|r| !r.is_empty()), "no empty shards after clamping");
            if aligned {
                assert!(ranges.iter().all(|r| r.start % 64 == 0), "leaf-aligned starts");
            }
        }
    }

    #[test]
    fn shard_ranges_clamp_to_fleet_and_leaves() {
        // More threads than nodes: one node per shard.
        assert_eq!(shard_ranges(2, 64, false, 16).len(), 2);
        // Leaf-aligned: a 2-leaf fleet cannot use more than 2 shards.
        assert_eq!(shard_ranges(100, 64, true, 16).len(), 2);
        // Single-leaf oversubscribed fleet: one shard (the caller then
        // falls back to the sequential backend).
        assert_eq!(shard_ranges(16, 64, true, 8).len(), 1);
        // Zero threads behaves like one.
        assert_eq!(shard_ranges(10, 64, false, 0).len(), 1);
    }

    #[test]
    fn shard_of_maps_nodes_to_their_range() {
        let ranges = shard_ranges(100, 64, false, 3);
        let starts: Vec<usize> = ranges.iter().map(|r| r.start).collect();
        for (i, r) in ranges.iter().enumerate() {
            assert_eq!(shard_of(&starts, r.start), i);
            assert_eq!(shard_of(&starts, r.end - 1), i);
        }
    }
}
