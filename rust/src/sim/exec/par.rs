//! The deterministic sharded backend: nodes are partitioned into
//! contiguous ranges, one worker thread per shard, advancing together in
//! conservative time windows derived from the fabric's minimum latency.
//!
//! # Why this is byte-identical to the sequential backend
//!
//! Every piece of mutable run state is owned by exactly one shard —
//! program/hot/stats arenas and the ingress/spine registers by the
//! *destination* node's shard, egress registers, RNG streams, and send
//! counters by the *source* node's shard. Shards only interact through
//! [`Transit`] values ordered by the canonical `(at, src, ctr)` key, and
//! the per-shard window bound guarantees a shard has **every** transit
//! with `at < bound` in hand before it processes that window:
//!
//! - at the round barrier each shard publishes `min_S`, the time of its
//!   earliest pending event (`u64::MAX` when idle);
//! - any event a shard `B` processes this round is at `t ≥ min_B`, so
//!   every transit `B` can still emit arrives at `≥ min_B + L`, where `L`
//!   is [`crate::net::Fabric::min_latency`];
//! - shard `A` may therefore safely process events strictly before
//!   `horizon_A = min over B≠A of (min_B + L)` as far as *other shards'
//!   queued events* are concerned — everything they could still emit
//!   lands at or beyond it. Idle shards contribute nothing
//!   (`u64::MAX`), so a shard running alone (a straggler tail, the final
//!   drain) is not throttled by the fleet-wide minimum;
//! - the horizon does **not** cover chains `A` itself starts mid-window:
//!   a transit `A` emits with arrival `a` can wake an idle shard whose
//!   reply lands as early as `a + L` — potentially before the end of a
//!   multi-window bound. The **chain guard** closes this: every emission
//!   tightens the live bound to `min(bound, a + L)`. An emission from an
//!   event processed at `t` has `a ≥ t + L`, so the guard lands at
//!   `≥ t + 2L`, above every event already popped — completed work is
//!   never invalidated, and any reply chain (two or more hops, each
//!   ≥ L) arrives at or beyond the tightened bound;
//! - transits are exchanged at the barrier after each window, before the
//!   next round's minima are published.
//!
//! The bound is additionally capped at `min_A + k·L` — the **window
//! coalescing** factor `k` (`NANOSORT_WINDOW_BATCH`, default
//! [`DEFAULT_WINDOW_BATCH`]) — so one shard never runs unboundedly ahead
//! of the exchange cadence. At `k = 1` every shard's bound reduces to
//! `global_min + L`, the classic single-window rule this backend shipped
//! with (the chain guard cannot bind there: it is always `≥ min_A + 2L`);
//! larger `k` lets a shard drain up to `k` *quiet* windows per barrier
//! round — coalescing stretches with no cross-shard emission, which is
//! exactly when no other shard could interleave a transit (§Perf: at
//! small tiers the 2-barrier round, not the event work, is the
//! wall-clock floor). The knob never changes results — horizon + chain
//! guard close every window's event set for any `k ≥ 1`, and
//! `window_batching_is_result_identity` plus
//! `window_batching_exact_under_cross_shard_reply_chains` in
//! `sim/engine.rs` pin it.
//!
//! Per-shard state therefore evolves through exactly the same sequence of
//! mutations as in the sequential backend (which is the same state
//! machine restricted to one all-covering shard), and the final merge
//! (node order, summed counters) is canonical. Stats/digest outputs match
//! byte for byte — `rust/tests/exec.rs` pins this for every workload,
//! tier, and perturbation knob.
//!
//! Fallbacks: a zero lookahead (degenerate fabric config), a single
//! effective shard, or an oversubscribed fabric too small to split on a
//! leaf boundary all degrade to [`super::seq::run_seq`] — same results,
//! no windowing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};

use crate::nanopu::Program;
use crate::net::Fabric;

use super::core::{merge_shards, RunSummary, Shard, SharedCtx, Transit};
use super::seq::run_seq;
use super::EngineParts;
use crate::sim::Time;

/// Default window-coalescing factor: a shard with exclusive claim on the
/// near future drains up to this many lookahead windows per barrier
/// round. Results are identical at any value (see module docs); this only
/// trades barrier overhead against exchange latency.
pub(crate) const DEFAULT_WINDOW_BATCH: u64 = 4;

/// Resolve the coalescing factor: an explicit executor setting wins,
/// then the `NANOSORT_WINDOW_BATCH` environment knob, then the default.
/// Clamped to ≥ 1 (`k = 0` would mean "process nothing", a livelock). A
/// malformed environment value panics rather than silently running the
/// default — matching the CLI's strict knob parsing, so a perf
/// measurement is never taken against a configuration other than the
/// one the operator asked for.
pub(crate) fn resolve_window_batch(explicit: Option<usize>) -> u64 {
    if let Some(k) = explicit {
        return (k as u64).max(1);
    }
    match std::env::var("NANOSORT_WINDOW_BATCH") {
        Ok(raw) => match raw.parse::<u64>() {
            Ok(k) => k.max(1),
            Err(_) => panic!(
                "NANOSORT_WINDOW_BATCH expects a positive integer, got {raw:?}"
            ),
        },
        Err(_) => DEFAULT_WINDOW_BATCH,
    }
}

/// Split `nodes` into up to `threads` contiguous shard ranges. When the
/// core is oversubscribed the per-leaf spine registers force shard
/// boundaries onto leaf boundaries; otherwise any node split works.
pub(crate) fn shard_ranges(
    nodes: usize,
    leaf_radix: usize,
    leaf_aligned: bool,
    threads: usize,
) -> Vec<std::ops::Range<usize>> {
    if nodes == 0 {
        return vec![0..0];
    }
    if leaf_aligned {
        let leaves = nodes.div_ceil(leaf_radix);
        let shards = threads.clamp(1, leaves);
        (0..shards)
            .map(|k| {
                let lo = (k * leaves / shards) * leaf_radix;
                let hi = (((k + 1) * leaves / shards) * leaf_radix).min(nodes);
                lo..hi
            })
            .collect()
    } else {
        let shards = threads.clamp(1, nodes);
        (0..shards).map(|k| k * nodes / shards..(k + 1) * nodes / shards).collect()
    }
}

/// Window-barrier synchronization state shared by the workers.
struct WindowSync<M> {
    barrier: Barrier,
    /// Per-shard earliest pending event time (u64::MAX = idle).
    mins: Vec<AtomicU64>,
    /// Per-destination-shard mailboxes, drained between windows.
    inboxes: Vec<Mutex<Vec<Transit<M>>>>,
}

/// Run `parts` on `threads` worker threads (resolved and > 1), falling
/// back to the sequential backend when sharding cannot help.
/// `window_batch` is the coalescing factor `k` (`None` = environment
/// knob / default; identical results at any value).
pub fn run_par<P: Program + Send>(
    parts: EngineParts<P>,
    threads: usize,
    window_batch: Option<usize>,
) -> RunSummary {
    let lookahead = parts.fabric.min_latency();
    let leaf_aligned = parts.fabric.cfg.oversub > 0;
    let ranges = shard_ranges(
        parts.programs.len(),
        parts.fabric.topo.leaf_radix,
        leaf_aligned,
        threads,
    );
    if ranges.len() <= 1 || lookahead == Time::ZERO {
        // Zero lookahead (degenerate config) or nothing to split:
        // conservative windows cannot make progress / cannot help.
        return run_seq(parts);
    }
    let batch = resolve_window_batch(window_batch);

    let EngineParts { programs, slow, fabric, core, groups, seed } = parts;
    let mut programs = programs;
    let mut slow = slow;
    // Carve the per-node vectors into shards, back to front so the
    // splits are O(shards) rather than O(nodes · shards).
    let mut shards: Vec<Shard<P>> = Vec::with_capacity(ranges.len());
    for range in ranges.iter().rev() {
        let progs = programs.split_off(range.start);
        let slows = slow.split_off(range.start);
        shards.push(Shard::new(range.clone(), progs, slows, &fabric, seed));
    }
    shards.reverse();

    let sync = WindowSync {
        barrier: Barrier::new(shards.len()),
        mins: (0..shards.len()).map(|_| AtomicU64::new(u64::MAX)).collect(),
        inboxes: (0..shards.len()).map(|_| Mutex::new(Vec::new())).collect(),
    };
    let starts: Vec<usize> = ranges.iter().map(|r| r.start).collect();

    let shards: Vec<Shard<P>> = std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .into_iter()
            .enumerate()
            .map(|(idx, mut shard)| {
                let sync = &sync;
                let starts = &starts;
                let fabric: &Fabric = &fabric;
                let core = &core;
                let groups = &groups;
                scope.spawn(move || {
                    let sx = SharedCtx { fabric, core, groups: groups.as_slice() };
                    worker(&mut shard, idx, &sx, sync, starts, lookahead, batch);
                    shard
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
    });

    merge_shards(shards)
}

/// Index of the shard owning `node` (ranges are contiguous + ascending).
fn shard_of(starts: &[usize], node: usize) -> usize {
    starts.partition_point(|&s| s <= node) - 1
}

fn worker<P: Program>(
    shard: &mut Shard<P>,
    idx: usize,
    sx: &SharedCtx<'_>,
    sync: &WindowSync<P::Msg>,
    starts: &[usize],
    lookahead: Time,
    batch: u64,
) {
    // Per-destination-shard outboxes, flushed under one short lock each
    // at the end of every window. (`Vec::append` in the flush leaves each
    // outbox empty *with its capacity*, so these amortize for free.)
    let mut out: Vec<Vec<Transit<P::Msg>>> = (0..starts.len()).map(|_| Vec::new()).collect();
    // Recycled inbox buffer: swapped with the shared mailbox each round,
    // drained in place (§Perf: `mem::take` on the mailbox allocated a
    // fresh Vec per shard per window — thousands of reallocs per shuffle
    // round at the paper tier; the pooled pair reallocates only on
    // high-water growth).
    let mut inbox: Vec<Transit<P::Msg>> = Vec::new();

    // Round 0: fire every on_start and exchange the initial transits.
    {
        let mut emit =
            |t: Transit<P::Msg>| out[shard_of(starts, t.flight.dst)].push(t);
        shard.start(sx, &mut emit);
    }
    flush(&mut out, sync, idx);
    sync.barrier.wait();

    loop {
        // Merge inbound transits (canonical-order queues make the merge
        // order irrelevant, but sort anyway so the insertion path is
        // deterministic bucket by bucket).
        std::mem::swap(&mut *sync.inboxes[idx].lock().expect("inbox"), &mut inbox);
        inbox.sort_unstable_by_key(|t| (t.flight.at, t.flight.src, t.flight.ctr));
        for t in inbox.drain(..) {
            shard.push(t);
        }

        // Publish the earliest pending event; after the barrier every
        // shard derives its own bound from the full minima vector — the
        // same deterministic inputs on every worker, no leader round.
        let own = shard.peek_at().map(|t| t.0).unwrap_or(u64::MAX);
        sync.mins[idx].store(own, Ordering::SeqCst);
        sync.barrier.wait();

        // horizon = earliest time any *other* shard could still emit a
        // transit into this shard (min over others of min + L); the own
        // cap bounds coalescing at `batch` lookahead windows.
        let mut horizon = u64::MAX;
        let mut all_idle = true;
        for (j, m) in sync.mins.iter().enumerate() {
            let v = m.load(Ordering::SeqCst);
            if v != u64::MAX {
                all_idle = false;
                if j != idx {
                    horizon = horizon.min(v.saturating_add(lookahead.0));
                }
            }
        }
        if all_idle {
            return; // global quiescence
        }
        let own_cap = own.saturating_add(lookahead.0.saturating_mul(batch));
        {
            // Chain guard: the horizon covers events other shards hold
            // *now*, but a transit this shard emits mid-window can wake
            // an idle shard whose reply lands as early as the transit's
            // arrival + L. Tightening the live bound to that point keeps
            // coalesced windows closed against two-hop reply chains:
            // every event already popped ran at t < arrival, and the
            // guard lands at ≥ arrival + L ≥ t + 2L — above everything
            // processed. Quiet (emission-free) stretches coalesce freely
            // up to the `batch` cap.
            let guard = std::cell::Cell::new(horizon.min(own_cap));
            let mut emit = |t: Transit<P::Msg>| {
                guard.set(guard.get().min(t.flight.at.0.saturating_add(lookahead.0)));
                out[shard_of(starts, t.flight.dst)].push(t);
            };
            shard.run_window_dyn(sx, &|| Time(guard.get()), &mut emit);
        }
        flush(&mut out, sync, idx);
        sync.barrier.wait();
    }
}

/// Hand this window's cross-shard transits to their destination inboxes.
fn flush<M>(out: &mut [Vec<Transit<M>>], sync: &WindowSync<M>, own: usize) {
    for (j, buf) in out.iter_mut().enumerate() {
        debug_assert!(j != own || buf.is_empty(), "own-shard transit routed via outbox");
        if !buf.is_empty() {
            sync.inboxes[j].lock().expect("inbox").append(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_exactly_and_in_order() {
        for (nodes, threads, aligned) in
            [(100usize, 3usize, false), (2, 8, false), (256, 4, true), (65_536, 12, true)]
        {
            let ranges = shard_ranges(nodes, 64, aligned, threads);
            assert!(ranges.len() <= threads.max(1));
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, nodes);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "contiguous");
            }
            assert!(ranges.iter().all(|r| !r.is_empty()), "no empty shards after clamping");
            if aligned {
                assert!(ranges.iter().all(|r| r.start % 64 == 0), "leaf-aligned starts");
            }
        }
    }

    #[test]
    fn shard_ranges_clamp_to_fleet_and_leaves() {
        // More threads than nodes: one node per shard.
        assert_eq!(shard_ranges(2, 64, false, 16).len(), 2);
        // Leaf-aligned: a 2-leaf fleet cannot use more than 2 shards.
        assert_eq!(shard_ranges(100, 64, true, 16).len(), 2);
        // Single-leaf oversubscribed fleet: one shard (the caller then
        // falls back to the sequential backend).
        assert_eq!(shard_ranges(16, 64, true, 8).len(), 1);
        // Zero threads behaves like one.
        assert_eq!(shard_ranges(10, 64, false, 0).len(), 1);
    }

    #[test]
    fn shard_of_maps_nodes_to_their_range() {
        let ranges = shard_ranges(100, 64, false, 3);
        let starts: Vec<usize> = ranges.iter().map(|r| r.start).collect();
        for (i, r) in ranges.iter().enumerate() {
            assert_eq!(shard_of(&starts, r.start), i);
            assert_eq!(shard_of(&starts, r.end - 1), i);
        }
    }

    #[test]
    fn window_batch_resolution_prefers_explicit_and_clamps() {
        assert_eq!(resolve_window_batch(Some(7)), 7);
        assert_eq!(resolve_window_batch(Some(1)), 1);
        // k = 0 would process nothing forever; clamp to identity.
        assert_eq!(resolve_window_batch(Some(0)), 1);
        // No explicit setting: env var or default, both ≥ 1. (The env
        // value itself is read-only here — tests must not mutate process
        // environment under a parallel test harness.)
        assert!(resolve_window_batch(None) >= 1);
    }
}
