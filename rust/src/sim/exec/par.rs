//! The deterministic sharded backend: nodes are partitioned into
//! contiguous ranges, one worker thread per shard, advancing together in
//! conservative time windows derived from the fabric's minimum latency.
//!
//! # Why this is byte-identical to the sequential backend
//!
//! Every piece of mutable run state is owned by exactly one shard —
//! program/hot/stats arenas and the ingress/spine registers by the
//! *destination* node's shard, egress registers, RNG streams, and send
//! counters by the *source* node's shard. Shards only interact through
//! [`Transit`] values ordered by the canonical `(at, src, ctr)` key, and
//! the per-shard window bound guarantees a shard has **every** transit
//! with `at < bound` in hand before it processes that window:
//!
//! - at the round barrier each shard publishes `min_S`, the time of its
//!   earliest pending event (`u64::MAX` when idle);
//! - windows are **topology-aware**: the lookahead from shard `B` to
//!   shard `A` is not the fabric-wide [`crate::net::Fabric::min_latency`]
//!   `L` but the per-pair entry `W[B][A]` of a [`BoundMatrix`] — the
//!   min-plus (Floyd–Warshall) closure over per-pair direct bounds
//!   derived from [`crate::net::Topology`] hop classes (loopback /
//!   same-leaf / cross-leaf). Any event `B` processes this round is at
//!   `t ≥ min_B`, and any causal chain from it that ends in a transit
//!   into `A` — directly, or relayed through any other shards — pays at
//!   least the closure bound, so it arrives at `≥ min_B + W[B][A]`. (The
//!   closure matters: two same-leaf hops can undercut one cross-leaf
//!   hop, so the direct pairwise bound alone would be unsound for
//!   relayed chains. Core-local timers never cross shards and therefore
//!   never weaken a cross-shard bound.);
//! - shard `A` may therefore safely process events strictly before
//!   `horizon_A = min over B≠A of (min_B + W[B][A])` as far as *other
//!   shards' queued events* are concerned. Idle shards contribute
//!   nothing (`u64::MAX`), so a shard running alone (a straggler tail,
//!   the final drain) is not throttled by the fleet-wide minimum; and
//!   leaf-local neighbours throttle each other far less than cross-spine
//!   pairs, which is the whole point;
//! - the horizon does **not** cover chains `A` itself starts mid-window:
//!   a transit `A` emits into shard `D` with arrival `a` can wake an
//!   idle shard whose reply lands as early as `a + W[D][A]` —
//!   potentially before the end of a multi-window bound. The **chain
//!   guard** closes this: every emission tightens the live bound to
//!   `min(bound, a + W[D][A])`. An emission from an event processed at
//!   `t` has `a ≥ t + W[A][D]`, so the guard lands at
//!   `≥ t + W[A][D] + W[D][A]`, above every event already popped —
//!   completed work is never invalidated, and any reply chain arrives at
//!   or beyond the tightened bound;
//! - transits are exchanged at the barrier after each window, before the
//!   next round's minima are published.
//!
//! The bound is additionally capped at `min_A + k·L` (with `L` the
//! matrix minimum, which equals the classic global `min_latency` — the
//! loopback diagonal; `matrix_minimum_is_the_conservative_global_bound`
//! pins adaptive ⊇ conservative) — the **window coalescing** factor `k`
//! (`NANOSORT_WINDOW_BATCH`, default [`DEFAULT_WINDOW_BATCH`]) — so one
//! shard never runs unboundedly ahead of the exchange cadence. Larger
//! `k` lets a shard drain up to `k` *quiet* windows per barrier round —
//! coalescing stretches with no cross-shard emission, which is exactly
//! when no other shard could interleave a transit (§Perf: at small
//! tiers the 2-barrier round, not the event work, is the wall-clock
//! floor). The knob never changes results — horizon + chain guard close
//! every window's event set for any `k ≥ 1`, and
//! `window_batching_is_result_identity` plus
//! `window_batching_exact_under_cross_shard_reply_chains` in
//! `sim/engine.rs` pin it.
//!
//! Per-shard state therefore evolves through exactly the same sequence of
//! mutations as in the sequential backend (which is the same state
//! machine restricted to one all-covering shard), and the final merge
//! (node order, summed counters) is canonical. Stats/digest outputs match
//! byte for byte — `rust/tests/exec.rs` pins this for every workload,
//! tier, and perturbation knob.
//!
//! Fallbacks: a zero lookahead (degenerate fabric config), a single
//! effective shard, or an oversubscribed fabric too small to split on a
//! leaf boundary all degrade to [`super::seq::run_seq`] — same results,
//! no windowing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};

use crate::nanopu::Program;
use crate::net::Fabric;

use super::core::{merge_shards, RunSummary, Shard, SharedCtx, Transit};
use super::seq::run_seq;
use super::EngineParts;
use crate::sim::Time;

/// Default window-coalescing factor: a shard with exclusive claim on the
/// near future drains up to this many lookahead windows per barrier
/// round. Results are identical at any value (see module docs); this only
/// trades barrier overhead against exchange latency.
pub(crate) const DEFAULT_WINDOW_BATCH: u64 = 4;

/// Resolve the coalescing factor: an explicit executor setting wins,
/// then the `NANOSORT_WINDOW_BATCH` environment knob, then the default.
/// Clamped to ≥ 1 (`k = 0` would mean "process nothing", a livelock). A
/// malformed environment value panics rather than silently running the
/// default — matching the CLI's strict knob parsing, so a perf
/// measurement is never taken against a configuration other than the
/// one the operator asked for.
pub(crate) fn resolve_window_batch(explicit: Option<usize>) -> u64 {
    if let Some(k) = explicit {
        return (k as u64).max(1);
    }
    match std::env::var("NANOSORT_WINDOW_BATCH") {
        Ok(raw) => match raw.parse::<u64>() {
            Ok(k) => k.max(1),
            Err(_) => panic!(
                "NANOSORT_WINDOW_BATCH expects a positive integer, got {raw:?}"
            ),
        },
        Err(_) => DEFAULT_WINDOW_BATCH,
    }
}

/// Split `nodes` into up to `threads` contiguous shard ranges. When the
/// core is oversubscribed the per-leaf spine registers force shard
/// boundaries onto leaf boundaries; otherwise any node split works.
pub(crate) fn shard_ranges(
    nodes: usize,
    leaf_radix: usize,
    leaf_aligned: bool,
    threads: usize,
) -> Vec<std::ops::Range<usize>> {
    if nodes == 0 {
        return vec![0..0];
    }
    if leaf_aligned {
        let leaves = nodes.div_ceil(leaf_radix);
        let shards = threads.clamp(1, leaves);
        (0..shards)
            .map(|k| {
                let lo = (k * leaves / shards) * leaf_radix;
                let hi = (((k + 1) * leaves / shards) * leaf_radix).min(nodes);
                lo..hi
            })
            .collect()
    } else {
        let shards = threads.clamp(1, nodes);
        (0..shards).map(|k| k * nodes / shards..(k + 1) * nodes / shards).collect()
    }
}

/// Per-shard-pair conservative lookahead: `get(from, to)` is a lower
/// bound on the time between an event processed on shard `from` and the
/// earliest transit any causal chain it starts can land on shard `to`.
///
/// Construction: the direct pairwise bound is minimum serialization plus
/// the propagation of the cheapest admissible hop class between the two
/// shards' node ranges — loopback `(0,0)` on the diagonal, same-leaf
/// `(2,1)` when the shards' leaf intervals intersect, cross-leaf `(4,3)`
/// otherwise — then closed under min-plus composition (Floyd–Warshall),
/// because a chain relayed through intermediate shards can undercut the
/// direct bound (two same-leaf hops are cheaper than one cross-leaf hop
/// at the paper constants). Perturbations only ever *add* latency (tail,
/// loss/RTO, contention, oversub spine queueing), so the hop-class floor
/// is sound under every knob.
pub(crate) struct BoundMatrix {
    n: usize,
    /// Row-major: `w[from * n + to]`.
    w: Vec<u64>,
}

impl BoundMatrix {
    pub fn new(fabric: &Fabric, ranges: &[std::ops::Range<usize>]) -> Self {
        let (topo, cfg) = (&fabric.topo, &fabric.cfg);
        let ser = cfg.serialization(0);
        let loopback = (ser + cfg.propagation(0, 0)).0;
        let same_leaf = (ser + cfg.propagation(2, 1)).0;
        let cross_leaf = (ser + cfg.propagation(4, 3)).0;
        let n = ranges.len();
        let leaves: Vec<(usize, usize)> = ranges
            .iter()
            .map(|r| {
                if r.is_empty() {
                    (usize::MAX, 0) // empty interval: intersects nothing
                } else {
                    (topo.leaf_of(r.start), topo.leaf_of(r.end - 1))
                }
            })
            .collect();
        let mut w = vec![0u64; n * n];
        for i in 0..n {
            for j in 0..n {
                w[i * n + j] = if i == j {
                    loopback
                } else if leaves[i].0 <= leaves[j].1 && leaves[j].0 <= leaves[i].1 {
                    same_leaf
                } else {
                    cross_leaf
                };
            }
        }
        // Min-plus closure: W[i][j] = min over relay paths of the summed
        // direct bounds. n = shard count (small), so O(n³) is free.
        for k in 0..n {
            for i in 0..n {
                for j in 0..n {
                    let via = w[i * n + k].saturating_add(w[k * n + j]);
                    if via < w[i * n + j] {
                        w[i * n + j] = via;
                    }
                }
            }
        }
        BoundMatrix { n, w }
    }

    /// Lower bound on `from`-shard → `to`-shard causal influence.
    pub fn get(&self, from: usize, to: usize) -> u64 {
        self.w[from * self.n + to]
    }

    /// Smallest entry — equal to the classic global
    /// [`crate::net::Fabric::min_latency`] bound (the loopback diagonal),
    /// so the adaptive matrix strictly dominates the conservative rule.
    pub fn min(&self) -> u64 {
        self.w.iter().copied().min().unwrap_or(0)
    }
}

/// Window-barrier synchronization state shared by the workers (also used
/// by the optimistic backend, `exec::opt`).
pub(crate) struct WindowSync<M> {
    pub barrier: Barrier,
    /// Per-shard earliest pending event time (u64::MAX = idle).
    pub mins: Vec<AtomicU64>,
    /// Per-destination-shard mailboxes, drained between windows.
    pub inboxes: Vec<Mutex<Vec<Transit<M>>>>,
}

impl<M> WindowSync<M> {
    pub fn new(shards: usize) -> Self {
        WindowSync {
            barrier: Barrier::new(shards),
            mins: (0..shards).map(|_| AtomicU64::new(u64::MAX)).collect(),
            inboxes: (0..shards).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }
}

/// Carve the per-node program/slowdown vectors into one [`Shard`] per
/// range, back to front so the splits are O(shards) rather than
/// O(nodes · shards).
pub(crate) fn carve_shards<P: Program>(
    ranges: &[std::ops::Range<usize>],
    mut programs: Vec<P>,
    mut slow: Vec<u32>,
    fabric: &Fabric,
    seed: u64,
) -> Vec<Shard<P>> {
    let mut shards: Vec<Shard<P>> = Vec::with_capacity(ranges.len());
    for range in ranges.iter().rev() {
        let progs = programs.split_off(range.start);
        let slows = slow.split_off(range.start);
        shards.push(Shard::new(range.clone(), progs, slows, fabric, seed));
    }
    shards.reverse();
    shards
}

/// Run `parts` on `threads` worker threads (resolved and > 1), falling
/// back to the sequential backend when sharding cannot help.
/// `window_batch` is the coalescing factor `k` (`None` = environment
/// knob / default; identical results at any value).
pub fn run_par<P: Program + Send>(
    parts: EngineParts<P>,
    threads: usize,
    window_batch: Option<usize>,
) -> RunSummary {
    let lookahead = parts.fabric.min_latency();
    let leaf_aligned = parts.fabric.cfg.oversub > 0;
    let ranges = shard_ranges(
        parts.programs.len(),
        parts.fabric.topo.leaf_radix,
        leaf_aligned,
        threads,
    );
    if ranges.len() <= 1 || lookahead == Time::ZERO {
        // Zero lookahead (degenerate config) or nothing to split:
        // conservative windows cannot make progress / cannot help.
        return run_seq(parts);
    }
    let batch = resolve_window_batch(window_batch);
    let bounds = BoundMatrix::new(&parts.fabric, &ranges);

    let EngineParts { programs, slow, fabric, core, groups, seed, pool } = parts;
    // Engines built without an explicit shared pool (direct Executor
    // calls in tests) get one sized to the shard count; a budget below
    // the shard count cannot host the workers (shard count is decided by
    // topology + threads before the pool is consulted).
    let pool = if pool.budget() >= ranges.len() {
        pool
    } else {
        std::sync::Arc::new(crate::pool::WorkerPool::new(ranges.len()))
    };
    // All-or-nothing: shard workers are claimed up front for the whole
    // run; kernel tiles inside the workers draw from what remains.
    let shard_claim =
        pool.claim_exact(ranges.len() - 1).expect("shard workers exceed the pool budget");
    let shards = carve_shards(&ranges, programs, slow, &fabric, seed);

    let sync = WindowSync::new(shards.len());
    let starts: Vec<usize> = ranges.iter().map(|r| r.start).collect();

    let shards: Vec<Shard<P>> = std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .into_iter()
            .enumerate()
            .map(|(idx, mut shard)| {
                let sync = &sync;
                let starts = &starts;
                let bounds = &bounds;
                let fabric: &Fabric = &fabric;
                let core = &core;
                let groups = &groups;
                let pool = &pool;
                scope.spawn(move || {
                    let _live = pool.enter();
                    let sx = SharedCtx { fabric, core, groups: groups.as_slice() };
                    worker(&mut shard, idx, &sx, sync, starts, bounds, batch);
                    shard
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
    });
    drop(shard_claim);

    merge_shards(shards)
}

/// Index of the shard owning `node` (ranges are contiguous + ascending).
pub(crate) fn shard_of(starts: &[usize], node: usize) -> usize {
    starts.partition_point(|&s| s <= node) - 1
}

fn worker<P: Program>(
    shard: &mut Shard<P>,
    idx: usize,
    sx: &SharedCtx<'_>,
    sync: &WindowSync<P::Msg>,
    starts: &[usize],
    bounds: &BoundMatrix,
    batch: u64,
) {
    // Per-destination-shard outboxes, flushed under one short lock each
    // at the end of every window. (`Vec::append` in the flush leaves each
    // outbox empty *with its capacity*, so these amortize for free.)
    let mut out: Vec<Vec<Transit<P::Msg>>> = (0..starts.len()).map(|_| Vec::new()).collect();
    // Recycled inbox buffer: swapped with the shared mailbox each round,
    // drained in place (§Perf: `mem::take` on the mailbox allocated a
    // fresh Vec per shard per window — thousands of reallocs per shuffle
    // round at the paper tier; the pooled pair reallocates only on
    // high-water growth).
    let mut inbox: Vec<Transit<P::Msg>> = Vec::new();

    // Round 0: fire every on_start and exchange the initial transits.
    {
        let mut emit =
            |t: Transit<P::Msg>| out[shard_of(starts, t.flight.dst as usize)].push(t);
        shard.start(sx, &mut emit);
    }
    flush(&mut out, sync, idx);
    sync.barrier.wait();

    loop {
        // Merge inbound transits (canonical-order queues make the merge
        // order irrelevant, but sort anyway so the insertion path is
        // deterministic bucket by bucket).
        std::mem::swap(&mut *sync.inboxes[idx].lock().expect("inbox"), &mut inbox);
        inbox.sort_unstable_by_key(|t| (t.flight.at, t.flight.src, t.flight.ctr));
        for t in inbox.drain(..) {
            shard.push(t);
        }

        // Publish the earliest pending event; after the barrier every
        // shard derives its own bound from the full minima vector — the
        // same deterministic inputs on every worker, no leader round.
        let own = shard.peek_at().map(|t| t.0).unwrap_or(u64::MAX);
        sync.mins[idx].store(own, Ordering::SeqCst);
        sync.barrier.wait();

        // horizon = earliest time any *other* shard could still land a
        // transit in this shard — min over others of min_B plus the
        // per-pair closure bound W[B][this] (see [`BoundMatrix`]); the
        // own cap bounds coalescing at `batch` minimum-latency windows.
        let mut horizon = u64::MAX;
        let mut all_idle = true;
        for (j, m) in sync.mins.iter().enumerate() {
            let v = m.load(Ordering::SeqCst);
            if v != u64::MAX {
                all_idle = false;
                if j != idx {
                    horizon = horizon.min(v.saturating_add(bounds.get(j, idx)));
                }
            }
        }
        if all_idle {
            return; // global quiescence
        }
        let own_cap = own.saturating_add(bounds.min().saturating_mul(batch));
        {
            // Chain guard: the horizon covers events other shards hold
            // *now*, but a transit this shard emits mid-window can wake
            // an idle shard whose reply lands as early as the transit's
            // arrival + W[dst-shard][this]. Tightening the live bound to
            // that point keeps coalesced windows closed against reply
            // chains: every event already popped ran at t < arrival, and
            // the guard lands at ≥ arrival + W[D][A] ≥ t + W[A][D] +
            // W[D][A] — above everything processed. Quiet (emission-free)
            // stretches coalesce freely up to the `batch` cap.
            let guard = std::cell::Cell::new(horizon.min(own_cap));
            let mut emit = |t: Transit<P::Msg>| {
                let d = shard_of(starts, t.flight.dst as usize);
                guard.set(guard.get().min(t.flight.at.0.saturating_add(bounds.get(d, idx))));
                out[d].push(t);
            };
            shard.run_window_dyn(sx, &|| Time(guard.get()), &mut emit);
        }
        flush(&mut out, sync, idx);
        sync.barrier.wait();
    }
}

/// Hand this window's cross-shard transits to their destination inboxes.
pub(crate) fn flush<M>(out: &mut [Vec<Transit<M>>], sync: &WindowSync<M>, own: usize) {
    for (j, buf) in out.iter_mut().enumerate() {
        debug_assert!(j != own || buf.is_empty(), "own-shard transit routed via outbox");
        if !buf.is_empty() {
            sync.inboxes[j].lock().expect("inbox").append(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_exactly_and_in_order() {
        for (nodes, threads, aligned) in
            [(100usize, 3usize, false), (2, 8, false), (256, 4, true), (65_536, 12, true)]
        {
            let ranges = shard_ranges(nodes, 64, aligned, threads);
            assert!(ranges.len() <= threads.max(1));
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, nodes);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "contiguous");
            }
            assert!(ranges.iter().all(|r| !r.is_empty()), "no empty shards after clamping");
            if aligned {
                assert!(ranges.iter().all(|r| r.start % 64 == 0), "leaf-aligned starts");
            }
        }
    }

    #[test]
    fn shard_ranges_clamp_to_fleet_and_leaves() {
        // More threads than nodes: one node per shard.
        assert_eq!(shard_ranges(2, 64, false, 16).len(), 2);
        // Leaf-aligned: a 2-leaf fleet cannot use more than 2 shards.
        assert_eq!(shard_ranges(100, 64, true, 16).len(), 2);
        // Single-leaf oversubscribed fleet: one shard (the caller then
        // falls back to the sequential backend).
        assert_eq!(shard_ranges(16, 64, true, 8).len(), 1);
        // Zero threads behaves like one.
        assert_eq!(shard_ranges(10, 64, false, 0).len(), 1);
    }

    #[test]
    fn shard_of_maps_nodes_to_their_range() {
        let ranges = shard_ranges(100, 64, false, 3);
        let starts: Vec<usize> = ranges.iter().map(|r| r.start).collect();
        for (i, r) in ranges.iter().enumerate() {
            assert_eq!(shard_of(&starts, r.start), i);
            assert_eq!(shard_of(&starts, r.end - 1), i);
        }
    }

    use crate::net::{NetConfig, Topology};

    fn paper_fabric(nodes: usize) -> Fabric {
        Fabric::new(Topology::paper(nodes), NetConfig::default(), 7)
    }

    /// Expected direct bound for a hop class, straight from the config.
    fn bound_for(f: &Fabric, links: u64, switches: u64) -> u64 {
        (f.cfg.serialization(0) + f.cfg.propagation(links, switches)).0
    }

    /// Loopback diagonal: a shard's self-bound is exactly the global
    /// conservative lookahead (2×NIC overhead + header serialization).
    #[test]
    fn matrix_diagonal_is_loopback() {
        let f = paper_fabric(256);
        let ranges = shard_ranges(256, 64, false, 4);
        let m = BoundMatrix::new(&f, &ranges);
        for i in 0..ranges.len() {
            assert_eq!(m.get(i, i), f.min_latency().0);
            assert_eq!(m.get(i, i), bound_for(&f, 0, 0));
        }
    }

    /// 4 shards × 64 nodes on radix-64 leaves: every shard is exactly one
    /// leaf, so every off-diagonal pair is cross-leaf (4 links, 3
    /// switches) — no closure path can undercut it (any relay would pay
    /// two cross-leaf hops).
    #[test]
    fn matrix_leaf_per_shard_pairs_are_cross_leaf() {
        let f = paper_fabric(256);
        let ranges = shard_ranges(256, 64, true, 4);
        assert_eq!(ranges.len(), 4);
        let m = BoundMatrix::new(&f, &ranges);
        let cross = bound_for(&f, 4, 3);
        for i in 0..4 {
            for j in 0..4 {
                if i != j {
                    assert_eq!(m.get(i, j), cross);
                }
            }
        }
    }

    /// 128 nodes split four ways (32 nodes each) on radix-64 leaves:
    /// shards 0,1 share leaf 0 and shards 2,3 share leaf 1, so those
    /// pairs get the same-leaf bound (2 links, 1 switch); pairs across
    /// the leaf boundary stay cross-leaf — and here no same-leaf relay
    /// chain connects them, so the closure leaves the direct bound.
    #[test]
    fn matrix_same_leaf_shards_get_the_wide_bound() {
        let f = paper_fabric(128);
        let ranges = shard_ranges(128, 64, false, 4);
        assert_eq!(ranges, vec![0..32, 32..64, 64..96, 96..128]);
        let m = BoundMatrix::new(&f, &ranges);
        let same = bound_for(&f, 2, 1);
        let cross = bound_for(&f, 4, 3);
        assert!(same < cross);
        assert_eq!(m.get(0, 1), same);
        assert_eq!(m.get(2, 3), same);
        assert_eq!(m.get(0, 2), cross);
        assert_eq!(m.get(1, 3), cross);
        assert_eq!(m.get(0, 3), cross);
    }

    /// Straddling shards chain the leaves together: 150 nodes in three
    /// 50-node shards put shard 1 across leaves 0 and 1, so shards 0 and
    /// 2 — though leaf-disjoint — are connected by a two-hop same-leaf
    /// relay through shard 1. At the paper constants two same-leaf hops
    /// undercut one cross-leaf hop, and the min-plus closure must take
    /// the relay path (the direct pairwise rule alone would be unsound
    /// for exactly this chain).
    #[test]
    fn matrix_closure_takes_same_leaf_relays() {
        let f = paper_fabric(150);
        let ranges = shard_ranges(150, 64, false, 3);
        assert_eq!(ranges, vec![0..50, 50..100, 100..150]);
        let m = BoundMatrix::new(&f, &ranges);
        let same = bound_for(&f, 2, 1);
        let cross = bound_for(&f, 4, 3);
        assert!(2 * same < cross, "paper constants make the relay cheaper");
        assert_eq!(m.get(0, 1), same, "shares leaf 0");
        assert_eq!(m.get(1, 2), same, "shares leaf 1");
        assert_eq!(m.get(0, 2), 2 * same, "closure through the straddler");
        assert_eq!(m.get(2, 0), 2 * same);
    }

    /// Partial last leaf: 129 nodes on radix-64 leaves puts one node on
    /// leaf 2. A final shard straddling leaves [1, 2] keeps the same-leaf
    /// bound to the leaf-1 shard (interval intersection handles ragged
    /// tails), while its bound to the leaf-0 shard stays cross-leaf — the
    /// relay through shard 1 (same + cross) can't beat direct cross-leaf.
    #[test]
    fn matrix_partial_last_leaf() {
        let f = paper_fabric(129);
        let ranges = vec![0..64, 64..120, 120..129];
        let m = BoundMatrix::new(&f, &ranges);
        assert_eq!(m.get(1, 2), bound_for(&f, 2, 1), "leaf-1 overlap");
        assert_eq!(m.get(0, 1), bound_for(&f, 4, 3));
        assert_eq!(m.get(2, 0), bound_for(&f, 4, 3));
        assert_eq!(m.get(0, 2), bound_for(&f, 4, 3));
    }

    /// Adaptive ⊇ conservative: the matrix minimum equals the old global
    /// `min_latency` bound on every fleet shape, so every per-pair window
    /// is at least as wide as the rule it replaces.
    #[test]
    fn matrix_minimum_is_the_conservative_global_bound() {
        for (nodes, threads, aligned) in
            [(256usize, 4usize, false), (128, 4, false), (150, 3, false), (256, 4, true), (129, 7, false)]
        {
            let f = paper_fabric(nodes);
            let ranges = shard_ranges(nodes, 64, aligned, threads);
            let m = BoundMatrix::new(&f, &ranges);
            assert_eq!(m.min(), f.min_latency().0);
            for i in 0..ranges.len() {
                for j in 0..ranges.len() {
                    assert!(m.get(i, j) >= f.min_latency().0, "adaptive below conservative");
                }
            }
        }
    }

    #[test]
    fn window_batch_resolution_prefers_explicit_and_clamps() {
        assert_eq!(resolve_window_batch(Some(7)), 7);
        assert_eq!(resolve_window_batch(Some(1)), 1);
        // k = 0 would process nothing forever; clamp to identity.
        assert_eq!(resolve_window_batch(Some(0)), 1);
        // No explicit setting: env var or default, both ≥ 1. (The env
        // value itself is read-only here — tests must not mutate process
        // environment under a parallel test harness.)
        assert!(resolve_window_batch(None) >= 1);
    }
}
