//! The engine core shared by every executor backend: per-node hot/cold
//! state, the timing-wheel event queue, the reorder buffer, stats arenas,
//! and the deliver/invoke machinery — everything below the scheduling
//! policy.
//!
//! A [`Shard`] owns a contiguous node range plus that range's fabric
//! endpoint state ([`TxLane`]/[`RxLane`]). The sequential backend runs one
//! shard covering every node; the parallel backend runs one shard per
//! worker thread. All cross-shard traffic travels as [`Transit`] values
//! and every queue orders by the canonical key `(at, src, ctr)`, so the
//! per-shard state machines are **identical under any sharding** — that
//! is the determinism contract (DESIGN.md §7) the executor equivalence
//! tests pin.

use std::collections::BTreeMap;

use crate::cpu::CoreModel;
use crate::nanopu::{Ctx, Group, NodeId, Program, SendOp, WireMsg};
use crate::net::{Fabric, Flight, NetStats, RxLane, TxLane};

use super::super::rng::SplitMix64;
use super::super::time::Time;

/// Cycles to store one out-of-order message into the reorder buffer.
const REORDER_STORE_CYCLES: u64 = 4;
/// Cycles to pop one message out of the reorder buffer.
const REORDER_POP_CYCLES: u64 = 6;
/// Maximum number of stages tracked per node (Fig 16 breakdown).
pub const MAX_STAGES: usize = 16;

/// One in-flight message: the sender-side [`Flight`] plus what arrives
/// at the destination ([`TransitKind`]).
#[derive(Clone)]
pub(crate) struct Transit<M> {
    pub flight: Flight,
    pub kind: TransitKind<M>,
}

/// What a [`Transit`] delivers (DESIGN.md §12: the loopback leg of a
/// multicast carries no payload at all).
#[derive(Clone)]
pub(crate) enum TransitKind<M> {
    /// A fabric-crossing message: admitted at the destination (spine +
    /// ingress queueing in canonical order), then invoked.
    Msg(M),
    /// A multicast self-leg — it occupies the ingress link and counts as
    /// a delivery (the switch really replicates the packet back down) but
    /// never reaches the handler, so it carries only the wire size the
    /// admission charge needs instead of a payload clone.
    Phantom { payload_bytes: u64 },
    /// Core-local timer self-delivery: skips the destination-side fabric
    /// phase entirely (no admit, no ingress occupancy, no net counters) —
    /// the flight's `at` *is* the delivery time.
    Timer(M),
}

/// Heap entry: the canonical ordering key `(at, src, ctr)` plus the slab
/// slot of the payload. The payload lives in [`EventSlab`] so the
/// calendar queue sifts small, cache-friendly elements — this is the
/// simulator's top hot path (§Perf: `BinaryHeap::pop` was 64% of the
/// headline run before this split).
#[derive(PartialEq, Eq, Clone, Copy)]
struct Event {
    at: Time,
    src: u32,
    ctr: u64,
    slot: u32,
}

impl Event {
    fn key(&self) -> (Time, u32, u64) {
        (self.at, self.src, self.ctr)
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key().cmp(&other.key())
    }
}

/// Number of recycled level-1 far-window slots (one aligned ring span
/// each): 64 slots × 262 µs = ~16.8 ms of level-1 reach before the
/// `BTreeMap` overflow tier is touched at all.
const FAR_SLOTS: usize = 64;

/// One bucket of the near ring. When `sorted`, events are descending by
/// the canonical key so the next event pops from the back in O(1).
struct Bucket {
    events: Vec<Event>,
    sorted: bool,
}

/// One recycled level-1 slot: an aligned far window's events, in push
/// order. `window` is meaningful only while `events` is non-empty; the
/// Vec's capacity survives re-homing, so a steady-state orbit of far
/// pushes allocates nothing.
struct FarSlot {
    window: u64,
    events: Vec<Event>,
}

/// Hierarchical timing wheel: a near ring of per-4ns-bucket mini-arrays
/// (level 0), a fixed ring of recycled far-window slots (level 1), and a
/// `BTreeMap` overflow for the pathological far future (level 2).
///
/// §Perf: a single `BinaryHeap` over ~1M in-flight events spent >60% of
/// the headline run in `pop` (20 sift levels of cache misses). Event
/// *lookahead* (arrival − now) is bounded by propagation + endpoint-link
/// queueing (µs-scale), so bucketing by coarse time keeps every touched
/// mini-array tiny and cache-resident; the cursor only moves forward.
/// The predecessor `CalendarQueue` (retained under `#[cfg(test)]` as the
/// differential reference) kept its far tier solely in a `BTreeMap`,
/// paying a node allocation per far push and dropping each window's Vec
/// after re-homing; the level-1 slot ring recycles both, so steady-state
/// rounds allocate zero (pinned by the engine's zero-alloc test).
///
/// §Scale: events beyond the ring window live in a far tier keyed by the
/// aligned window index (`bucket >> ring_bits`). Windows within
/// [`FAR_SLOTS`] spans of the cursor land in their level-1 slot (index
/// `window % FAR_SLOTS` — injective over the reachable range, see
/// [`TimingWheel::push`]); anything further lands in the overflow map,
/// whose drained Vecs are recycled through `spare`. When the cursor
/// crosses a window boundary the window is re-homed wholesale into the
/// ring **from both far tiers** — a window can be split across them when
/// the cursor's advance moved it into level-1 reach after overflow
/// pushes. Ordering is exact: windows and buckets partition time, and
/// each bucket orders by the canonical `(at, src, ctr)` key — identical
/// results to one global heap (differentially tested against the
/// reference queue).
///
/// §Exec: [`TimingWheel::pop_before`] bounds how far the cursor may
/// advance, so the parallel executor can drain exactly one conservative
/// time window and still accept later cross-shard pushes behind the next
/// window boundary. [`TimingWheel::peek_at`] reports the earliest event
/// time without moving the cursor (cached; invalidated by pops).
struct TimingWheel {
    ring: Vec<Bucket>,
    /// log2 of time-units per bucket (6 => 64 units = 4 ns).
    g_shift: u32,
    /// Ring size mask (ring.len() - 1).
    mask: u64,
    /// log2 of the ring length — the aligned far-window width.
    ring_bits: u32,
    /// Absolute bucket index the cursor is on.
    cur: u64,
    /// Level 1: recycled slots for far windows within `FAR_SLOTS` spans
    /// of the cursor, indexed by `window % FAR_SLOTS`.
    far_ring: Vec<FarSlot>,
    /// Level 2: aligned window index → events, for windows beyond the
    /// level-1 reach. Re-homed (with the level-1 slot) at window entry.
    overflow: BTreeMap<u64, Vec<Event>>,
    /// Recycled Vec capacities from drained overflow windows.
    spare: Vec<Vec<Event>>,
    /// Events currently resident in the near ring (vs the far tiers).
    ring_count: usize,
    len: usize,
    /// Cached earliest event time (None = unknown, recompute on demand).
    peek_cache: Option<Time>,
}

impl TimingWheel {
    /// 2^16 buckets x 4 ns = 262 µs of near-ring lookahead window.
    fn new() -> Self {
        let ring_bits = 16u32;
        let buckets = 1usize << ring_bits;
        TimingWheel {
            ring: (0..buckets).map(|_| Bucket { events: Vec::new(), sorted: true }).collect(),
            g_shift: 6,
            mask: (buckets - 1) as u64,
            ring_bits,
            cur: 0,
            far_ring: (0..FAR_SLOTS).map(|_| FarSlot { window: 0, events: Vec::new() }).collect(),
            overflow: BTreeMap::new(),
            spare: Vec::new(),
            ring_count: 0,
            len: 0,
            peek_cache: None,
        }
    }

    fn bucket_of(&self, at: Time) -> u64 {
        at.0 >> self.g_shift
    }

    /// Land one event in the near ring (its bucket must lie within one
    /// ring span of the cursor).
    fn home(&mut self, ev: Event) {
        let b = self.bucket_of(ev.at);
        debug_assert!(b >= self.cur && b < self.cur + self.ring.len() as u64);
        let bucket = &mut self.ring[(b & self.mask) as usize];
        bucket.events.push(ev);
        bucket.sorted = false;
        self.ring_count += 1;
    }

    fn push(&mut self, ev: Event) {
        let b = self.bucket_of(ev.at);
        debug_assert!(b >= self.cur, "event scheduled in the past");
        self.len += 1;
        if let Some(cache) = self.peek_cache {
            self.peek_cache = Some(cache.min(ev.at));
        }
        if b < self.cur + self.ring.len() as u64 {
            self.home(ev);
            return;
        }
        // Far event. Level-1 residency argument: a slot holds window `w'`
        // only while `cur_window < w' <= cur_window + FAR_SLOTS` (it was
        // in that range when pushed, the cursor only advances between
        // bursts, and window entry re-homes the slot), so two distinct
        // windows in the reachable range can never share `w % FAR_SLOTS`
        // — the range spans exactly FAR_SLOTS values.
        let w = b >> self.ring_bits;
        let cur_window = self.cur >> self.ring_bits;
        if w <= cur_window + FAR_SLOTS as u64 {
            let slot = &mut self.far_ring[(w % FAR_SLOTS as u64) as usize];
            if slot.events.is_empty() {
                slot.window = w;
            }
            debug_assert!(slot.window == w, "far-ring slot collision");
            slot.events.push(ev);
            return;
        }
        match self.overflow.entry(w) {
            std::collections::btree_map::Entry::Occupied(mut e) => e.get_mut().push(ev),
            std::collections::btree_map::Entry::Vacant(e) => {
                let mut events = self.spare.pop().unwrap_or_default();
                events.push(ev);
                e.insert(events);
            }
        }
    }

    /// Move one far window's events into the ring — from its level-1 slot
    /// *and* the overflow map (the window can be split across both). Only
    /// called once the cursor has entered (or is jumping to) that aligned
    /// window, at which point every event's bucket lies within the ring's
    /// lookahead. Both containers' capacities are recycled.
    fn rehome(&mut self, window: u64) {
        let idx = (window % FAR_SLOTS as u64) as usize;
        if self.far_ring[idx].window == window && !self.far_ring[idx].events.is_empty() {
            let mut events = std::mem::take(&mut self.far_ring[idx].events);
            for ev in events.drain(..) {
                self.home(ev);
            }
            self.far_ring[idx].events = events; // hand the capacity back
        }
        if let Some(mut events) = self.overflow.remove(&window) {
            for ev in events.drain(..) {
                self.home(ev);
            }
            self.spare.push(events);
        }
    }

    /// Earliest populated far window across both far tiers (a 64-slot
    /// scan plus the overflow map's first key).
    fn first_far_window(&self) -> Option<u64> {
        let mut min_w: Option<u64> = None;
        for slot in &self.far_ring {
            if !slot.events.is_empty() {
                min_w = Some(min_w.map_or(slot.window, |m| m.min(slot.window)));
            }
        }
        if let Some((&w, _)) = self.overflow.iter().next() {
            min_w = Some(min_w.map_or(w, |m| m.min(w)));
        }
        min_w
    }

    /// Earliest event time within far window `w`, across both far tiers.
    fn far_window_min(&self, w: u64) -> Option<Time> {
        let slot = &self.far_ring[(w % FAR_SLOTS as u64) as usize];
        let slot_min = if slot.window == w {
            slot.events.iter().map(|e| e.at).min()
        } else {
            None
        };
        let over_min =
            self.overflow.get(&w).and_then(|events| events.iter().map(|e| e.at).min());
        match (slot_min, over_min) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, None) => a,
            (None, b) => b,
        }
    }

    /// Earliest event time in the queue, without advancing the cursor
    /// (safe to call even when later out-of-window pushes are still
    /// expected). O(1) when the cache is warm; otherwise a forward scan
    /// from the cursor, amortized by the cursor's own monotone walk.
    ///
    /// The earliest *far* window must be consulted too: once the cursor
    /// has advanced into the aligned window *before* it, the ring's
    /// bucket range overlaps the window's — a ring bucket can hold a
    /// later event than an un-rehomed far one, and reporting the ring
    /// minimum alone would inflate the parallel executor's window bound
    /// and break the conservative-window closure. (Re-homing is still
    /// deferred to the cursor crossing: a window's *late* events may not
    /// fit the ring yet.) Later far windows start at or beyond the first
    /// one's end, so only the first can compete.
    fn peek_at(&mut self) -> Option<Time> {
        if self.len == 0 {
            return None;
        }
        if let Some(t) = self.peek_cache {
            return Some(t);
        }
        let ring_min = if self.ring_count == 0 {
            None
        } else {
            let mut i = self.cur;
            loop {
                let b = &self.ring[(i & self.mask) as usize];
                if !b.events.is_empty() {
                    break Some(
                        b.events.iter().map(|e| e.at).min().expect("non-empty bucket"),
                    );
                }
                i += 1;
            }
        };
        let far_min = self.first_far_window().and_then(|window| {
            let wstart = window << self.ring_bits;
            if ring_min.is_some_and(|t| wstart > self.bucket_of(t)) {
                None
            } else {
                self.far_window_min(window)
            }
        });
        let t = match (ring_min, far_min) {
            (Some(r), Some(f)) => r.min(f),
            (Some(r), None) => r,
            (None, Some(f)) => f,
            (None, None) => unreachable!("len > 0 but no events"),
        };
        self.peek_cache = Some(t);
        Some(t)
    }

    /// Pop the next event in canonical order, but only if its time is
    /// `< bound`; the cursor never advances past `bound`'s bucket, so
    /// events `>= bound` (the only kind a conservative window can still
    /// produce) remain pushable. `Time(u64::MAX)` = unbounded (the
    /// sequential backend's drain-to-quiescence).
    fn pop_before(&mut self, bound: Time) -> Option<Event> {
        if self.len == 0 || bound == Time::ZERO {
            return None;
        }
        // Last bucket that can hold an event strictly before `bound`.
        let limit = (bound.0 - 1) >> self.g_shift;
        loop {
            if self.ring_count == 0 {
                // Everything left lives in the far tiers: fast-forward
                // the cursor to the first populated window and re-home it
                // wholesale — unless that window lies beyond the bound.
                let Some(window) = self.first_far_window() else { return None };
                let wstart = window << self.ring_bits;
                if wstart > limit {
                    return None;
                }
                self.cur = self.cur.max(wstart);
                self.rehome(window);
                continue;
            }
            if self.cur > limit {
                return None;
            }
            let bucket = &mut self.ring[(self.cur & self.mask) as usize];
            if !bucket.events.is_empty() {
                if !bucket.sorted {
                    // Sort once per drain; a mid-drain insert re-sorts the
                    // (small) remainder. Descending so pops come off the
                    // back. Safe: inserts-while-draining always carry
                    // `at` >= the last popped time (positive latency).
                    bucket.events.sort_unstable_by(|a, b| b.key().cmp(&a.key()));
                    bucket.sorted = true;
                }
                let next = bucket.events.last().expect("non-empty bucket");
                if next.at >= bound {
                    // Mid-bucket bound: the rest of this bucket belongs to
                    // a later window. Leave the cursor here.
                    return None;
                }
                self.len -= 1;
                self.ring_count -= 1;
                self.peek_cache = None;
                return bucket.events.pop();
            }
            if self.cur == limit {
                // Never walk past the last in-bound bucket: when `bound`
                // is not bucket-aligned, a later push at `at >= bound` can
                // still land in this bucket (`at >> g_shift == limit`), and
                // a cursor beyond it would reject that push as "scheduled
                // in the past" (and alias its ring slot a full span later).
                return None;
            }
            self.cur += 1;
            if self.cur & self.mask == 0 {
                // Entered a new aligned window: its far events (if any)
                // can now land in the ring before the cursor reaches them.
                self.rehome(self.cur >> self.ring_bits);
            }
        }
    }

    /// Highest pop bound a speculative burst may use such that rewinding
    /// the cursor afterwards is sound: the start of the next aligned far
    /// window. Under any bound `<=` this, `pop_before` can never re-home
    /// a far window (far windows begin at or beyond the boundary) and the
    /// cursor never crosses the window boundary, so every popped event's
    /// bucket stays within one ring span of the saved cursor and a
    /// rollback can re-push it verbatim without ring aliasing. The
    /// level-1 residency invariant survives too: no pushes reach the
    /// wheel mid-burst (the shard diverts all emissions), and the rewind
    /// restores the exact cursor the resident slots were admitted under.
    fn spec_fence(&self) -> Time {
        let boundary = ((self.cur >> self.ring_bits) + 1) << self.ring_bits;
        Time(boundary << self.g_shift)
    }

    /// Rewind the cursor to a position saved before a speculative burst
    /// bounded by [`TimingWheel::spec_fence`]. The caller re-pushes the
    /// burst's pops afterwards.
    fn rewind(&mut self, cursor: u64) {
        debug_assert!(cursor <= self.cur);
        self.cur = cursor;
        self.peek_cache = None;
    }
}

/// Free-listed payload storage for in-flight transits (u32 slots keep the
/// heap entry compact; in-flight counts are <= 2^32 by construction).
struct EventSlab<M> {
    payloads: Vec<Option<Transit<M>>>,
    free: Vec<u32>,
}

impl<M> EventSlab<M> {
    fn new() -> Self {
        EventSlab { payloads: Vec::new(), free: Vec::new() }
    }

    fn insert(&mut self, t: Transit<M>) -> u32 {
        if let Some(slot) = self.free.pop() {
            self.payloads[slot as usize] = Some(t);
            slot
        } else {
            self.payloads.push(Some(t));
            (self.payloads.len() - 1) as u32
        }
    }

    fn remove(&mut self, slot: u32) -> Transit<M> {
        let t = self.payloads[slot as usize].take().expect("slot occupied");
        self.free.push(slot);
        t
    }
}

/// Timing wheel + payload slab, keyed by the canonical `(at, src, ctr)`
/// order. One per shard.
pub(crate) struct EventQueue<M> {
    wheel: TimingWheel,
    slab: EventSlab<M>,
}

impl<M> EventQueue<M> {
    pub fn new() -> Self {
        EventQueue { wheel: TimingWheel::new(), slab: EventSlab::new() }
    }

    pub fn push(&mut self, t: Transit<M>) {
        let ev = Event {
            at: t.flight.at,
            src: t.flight.src,
            ctr: t.flight.ctr,
            slot: 0,
        };
        let slot = self.slab.insert(t);
        self.wheel.push(Event { slot, ..ev });
    }

    pub fn peek_at(&mut self) -> Option<Time> {
        self.wheel.peek_at()
    }

    pub fn pop_before(&mut self, bound: Time) -> Option<Transit<M>> {
        self.wheel.pop_before(bound).map(|ev| self.slab.remove(ev.slot))
    }

    pub fn is_empty(&self) -> bool {
        self.wheel.len == 0
    }

    /// Opaque cursor token for [`EventQueue::rewind`].
    pub fn cursor(&self) -> u64 {
        self.wheel.cur
    }

    /// The cursor position corresponding to `at`'s bucket.
    pub fn cursor_of(&self, at: Time) -> u64 {
        self.wheel.bucket_of(at)
    }

    /// See [`TimingWheel::spec_fence`].
    pub fn spec_fence(&self) -> Time {
        self.wheel.spec_fence()
    }

    /// See [`TimingWheel::rewind`].
    pub fn rewind(&mut self, cursor: u64) {
        self.wheel.rewind(cursor);
    }
}

/// Per-node accounting (drives Figs 15b and 16).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NodeStats {
    /// Busy time attributed to each stage.
    pub busy: [Time; MAX_STAGES],
    /// Idle (waiting-for-message) time attributed to each stage.
    pub idle: [Time; MAX_STAGES],
    /// Messages processed.
    pub msgs_in: u64,
    /// Messages sent.
    pub msgs_out: u64,
    /// Last time this node did any work.
    pub last_active: Time,
    /// Stage at which the node declared itself finished.
    pub finished: bool,
}

impl Default for NodeStats {
    fn default() -> Self {
        NodeStats {
            busy: [Time::ZERO; MAX_STAGES],
            idle: [Time::ZERO; MAX_STAGES],
            msgs_in: 0,
            msgs_out: 0,
            last_active: Time::ZERO,
            finished: false,
        }
    }
}

impl NodeStats {
    pub fn total_busy(&self) -> Time {
        Time(self.busy.iter().map(|t| t.0).sum())
    }
    pub fn total_idle(&self) -> Time {
        Time(self.idle.iter().map(|t| t.0).sum())
    }
}

/// Hot per-node scheduling state: everything the deliver/invoke path
/// mutates on every event, packed into a flat arena so the top of the
/// event loop touches one cache line per node instead of the full
/// program + stats struct (§Scale). The stage and finished flag share
/// one byte (stage needs 4 bits — [`MAX_STAGES`] is 16 — and finished
/// is bit 7), so a HotNode is 9 B payload instead of 16 and the hyper
/// tier's 2^20-entry arena stays under 10 MB.
#[derive(Clone, Copy)]
struct HotNode {
    busy_until: Time,
    /// Bit 7 = finished; low 4 bits = stage.
    packed: u8,
}

impl HotNode {
    const FINISHED: u8 = 0x80;
    const STAGE_MASK: u8 = (MAX_STAGES - 1) as u8;

    fn stage(self) -> u8 {
        self.packed & Self::STAGE_MASK
    }

    fn finished(self) -> bool {
        self.packed & Self::FINISHED != 0
    }

    fn set(&mut self, stage: u8, finished: bool) {
        debug_assert!(stage < MAX_STAGES as u8);
        self.packed = (stage & Self::STAGE_MASK) | if finished { Self::FINISHED } else { 0 };
    }
}

/// Cold per-node state: the program itself, its RNG stream, and the
/// reorder buffer (touched only on delivery to *this* node).
struct NodeSlot<P: Program> {
    prog: P,
    rng: SplitMix64,
    /// Reorder buffer: (step, src, msg), kept in arrival order. The
    /// source id is stored at fabric width (`u32`, see [`Flight`]).
    held: Vec<(u32, u32, P::Msg)>,
}

/// Outcome of a completed run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    /// Latest busy-until across all nodes (the job completion time).
    pub makespan: Time,
    /// Per-node accounting.
    pub node_stats: Vec<NodeStats>,
    /// Fabric counters.
    pub net: NetStats,
    /// Total events processed (engine-level, for perf work).
    pub events: u64,
    /// Executor-side observability counters. **Never** part of a digest
    /// or rendered report: backends legitimately differ here (rollback
    /// counts, barrier rounds) while everything above must not.
    pub profile: ExecProfile,
}

/// Speculation/scheduling counters for one run. All zero for the
/// sequential backend; the optimistic backend fills every field and the
/// BENCH records surface `rollbacks` and `committed_window_avg`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExecProfile {
    /// Barrier rounds driven (parallel backends).
    pub rounds: u64,
    /// Speculative bursts begun.
    pub speculated: u64,
    /// Bursts committed.
    pub committed: u64,
    /// Bursts rolled back (straggler message, uncovered horizon, or the
    /// test-only forced hook).
    pub rollbacks: u64,
    /// Sum over committed bursts of (last − first) speculated event time.
    pub committed_span: u64,
}

impl ExecProfile {
    pub fn merge(&mut self, other: &ExecProfile) {
        self.rounds = self.rounds.max(other.rounds);
        self.speculated += other.speculated;
        self.committed += other.committed;
        self.rollbacks += other.rollbacks;
        self.committed_span += other.committed_span;
    }

    /// Mean committed speculative burst span, in time units.
    pub fn committed_window_avg(&self) -> f64 {
        if self.committed == 0 {
            0.0
        } else {
            self.committed_span as f64 / self.committed as f64
        }
    }
}

impl RunSummary {
    /// Mean busy fraction across nodes (busy / makespan).
    pub fn mean_utilization(&self) -> f64 {
        if self.makespan == Time::ZERO || self.node_stats.is_empty() {
            return 0.0;
        }
        let total: f64 = self.node_stats.iter().map(|s| s.total_busy().0 as f64).sum();
        total / (self.makespan.0 as f64 * self.node_stats.len() as f64)
    }
}

/// Run-wide state shared read-only across shards.
pub(crate) struct SharedCtx<'a> {
    pub fabric: &'a Fabric,
    pub core: &'a CoreModel,
    pub groups: &'a [Group],
}

/// One executor shard: a contiguous node range with its programs, hot and
/// stats arenas, event queue, and fabric endpoint lanes. Shards never
/// touch each other's state; they communicate only through [`Transit`]s
/// handed to the `emit` hook (and even that hook is unreachable in the
/// single-shard sequential configuration).
pub(crate) struct Shard<P: Program> {
    pub range: std::ops::Range<usize>,
    nodes: Vec<NodeSlot<P>>,
    /// Per-node compute slowdown factor (1 = nominal; straggler
    /// perturbation layer), applied to every cycle-to-time conversion.
    slow: Vec<u32>,
    /// Flat hot-state arena, indexed by node - range.start (§Scale).
    hot: Vec<HotNode>,
    /// Flat stats arena; handed to [`RunSummary`] without a copy.
    pub stats: Vec<NodeStats>,
    queue: EventQueue<P::Msg>,
    tx: TxLane,
    rx: RxLane,
    pub net: NetStats,
    pub events: u64,
    /// Scratch buffer for handler-emitted ops (reused across invokes —
    /// §Perf: one Vec alloc/free per delivered message otherwise).
    ops_scratch: Vec<(u64, SendOp<P::Msg>)>,
    /// When set (speculative bursts only), *every* emission — own-shard
    /// sends and timers included — is handed to the `emit` hook instead of
    /// the local queue, so the caller can buffer it until the burst
    /// commits (DESIGN.md §10).
    divert: bool,
}

impl<P: Program> Shard<P> {
    /// Build one shard over `programs` for the absolute node range
    /// `range` (`programs[i]` runs node `range.start + i`).
    pub fn new(
        range: std::ops::Range<usize>,
        programs: Vec<P>,
        slow: Vec<u32>,
        fabric: &Fabric,
        seed: u64,
    ) -> Self {
        assert_eq!(programs.len(), range.len());
        assert_eq!(slow.len(), range.len());
        let root = SplitMix64::new(seed);
        let base = range.start;
        let nodes = programs
            .into_iter()
            .enumerate()
            .map(|(i, prog)| NodeSlot {
                prog,
                // Streams derive from the absolute node id, so they are
                // identical under any sharding.
                rng: root.derive((base + i) as u64),
                held: Vec::new(),
            })
            .collect();
        Shard {
            nodes,
            slow,
            hot: vec![HotNode { busy_until: Time::ZERO, packed: 0 }; range.len()],
            stats: vec![NodeStats::default(); range.len()],
            queue: EventQueue::new(),
            tx: fabric.tx_lane(range.clone()),
            rx: fabric.rx_lane(range.clone()),
            net: NetStats::default(),
            events: 0,
            ops_scratch: Vec::new(),
            divert: false,
            range,
        }
    }

    fn ix(&self, id: NodeId) -> usize {
        debug_assert!(self.range.contains(&id));
        id - self.range.start
    }

    fn owns(&self, id: usize) -> bool {
        self.range.contains(&id)
    }

    /// Accept a transit produced by another shard.
    pub fn push(&mut self, t: Transit<P::Msg>) {
        debug_assert!(self.owns(t.flight.dst as usize));
        self.queue.push(t);
    }

    /// Earliest pending event time (for the window-bound computation).
    pub fn peek_at(&mut self) -> Option<Time> {
        self.queue.peek_at()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty()
    }

    /// Fire every node's `on_start` at t=0, in node-id order (the cluster
    /// is pre-loaded and triggered together, like the paper's benchmark
    /// start).
    pub fn start(&mut self, sx: &SharedCtx<'_>, emit: &mut impl FnMut(Transit<P::Msg>)) {
        for id in self.range.clone() {
            self.invoke(sx, id, Time::ZERO, None, emit);
            self.drain_reorder(sx, id, emit);
        }
    }

    /// Pop and process every queued transit with `at < bound`, in
    /// canonical order. `Time(u64::MAX)` = run to quiescence.
    pub fn run_window(
        &mut self,
        sx: &SharedCtx<'_>,
        bound: Time,
        emit: &mut impl FnMut(Transit<P::Msg>),
    ) {
        self.run_window_dyn(sx, &|| bound, emit);
    }

    /// [`Shard::run_window`] with a bound re-read before every pop. The
    /// parallel backends' coalesced windows tighten it mid-drain when an
    /// emission opens a potential cross-shard reply chain (the chain
    /// guard, see `exec::par`). The bound may only shrink, and a
    /// tightening triggered by an event processed at `t` can never land
    /// below `t` plus a full cross-shard round trip through the bound
    /// matrix — above every event already popped — so completed pops stay
    /// valid.
    pub fn run_window_dyn(
        &mut self,
        sx: &SharedCtx<'_>,
        bound: &impl Fn() -> Time,
        emit: &mut impl FnMut(Transit<P::Msg>),
    ) {
        while let Some(t) = self.queue.pop_before(bound()) {
            self.events += 1;
            let (src, dst) = (t.flight.src as usize, t.flight.dst as usize);
            // Destination-side fabric phase: spine + ingress queueing, in
            // canonical order per destination. Timers never crossed the
            // fabric, so they bypass admission and fire at their own time.
            match t.kind {
                TransitKind::Timer(msg) => self.deliver(sx, t.flight.at, src, dst, msg, emit),
                TransitKind::Msg(msg) => {
                    let arrival = sx
                        .fabric
                        .admit(&mut self.rx, &mut self.net, &t.flight, msg.wire_bytes());
                    self.deliver(sx, arrival, src, dst, msg, emit);
                }
                TransitKind::Phantom { payload_bytes } => {
                    // Multicast self-leg: delivered, never invoked.
                    sx.fabric.admit(&mut self.rx, &mut self.net, &t.flight, payload_bytes);
                }
            }
        }
    }

    fn deliver(
        &mut self,
        sx: &SharedCtx<'_>,
        at: Time,
        src: NodeId,
        dst: NodeId,
        msg: P::Msg,
        emit: &mut impl FnMut(Transit<P::Msg>),
    ) {
        let i = self.ix(dst);
        let step = msg.step();
        if step > self.nodes[i].prog.step() {
            // Future-step message: RX + store into the reorder buffer.
            let sf = self.slow[i] as u64;
            let hot = &mut self.hot[i];
            let st = &mut self.stats[i];
            let start = at.max(hot.busy_until);
            let idle = start.saturating_sub(hot.busy_until);
            let stage = hot.stage() as usize;
            st.idle[stage] += idle;
            let cost = Time::from_cycles(
                (sx.core.rx_cycles(msg.wire_bytes()) + REORDER_STORE_CYCLES) * sf,
            );
            hot.busy_until = start + cost;
            st.busy[stage] += cost;
            st.last_active = hot.busy_until;
            st.msgs_in += 1;
            self.nodes[i].held.push((step, src as u32, msg));
            return;
        }
        self.invoke(sx, dst, at, Some((src, msg, true)), emit);
        self.drain_reorder(sx, dst, emit);
    }

    /// Re-deliver buffered messages whose step has become current.
    fn drain_reorder(
        &mut self,
        sx: &SharedCtx<'_>,
        id: NodeId,
        emit: &mut impl FnMut(Transit<P::Msg>),
    ) {
        let i = self.ix(id);
        loop {
            let cur = self.nodes[i].prog.step();
            let pos = self.nodes[i].held.iter().position(|(s, _, _)| *s <= cur);
            let Some(pos) = pos else { break };
            let (_, src, msg) = self.nodes[i].held.remove(pos);
            let at = self.hot[i].busy_until;
            self.invoke_held(sx, id, at, src as usize, msg, emit);
        }
    }

    fn invoke_held(
        &mut self,
        sx: &SharedCtx<'_>,
        id: NodeId,
        at: Time,
        src: NodeId,
        msg: P::Msg,
        emit: &mut impl FnMut(Transit<P::Msg>),
    ) {
        let i = self.ix(id);
        // Pop cost instead of RX (already read off the NIC at arrival).
        let pop = Time::from_cycles(REORDER_POP_CYCLES * self.slow[i] as u64);
        let resume = {
            let hot = &mut self.hot[i];
            hot.busy_until = at.max(hot.busy_until) + pop;
            hot.busy_until
        };
        self.invoke(sx, id, resume, Some((src, msg, false)), emit);
    }

    /// Core of the model: run one handler and apply its effects.
    fn invoke(
        &mut self,
        sx: &SharedCtx<'_>,
        id: NodeId,
        at: Time,
        input: Option<(NodeId, P::Msg, bool)>,
        emit: &mut impl FnMut(Transit<P::Msg>),
    ) {
        let i = self.ix(id);
        let sf = self.slow[i] as u64;
        let slot = &mut self.nodes[i];
        let hot = &mut self.hot[i];
        let st = &mut self.stats[i];
        let start = at.max(hot.busy_until);
        // Idle attribution: waiting between end of previous work and start.
        let idle = start.saturating_sub(hot.busy_until);
        if input.is_some() {
            st.idle[hot.stage() as usize] += idle;
        }

        let mut entry = start;
        let charge_rx = matches!(&input, Some((_, _, true)));
        if let Some((_, msg, _)) = &input {
            if charge_rx {
                entry += Time::from_cycles(sx.core.rx_cycles(msg.wire_bytes()) * sf);
            }
            st.msgs_in += 1;
        }

        let mut stage = hot.stage();
        let mut finished = hot.finished();
        debug_assert!(self.ops_scratch.is_empty());
        let mut ctx = Ctx {
            node: id,
            core: sx.core,
            rng: &mut slot.rng,
            entry,
            cycles: 0,
            ops: std::mem::take(&mut self.ops_scratch),
            stage: &mut stage,
            finished: &mut finished,
            mcast_supported: sx.fabric.multicast_supported(),
        };
        let was_msg = input.is_some();
        match input {
            Some((src, msg, _)) => slot.prog.on_message(&mut ctx, src, msg),
            None => slot.prog.on_start(&mut ctx),
        }
        let cycles = ctx.cycles;
        let ops = std::mem::take(&mut ctx.ops);
        drop(ctx);

        let end = entry + Time::from_cycles(cycles * sf);
        let busy_span = end.saturating_sub(start);
        st.busy[hot.stage() as usize] += busy_span;
        hot.set(stage, finished);
        st.finished = finished;
        hot.busy_until = end;
        if busy_span > Time::ZERO || was_msg {
            st.last_active = end;
        }
        st.msgs_out += ops.len() as u64;

        // Hand sends to the fabric at the local time they were issued.
        let mut ops = ops;
        for (cyc_offset, op) in ops.drain(..) {
            let ready = entry + Time::from_cycles(cyc_offset * sf);
            match op {
                SendOp::Unicast { dst, msg } => {
                    let flight = sx.fabric.send(
                        &mut self.tx,
                        &mut self.net,
                        id,
                        dst,
                        msg.wire_bytes(),
                        ready,
                    );
                    self.route(flight, TransitKind::Msg(msg), emit);
                }
                SendOp::Timer { delay, msg } => {
                    // Core-local self-delivery: mint a canonical flight at
                    // the absolute fire time, never touching the fabric.
                    let flight = sx.fabric.timer(&mut self.tx, id, ready + delay);
                    self.route(flight, TransitKind::Timer(msg), emit);
                }
                SendOp::Multicast { group, msg } => {
                    // The packet serializes once at the sender; every
                    // member gets its own leg (and the sender's own copy
                    // travels as a phantom: it holds the downlink and
                    // counts as delivered but is never invoked — so the
                    // loopback leg carries the wire size, not a payload
                    // clone).
                    let payload_bytes = msg.wire_bytes();
                    let on_wire = sx.fabric.mcast_depart(
                        &mut self.tx,
                        &mut self.net,
                        id,
                        payload_bytes,
                        ready,
                    );
                    for dst in sx.groups[group].iter() {
                        let flight =
                            sx.fabric.mcast_leg(&mut self.tx, &mut self.net, id, dst, on_wire);
                        let kind = if dst == id {
                            TransitKind::Phantom { payload_bytes }
                        } else {
                            TransitKind::Msg(msg.clone())
                        };
                        self.route(flight, kind, emit);
                    }
                }
            }
        }
        // Return the drained buffer to the scratch slot for reuse.
        self.ops_scratch = ops;
    }

    /// Queue one flight locally or hand it to the cross-shard emitter.
    fn route(
        &mut self,
        flight: Flight,
        kind: TransitKind<P::Msg>,
        emit: &mut impl FnMut(Transit<P::Msg>),
    ) {
        let own = self.owns(flight.dst as usize);
        let t = Transit { flight, kind };
        if own && !self.divert {
            self.queue.push(t);
        } else {
            emit(t);
        }
    }

    /// See [`EventQueue::spec_fence`]: the hard upper bound for a
    /// speculative burst's pop window.
    pub fn spec_fence(&self) -> Time {
        self.queue.spec_fence()
    }

    /// Open a speculative burst: snapshot the cheap wholesale state
    /// (fabric counters, spine registers, event count, queue cursor) and
    /// reset the lazy per-node backup log.
    pub fn begin_burst(&mut self, log: &mut SpecLog<P>) {
        log.burst += 1;
        log.saved.clear();
        log.redo.clear();
        self.rx.spec_save_spines_into(&mut log.spines);
        log.net = self.net.clone();
        log.events = self.events;
        log.cursor = self.queue.cursor();
    }

    /// Optimistically drain events with `at < bound()` while journaling
    /// undo state into `log`: every pop is recorded for re-push, and each
    /// touched node's program/RNG/reorder-buffer/hot/stats plus its fabric
    /// lane registers are backed up at most once per burst
    /// (generation-stamped). One event only ever mutates its destination
    /// node's state — sends, timers, and RNG draws all charge the invoked
    /// node — so the per-destination backup covers the whole mutation.
    /// All emissions are diverted to `emit` (see [`Shard::route`]).
    pub fn run_window_spec(
        &mut self,
        sx: &SharedCtx<'_>,
        bound: &impl Fn() -> Time,
        emit: &mut impl FnMut(Transit<P::Msg>),
        log: &mut SpecLog<P>,
    ) where
        P: Clone,
    {
        debug_assert!(!self.divert);
        debug_assert!(bound() <= self.spec_fence(), "burst bound past the rewind fence");
        self.divert = true;
        while let Some(t) = self.queue.pop_before(bound()) {
            let i = self.ix(t.flight.dst as usize);
            if log.node_stamp[i] != log.burst {
                log.node_stamp[i] = log.burst;
                log.saved.push((
                    i,
                    NodeBackup {
                        prog: self.nodes[i].prog.clone(),
                        rng: self.nodes[i].rng.clone(),
                        held: self.nodes[i].held.clone(),
                        hot: self.hot[i],
                        stats: self.stats[i].clone(),
                        tx: self.tx.spec_save(t.flight.dst as usize),
                        ingress: self.rx.spec_save(t.flight.dst as usize),
                    },
                ));
            }
            log.redo.push(t.clone());
            self.events += 1;
            let (src, dst) = (t.flight.src as usize, t.flight.dst as usize);
            match t.kind {
                TransitKind::Timer(msg) => self.deliver(sx, t.flight.at, src, dst, msg, emit),
                TransitKind::Msg(msg) => {
                    let arrival = sx
                        .fabric
                        .admit(&mut self.rx, &mut self.net, &t.flight, msg.wire_bytes());
                    self.deliver(sx, arrival, src, dst, msg, emit);
                }
                TransitKind::Phantom { payload_bytes } => {
                    sx.fabric.admit(&mut self.rx, &mut self.net, &t.flight, payload_bytes);
                }
            }
        }
        self.divert = false;
        // The walk may have advanced the cursor over empty buckets beyond
        // the last pop (up to the burst bound — past the conservative
        // horizon by design). Later inbound transits are only guaranteed
        // to key after the last *popped* event, so retreat the cursor to
        // that event's bucket — or all the way back when the burst popped
        // nothing. This moves a position, not contents: re-walking empty
        // buckets is free, and every remaining event sits at or beyond it.
        let back = match log.last_key() {
            Some((at, _, _)) => log.cursor.max(self.queue.cursor_of(at)),
            None => log.cursor,
        };
        self.queue.rewind(back);
    }

    /// Undo one speculative burst: restore every touched node and fabric
    /// register, rewind the queue cursor, and re-push the popped transits
    /// (no anti-messages exist — the burst's emissions were buffered by
    /// the caller and are simply dropped).
    pub fn rollback_burst(&mut self, log: &mut SpecLog<P>) {
        self.net = log.net.clone();
        self.events = log.events;
        self.rx.spec_restore_spines(&log.spines);
        for (i, b) in log.saved.drain(..) {
            let node = self.range.start + i;
            self.nodes[i].prog = b.prog;
            self.nodes[i].rng = b.rng;
            self.nodes[i].held = b.held;
            self.hot[i] = b.hot;
            self.stats[i] = b.stats;
            self.tx.spec_restore(node, &b.tx);
            self.rx.spec_restore(node, b.ingress);
        }
        self.queue.rewind(log.cursor);
        for t in log.redo.drain(..) {
            self.queue.push(t);
        }
    }
}

/// Backup of everything processing one event can mutate on its
/// destination node (DESIGN.md §10).
struct NodeBackup<P: Program> {
    prog: P,
    rng: SplitMix64,
    held: Vec<(u32, u32, P::Msg)>,
    hot: HotNode,
    stats: NodeStats,
    /// Sender-side lane registers (egress busy-until, RNG, flight ctr).
    tx: (Time, SplitMix64, u64),
    /// Destination ingress busy-until register.
    ingress: Time,
}

/// Per-shard undo journal for one optimistic burst. Owned by the
/// optimistic executor's worker; reused across bursts (the generation
/// stamp makes per-node backups lazy without clearing the stamp arena).
pub(crate) struct SpecLog<P: Program> {
    burst: u64,
    /// Last burst id that backed up each local node index.
    node_stamp: Vec<u64>,
    saved: Vec<(usize, NodeBackup<P>)>,
    spines: Vec<Time>,
    net: NetStats,
    events: u64,
    cursor: u64,
    /// Clones of every popped transit, in pop order.
    redo: Vec<Transit<P::Msg>>,
}

impl<P: Program> SpecLog<P> {
    pub fn new(shard_len: usize) -> Self {
        SpecLog {
            burst: 0,
            node_stamp: vec![0; shard_len],
            saved: Vec::new(),
            spines: Vec::new(),
            net: NetStats::default(),
            events: 0,
            cursor: 0,
            redo: Vec::new(),
        }
    }

    /// Canonical key of the last (deepest) speculated event.
    pub fn last_key(&self) -> Option<(Time, u32, u64)> {
        self.redo.last().map(|t| (t.flight.at, t.flight.src, t.flight.ctr))
    }

    /// Time of the first speculated event (published as the shard's event
    /// minimum while the burst is pending — see `exec::opt`).
    pub fn first_at(&self) -> Option<Time> {
        self.redo.first().map(|t| t.flight.at)
    }

    pub fn is_pending(&self) -> bool {
        !self.redo.is_empty()
    }

    /// Drop the undo journal after a commit (the speculated state *is*
    /// the committed state; nothing to restore or re-push).
    pub fn resolve(&mut self) {
        self.saved.clear();
        self.redo.clear();
    }
}

/// Merge completed shards (in ascending node order) into one summary.
pub(crate) fn merge_shards<P: Program>(shards: Vec<Shard<P>>) -> RunSummary {
    let mut node_stats = Vec::new();
    let mut net = NetStats::default();
    let mut events = 0;
    for shard in shards {
        debug_assert_eq!(shard.range.start, node_stats.len());
        node_stats.extend(shard.stats);
        net.merge(&shard.net);
        events += shard.events;
    }
    let makespan = node_stats.iter().map(|s| s.last_active).max().unwrap_or(Time::ZERO);
    RunSummary { makespan, node_stats, net, events, profile: ExecProfile::default() }
}

/// Bench-only probe (`rust/benches/substrate.rs`): drive `rounds`
/// push/pop alternations through a [`TimingWheel`] after a warm-up lap,
/// and return how many heap allocations the measured pass performed on
/// this thread. The wheel's steady-state contract is **zero** — the
/// bench asserts the returned count, not just the wall-clock.
#[doc(hidden)]
pub fn queue_churn_allocs(rounds: u64) -> u64 {
    let mut wheel = TimingWheel::new();
    // One 64-bucket stride per round, bucket-aligned: the orbit closes
    // after 1,024 rounds (65,536-bucket ring), so the warm lap touches
    // every slot the measured pass will revisit.
    let step = 64u64 << 6;
    let mut at = 0u64;
    let mut ctr = 0u64;
    let mut churn = |wheel: &mut TimingWheel, n: u64| {
        for _ in 0..n {
            at += step;
            ctr += 1;
            wheel.push(Event { at: Time(at), src: 0, ctr, slot: 0 });
            let popped = wheel.pop_before(Time(u64::MAX)).expect("event just pushed");
            debug_assert_eq!(popped.at, Time(at));
        }
    };
    churn(&mut wheel, 2048);
    let before = crate::mem::thread_alloc_count();
    churn(&mut wheel, rounds);
    crate::mem::thread_alloc_count() - before
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(at: u64, src: u32, ctr: u64) -> Event {
        Event { at: Time(at), src, ctr, slot: 0 }
    }

    /// The predecessor calendar queue, retained verbatim as the
    /// differential reference for the production [`TimingWheel`]: the
    /// same near ring, but the whole far tier lives in a `BTreeMap`
    /// keyed by aligned window index — one node allocation per far push,
    /// and each window's Vec is dropped after re-homing. The contract
    /// battery below runs against both implementations, and the
    /// randomized test byte-compares their pop sequences under every
    /// operation the executors use.
    struct CalendarQueue {
        ring: Vec<Bucket>,
        g_shift: u32,
        mask: u64,
        ring_bits: u32,
        cur: u64,
        far: BTreeMap<u64, Vec<Event>>,
        ring_count: usize,
        len: usize,
        peek_cache: Option<Time>,
    }

    impl CalendarQueue {
        fn new() -> Self {
            let ring_bits = 16u32;
            let buckets = 1usize << ring_bits;
            CalendarQueue {
                ring: (0..buckets)
                    .map(|_| Bucket { events: Vec::new(), sorted: true })
                    .collect(),
                g_shift: 6,
                mask: (buckets - 1) as u64,
                ring_bits,
                cur: 0,
                far: BTreeMap::new(),
                ring_count: 0,
                len: 0,
                peek_cache: None,
            }
        }

        fn bucket_of(&self, at: Time) -> u64 {
            at.0 >> self.g_shift
        }

        fn push(&mut self, ev: Event) {
            let b = self.bucket_of(ev.at);
            debug_assert!(b >= self.cur, "event scheduled in the past");
            self.len += 1;
            if let Some(cache) = self.peek_cache {
                self.peek_cache = Some(cache.min(ev.at));
            }
            if b >= self.cur + self.ring.len() as u64 {
                self.far.entry(b >> self.ring_bits).or_default().push(ev);
            } else {
                let bucket = &mut self.ring[(b & self.mask) as usize];
                bucket.events.push(ev);
                bucket.sorted = false;
                self.ring_count += 1;
            }
        }

        fn rehome(&mut self, window: u64) {
            let Some(events) = self.far.remove(&window) else { return };
            for ev in events {
                let b = self.bucket_of(ev.at);
                debug_assert!(b >= self.cur && b < self.cur + self.ring.len() as u64);
                let bucket = &mut self.ring[(b & self.mask) as usize];
                bucket.events.push(ev);
                bucket.sorted = false;
                self.ring_count += 1;
            }
        }

        fn peek_at(&mut self) -> Option<Time> {
            if self.len == 0 {
                return None;
            }
            if let Some(t) = self.peek_cache {
                return Some(t);
            }
            let ring_min = if self.ring_count == 0 {
                None
            } else {
                let mut i = self.cur;
                loop {
                    let b = &self.ring[(i & self.mask) as usize];
                    if !b.events.is_empty() {
                        break Some(
                            b.events.iter().map(|e| e.at).min().expect("non-empty bucket"),
                        );
                    }
                    i += 1;
                }
            };
            let far_min = self.far.iter().next().and_then(|(&window, events)| {
                let wstart = window << self.ring_bits;
                if ring_min.is_some_and(|t| wstart > self.bucket_of(t)) {
                    None
                } else {
                    events.iter().map(|e| e.at).min()
                }
            });
            let t = match (ring_min, far_min) {
                (Some(r), Some(f)) => r.min(f),
                (Some(r), None) => r,
                (None, Some(f)) => f,
                (None, None) => unreachable!("len > 0 but no events"),
            };
            self.peek_cache = Some(t);
            Some(t)
        }

        fn pop_before(&mut self, bound: Time) -> Option<Event> {
            if self.len == 0 || bound == Time::ZERO {
                return None;
            }
            let limit = (bound.0 - 1) >> self.g_shift;
            loop {
                if self.ring_count == 0 {
                    if self.far.is_empty() {
                        return None;
                    }
                    let (&window, _) = self.far.iter().next().expect("checked non-empty");
                    let wstart = window << self.ring_bits;
                    if wstart > limit {
                        return None;
                    }
                    self.cur = self.cur.max(wstart);
                    self.rehome(window);
                    continue;
                }
                if self.cur > limit {
                    return None;
                }
                let bucket = &mut self.ring[(self.cur & self.mask) as usize];
                if !bucket.events.is_empty() {
                    if !bucket.sorted {
                        bucket.events.sort_unstable_by(|a, b| b.key().cmp(&a.key()));
                        bucket.sorted = true;
                    }
                    let next = bucket.events.last().expect("non-empty bucket");
                    if next.at >= bound {
                        return None;
                    }
                    self.len -= 1;
                    self.ring_count -= 1;
                    self.peek_cache = None;
                    return bucket.events.pop();
                }
                if self.cur == limit {
                    return None;
                }
                self.cur += 1;
                if self.cur & self.mask == 0 {
                    self.rehome(self.cur >> self.ring_bits);
                }
            }
        }

        fn spec_fence(&self) -> Time {
            let boundary = ((self.cur >> self.ring_bits) + 1) << self.ring_bits;
            Time(boundary << self.g_shift)
        }

        fn rewind(&mut self, cursor: u64) {
            debug_assert!(cursor <= self.cur);
            self.cur = cursor;
            self.peek_cache = None;
        }
    }

    /// The queue contract every executor relies on, instantiated against
    /// both the production wheel and the retained reference — one macro,
    /// two gates, so a wheel regression shows up as a one-sided failure.
    macro_rules! queue_contract_tests {
        ($modname:ident, $Q:ty) => {
            mod $modname {
                use super::*;

                /// The far tier + bounded pop must order exactly like one
                /// global heap, for events scattered across many ring
                /// windows (far beyond the 262 µs lookahead) interleaved
                /// with near events.
                #[test]
                fn far_tier_orders_exactly() {
                    let mut q = <$Q>::new();
                    let window_units: u64 = 64 << 16; // one ring span in time units
                    let mut rng = SplitMix64::new(0xCA1);
                    let mut expect: Vec<(u64, u32, u64)> = Vec::new();
                    let mut ctr = 0u64;
                    // Phase 1: events over ~40 windows, in random order.
                    for _ in 0..5_000 {
                        let at = rng.next_below(40 * window_units);
                        let src = rng.index(64) as u32;
                        ctr += 1;
                        q.push(ev(at, src, ctr));
                        expect.push((at, src, ctr));
                    }
                    expect.sort_unstable();
                    let mut popped = Vec::new();
                    // Interleave: drain half, then push more events *ahead
                    // of the cursor* (as the fabric does — positive
                    // latency), drain the rest.
                    for _ in 0..2_500 {
                        let e = q.pop_before(Time(u64::MAX)).unwrap();
                        popped.push((e.at.0, e.src, e.ctr));
                    }
                    let now = popped.last().unwrap().0;
                    let mut late: Vec<(u64, u32, u64)> = Vec::new();
                    for _ in 0..2_500 {
                        let at = now + rng.next_below(45 * window_units);
                        let src = rng.index(64) as u32;
                        ctr += 1;
                        q.push(ev(at, src, ctr));
                        late.push((at, src, ctr));
                    }
                    while let Some(e) = q.pop_before(Time(u64::MAX)) {
                        popped.push((e.at.0, e.src, e.ctr));
                    }
                    assert_eq!(popped.len(), 7_500);
                    // Every pop must be totally ordered by (at, src, ctr).
                    assert!(popped.windows(2).all(|w| w[0] < w[1]), "pops out of order");
                    // And the multiset must be exactly what was pushed.
                    let mut all = expect;
                    all.extend(late);
                    all.sort_unstable();
                    let mut got = popped;
                    got.sort_unstable();
                    assert_eq!(got, all);
                }

                /// Bounded pops stop exactly at the bound (strictly-before
                /// contract) and later pushes behind the *cursor's*
                /// high-water mark but ahead of the bound still order
                /// correctly — the window-barrier edge case.
                #[test]
                fn bounded_pop_respects_windows() {
                    let mut q = <$Q>::new();
                    q.push(ev(10, 0, 0));
                    q.push(ev(500, 0, 1));
                    q.push(ev(10_000, 0, 2));
                    assert_eq!(q.peek_at(), Some(Time(10)));
                    // Window [0, 500): only the first event pops.
                    assert_eq!(q.pop_before(Time(500)).unwrap().at, Time(10));
                    assert!(q.pop_before(Time(500)).is_none());
                    // A cross-shard push lands between the windows.
                    q.push(ev(600, 3, 0));
                    assert_eq!(q.peek_at(), Some(Time(500)));
                    // Window [500, 10_000): both mid events pop, in order.
                    assert_eq!(q.pop_before(Time(10_000)).unwrap().at, Time(500));
                    assert_eq!(q.pop_before(Time(10_000)).unwrap().at, Time(600));
                    assert!(q.pop_before(Time(10_000)).is_none());
                    assert_eq!(q.pop_before(Time(u64::MAX)).unwrap().at, Time(10_000));
                    assert!(q.pop_before(Time(u64::MAX)).is_none());
                    assert_eq!(q.peek_at(), None);
                }

                /// Ties at one timestamp break by (src, ctr) — the
                /// canonical order is processing-order independent.
                #[test]
                fn ties_break_by_src_then_ctr() {
                    let mut q = <$Q>::new();
                    q.push(ev(64, 2, 0));
                    q.push(ev(64, 0, 1));
                    q.push(ev(64, 0, 0));
                    q.push(ev(64, 1, 9));
                    let order: Vec<(u32, u64)> =
                        std::iter::from_fn(|| q.pop_before(Time(u64::MAX)))
                            .map(|e| (e.src, e.ctr))
                            .collect();
                    assert_eq!(order, vec![(0, 0), (0, 1), (1, 9), (2, 0)]);
                }

                /// Regression: the ring's bucket range can overlap the
                /// earliest far window once the cursor has advanced, so
                /// `peek_at` must consult both — an un-rehomed far event
                /// can be earlier than every ring event, and reporting the
                /// ring minimum alone would inflate the parallel
                /// executor's window bound.
                #[test]
                fn peek_sees_far_events_earlier_than_ring_events() {
                    let mut q = <$Q>::new();
                    let bucket_units = 64u64; // 1 << g_shift
                    // Event in bucket 40,000 — popping it advances the
                    // cursor there without crossing the 65,536-bucket
                    // window boundary (no rehome).
                    q.push(ev(40_000 * bucket_units, 0, 0));
                    // Far event (bucket 66,000, window 1): beyond the ring
                    // span while the cursor sits at 0.
                    q.push(ev(66_000 * bucket_units, 0, 1));
                    assert_eq!(
                        q.pop_before(Time(u64::MAX)).unwrap().at,
                        Time(40_000 * bucket_units)
                    );
                    // Ring now spans buckets [40,000, 105,536): this later
                    // event goes into the ring even though the earlier far
                    // event is still far.
                    q.push(ev(70_000 * bucket_units, 0, 2));
                    // The true minimum is the far event, not the ring one.
                    assert_eq!(q.peek_at(), Some(Time(66_000 * bucket_units)));
                    assert_eq!(
                        q.pop_before(Time(u64::MAX)).unwrap().at,
                        Time(66_000 * bucket_units)
                    );
                    assert_eq!(
                        q.pop_before(Time(u64::MAX)).unwrap().at,
                        Time(70_000 * bucket_units)
                    );
                    assert!(q.pop_before(Time(u64::MAX)).is_none());
                }

                /// Regression: a bounded pop walking empty buckets must
                /// not advance the cursor past the bound's own bucket.
                /// With an unaligned bound, a later push at `at >= bound`
                /// can still land in that bucket — an overshot cursor
                /// would reject it as "scheduled in the past" (and alias
                /// its ring slot a full span later in release builds).
                #[test]
                fn bounded_pop_never_overshoots_the_bound_bucket() {
                    let mut q = <$Q>::new();
                    q.push(ev(10, 0, 0));
                    // Unaligned bound inside bucket 3 (64-unit buckets):
                    // the drain pops the one event, then walks empty
                    // buckets up to the limit.
                    assert_eq!(q.pop_before(Time(230)).unwrap().at, Time(10));
                    assert!(q.pop_before(Time(230)).is_none());
                    assert!(q.cur <= 3, "cursor overshot the bound bucket");
                    // A conservative-window push at `at >= bound` sharing
                    // the bound's bucket must be accepted and pop next.
                    q.push(ev(250, 1, 0));
                    assert_eq!(q.pop_before(Time(u64::MAX)).unwrap().at, Time(250));
                }

                /// The speculation fence/rewind contract: a burst bounded
                /// by `spec_fence` can be undone by rewinding the cursor
                /// and re-pushing its pops, after which the identical
                /// sequence replays and later (beyond-fence) events still
                /// drain in order.
                #[test]
                fn rewind_replays_a_fenced_burst_exactly() {
                    let mut q = <$Q>::new();
                    let mut rng = SplitMix64::new(0x5EC);
                    let fence = q.spec_fence();
                    let mut ctr = 0u64;
                    for _ in 0..500 {
                        // Spread events below and beyond the fence.
                        let at = rng.next_below(fence.0 + fence.0 / 2);
                        ctr += 1;
                        q.push(ev(at, rng.index(8) as u32, ctr));
                    }
                    let cursor = q.cur;
                    let first: Vec<(u64, u32, u64)> =
                        std::iter::from_fn(|| q.pop_before(fence))
                            .map(|e| (e.at.0, e.src, e.ctr))
                            .collect();
                    assert!(!first.is_empty(), "degenerate test: nothing below the fence");
                    q.rewind(cursor);
                    for &(at, src, c) in &first {
                        q.push(ev(at, src, c));
                    }
                    let replay: Vec<(u64, u32, u64)> =
                        std::iter::from_fn(|| q.pop_before(fence))
                            .map(|e| (e.at.0, e.src, e.ctr))
                            .collect();
                    assert_eq!(first, replay);
                    let rest: Vec<u64> = std::iter::from_fn(|| q.pop_before(Time(u64::MAX)))
                        .map(|e| e.at.0)
                        .collect();
                    assert_eq!(first.len() + rest.len(), 500);
                    assert!(
                        rest.windows(2).all(|w| w[0] <= w[1]),
                        "post-fence drain out of order"
                    );
                    assert!(rest.iter().all(|&at| at >= fence.0));
                }

                /// peek_at never advances the cursor: a push earlier than
                /// a previous peek result (but later than anything popped)
                /// must still surface.
                #[test]
                fn peek_does_not_commit_the_cursor() {
                    let mut q = <$Q>::new();
                    q.push(ev(100_000, 0, 0));
                    assert_eq!(q.peek_at(), Some(Time(100_000)));
                    q.push(ev(70, 0, 1));
                    assert_eq!(q.peek_at(), Some(Time(70)));
                    assert_eq!(q.pop_before(Time(u64::MAX)).unwrap().at, Time(70));
                    assert_eq!(q.pop_before(Time(u64::MAX)).unwrap().at, Time(100_000));
                }
            }
        };
    }

    queue_contract_tests!(wheel_contract, TimingWheel);
    queue_contract_tests!(reference_contract, CalendarQueue);

    /// Differential battery: the production wheel against the reference
    /// calendar queue under randomized interleavings of the full surface
    /// (push bursts into every tier, bounded drains, peeks, fenced
    /// speculative bursts with rewind + replay), byte-comparing every pop.
    /// `floor` tracks the highest drain bound used so far: after a
    /// bounded drain the cursor may sit on the bound's bucket, so new
    /// pushes must stay at or beyond it (exactly the executors' positive-
    /// latency discipline).
    #[test]
    fn wheel_matches_reference_under_random_interleavings() {
        let window_units: u64 = 64 << 16;
        for case in 0..40u64 {
            let mut rng = SplitMix64::new(0xD1FF ^ (case * 0x9E37_79B9));
            let mut wheel = TimingWheel::new();
            let mut cal = CalendarQueue::new();
            let mut ctr = 0u64;
            let mut floor = 0u64;
            let mut live = 0i64;
            for _ in 0..400 {
                match rng.index(10) {
                    0..=3 => {
                        // Push burst: identical events into both queues,
                        // spread from near buckets deep into the far
                        // tiers (past the 64-window level-1 reach).
                        let n = 8 + rng.index(56);
                        let span = 1 + rng.next_below(90 * window_units);
                        for _ in 0..n {
                            let at = floor + 1 + rng.next_below(span);
                            let src = rng.index(64) as u32;
                            ctr += 1;
                            wheel.push(ev(at, src, ctr));
                            cal.push(ev(at, src, ctr));
                            live += 1;
                        }
                    }
                    4..=6 => {
                        // Bounded drain: byte-compare the pop sequences.
                        let bound = Time(floor + 1 + rng.next_below(4 * window_units));
                        loop {
                            let a = wheel.pop_before(bound).map(|e| e.key());
                            let b = cal.pop_before(bound).map(|e| e.key());
                            assert_eq!(a, b, "case {case}: bounded pops diverged");
                            if a.is_none() {
                                break;
                            }
                            live -= 1;
                        }
                        floor = floor.max(bound.0);
                    }
                    7 => {
                        assert_eq!(
                            wheel.peek_at(),
                            cal.peek_at(),
                            "case {case}: peeks diverged"
                        );
                    }
                    _ => {
                        // Fenced burst + rewind + replay: the speculation
                        // surface. The fences agree because the cursor
                        // trajectories agree.
                        assert_eq!(wheel.cur, cal.cur, "case {case}: cursors diverged");
                        assert_eq!(wheel.spec_fence(), cal.spec_fence());
                        let fence = wheel.spec_fence();
                        let saved = wheel.cur;
                        let mut burst = Vec::new();
                        loop {
                            let a = wheel.pop_before(fence).map(|e| e.key());
                            let b = cal.pop_before(fence).map(|e| e.key());
                            assert_eq!(a, b, "case {case}: fenced pops diverged");
                            match a {
                                Some(k) => burst.push(k),
                                None => break,
                            }
                        }
                        wheel.rewind(saved);
                        cal.rewind(saved);
                        for &(at, src, c) in &burst {
                            wheel.push(ev(at.0, src, c));
                            cal.push(ev(at.0, src, c));
                        }
                        for &k in &burst {
                            assert_eq!(
                                wheel.pop_before(fence).map(|e| e.key()),
                                Some(k),
                                "case {case}: wheel replay diverged"
                            );
                            assert_eq!(
                                cal.pop_before(fence).map(|e| e.key()),
                                Some(k),
                                "case {case}: reference replay diverged"
                            );
                            live -= 1;
                        }
                        assert!(wheel.pop_before(fence).is_none());
                        assert!(cal.pop_before(fence).is_none());
                        floor = floor.max(fence.0);
                    }
                }
            }
            // Final unbounded drain: full order + multiset identity.
            let mut last = None;
            loop {
                let a = wheel.pop_before(Time(u64::MAX)).map(|e| e.key());
                let b = cal.pop_before(Time(u64::MAX)).map(|e| e.key());
                assert_eq!(a, b, "case {case}: final drain diverged");
                let Some(k) = a else { break };
                assert!(last < Some(k), "case {case}: final drain out of order");
                last = Some(k);
                live -= 1;
            }
            assert_eq!(live, 0, "case {case}: events lost or duplicated");
        }
    }
}
