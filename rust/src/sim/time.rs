//! Virtual time for the cycle-calibrated simulation.
//!
//! The nanoPU target clock is 3.2 GHz (paper §5.1), i.e. one cycle is
//! 0.3125 ns. To keep all arithmetic exact-integer we count time in units
//! of 1/16 ns: one nanosecond is 16 units and one cycle is exactly 5.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// Units per nanosecond (1 unit = 62.5 ps).
pub const UNITS_PER_NS: u64 = 16;
/// Units per 3.2 GHz cycle (0.3125 ns * 16 = 5, exact).
pub const UNITS_PER_CYCLE: u64 = 5;
/// Target core clock, cycles per second (paper §5.1).
pub const CLOCK_HZ: u64 = 3_200_000_000;

/// A point in (or span of) virtual time. Ordered, copy, exact-integer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Time(pub u64);

impl Time {
    pub const ZERO: Time = Time(0);

    /// From whole nanoseconds.
    pub fn from_ns(ns: u64) -> Time {
        Time(ns * UNITS_PER_NS)
    }

    /// From 3.2 GHz core cycles.
    pub fn from_cycles(cycles: u64) -> Time {
        Time(cycles * UNITS_PER_CYCLE)
    }

    /// Whole nanoseconds (truncating).
    pub fn as_ns(self) -> u64 {
        self.0 / UNITS_PER_NS
    }

    /// Fractional nanoseconds.
    pub fn as_ns_f64(self) -> f64 {
        self.0 as f64 / UNITS_PER_NS as f64
    }

    /// Fractional microseconds.
    pub fn as_us_f64(self) -> f64 {
        self.as_ns_f64() / 1000.0
    }

    /// Whole 3.2 GHz cycles (truncating).
    pub fn as_cycles(self) -> u64 {
        self.0 / UNITS_PER_CYCLE
    }

    pub fn max(self, other: Time) -> Time {
        Time(self.0.max(other.0))
    }

    /// Saturating difference.
    pub fn saturating_sub(self, other: Time) -> Time {
        Time(self.0.saturating_sub(other.0))
    }
}

impl Add for Time {
    type Output = Time;
    fn add(self, rhs: Time) -> Time {
        Time(self.0 + rhs.0)
    }
}

impl AddAssign for Time {
    fn add_assign(&mut self, rhs: Time) {
        self.0 += rhs.0;
    }
}

impl Sub for Time {
    type Output = Time;
    fn sub(self, rhs: Time) -> Time {
        Time(self.0 - rhs.0)
    }
}

impl fmt::Display for Time {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ns = self.as_ns_f64();
        if ns >= 1000.0 {
            write!(f, "{:.3}us", ns / 1000.0)
        } else {
            write!(f, "{ns:.1}ns")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ns_cycle_grid_is_exact() {
        assert_eq!(Time::from_ns(1).0, 16);
        assert_eq!(Time::from_cycles(1).0, 5);
        // 16 cycles == 5 ns exactly on the grid.
        assert_eq!(Time::from_cycles(16), Time::from_ns(5));
        assert_eq!(Time::from_cycles(3200).as_ns(), 1000);
    }

    #[test]
    fn conversions_round_trip() {
        let t = Time::from_ns(263);
        assert_eq!(t.as_ns(), 263);
        let c = Time::from_cycles(96_000); // 30us of cycles at 3.2GHz
        assert_eq!(c.as_ns(), 30_000);
        assert!((c.as_us_f64() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn ordering_and_arith() {
        let a = Time::from_ns(10);
        let b = Time::from_ns(3);
        assert!(a > b);
        assert_eq!((a + b).as_ns(), 13);
        assert_eq!((a - b).as_ns(), 7);
        assert_eq!(b.saturating_sub(a), Time::ZERO);
        assert_eq!(a.max(b), a);
    }

    #[test]
    fn display_formats() {
        assert_eq!(format!("{}", Time::from_ns(43)), "43.0ns");
        assert_eq!(format!("{}", Time::from_ns(68_000)), "68.000us");
    }
}
