//! The engine facade: configure a fleet (programs, fabric, core model,
//! groups, stragglers), then run it on an execution backend.
//!
//! Model (DESIGN.md §1): each node is a sequential core with a
//! `busy_until` register. A message delivered at `t` begins processing at
//! `max(t, busy_until)`; the handler's RX cost, compute cycles, and TX
//! costs extend `busy_until`; every send is handed to the fabric at the
//! sender-local time at which the handler issued it. The run ends at
//! global quiescence (event queues empty); the makespan is the latest
//! busy-until across nodes.
//!
//! Reorder buffer (paper §5.2): messages for a future algorithm step pay
//! their RX cost on arrival (the software reads them off the NIC) plus a
//! small store, and are re-delivered (cheap pop) once the program reaches
//! that step.
//!
//! The event loop itself lives in [`crate::sim::exec`]: [`Engine::run`]
//! uses the sequential backend, [`Engine::run_threads`] picks the
//! deterministic sharded backend for `threads != 1`, and
//! [`Engine::run_exec`] selects any backend by [`ExecKind`] — all
//! produce byte-identical results (the §7 + §10 determinism contract).

use std::sync::Arc;

use crate::cpu::CoreModel;
use crate::nanopu::{Group, GroupId, NodeId, Program};
use crate::net::Fabric;
use crate::pool::WorkerPool;

use super::exec::{
    resolve_threads, run_seq_inner, EngineParts, ExecKind, Executor, OptExecutor, ParExecutor,
    RunSummary,
};

/// The engine: node programs + fabric + core model + groups, ready to be
/// handed to an execution backend.
pub struct Engine<P: Program> {
    programs: Vec<P>,
    /// Per-node compute slowdown factor (1 = nominal). Straggler cores
    /// (perturbation layer) get a larger factor, applied to every
    /// cycle-to-time conversion for that node.
    slow: Vec<u32>,
    fabric: Fabric,
    core: CoreModel,
    groups: Vec<Group>,
    seed: u64,
    /// Shared host worker pool (`None` until a caller provides one or a
    /// threaded run sizes a default from its `--threads` budget).
    pool: Option<Arc<WorkerPool>>,
}

impl<P: Program> Engine<P> {
    /// Build an engine over `programs` (node id = index).
    pub fn new(programs: Vec<P>, fabric: Fabric, core: CoreModel, seed: u64) -> Self {
        assert_eq!(programs.len(), fabric.topo.nodes, "program count != topology nodes");
        let n = programs.len();
        Engine { programs, slow: vec![1; n], fabric, core, groups: Vec::new(), seed, pool: None }
    }

    /// Share a host worker pool with this run: shard workers and
    /// parallel compute kernels then draw from one `--threads` budget
    /// ([`crate::pool`]). The scenario layer always sets this; a run
    /// without one gets a budget-1 pool (sequential path) or an
    /// executor-sized fallback (direct threaded `Executor` calls).
    pub fn set_pool(&mut self, pool: Arc<WorkerPool>) {
        self.pool = Some(pool);
    }

    /// Register a multicast group (a member list or an id range);
    /// returns its id.
    pub fn add_group(&mut self, members: impl Into<Group>) -> GroupId {
        self.groups.push(members.into());
        self.groups.len() - 1
    }

    pub fn core(&self) -> &CoreModel {
        &self.core
    }

    /// Mark `node` as a straggler: all its compute (RX, handler cycles,
    /// TX issue offsets) runs `factor`× slower. Factor 1 is exactly the
    /// nominal path (the default for every node).
    pub fn slow_down(&mut self, node: NodeId, factor: u32) {
        self.slow[node] = factor.max(1);
    }

    fn into_parts(self) -> EngineParts<P> {
        EngineParts {
            programs: self.programs,
            slow: self.slow,
            fabric: self.fabric,
            core: self.core,
            groups: self.groups,
            seed: self.seed,
            pool: self.pool.unwrap_or_else(|| Arc::new(WorkerPool::new(1))),
        }
    }

    /// Run to quiescence on the sequential backend; consumes the engine.
    pub fn run(self) -> RunSummary {
        run_seq_inner(self.into_parts())
    }
}

impl<P: Program + Send + Clone> Engine<P> {
    /// Run to quiescence on `threads` worker threads (`1` = the
    /// sequential backend, `0` = all available host cores); consumes the
    /// engine. Results are byte-identical at every thread count — the
    /// parallel backend's determinism contract ([`crate::sim::exec`]).
    pub fn run_threads(self, threads: usize) -> RunSummary {
        self.run_exec(ExecKind::Par, threads, None, None)
    }

    /// Run to quiescence on the backend named by `kind`; consumes the
    /// engine. `threads == 1` (or [`ExecKind::Seq`]) collapses to the
    /// sequential reference path; `window_batch` and
    /// `force_rollback_every` thread the parallel/optimistic knobs
    /// through (ignored where meaningless). Results are byte-identical
    /// across every combination.
    pub fn run_exec(
        mut self,
        kind: ExecKind,
        threads: usize,
        window_batch: Option<usize>,
        force_rollback_every: Option<u64>,
    ) -> RunSummary {
        if self.pool.is_none() {
            self.pool = Some(Arc::new(WorkerPool::new(resolve_threads(threads))));
        }
        match kind {
            ExecKind::Seq => self.run(),
            _ if threads == 1 => self.run(),
            ExecKind::Par => {
                ParExecutor { threads, window_batch }.run(self.into_parts())
            }
            ExecKind::Opt => {
                OptExecutor { threads, window_batch, force_rollback_every }
                    .run(self.into_parts())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nanopu::{Ctx, WireMsg};
    use crate::net::{NetConfig, Topology};
    use crate::sim::Time;

    /// Ping-pong program: node 0 sends `hops` round trips to node 1.
    #[derive(Clone)]
    struct Ping {
        remaining: u32,
    }

    #[derive(Clone)]
    struct Msg;
    impl WireMsg for Msg {
        fn wire_bytes(&self) -> u64 {
            8
        }
    }

    impl Program for Ping {
        type Msg = Msg;
        fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
            if ctx.node() == 0 && self.remaining > 0 {
                ctx.send(1, Msg);
            }
        }
        fn on_message(&mut self, ctx: &mut Ctx<Msg>, src: NodeId, _msg: Msg) {
            if self.remaining > 0 {
                self.remaining -= 1;
                if self.remaining > 0 {
                    ctx.send(src, Msg);
                }
            }
        }
    }

    fn tiny_engine(progs: Vec<Ping>) -> Engine<Ping> {
        let topo = Topology::paper(progs.len());
        let fabric = Fabric::new(topo, NetConfig::default(), 1);
        Engine::new(progs, fabric, CoreModel::default(), 42)
    }

    #[test]
    fn ping_pong_terminates_with_sane_latency() {
        let e = tiny_engine(vec![Ping { remaining: 10 }, Ping { remaining: 10 }]);
        let summary = e.run();
        // Same-leaf one-way ≈ tx + 2*28 + 2*43 + 263 + ser + rx ≈ 420 ns;
        // 10 one-way legs ≈ 4.2 µs. Allow generous bounds.
        let us = summary.makespan.as_us_f64();
        assert!((2.0..10.0).contains(&us), "makespan = {us} µs");
        assert!(summary.events >= 10);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = tiny_engine(vec![Ping { remaining: 7 }, Ping { remaining: 7 }]).run();
        let b = tiny_engine(vec![Ping { remaining: 7 }, Ping { remaining: 7 }]).run();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.events, b.events);
        assert_eq!(a.net.msgs_sent, b.net.msgs_sent);
    }

    /// The two backends must agree byte for byte — even on a ping-pong
    /// whose two nodes land on two different shards (the smallest
    /// possible shard: one node each).
    #[test]
    fn seq_and_par_agree_on_ping_pong() {
        let seq = tiny_engine(vec![Ping { remaining: 9 }, Ping { remaining: 9 }]).run();
        for threads in [2usize, 4, 0] {
            let par = tiny_engine(vec![Ping { remaining: 9 }, Ping { remaining: 9 }])
                .run_threads(threads);
            assert_eq!(seq.makespan, par.makespan, "threads={threads}");
            assert_eq!(seq.events, par.events, "threads={threads}");
            assert_eq!(seq.net.msgs_sent, par.net.msgs_sent);
            assert_eq!(seq.net.msgs_delivered, par.net.msgs_delivered);
        }
    }

    /// Fan-in program: N-1 nodes send to node 0; checks idle/busy tracking.
    #[derive(Clone)]
    struct FanIn {
        expect: u32,
        got: u32,
    }
    impl Program for FanIn {
        type Msg = Msg;
        fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
            if ctx.node() != 0 {
                ctx.send(0, Msg);
            }
        }
        fn on_message(&mut self, ctx: &mut Ctx<Msg>, _src: NodeId, _msg: Msg) {
            self.got += 1;
            ctx.compute(10);
            if self.got == self.expect {
                ctx.finish();
            }
        }
    }

    fn fan_in_engine(n: usize) -> Engine<FanIn> {
        let progs: Vec<FanIn> =
            (0..n).map(|_| FanIn { expect: (n - 1) as u32, got: 0 }).collect();
        let topo = Topology::paper(n);
        let fabric = Fabric::new(topo, NetConfig::default(), 3);
        Engine::new(progs, fabric, CoreModel::default(), 5)
    }

    #[test]
    fn fan_in_counts_messages_and_busy_time() {
        let n = 32;
        let summary = fan_in_engine(n).run();
        assert_eq!(summary.net.msgs_sent, (n - 1) as u64);
        assert_eq!(summary.net.msgs_delivered, (n - 1) as u64);
        let s0 = &summary.node_stats[0];
        assert_eq!(s0.msgs_in, (n - 1) as u64);
        assert!(s0.finished);
        assert!(s0.total_busy() > Time::ZERO);
        // RX-bound incast: 31 messages ≈ 31 * rx(8B) ≈ 31*18 cycles.
        let busy_ns = s0.total_busy().as_ns_f64();
        assert!(busy_ns > 100.0, "busy = {busy_ns}");
    }

    /// Cross-shard incast: every sender lives on a different shard than
    /// the receiver; the ingress-serialization chain (destination-owned
    /// state, canonical admission order) must replay identically.
    #[test]
    fn seq_and_par_agree_on_fan_in() {
        let n = 32;
        let seq = fan_in_engine(n).run();
        for threads in [2usize, 3, 8, 32] {
            let par = fan_in_engine(n).run_threads(threads);
            assert_eq!(seq.makespan, par.makespan, "threads={threads}");
            assert_eq!(seq.events, par.events);
            for (a, b) in seq.node_stats.iter().zip(&par.node_stats) {
                assert_eq!(a.msgs_in, b.msgs_in);
                assert_eq!(a.last_active, b.last_active);
                assert_eq!(a.total_busy(), b.total_busy());
                assert_eq!(a.total_idle(), b.total_idle());
            }
        }
    }

    /// Group-broadcast program: node 0 multicasts to a range group; every
    /// member acks. Exercises `Group::Range` through the batched path.
    #[derive(Clone)]
    struct Bcast {
        acks: u32,
    }
    impl Program for Bcast {
        type Msg = Msg;
        fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
            if ctx.node() == 0 {
                ctx.multicast(0, Msg);
            }
        }
        fn on_message(&mut self, ctx: &mut Ctx<Msg>, src: NodeId, _msg: Msg) {
            if ctx.node() != 0 {
                ctx.send(src, Msg);
            } else {
                self.acks += 1;
            }
        }
    }

    fn bcast_engine(n: usize, members: Group) -> Engine<Bcast> {
        let progs: Vec<Bcast> = (0..n).map(|_| Bcast { acks: 0 }).collect();
        let fabric = Fabric::new(Topology::paper(n), NetConfig::default(), 3);
        let mut engine = Engine::new(progs, fabric, CoreModel::default(), 5);
        engine.add_group(members);
        engine
    }

    #[test]
    fn range_groups_deliver_to_every_member_once() {
        let n = 16;
        let engine = bcast_engine(n, Group::from(0..n));
        let summary = engine.run();
        // One multicast in, n members delivered on the wire (the sender's
        // own copy is a phantom leg), n-1 handler deliveries + n-1 acks.
        assert_eq!(summary.net.multicasts, 1);
        assert_eq!(summary.net.msgs_delivered, (2 * n - 1) as u64);
        assert_eq!(summary.node_stats[0].msgs_in, (n - 1) as u64);
        for id in 1..n {
            assert_eq!(summary.node_stats[id].msgs_in, 1, "node {id}");
        }
    }

    #[test]
    fn range_and_list_groups_are_equivalent() {
        let n = 16;
        let a = bcast_engine(n, Group::from(0..n)).run();
        let b = bcast_engine(n, Group::from((0..n).collect::<Vec<_>>())).run();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.events, b.events);
        assert_eq!(a.net.msgs_delivered, b.net.msgs_delivered);
    }

    /// Multicast fan-out crossing shard boundaries (including the phantom
    /// self-leg staying local) must replay identically in parallel.
    #[test]
    fn seq_and_par_agree_on_multicast() {
        let n = 16;
        let seq = bcast_engine(n, Group::from(0..n)).run();
        for threads in [2usize, 5, 16] {
            let par = bcast_engine(n, Group::from(0..n)).run_threads(threads);
            assert_eq!(seq.makespan, par.makespan, "threads={threads}");
            assert_eq!(seq.events, par.events);
            assert_eq!(seq.net.msgs_delivered, par.net.msgs_delivered);
        }
    }

    /// Reorder program: node 1 expects step-0 then step-1 messages, but
    /// node 0 sends the step-1 message *first*.
    #[derive(Clone)]
    struct StepMsg(u32);
    impl WireMsg for StepMsg {
        fn wire_bytes(&self) -> u64 {
            8
        }
        fn step(&self) -> u32 {
            self.0
        }
    }
    #[derive(Clone)]
    struct Reorderee {
        at_step: u32,
        log: Vec<u32>,
    }
    impl Program for Reorderee {
        type Msg = StepMsg;
        fn on_start(&mut self, ctx: &mut Ctx<StepMsg>) {
            if ctx.node() == 0 {
                // Send out of order: step 1 first, then step 0.
                ctx.send(1, StepMsg(1));
                ctx.send(1, StepMsg(0));
            }
        }
        fn on_message(&mut self, _ctx: &mut Ctx<StepMsg>, _src: NodeId, msg: StepMsg) {
            self.log.push(msg.0);
            if msg.0 == 0 {
                self.at_step = 1; // now willing to take step-1 messages
            }
        }
        fn step(&self) -> u32 {
            self.at_step
        }
    }

    #[test]
    fn reorder_buffer_delivers_in_step_order() {
        let progs = vec![
            Reorderee { at_step: 0, log: vec![] },
            Reorderee { at_step: 0, log: vec![] },
        ];
        let topo = Topology::paper(2);
        let fabric = Fabric::new(topo, NetConfig::default(), 9);
        // Engine::run consumes programs; check via stats instead — both
        // messages must be processed (msgs_in = 2, one of them buffered).
        let summary = Engine::new(progs, fabric, CoreModel::default(), 11).run();
        let s1 = &summary.node_stats[1];
        // step-1 msg arrives first (buffered, +1 msg_in), then step-0 is
        // processed, then the buffered one is re-delivered (+1 msg_in).
        assert_eq!(s1.msgs_in, 3, "arrival + buffered redelivery accounting");
    }

    #[test]
    fn straggler_slowdown_extends_makespan_and_factor_one_is_identity() {
        let run = |slow: Option<(NodeId, u32)>, threads: usize| {
            let mut e = tiny_engine(vec![Ping { remaining: 10 }, Ping { remaining: 10 }]);
            if let Some((node, factor)) = slow {
                e.slow_down(node, factor);
            }
            e.run_threads(threads)
        };
        let base = run(None, 1);
        let identity = run(Some((1, 1)), 1);
        assert_eq!(base.makespan, identity.makespan, "factor 1 must be exact");
        assert_eq!(base.events, identity.events);
        let slowed = run(Some((1, 8)), 1);
        assert!(
            slowed.makespan > base.makespan,
            "slowed {} !> base {}",
            slowed.makespan.as_ns_f64(),
            base.makespan.as_ns_f64()
        );
        // Determinism under slowdown, at any thread count.
        let again = run(Some((1, 8)), 1);
        assert_eq!(slowed.makespan, again.makespan);
        let par = run(Some((1, 8)), 2);
        assert_eq!(slowed.makespan, par.makespan, "straggler run must shard identically");
    }

    #[test]
    fn quiescence_with_no_work() {
        let e = tiny_engine(vec![Ping { remaining: 0 }, Ping { remaining: 0 }]);
        let summary = e.run();
        assert_eq!(summary.makespan, Time::ZERO);
        assert_eq!(summary.events, 0);
        // The parallel backend also terminates on an empty event set.
        let e = tiny_engine(vec![Ping { remaining: 0 }, Ping { remaining: 0 }]);
        assert_eq!(e.run_threads(2).makespan, Time::ZERO);
    }

    /// Window coalescing is a host-perf knob, never a semantics knob:
    /// every coalescing factor — including the identity `k = 1` (the
    /// pre-coalescing one-window-per-round schedule) and factors far
    /// beyond any real tuning — must reproduce the sequential backend's
    /// results bit for bit, on both the latency-sensitive ping-pong and
    /// the cross-shard fan-in.
    #[test]
    fn window_batching_is_result_identity() {
        let seq_pp = tiny_engine(vec![Ping { remaining: 10 }, Ping { remaining: 10 }]).run();
        let seq_fan = fan_in_engine(32).run();
        for k in [1usize, 2, 4, 16, 1000] {
            let exec = ParExecutor { threads: 2, window_batch: Some(k) };
            let pp = exec.run(
                tiny_engine(vec![Ping { remaining: 10 }, Ping { remaining: 10 }])
                    .into_parts(),
            );
            assert_eq!(seq_pp.makespan, pp.makespan, "ping-pong k={k}");
            assert_eq!(seq_pp.events, pp.events, "ping-pong k={k}");
            assert_eq!(seq_pp.net.msgs_delivered, pp.net.msgs_delivered);

            let exec = ParExecutor { threads: 8, window_batch: Some(k) };
            let fan = exec.run(fan_in_engine(32).into_parts());
            assert_eq!(seq_fan.makespan, fan.makespan, "fan-in k={k}");
            assert_eq!(seq_fan.events, fan.events, "fan-in k={k}");
            for (a, b) in seq_fan.node_stats.iter().zip(&fan.node_stats) {
                assert_eq!(a.total_busy(), b.total_busy(), "fan-in k={k}");
                assert_eq!(a.total_idle(), b.total_idle(), "fan-in k={k}");
            }
        }
    }

    /// The chain-guard hazard shape: shard 0 holds a long self-send
    /// chain (a local event every ~L) *and* wakes shard 1 at t=0; the
    /// woken shard's reply lands ~2 latencies in — in the middle of what
    /// a naive k-window coalesced drain would have already processed.
    /// Without the guard, a coalescing factor ≥ 3 processes the 3L-ish
    /// self-chain event before the 2L-ish reply and diverges from the
    /// sequential order (debug builds panic on the past-push assert).
    #[derive(Clone)]
    struct ChainEcho {
        /// Remaining self-chain hops (node 0 only).
        hops: u32,
    }
    #[derive(Clone)]
    enum EchoMsg {
        Wake,
        SelfHop,
        Reply,
    }
    impl WireMsg for EchoMsg {
        fn wire_bytes(&self) -> u64 {
            8
        }
    }
    impl Program for ChainEcho {
        type Msg = EchoMsg;
        fn on_start(&mut self, ctx: &mut Ctx<EchoMsg>) {
            if ctx.node() == 0 {
                ctx.send(1, EchoMsg::Wake);
                ctx.send(0, EchoMsg::SelfHop);
            }
        }
        fn on_message(&mut self, ctx: &mut Ctx<EchoMsg>, src: NodeId, msg: EchoMsg) {
            match msg {
                EchoMsg::Wake => ctx.send(src, EchoMsg::Reply),
                EchoMsg::SelfHop => {
                    if self.hops > 0 {
                        self.hops -= 1;
                        ctx.send(0, EchoMsg::SelfHop);
                    }
                }
                EchoMsg::Reply => {
                    // Make the interleaving observable: the reply's
                    // handler burns cycles, so processing it out of
                    // order shifts busy_until for every later self-hop.
                    ctx.compute(500);
                }
            }
        }
    }

    #[test]
    fn window_batching_exact_under_cross_shard_reply_chains() {
        let mk = || {
            let progs = vec![ChainEcho { hops: 40 }, ChainEcho { hops: 0 }];
            let fabric = Fabric::new(Topology::paper(2), NetConfig::default(), 7);
            Engine::new(progs, fabric, CoreModel::default(), 13)
        };
        let seq = mk().run();
        assert!(seq.events > 40, "self-chain + wake + reply all processed");
        for k in [1usize, 2, 3, 4, 16, 1000] {
            let par = ParExecutor { threads: 2, window_batch: Some(k) }.run(mk().into_parts());
            assert_eq!(seq.makespan, par.makespan, "k={k}");
            assert_eq!(seq.events, par.events, "k={k}");
            for (a, b) in seq.node_stats.iter().zip(&par.node_stats) {
                assert_eq!(a.total_busy(), b.total_busy(), "k={k}");
                assert_eq!(a.total_idle(), b.total_idle(), "k={k}");
                assert_eq!(a.last_active, b.last_active, "k={k}");
            }
        }
    }

    /// A straggling lone node (the coalescing win case: one shard holds
    /// every pending event while the rest idle) must drain identically
    /// at every factor.
    #[test]
    fn window_batching_exact_under_stragglers() {
        let mk = || {
            let mut e = fan_in_engine(16);
            e.slow_down(3, 64);
            e
        };
        let seq = mk().run();
        for k in [1usize, 4, 64] {
            let par = ParExecutor { threads: 4, window_batch: Some(k) }.run(mk().into_parts());
            assert_eq!(seq.makespan, par.makespan, "k={k}");
            assert_eq!(seq.events, par.events, "k={k}");
        }
    }

    /// Zero-lookahead fabrics (degenerate config) cannot window; the
    /// parallel entry point must fall back to sequential semantics
    /// rather than deadlock or diverge.
    #[test]
    fn zero_lookahead_falls_back_to_sequential() {
        let degenerate =
            || NetConfig { nic_overhead_ns: 0, header_bytes: 0, ..NetConfig::default() };
        let mk = || {
            let progs = vec![Ping { remaining: 6 }, Ping { remaining: 6 }];
            let fabric = Fabric::new(Topology::paper(2), degenerate(), 1);
            Engine::new(progs, fabric, CoreModel::default(), 42)
        };
        assert_eq!(mk().fabric.min_latency(), Time::ZERO);
        let seq = mk().run();
        let par = mk().run_threads(4);
        assert_eq!(seq.makespan, par.makespan);
        assert_eq!(seq.events, par.events);
        let opt = mk().run_exec(ExecKind::Opt, 4, None, None);
        assert_eq!(seq.makespan, opt.makespan);
        assert_eq!(seq.events, opt.events);
    }

    /// The optimistic backend joins the §7 contract on every shape that
    /// already stresses the conservative one: latency ping-pong across
    /// one-node shards, cross-shard incast, multicast fan-out, the
    /// chain-guard hazard, and a straggling core — full per-node stats
    /// and fabric counters, not just the makespan.
    #[test]
    fn opt_backend_matches_sequential_everywhere() {
        let cases: Vec<(&str, RunSummary, Box<dyn Fn(usize) -> RunSummary>)> = vec![
            (
                "ping-pong",
                tiny_engine(vec![Ping { remaining: 9 }, Ping { remaining: 9 }]).run(),
                Box::new(|threads| {
                    tiny_engine(vec![Ping { remaining: 9 }, Ping { remaining: 9 }])
                        .run_exec(ExecKind::Opt, threads, None, None)
                }),
            ),
            (
                "fan-in",
                fan_in_engine(32).run(),
                Box::new(|threads| {
                    fan_in_engine(32).run_exec(ExecKind::Opt, threads, None, None)
                }),
            ),
            (
                "multicast",
                bcast_engine(16, Group::from(0..16)).run(),
                Box::new(|threads| {
                    bcast_engine(16, Group::from(0..16))
                        .run_exec(ExecKind::Opt, threads, None, None)
                }),
            ),
            (
                "chain-echo",
                {
                    let progs = vec![ChainEcho { hops: 40 }, ChainEcho { hops: 0 }];
                    let fabric = Fabric::new(Topology::paper(2), NetConfig::default(), 7);
                    Engine::new(progs, fabric, CoreModel::default(), 13).run()
                },
                Box::new(|threads| {
                    let progs = vec![ChainEcho { hops: 40 }, ChainEcho { hops: 0 }];
                    let fabric = Fabric::new(Topology::paper(2), NetConfig::default(), 7);
                    Engine::new(progs, fabric, CoreModel::default(), 13)
                        .run_exec(ExecKind::Opt, threads, None, None)
                }),
            ),
            (
                "straggler",
                {
                    let mut e = fan_in_engine(16);
                    e.slow_down(3, 64);
                    e.run()
                },
                Box::new(|threads| {
                    let mut e = fan_in_engine(16);
                    e.slow_down(3, 64);
                    e.run_exec(ExecKind::Opt, threads, None, None)
                }),
            ),
        ];
        for (name, seq, opt_run) in &cases {
            for threads in [2usize, 4] {
                let opt = opt_run(threads);
                assert_eq!(seq.makespan, opt.makespan, "{name} threads={threads}");
                assert_eq!(seq.events, opt.events, "{name} threads={threads}");
                assert_eq!(seq.net, opt.net, "{name} threads={threads}");
                assert_eq!(seq.node_stats, opt.node_stats, "{name} threads={threads}");
                // Every burst that went pending resolved exactly once.
                let p = opt.profile;
                assert_eq!(p.speculated, p.committed + p.rollbacks, "{name}");
            }
        }
    }

    /// Independent self-send chain per node: every shard stays busy for
    /// the whole run with zero cross-shard traffic, so the optimistic
    /// backend demonstrably speculates — and, with no inbound transits,
    /// no straggler can exist.
    #[derive(Clone)]
    struct SelfChain {
        hops: u32,
    }
    impl Program for SelfChain {
        type Msg = Msg;
        fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
            if self.hops > 0 {
                let me = ctx.node();
                ctx.send(me, Msg);
            }
        }
        fn on_message(&mut self, ctx: &mut Ctx<Msg>, _src: NodeId, _msg: Msg) {
            if self.hops > 0 {
                self.hops -= 1;
                if self.hops > 0 {
                    let me = ctx.node();
                    ctx.send(me, Msg);
                }
            }
        }
    }

    fn self_chain_engine(n: usize, hops: u32) -> Engine<SelfChain> {
        let progs: Vec<SelfChain> = (0..n).map(|_| SelfChain { hops }).collect();
        let fabric = Fabric::new(Topology::paper(n), NetConfig::default(), 3);
        Engine::new(progs, fabric, CoreModel::default(), 17)
    }

    /// Speculation engages (and commits) on shard-local work, stays
    /// byte-identical to the sequential backend at every coalescing
    /// factor, and the forced-rollback hook exercises the full recovery
    /// path — rollback + conservative re-execution — without disturbing
    /// a single byte of the result.
    #[test]
    fn opt_speculation_and_forced_rollbacks_are_result_identity() {
        let seq = self_chain_engine(4, 300).run();
        let opt = self_chain_engine(4, 300).run_exec(ExecKind::Opt, 2, None, None);
        assert_eq!(seq.makespan, opt.makespan);
        assert_eq!(seq.events, opt.events);
        assert_eq!(seq.node_stats, opt.node_stats);
        let p = opt.profile;
        assert!(p.speculated > 0, "dense local chains must trigger speculation");
        assert_eq!(p.speculated, p.committed + p.rollbacks);
        assert!(p.committed > 0, "uncontended bursts must commit");

        for k in [1usize, 4, 1000] {
            let opt = self_chain_engine(4, 300)
                .run_exec(ExecKind::Opt, 2, Some(k), None);
            assert_eq!(seq.makespan, opt.makespan, "k={k}");
            assert_eq!(seq.node_stats, opt.node_stats, "k={k}");
        }

        for force in [1u64, 3] {
            let opt = self_chain_engine(4, 300)
                .run_exec(ExecKind::Opt, 2, None, Some(force));
            assert_eq!(seq.makespan, opt.makespan, "force={force}");
            assert_eq!(seq.events, opt.events, "force={force}");
            assert_eq!(seq.node_stats, opt.node_stats, "force={force}");
            let p = opt.profile;
            assert_eq!(p.speculated, p.committed + p.rollbacks, "force={force}");
            if force == 1 {
                assert_eq!(p.committed, 0, "every burst must have been rolled back");
                assert_eq!(p.rollbacks, p.speculated);
                assert!(p.rollbacks > 0, "the hook must have fired");
            }
        }
    }

    /// Timer-loop program for the steady-state zero-alloc pin: node 0
    /// re-arms a timer forever, with every fire landing on a
    /// 64-bucket-aligned stride so the orbit revisits the same 1,024
    /// timing-wheel slots each lap (a cold slot's bucket Vec allocates on
    /// first touch; alignment makes the warm set finite and small).
    #[derive(Clone)]
    struct TickMsg;
    impl WireMsg for TickMsg {
        fn wire_bytes(&self) -> u64 {
            8
        }
    }
    #[derive(Clone)]
    struct Ticker {
        fires: u64,
        total: u64,
        warmup: u64,
        baseline: u64,
        violations: Arc<std::sync::atomic::AtomicU64>,
    }
    impl Ticker {
        fn rearm(&self, ctx: &mut Ctx<TickMsg>) {
            // One ring lap = 65,536 buckets; stride = 64 buckets, so the
            // orbit closes after 1,024 fires and every later fire lands
            // in an already-warm slot.
            const STRIDE: u64 = 64 << 6;
            let now = ctx.now().0;
            let target = (now / STRIDE + 1) * STRIDE;
            ctx.timer(Time(target - now), TickMsg);
        }
    }
    impl Program for Ticker {
        type Msg = TickMsg;
        fn on_start(&mut self, ctx: &mut Ctx<TickMsg>) {
            if ctx.node() == 0 {
                self.rearm(ctx);
            }
        }
        fn on_message(&mut self, ctx: &mut Ctx<TickMsg>, _src: NodeId, _msg: TickMsg) {
            self.fires += 1;
            let count = crate::mem::thread_alloc_count();
            if self.fires > self.warmup && count != self.baseline {
                self.violations
                    .fetch_add(count - self.baseline, std::sync::atomic::Ordering::Relaxed);
            }
            self.baseline = count;
            if self.fires < self.total {
                self.rearm(ctx);
            }
        }
    }

    /// The ISSUE 10 acceptance pin: once the data plane is warm (ring
    /// buckets touched, scratch buffers grown), a steady-state event
    /// round performs **zero** heap allocations — pop, deliver, handler,
    /// timer re-arm, push, repeat. Measured with the per-thread allocator
    /// counter between consecutive fires, so parallel test threads
    /// cannot perturb it.
    #[test]
    fn steady_state_rounds_allocate_zero() {
        let violations = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let mk = |v: &Arc<std::sync::atomic::AtomicU64>| Ticker {
            fires: 0,
            total: 1400,
            warmup: 1100,
            baseline: 0,
            violations: v.clone(),
        };
        let progs = vec![mk(&violations), mk(&violations)];
        let fabric = Fabric::new(Topology::paper(2), NetConfig::default(), 1);
        let summary = Engine::new(progs, fabric, CoreModel::default(), 42).run();
        assert!(summary.events >= 1400, "ticker under-ran: {} events", summary.events);
        assert_eq!(
            violations.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "steady-state rounds allocated on the heap"
        );
    }
}
