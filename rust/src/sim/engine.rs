//! The discrete-event engine that drives node programs over the fabric.
//!
//! Model (DESIGN.md §1): each node is a sequential core with a
//! `busy_until` register. A message delivered at `t` begins processing at
//! `max(t, busy_until)`; the handler's RX cost, compute cycles, and TX
//! costs extend `busy_until`; every send is handed to the fabric at the
//! sender-local time at which the handler issued it. The run ends at
//! global quiescence (event heap empty); the makespan is the latest
//! busy-until across nodes.
//!
//! Reorder buffer (paper §5.2): messages for a future algorithm step pay
//! their RX cost on arrival (the software reads them off the NIC) plus a
//! small store, and are re-delivered (cheap pop) once the program reaches
//! that step.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cpu::CoreModel;
use crate::nanopu::{Ctx, GroupId, NodeId, Program, SendOp, WireMsg};
use crate::net::{Fabric, NetStats};

use super::rng::SplitMix64;
use super::time::Time;

/// Cycles to store one out-of-order message into the reorder buffer.
const REORDER_STORE_CYCLES: u64 = 4;
/// Cycles to pop one message out of the reorder buffer.
const REORDER_POP_CYCLES: u64 = 6;
/// Maximum number of stages tracked per node (Fig 16 breakdown).
pub const MAX_STAGES: usize = 16;

/// Heap entry: 24 bytes. The payload lives in a slab (`EventSlab`) so the
/// binary heap sifts small, cache-friendly elements — this is the
/// simulator's top hot path (§Perf: `BinaryHeap::pop` was 64% of the
/// headline run before this split).
#[derive(PartialEq, Eq)]
struct Event {
    at: Time,
    seq: u64,
    slot: u32,
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Calendar queue: a ring of per-4ns-window mini-heaps plus an overflow
/// heap for events beyond the lookahead window.
///
/// §Perf: a single `BinaryHeap` over ~1M in-flight events spent >60% of
/// the headline run in `pop` (20 sift levels of cache misses). Event
/// *lookahead* (arrival − now) is bounded by propagation + endpoint-link
/// queueing (µs-scale), so bucketing by coarse time keeps every touched
/// mini-heap tiny and cache-resident; the cursor only moves forward.
/// Ordering is exact: buckets partition time, and each mini-heap orders
/// by `(at, seq)` — identical results to the global heap (tested).
struct Bucket {
    /// Events of this bucket. When `sorted`, descending by `(at, seq)` so
    /// the next event pops from the back in O(1).
    events: Vec<Event>,
    sorted: bool,
}

struct CalendarQueue {
    ring: Vec<Bucket>,
    /// log2 of time-units per bucket (6 => 64 units = 4 ns).
    g_shift: u32,
    /// Ring size mask (ring.len() - 1).
    mask: u64,
    /// Absolute bucket index the cursor is on.
    cur: u64,
    /// Events whose bucket is beyond the ring window.
    overflow: BinaryHeap<Reverse<Event>>,
    len: usize,
}

impl CalendarQueue {
    /// 2^16 buckets x 4 ns = 262 µs of lookahead window.
    fn new() -> Self {
        let buckets = 1usize << 16;
        CalendarQueue {
            ring: (0..buckets).map(|_| Bucket { events: Vec::new(), sorted: true }).collect(),
            g_shift: 6,
            mask: (buckets - 1) as u64,
            cur: 0,
            overflow: BinaryHeap::new(),
            len: 0,
        }
    }

    fn bucket_of(&self, at: Time) -> u64 {
        at.0 >> self.g_shift
    }

    fn push(&mut self, ev: Event) {
        let b = self.bucket_of(ev.at);
        debug_assert!(b >= self.cur, "event scheduled in the past");
        self.len += 1;
        if b >= self.cur + self.ring.len() as u64 {
            self.overflow.push(Reverse(ev));
        } else {
            let bucket = &mut self.ring[(b & self.mask) as usize];
            bucket.events.push(ev);
            bucket.sorted = false;
        }
    }

    fn pop(&mut self) -> Option<Event> {
        if self.len == 0 {
            return None;
        }
        loop {
            // Re-home overflow events whose bucket has entered the window.
            while let Some(Reverse(top)) = self.overflow.peek() {
                let b = self.bucket_of(top.at);
                if b < self.cur + self.ring.len() as u64 {
                    let Some(Reverse(ev)) = self.overflow.pop() else { unreachable!() };
                    let bucket = &mut self.ring[(b & self.mask) as usize];
                    bucket.events.push(ev);
                    bucket.sorted = false;
                    self.len += 1; // moved, not new — compensated below
                    self.len -= 1;
                } else {
                    break;
                }
            }
            let bucket = &mut self.ring[(self.cur & self.mask) as usize];
            if !bucket.events.is_empty() {
                if !bucket.sorted {
                    // Sort once per drain; a mid-drain insert re-sorts the
                    // (small) remainder. Descending so pops come off the
                    // back. Safe: inserts-while-draining always carry
                    // `at` >= the last popped time (positive latency).
                    bucket
                        .events
                        .sort_unstable_by(|a, b| (b.at, b.seq).cmp(&(a.at, a.seq)));
                    bucket.sorted = true;
                }
                self.len -= 1;
                return bucket.events.pop();
            }
            self.cur += 1;
        }
    }
}

/// Free-listed payload storage for in-flight events (u32 endpoints keep
/// the entry compact; node counts are <= 2^32 by construction).
struct EventSlab<M> {
    payloads: Vec<Option<(u32, u32, M)>>,
    free: Vec<u32>,
}

impl<M> EventSlab<M> {
    fn new() -> Self {
        EventSlab { payloads: Vec::new(), free: Vec::new() }
    }

    fn insert(&mut self, src: NodeId, dst: NodeId, msg: M) -> u32 {
        let entry = (src as u32, dst as u32, msg);
        if let Some(slot) = self.free.pop() {
            self.payloads[slot as usize] = Some(entry);
            slot
        } else {
            self.payloads.push(Some(entry));
            (self.payloads.len() - 1) as u32
        }
    }

    fn remove(&mut self, slot: u32) -> (NodeId, NodeId, M) {
        let (src, dst, msg) = self.payloads[slot as usize].take().expect("slot occupied");
        self.free.push(slot);
        (src as NodeId, dst as NodeId, msg)
    }
}

/// Per-node accounting (drives Figs 15b and 16).
#[derive(Debug, Clone)]
pub struct NodeStats {
    /// Busy time attributed to each stage.
    pub busy: [Time; MAX_STAGES],
    /// Idle (waiting-for-message) time attributed to each stage.
    pub idle: [Time; MAX_STAGES],
    /// Messages processed.
    pub msgs_in: u64,
    /// Messages sent.
    pub msgs_out: u64,
    /// Last time this node did any work.
    pub last_active: Time,
    /// Stage at which the node declared itself finished.
    pub finished: bool,
}

impl Default for NodeStats {
    fn default() -> Self {
        NodeStats {
            busy: [Time::ZERO; MAX_STAGES],
            idle: [Time::ZERO; MAX_STAGES],
            msgs_in: 0,
            msgs_out: 0,
            last_active: Time::ZERO,
            finished: false,
        }
    }
}

impl NodeStats {
    pub fn total_busy(&self) -> Time {
        Time(self.busy.iter().map(|t| t.0).sum())
    }
    pub fn total_idle(&self) -> Time {
        Time(self.idle.iter().map(|t| t.0).sum())
    }
}

struct NodeSlot<P: Program> {
    prog: P,
    busy_until: Time,
    stage: u8,
    finished: bool,
    rng: SplitMix64,
    /// Reorder buffer: (step, src, msg), kept in arrival order.
    held: Vec<(u32, NodeId, P::Msg)>,
    stats: NodeStats,
}

/// Outcome of a completed run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    /// Latest busy-until across all nodes (the job completion time).
    pub makespan: Time,
    /// Per-node accounting.
    pub node_stats: Vec<NodeStats>,
    /// Fabric counters.
    pub net: NetStats,
    /// Total events processed (engine-level, for perf work).
    pub events: u64,
}

impl RunSummary {
    /// Mean busy fraction across nodes (busy / makespan).
    pub fn mean_utilization(&self) -> f64 {
        if self.makespan == Time::ZERO || self.node_stats.is_empty() {
            return 0.0;
        }
        let total: f64 = self.node_stats.iter().map(|s| s.total_busy().0 as f64).sum();
        total / (self.makespan.0 as f64 * self.node_stats.len() as f64)
    }
}

/// The engine: nodes + heap + fabric + core model.
pub struct Engine<P: Program> {
    nodes: Vec<NodeSlot<P>>,
    heap: CalendarQueue,
    slab: EventSlab<P::Msg>,
    fabric: Fabric,
    core: CoreModel,
    groups: Vec<Vec<NodeId>>,
    seq: u64,
    events: u64,
    /// Scratch buffer for handler-emitted ops (reused across invokes —
    /// §Perf: one Vec alloc/free per delivered message otherwise).
    ops_scratch: Vec<(u64, SendOp<P::Msg>)>,
}

impl<P: Program> Engine<P> {
    /// Build an engine over `programs` (node id = index).
    pub fn new(programs: Vec<P>, fabric: Fabric, core: CoreModel, seed: u64) -> Self {
        assert_eq!(programs.len(), fabric.topo.nodes, "program count != topology nodes");
        let root = SplitMix64::new(seed);
        let nodes = programs
            .into_iter()
            .enumerate()
            .map(|(i, prog)| NodeSlot {
                prog,
                busy_until: Time::ZERO,
                stage: 0,
                finished: false,
                rng: root.derive(i as u64),
                held: Vec::new(),
                stats: NodeStats::default(),
            })
            .collect();
        Engine {
            nodes,
            heap: CalendarQueue::new(),
            slab: EventSlab::new(),
            fabric,
            core,
            groups: Vec::new(),
            seq: 0,
            events: 0,
            ops_scratch: Vec::new(),
        }
    }

    /// Register a multicast group; returns its id.
    pub fn add_group(&mut self, members: Vec<NodeId>) -> GroupId {
        self.groups.push(members);
        self.groups.len() - 1
    }

    pub fn core(&self) -> &CoreModel {
        &self.core
    }

    /// Run to quiescence; consumes the engine.
    pub fn run(mut self) -> RunSummary {
        // Start every node at t=0 (the cluster is pre-loaded and triggered
        // together, like the paper's benchmark start).
        for id in 0..self.nodes.len() {
            self.invoke(id, Time::ZERO, None);
            self.drain_reorder(id);
        }
        while let Some(ev) = self.heap.pop() {
            self.events += 1;
            let (src, dst, msg) = self.slab.remove(ev.slot);
            self.deliver(ev.at, src, dst, msg);
        }
        let makespan = self
            .nodes
            .iter()
            .map(|n| n.stats.last_active)
            .max()
            .unwrap_or(Time::ZERO);
        RunSummary {
            makespan,
            net: self.fabric.stats().clone(),
            node_stats: self.nodes.into_iter().map(|n| n.stats).collect(),
            events: self.events,
        }
    }

    fn deliver(&mut self, at: Time, src: NodeId, dst: NodeId, msg: P::Msg) {
        let step = msg.step();
        if step > self.nodes[dst].prog.step() {
            // Future-step message: RX + store into the reorder buffer.
            let slot = &mut self.nodes[dst];
            let start = at.max(slot.busy_until);
            let idle = start.saturating_sub(slot.busy_until);
            let stage = slot.stage as usize;
            slot.stats.idle[stage] += idle;
            let cost = Time::from_cycles(
                self.core.rx_cycles(msg.wire_bytes()) + REORDER_STORE_CYCLES,
            );
            slot.busy_until = start + cost;
            slot.stats.busy[stage] += cost;
            slot.stats.last_active = slot.busy_until;
            slot.stats.msgs_in += 1;
            slot.held.push((step, src, msg));
            return;
        }
        self.invoke(dst, at, Some((src, msg, true)));
        self.drain_reorder(dst);
    }

    /// Re-deliver buffered messages whose step has become current.
    fn drain_reorder(&mut self, id: NodeId) {
        loop {
            let cur = self.nodes[id].prog.step();
            let pos = self.nodes[id].held.iter().position(|(s, _, _)| *s <= cur);
            let Some(pos) = pos else { break };
            let (_, src, msg) = self.nodes[id].held.remove(pos);
            let at = self.nodes[id].busy_until;
            self.invoke_held(id, at, src, msg);
        }
    }

    fn invoke_held(&mut self, id: NodeId, at: Time, src: NodeId, msg: P::Msg) {
        // Pop cost instead of RX (already read off the NIC at arrival).
        let resume = {
            let slot = &mut self.nodes[id];
            slot.busy_until =
                at.max(slot.busy_until) + Time::from_cycles(REORDER_POP_CYCLES);
            slot.busy_until
        };
        self.invoke(id, resume, Some((src, msg, false)));
    }

    /// Core of the model: run one handler and apply its effects.
    fn invoke(&mut self, id: NodeId, at: Time, input: Option<(NodeId, P::Msg, bool)>) {
        let slot = &mut self.nodes[id];
        let start = at.max(slot.busy_until);
        // Idle attribution: waiting between end of previous work and start.
        let idle = start.saturating_sub(slot.busy_until);
        if input.is_some() {
            slot.stats.idle[slot.stage as usize] += idle;
        }

        let mut entry = start;
        let charge_rx = matches!(&input, Some((_, _, true)));
        if let Some((_, msg, _)) = &input {
            if charge_rx {
                entry += Time::from_cycles(self.core.rx_cycles(msg.wire_bytes()));
            }
            slot.stats.msgs_in += 1;
        }

        let mut stage = slot.stage;
        let mut finished = slot.finished;
        debug_assert!(self.ops_scratch.is_empty());
        let mut ctx = Ctx {
            node: id,
            core: &self.core,
            rng: &mut slot.rng,
            entry,
            cycles: 0,
            ops: std::mem::take(&mut self.ops_scratch),
            stage: &mut stage,
            finished: &mut finished,
            mcast_supported: self.fabric.multicast_supported(),
        };
        let was_msg = input.is_some();
        match input {
            Some((src, msg, _)) => slot.prog.on_message(&mut ctx, src, msg),
            None => slot.prog.on_start(&mut ctx),
        }
        let cycles = ctx.cycles;
        let ops = std::mem::take(&mut ctx.ops);
        drop(ctx);

        let end = entry + Time::from_cycles(cycles);
        let busy_span = end.saturating_sub(start);
        slot.stats.busy[slot.stage as usize] += busy_span;
        slot.stage = stage;
        slot.finished = finished;
        slot.stats.finished = finished;
        slot.busy_until = end;
        if busy_span > Time::ZERO || was_msg {
            slot.stats.last_active = end;
        }
        slot.stats.msgs_out += ops.len() as u64;

        // Hand sends to the fabric at the local time they were issued.
        let mut ops = ops;
        for (cyc_offset, op) in ops.drain(..) {
            let ready = entry + Time::from_cycles(cyc_offset);
            match op {
                SendOp::Unicast { dst, msg } => {
                    let arr = self.fabric.unicast(id, dst, msg.wire_bytes(), ready);
                    self.push_event(arr, id, dst, msg);
                }
                SendOp::Multicast { group, msg } => {
                    let members = std::mem::take(&mut self.groups[group]);
                    let deliveries =
                        self.fabric.multicast(id, &members, msg.wire_bytes(), ready);
                    self.groups[group] = members;
                    for (dst, arr) in deliveries {
                        if dst != id {
                            self.push_event(arr, id, dst, msg.clone());
                        }
                    }
                }
            }
        }
        // Return the drained buffer to the scratch slot for reuse.
        self.ops_scratch = ops;
    }

    fn push_event(&mut self, at: Time, src: NodeId, dst: NodeId, msg: P::Msg) {
        self.seq += 1;
        let slot = self.slab.insert(src, dst, msg);
        self.heap.push(Event { at, seq: self.seq, slot });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{NetConfig, Topology};

    /// Ping-pong program: node 0 sends `hops` round trips to node 1.
    #[derive(Clone)]
    struct Ping {
        remaining: u32,
    }

    #[derive(Clone)]
    struct Msg;
    impl WireMsg for Msg {
        fn wire_bytes(&self) -> u64 {
            8
        }
    }

    impl Program for Ping {
        type Msg = Msg;
        fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
            if ctx.node() == 0 && self.remaining > 0 {
                ctx.send(1, Msg);
            }
        }
        fn on_message(&mut self, ctx: &mut Ctx<Msg>, src: NodeId, _msg: Msg) {
            if self.remaining > 0 {
                self.remaining -= 1;
                if self.remaining > 0 {
                    ctx.send(src, Msg);
                }
            }
        }
    }

    fn tiny_engine(progs: Vec<Ping>) -> Engine<Ping> {
        let topo = Topology::paper(progs.len());
        let fabric = Fabric::new(topo, NetConfig::default(), 1);
        Engine::new(progs, fabric, CoreModel::default(), 42)
    }

    #[test]
    fn ping_pong_terminates_with_sane_latency() {
        let e = tiny_engine(vec![Ping { remaining: 10 }, Ping { remaining: 10 }]);
        let summary = e.run();
        // Same-leaf one-way ≈ tx + 2*28 + 2*43 + 263 + ser + rx ≈ 420 ns;
        // 10 one-way legs ≈ 4.2 µs. Allow generous bounds.
        let us = summary.makespan.as_us_f64();
        assert!((2.0..10.0).contains(&us), "makespan = {us} µs");
        assert!(summary.events >= 10);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = tiny_engine(vec![Ping { remaining: 7 }, Ping { remaining: 7 }]).run();
        let b = tiny_engine(vec![Ping { remaining: 7 }, Ping { remaining: 7 }]).run();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.events, b.events);
        assert_eq!(a.net.msgs_sent, b.net.msgs_sent);
    }

    /// Fan-in program: N-1 nodes send to node 0; checks idle/busy tracking.
    #[derive(Clone)]
    struct FanIn {
        expect: u32,
        got: u32,
    }
    impl Program for FanIn {
        type Msg = Msg;
        fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
            if ctx.node() != 0 {
                ctx.send(0, Msg);
            }
        }
        fn on_message(&mut self, ctx: &mut Ctx<Msg>, _src: NodeId, _msg: Msg) {
            self.got += 1;
            ctx.compute(10);
            if self.got == self.expect {
                ctx.finish();
            }
        }
    }

    #[test]
    fn fan_in_counts_messages_and_busy_time() {
        let n = 32;
        let progs: Vec<FanIn> =
            (0..n).map(|_| FanIn { expect: (n - 1) as u32, got: 0 }).collect();
        let topo = Topology::paper(n);
        let fabric = Fabric::new(topo, NetConfig::default(), 3);
        let summary = Engine::new(progs, fabric, CoreModel::default(), 5).run();
        assert_eq!(summary.net.msgs_sent, (n - 1) as u64);
        assert_eq!(summary.net.msgs_delivered, (n - 1) as u64);
        let s0 = &summary.node_stats[0];
        assert_eq!(s0.msgs_in, (n - 1) as u64);
        assert!(s0.finished);
        assert!(s0.total_busy() > Time::ZERO);
        // RX-bound incast: 31 messages ≈ 31 * rx(8B) ≈ 31*18 cycles.
        let busy_ns = s0.total_busy().as_ns_f64();
        assert!(busy_ns > 100.0, "busy = {busy_ns}");
    }

    /// Reorder program: node 1 expects step-0 then step-1 messages, but
    /// node 0 sends the step-1 message *first*.
    #[derive(Clone)]
    struct StepMsg(u32);
    impl WireMsg for StepMsg {
        fn wire_bytes(&self) -> u64 {
            8
        }
        fn step(&self) -> u32 {
            self.0
        }
    }
    #[derive(Clone)]
    struct Reorderee {
        at_step: u32,
        log: Vec<u32>,
    }
    impl Program for Reorderee {
        type Msg = StepMsg;
        fn on_start(&mut self, ctx: &mut Ctx<StepMsg>) {
            if ctx.node() == 0 {
                // Send out of order: step 1 first, then step 0.
                ctx.send(1, StepMsg(1));
                ctx.send(1, StepMsg(0));
            }
        }
        fn on_message(&mut self, _ctx: &mut Ctx<StepMsg>, _src: NodeId, msg: StepMsg) {
            self.log.push(msg.0);
            if msg.0 == 0 {
                self.at_step = 1; // now willing to take step-1 messages
            }
        }
        fn step(&self) -> u32 {
            self.at_step
        }
    }

    #[test]
    fn reorder_buffer_delivers_in_step_order() {
        let progs = vec![
            Reorderee { at_step: 0, log: vec![] },
            Reorderee { at_step: 0, log: vec![] },
        ];
        let topo = Topology::paper(2);
        let fabric = Fabric::new(topo, NetConfig::default(), 9);
        // Engine::run consumes programs; to inspect the log we re-run the
        // scenario through a channel: check via stats instead — both
        // messages must be processed (msgs_in = 2, one of them buffered).
        let summary = Engine::new(progs, fabric, CoreModel::default(), 11).run();
        let s1 = &summary.node_stats[1];
        // step-1 msg arrives first (buffered, +1 msg_in), then step-0 is
        // processed, then the buffered one is re-delivered (+1 msg_in).
        assert_eq!(s1.msgs_in, 3, "arrival + buffered redelivery accounting");
    }

    #[test]
    fn quiescence_with_no_work() {
        let e = tiny_engine(vec![Ping { remaining: 0 }, Ping { remaining: 0 }]);
        let summary = e.run();
        assert_eq!(summary.makespan, Time::ZERO);
        assert_eq!(summary.events, 0);
    }
}
