//! The discrete-event engine that drives node programs over the fabric.
//!
//! Model (DESIGN.md §1): each node is a sequential core with a
//! `busy_until` register. A message delivered at `t` begins processing at
//! `max(t, busy_until)`; the handler's RX cost, compute cycles, and TX
//! costs extend `busy_until`; every send is handed to the fabric at the
//! sender-local time at which the handler issued it. The run ends at
//! global quiescence (event heap empty); the makespan is the latest
//! busy-until across nodes.
//!
//! Reorder buffer (paper §5.2): messages for a future algorithm step pay
//! their RX cost on arrival (the software reads them off the NIC) plus a
//! small store, and are re-delivered (cheap pop) once the program reaches
//! that step.
//!
//! §Scale: the paper-scale configuration (65,536 nodes × 1M keys) keeps
//! ~1M events in flight. The layout is tuned for that: per-node hot state
//! is a flat arena ([`HotNode`], 16 B/node) separate from cold program
//! state, stats live in their own arena handed to [`RunSummary`] without
//! a copy, multicast deliveries are injected through one reused scratch
//! buffer, and the calendar queue backs its ring with a *sharded* far
//! tier (bulk re-homed per window) instead of a global overflow heap.

use std::collections::BTreeMap;

use crate::cpu::CoreModel;
use crate::nanopu::{Ctx, Group, GroupId, NodeId, Program, SendOp, WireMsg};
use crate::net::{Fabric, NetStats};

use super::rng::SplitMix64;
use super::time::Time;

/// Cycles to store one out-of-order message into the reorder buffer.
const REORDER_STORE_CYCLES: u64 = 4;
/// Cycles to pop one message out of the reorder buffer.
const REORDER_POP_CYCLES: u64 = 6;
/// Maximum number of stages tracked per node (Fig 16 breakdown).
pub const MAX_STAGES: usize = 16;

/// Heap entry: 24 bytes. The payload lives in a slab (`EventSlab`) so the
/// calendar queue sifts small, cache-friendly elements — this is the
/// simulator's top hot path (§Perf: `BinaryHeap::pop` was 64% of the
/// headline run before this split).
#[derive(PartialEq, Eq)]
struct Event {
    at: Time,
    seq: u64,
    slot: u32,
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Calendar queue: a ring of per-4ns-window mini-heaps plus a sharded far
/// tier for events beyond the lookahead window.
///
/// §Perf: a single `BinaryHeap` over ~1M in-flight events spent >60% of
/// the headline run in `pop` (20 sift levels of cache misses). Event
/// *lookahead* (arrival − now) is bounded by propagation + endpoint-link
/// queueing (µs-scale), so bucketing by coarse time keeps every touched
/// mini-heap tiny and cache-resident; the cursor only moves forward.
///
/// §Scale: events beyond the ring window used to sit in one overflow
/// `BinaryHeap`, re-homed one `pop` at a time (O(log n) each, and the
/// heap grows unbounded under heavy tail injection). The far tier is now
/// *sharded* by window index (`bucket >> ring_bits`): pushes append to
/// their shard in O(1), and when the cursor crosses a window boundary the
/// next shard is re-homed wholesale into the ring. Ordering is exact:
/// shards and buckets partition time, and each mini-heap orders by
/// `(at, seq)` — identical results to the global heap (tested).
struct Bucket {
    /// Events of this bucket. When `sorted`, descending by `(at, seq)` so
    /// the next event pops from the back in O(1).
    events: Vec<Event>,
    sorted: bool,
}

struct CalendarQueue {
    ring: Vec<Bucket>,
    /// log2 of time-units per bucket (6 => 64 units = 4 ns).
    g_shift: u32,
    /// Ring size mask (ring.len() - 1).
    mask: u64,
    /// log2 of the ring length — the aligned far-shard width.
    ring_bits: u32,
    /// Absolute bucket index the cursor is on.
    cur: u64,
    /// Far tier: aligned window index (bucket >> ring_bits) → its events,
    /// in push order. Re-homed in bulk when the cursor enters the window.
    far: BTreeMap<u64, Vec<Event>>,
    /// Events currently resident in the ring (vs the far tier).
    ring_count: usize,
    len: usize,
}

impl CalendarQueue {
    /// 2^16 buckets x 4 ns = 262 µs of lookahead window.
    fn new() -> Self {
        let ring_bits = 16u32;
        let buckets = 1usize << ring_bits;
        CalendarQueue {
            ring: (0..buckets).map(|_| Bucket { events: Vec::new(), sorted: true }).collect(),
            g_shift: 6,
            mask: (buckets - 1) as u64,
            ring_bits,
            cur: 0,
            far: BTreeMap::new(),
            ring_count: 0,
            len: 0,
        }
    }

    fn bucket_of(&self, at: Time) -> u64 {
        at.0 >> self.g_shift
    }

    fn push(&mut self, ev: Event) {
        let b = self.bucket_of(ev.at);
        debug_assert!(b >= self.cur, "event scheduled in the past");
        self.len += 1;
        if b >= self.cur + self.ring.len() as u64 {
            self.far.entry(b >> self.ring_bits).or_default().push(ev);
        } else {
            let bucket = &mut self.ring[(b & self.mask) as usize];
            bucket.events.push(ev);
            bucket.sorted = false;
            self.ring_count += 1;
        }
    }

    /// Move one far shard's events into the ring. Only called once the
    /// cursor has entered (or is jumping to) that aligned window, at which
    /// point every shard event's bucket lies within the ring's lookahead.
    fn rehome(&mut self, window: u64) {
        let Some(events) = self.far.remove(&window) else { return };
        for ev in events {
            let b = self.bucket_of(ev.at);
            debug_assert!(b >= self.cur && b < self.cur + self.ring.len() as u64);
            let bucket = &mut self.ring[(b & self.mask) as usize];
            bucket.events.push(ev);
            bucket.sorted = false;
            self.ring_count += 1;
        }
    }

    fn pop(&mut self) -> Option<Event> {
        if self.len == 0 {
            return None;
        }
        loop {
            if self.ring_count == 0 {
                // Everything left lives in the far tier: fast-forward the
                // cursor to the first populated shard and re-home it
                // wholesale (no bucket-by-bucket scanning across the gap).
                let (&window, _) = self.far.iter().next().expect("len > 0 but no events");
                self.cur = self.cur.max(window << self.ring_bits);
                self.rehome(window);
                continue;
            }
            let bucket = &mut self.ring[(self.cur & self.mask) as usize];
            if !bucket.events.is_empty() {
                if !bucket.sorted {
                    // Sort once per drain; a mid-drain insert re-sorts the
                    // (small) remainder. Descending so pops come off the
                    // back. Safe: inserts-while-draining always carry
                    // `at` >= the last popped time (positive latency).
                    bucket
                        .events
                        .sort_unstable_by(|a, b| (b.at, b.seq).cmp(&(a.at, a.seq)));
                    bucket.sorted = true;
                }
                self.len -= 1;
                self.ring_count -= 1;
                return bucket.events.pop();
            }
            self.cur += 1;
            if self.cur & self.mask == 0 {
                // Entered a new aligned window: its far shard (if any) can
                // now land in the ring before the cursor reaches it.
                self.rehome(self.cur >> self.ring_bits);
            }
        }
    }
}

/// Free-listed payload storage for in-flight events (u32 endpoints keep
/// the entry compact; node counts are <= 2^32 by construction).
struct EventSlab<M> {
    payloads: Vec<Option<(u32, u32, M)>>,
    free: Vec<u32>,
}

impl<M> EventSlab<M> {
    fn new() -> Self {
        EventSlab { payloads: Vec::new(), free: Vec::new() }
    }

    fn insert(&mut self, src: NodeId, dst: NodeId, msg: M) -> u32 {
        let entry = (src as u32, dst as u32, msg);
        if let Some(slot) = self.free.pop() {
            self.payloads[slot as usize] = Some(entry);
            slot
        } else {
            self.payloads.push(Some(entry));
            (self.payloads.len() - 1) as u32
        }
    }

    fn remove(&mut self, slot: u32) -> (NodeId, NodeId, M) {
        let (src, dst, msg) = self.payloads[slot as usize].take().expect("slot occupied");
        self.free.push(slot);
        (src as NodeId, dst as NodeId, msg)
    }
}

/// Per-node accounting (drives Figs 15b and 16).
#[derive(Debug, Clone)]
pub struct NodeStats {
    /// Busy time attributed to each stage.
    pub busy: [Time; MAX_STAGES],
    /// Idle (waiting-for-message) time attributed to each stage.
    pub idle: [Time; MAX_STAGES],
    /// Messages processed.
    pub msgs_in: u64,
    /// Messages sent.
    pub msgs_out: u64,
    /// Last time this node did any work.
    pub last_active: Time,
    /// Stage at which the node declared itself finished.
    pub finished: bool,
}

impl Default for NodeStats {
    fn default() -> Self {
        NodeStats {
            busy: [Time::ZERO; MAX_STAGES],
            idle: [Time::ZERO; MAX_STAGES],
            msgs_in: 0,
            msgs_out: 0,
            last_active: Time::ZERO,
            finished: false,
        }
    }
}

impl NodeStats {
    pub fn total_busy(&self) -> Time {
        Time(self.busy.iter().map(|t| t.0).sum())
    }
    pub fn total_idle(&self) -> Time {
        Time(self.idle.iter().map(|t| t.0).sum())
    }
}

/// Hot per-node scheduling state: everything the deliver/invoke path
/// mutates on every event, packed into a flat 16 B/node arena so the top
/// of the event loop touches one cache line per node instead of the full
/// program + stats struct (§Scale).
#[derive(Clone, Copy)]
struct HotNode {
    busy_until: Time,
    stage: u8,
    finished: bool,
}

/// Cold per-node state: the program itself, its RNG stream, and the
/// reorder buffer (touched only on delivery to *this* node).
struct NodeSlot<P: Program> {
    prog: P,
    rng: SplitMix64,
    /// Reorder buffer: (step, src, msg), kept in arrival order.
    held: Vec<(u32, NodeId, P::Msg)>,
}

/// Outcome of a completed run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    /// Latest busy-until across all nodes (the job completion time).
    pub makespan: Time,
    /// Per-node accounting.
    pub node_stats: Vec<NodeStats>,
    /// Fabric counters.
    pub net: NetStats,
    /// Total events processed (engine-level, for perf work).
    pub events: u64,
}

impl RunSummary {
    /// Mean busy fraction across nodes (busy / makespan).
    pub fn mean_utilization(&self) -> f64 {
        if self.makespan == Time::ZERO || self.node_stats.is_empty() {
            return 0.0;
        }
        let total: f64 = self.node_stats.iter().map(|s| s.total_busy().0 as f64).sum();
        total / (self.makespan.0 as f64 * self.node_stats.len() as f64)
    }
}

/// The engine: nodes + calendar queue + fabric + core model.
pub struct Engine<P: Program> {
    nodes: Vec<NodeSlot<P>>,
    /// Per-node compute slowdown factor (1 = nominal). Straggler cores
    /// (perturbation layer) get a larger factor, applied to every
    /// cycle-to-time conversion for that node.
    slow: Vec<u32>,
    /// Flat hot-state arena, indexed by node id (§Scale).
    hot: Vec<HotNode>,
    /// Flat stats arena, indexed by node id; handed to [`RunSummary`]
    /// without a copy at the end of the run.
    stats: Vec<NodeStats>,
    heap: CalendarQueue,
    slab: EventSlab<P::Msg>,
    fabric: Fabric,
    core: CoreModel,
    groups: Vec<Group>,
    seq: u64,
    events: u64,
    /// Scratch buffer for handler-emitted ops (reused across invokes —
    /// §Perf: one Vec alloc/free per delivered message otherwise).
    ops_scratch: Vec<(u64, SendOp<P::Msg>)>,
    /// Scratch for multicast delivery batches (reused across multicasts —
    /// §Scale: one Vec alloc per group send otherwise).
    mcast_scratch: Vec<(usize, Time)>,
}

impl<P: Program> Engine<P> {
    /// Build an engine over `programs` (node id = index).
    pub fn new(programs: Vec<P>, fabric: Fabric, core: CoreModel, seed: u64) -> Self {
        assert_eq!(programs.len(), fabric.topo.nodes, "program count != topology nodes");
        let n = programs.len();
        let root = SplitMix64::new(seed);
        let nodes = programs
            .into_iter()
            .enumerate()
            .map(|(i, prog)| NodeSlot { prog, rng: root.derive(i as u64), held: Vec::new() })
            .collect();
        Engine {
            nodes,
            slow: vec![1; n],
            hot: vec![HotNode { busy_until: Time::ZERO, stage: 0, finished: false }; n],
            stats: vec![NodeStats::default(); n],
            heap: CalendarQueue::new(),
            slab: EventSlab::new(),
            fabric,
            core,
            groups: Vec::new(),
            seq: 0,
            events: 0,
            ops_scratch: Vec::new(),
            mcast_scratch: Vec::new(),
        }
    }

    /// Register a multicast group (a member list or an id range);
    /// returns its id.
    pub fn add_group(&mut self, members: impl Into<Group>) -> GroupId {
        self.groups.push(members.into());
        self.groups.len() - 1
    }

    pub fn core(&self) -> &CoreModel {
        &self.core
    }

    /// Mark `node` as a straggler: all its compute (RX, handler cycles,
    /// TX issue offsets) runs `factor`× slower. Factor 1 is exactly the
    /// nominal path (the default for every node).
    pub fn slow_down(&mut self, node: NodeId, factor: u32) {
        self.slow[node] = factor.max(1);
    }

    /// Cycle-to-time conversion with the node's slowdown factor applied.
    fn node_cycles(&self, id: NodeId, cycles: u64) -> Time {
        Time::from_cycles(cycles * self.slow[id] as u64)
    }

    /// Run to quiescence; consumes the engine.
    pub fn run(mut self) -> RunSummary {
        // Start every node at t=0 (the cluster is pre-loaded and triggered
        // together, like the paper's benchmark start).
        for id in 0..self.nodes.len() {
            self.invoke(id, Time::ZERO, None);
            self.drain_reorder(id);
        }
        while let Some(ev) = self.heap.pop() {
            self.events += 1;
            let (src, dst, msg) = self.slab.remove(ev.slot);
            self.deliver(ev.at, src, dst, msg);
        }
        let makespan =
            self.stats.iter().map(|s| s.last_active).max().unwrap_or(Time::ZERO);
        RunSummary {
            makespan,
            net: self.fabric.stats().clone(),
            node_stats: self.stats,
            events: self.events,
        }
    }

    fn deliver(&mut self, at: Time, src: NodeId, dst: NodeId, msg: P::Msg) {
        let step = msg.step();
        if step > self.nodes[dst].prog.step() {
            // Future-step message: RX + store into the reorder buffer.
            let sf = self.slow[dst] as u64;
            let hot = &mut self.hot[dst];
            let st = &mut self.stats[dst];
            let start = at.max(hot.busy_until);
            let idle = start.saturating_sub(hot.busy_until);
            let stage = hot.stage as usize;
            st.idle[stage] += idle;
            let cost = Time::from_cycles(
                (self.core.rx_cycles(msg.wire_bytes()) + REORDER_STORE_CYCLES) * sf,
            );
            hot.busy_until = start + cost;
            st.busy[stage] += cost;
            st.last_active = hot.busy_until;
            st.msgs_in += 1;
            self.nodes[dst].held.push((step, src, msg));
            return;
        }
        self.invoke(dst, at, Some((src, msg, true)));
        self.drain_reorder(dst);
    }

    /// Re-deliver buffered messages whose step has become current.
    fn drain_reorder(&mut self, id: NodeId) {
        loop {
            let cur = self.nodes[id].prog.step();
            let pos = self.nodes[id].held.iter().position(|(s, _, _)| *s <= cur);
            let Some(pos) = pos else { break };
            let (_, src, msg) = self.nodes[id].held.remove(pos);
            let at = self.hot[id].busy_until;
            self.invoke_held(id, at, src, msg);
        }
    }

    fn invoke_held(&mut self, id: NodeId, at: Time, src: NodeId, msg: P::Msg) {
        // Pop cost instead of RX (already read off the NIC at arrival).
        let pop = self.node_cycles(id, REORDER_POP_CYCLES);
        let resume = {
            let hot = &mut self.hot[id];
            hot.busy_until = at.max(hot.busy_until) + pop;
            hot.busy_until
        };
        self.invoke(id, resume, Some((src, msg, false)));
    }

    /// Core of the model: run one handler and apply its effects.
    fn invoke(&mut self, id: NodeId, at: Time, input: Option<(NodeId, P::Msg, bool)>) {
        let sf = self.slow[id] as u64;
        let slot = &mut self.nodes[id];
        let hot = &mut self.hot[id];
        let st = &mut self.stats[id];
        let start = at.max(hot.busy_until);
        // Idle attribution: waiting between end of previous work and start.
        let idle = start.saturating_sub(hot.busy_until);
        if input.is_some() {
            st.idle[hot.stage as usize] += idle;
        }

        let mut entry = start;
        let charge_rx = matches!(&input, Some((_, _, true)));
        if let Some((_, msg, _)) = &input {
            if charge_rx {
                entry += Time::from_cycles(self.core.rx_cycles(msg.wire_bytes()) * sf);
            }
            st.msgs_in += 1;
        }

        let mut stage = hot.stage;
        let mut finished = hot.finished;
        debug_assert!(self.ops_scratch.is_empty());
        let mut ctx = Ctx {
            node: id,
            core: &self.core,
            rng: &mut slot.rng,
            entry,
            cycles: 0,
            ops: std::mem::take(&mut self.ops_scratch),
            stage: &mut stage,
            finished: &mut finished,
            mcast_supported: self.fabric.multicast_supported(),
        };
        let was_msg = input.is_some();
        match input {
            Some((src, msg, _)) => slot.prog.on_message(&mut ctx, src, msg),
            None => slot.prog.on_start(&mut ctx),
        }
        let cycles = ctx.cycles;
        let ops = std::mem::take(&mut ctx.ops);
        drop(ctx);

        let end = entry + Time::from_cycles(cycles * sf);
        let busy_span = end.saturating_sub(start);
        st.busy[hot.stage as usize] += busy_span;
        hot.stage = stage;
        hot.finished = finished;
        st.finished = finished;
        hot.busy_until = end;
        if busy_span > Time::ZERO || was_msg {
            st.last_active = end;
        }
        st.msgs_out += ops.len() as u64;

        // Hand sends to the fabric at the local time they were issued.
        let mut ops = ops;
        for (cyc_offset, op) in ops.drain(..) {
            let ready = entry + Time::from_cycles(cyc_offset * sf);
            match op {
                SendOp::Unicast { dst, msg } => {
                    let arr = self.fabric.unicast(id, dst, msg.wire_bytes(), ready);
                    self.push_event(arr, id, dst, msg);
                }
                SendOp::Multicast { group, msg } => {
                    // Batched injection: the fabric computes every member's
                    // delivery time into one reused scratch buffer (no Vec
                    // per group send), then events are pushed in bulk.
                    let mut deliveries = std::mem::take(&mut self.mcast_scratch);
                    debug_assert!(deliveries.is_empty());
                    self.fabric.multicast_into(
                        id,
                        self.groups[group].iter(),
                        msg.wire_bytes(),
                        ready,
                        &mut deliveries,
                    );
                    for &(dst, arr) in &deliveries {
                        if dst != id {
                            self.push_event(arr, id, dst, msg.clone());
                        }
                    }
                    deliveries.clear();
                    self.mcast_scratch = deliveries;
                }
            }
        }
        // Return the drained buffer to the scratch slot for reuse.
        self.ops_scratch = ops;
    }

    fn push_event(&mut self, at: Time, src: NodeId, dst: NodeId, msg: P::Msg) {
        self.seq += 1;
        let slot = self.slab.insert(src, dst, msg);
        self.heap.push(Event { at, seq: self.seq, slot });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{NetConfig, Topology};

    /// Ping-pong program: node 0 sends `hops` round trips to node 1.
    #[derive(Clone)]
    struct Ping {
        remaining: u32,
    }

    #[derive(Clone)]
    struct Msg;
    impl WireMsg for Msg {
        fn wire_bytes(&self) -> u64 {
            8
        }
    }

    impl Program for Ping {
        type Msg = Msg;
        fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
            if ctx.node() == 0 && self.remaining > 0 {
                ctx.send(1, Msg);
            }
        }
        fn on_message(&mut self, ctx: &mut Ctx<Msg>, src: NodeId, _msg: Msg) {
            if self.remaining > 0 {
                self.remaining -= 1;
                if self.remaining > 0 {
                    ctx.send(src, Msg);
                }
            }
        }
    }

    fn tiny_engine(progs: Vec<Ping>) -> Engine<Ping> {
        let topo = Topology::paper(progs.len());
        let fabric = Fabric::new(topo, NetConfig::default(), 1);
        Engine::new(progs, fabric, CoreModel::default(), 42)
    }

    #[test]
    fn ping_pong_terminates_with_sane_latency() {
        let e = tiny_engine(vec![Ping { remaining: 10 }, Ping { remaining: 10 }]);
        let summary = e.run();
        // Same-leaf one-way ≈ tx + 2*28 + 2*43 + 263 + ser + rx ≈ 420 ns;
        // 10 one-way legs ≈ 4.2 µs. Allow generous bounds.
        let us = summary.makespan.as_us_f64();
        assert!((2.0..10.0).contains(&us), "makespan = {us} µs");
        assert!(summary.events >= 10);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = tiny_engine(vec![Ping { remaining: 7 }, Ping { remaining: 7 }]).run();
        let b = tiny_engine(vec![Ping { remaining: 7 }, Ping { remaining: 7 }]).run();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.events, b.events);
        assert_eq!(a.net.msgs_sent, b.net.msgs_sent);
    }

    /// Fan-in program: N-1 nodes send to node 0; checks idle/busy tracking.
    #[derive(Clone)]
    struct FanIn {
        expect: u32,
        got: u32,
    }
    impl Program for FanIn {
        type Msg = Msg;
        fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
            if ctx.node() != 0 {
                ctx.send(0, Msg);
            }
        }
        fn on_message(&mut self, ctx: &mut Ctx<Msg>, _src: NodeId, _msg: Msg) {
            self.got += 1;
            ctx.compute(10);
            if self.got == self.expect {
                ctx.finish();
            }
        }
    }

    #[test]
    fn fan_in_counts_messages_and_busy_time() {
        let n = 32;
        let progs: Vec<FanIn> =
            (0..n).map(|_| FanIn { expect: (n - 1) as u32, got: 0 }).collect();
        let topo = Topology::paper(n);
        let fabric = Fabric::new(topo, NetConfig::default(), 3);
        let summary = Engine::new(progs, fabric, CoreModel::default(), 5).run();
        assert_eq!(summary.net.msgs_sent, (n - 1) as u64);
        assert_eq!(summary.net.msgs_delivered, (n - 1) as u64);
        let s0 = &summary.node_stats[0];
        assert_eq!(s0.msgs_in, (n - 1) as u64);
        assert!(s0.finished);
        assert!(s0.total_busy() > Time::ZERO);
        // RX-bound incast: 31 messages ≈ 31 * rx(8B) ≈ 31*18 cycles.
        let busy_ns = s0.total_busy().as_ns_f64();
        assert!(busy_ns > 100.0, "busy = {busy_ns}");
    }

    /// Group-broadcast program: node 0 multicasts to a range group; every
    /// member acks. Exercises `Group::Range` through the batched path.
    #[derive(Clone)]
    struct Bcast {
        acks: u32,
    }
    impl Program for Bcast {
        type Msg = Msg;
        fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
            if ctx.node() == 0 {
                ctx.multicast(0, Msg);
            }
        }
        fn on_message(&mut self, ctx: &mut Ctx<Msg>, src: NodeId, _msg: Msg) {
            if ctx.node() != 0 {
                ctx.send(src, Msg);
            } else {
                self.acks += 1;
            }
        }
    }

    #[test]
    fn range_groups_deliver_to_every_member_once() {
        let n = 16;
        let progs: Vec<Bcast> = (0..n).map(|_| Bcast { acks: 0 }).collect();
        let topo = Topology::paper(n);
        let fabric = Fabric::new(topo, NetConfig::default(), 3);
        let mut engine = Engine::new(progs, fabric, CoreModel::default(), 5);
        let gid = engine.add_group(0..n);
        assert_eq!(gid, 0);
        let summary = engine.run();
        // One multicast in, n-1 members deliver (self excluded), n-1 acks.
        assert_eq!(summary.net.multicasts, 1);
        assert_eq!(summary.node_stats[0].msgs_in, (n - 1) as u64);
        for id in 1..n {
            assert_eq!(summary.node_stats[id].msgs_in, 1, "node {id}");
        }
    }

    #[test]
    fn range_and_list_groups_are_equivalent() {
        let n = 16;
        let build = |members: Group| {
            let progs: Vec<Bcast> = (0..n).map(|_| Bcast { acks: 0 }).collect();
            let fabric = Fabric::new(Topology::paper(n), NetConfig::default(), 3);
            let mut engine = Engine::new(progs, fabric, CoreModel::default(), 5);
            engine.add_group(members);
            engine.run()
        };
        let a = build(Group::from(0..n));
        let b = build(Group::from((0..n).collect::<Vec<_>>()));
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.events, b.events);
        assert_eq!(a.net.msgs_delivered, b.net.msgs_delivered);
    }

    /// Reorder program: node 1 expects step-0 then step-1 messages, but
    /// node 0 sends the step-1 message *first*.
    #[derive(Clone)]
    struct StepMsg(u32);
    impl WireMsg for StepMsg {
        fn wire_bytes(&self) -> u64 {
            8
        }
        fn step(&self) -> u32 {
            self.0
        }
    }
    #[derive(Clone)]
    struct Reorderee {
        at_step: u32,
        log: Vec<u32>,
    }
    impl Program for Reorderee {
        type Msg = StepMsg;
        fn on_start(&mut self, ctx: &mut Ctx<StepMsg>) {
            if ctx.node() == 0 {
                // Send out of order: step 1 first, then step 0.
                ctx.send(1, StepMsg(1));
                ctx.send(1, StepMsg(0));
            }
        }
        fn on_message(&mut self, _ctx: &mut Ctx<StepMsg>, _src: NodeId, msg: StepMsg) {
            self.log.push(msg.0);
            if msg.0 == 0 {
                self.at_step = 1; // now willing to take step-1 messages
            }
        }
        fn step(&self) -> u32 {
            self.at_step
        }
    }

    #[test]
    fn reorder_buffer_delivers_in_step_order() {
        let progs = vec![
            Reorderee { at_step: 0, log: vec![] },
            Reorderee { at_step: 0, log: vec![] },
        ];
        let topo = Topology::paper(2);
        let fabric = Fabric::new(topo, NetConfig::default(), 9);
        // Engine::run consumes programs; to inspect the log we re-run the
        // scenario through a channel: check via stats instead — both
        // messages must be processed (msgs_in = 2, one of them buffered).
        let summary = Engine::new(progs, fabric, CoreModel::default(), 11).run();
        let s1 = &summary.node_stats[1];
        // step-1 msg arrives first (buffered, +1 msg_in), then step-0 is
        // processed, then the buffered one is re-delivered (+1 msg_in).
        assert_eq!(s1.msgs_in, 3, "arrival + buffered redelivery accounting");
    }

    #[test]
    fn straggler_slowdown_extends_makespan_and_factor_one_is_identity() {
        let run = |slow: Option<(NodeId, u32)>| {
            let mut e = tiny_engine(vec![Ping { remaining: 10 }, Ping { remaining: 10 }]);
            if let Some((node, factor)) = slow {
                e.slow_down(node, factor);
            }
            e.run()
        };
        let base = run(None);
        let identity = run(Some((1, 1)));
        assert_eq!(base.makespan, identity.makespan, "factor 1 must be exact");
        assert_eq!(base.events, identity.events);
        let slowed = run(Some((1, 8)));
        assert!(
            slowed.makespan > base.makespan,
            "slowed {} !> base {}",
            slowed.makespan.as_ns_f64(),
            base.makespan.as_ns_f64()
        );
        // Determinism under slowdown.
        let again = run(Some((1, 8)));
        assert_eq!(slowed.makespan, again.makespan);
    }

    #[test]
    fn quiescence_with_no_work() {
        let e = tiny_engine(vec![Ping { remaining: 0 }, Ping { remaining: 0 }]);
        let summary = e.run();
        assert_eq!(summary.makespan, Time::ZERO);
        assert_eq!(summary.events, 0);
    }

    /// The sharded far tier must order exactly like one global heap, for
    /// events scattered across many ring windows (far beyond the 262 µs
    /// lookahead) interleaved with near events.
    #[test]
    fn calendar_far_tier_orders_exactly() {
        let mut q = CalendarQueue::new();
        let window_units: u64 = 64 << 16; // one full ring span in time units
        let mut rng = SplitMix64::new(0xCA1);
        let mut expect: Vec<(u64, u64)> = Vec::new();
        let mut seq = 0u64;
        // Phase 1: events spread over ~40 windows, pushed in random order.
        for _ in 0..5_000 {
            let at = rng.next_below(40 * window_units);
            seq += 1;
            q.push(Event { at: Time(at), seq, slot: 0 });
            expect.push((at, seq));
        }
        expect.sort_unstable();
        let mut popped = Vec::new();
        // Interleave: drain half, then push more events *ahead of the
        // cursor* (as the fabric does — positive latency), drain the rest.
        for _ in 0..2_500 {
            let ev = q.pop().unwrap();
            popped.push((ev.at.0, ev.seq));
        }
        let now = popped.last().unwrap().0;
        let mut late: Vec<(u64, u64)> = Vec::new();
        for _ in 0..2_500 {
            let at = now + rng.next_below(45 * window_units);
            seq += 1;
            q.push(Event { at: Time(at), seq, slot: 0 });
            late.push((at, seq));
        }
        while let Some(ev) = q.pop() {
            popped.push((ev.at.0, ev.seq));
        }
        assert_eq!(popped.len(), 7_500);
        // Every pop must be totally ordered by (at, seq).
        assert!(popped.windows(2).all(|w| w[0] < w[1]), "pops out of order");
        // And the multiset must be exactly what was pushed.
        let mut all = expect;
        all.extend(late);
        all.sort_unstable();
        let mut got = popped;
        got.sort_unstable();
        assert_eq!(got, all);
    }
}
