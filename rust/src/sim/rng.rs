//! Deterministic RNG for the simulation (SplitMix64).
//!
//! Every run is a pure function of its seed: node programs draw from
//! per-node streams derived from the run seed, so results are reproducible
//! across machines and thread counts (the figure sweeps parallelize over
//! *runs*, never within one).

/// SplitMix64 — tiny, fast, high-quality 64-bit generator.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Derive a child stream (e.g. per node) that is independent of the
    /// parent's future output.
    pub fn derive(&self, stream: u64) -> SplitMix64 {
        let mut base = SplitMix64::new(self.state ^ 0x9e37_79b9_7f4a_7c15u64.rotate_left(17));
        let a = base.next_u64();
        SplitMix64::new(a ^ stream.wrapping_mul(0xbf58_476d_1ce4_e5b9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift; bound > 0).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize index in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// True with probability `num/den`.
    pub fn chance(&mut self, num: u64, den: u64) -> bool {
        self.next_below(den) < num
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n), sorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm: O(k) expected, no O(n) scratch.
        let mut chosen = std::collections::BTreeSet::new();
        for j in (n - k)..n {
            let t = self.index(j + 1);
            if !chosen.insert(t) {
                chosen.insert(j);
            }
        }
        chosen.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn derive_streams_differ() {
        let root = SplitMix64::new(7);
        let mut s0 = root.derive(0);
        let mut s1 = root.derive(1);
        let a: Vec<u64> = (0..8).map(|_| s0.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| s1.next_u64()).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn next_below_in_range_and_roughly_uniform() {
        let mut r = SplitMix64::new(1);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            let v = r.next_below(10);
            assert!(v < 10);
            counts[v as usize] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "skewed bucket: {c}");
        }
    }

    #[test]
    fn sample_indices_distinct_and_bounded() {
        let mut r = SplitMix64::new(3);
        for _ in 0..50 {
            let s = r.sample_indices(20, 7);
            assert_eq!(s.len(), 7);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn sample_indices_full_range() {
        let mut r = SplitMix64::new(4);
        let s = r.sample_indices(5, 5);
        assert_eq!(s, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn chance_probability() {
        let mut r = SplitMix64::new(11);
        let hits = (0..40_000).filter(|_| r.chance(3, 8)).count();
        let p = hits as f64 / 40_000.0;
        assert!((p - 0.375).abs() < 0.02, "p={p}");
    }
}
