//! Small statistics toolkit for reports: summaries, percentiles, and
//! fixed-width text histograms (Figs 13, 14, 16 report distributions).

/// Summary statistics over a sample of f64 values.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute from a sample (not required to be sorted).
    ///
    /// Degenerate inputs are well-defined instead of panicking: an empty
    /// sample yields `n = 0` with every statistic NaN (checkable via
    /// [`Summary::is_empty`]), and a single-element sample yields that
    /// element for every order statistic with `std = 0`.
    pub fn of(values: &[f64]) -> Summary {
        if values.is_empty() {
            return Summary {
                n: 0,
                mean: f64::NAN,
                std: f64::NAN,
                min: f64::NAN,
                p50: f64::NAN,
                p90: f64::NAN,
                p95: f64::NAN,
                p99: f64::NAN,
                max: f64::NAN,
            };
        }
        let mut v = values.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        let mean = v.iter().sum::<f64>() / n as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: v[0],
            p50: percentile_sorted(&v, 0.50),
            p90: percentile_sorted(&v, 0.90),
            p95: percentile_sorted(&v, 0.95),
            p99: percentile_sorted(&v, 0.99),
            max: v[n - 1],
        }
    }

    /// True when computed from an empty sample (all statistics NaN).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
}

/// Nearest-rank percentile of an ascending-sorted sample, q in [0, 1].
/// An empty sample has no percentiles: returns NaN instead of panicking.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Fixed-bin histogram for terminal output.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn of(values: &[f64], bins: usize) -> Histogram {
        assert!(bins > 0 && !values.is_empty());
        let lo = values.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut counts = vec![0u64; bins];
        let span = (hi - lo).max(f64::MIN_POSITIVE);
        for &v in values {
            let i = (((v - lo) / span) * bins as f64) as usize;
            counts[i.min(bins - 1)] += 1;
        }
        Histogram { lo, hi, counts }
    }

    /// Render as rows of `low..high | ###### count`.
    pub fn render(&self, width: usize) -> String {
        let max = *self.counts.iter().max().unwrap_or(&1) as f64;
        let bins = self.counts.len();
        let step = (self.hi - self.lo) / bins as f64;
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = "#".repeat(((c as f64 / max) * width as f64).round() as usize);
            out.push_str(&format!(
                "{:>10.2} .. {:>10.2} | {:<width$} {}\n",
                self.lo + step * i as f64,
                self.lo + step * (i + 1) as f64,
                bar,
                c,
                width = width
            ));
        }
        out
    }
}

/// Render one aligned text table row (figure reports share this).
pub fn table_row(cells: &[String], widths: &[usize]) -> String {
    cells
        .iter()
        .zip(widths)
        .map(|(c, w)| format!("{c:>w$}"))
        .collect::<Vec<_>>()
        .join("  ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn summary_std() {
        let s = Summary::of(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.std - 2.0).abs() < 1e-12); // classic example
    }

    #[test]
    fn percentiles_of_uniform() {
        let v: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 0.5), 50.0);
        assert_eq!(percentile_sorted(&v, 0.99), 99.0);
        assert_eq!(percentile_sorted(&v, 1.0), 100.0);
    }

    #[test]
    fn histogram_counts_everything() {
        let v = vec![0.0, 0.1, 0.5, 0.9, 1.0];
        let h = Histogram::of(&v, 2);
        assert_eq!(h.counts.iter().sum::<u64>(), 5);
        // 0.5 lands exactly on the second bin's lower edge.
        assert_eq!(h.counts, vec![2, 3]);
        let text = h.render(10);
        assert!(text.contains('#'));
    }

    #[test]
    fn summary_empty_is_nan_not_panic() {
        let s = Summary::of(&[]);
        assert!(s.is_empty());
        assert_eq!(s.n, 0);
        for v in [s.mean, s.std, s.min, s.p50, s.p90, s.p95, s.p99, s.max] {
            assert!(v.is_nan(), "empty-sample statistics are NaN, got {v}");
        }
    }

    #[test]
    fn summary_single_element() {
        let s = Summary::of(&[7.5]);
        assert_eq!(s.n, 1);
        assert!(!s.is_empty());
        assert_eq!(s.std, 0.0);
        for v in [s.mean, s.min, s.p50, s.p90, s.p95, s.p99, s.max] {
            assert_eq!(v, 7.5);
        }
    }

    #[test]
    fn percentile_of_empty_is_nan() {
        assert!(percentile_sorted(&[], 0.5).is_nan());
        assert!(percentile_sorted(&[], 0.0).is_nan());
    }

    #[test]
    fn percentile_of_single_element_is_that_element() {
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(percentile_sorted(&[3.25], q), 3.25);
        }
    }
}
