//! `repro` — the NanoSort reproduction CLI.
//!
//! ```text
//! repro fig <id|all> [--xla] [--seed N] [--runs N] [--quick] [--csv]
//! repro run nanosort   [--nodes N] [--kpn K] [--buckets B] [--incast F]
//!                      [--values] [--naive-pivots] [--no-multicast] [--xla] [--seed N]
//! repro run millisort  [--cores N] [--keys K] [--rf R] [--no-multicast] [--xla] [--seed N]
//! repro run mergemin   [--cores N] [--vpc V] [--incast K] [--no-multicast] [--xla] [--seed N]
//! repro run setalgebra [--cores N] [--lists Q] [--incast K] [--ids I]
//!                      [--no-multicast] [--xla] [--seed N]
//! repro artifacts      # list loaded XLA artifacts
//! repro list           # list figure ids and registered workloads
//! ```
//!
//! `repro run <name>` is registry-driven: the workload is looked up in
//! [`nanosort::scenario::registry`], its typed parameter descriptors are
//! parsed from the flags, and the run executes through one
//! [`nanosort::scenario::Scenario`] code path shared by all workloads —
//! adding a workload to the registry adds it here (and to the help text)
//! with no CLI changes.

use anyhow::{bail, Result};

use nanosort::benchfig::{run_figure, ALL_FIGURES};
use nanosort::coordinator::Args;
use nanosort::net::NetConfig;
use nanosort::runtime::XlaEngine;
use nanosort::scenario::{registry, Scenario};

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let mut args = Args::from_env();
    match args.positional().as_deref() {
        Some("fig") => cmd_fig(args),
        Some("run") => cmd_run(args),
        Some("artifacts") => cmd_artifacts(),
        Some("list") => {
            println!("figure ids: {}", ALL_FIGURES.join(", "));
            println!("workloads : {}", registry::names().join(", "));
            Ok(())
        }
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown command {cmd:?}\n");
            }
            println!("{}", help());
            Ok(())
        }
    }
}

fn help() -> String {
    format!(
        "repro — NanoSort reproduction CLI
  repro fig <id|all> [--xla] [--seed N] [--runs N] [--quick] [--csv]
{}  repro artifacts | repro list",
        registry::cli_help()
    )
}

fn cmd_fig(mut args: Args) -> Result<()> {
    let id = args.positional().unwrap_or_else(|| "all".into());
    let csv = args.flag("csv");
    let opts = args.run_options()?;
    ensure_consumed(&args)?;
    let ids: Vec<&str> = if id == "all" {
        ALL_FIGURES.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        let start = std::time::Instant::now();
        let tables = run_figure(id, &opts)?;
        for t in &tables {
            if csv {
                println!("# {}\n{}", t.title, t.to_csv());
            } else {
                println!("{}", t.render());
            }
        }
        eprintln!("[fig {id}: {:.2?}]", start.elapsed());
    }
    Ok(())
}

/// The single data-driven run path: registry lookup → parameter parse →
/// workload construction → scenario execution → unified report.
fn cmd_run(mut args: Args) -> Result<()> {
    let which = args.positional().unwrap_or_default();
    let spec = registry::find(&which)?;
    let params = registry::parse_args(spec, &mut args)?;
    let no_mcast = args.flag("no-multicast");
    let opts = args.run_options()?;
    ensure_consumed(&args)?;

    let workload = (spec.build)(&params)?;
    let nodes = params.u64(spec.nodes_param.name)? as usize;
    let net = NetConfig { multicast: !no_mcast, ..NetConfig::default() };
    let report = Scenario::from_dyn(workload)
        .nodes(nodes)
        .net(net)
        .compute(opts.compute)
        .seed(opts.seed)
        .run()?;
    print!("{}", report.render());
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let engine = XlaEngine::open_default()?;
    println!("platform: {}", engine.platform_name());
    for spec in &engine.manifest().artifacts {
        let ins: Vec<String> =
            spec.inputs.iter().map(|t| format!("{}{:?}", t.dtype, t.shape)).collect();
        let outs: Vec<String> =
            spec.outputs.iter().map(|t| format!("{}{:?}", t.dtype, t.shape)).collect();
        println!("  {:<32} {} -> {}", spec.name, ins.join(", "), outs.join(", "));
    }
    println!("{} artifacts", engine.manifest().artifacts.len());
    Ok(())
}

fn ensure_consumed(args: &Args) -> Result<()> {
    if !args.rest().is_empty() {
        bail!("unrecognized arguments: {:?}", args.rest());
    }
    Ok(())
}
