//! `repro` — the NanoSort reproduction CLI.
//!
//! ```text
//! repro fig <id|all> [--xla] [--seed N] [--runs N] [--quick] [--csv]
//! repro run nanosort  [--nodes N] [--kpn K] [--buckets B] [--incast F]
//!                     [--values] [--no-multicast] [--xla] [--seed N]
//! repro run millisort [--cores N] [--keys K] [--rf R] [--xla] [--seed N]
//! repro run mergemin  [--cores N] [--vpc V] [--incast K] [--xla] [--seed N]
//! repro artifacts     # list loaded XLA artifacts
//! repro list          # list figure ids
//! ```


use anyhow::{bail, Result};

use nanosort::algo::mergemin::{run_mergemin, MergeMinConfig};
use nanosort::algo::millisort::{run_millisort, MilliSortConfig};
use nanosort::algo::nanosort::{run_nanosort, NanoSortConfig, PivotMode};
use nanosort::algo::setalgebra::{run_setalgebra, SetAlgebraConfig};
use nanosort::benchfig::{run_figure, ALL_FIGURES};
use nanosort::coordinator::{f, Args};
use nanosort::runtime::XlaEngine;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let mut args = Args::from_env();
    match args.positional().as_deref() {
        Some("fig") => cmd_fig(args),
        Some("run") => cmd_run(args),
        Some("artifacts") => cmd_artifacts(),
        Some("list") => {
            println!("figure ids: {}", ALL_FIGURES.join(", "));
            Ok(())
        }
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown command {cmd:?}\n");
            }
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "repro — NanoSort reproduction CLI
  repro fig <id|all> [--xla] [--seed N] [--runs N] [--quick] [--csv]
  repro run nanosort  [--nodes N] [--kpn K] [--buckets B] [--incast F] [--values] [--no-multicast] [--xla]
  repro run millisort [--cores N] [--keys K] [--rf R] [--xla]
  repro run mergemin  [--cores N] [--vpc V] [--incast K] [--xla]
  repro artifacts | repro list";

fn cmd_fig(mut args: Args) -> Result<()> {
    let id = args.positional().unwrap_or_else(|| "all".into());
    let csv = args.flag("csv");
    let opts = args.run_options();
    ensure_consumed(&args)?;
    let ids: Vec<&str> = if id == "all" {
        ALL_FIGURES.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        let start = std::time::Instant::now();
        let tables = run_figure(id, &opts)?;
        for t in &tables {
            if csv {
                println!("# {}\n{}", t.title, t.to_csv());
            } else {
                println!("{}", t.render());
            }
        }
        eprintln!("[fig {id}: {:.2?}]", start.elapsed());
    }
    Ok(())
}

fn cmd_run(mut args: Args) -> Result<()> {
    let which = args.positional().unwrap_or_default();
    match which.as_str() {
        "nanosort" => {
            let nodes = args.num("nodes").unwrap_or(4096);
            let kpn = args.num("kpn").unwrap_or(16);
            let buckets = args.num("buckets").unwrap_or(16);
            let incast = args.num("incast").unwrap_or(buckets);
            let values = args.flag("values");
            let no_mcast = args.flag("no-multicast");
            let naive = args.flag("naive-pivots");
            let opts = args.run_options();
            ensure_consumed(&args)?;
            let mut cfg = NanoSortConfig {
                nodes,
                keys_per_node: kpn,
                buckets,
                median_incast: incast,
                shuffle_values: values,
                pivot_mode: if naive { PivotMode::Naive } else { PivotMode::Paper },
                seed: opts.seed,
                ..Default::default()
            };
            cfg.net.multicast = !no_mcast;
            let r = run_nanosort(&cfg, opts.compute.build()?);
            println!(
                "nanosort: nodes={nodes} keys={} buckets={buckets} incast={incast}",
                cfg.total_keys()
            );
            println!(
                "runtime = {:.2} µs | valid = {} | skew = {:.2} | msgs = {} | util = {:.1}%",
                r.runtime().as_us_f64(),
                r.validation.ok(),
                r.skew,
                r.summary.net.msgs_sent,
                100.0 * r.summary.mean_utilization()
            );
            for l in &r.levels {
                println!(
                    "  stage {}: busy mean {} µs max {} µs | idle mean {} µs max {} µs",
                    l.stage,
                    f(l.mean_busy_us),
                    f(l.max_busy_us),
                    f(l.mean_idle_us),
                    f(l.max_idle_us)
                );
            }
            Ok(())
        }
        "millisort" => {
            let cores = args.num("cores").unwrap_or(64);
            let keys = args.num("keys").unwrap_or(4096);
            let rf = args.num("rf").unwrap_or(4);
            let opts = args.run_options();
            ensure_consumed(&args)?;
            let cfg = MilliSortConfig {
                cores,
                total_keys: keys,
                reduction_factor: rf,
                seed: opts.seed,
                ..Default::default()
            };
            let r = run_millisort(&cfg, opts.compute.build()?);
            println!(
                "millisort: cores={cores} keys={keys} rf={rf}\nruntime = {:.2} µs | valid = {} | msgs = {}",
                r.runtime().as_us_f64(),
                r.validation.ok(),
                r.summary.net.msgs_sent
            );
            Ok(())
        }
        "mergemin" => {
            let cores = args.num("cores").unwrap_or(64);
            let vpc = args.num("vpc").unwrap_or(128);
            let incast = args.num("incast").unwrap_or(8);
            let opts = args.run_options();
            ensure_consumed(&args)?;
            let cfg = MergeMinConfig {
                cores,
                values_per_core: vpc,
                incast,
                seed: opts.seed,
                ..Default::default()
            };
            let r = run_mergemin(&cfg, opts.compute.build()?);
            println!(
                "mergemin: cores={cores} vpc={vpc} incast={incast}\nruntime = {:.0} ns | correct = {}",
                r.summary.makespan.as_ns_f64(),
                r.correct()
            );
            Ok(())
        }
        "setalgebra" => {
            let cores = args.num("cores").unwrap_or(64);
            let lists = args.num("lists").unwrap_or(4);
            let incast = args.num("incast").unwrap_or(8);
            let opts = args.run_options();
            ensure_consumed(&args)?;
            let cfg = SetAlgebraConfig {
                cores,
                lists,
                incast,
                seed: opts.seed,
                ..Default::default()
            };
            let r = run_setalgebra(&cfg, opts.compute.build()?);
            println!(
                "setalgebra: cores={cores} lists={lists} incast={incast}\nruntime = {:.0} ns | |intersection| = {} | correct = {}",
                r.summary.makespan.as_ns_f64(),
                r.found,
                r.correct()
            );
            Ok(())
        }
        other => bail!("unknown run target {other:?} (nanosort|millisort|mergemin|setalgebra)"),
    }
}

fn cmd_artifacts() -> Result<()> {
    let engine = XlaEngine::open_default()?;
    println!("platform: {}", engine.platform_name());
    for spec in &engine.manifest().artifacts {
        let ins: Vec<String> =
            spec.inputs.iter().map(|t| format!("{}{:?}", t.dtype, t.shape)).collect();
        let outs: Vec<String> =
            spec.outputs.iter().map(|t| format!("{}{:?}", t.dtype, t.shape)).collect();
        println!("  {:<32} {} -> {}", spec.name, ins.join(", "), outs.join(", "));
    }
    println!("{} artifacts", engine.manifest().artifacts.len());
    Ok(())
}

fn ensure_consumed(args: &Args) -> Result<()> {
    if !args.rest().is_empty() {
        bail!("unrecognized arguments: {:?}", args.rest());
    }
    Ok(())
}

