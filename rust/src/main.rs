//! `repro` — the NanoSort reproduction CLI.
//!
//! ```text
//! repro fig <id|all> [--compute P] [--seed N] [--runs N] [--quick] [--csv]
//! repro run <workload> [--<param> ...] [--skew D] [--loss N] [--oversub F]
//!                      [--stragglers N] [--no-multicast] [--compute P]
//!                      [--seed N] [--threads N] [--exec E]
//! repro run <workload> --help   # full parameter-descriptor listing
//! repro sweep <workload> [--tier smoke|mid|paper] --axis <param>=a,b,c
//!                      [--axis ...] [--compute P] [--seed N] [--threads N]
//!                      [--exec E]
//! repro serve [mix]    [--sched fifo|sjf|reserve|all] [--tier smoke|mid|paper]
//!                      [--jobs N] [--iat NS] [--bless] [--compute P] [--threads N]
//!                      [--exec E]
//! repro serve --help   # service parameter descriptors
//! repro paper          [--tier smoke|mid|paper|hyper-smoke|hyper] [--bless]
//!                      [--compute P] [--threads N] [--exec E] [--spill]
//! repro artifacts      # list loaded XLA artifacts
//! repro list           # list figure ids and registered workloads
//! ```
//!
//! `--compute native|radix|xla` selects the data plane everywhere
//! (default `radix`; `--xla` is shorthand for `--compute xla`). Digests
//! are plane-invariant — `repro paper --compute radix` re-runs the tier
//! on the native oracle and hard-fails on any divergence.
//!
//! `repro run <name>` is registry-driven: the workload is looked up in
//! [`nanosort::scenario::registry`], its typed parameter descriptors are
//! parsed from the flags, and the run executes through one
//! [`nanosort::scenario::Scenario`] code path shared by all workloads —
//! adding a workload to the registry adds it here (and to the help text)
//! with no CLI changes. Environment knobs ([`nanosort::perturb`]) apply
//! to every workload: input skew, packet loss + retransmit, core
//! oversubscription, stragglers.
//!
//! `repro sweep` runs the cartesian product of `--axis` values over the
//! workload's conformance-tier base configuration (conformance seed, so
//! every cell is deterministic), prints one JSON line per cell plus a
//! table comparing each cell against the unperturbed baseline.
//!
//! `repro paper` is the conformance entry point: it runs NanoSort at a
//! named scale tier (default: the paper's 65,536-core × 1M-key headline)
//! with the fixed conformance seed, compares the canonical digest against
//! the golden under `rust/conformance/golden/` (`--bless` accepts an
//! intentional change; a missing golden is created), and writes
//! `BENCH_nanosort.json` with the simulated makespan + wall-clock plus
//! the memory trajectory (`peak_rss_mb`/`bytes_spilled`/`alloc_count`).
//! The `hyper-smoke` (2^17 cores) and `hyper` (2^20 cores × 96 keys)
//! tiers force per-node streamed input generation; `--spill` routes the
//! final output blocks through disk-binned spill files (also enabled by
//! `NANOSORT_SPILL_DIR=<dir>`) — digests are byte-identical either way.
//!
//! `--threads N` (everywhere) picks the executor worker count: `1`
//! (default) is the sequential reference, `0` = all host cores, anything
//! else shards the simulated fleet across that many worker threads —
//! byte-identical results by the [`nanosort::sim::exec`] determinism
//! contract. `--exec seq|par|opt` picks *which* sharded backend those
//! workers drive (default `par`, the conservative adaptive-window
//! executor; `opt` adds speculation past the window bound with rollback
//! on mis-speculation — still byte-identical). `repro paper --threads N`
//! runs *both* backends, hard-fails on any digest divergence, and
//! records both wall-clocks plus the chosen backend (and, for `opt`, its
//! rollback counters) in the bench record. `repro sweep --threads N`
//! additionally fans independent grid cells out across the worker pool;
//! `repro sweep --exec E` runs every cell through backend `E` instead of
//! the sequential reference.

use std::sync::Arc;

use anyhow::{bail, Result};

use nanosort::benchfig::{run_figure, ALL_FIGURES};
use nanosort::compute::RadixCompute;
use nanosort::conformance::{self, BenchRecord, GoldenOutcome, Tier};
use nanosort::coordinator::{Args, ComputeChoice};
use nanosort::pool::WorkerPool;
use nanosort::net::NetConfig;
use nanosort::perturb::{self, sweep, Perturbations};
use nanosort::runtime::XlaEngine;
use nanosort::scenario::{registry, Scenario};
use nanosort::service::{self, Mix, SchedPolicy, ServiceConfig};
use nanosort::sim::ExecKind;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let mut args = Args::from_env();
    match args.positional().as_deref() {
        Some("fig") => cmd_fig(args),
        Some("run") => cmd_run(args),
        Some("sweep") => cmd_sweep(args),
        Some("serve") => cmd_serve(args),
        Some("paper") => cmd_paper(args),
        Some("artifacts") => cmd_artifacts(),
        Some("list") => {
            println!("figure ids: {}", ALL_FIGURES.join(", "));
            println!("workloads : {}", registry::names().join(", "));
            Ok(())
        }
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown command {cmd:?}\n");
            }
            println!("{}", help());
            Ok(())
        }
    }
}

fn help() -> String {
    format!(
        "repro — NanoSort reproduction CLI
  repro fig <id|all> [--compute P] [--seed N] [--runs N] [--quick] [--csv]
{}  repro sweep <workload> [--tier smoke|mid|paper] --axis <param>=a,b,c [--axis ...] [--compute P] [--seed N] [--threads N] [--exec E]
  repro serve [mix]  [--sched fifo|sjf|reserve|all] [--tier smoke|mid|paper] [--jobs N] [--iat NS] [--bless] [--compute P] [--threads N] [--exec E]
  repro serve --help # service parameter descriptors (mix, scheduler, arrival knobs)
  repro fig loadsweep # offered load × scheduler sweep of the job service
  repro fig memsweep # peak RSS + allocation count vs fleet size (the memory-diet figure)
  repro paper       [--tier smoke|mid|paper|hyper-smoke|hyper] [--bless] [--compute P] [--threads N] [--exec E] [--spill]
  repro artifacts | repro list
  (--compute P: data plane, native|radix|xla, default radix; digests are plane-invariant)
  (--threads N: executor worker threads; 1 = sequential, 0 = all cores; results are identical)
  (--exec E: sharded backend, seq|par|opt, default par; opt speculates past the window bound with rollback — results are identical)
  (--spill: spill output blocks to disk bins, GraySort style; NANOSORT_SPILL_DIR=<dir> picks the directory — results are identical)
  (hyper tiers: hyper-smoke = 2^17 cores, hyper = 2^20 cores × 96 keys ≈ 100.7M; streamed input forced on, BENCH records peak_rss_mb)",
        registry::cli_help()
    )
}

fn cmd_fig(mut args: Args) -> Result<()> {
    let id = args.positional().unwrap_or_else(|| "all".into());
    let csv = args.flag("csv");
    let opts = args.run_options()?;
    ensure_consumed(&args)?;
    let ids: Vec<&str> = if id == "all" {
        ALL_FIGURES.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        let start = std::time::Instant::now();
        let tables = run_figure(id, &opts)?;
        for t in &tables {
            if csv {
                println!("# {}\n{}", t.title, t.to_csv());
            } else {
                println!("{}", t.render());
            }
        }
        eprintln!("[fig {id}: {:.2?}]", start.elapsed());
    }
    Ok(())
}

/// The single data-driven run path: registry lookup → parameter parse →
/// workload construction → scenario execution → unified report.
/// `--help` after the workload name prints the typed parameter
/// descriptors instead of running.
fn cmd_run(mut args: Args) -> Result<()> {
    let which = args.positional().unwrap_or_default();
    let spec = registry::find(&which)?;
    if args.flag("help") {
        print!("{}", registry::describe(spec));
        return Ok(());
    }
    let params = registry::parse_args(spec, &mut args)?;
    let no_mcast = args.flag("no-multicast");
    // Environment knobs (perturbation layer): shared by every workload.
    let mut net = NetConfig { multicast: !no_mcast, ..NetConfig::default() };
    let mut knobs = Perturbations::default();
    for &(name, _) in perturb::ENV_AXES {
        if let Some(value) = args.value_checked(name)? {
            perturb::apply_env_setting(name, &value, &mut net, &mut knobs)?;
        }
    }
    let threads = args.num_checked("threads")?.unwrap_or(1);
    let exec = exec_choice(&mut args)?.unwrap_or_default();
    let opts = args.run_options()?;
    ensure_consumed(&args)?;

    let workload = (spec.build)(&params)?;
    let nodes = params.u64(spec.nodes_param.name)? as usize;
    let report = Scenario::from_dyn(workload)
        .nodes(nodes)
        .net(net)
        .perturb(knobs)
        .compute(opts.compute)
        .seed(opts.seed)
        .threads(threads)
        .exec(exec)
        .run()?;
    print!("{}", report.render());
    Ok(())
}

/// Deterministic perturbation sweep: cartesian product of `--axis`
/// values over the workload's conformance-tier base configuration.
/// Emits one JSON line per cell, then the baseline-comparison table.
fn cmd_sweep(mut args: Args) -> Result<()> {
    let which = args.positional().unwrap_or_default();
    let spec = registry::find(&which)?;
    let tier = match args.value_checked("tier")? {
        Some(t) => Tier::parse(&t)?,
        None => Tier::Smoke,
    };
    let mut axes = Vec::new();
    while let Some(raw) = args.value_checked("axis")? {
        axes.push(sweep::parse_axis(&raw)?);
    }
    anyhow::ensure!(
        !axes.is_empty(),
        "repro sweep needs at least one --axis <param>=a,b,c (try --axis skew=uniform,zipfian)"
    );
    let compute = args.compute_choice()?;
    let seed = args.num_checked("seed")?.unwrap_or(conformance::CONFORMANCE_SEED);
    let threads = args.num_checked("threads")?.unwrap_or(1);
    let exec = exec_choice(&mut args)?;
    ensure_consumed(&args)?;

    eprintln!(
        "[sweep: {} @ {} tier, seed {seed:#x}, {} ax{}, {} worker{}]",
        spec.name,
        tier.name(),
        axes.len(),
        if axes.len() == 1 { "is" } else { "es" },
        sweep::resolve_threads(threads),
        if sweep::resolve_threads(threads) == 1 { "" } else { "s" }
    );
    let start = std::time::Instant::now();
    // Cells stream to stdout as they complete (grid order): at big
    // grids the JSON trajectory is available to a consumer long before
    // the sweep finishes, and no per-cell record is buffered for
    // printing's sake.
    let outcome = sweep::run_sweep_with(spec, tier, &axes, compute, seed, threads, exec, &|_, cell| {
        println!("{}", cell.json_line(spec.name, tier.name(), seed));
    })?;
    println!("{}", outcome.table.render());
    eprintln!("[sweep: {} cells in {:.2?}]", outcome.cells.len(), start.elapsed());
    Ok(())
}

/// `repro serve --help` output, mirroring [`registry::describe`]'s
/// typed-descriptor format for the service's own parameters.
fn serve_describe() -> String {
    let mut out = String::from(
        "serve — multi-tenant sorting service: open Poisson job arrivals, pluggable \
         admission schedulers, one shared fabric\n\nservice parameters:\n",
    );
    out += "  [mix]                  workload mix: nanosort = every job a NanoSort \
            instance; mixed = zipf-popularity draw over all four registered workloads \
            (default nanosort)\n";
    out += "  --sched <S>            admission scheduler: fifo (strict arrival order) | \
            sjf (smallest job first) | reserve (whole-leaf partition reservation) | all \
            (default all)\n";
    out += "  --tier <T>             scale tier: smoke (256 workers, 24 jobs) | mid \
            (1024, 64) | paper (4096, 256) (default smoke)\n";
    out += "  --iat <NS>             mean Poisson interarrival gap in ns; offered load = \
            1/iat (default from tier; skips golden/bench when overridden)\n";
    out += "  --jobs <N>             trace length in jobs — the run's duration knob \
            (default from tier; skips golden/bench when overridden)\n";
    out += "  --bless                accept an intentional service-digest change\n";
    out += "\nenvironment knobs:\n";
    for (name, help) in perturb::ENV_AXES {
        // Skew shifts per-job inputs; loss/rto/tail/oversub shape the
        // shared fabric; stragglers slow fleet machines (never the
        // coordinator node).
        out += &format!("  {:<22} {help}\n", format!("--{name} <V>"));
    }
    out += "  --compute <P>          data plane: native|radix|xla (default radix; \
            digests are plane-invariant)\n";
    out += "  --threads <N>          executor worker threads (1 = sequential, 0 = all \
            cores; identical results — N != 1 cross-checks both backends)\n";
    out += "  --exec <E>             sharded backend for the cross-check: seq|par|opt \
            (default par; the service opts out of speculation, so opt runs its \
            conservative path — still byte-identical)\n";
    out
}

/// The service conformance entry point: run the tier's open job stream
/// under each requested scheduler with the fixed conformance seed,
/// cross-check the sequential and sharded executors when `--threads N`
/// is given, compare each `service_<mix>_<sched>_<tier>` digest against
/// its golden, and write the per-scheduler `BENCH_service*.json` record.
fn cmd_serve(mut args: Args) -> Result<()> {
    if args.flag("help") {
        print!("{}", serve_describe());
        return Ok(());
    }
    let mix = match args.positional() {
        Some(m) => Mix::parse(&m)?,
        None => Mix::Nanosort,
    };
    let sched_arg = args.value_checked("sched")?.unwrap_or_else(|| "all".into());
    let tier = match args.value_checked("tier")? {
        Some(t) => Tier::parse(&t)?,
        None => Tier::Smoke,
    };
    let iat_override: Option<u64> = args.num_checked("iat")?;
    let jobs_override: Option<usize> = args.num_checked("jobs")?;
    let bless = args.flag("bless");
    let compute = args.compute_choice()?;
    let threads: usize = args.num_checked("threads")?.unwrap_or(1);
    let exec = exec_choice(&mut args)?.unwrap_or_default();
    // Environment knobs shape the shared fabric and every job's inputs.
    let mut net = NetConfig { multicast: false, ..NetConfig::default() };
    let mut knobs = Perturbations::default();
    let mut env_custom = false;
    for &(name, _) in perturb::ENV_AXES {
        if let Some(value) = args.value_checked(name)? {
            perturb::apply_env_setting(name, &value, &mut net, &mut knobs)?;
            env_custom = true;
        }
    }
    ensure_consumed(&args)?;
    anyhow::ensure!(
        !(compute == ComputeChoice::Xla && threads != 1),
        "--compute xla requires --threads 1 (the XLA data plane is single-threaded)"
    );

    let policies: Vec<SchedPolicy> = if sched_arg == "all" {
        SchedPolicy::ALL.to_vec()
    } else {
        vec![SchedPolicy::parse(&sched_arg)?]
    };
    let (workers, mut arrivals) = service::service_tier(tier, mix);
    if let Some(iat) = iat_override {
        arrivals.mean_iat_ns = iat;
    }
    if let Some(jobs) = jobs_override {
        arrivals.jobs = jobs;
    }
    // Overridden arrival or environment knobs are exploration, not
    // conformance: goldens and bench records pin the tier's canonical
    // configuration only.
    let custom = iat_override.is_some() || jobs_override.is_some() || env_custom;
    let plane = compute.build()?;
    let mut bench_parts = Vec::new();
    for policy in policies {
        eprintln!(
            "[service: {} mix @ {} tier, sched {}, {} workers, {} jobs, seed {:#x}]",
            mix.name(),
            tier.name(),
            policy.name(),
            workers,
            arrivals.jobs,
            conformance::CONFORMANCE_SEED
        );
        let mut cfg = ServiceConfig::new(workers, arrivals.clone(), policy)?;
        cfg.net = net.clone();
        cfg.perturb = knobs.clone();
        cfg.compute = plane.clone();
        let start = std::time::Instant::now();
        let report = service::run_service(&cfg, conformance::CONFORMANCE_SEED)?;
        let wall = start.elapsed().as_secs_f64();
        print!("{}", report.render());
        println!("wall-clock: {wall:.2} s");
        let digest = service::service_digest(&report, tier.name());
        if threads != 1 {
            let resolved = nanosort::sim::exec::resolve_threads(threads);
            let mut pcfg = ServiceConfig::new(workers, arrivals.clone(), policy)?;
            pcfg.net = net.clone();
            pcfg.perturb = knobs.clone();
            pcfg.compute = plane.clone();
            pcfg.threads = resolved;
            pcfg.exec = exec;
            let pstart = std::time::Instant::now();
            let par = service::run_service(&pcfg, conformance::CONFORMANCE_SEED)?;
            let pwall = pstart.elapsed().as_secs_f64();
            let par_digest = service::service_digest(&par, tier.name());
            anyhow::ensure!(
                digest == par_digest,
                "executor divergence: {}({resolved} threads) service digest \
                 differs from SeqExecutor:\n{}",
                exec.name(),
                nanosort::conformance::golden::line_diff(&digest, &par_digest)
            );
            println!(
                "executor: seq {wall:.2} s vs {}[{resolved}] {pwall:.2} s ({:.2}x) | \
                 digests identical",
                exec.name(),
                wall / pwall.max(1e-9)
            );
        }
        if custom {
            continue;
        }
        bench_parts
            .push(service::service_bench_json(&report, tier.name(), wall, 1).trim_end().to_string());
        let name = format!("service_{}_{}_{}", mix.name(), policy.name(), tier.name());
        match conformance::check_golden(&name, &digest, bless)? {
            GoldenOutcome::Matched => println!("golden: {name}.json matches"),
            GoldenOutcome::Blessed { path, created } => println!(
                "golden: {} {} — commit it to pin this result",
                if created { "created" } else { "re-blessed" },
                path.display()
            ),
            GoldenOutcome::Mismatch { path, diff } => bail!(
                "seeded-result drift vs {}:\n{}\nre-run with --bless to accept an \
                 intentional change",
                path.display(),
                diff
            ),
        }
    }
    if !bench_parts.is_empty() {
        let path = conformance::bench_path("service", tier.name());
        std::fs::write(&path, format!("[\n{}\n]\n", bench_parts.join(",\n")))?;
        println!("bench record: {}", path.display());
    }
    Ok(())
}

/// Conformance run at a named scale tier: fixed seed, golden comparison,
/// `BENCH_nanosort.json` emission, and the paper-headline side-by-side.
///
/// Differential gates, each hard-failing on digest divergence:
/// - `--compute radix` (the default) re-runs the tier on the
///   `NativeCompute` oracle plane and cross-checks the digests — the §8
///   data-plane contract — recording the oracle wall-clock as the
///   radix-kernel before/after (`wall_clock_native_s`/`compute_speedup`).
/// - `--threads N` (N != 1) runs **both** executor backends — the
///   sequential reference first, then the sharded executor chosen by
///   `--exec` (default `par`; `opt` adds the speculative rollback
///   backend and records its rollback counters) — and records both
///   wall-clocks (the executor-speedup half of the trajectory).
fn cmd_paper(mut args: Args) -> Result<()> {
    let tier = match args.value_checked("tier")? {
        Some(t) => Tier::parse(&t)?,
        None => Tier::Paper,
    };
    let bless = args.flag("bless");
    let compute = args.compute_choice()?;
    let threads: usize = args.num_checked("threads")?.unwrap_or(1);
    let exec = exec_choice(&mut args)?.unwrap_or_default();
    let spill = args.flag("spill");
    ensure_consumed(&args)?;
    if spill && std::env::var_os("NANOSORT_SPILL_DIR").is_none() {
        // `--spill` without an explicit NANOSORT_SPILL_DIR gets a
        // per-process scratch directory. The scenario layer reads the
        // variable on every run, so setting it here covers the primary
        // leg and both comparison legs — all digest-invisible.
        let dir = std::env::temp_dir().join(format!("nanosort_spill_{}", std::process::id()));
        std::env::set_var("NANOSORT_SPILL_DIR", &dir);
        eprintln!("[spill: binned output sinks under {}]", dir.display());
    }
    // Fail fast, before the (potentially minutes-long) sequential tier
    // run: the XLA plane drives a single-threaded PJRT client, so the
    // parallel pass would be rejected by the scenario layer anyway.
    anyhow::ensure!(
        !(compute == ComputeChoice::Xla && threads != 1),
        "--compute xla requires --threads 1 (the XLA data plane is single-threaded; \
         native/radix --threads N and xla --threads 1 still cross-check, since the \
         executor backends are byte-identical)"
    );

    let spec = registry::find("nanosort")?;
    eprintln!(
        "[conformance: nanosort @ {} tier, seed {:#x}, {} data plane]",
        tier.name(),
        conformance::CONFORMANCE_SEED,
        compute.name()
    );
    // The radix plane is built explicitly (rather than through the
    // `ComputeChoice` path) so the primary run's tuner mode and kernel
    // histogram can land in the bench record afterwards. The sequential
    // primary leg gets a budget-1 pool: parallel kernels stay inline,
    // and `NANOSORT_TUNER` still selects the sequential families.
    let radix_plane = if compute == ComputeChoice::Radix {
        let pool = Arc::new(WorkerPool::new(1));
        Some((Arc::new(RadixCompute::with_pool(pool.clone())), pool))
    } else {
        None
    };
    let alloc_before = nanosort::mem::alloc_count();
    let (report, wall) = match &radix_plane {
        Some((plane, pool)) => conformance::run_tier_with(
            spec,
            tier,
            plane.clone(),
            pool.clone(),
            1,
            ExecKind::default(),
        )?,
        None => conformance::run_tier(spec, tier, compute, 1)?,
    };
    // Memory trajectory of the primary leg: drain the spill byte
    // counter before the comparison legs run (they spill too when the
    // knob is on, but BENCH records the primary measurement).
    let alloc_delta = nanosort::mem::alloc_count().saturating_sub(alloc_before);
    let bytes_spilled = nanosort::graysort::take_bytes_spilled();
    print!("{}", report.render());
    let us = report.runtime().as_us_f64();
    println!(
        "paper-scale: simulated {:.2} µs vs paper {:.0} µs ({:.2}x) | {} nodes | wall-clock {:.2} s",
        us,
        conformance::PAPER_RUNTIME_US,
        us / conformance::PAPER_RUNTIME_US,
        report.nodes,
        wall
    );
    println!(
        "phases: input_gen {:.2} s | sim {:.2} s | validate {:.2} s",
        report.phases.input_gen_s, report.phases.sim_s, report.phases.validate_s
    );
    anyhow::ensure!(
        report.validation.ok(),
        "validation failed: {}",
        report.validation.detail
    );
    let digest = conformance::digest_json(&report, tier.name());

    let peak_rss = nanosort::mem::peak_rss_mb();
    if let Some(mb) = peak_rss {
        println!(
            "memory: peak RSS {mb} MiB | {bytes_spilled} bytes spilled | {alloc_delta} allocs"
        );
    }
    let mut record = BenchRecord::from_report(&report, tier, wall)
        .with_mem(peak_rss, bytes_spilled, alloc_delta);
    if let Some((plane, _)) = &radix_plane {
        // Telemetry from the primary run: which kernel families the
        // tuner actually dispatched (digest-invisible, BENCH-only).
        record = record.with_tuner(plane.tuner_mode(), plane.kernel_histogram());
    }
    if compute == ComputeChoice::Radix {
        // Differential oracle pass: same tier on NativeCompute; the §8
        // contract says the digest must be byte-identical, and the pair
        // of wall-clocks is the kernel win the BENCH trajectory tracks.
        let (native_report, native_wall) =
            conformance::run_tier(spec, tier, ComputeChoice::Native, 1)?;
        let native_digest = conformance::digest_json(&native_report, tier.name());
        anyhow::ensure!(
            digest == native_digest,
            "data-plane divergence: radix digest differs from the native oracle:\n{}",
            nanosort::conformance::golden::line_diff(&native_digest, &digest)
        );
        println!(
            "compute: native {native_wall:.2} s vs radix {wall:.2} s ({:.2}x) | digests identical",
            native_wall / wall.max(1e-9)
        );
        record = record.with_native_baseline(native_wall);
    }
    if threads != 1 {
        let resolved = nanosort::sim::exec::resolve_threads(threads);
        let (par_report, par_wall) =
            conformance::run_tier_exec(spec, tier, compute, resolved, exec)?;
        let par_digest = conformance::digest_json(&par_report, tier.name());
        anyhow::ensure!(
            digest == par_digest,
            "executor divergence: {}({resolved} threads) digest differs from \
             SeqExecutor:\n{}",
            exec.name(),
            nanosort::conformance::golden::line_diff(&digest, &par_digest)
        );
        println!(
            "executor: seq {wall:.2} s vs {}[{resolved}] {par_wall:.2} s ({:.2}x speedup) | digests identical",
            exec.name(),
            wall / par_wall.max(1e-9)
        );
        if exec == ExecKind::Opt {
            let p = &par_report.summary.profile;
            println!(
                "speculation: {} bursts, {} committed, {} rollbacks",
                p.speculated, p.committed, p.rollbacks
            );
        }
        record = record
            .with_parallel(resolved, par_wall)
            .with_exec(exec, &par_report.summary.profile);
    }
    let bench = conformance::write_bench(&record)?;
    println!("bench record: {}", bench.display());

    // Same name the test gate uses for (workload, tier). Native and
    // radix share one golden — their digests are identical by the §8
    // contract, so the shared file *is* the cross-plane drift gate. XLA
    // runs get their own goldens: the planes agree on results but a
    // bless must never overwrite the native/radix-pinned file with
    // another plane's.
    let xla = compute == ComputeChoice::Xla;
    let name = format!("nanosort_{}{}", tier.name(), if xla { "_xla" } else { "" });
    match conformance::check_golden(&name, &digest, bless)? {
        GoldenOutcome::Matched => {
            println!("golden: {name}.json matches");
            Ok(())
        }
        GoldenOutcome::Blessed { path, created } => {
            println!(
                "golden: {} {} — commit it to pin this result",
                if created { "created" } else { "re-blessed" },
                path.display()
            );
            Ok(())
        }
        GoldenOutcome::Mismatch { path, diff } => {
            bail!(
                "seeded-result drift vs {}:\n{}\nre-run with --bless to accept an \
                 intentional change",
                path.display(),
                diff
            )
        }
    }
}

fn cmd_artifacts() -> Result<()> {
    let engine = XlaEngine::open_default()?;
    println!("platform: {}", engine.platform_name());
    for spec in &engine.manifest().artifacts {
        let ins: Vec<String> =
            spec.inputs.iter().map(|t| format!("{}{:?}", t.dtype, t.shape)).collect();
        let outs: Vec<String> =
            spec.outputs.iter().map(|t| format!("{}{:?}", t.dtype, t.shape)).collect();
        println!("  {:<32} {} -> {}", spec.name, ins.join(", "), outs.join(", "));
    }
    println!("{} artifacts", engine.manifest().artifacts.len());
    Ok(())
}

fn ensure_consumed(args: &Args) -> Result<()> {
    if !args.rest().is_empty() {
        bail!("unrecognized arguments: {:?}", args.rest());
    }
    Ok(())
}

/// Parse the shared `--exec seq|par|opt` backend flag. `None` = not
/// given (callers default to [`ExecKind::default`], the conservative
/// sharded backend; the sweep keeps its sequential cells instead).
fn exec_choice(args: &mut Args) -> Result<Option<ExecKind>> {
    match args.value_checked("exec")? {
        Some(raw) => match ExecKind::parse(&raw) {
            Some(kind) => Ok(Some(kind)),
            None => bail!("unknown executor {raw:?} (known: seq|par|opt)"),
        },
        None => Ok(None),
    }
}
