//! Host memory accounting for the BENCH records (§Scale).
//!
//! The hyper tiers exist to prove the simulator's footprint scales
//! sublinearly in keys and tightly in nodes — which is only checkable if
//! the memory trajectory is recorded next to wall-clock. Two figures:
//!
//! - **peak RSS** ([`peak_rss_mb`]): the kernel's high-water mark for
//!   resident set size (`VmHWM` in `/proc/self/status`). Monotone over
//!   the process lifetime, which is exactly what a ceiling check wants
//!   (CI fails the job if a hyper-smoke run's peak exceeds the budget in
//!   the golden's BENCH sidecar) — but it also means a figure sweeping
//!   node counts must run ascending sizes to attribute the peak
//!   per-cell (`repro fig memsweep` does).
//! - **allocation count** ([`alloc_count`]): total heap allocations via
//!   the counting global allocator, a churn proxy that catches
//!   per-node/per-round reallocation regressions RSS alone hides (a
//!   free/alloc ping-pong has flat RSS and a huge count).
//!
//! Both are pure host-side measurements: never digest material, never in
//! a `RunReport`, surfaced only through `BENCH_*.json`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Process peak resident set size in MiB (`VmHWM`), or `None` where
/// `/proc/self/status` is unavailable (non-Linux hosts) — the BENCH
/// field is optional for exactly that case.
pub fn peak_rss_mb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb / 1024);
        }
    }
    None
}

static ALLOCS: AtomicU64 = AtomicU64::new(0);

std::thread_local! {
    /// Per-thread allocation counter (see [`thread_alloc_count`]). A
    /// const-initialized `Cell<u64>` has no destructor, so touching it
    /// from inside the allocator cannot recurse through TLS
    /// registration, and `try_with` makes the increment a no-op during
    /// thread teardown instead of a panic.
    static THREAD_ALLOCS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Total heap allocations since process start (relaxed counter; exact
/// enough for a churn trajectory, free of synchronization cost).
pub fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Heap allocations made by the *calling thread* since it started.
/// Zero-alloc pins diff this across a code region: unlike the global
/// [`alloc_count`], it cannot be perturbed by concurrently running
/// threads (the test harness runs tests in parallel), so
/// `assert_eq!(delta, 0)` is race-free.
pub fn thread_alloc_count() -> u64 {
    THREAD_ALLOCS.try_with(std::cell::Cell::get).unwrap_or(0)
}

/// System allocator wrapped with one relaxed counter increment per
/// allocation. Installed as the crate's `#[global_allocator]`
/// (`src/lib.rs`); the per-alloc cost is a single uncontended atomic
/// add, noise next to the allocation itself.
pub struct CountingAlloc;

// SAFETY: pure delegation to `System`; the counter has no effect on the
// returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_count_is_monotone_and_advances() {
        let before = alloc_count();
        let v: Vec<u64> = Vec::with_capacity(1024);
        drop(v);
        assert!(alloc_count() > before, "heap allocation not counted");
    }

    #[test]
    fn thread_alloc_count_advances_and_is_quiet_when_idle() {
        let before = thread_alloc_count();
        let v: Vec<u64> = Vec::with_capacity(64);
        drop(v);
        assert!(thread_alloc_count() > before, "own-thread allocation not counted");
        // An allocation-free region moves the thread counter by exactly
        // zero, regardless of what other test threads are doing.
        let quiet = thread_alloc_count();
        let x = std::hint::black_box(42u64) + std::hint::black_box(1);
        assert_eq!(x, 43);
        assert_eq!(thread_alloc_count(), quiet);
    }

    #[test]
    fn peak_rss_parses_on_linux() {
        // On Linux the file exists and the process surely exceeds 1 MiB;
        // elsewhere None is the contract.
        if std::path::Path::new("/proc/self/status").exists() {
            let mb = peak_rss_mb().expect("VmHWM present on Linux");
            assert!(mb >= 1, "implausible peak RSS {mb} MiB");
        } else {
            assert_eq!(peak_rss_mb(), None);
        }
    }
}
