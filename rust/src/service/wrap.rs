//! The multi-job wrapper protocol: one shared fleet runs many workload
//! instances concurrently, each namespaced onto a contiguous worker
//! range, under an in-simulation coordinator.
//!
//! Node `workers` (the extra last node of the fabric) runs the
//! [`Coordinator`]: it replays the arrival trace off a core-local timer
//! chain, queues jobs, admits them per [`SchedPolicy`] onto disjoint
//! ranges from [`RangeAlloc`], and collects per-worker `Done` reports.
//! Every other node runs a [`Worker`]: idle until a `Kick{job, slot}`
//! hands it slot `slot` of job `job`'s pre-built program, then a pure
//! relay — inner algorithm messages cross the fabric wrapped in an 8 B
//! [`ServiceMsg::Inner`] header, and [`adapt`] re-bases node ids so the
//! inner program never learns it is sharing a fabric.
//!
//! Determinism under the sharded executor (DESIGN.md §9): all
//! cross-node shared state in [`ServiceArena`] is written and read only
//! at points ordered by message chains (placement is written before the
//! admission Kicks depart; a worker reads a job's placement only on
//! messages that causally follow those Kicks), so every access pair is
//! separated by at least one conservative window barrier.

use std::sync::{Arc, Mutex};

use crate::algo::mergemin::{MergeMin, MinMsg};
use crate::algo::millisort::{MilliSort, MsMsg};
use crate::algo::nanosort::{NanoSort, NsMsg};
use crate::algo::setalgebra::{CountMsg, SetAlgebra};
use crate::nanopu::{Ctx, NodeId, Program, SendOp, WireMsg};
use crate::scenario::Workload;
use crate::sim::Time;

use super::sched::{RangeAlloc, SchedPolicy};
use super::JobRecord;

/// Service header bytes prepended to every wrapped inner message.
pub(crate) const CTRL_BYTES: u64 = 8;

/// Coordinator bookkeeping cycles per arrival processed off a tick.
const ARRIVAL_CYCLES: u64 = 16;
/// Coordinator base cost of one timer tick.
const TICK_CYCLES: u64 = 24;
/// Coordinator cost of one admission decision (queue scan + allocator).
const ADMIT_CYCLES: u64 = 64;
/// Coordinator cost of folding in one worker `Done`.
const DONE_CYCLES: u64 = 24;
/// Worker cost of installing a kicked job (arena fetch + reset).
const KICK_CYCLES: u64 = 24;
/// Worker cost of stashing a not-yet-current job's message (mirrors the
/// engine reorder buffer's store cost).
const STASH_CYCLES: u64 = 4;
/// Worker cost of popping a stashed message back out.
const UNSTASH_CYCLES: u64 = 6;

pub(crate) type NsProg = <NanoSort as Workload>::Prog;
pub(crate) type MsProg = <MilliSort as Workload>::Prog;
pub(crate) type MmProg = <MergeMin as Workload>::Prog;
pub(crate) type SaProg = <SetAlgebra as Workload>::Prog;

/// An algorithm message of any registered workload, as carried inside a
/// [`ServiceMsg::Inner`] envelope.
#[derive(Clone)]
pub(crate) enum InnerMsg {
    Ns(NsMsg),
    Ms(MsMsg),
    Min(MinMsg),
    Count(CountMsg),
}

impl InnerMsg {
    fn wire_bytes(&self) -> u64 {
        match self {
            InnerMsg::Ns(m) => m.wire_bytes(),
            InnerMsg::Ms(m) => m.wire_bytes(),
            InnerMsg::Min(m) => m.wire_bytes(),
            InnerMsg::Count(m) => m.wire_bytes(),
        }
    }

    fn step(&self) -> u32 {
        match self {
            InnerMsg::Ns(m) => m.step(),
            InnerMsg::Ms(m) => m.step(),
            InnerMsg::Min(m) => m.step(),
            InnerMsg::Count(m) => m.step(),
        }
    }
}

/// A node program of any registered workload, as installed on a worker.
#[derive(Clone)]
pub(crate) enum InnerProg {
    Ns(NsProg),
    Ms(MsProg),
    Min(MmProg),
    Count(SaProg),
}

impl InnerProg {
    fn step(&self) -> u32 {
        match self {
            InnerProg::Ns(p) => p.step(),
            InnerProg::Ms(p) => p.step(),
            InnerProg::Min(p) => p.step(),
            InnerProg::Count(p) => p.step(),
        }
    }

    fn start(
        &mut self,
        octx: &mut Ctx<'_, ServiceMsg>,
        job: u32,
        base: NodeId,
        stage: &mut u8,
        finished: &mut bool,
    ) {
        match self {
            InnerProg::Ns(p) => adapt(octx, job, base, stage, finished, InnerMsg::Ns, |c| {
                p.on_start(c)
            }),
            InnerProg::Ms(p) => adapt(octx, job, base, stage, finished, InnerMsg::Ms, |c| {
                p.on_start(c)
            }),
            InnerProg::Min(p) => adapt(octx, job, base, stage, finished, InnerMsg::Min, |c| {
                p.on_start(c)
            }),
            InnerProg::Count(p) => {
                adapt(octx, job, base, stage, finished, InnerMsg::Count, |c| p.on_start(c))
            }
        }
    }

    /// Deliver one inner message (`src` is already re-based to the job's
    /// namespace). The (program, message) kinds always match because both
    /// are keyed by the same job id.
    fn deliver(
        &mut self,
        octx: &mut Ctx<'_, ServiceMsg>,
        job: u32,
        base: NodeId,
        stage: &mut u8,
        finished: &mut bool,
        src: NodeId,
        msg: InnerMsg,
    ) {
        match (self, msg) {
            (InnerProg::Ns(p), InnerMsg::Ns(m)) => {
                adapt(octx, job, base, stage, finished, InnerMsg::Ns, |c| {
                    p.on_message(c, src, m)
                })
            }
            (InnerProg::Ms(p), InnerMsg::Ms(m)) => {
                adapt(octx, job, base, stage, finished, InnerMsg::Ms, |c| {
                    p.on_message(c, src, m)
                })
            }
            (InnerProg::Min(p), InnerMsg::Min(m)) => {
                adapt(octx, job, base, stage, finished, InnerMsg::Min, |c| {
                    p.on_message(c, src, m)
                })
            }
            (InnerProg::Count(p), InnerMsg::Count(m)) => {
                adapt(octx, job, base, stage, finished, InnerMsg::Count, |c| {
                    p.on_message(c, src, m)
                })
            }
            _ => unreachable!("inner message kind does not match the job's program"),
        }
    }
}

/// The service wire protocol. `Tick` is timer-only (it never crosses the
/// fabric); everything else is ordinary unicast traffic paying the full
/// fabric model.
#[derive(Clone)]
pub(crate) enum ServiceMsg {
    /// Coordinator → worker: install slot `slot` of job `job` and start.
    Kick { job: u32, slot: u32 },
    /// Worker → coordinator: this worker's share of `job` is complete.
    Done { job: u32 },
    /// Coordinator self-timer: the arrival clock.
    Tick,
    /// A namespaced algorithm message: [`CTRL_BYTES`] of header plus the
    /// inner payload.
    Inner { job: u32, msg: InnerMsg },
}

impl WireMsg for ServiceMsg {
    fn wire_bytes(&self) -> u64 {
        match self {
            ServiceMsg::Kick { .. } => 16,
            ServiceMsg::Done { .. } => 8,
            ServiceMsg::Tick => 8,
            ServiceMsg::Inner { msg, .. } => CTRL_BYTES + msg.wire_bytes(),
        }
    }

    /// The engine's reorder buffer gates wrapped messages exactly like
    /// the inner protocol, shifted up one step: step 0 stays reserved for
    /// control messages (Kick/Done/Tick), which are always deliverable.
    fn step(&self) -> u32 {
        match self {
            ServiceMsg::Inner { msg, .. } => msg.step() + 1,
            _ => 0,
        }
    }
}

/// Run the inner handler `f` inside a namespaced [`Ctx`] carved out of
/// the worker's real context, then translate its effects back out:
/// node ids shift by `base`, every outbound unicast/timer is wrapped in
/// [`ServiceMsg::Inner`], and each wrapped send's extra TX serialization
/// (the inner handler charged TX for the *inner* byte count) is folded
/// into the running cycle count so later sends shift with it.
fn adapt<M: WireMsg>(
    octx: &mut Ctx<'_, ServiceMsg>,
    job: u32,
    base: NodeId,
    stage: &mut u8,
    finished: &mut bool,
    wrap: impl Fn(M) -> InnerMsg,
    f: impl for<'b> FnOnce(&mut Ctx<'b, M>),
) {
    let mut ictx = Ctx {
        node: octx.node - base,
        core: octx.core,
        rng: &mut *octx.rng,
        entry: octx.entry,
        cycles: octx.cycles,
        ops: Vec::new(),
        stage,
        finished,
        // Per-job dynamic groups cannot be registered mid-run; the
        // service fabric always degrades broadcasts to unicast loops.
        mcast_supported: false,
    };
    f(&mut ictx);
    let Ctx { cycles, ops, .. } = ictx;
    let core = octx.core;
    let mut extra = 0u64;
    for (cyc, op) in ops {
        match op {
            SendOp::Unicast { dst, msg } => {
                let inner_bytes = msg.wire_bytes();
                let wrapped = ServiceMsg::Inner { job, msg: wrap(msg) };
                extra += core
                    .tx_cycles(wrapped.wire_bytes())
                    .saturating_sub(core.tx_cycles(inner_bytes));
                octx.ops
                    .push((cyc + extra, SendOp::Unicast { dst: dst + base, msg: wrapped }));
            }
            SendOp::Timer { delay, msg } => {
                let wrapped = ServiceMsg::Inner { job, msg: wrap(msg) };
                octx.ops.push((cyc + extra, SendOp::Timer { delay, msg: wrapped }));
            }
            SendOp::Multicast { .. } => {
                unreachable!("service jobs run with multicast disabled")
            }
        }
    }
    octx.cycles = cycles + extra;
}

/// One job's shared run-state: its pre-built per-slot programs (taken
/// exactly once, by the Kick) and its current placement.
pub(crate) struct JobState {
    /// Worker nodes this job occupies once placed.
    pub nodes: usize,
    /// Slot-indexed programs, built host-side before the run.
    pub programs: Vec<Mutex<Option<InnerProg>>>,
    /// Base of the job's current range; `None` before admission and
    /// again after completion (written by the coordinator only, at
    /// points ordered before/after every worker read — see module docs).
    pub placement: Mutex<Option<usize>>,
}

/// Cross-node shared state of one service run. Every access is ordered
/// by the simulation's own message causality (module docs), so the
/// mutexes are for `Sync` soundness, never for logical ordering.
pub(crate) struct ServiceArena {
    pub jobs: Vec<JobState>,
    /// Per-job outcome records, indexed by job id; the coordinator fills
    /// admission/completion fields in as the run progresses.
    pub records: Mutex<Vec<JobRecord>>,
}

/// A running job, from the coordinator's point of view.
#[derive(Clone)]
struct JobRun {
    base: usize,
    footprint: usize,
    /// Worker `Done`s still outstanding.
    remaining: usize,
}

/// The coordinator program (node id = worker count).
#[derive(Clone)]
pub(crate) struct Coordinator {
    arena: Arc<ServiceArena>,
    policy: SchedPolicy,
    /// `(arrival, job, nodes)` in arrival order.
    trace: Vec<(Time, u32, usize)>,
    /// Next trace index still to arrive.
    next: usize,
    /// Arrived-but-unadmitted jobs, `(job, nodes)` in arrival order.
    queue: Vec<(u32, usize)>,
    alloc: RangeAlloc,
    running: Vec<Option<JobRun>>,
    /// Admission sequence counter (total order of scheduler decisions).
    admits: u64,
    completed: usize,
}

impl Coordinator {
    pub fn new(
        arena: Arc<ServiceArena>,
        policy: SchedPolicy,
        trace: Vec<(Time, u32, usize)>,
        workers: usize,
    ) -> Self {
        let jobs = arena.jobs.len();
        Coordinator {
            arena,
            policy,
            trace,
            next: 0,
            queue: Vec::new(),
            alloc: RangeAlloc::new(workers),
            running: (0..jobs).map(|_| None).collect(),
            admits: 0,
            completed: 0,
        }
    }

    fn on_start(&mut self, ctx: &mut Ctx<'_, ServiceMsg>) {
        match self.trace.first() {
            // The arrival clock: a timer chain hitting each nominal
            // arrival (timers draw no RNG and never touch the fabric).
            Some(&(at, _, _)) => ctx.timer(at, ServiceMsg::Tick),
            None => ctx.finish(),
        }
    }

    fn tick(&mut self, ctx: &mut Ctx<'_, ServiceMsg>) {
        let now = ctx.now();
        let mut due = 0u64;
        while self.next < self.trace.len() && self.trace[self.next].0 <= now {
            let (_, job, nodes) = self.trace[self.next];
            self.queue.push((job, nodes));
            self.next += 1;
            due += 1;
        }
        if self.next < self.trace.len() {
            // Re-anchor on the absolute nominal arrival so handler
            // processing time never accumulates into the open stream.
            let delay = self.trace[self.next].0.saturating_sub(now);
            ctx.timer(delay, ServiceMsg::Tick);
        }
        ctx.compute(TICK_CYCLES + ARRIVAL_CYCLES * due);
        self.try_admit(ctx);
    }

    fn done(&mut self, ctx: &mut Ctx<'_, ServiceMsg>, job: u32) {
        ctx.compute(DONE_CYCLES);
        let j = job as usize;
        let run = self.running[j].as_mut().expect("Done for a job that is not running");
        run.remaining -= 1;
        if run.remaining > 0 {
            return;
        }
        let JobRun { base, footprint, .. } =
            self.running[j].take().expect("checked running above");
        {
            let mut recs = self.arena.records.lock().unwrap();
            recs[j].finish = ctx.now();
            recs[j].completed = true;
        }
        // Placement is cleared before the range becomes reusable, so a
        // worker that later sees this job's leftovers drops them as
        // stale instead of stashing them forever.
        *self.arena.jobs[j].placement.lock().unwrap() = None;
        self.alloc.release(base, footprint);
        self.completed += 1;
        if self.completed == self.trace.len() {
            ctx.finish();
        }
        self.try_admit(ctx);
    }

    fn try_admit(&mut self, ctx: &mut Ctx<'_, ServiceMsg>) {
        while let Some((qi, base)) = self.policy.pick(&self.queue, &self.alloc) {
            let (job, nodes) = self.queue.remove(qi);
            let footprint = self.policy.footprint(nodes);
            self.alloc.take(base, footprint);
            self.running[job as usize] =
                Some(JobRun { base, footprint, remaining: nodes });
            // Placement must be visible before any Kick departs: every
            // worker read of it causally follows one of these Kicks.
            *self.arena.jobs[job as usize].placement.lock().unwrap() = Some(base);
            {
                let mut recs = self.arena.records.lock().unwrap();
                let r = &mut recs[job as usize];
                r.admit_seq = self.admits;
                r.base = base;
                r.start = ctx.now();
            }
            self.admits += 1;
            ctx.compute(ADMIT_CYCLES);
            // Control-plane fan-out pays the real unicast egress chain.
            for slot in 0..nodes {
                ctx.send(base + slot, ServiceMsg::Kick { job, slot: slot as u32 });
            }
        }
    }
}

/// The job a worker is currently running.
#[derive(Clone)]
struct Active {
    job: u32,
    base: NodeId,
    inner: InnerProg,
    stage: u8,
    finished: bool,
    done_sent: bool,
    /// Job-local reorder buffer: messages of the active job stashed
    /// before its kick (relative src, message), drained in step order.
    held: Vec<(NodeId, InnerMsg)>,
}

/// A worker program: idle relay until kicked, then the active job's
/// inner program namespaced through [`adapt`].
#[derive(Clone)]
pub(crate) struct Worker {
    arena: Arc<ServiceArena>,
    coord: NodeId,
    active: Option<Active>,
    /// Messages for *other* (placed, not-yet-kicked-here) jobs,
    /// `(job, absolute src, msg)` in arrival order.
    pending: Vec<(u32, NodeId, InnerMsg)>,
}

impl Worker {
    pub fn new(arena: Arc<ServiceArena>, coord: NodeId) -> Self {
        Worker { arena, coord, active: None, pending: Vec::new() }
    }

    fn step(&self) -> u32 {
        // Mirrors [`ServiceMsg::step`]: active job's step shifted up one,
        // step 0 (control traffic) always acceptable.
        self.active.as_ref().map_or(0, |a| a.inner.step() + 1)
    }

    fn kick(&mut self, ctx: &mut Ctx<'_, ServiceMsg>, job: u32, slot: usize) {
        ctx.compute(KICK_CYCLES);
        let inner = self.arena.jobs[job as usize].programs[slot]
            .lock()
            .unwrap()
            .take()
            .expect("job slot kicked twice");
        let base = ctx.node() - slot;
        ctx.set_stage(0);
        *ctx.finished = false; // new job: this worker is busy again
        self.active = Some(Active {
            job,
            base,
            inner,
            stage: 0,
            finished: false,
            done_sent: false,
            held: Vec::new(),
        });
        // Early-arrived messages of this job move into its held buffer;
        // leftovers of completed jobs are pruned (their placement is
        // gone), anything else keeps waiting for its own kick.
        let (mine, mut rest): (Vec<_>, Vec<_>) =
            std::mem::take(&mut self.pending).into_iter().partition(|e| e.0 == job);
        let arena = &self.arena;
        rest.retain(|(j, _, _)| arena.jobs[*j as usize].placement.lock().unwrap().is_some());
        self.pending = rest;
        let a = self.active.as_mut().expect("just installed");
        for (_, src, m) in mine {
            a.held.push((src - a.base, m));
        }
        let Active { job, base, inner, stage, finished, .. } = a;
        inner.start(ctx, *job, *base, stage, finished);
        self.after_inner(ctx);
    }

    fn inner_msg(&mut self, ctx: &mut Ctx<'_, ServiceMsg>, job: u32, src: NodeId, msg: InnerMsg) {
        if matches!(&self.active, Some(a) if a.job == job) {
            let a = self.active.as_mut().expect("checked above");
            let rel = src - a.base;
            let Active { job, base, inner, stage, finished, .. } = a;
            // Engine reorder gating (one-step-shifted) guarantees
            // msg.step() <= inner.step() here.
            inner.deliver(ctx, *job, *base, stage, finished, rel, msg);
            self.after_inner(ctx);
            return;
        }
        // Not the active job: either an early message of a job whose
        // kick is still in flight (this node is inside its placement) —
        // stash it — or a stale leftover of a completed job — drop it.
        let st = &self.arena.jobs[job as usize];
        let covered = st
            .placement
            .lock()
            .unwrap()
            .is_some_and(|b| b <= ctx.node() && ctx.node() < b + st.nodes);
        if covered {
            ctx.compute(STASH_CYCLES);
            self.pending.push((job, src, msg));
        }
    }

    /// Post-handler bookkeeping: report the finishing transition to the
    /// coordinator, then drain held messages that have become current
    /// (each drained delivery can itself finish the job or advance the
    /// step, so loop until a fixpoint).
    fn after_inner(&mut self, ctx: &mut Ctx<'_, ServiceMsg>) {
        let coord = self.coord;
        loop {
            let Some(a) = self.active.as_mut() else { return };
            let stage = a.stage;
            ctx.set_stage(stage);
            if a.finished && !a.done_sent {
                a.done_sent = true;
                let job = a.job;
                ctx.finish();
                ctx.send(coord, ServiceMsg::Done { job });
            }
            let cur = a.inner.step();
            let Some(pos) = a.held.iter().position(|(_, m)| m.step() <= cur) else {
                return;
            };
            let (src, m) = a.held.remove(pos);
            ctx.compute(UNSTASH_CYCLES);
            let Active { job, base, inner, stage, finished, .. } = a;
            inner.deliver(ctx, *job, *base, stage, finished, src, m);
        }
    }
}

/// The one program type every node of a service run executes.
#[derive(Clone)]
pub(crate) enum ServiceProg {
    Worker(Worker),
    Coordinator(Coordinator),
}

impl Program for ServiceProg {
    type Msg = ServiceMsg;

    fn on_start(&mut self, ctx: &mut Ctx<'_, ServiceMsg>) {
        match self {
            ServiceProg::Worker(_) => {} // workers idle until kicked
            ServiceProg::Coordinator(c) => c.on_start(ctx),
        }
    }

    fn on_message(&mut self, ctx: &mut Ctx<'_, ServiceMsg>, src: NodeId, msg: ServiceMsg) {
        match (self, msg) {
            (ServiceProg::Worker(w), ServiceMsg::Kick { job, slot }) => {
                w.kick(ctx, job, slot as usize)
            }
            (ServiceProg::Worker(w), ServiceMsg::Inner { job, msg }) => {
                w.inner_msg(ctx, job, src, msg)
            }
            (ServiceProg::Coordinator(c), ServiceMsg::Tick) => c.tick(ctx),
            (ServiceProg::Coordinator(c), ServiceMsg::Done { job }) => c.done(ctx, job),
            _ => unreachable!("service message routed to the wrong node kind"),
        }
    }

    fn step(&self) -> u32 {
        match self {
            ServiceProg::Worker(w) => w.step(),
            // The coordinator only ever receives step-0 control traffic.
            ServiceProg::Coordinator(_) => 0,
        }
    }

    /// The service program's event-visible state straddles shared arenas
    /// a clone cannot checkpoint: [`Worker::kick`] destructively takes
    /// the job's slot program, and placements/records live behind
    /// `Arc`-shared mutexes (DESIGN.md §9). Rolling back a clone would
    /// leave the arena mutated, so the optimistic executor must run this
    /// program conservatively.
    fn speculation_safe(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wrapped_wire_bytes_add_the_header() {
        let inner = MinMsg { round: 2, value: 5 };
        let raw = inner.wire_bytes();
        let wrapped = ServiceMsg::Inner { job: 3, msg: InnerMsg::Min(inner) };
        assert_eq!(wrapped.wire_bytes(), CTRL_BYTES + raw);
        assert_eq!(ServiceMsg::Kick { job: 0, slot: 0 }.wire_bytes(), 16);
        assert_eq!(ServiceMsg::Done { job: 0 }.wire_bytes(), 8);
        assert_eq!(ServiceMsg::Tick.wire_bytes(), 8);
    }

    #[test]
    fn wrapped_step_shifts_up_and_control_stays_zero() {
        let inner = MinMsg { round: 4, value: 1 };
        let istep = inner.step();
        let wrapped = ServiceMsg::Inner { job: 0, msg: InnerMsg::Min(inner) };
        assert_eq!(wrapped.step(), istep + 1);
        assert_eq!(ServiceMsg::Kick { job: 9, slot: 1 }.step(), 0);
        assert_eq!(ServiceMsg::Done { job: 9 }.step(), 0);
        assert_eq!(ServiceMsg::Tick.step(), 0);
    }
}
