//! Sorting as a service: a multi-tenant job service over one shared
//! simulated fabric (DESIGN.md §9).
//!
//! The paper evaluates NanoSort one job at a time; real granular
//! datacenters run an *open stream* of them. This layer closes that gap
//! on top of the existing Scenario/Engine stack:
//!
//! - [`arrivals`] — deterministic open arrivals: Poisson interarrivals
//!   (von Neumann sampler, no `libm`), a zipf-popularity workload mix
//!   over the whole registry, and a configurable size-class split.
//! - [`sched`] — coordinator-level admission policies (`fifo` / `sjf` /
//!   `reserve`) over a first-fit contiguous range allocator.
//! - [`wrap`] — the in-simulation protocol: a coordinator node admits
//!   jobs onto disjoint worker ranges and worker nodes relay namespaced
//!   inner-algorithm messages, so concurrent jobs share the fabric (and
//!   its congestion) without sharing state.
//! - this module — the host-side runner ([`run_service`]), per-job
//!   output validation through each workload's own `finish` hook, the
//!   [`ServiceReport`] (offered vs achieved load, queueing delay, and
//!   p50/p95/p99 JCT per size class), the canonical service digest
//!   ([`service_digest`]) pinned by the `service` conformance tier, and
//!   the `loadsweep` benchfig.
//!
//! Determinism: the service digest is byte-identical across executor
//! backends, thread counts, and data planes — same contract as the
//! single-job goldens. Timers carry no RNG, per-job perturbation draws
//! come from per-job derived streams, and all cross-node shared state is
//! ordered by simulated message causality (see [`wrap`]'s module docs).

pub mod arrivals;
pub mod sched;
mod wrap;

pub use arrivals::{ArrivalConfig, JobKind, JobSpec, Mix, SizeClass};
pub use sched::{RangeAlloc, SchedPolicy, LEAF_RADIX};

use std::sync::{Arc, Mutex};

use anyhow::{ensure, Context, Result};

use crate::compute::LocalCompute;
use crate::conformance::Tier;
use crate::coordinator::{f, ComputeChoice, RunOptions, Table};
use crate::cpu::CoreModel;
use crate::net::{Fabric, NetConfig, NetStats, Topology};
use crate::perturb::Perturbations;
use crate::scenario::{Finish, ScenarioEnv, Workload};
use crate::sim::{Engine, ExecKind, RunSummary, Time};
use crate::stats::Summary;

use wrap::{Coordinator, InnerProg, JobState, ServiceArena, ServiceProg, Worker};

/// Everything one service run needs besides the seed.
pub struct ServiceConfig {
    /// Worker fleet size (the fabric gets one extra coordinator node).
    pub workers: usize,
    pub arrivals: ArrivalConfig,
    pub policy: SchedPolicy,
    /// Fabric configuration. Multicast is always forced off: per-job
    /// dynamic groups cannot be registered mid-run, so inner broadcasts
    /// degrade to unicast loops (see [`wrap`]).
    pub net: NetConfig,
    pub core: CoreModel,
    pub compute: Arc<dyn LocalCompute>,
    /// Fleet-level perturbations: the input distribution applies to
    /// every job's input generation; stragglers are *machine* properties
    /// picked once per fleet (never the coordinator node).
    pub perturb: Perturbations,
    pub threads: usize,
    /// Execution backend. The service program opts out of speculation
    /// ([`crate::nanopu::Program::speculation_safe`] — its state lives in
    /// shared arenas a clone cannot checkpoint), so `opt` here runs the
    /// conservative adaptive-window path; results are byte-identical
    /// across all backends either way.
    pub exec: ExecKind,
}

impl ServiceConfig {
    /// Default environment around the three load-bearing knobs.
    pub fn new(workers: usize, arrivals: ArrivalConfig, policy: SchedPolicy) -> Result<Self> {
        Ok(ServiceConfig {
            workers,
            arrivals,
            policy,
            net: NetConfig { multicast: false, ..NetConfig::default() },
            core: CoreModel::default(),
            compute: ComputeChoice::default().build()?,
            perturb: Perturbations::default(),
            threads: 1,
            exec: ExecKind::default(),
        })
    }
}

/// One job's lifecycle through the service, filled in by the
/// in-simulation coordinator. Sentinels before admission:
/// `admit_seq == u64::MAX`, `base == usize::MAX`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobRecord {
    pub job: u32,
    pub workload: &'static str,
    pub class: SizeClass,
    pub nodes: usize,
    /// Nominal arrival (from the trace).
    pub arrival: Time,
    /// Position in the coordinator's total admission order.
    pub admit_seq: u64,
    /// First worker node of the job's range.
    pub base: usize,
    /// Admission time (coordinator clock).
    pub start: Time,
    /// Last worker `Done` folded in (coordinator clock).
    pub finish: Time,
    pub completed: bool,
}

impl JobRecord {
    /// Queueing delay: arrival → admission.
    pub fn wait(&self) -> Time {
        self.start.saturating_sub(self.arrival)
    }

    /// Job completion time: arrival → finish (wait + service).
    pub fn jct(&self) -> Time {
        self.finish.saturating_sub(self.arrival)
    }
}

/// A job's record plus its output validation verdict.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub record: JobRecord,
    /// The workload's own validator, run over the job's carved-out slice
    /// of the fleet (always `true` — a failure aborts the run loudly).
    pub validated: bool,
}

/// Outcome of one service run.
pub struct ServiceReport {
    pub mix: Mix,
    pub policy: SchedPolicy,
    pub workers: usize,
    pub seed: u64,
    pub compute: &'static str,
    pub mean_iat_ns: u64,
    /// Per-job outcomes in job-id (= arrival) order.
    pub jobs: Vec<JobOutcome>,
    /// Fleet makespan: first arrival scheduled at t = 0, last event.
    pub makespan: Time,
    pub events: u64,
    /// Fleet-level fabric counters (shared by all jobs; per-job net
    /// attribution is not tracked — DESIGN.md §9).
    pub net: NetStats,
}

impl ServiceReport {
    /// Nominal offered load from the arrival process, jobs per ms.
    pub fn offered_jobs_per_ms(&self) -> f64 {
        1.0e6 / self.mean_iat_ns.max(1) as f64
    }

    /// Completed jobs per ms of fleet makespan.
    pub fn achieved_jobs_per_ms(&self) -> f64 {
        let ms = self.makespan.as_us_f64() / 1000.0;
        if ms <= 0.0 {
            0.0
        } else {
            self.jobs.len() as f64 / ms
        }
    }

    pub fn jct_summary(&self) -> Summary {
        Summary::of(&self.jct_us(None))
    }

    pub fn wait_summary(&self) -> Summary {
        let waits: Vec<f64> =
            self.jobs.iter().map(|j| j.record.wait().as_us_f64()).collect();
        Summary::of(&waits)
    }

    /// JCT summary restricted to one size class.
    pub fn class_jct_summary(&self, class: SizeClass) -> Summary {
        Summary::of(&self.jct_us(Some(class)))
    }

    fn jct_us(&self, class: Option<SizeClass>) -> Vec<f64> {
        self.jobs
            .iter()
            .filter(|j| class.is_none_or(|c| j.record.class == c))
            .map(|j| j.record.jct().as_us_f64())
            .collect()
    }

    /// Deterministic text rendering (the CLI's `repro serve` output).
    pub fn render(&self) -> String {
        let jct = self.jct_summary();
        let wait = self.wait_summary();
        let mut out = format!(
            "service: mix={} sched={} workers={} jobs={} seed={} compute={}\n",
            self.mix.name(),
            self.policy.name(),
            self.workers,
            self.jobs.len(),
            self.seed,
            self.compute
        );
        out += &format!(
            "makespan = {:.2} µs | events = {} | msgs = {} | retransmits = {}\n",
            self.makespan.as_us_f64(),
            self.events,
            self.net.msgs_sent,
            self.net.retransmits
        );
        out += &format!(
            "offered = {} jobs/ms | achieved = {} jobs/ms\n",
            f(self.offered_jobs_per_ms()),
            f(self.achieved_jobs_per_ms())
        );
        if !jct.is_empty() {
            out += &format!(
                "jct µs: p50 = {} | p95 = {} | p99 = {} | max = {}\n",
                f(jct.p50),
                f(jct.p95),
                f(jct.p99),
                f(jct.max)
            );
            out += &format!(
                "wait µs: mean = {} | p50 = {} | p99 = {}\n",
                f(wait.mean),
                f(wait.p50),
                f(wait.p99)
            );
        }
        for class in SizeClass::ALL {
            let s = self.class_jct_summary(class);
            if !s.is_empty() {
                out += &format!(
                    "  class {:<6} n = {:<3} jct µs: p50 = {} | p95 = {} | p99 = {}\n",
                    class.name(),
                    s.n,
                    f(s.p50),
                    f(s.p95),
                    f(s.p99)
                );
            }
        }
        out
    }
}

/// Generate the arrival trace for `cfg` and run it. The same `seed`
/// drives arrivals, fabric jitter, and per-node program streams, so one
/// `(config, seed)` pair fully determines the report.
pub fn run_service(cfg: &ServiceConfig, seed: u64) -> Result<ServiceReport> {
    run_service_trace(cfg, seed, arrivals::generate(&cfg.arrivals, seed))
}

/// Run an explicit job trace (the tests' entry point for crafted mixes).
/// `specs` must be sorted by arrival with ids `0..n` (what
/// [`arrivals::generate`] produces).
pub fn run_service_trace(
    cfg: &ServiceConfig,
    seed: u64,
    specs: Vec<JobSpec>,
) -> Result<ServiceReport> {
    ensure!(cfg.workers > 0, "service needs at least one worker");
    ensure!(
        cfg.threads == 1 || cfg.compute.name() != "xla",
        "the XLA data plane is single-threaded; run it with --threads 1"
    );
    if cfg.policy == SchedPolicy::Reserve {
        ensure!(
            cfg.workers % LEAF_RADIX == 0,
            "the reserve scheduler partitions whole {LEAF_RADIX}-node leaves; \
             fleet size {} is not leaf-aligned",
            cfg.workers
        );
    }
    let net = NetConfig { multicast: false, ..cfg.net.clone() };
    // One pool = one `--threads` budget for the whole service run: the
    // shard workers below and any parallel kernels in `cfg.compute` draw
    // from it. (A plane built elsewhere carries its own pool — still a
    // single budget per plane, its kernels just stay inline here.)
    let pool = Arc::new(crate::pool::WorkerPool::new(
        crate::sim::exec::resolve_threads(cfg.threads),
    ));

    // Host-side build: per-job programs and finish hooks through each
    // workload's own `Workload::build`, against a synthesized per-job
    // environment (the job's nodes/seed, the shared fabric's knobs).
    let mut jobs = Vec::with_capacity(specs.len());
    let mut records = Vec::with_capacity(specs.len());
    let mut finishes = Vec::with_capacity(specs.len());
    let mut trace = Vec::with_capacity(specs.len());
    for (i, spec) in specs.iter().enumerate() {
        ensure!(spec.id as usize == i, "job ids must be 0..n in trace order");
        let footprint = cfg.policy.footprint(spec.nodes);
        ensure!(
            footprint <= cfg.workers,
            "job {} ({}) needs {footprint} workers under {} but the fleet has {}",
            spec.id,
            spec.kind.workload(),
            cfg.policy.name(),
            cfg.workers
        );
        let env = ScenarioEnv {
            nodes: spec.nodes,
            net: net.clone(),
            core: cfg.core.clone(),
            compute: cfg.compute.clone(),
            seed: spec.seed,
            // Input skew applies per job; stragglers are fleet-level
            // machine properties, applied to the engine below.
            perturb: Perturbations { dist: cfg.perturb.dist, stragglers: Default::default() },
            threads: cfg.threads,
            exec: cfg.exec,
            window_batch: None,
            force_rollback_every: None,
            pool: pool.clone(),
            // Service jobs are smoke/mid-sized: the hyper-tier memory
            // paths (streamed input, spill) stay off here.
            stream_input: false,
            spill_dir: None,
        };
        let (programs, finish) = build_job(&spec.kind, &env)
            .with_context(|| format!("building job {} ({})", spec.id, spec.kind.workload()))?;
        ensure!(
            programs.len() == spec.nodes,
            "job {} built {} programs for {} nodes",
            spec.id,
            programs.len(),
            spec.nodes
        );
        jobs.push(JobState {
            nodes: spec.nodes,
            programs: programs.into_iter().map(|p| Mutex::new(Some(p))).collect(),
            placement: Mutex::new(None),
        });
        records.push(JobRecord {
            job: spec.id,
            workload: spec.kind.workload(),
            class: spec.class,
            nodes: spec.nodes,
            arrival: spec.arrival,
            admit_seq: u64::MAX,
            base: usize::MAX,
            start: Time::ZERO,
            finish: Time::ZERO,
            completed: false,
        });
        finishes.push((env, finish));
        trace.push((spec.arrival, spec.id, spec.nodes));
    }

    let arena = Arc::new(ServiceArena { jobs, records: Mutex::new(records) });
    let coord = cfg.workers;
    let mut programs: Vec<ServiceProg> = (0..cfg.workers)
        .map(|_| ServiceProg::Worker(Worker::new(arena.clone(), coord)))
        .collect();
    programs.push(ServiceProg::Coordinator(Coordinator::new(
        arena.clone(),
        cfg.policy,
        trace,
        cfg.workers,
    )));
    let fabric = Fabric::new(Topology::paper(cfg.workers + 1), net.clone(), seed);
    let mut engine = Engine::new(programs, fabric, cfg.core.clone(), seed);
    // Stragglers are slow machines, not slow jobs: picked once for the
    // whole fleet (stream 0 of the per-job-salted selection) and never
    // the coordinator, so every job admitted onto a straggler inherits
    // the slowdown — exactly what a real shared cluster does.
    let st = cfg.perturb.stragglers;
    for node in st.picks(seed, 0, cfg.workers) {
        engine.slow_down(node, st.factor);
    }
    engine.set_pool(pool);
    let summary = engine.run_exec(cfg.exec, cfg.threads, None, None);

    let records = std::mem::take(&mut *arena.records.lock().unwrap());
    let mut outcomes = Vec::with_capacity(records.len());
    for ((env, finish), rec) in finishes.into_iter().zip(records) {
        ensure!(
            rec.completed,
            "job {} ({}) never completed (arrived at {} units)",
            rec.job,
            rec.workload,
            rec.arrival.0
        );
        // Carve the job's slice of the fleet into a per-job summary for
        // its validator. Fabric counters are fleet-level only, so the
        // carved net stats are zeroed (DESIGN.md §9).
        let carved = RunSummary {
            makespan: rec.finish.saturating_sub(rec.start),
            node_stats: summary.node_stats[rec.base..rec.base + rec.nodes].to_vec(),
            net: NetStats::default(),
            events: 0,
            profile: Default::default(),
        };
        let report = finish(&env, carved);
        ensure!(
            report.validation.ok(),
            "job {} ({}) failed output validation: {}",
            rec.job,
            rec.workload,
            report.validation.detail
        );
        outcomes.push(JobOutcome { record: rec, validated: true });
    }

    Ok(ServiceReport {
        mix: cfg.arrivals.mix,
        policy: cfg.policy,
        workers: cfg.workers,
        seed,
        compute: cfg.compute.name(),
        mean_iat_ns: cfg.arrivals.mean_iat_ns,
        jobs: outcomes,
        makespan: summary.makespan,
        events: summary.events,
        net: summary.net,
    })
}

/// Build one job's per-slot programs and finish hook through the
/// workload's own `build` path (input generation included).
fn build_job(kind: &JobKind, env: &ScenarioEnv) -> Result<(Vec<InnerProg>, Finish)> {
    Ok(match kind {
        JobKind::NanoSort(w) => {
            // Per-job multicast groups can't be registered mid-run; the
            // env has multicast off, so group sends degrade to unicast
            // loops inside the wrapper and the built groups are unused.
            let b = w.build(env)?;
            (b.programs.into_iter().map(InnerProg::Ns).collect(), b.finish)
        }
        JobKind::MilliSort(w) => {
            let b = w.build(env)?;
            (b.programs.into_iter().map(InnerProg::Ms).collect(), b.finish)
        }
        JobKind::MergeMin(w) => {
            let b = w.build(env)?;
            (b.programs.into_iter().map(InnerProg::Min).collect(), b.finish)
        }
        JobKind::SetAlgebra(w) => {
            let b = w.build(env)?;
            (b.programs.into_iter().map(InnerProg::Count).collect(), b.finish)
        }
    })
}

/// Fleet size and arrival configuration of the `service` conformance
/// tier ladder (≥ 20 jobs at every tier — the acceptance floor).
pub fn service_tier(tier: Tier, mix: Mix) -> (usize, ArrivalConfig) {
    match tier {
        Tier::Smoke => {
            (256, ArrivalConfig { jobs: 24, mean_iat_ns: 4_000, mix, ..Default::default() })
        }
        Tier::Mid => {
            (1024, ArrivalConfig { jobs: 64, mean_iat_ns: 2_000, mix, ..Default::default() })
        }
        Tier::Paper => {
            (4096, ArrivalConfig { jobs: 256, mean_iat_ns: 1_000, mix, ..Default::default() })
        }
        // The service ladder tops out at the paper shape: the hyper
        // tiers probe single-tenant memory scaling ([`crate::mem`]), not
        // multi-tenant scheduling, so they alias the paper arrivals.
        Tier::HyperSmoke | Tier::Hyper => {
            (4096, ArrivalConfig { jobs: 256, mean_iat_ns: 1_000, mix, ..Default::default() })
        }
    }
}

/// Canonical line-oriented JSON digest of one service run: fleet header
/// plus one line per job (arrival, scheduler decision, start, finish).
/// Exact integers for sim-exact values, quoted `%.6f` for floats; no
/// backend-, thread-, or plane-dependent field may appear. Golden name:
/// `service_<mix>_<sched>_<tier>`.
pub fn service_digest(r: &ServiceReport, tier: &str) -> String {
    let jct = r.jct_summary();
    let wait = r.wait_summary();
    let mut lines = vec![
        format!("  \"service\": \"{}\"", r.mix.name()),
        format!("  \"tier\": \"{tier}\""),
        format!("  \"sched\": \"{}\"", r.policy.name()),
        format!("  \"workers\": {}", r.workers),
        format!("  \"seed\": {}", r.seed),
        format!("  \"jobs\": {}", r.jobs.len()),
        format!("  \"makespan_units\": {}", r.makespan.0),
        format!("  \"events\": {}", r.events),
        format!("  \"msgs_sent\": {}", r.net.msgs_sent),
        format!("  \"msgs_delivered\": {}", r.net.msgs_delivered),
        format!("  \"retransmits\": {}", r.net.retransmits),
        format!("  \"jct_p50_us\": \"{:.6}\"", jct.p50),
        format!("  \"jct_p95_us\": \"{:.6}\"", jct.p95),
        format!("  \"jct_p99_us\": \"{:.6}\"", jct.p99),
        format!("  \"wait_mean_us\": \"{:.6}\"", wait.mean),
        format!("  \"wait_p99_us\": \"{:.6}\"", wait.p99),
    ];
    for j in &r.jobs {
        let rec = &j.record;
        lines.push(format!(
            "  \"job{}\": {{\"workload\": \"{}\", \"class\": \"{}\", \"nodes\": {}, \
             \"arrival_units\": {}, \"admit_seq\": {}, \"base\": {}, \"start_units\": {}, \
             \"finish_units\": {}, \"valid\": {}}}",
            rec.job,
            rec.workload,
            rec.class.name(),
            rec.nodes,
            rec.arrival.0,
            rec.admit_seq,
            rec.base,
            rec.start.0,
            rec.finish.0,
            j.validated
        ));
    }
    format!("{{\n{}\n}}\n", lines.join(",\n"))
}

/// `BENCH_service*.json` record: simulated service quality next to the
/// host cost of producing it (same two-axis contract as [`crate::conformance::BenchRecord`]).
pub fn service_bench_json(
    r: &ServiceReport,
    tier: &str,
    wall_clock_s: f64,
    threads: usize,
) -> String {
    let jct = r.jct_summary();
    let wait = r.wait_summary();
    format!(
        "{{\n  \"workload\": \"service\",\n  \"tier\": \"{tier}\",\n  \"mix\": \"{}\",\n  \
         \"sched\": \"{}\",\n  \"workers\": {},\n  \"jobs\": {},\n  \"mean_iat_ns\": {},\n  \
         \"makespan_us\": {:.3},\n  \"offered_jobs_per_ms\": {:.3},\n  \
         \"achieved_jobs_per_ms\": {:.3},\n  \"jct_p50_us\": {:.3},\n  \
         \"jct_p95_us\": {:.3},\n  \"jct_p99_us\": {:.3},\n  \"wait_mean_us\": {:.3},\n  \
         \"events\": {},\n  \"msgs_sent\": {},\n  \"threads\": {threads},\n  \
         \"wall_clock_s\": {wall_clock_s:.3},\n  \"validated\": true\n}}\n",
        r.mix.name(),
        r.policy.name(),
        r.workers,
        r.jobs.len(),
        r.mean_iat_ns,
        r.makespan.as_us_f64(),
        r.offered_jobs_per_ms(),
        r.achieved_jobs_per_ms(),
        jct.p50,
        jct.p95,
        jct.p99,
        wait.mean,
        r.events,
        r.net.msgs_sent
    )
}

/// `repro fig loadsweep`: offered load × scheduler at smoke scale —
/// the tail-JCT/utilization trade each policy makes as load rises.
pub fn loadsweep_figure(opts: &RunOptions) -> Result<Table> {
    let mut t = Table::new(
        "Load sweep: offered load × scheduler (open Poisson arrivals, shared fleet)",
        &[
            "sched",
            "iat_ns",
            "offered/ms",
            "achieved/ms",
            "jct_p50_us",
            "jct_p95_us",
            "jct_p99_us",
            "wait_mean_us",
        ],
    );
    let (workers, jobs) = if opts.quick { (128, 12) } else { (256, 24) };
    let iats: &[u64] = if opts.quick { &[4_000, 1_000] } else { &[8_000, 4_000, 2_000, 1_000] };
    let plane = opts.compute.build()?;
    for policy in SchedPolicy::ALL {
        for &iat in iats {
            let arrivals = ArrivalConfig {
                jobs,
                mean_iat_ns: iat,
                mix: Mix::Nanosort,
                ..Default::default()
            };
            let mut cfg = ServiceConfig::new(workers, arrivals, policy)?;
            cfg.compute = plane.clone();
            let r = run_service(&cfg, opts.seed)
                .with_context(|| format!("loadsweep {} iat={iat}", policy.name()))?;
            let jct = r.jct_summary();
            let wait = r.wait_summary();
            t.row(vec![
                policy.name().into(),
                iat.to_string(),
                f(r.offered_jobs_per_ms()),
                f(r.achieved_jobs_per_ms()),
                f(jct.p50),
                f(jct.p95),
                f(jct.p99),
                f(wait.mean),
            ]);
        }
    }
    t.note(
        "Shape to match: queueing delay (and thus tail JCT) grows as interarrival \
         shrinks; sjf flattens small-job tails vs fifo; reserve trades utilization \
         for whole-leaf isolation.",
    );
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(policy: SchedPolicy) -> ServiceConfig {
        let arrivals = ArrivalConfig {
            jobs: 6,
            mean_iat_ns: 2_000,
            mix: Mix::Nanosort,
            ..Default::default()
        };
        ServiceConfig::new(128, arrivals, policy).unwrap()
    }

    #[test]
    fn service_run_completes_and_validates_every_job() {
        let r = run_service(&tiny_cfg(SchedPolicy::Fifo), 7).unwrap();
        assert_eq!(r.jobs.len(), 6);
        assert!(r.jobs.iter().all(|j| j.record.completed && j.validated));
        assert!(r.makespan > Time::ZERO);
        assert!(r.events > 0);
        // Every job waits at least as long as its nominal arrival says.
        for j in &r.jobs {
            assert!(j.record.start >= j.record.arrival, "job {}", j.record.job);
            assert!(j.record.finish > j.record.start, "job {}", j.record.job);
        }
        assert_eq!(r.jct_summary().n, 6);
    }

    #[test]
    fn admission_order_is_total_and_starts_monotone_in_admit_seq() {
        let r = run_service(&tiny_cfg(SchedPolicy::Fifo), 7).unwrap();
        let mut by_seq: Vec<&JobRecord> = r.jobs.iter().map(|j| &j.record).collect();
        by_seq.sort_by_key(|rec| rec.admit_seq);
        assert!(by_seq.iter().all(|rec| rec.admit_seq != u64::MAX));
        assert!(by_seq.windows(2).all(|w| w[0].admit_seq + 1 == w[1].admit_seq));
        assert!(by_seq.windows(2).all(|w| w[0].start <= w[1].start));
    }

    #[test]
    fn digest_is_canonical_line_json() {
        let r = run_service(&tiny_cfg(SchedPolicy::Sjf), 7).unwrap();
        let d = service_digest(&r, "smoke");
        assert!(d.starts_with("{\n") && d.ends_with("}\n"));
        assert!(d.contains("\"service\": \"nanosort\""));
        assert!(d.contains("\"sched\": \"sjf\""));
        assert!(d.contains("\"job0\": {\"workload\": "));
        assert!(d.contains("\"job5\": "));
        assert!(!d.contains("job6"));
        // Rendering is deterministic for the same report.
        assert_eq!(d, service_digest(&r, "smoke"));
    }

    #[test]
    fn zero_job_service_quiesces_immediately() {
        let mut cfg = tiny_cfg(SchedPolicy::Fifo);
        cfg.arrivals.jobs = 0;
        let r = run_service(&cfg, 7).unwrap();
        assert!(r.jobs.is_empty());
        assert_eq!(r.makespan, Time::ZERO);
        assert_eq!(r.net.msgs_sent, 0);
        let d = service_digest(&r, "smoke");
        assert!(d.contains("\"jobs\": 0"));
        assert!(!d.contains("\"job0\""));
    }

    #[test]
    fn oversized_job_is_a_loud_error() {
        let arrivals = ArrivalConfig {
            jobs: 4,
            mean_iat_ns: 2_000,
            mix: Mix::Nanosort,
            // All jobs Large (64 nodes) — too big for a 32-worker fleet.
            size_weights: [0, 0, 1],
        };
        let cfg = ServiceConfig::new(32, arrivals, SchedPolicy::Fifo).unwrap();
        let err = run_service(&cfg, 7).unwrap_err();
        assert!(err.to_string().contains("needs"), "{err:#}");
    }

    #[test]
    fn reserve_requires_a_leaf_aligned_fleet() {
        let arrivals = ArrivalConfig { jobs: 2, ..Default::default() };
        let cfg = ServiceConfig::new(100, arrivals, SchedPolicy::Reserve).unwrap();
        let err = run_service(&cfg, 7).unwrap_err();
        assert!(err.to_string().contains("leaf"), "{err:#}");
    }

    #[test]
    fn mixed_mix_runs_all_workload_kinds_on_one_fabric() {
        let arrivals = ArrivalConfig {
            jobs: 12,
            mean_iat_ns: 2_000,
            mix: Mix::Mixed,
            ..Default::default()
        };
        let cfg = ServiceConfig::new(128, arrivals, SchedPolicy::Sjf).unwrap();
        let r = run_service(&cfg, 11).unwrap();
        assert!(r.jobs.iter().all(|j| j.record.completed && j.validated));
        // The zipf mix at 12 jobs reliably includes at least 2 kinds.
        let mut kinds: Vec<&str> = r.jobs.iter().map(|j| j.record.workload).collect();
        kinds.sort_unstable();
        kinds.dedup();
        assert!(kinds.len() >= 2, "only {kinds:?}");
    }

    #[test]
    fn tier_ladder_meets_the_acceptance_floor() {
        for tier in Tier::ALL {
            let (workers, arrivals) = service_tier(tier, Mix::Nanosort);
            assert!(arrivals.jobs >= 20, "{}: {} jobs", tier.name(), arrivals.jobs);
            assert_eq!(workers % LEAF_RADIX, 0, "{}", tier.name());
            // Largest job of either mix (64 nodes) fits even reserved.
            assert!(SchedPolicy::Reserve.footprint(64) <= workers);
        }
    }

    #[test]
    fn report_load_metrics() {
        let r = run_service(&tiny_cfg(SchedPolicy::Fifo), 7).unwrap();
        assert!((r.offered_jobs_per_ms() - 500.0).abs() < 1e-9, "1e6/2000");
        assert!(r.achieved_jobs_per_ms() > 0.0);
        let s = r.render();
        assert!(s.contains("service: mix=nanosort sched=fifo"));
        assert!(s.contains("offered = "));
        assert!(s.contains("jct µs: p50 = "));
    }
}
