//! Open arrival generation: deterministic Poisson interarrivals, zipfian
//! workload popularity over the registry, and a configurable job-size
//! mix, producing the [`JobSpec`] trace the coordinator replays.
//!
//! Everything here is integer/fixed-point arithmetic on
//! [`SplitMix64`] draws — no `libm` transcendentals — so a trace (and
//! therefore the service digest) is bit-identical across platforms.

use crate::algo::mergemin::MergeMin;
use crate::algo::millisort::MilliSort;
use crate::algo::nanosort::NanoSort;
use crate::algo::setalgebra::SetAlgebra;
use crate::sim::{SplitMix64, Time};

use anyhow::{bail, Result};

/// Seed salt separating the service layer's RNG streams from every other
/// consumer of the master seed.
pub const SERVICE_SALT: u64 = 0x736f_7274_7376_6331; // "sortsvc1"

/// Zipfian popularity weights over the workload registry order
/// (nanosort, millisort, mergemin, setalgebra): the exact θ=1 harmonic
/// series 1/1 : 1/2 : 1/3 : 1/4 scaled by lcm(1..4) = 12, kept as
/// integers so the popularity draw never touches floating point.
const MIX_WEIGHTS: [u64; 4] = [12, 6, 4, 3];

/// Job size class; the mix draws classes by [`ArrivalConfig::size_weights`]
/// and each (workload, class) pair maps to a fixed shape in [`job_kind`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SizeClass {
    Small,
    Medium,
    Large,
}

impl SizeClass {
    pub const ALL: [SizeClass; 3] = [SizeClass::Small, SizeClass::Medium, SizeClass::Large];

    pub fn name(self) -> &'static str {
        match self {
            SizeClass::Small => "small",
            SizeClass::Medium => "medium",
            SizeClass::Large => "large",
        }
    }
}

/// Which workload population the service draws jobs from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mix {
    /// Every job is a NanoSort instance (size class still varies).
    Nanosort,
    /// Zipf-popularity draw over all four registered workloads.
    Mixed,
}

impl Mix {
    pub fn name(self) -> &'static str {
        match self {
            Mix::Nanosort => "nanosort",
            Mix::Mixed => "mixed",
        }
    }

    pub fn parse(s: &str) -> Result<Mix> {
        match s {
            "nanosort" => Ok(Mix::Nanosort),
            "mixed" => Ok(Mix::Mixed),
            other => bail!("unknown mix {other:?} (known: nanosort|mixed)"),
        }
    }
}

/// A fully-built workload instance a job runs (constructed per job so
/// per-class shapes are self-contained in the trace).
#[derive(Debug, Clone)]
pub enum JobKind {
    NanoSort(NanoSort),
    MilliSort(MilliSort),
    MergeMin(MergeMin),
    SetAlgebra(SetAlgebra),
}

impl JobKind {
    pub fn workload(&self) -> &'static str {
        match self {
            JobKind::NanoSort(_) => "nanosort",
            JobKind::MilliSort(_) => "millisort",
            JobKind::MergeMin(_) => "mergemin",
            JobKind::SetAlgebra(_) => "setalgebra",
        }
    }
}

/// One job in the arrival trace.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Dense job id (index into the trace; also the per-job RNG stream
    /// selector for perturbation draws — [`crate::perturb::job_salt`]).
    pub id: u32,
    /// Nominal arrival time (the coordinator's Tick clock replays it).
    pub arrival: Time,
    /// Worker nodes this job needs (contiguous once placed).
    pub nodes: usize,
    pub class: SizeClass,
    pub kind: JobKind,
    /// Per-job input seed (derived; disjoint across jobs by stream).
    pub seed: u64,
}

/// Open-arrival generator configuration.
#[derive(Debug, Clone)]
pub struct ArrivalConfig {
    /// Jobs in the trace.
    pub jobs: usize,
    /// Mean Poisson interarrival gap, ns (offered load = 1/mean).
    pub mean_iat_ns: u64,
    pub mix: Mix,
    /// Relative draw weights for small/medium/large job sizes.
    pub size_weights: [u64; 3],
}

impl Default for ArrivalConfig {
    fn default() -> Self {
        ArrivalConfig {
            jobs: 24,
            mean_iat_ns: 4_000,
            mix: Mix::Nanosort,
            size_weights: [8, 3, 1],
        }
    }
}

/// The fixed shape of one (workload, size-class) cell: fleet slice plus
/// the workload parameters. NanoSort sizes are powers of its bucket
/// radix (4) as `depth_of` requires; MilliSort keys scale with cores so
/// per-core load stays constant across classes.
pub fn job_kind(workload: usize, class: SizeClass) -> (usize, JobKind) {
    use SizeClass::*;
    match workload {
        0 => {
            let nodes = match class {
                Small => 4,
                Medium => 16,
                Large => 64,
            };
            (
                nodes,
                JobKind::NanoSort(NanoSort {
                    keys_per_node: 8,
                    buckets: 4,
                    median_incast: 4,
                    ..Default::default()
                }),
            )
        }
        1 => {
            let cores = match class {
                Small => 4,
                Medium => 8,
                Large => 16,
            };
            (
                cores,
                JobKind::MilliSort(MilliSort {
                    total_keys: 16 * cores,
                    ..Default::default()
                }),
            )
        }
        2 => {
            let cores = match class {
                Small => 8,
                Medium => 32,
                Large => 64,
            };
            (cores, JobKind::MergeMin(MergeMin { values_per_core: 64, incast: 8 }))
        }
        _ => {
            let cores = match class {
                Small => 8,
                Medium => 32,
                Large => 64,
            };
            (
                cores,
                JobKind::SetAlgebra(SetAlgebra {
                    lists: 3,
                    ids_per_core: 32,
                    incast: 8,
                    ..Default::default()
                }),
            )
        }
    }
}

/// Draw an index from integer `weights` (probability ∝ weight).
fn pick_weighted(rng: &mut SplitMix64, weights: &[u64]) -> usize {
    let total: u64 = weights.iter().sum();
    debug_assert!(total > 0);
    let mut x = rng.next_below(total);
    for (i, &w) in weights.iter().enumerate() {
        if x < w {
            return i;
        }
        x -= w;
    }
    weights.len() - 1
}

/// One Exp(mean)-distributed gap in [`Time`] units, via von Neumann's
/// 1951 comparison method: only uniform u64 draws and integer compares
/// decide the sample, and the final magnitude is a 128-bit fixed-point
/// product — bit-identical on every platform, unlike `ln()`.
///
/// The integer part is the count of *rejected* unit intervals (each
/// accepted with probability 1/e via descending-run parity); the
/// fractional part is the first uniform of the accepting run.
fn exp_gap_units(rng: &mut SplitMix64, mean_units: u64) -> u64 {
    let mut whole: u64 = 0;
    loop {
        let u0 = rng.next_u64();
        let mut last = u0;
        let mut run: u64 = 1;
        loop {
            let u = rng.next_u64();
            if u < last {
                last = u;
                run += 1;
            } else {
                break;
            }
        }
        if run % 2 == 1 {
            let frac = ((u0 as u128 * mean_units as u128) >> 64) as u64;
            return whole.saturating_mul(mean_units).saturating_add(frac);
        }
        whole += 1;
    }
}

/// Generate the deterministic arrival trace for `(cfg, seed)`: Poisson
/// arrivals at rate `1/mean_iat_ns`, workload popularity per the mix,
/// size class per `size_weights`, and a derived per-job input seed.
pub fn generate(cfg: &ArrivalConfig, seed: u64) -> Vec<JobSpec> {
    let root = SplitMix64::new(seed ^ SERVICE_SALT);
    let mut iat_rng = root.derive(1);
    let mut mix_rng = root.derive(2);
    let mut size_rng = root.derive(3);
    let mean_units = Time::from_ns(cfg.mean_iat_ns).0.max(1);
    let mut at = Time::ZERO;
    (0..cfg.jobs)
        .map(|id| {
            at += Time(exp_gap_units(&mut iat_rng, mean_units));
            let workload = match cfg.mix {
                Mix::Nanosort => 0,
                Mix::Mixed => pick_weighted(&mut mix_rng, &MIX_WEIGHTS),
            };
            let class = SizeClass::ALL[pick_weighted(&mut size_rng, &cfg.size_weights)];
            let (nodes, kind) = job_kind(workload, class);
            JobSpec {
                id: id as u32,
                arrival: at,
                nodes,
                class,
                kind,
                seed: root.derive(16 + id as u64).next_u64(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_monotone() {
        let cfg = ArrivalConfig::default();
        let a = generate(&cfg, 7);
        let b = generate(&cfg, 7);
        assert_eq!(a.len(), cfg.jobs);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.nodes, y.nodes);
            assert_eq!(x.seed, y.seed);
        }
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(a[0].arrival > Time::ZERO, "first gap is drawn too");
        // A different seed moves the arrivals.
        let c = generate(&cfg, 8);
        assert!(a.iter().zip(&c).any(|(x, y)| x.arrival != y.arrival));
    }

    #[test]
    fn exponential_mean_is_roughly_right() {
        let mut rng = SplitMix64::new(42);
        let mean = Time::from_ns(4_000).0;
        let n = 4000u64;
        let total: u128 = (0..n).map(|_| exp_gap_units(&mut rng, mean) as u128).sum();
        let got = (total / n as u128) as u64;
        // Within 10% of the configured mean over 4k draws.
        assert!(
            got > mean * 9 / 10 && got < mean * 11 / 10,
            "sample mean {got} vs configured {mean}"
        );
    }

    #[test]
    fn exponential_tail_exceeds_the_mean() {
        // P(X > mean) = 1/e ≈ 37%: the integer part must sometimes be > 0.
        let mut rng = SplitMix64::new(1);
        let over = (0..1000).filter(|_| exp_gap_units(&mut rng, 1000) > 1000).count();
        assert!(over > 250 && over < 500, "{over}/1000 over the mean");
    }

    #[test]
    fn nanosort_mix_is_all_nanosort() {
        let cfg = ArrivalConfig { jobs: 32, ..Default::default() };
        assert!(generate(&cfg, 3).iter().all(|j| j.workload_is("nanosort")));
    }

    impl JobSpec {
        fn workload_is(&self, name: &str) -> bool {
            self.kind.workload() == name
        }
    }

    #[test]
    fn mixed_popularity_is_zipf_ordered() {
        let cfg = ArrivalConfig { jobs: 400, mix: Mix::Mixed, ..Default::default() };
        let trace = generate(&cfg, 11);
        let count = |w: &str| trace.iter().filter(|j| j.workload_is(w)).count();
        let (ns, ms, mm, sa) =
            (count("nanosort"), count("millisort"), count("mergemin"), count("setalgebra"));
        assert_eq!(ns + ms + mm + sa, 400);
        assert!(ns > ms && ms > sa, "zipf order: {ns} {ms} {mm} {sa}");
        assert!(sa > 0, "even the least-popular workload appears");
    }

    #[test]
    fn size_weights_shape_the_class_histogram() {
        let cfg = ArrivalConfig { jobs: 400, ..Default::default() };
        let trace = generate(&cfg, 5);
        let small = trace.iter().filter(|j| j.class == SizeClass::Small).count();
        let large = trace.iter().filter(|j| j.class == SizeClass::Large).count();
        assert!(small > large, "default mix favors small jobs: {small} vs {large}");
        // All-large weights produce only large jobs.
        let cfg = ArrivalConfig { size_weights: [0, 0, 1], jobs: 16, ..Default::default() };
        assert!(generate(&cfg, 5).iter().all(|j| j.class == SizeClass::Large));
    }

    #[test]
    fn per_job_seeds_are_distinct() {
        let trace = generate(&ArrivalConfig { jobs: 64, ..Default::default() }, 9);
        let mut seeds: Vec<u64> = trace.iter().map(|j| j.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 64);
    }

    #[test]
    fn every_job_kind_cell_is_well_formed() {
        for w in 0..4 {
            for class in SizeClass::ALL {
                let (nodes, kind) = job_kind(w, class);
                assert!(nodes >= 4 && nodes <= 64, "{} {}", kind.workload(), class.name());
                if let JobKind::MilliSort(ms) = &kind {
                    assert_eq!(ms.total_keys % nodes, 0);
                }
                if let JobKind::NanoSort(_) = &kind {
                    assert!(nodes.is_power_of_two());
                }
            }
        }
    }
}
