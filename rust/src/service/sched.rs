//! Coordinator-level job schedulers and the worker-range allocator.
//!
//! The service admits each queued job onto a *contiguous* range of
//! worker nodes (contiguity is what makes per-job node-id namespacing a
//! base offset — see [`super::wrap`]). [`RangeAlloc`] is the free-list
//! over the worker id space; [`SchedPolicy`] decides which queued job is
//! admitted next and where.

use anyhow::{bail, Result};

/// Reservation granularity of [`SchedPolicy::Reserve`]: one leaf switch
/// of the paper fabric ([`crate::net::Topology::paper`]'s radix).
pub const LEAF_RADIX: usize = 64;

/// First-fit free-list allocator over the worker id space `0..nodes`.
/// Ranges are kept sorted, disjoint, and non-adjacent (adjacent frees
/// merge), so `fit` scans lowest-base-first — deterministic placement.
#[derive(Debug, Clone)]
pub struct RangeAlloc {
    nodes: usize,
    /// Sorted, disjoint, non-adjacent free ranges `[start, end)`.
    free: Vec<(usize, usize)>,
}

impl RangeAlloc {
    pub fn new(nodes: usize) -> Self {
        RangeAlloc { nodes, free: if nodes > 0 { vec![(0, nodes)] } else { Vec::new() } }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Total free workers (not necessarily contiguous).
    pub fn free_nodes(&self) -> usize {
        self.free.iter().map(|(s, e)| e - s).sum()
    }

    /// Lowest base where `n` nodes fit with `base % align == 0`.
    pub fn fit(&self, n: usize, align: usize) -> Option<usize> {
        assert!(n > 0 && align > 0);
        for &(s, e) in &self.free {
            let base = s.div_ceil(align) * align;
            if base + n <= e {
                return Some(base);
            }
        }
        None
    }

    /// Claim `[base, base + n)`. Panics if any of it is not free (the
    /// coordinator only takes ranges returned by [`RangeAlloc::fit`]).
    pub fn take(&mut self, base: usize, n: usize) {
        let i = self
            .free
            .iter()
            .position(|&(s, e)| s <= base && base + n <= e)
            .expect("take() of a range that is not free");
        let (s, e) = self.free.remove(i);
        if base + n < e {
            self.free.insert(i, (base + n, e));
        }
        if s < base {
            self.free.insert(i, (s, base));
        }
    }

    /// Return `[base, base + n)` to the free list, merging neighbors.
    pub fn release(&mut self, base: usize, n: usize) {
        let i = self.free.partition_point(|&(s, _)| s < base);
        self.free.insert(i, (base, base + n));
        if i + 1 < self.free.len() && self.free[i].1 == self.free[i + 1].0 {
            self.free[i].1 = self.free[i + 1].1;
            self.free.remove(i + 1);
        }
        if i > 0 && self.free[i - 1].1 == self.free[i].0 {
            self.free[i - 1].1 = self.free[i].1;
            self.free.remove(i);
        }
    }
}

/// Which queued job the coordinator admits next, and where.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Strict arrival order: the head-of-line job is admitted first-fit
    /// or nothing is (large jobs block smaller ones behind them).
    Fifo,
    /// Smallest-job-first: the smallest queued job (ties by arrival)
    /// is admitted when it fits — classic tail-JCT trade: small jobs
    /// jump the line, large jobs risk starvation under load.
    Sjf,
    /// Partition-reserving FIFO: arrival order, but every job gets a
    /// private leaf-aligned reservation of whole [`LEAF_RADIX`] leaves,
    /// queueing while the fabric is full — no leaf is ever shared
    /// between jobs, at the cost of internal fragmentation.
    Reserve,
}

impl SchedPolicy {
    pub const ALL: [SchedPolicy; 3] =
        [SchedPolicy::Fifo, SchedPolicy::Sjf, SchedPolicy::Reserve];

    pub fn name(self) -> &'static str {
        match self {
            SchedPolicy::Fifo => "fifo",
            SchedPolicy::Sjf => "sjf",
            SchedPolicy::Reserve => "reserve",
        }
    }

    pub fn parse(s: &str) -> Result<SchedPolicy> {
        match s {
            "fifo" => Ok(SchedPolicy::Fifo),
            "sjf" => Ok(SchedPolicy::Sjf),
            "reserve" => Ok(SchedPolicy::Reserve),
            other => bail!("unknown scheduler {other:?} (known: fifo|sjf|reserve)"),
        }
    }

    /// Workers actually claimed for a job of `n` nodes.
    pub fn footprint(self, n: usize) -> usize {
        match self {
            SchedPolicy::Reserve => n.div_ceil(LEAF_RADIX) * LEAF_RADIX,
            _ => n,
        }
    }

    fn alignment(self) -> usize {
        match self {
            SchedPolicy::Reserve => LEAF_RADIX,
            _ => 1,
        }
    }

    /// Pick the next admission from `queue` (entries are `(job, nodes)`
    /// in arrival order): returns `(queue index, base)` or `None` when
    /// nothing admissible fits.
    pub fn pick(self, queue: &[(u32, usize)], alloc: &RangeAlloc) -> Option<(usize, usize)> {
        match self {
            SchedPolicy::Fifo | SchedPolicy::Reserve => {
                let &(_, n) = queue.first()?;
                alloc.fit(self.footprint(n), self.alignment()).map(|b| (0, b))
            }
            SchedPolicy::Sjf => {
                // Smallest queued job, ties by arrival order. If the
                // smallest doesn't fit, nothing larger can either.
                let (i, &(_, n)) = queue
                    .iter()
                    .enumerate()
                    .min_by_key(|(i, (_, n))| (*n, *i))?;
                alloc.fit(n, 1).map(|b| (i, b))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_first_fit_take_release_merge() {
        let mut a = RangeAlloc::new(256);
        assert_eq!(a.free_nodes(), 256);
        assert_eq!(a.fit(16, 1), Some(0));
        a.take(0, 16);
        assert_eq!(a.fit(16, 1), Some(16));
        a.take(16, 16);
        a.take(32, 64);
        assert_eq!(a.free_nodes(), 256 - 96);
        // Free the middle range; first-fit lands back in the hole.
        a.release(16, 16);
        assert_eq!(a.fit(16, 1), Some(16));
        assert_eq!(a.fit(32, 1), Some(96));
        // Release everything; neighbors merge back to one range.
        a.release(0, 16);
        a.release(32, 64);
        assert_eq!(a.fit(256, 1), Some(0));
        assert_eq!(a.free_nodes(), 256);
    }

    #[test]
    fn alloc_alignment() {
        let mut a = RangeAlloc::new(256);
        a.take(0, 10);
        // Next 64-aligned base after the hole at 10 is 64.
        assert_eq!(a.fit(64, 64), Some(64));
        assert_eq!(a.fit(10, 1), Some(10));
        a.take(64, 192);
        assert_eq!(a.fit(64, 64), None, "only [10, 64) left");
    }

    #[test]
    #[should_panic(expected = "not free")]
    fn alloc_take_of_busy_range_panics() {
        let mut a = RangeAlloc::new(64);
        a.take(0, 32);
        a.take(16, 8);
    }

    #[test]
    fn policy_names_round_trip() {
        for p in SchedPolicy::ALL {
            assert_eq!(SchedPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(SchedPolicy::parse("lifo").is_err());
    }

    #[test]
    fn fifo_is_head_of_line_blocking() {
        let mut alloc = RangeAlloc::new(64);
        alloc.take(0, 32); // half the fabric busy
        let queue = [(0u32, 64usize), (1, 4)];
        // FIFO refuses to jump the 64-node head even though job 1 fits.
        assert_eq!(SchedPolicy::Fifo.pick(&queue, &alloc), None);
        // SJF admits the small job immediately.
        assert_eq!(SchedPolicy::Sjf.pick(&queue, &alloc), Some((1, 32)));
    }

    #[test]
    fn sjf_breaks_ties_by_arrival() {
        let alloc = RangeAlloc::new(64);
        let queue = [(7u32, 8usize), (9, 8), (3, 16)];
        assert_eq!(SchedPolicy::Sjf.pick(&queue, &alloc), Some((0, 0)));
    }

    #[test]
    fn reserve_rounds_footprint_to_whole_leaves() {
        assert_eq!(SchedPolicy::Reserve.footprint(1), 64);
        assert_eq!(SchedPolicy::Reserve.footprint(64), 64);
        assert_eq!(SchedPolicy::Reserve.footprint(65), 128);
        assert_eq!(SchedPolicy::Fifo.footprint(65), 65);
        let mut alloc = RangeAlloc::new(256);
        alloc.take(0, 64);
        let queue = [(0u32, 10usize)];
        // 10 nodes reserve a whole leaf, at the next leaf boundary.
        assert_eq!(SchedPolicy::Reserve.pick(&queue, &alloc), Some((0, 64)));
    }
}
