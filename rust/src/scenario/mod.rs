//! L3.5 scenario layer: one run API for every workload.
//!
//! The paper's thesis is that nanosecond-scale granularity pays off across
//! *many* workloads — NanoSort, MilliSort, MergeMin, and set algebra are
//! all instances of one pattern: partition the input, run event-driven
//! node programs over the fabric, aggregate and validate the result. This
//! module captures that pattern once:
//!
//! - [`Workload`] — what an algorithm must provide: input generation,
//!   program construction, multicast-group registration, and result
//!   extraction/validation (all inside [`Workload::build`]).
//! - [`Scenario`] — the builder that owns every *environment* knob
//!   (fleet size, [`NetConfig`], [`CoreModel`], data plane, seed, worker
//!   threads) and the single engine/fabric wiring path shared by the
//!   CLI, the figures, the benches, and the examples.
//! - [`RunReport`] — the unified outcome: makespan, per-stage busy/idle
//!   breakdown, net stats, validation, and workload-specific metrics.
//! - [`registry`] — the static name → [`WorkloadSpec`] table (typed
//!   parameter descriptors) that drives `repro run <name>` from data.
//!
//! ```no_run
//! use nanosort::algo::nanosort::NanoSort;
//! use nanosort::scenario::Scenario;
//!
//! let report = Scenario::new(NanoSort::default())
//!     .nodes(256)
//!     .seed(42)
//!     .run()
//!     .unwrap();
//! assert!(report.validation.ok());
//! ```
//!
//! Environment *perturbations* — input skew ([`crate::perturb::KeyDistribution`]),
//! packet loss, core oversubscription, stragglers — are scenario knobs
//! (`Scenario::perturb` / [`crate::net::NetConfig`]), swept in grids by
//! `repro sweep` (see [`crate::perturb::sweep`]). New *workloads* are
//! added as single self-contained [`Workload`] impls plus one
//! [`registry`] entry — no CLI, figure, or engine changes.
//!
//! Execution backend: [`Scenario::threads`] picks how many host worker
//! threads simulate the fleet (`1` = the sequential reference backend,
//! `0` = all available cores) and [`Scenario::exec`] which backend runs
//! them (conservative windows or optimistic speculation with rollback).
//! Results are byte-identical at every combination — see
//! [`crate::sim::exec`] for the determinism contract.

pub mod registry;

pub use registry::{ParamKind, ParamSpec, WorkloadSpec};

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::compute::LocalCompute;
use crate::coordinator::{f, ComputeChoice};
use crate::cpu::CoreModel;
use crate::graysort::ValidationReport;
use crate::nanopu::{Group, Program};
use crate::net::{Fabric, NetConfig, Topology};
use crate::perturb::{KeyDistribution, Perturbations};
use crate::pool::WorkerPool;
use crate::sim::{Engine, ExecKind, RunSummary, Time, MAX_STAGES};

/// Everything the environment (not the workload) decides about a run.
pub struct ScenarioEnv {
    /// Fleet size (simulated cores).
    pub nodes: usize,
    /// Fabric configuration (latencies, bandwidth, multicast, tails,
    /// loss, oversubscription).
    pub net: NetConfig,
    /// Endpoint core cost model.
    pub core: CoreModel,
    /// Node-local data plane.
    pub compute: Arc<dyn LocalCompute>,
    /// Master seed (input generation, fabric jitter, per-node RNG streams).
    pub seed: u64,
    /// Scenario-level perturbations: input [`KeyDistribution`] (read by
    /// every workload's input path) and straggler cores (applied to the
    /// engine). Defaults are the unperturbed paper assumptions.
    pub perturb: Perturbations,
    /// Host worker threads simulating the fleet (`1` = sequential
    /// backend, `0` = all available cores). Never changes results.
    pub threads: usize,
    /// Execution backend (`--exec`): conservative parallel windows by
    /// default; `seq` forces the reference path, `opt` speculates with
    /// rollback. Never changes results ([`crate::sim::exec`]).
    pub exec: ExecKind,
    /// Window-coalescing factor override (`None` = the
    /// `NANOSORT_WINDOW_BATCH` environment knob / default). Never
    /// changes results.
    pub window_batch: Option<usize>,
    /// Test-only optimistic-executor fault hook: force a rollback on
    /// every `n`-th speculative burst. Never changes results.
    pub force_rollback_every: Option<u64>,
    /// The shared host worker pool ([`crate::pool`]): one `--threads`
    /// budget covering executor shard workers and parallel compute
    /// kernels. Never changes results.
    pub pool: Arc<WorkerPool>,
    /// Stream input generation per node instead of materializing the full
    /// key array up front (`--stream-input` / `NANOSORT_STREAM_INPUT`,
    /// auto-enabled by the hyper conformance tiers). Workloads whose
    /// input distribution supports per-node derivation generate each
    /// node's keys lazily and validate against a streaming summary;
    /// everything else silently falls back to the materialized path.
    /// Never changes results — the per-node streams are byte-identical
    /// to the materialized slices (pinned by digest-identity tests).
    pub stream_input: bool,
    /// Spill cold per-node output buffers to binned shard files under
    /// this directory instead of holding them in RAM
    /// (`--spill` / `NANOSORT_SPILL_DIR`). `None` (the default) keeps
    /// outputs in memory. Never changes results — validation reads the
    /// spilled blocks back in canonical node order.
    pub spill_dir: Option<std::path::PathBuf>,
}

/// Result-extraction hook: runs after quiescence with the engine summary.
pub type Finish = Box<dyn FnOnce(&ScenarioEnv, RunSummary) -> RunReport>;

/// Per-node output sink: one slot per node, written from executor worker
/// threads and read back in canonical node order at finish.
///
/// §Perf: the sort workloads used to funnel every node's final block
/// through one `Mutex<Vec<...>>` — at 65,536 nodes across a threaded
/// executor that is a 100k-acquisition contention burst at the end of the
/// run. The first fix was one `Mutex<Option<T>>` per slot, but a `Mutex`
/// is 16+ bytes of lock word and poison flag — at the hyper tier
/// (2^20 nodes) that is ~16 MiB of pure lock overhead per slot arena, and
/// three arenas per sort run. The current shape stripes the slots into
/// [`SLOT_STRIPES`] *contiguous* `Mutex<Vec<Option<T>>>` blocks: executor
/// shards own contiguous node ranges, so concurrent writers land on
/// different stripes almost always (and merely queue briefly when ranges
/// straddle a stripe boundary), while lock overhead drops from O(nodes)
/// to O(1).
///
/// Writes *overwrite* (last write wins) rather than write-once: under the
/// optimistic executor a node's finishing event can run inside a
/// speculative burst that is later rolled back and re-executed, writing
/// its slot twice. The re-execution is deterministic, so overwriting
/// converges on exactly the sequential value (DESIGN.md §10); a
/// write-once panic here would turn a legal rollback into a crash.
pub struct NodeSlots<T> {
    /// Contiguous stripes of `stripe_len` slots each (ragged tail on the
    /// last stripe).
    stripes: Vec<Mutex<Vec<Option<T>>>>,
    stripe_len: usize,
    len: usize,
}

/// Lock-stripe count for [`NodeSlots`]: enough that contiguous executor
/// shard ranges map to disjoint stripes at any realistic `--threads`,
/// small enough that the lock overhead is constant, not per-node.
const SLOT_STRIPES: usize = 64;

impl<T> NodeSlots<T> {
    pub fn new(nodes: usize) -> Self {
        let stripe_len = nodes.div_ceil(SLOT_STRIPES).max(1);
        let stripes = (0..nodes.div_ceil(stripe_len))
            .map(|s| {
                let lo = s * stripe_len;
                let hi = ((s + 1) * stripe_len).min(nodes);
                Mutex::new((lo..hi).map(|_| None).collect())
            })
            .collect();
        NodeSlots { stripes, stripe_len, len: nodes }
    }

    /// Write node `id`'s output, replacing any previous write (see the
    /// type docs for why replacement is the correct semantics).
    pub fn set(&self, id: usize, value: T) {
        let stripe = &self.stripes[id / self.stripe_len];
        stripe.lock().expect("node output stripe")[id % self.stripe_len] = Some(value);
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Move every slot value out, in canonical node order (an unwritten
    /// slot yields the default — e.g. an empty block for sort outputs,
    /// which the validators then flag). Runs after quiescence, so no
    /// writer exists; no per-node clone.
    pub fn take_vecs(&self) -> Vec<T>
    where
        T: Default,
    {
        let mut out = Vec::with_capacity(self.len);
        self.take_each(|_, v| out.push(v));
        out
    }

    /// Move one slot's value out (the default if unwritten), leaving the
    /// slot empty. The streaming finish paths use this to pair blocks
    /// from two slot arenas (keys + values) node by node.
    pub fn take(&self, id: usize) -> T
    where
        T: Default,
    {
        self.stripes[id / self.stripe_len].lock().expect("node output stripe")
            [id % self.stripe_len]
            .take()
            .unwrap_or_default()
    }

    /// Stream every slot value out in canonical node order without
    /// materializing a `Vec` of all of them — the hyper-tier finish path
    /// feeds each block to a streaming validator or spill sink and drops
    /// it before touching the next. Unwritten slots yield the default,
    /// exactly like [`NodeSlots::take_vecs`].
    pub fn take_each(&self, mut visit: impl FnMut(usize, T))
    where
        T: Default,
    {
        for (s, stripe) in self.stripes.iter().enumerate() {
            let mut guard = stripe.lock().expect("node output stripe");
            for (i, slot) in guard.iter_mut().enumerate() {
                visit(s * self.stripe_len + i, slot.take().unwrap_or_default());
            }
        }
    }
}

/// Everything a workload hands the engine for one run.
pub struct Built<P: Program> {
    /// One program per node (`programs.len()` must equal `env.nodes`).
    pub programs: Vec<P>,
    /// Multicast groups (member lists or id ranges), registered with the
    /// engine in order (index = id).
    pub groups: Vec<Group>,
    /// Extracts the workload's outputs (validation, metrics) into the
    /// unified report once the run completes.
    pub finish: Finish,
}

/// A distributed workload runnable on the simulated nanoPU cluster.
///
/// Implementations own the *what* (input generation, node programs,
/// validation); the [`Scenario`] owns the *where* (fleet size, network,
/// core model, data plane, seed, executor threads).
pub trait Workload {
    /// The node program type this workload runs. `Send` so the fleet can
    /// shard across the parallel backend's worker threads (messages are
    /// `Send` by the [`crate::nanopu::WireMsg`] bound); `Clone` so the
    /// optimistic backend can checkpoint nodes for rollback.
    type Prog: Program + Send + Clone;

    /// Registry/report name (e.g. `"nanosort"`).
    fn name(&self) -> &'static str;

    /// Fleet size used when the scenario does not set one.
    fn default_nodes(&self) -> usize;

    /// Generate inputs and construct one program per node, plus multicast
    /// groups and the result-extraction hook.
    fn build(&self, env: &ScenarioEnv) -> Result<Built<Self::Prog>>;
}

/// Object-safe view of a [`Workload`]; the blanket impl contains the one
/// engine/fabric wiring path every run goes through.
pub trait DynWorkload {
    fn name(&self) -> &'static str;
    fn default_nodes(&self) -> usize;
    fn run_on(&self, env: &ScenarioEnv) -> Result<RunReport>;
}

impl<W: Workload> DynWorkload for W {
    fn name(&self) -> &'static str {
        Workload::name(self)
    }

    fn default_nodes(&self) -> usize {
        Workload::default_nodes(self)
    }

    fn run_on(&self, env: &ScenarioEnv) -> Result<RunReport> {
        // Host-side phase clocks (BENCH breakdown): input generation +
        // program construction, then simulation, then result extraction
        // and validation. Wall-clock only — never part of a digest.
        let t_gen = Instant::now();
        let built = self.build(env)?;
        let input_gen_s = t_gen.elapsed().as_secs_f64();
        anyhow::ensure!(
            built.programs.len() == env.nodes,
            "workload {} built {} programs for {} nodes",
            Workload::name(self),
            built.programs.len(),
            env.nodes
        );
        // Engine/fabric construction is charged to the `sim` phase so
        // the three phases partition the whole run: input_gen + sim +
        // validate ≈ total wall-clock, no unattributed gap.
        let t_sim = Instant::now();
        let fabric = Fabric::new(Topology::paper(env.nodes), env.net.clone(), env.seed);
        let mut engine = Engine::new(built.programs, fabric, env.core.clone(), env.seed);
        for members in built.groups {
            engine.add_group(members);
        }
        // Straggler perturbation: a seeded subset of cores runs its
        // compute slower (off by default — the selection stream is only
        // created when the knob is on). A solo scenario run is job 0 of
        // the per-job-salted selection ([`crate::perturb::StragglerConfig::picks`]).
        let st = env.perturb.stragglers;
        for node in st.picks(env.seed, 0, env.nodes) {
            engine.slow_down(node, st.factor);
        }
        engine.set_pool(env.pool.clone());
        let summary = engine.run_exec(
            env.exec,
            env.threads,
            env.window_batch,
            env.force_rollback_every,
        );
        let sim_s = t_sim.elapsed().as_secs_f64();
        let t_val = Instant::now();
        let mut report = (built.finish)(env, summary);
        report.phases = PhaseWallClock { input_gen_s, sim_s, validate_s: t_val.elapsed().as_secs_f64() };
        Ok(report)
    }
}

/// Which data plane a scenario runs on.
enum ComputeSel {
    Choice(ComputeChoice),
    Instance(Arc<dyn LocalCompute>),
}

/// Builder for one simulated run:
/// `Scenario::new(workload).nodes(n).net(..).seed(s).run()`.
///
/// # Examples
///
/// A seeded end-to-end run (this executes in the doctest suite):
///
/// ```
/// use nanosort::algo::mergemin::MergeMin;
/// use nanosort::scenario::Scenario;
/// use nanosort::sim::Time;
///
/// let report = Scenario::new(MergeMin { values_per_core: 16, incast: 4 })
///     .nodes(8)
///     .seed(7)
///     .run()
///     .unwrap();
/// assert!(report.validation.ok());
/// assert!(report.runtime() > Time::ZERO);
/// assert_eq!(report.metric_u64("found_min"), report.metric_u64("true_min"));
/// ```
pub struct Scenario {
    workload: Box<dyn DynWorkload>,
    nodes: Option<usize>,
    net: NetConfig,
    core: CoreModel,
    compute: ComputeSel,
    seed: u64,
    perturb: Perturbations,
    threads: usize,
    exec: ExecKind,
    window_batch: Option<usize>,
    force_rollback_every: Option<u64>,
    pool: Option<Arc<WorkerPool>>,
    stream_input: bool,
    spill_dir: Option<std::path::PathBuf>,
}

impl Scenario {
    pub fn new(workload: impl Workload + 'static) -> Self {
        Scenario::from_dyn(Box::new(workload))
    }

    /// Registry path: the workload arrives type-erased.
    pub fn from_dyn(workload: Box<dyn DynWorkload>) -> Self {
        Scenario {
            workload,
            nodes: None,
            net: NetConfig::default(),
            core: CoreModel::default(),
            compute: ComputeSel::Choice(ComputeChoice::default()),
            seed: 1,
            perturb: Perturbations::default(),
            threads: 1,
            exec: ExecKind::default(),
            window_batch: None,
            force_rollback_every: None,
            pool: None,
            stream_input: false,
            spill_dir: None,
        }
    }

    /// Fleet size; defaults to [`Workload::default_nodes`].
    pub fn nodes(mut self, n: usize) -> Self {
        self.nodes = Some(n);
        self
    }

    pub fn net(mut self, net: NetConfig) -> Self {
        self.net = net;
        self
    }

    pub fn core(mut self, core: CoreModel) -> Self {
        self.core = core;
        self
    }

    /// Select the data plane by kind (built lazily in [`Scenario::run`]).
    pub fn compute(mut self, choice: ComputeChoice) -> Self {
        self.compute = ComputeSel::Choice(choice);
        self
    }

    /// Use an already-constructed data plane (shared across runs).
    pub fn compute_with(mut self, plane: Arc<dyn LocalCompute>) -> Self {
        self.compute = ComputeSel::Instance(plane);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Host worker threads simulating the fleet: `1` (default) runs the
    /// sequential reference backend, `0` uses every available core, any
    /// other value shards the fleet across that many threads. Results
    /// are byte-identical at every setting ([`crate::sim::exec`]).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Execution backend ([`ExecKind::Par`] by default; `--exec` on the
    /// CLI). Results are byte-identical at every setting.
    pub fn exec(mut self, exec: ExecKind) -> Self {
        self.exec = exec;
        self
    }

    /// Override the window-coalescing factor (instead of the
    /// `NANOSORT_WINDOW_BATCH` environment knob). Results are
    /// byte-identical at every value.
    pub fn window_batch(mut self, k: usize) -> Self {
        self.window_batch = Some(k);
        self
    }

    /// Test-only: force the optimistic backend to roll back every `n`-th
    /// speculative burst (exercises the recovery path; results are
    /// byte-identical with the hook on or off).
    pub fn force_rollback_every(mut self, n: u64) -> Self {
        self.force_rollback_every = Some(n);
        self
    }

    /// Share a host worker pool across runs (the service layer hands
    /// every job the same budget). Default: a pool sized to
    /// [`Scenario::threads`], built per run.
    pub fn pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Generate inputs per node instead of materializing the full key
    /// array ([`ScenarioEnv::stream_input`]; also enabled by the
    /// `NANOSORT_STREAM_INPUT` environment knob). Results are
    /// byte-identical with streaming on or off.
    pub fn stream_input(mut self) -> Self {
        self.stream_input = true;
        self
    }

    /// Spill cold per-node output buffers under `dir`
    /// ([`ScenarioEnv::spill_dir`]; also enabled by the
    /// `NANOSORT_SPILL_DIR` environment knob). Results are byte-identical
    /// with spill on or off.
    pub fn spill_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.spill_dir = Some(dir.into());
        self
    }

    /// Set the full perturbation block (input distribution + stragglers).
    pub fn perturb(mut self, perturb: Perturbations) -> Self {
        self.perturb = perturb;
        self
    }

    /// Convenience: set only the input [`KeyDistribution`].
    pub fn dist(mut self, dist: KeyDistribution) -> Self {
        self.perturb.dist = dist;
        self
    }

    /// Convenience: mark `count` seeded-random cores as stragglers, each
    /// `factor`× slower.
    pub fn stragglers(mut self, count: usize, factor: u32) -> Self {
        self.perturb.stragglers = crate::perturb::StragglerConfig { count, factor };
        self
    }

    /// Build the environment, run to quiescence, extract the report.
    pub fn run(self) -> Result<RunReport> {
        let nodes = self.nodes.unwrap_or_else(|| self.workload.default_nodes());
        // Fabric flights store node ids at u32 width (§Scale, DESIGN.md
        // §11); every fleet is sized through this one path.
        anyhow::ensure!(
            nodes <= u32::MAX as usize,
            "fleet of {nodes} nodes exceeds the u32 node-id width"
        );
        // One pool = one `--threads` budget: a plane built here shares it
        // with the executor, so shard workers and kernel tiles can never
        // oversubscribe the host ([`crate::pool`]).
        let pool = self.pool.clone().unwrap_or_else(|| {
            Arc::new(WorkerPool::new(crate::sim::exec::resolve_threads(self.threads)))
        });
        let compute = match self.compute {
            ComputeSel::Choice(choice) => choice.build_pooled(&pool)?,
            ComputeSel::Instance(plane) => plane,
        };
        // The XLA data plane drives a single-threaded PJRT client; the
        // sharded executor would call it from several worker threads.
        anyhow::ensure!(
            self.threads == 1 || compute.name() != "xla",
            "the XLA data plane is single-threaded; run it with --threads 1 \
             (the executor backends are byte-identical, so native --threads N \
             and xla --threads 1 still cross-check)"
        );
        // Environment knobs fill in what the builder left unset; the
        // builder always wins so programmatic callers are immune to a
        // stray variable. Both knobs are digest-invisible by contract.
        let stream_input = self.stream_input
            || std::env::var("NANOSORT_STREAM_INPUT").is_ok_and(|v| v != "0" && !v.is_empty());
        let spill_dir = self.spill_dir.or_else(|| {
            std::env::var_os("NANOSORT_SPILL_DIR")
                .filter(|v| !v.is_empty())
                .map(std::path::PathBuf::from)
        });
        let env = ScenarioEnv {
            nodes,
            net: self.net,
            core: self.core,
            compute,
            seed: self.seed,
            perturb: self.perturb,
            threads: self.threads,
            exec: self.exec,
            window_batch: self.window_batch,
            force_rollback_every: self.force_rollback_every,
            pool,
            stream_input,
            spill_dir,
        };
        self.workload.run_on(&env)
    }
}

/// Unified validation outcome. Sort workloads carry the full
/// [`ValidationReport`]; scalar workloads carry a pass/fail check with a
/// human-readable detail line.
#[derive(Debug, Clone)]
pub struct Validation {
    pub passed: bool,
    pub detail: String,
    /// Present for workloads validated as distributed sorts.
    pub sort: Option<ValidationReport>,
}

impl Validation {
    pub fn check(passed: bool, detail: impl Into<String>) -> Self {
        Validation { passed, detail: detail.into(), sort: None }
    }

    pub fn from_sort(report: ValidationReport) -> Self {
        Validation {
            passed: report.ok(),
            detail: format!(
                "sorted={} permutation={} values={}",
                report.globally_sorted, report.is_permutation, report.values_intact
            ),
            sort: Some(report),
        }
    }

    pub fn ok(&self) -> bool {
        self.passed
    }
}

/// Typed workload-specific report value.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MetricValue {
    U64(u64),
    F64(f64),
    Bool(bool),
}

impl std::fmt::Display for MetricValue {
    fn fmt(&self, w: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MetricValue::U64(v) => write!(w, "{v}"),
            MetricValue::F64(v) => write!(w, "{}", f(*v)),
            MetricValue::Bool(v) => write!(w, "{v}"),
        }
    }
}

/// Named workload-specific metric (e.g. `skew`, `found_min`).
#[derive(Debug, Clone)]
pub struct Metric {
    pub name: &'static str,
    pub value: MetricValue,
}

/// Per-stage busy/idle summary across nodes (Fig 16's breakdown,
/// generalized to every workload; stage = recursion level for NanoSort).
#[derive(Debug, Clone)]
pub struct StageBreakdown {
    pub stage: usize,
    pub mean_busy_us: f64,
    pub mean_idle_us: f64,
    pub max_busy_us: f64,
    pub max_idle_us: f64,
}

/// Summarize the engine's per-node stage accounting: one row per stage,
/// from 0 through the highest stage any node touched.
pub fn stage_breakdown(summary: &RunSummary) -> Vec<StageBreakdown> {
    let max_stage = (0..MAX_STAGES)
        .rev()
        .find(|&s| {
            summary
                .node_stats
                .iter()
                .any(|n| n.busy[s] > Time::ZERO || n.idle[s] > Time::ZERO)
        })
        .unwrap_or(0);
    let n = summary.node_stats.len().max(1) as f64;
    (0..=max_stage)
        .map(|stage| {
            let mut row = StageBreakdown {
                stage,
                mean_busy_us: 0.0,
                mean_idle_us: 0.0,
                max_busy_us: 0.0,
                max_idle_us: 0.0,
            };
            for s in &summary.node_stats {
                let busy = s.busy[stage].as_us_f64();
                let idle = s.idle[stage].as_us_f64();
                row.mean_busy_us += busy;
                row.mean_idle_us += idle;
                row.max_busy_us = row.max_busy_us.max(busy);
                row.max_idle_us = row.max_idle_us.max(idle);
            }
            row.mean_busy_us /= n;
            row.mean_idle_us /= n;
            row
        })
        .collect()
}

/// Host wall-clock spent in each phase of one scenario run (seconds).
/// Pure measurement: excluded from digests and [`RunReport::render`]
/// (both must be deterministic); surfaced through `BENCH_*.json`.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseWallClock {
    /// Input generation + per-node program construction ([`Workload::build`]).
    pub input_gen_s: f64,
    /// Fabric/engine construction plus the discrete-event simulation
    /// itself (executor run to quiescence).
    pub sim_s: f64,
    /// Result extraction + validation (the workload's finish hook).
    pub validate_s: f64,
}

/// Unified outcome of one scenario run, identical in shape across all
/// workloads: makespan + net stats (in `summary`), per-stage busy/idle
/// breakdown, validation, and named workload metrics.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub workload: &'static str,
    pub nodes: usize,
    pub seed: u64,
    /// Data-plane name (`native` / `radix` / `xla`).
    pub compute: &'static str,
    pub summary: RunSummary,
    pub validation: Validation,
    pub stages: Vec<StageBreakdown>,
    pub metrics: Vec<Metric>,
    /// Host wall-clock per phase (filled by the scenario runner).
    pub phases: PhaseWallClock,
}

impl RunReport {
    /// Fill the common fields; workloads chain [`RunReport::with_metric`].
    pub fn new(
        workload: &'static str,
        env: &ScenarioEnv,
        summary: RunSummary,
        validation: Validation,
    ) -> Self {
        let stages = stage_breakdown(&summary);
        RunReport {
            workload,
            nodes: env.nodes,
            seed: env.seed,
            compute: env.compute.name(),
            summary,
            validation,
            stages,
            metrics: Vec::new(),
            phases: PhaseWallClock::default(),
        }
    }

    pub fn with_metric(mut self, name: &'static str, value: MetricValue) -> Self {
        self.metrics.push(Metric { name, value });
        self
    }

    /// Job completion time (latest busy-until across nodes).
    pub fn runtime(&self) -> Time {
        self.summary.makespan
    }

    pub fn metric(&self, name: &str) -> Option<MetricValue> {
        self.metrics.iter().find(|m| m.name == name).map(|m| m.value)
    }

    pub fn metric_u64(&self, name: &str) -> Option<u64> {
        match self.metric(name) {
            Some(MetricValue::U64(v)) => Some(v),
            _ => None,
        }
    }

    pub fn metric_f64(&self, name: &str) -> Option<f64> {
        match self.metric(name) {
            Some(MetricValue::F64(v)) => Some(v),
            Some(MetricValue::U64(v)) => Some(v as f64),
            _ => None,
        }
    }

    /// Deterministic text rendering (the CLI's `repro run` output; also the
    /// byte-for-byte artifact the determinism tests compare).
    pub fn render(&self) -> String {
        let mut out = format!(
            "{}: nodes={} seed={} compute={}\n",
            self.workload, self.nodes, self.seed, self.compute
        );
        out += &format!(
            "runtime = {:.2} µs ({:.0} ns) | valid = {} | msgs = {} | util = {:.1}%\n",
            self.summary.makespan.as_us_f64(),
            self.summary.makespan.as_ns_f64(),
            self.validation.passed,
            self.summary.net.msgs_sent,
            100.0 * self.summary.mean_utilization()
        );
        if !self.validation.detail.is_empty() {
            out += &format!("validation: {}\n", self.validation.detail);
        }
        for m in &self.metrics {
            out += &format!("{} = {}\n", m.name, m.value);
        }
        for l in &self.stages {
            out += &format!(
                "  stage {}: busy mean {} µs max {} µs | idle mean {} µs max {} µs\n",
                l.stage,
                f(l.mean_busy_us),
                f(l.max_busy_us),
                f(l.mean_idle_us),
                f(l.max_idle_us)
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::mergemin::MergeMin;
    use crate::algo::nanosort::NanoSort;

    #[test]
    fn scenario_defaults_run_clean() {
        let r = Scenario::new(MergeMin::default()).run().unwrap();
        assert_eq!(r.workload, "mergemin");
        assert_eq!(r.nodes, 64);
        assert!(r.validation.ok(), "{}", r.validation.detail);
        assert!(r.runtime() > Time::ZERO);
        assert_eq!(r.compute, "radix", "the radix plane is the default");
    }

    #[test]
    fn scenario_knobs_apply() {
        let net = NetConfig { multicast: false, ..NetConfig::default() };
        let r = Scenario::new(NanoSort { keys_per_node: 8, ..Default::default() })
            .nodes(16)
            .net(net)
            .seed(9)
            .run()
            .unwrap();
        assert_eq!(r.nodes, 16);
        assert_eq!(r.seed, 9);
        assert!(r.validation.ok());
        assert_eq!(r.summary.net.multicasts, 0);
    }

    #[test]
    fn bad_fleet_size_is_an_error_not_a_panic() {
        // 17 is not buckets^r for buckets=16.
        let err = Scenario::new(NanoSort::default()).nodes(17).run();
        assert!(err.is_err());
    }

    #[test]
    fn report_metrics_typed_accessors() {
        let r = Scenario::new(MergeMin::default()).nodes(8).run().unwrap();
        assert!(r.metric_u64("found_min").is_some());
        assert_eq!(r.metric_u64("found_min"), r.metric_u64("true_min"));
        assert!(r.metric("nope").is_none());
        assert!(r.metric_f64("found_min").is_some(), "u64 metrics widen to f64");
    }

    #[test]
    fn render_contains_the_load_bearing_lines() {
        let r = Scenario::new(MergeMin::default()).nodes(8).run().unwrap();
        let s = r.render();
        assert!(s.contains("mergemin: nodes=8"));
        assert!(s.contains("runtime = "));
        assert!(s.contains("valid = true"));
        assert!(s.contains("found_min = "));
        assert!(s.contains("stage 0:"));
    }

    #[test]
    fn stage_breakdown_covers_active_stages_only() {
        // MergeMin never calls set_stage: exactly one stage row.
        let r = Scenario::new(MergeMin::default()).nodes(8).run().unwrap();
        assert_eq!(r.stages.len(), 1);
        // NanoSort at 256 = 16^2 runs stages 0, 1, and the final stage 2.
        let r = Scenario::new(NanoSort::default()).nodes(256).run().unwrap();
        assert_eq!(r.stages.len(), 3);
    }
}
