//! Static workload registry: name → spec (typed parameter descriptors +
//! constructor), so `repro run <name> --param value` is one data-driven
//! code path and cross-workload tests can enumerate every scenario.
//!
//! Adding a workload = one [`Workload`] impl + one [`WorkloadSpec`] row
//! here; the CLI, help text, and integration tests pick it up from data.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::algo::mergemin::MergeMin;
use crate::algo::millisort::MilliSort;
use crate::algo::nanosort::{NanoSort, PivotMode};
use crate::algo::setalgebra::SetAlgebra;
use crate::coordinator::Args;

use super::DynWorkload;

/// How a parameter parses from the CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    /// `--name <n>` — unsigned integer.
    U64,
    /// `--name` — boolean presence flag.
    Flag,
}

/// Where a parameter's value comes from when the CLI omits it.
#[derive(Debug, Clone, Copy)]
pub enum ParamDefault {
    U64(u64),
    /// Follows the resolved value of an earlier parameter in the spec
    /// (e.g. nanosort's `--incast` defaults to `--buckets`).
    FromParam(&'static str),
    /// Flags default to off.
    False,
}

/// One typed parameter descriptor.
pub struct ParamSpec {
    pub name: &'static str,
    pub kind: ParamKind,
    pub default: ParamDefault,
    pub help: &'static str,
}

/// A parsed parameter value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamValue {
    U64(u64),
    Flag(bool),
}

/// Resolved parameter values for one workload invocation.
#[derive(Debug, Default, Clone)]
pub struct ParamMap(HashMap<&'static str, ParamValue>);

impl ParamMap {
    pub fn u64(&self, name: &str) -> Result<u64> {
        match self.0.get(name) {
            Some(ParamValue::U64(v)) => Ok(*v),
            _ => bail!("missing numeric parameter {name:?}"),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        matches!(self.0.get(name), Some(ParamValue::Flag(true)))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.0.contains_key(name)
    }

    fn set(&mut self, name: &'static str, value: ParamValue) {
        self.0.insert(name, value);
    }
}

/// One registry row: everything the CLI and the tests need to construct
/// and run a workload from strings.
pub struct WorkloadSpec {
    pub name: &'static str,
    pub summary: &'static str,
    /// The parameter that sets the fleet size (`--nodes` for nanosort,
    /// `--cores` elsewhere) — routed to [`super::Scenario::nodes`].
    pub nodes_param: ParamSpec,
    pub params: &'static [ParamSpec],
    /// Construct the workload from resolved parameters.
    pub build: fn(&ParamMap) -> Result<Box<dyn DynWorkload>>,
    /// CI-small parameter overrides for smoke/integration runs.
    pub smoke: &'static [(&'static str, u64)],
}

impl WorkloadSpec {
    /// All parameters, fleet-size first (the defaulting/resolution order).
    pub fn all_params(&self) -> impl Iterator<Item = &ParamSpec> {
        std::iter::once(&self.nodes_param).chain(self.params.iter())
    }
}

/// Every workload this build can run, in paper order.
pub static WORKLOADS: &[WorkloadSpec] = &[
    WorkloadSpec {
        name: "nanosort",
        summary: "the paper's recursive pivot/shuffle sort (§4/§5)",
        nodes_param: ParamSpec {
            name: "nodes",
            kind: ParamKind::U64,
            default: ParamDefault::U64(4096),
            help: "cores; must equal buckets^r",
        },
        params: &[
            ParamSpec {
                name: "kpn",
                kind: ParamKind::U64,
                default: ParamDefault::U64(16),
                help: "keys pre-loaded per core",
            },
            ParamSpec {
                name: "buckets",
                kind: ParamKind::U64,
                default: ParamDefault::U64(16),
                help: "buckets per recursion level",
            },
            ParamSpec {
                name: "incast",
                kind: ParamKind::U64,
                default: ParamDefault::FromParam("buckets"),
                help: "median/count-tree incast",
            },
            ParamSpec {
                name: "values",
                kind: ParamKind::Flag,
                default: ParamDefault::False,
                help: "run the GraySort value-redistribution phase",
            },
            ParamSpec {
                name: "naive-pivots",
                kind: ParamKind::Flag,
                default: ParamDefault::False,
                help: "ablation: naive pivot proposals instead of PivotSelect",
            },
        ],
        build: build_nanosort,
        smoke: &[("nodes", 16), ("kpn", 8), ("buckets", 4)],
    },
    WorkloadSpec {
        name: "millisort",
        summary: "the MilliSort baseline re-hosted on the nanoPU substrate (§6.2.2)",
        nodes_param: ParamSpec {
            name: "cores",
            kind: ParamKind::U64,
            default: ParamDefault::U64(64),
            help: "cores",
        },
        params: &[
            ParamSpec {
                name: "keys",
                kind: ParamKind::U64,
                default: ParamDefault::U64(4096),
                help: "total keys; must divide evenly across cores",
            },
            ParamSpec {
                name: "rf",
                kind: ParamKind::U64,
                default: ParamDefault::U64(4),
                help: "gather/scatter reduction factor (Fig 10's knob)",
            },
        ],
        build: build_millisort,
        smoke: &[("cores", 8), ("keys", 128)],
    },
    WorkloadSpec {
        name: "mergemin",
        summary: "global-minimum merge tree, the §3.1 design-space probe",
        nodes_param: ParamSpec {
            name: "cores",
            kind: ParamKind::U64,
            default: ParamDefault::U64(64),
            help: "cores",
        },
        params: &[
            ParamSpec {
                name: "vpc",
                kind: ParamKind::U64,
                default: ParamDefault::U64(128),
                help: "values per core",
            },
            ParamSpec {
                name: "incast",
                kind: ParamKind::U64,
                default: ParamDefault::U64(8),
                help: "merge-tree incast (1 = chain)",
            },
        ],
        build: build_mergemin,
        smoke: &[("cores", 8), ("vpc", 16), ("incast", 4)],
    },
    WorkloadSpec {
        name: "setalgebra",
        summary: "distributed posting-list intersection (§3.2 web search)",
        nodes_param: ParamSpec {
            name: "cores",
            kind: ParamKind::U64,
            default: ParamDefault::U64(64),
            help: "cores",
        },
        params: &[
            ParamSpec {
                name: "lists",
                kind: ParamKind::U64,
                default: ParamDefault::U64(4),
                help: "posting lists per query (q-way intersection)",
            },
            ParamSpec {
                name: "incast",
                kind: ParamKind::U64,
                default: ParamDefault::U64(8),
                help: "reduce-tree incast",
            },
            ParamSpec {
                name: "ids",
                kind: ParamKind::U64,
                default: ParamDefault::U64(128),
                help: "doc ids per list per core",
            },
        ],
        build: build_setalgebra,
        smoke: &[("cores", 8), ("lists", 3), ("incast", 4), ("ids", 32)],
    },
];

fn build_nanosort(p: &ParamMap) -> Result<Box<dyn DynWorkload>> {
    Ok(Box::new(NanoSort {
        keys_per_node: p.u64("kpn")? as usize,
        buckets: p.u64("buckets")? as usize,
        median_incast: p.u64("incast")? as usize,
        shuffle_values: p.flag("values"),
        pivot_mode: if p.flag("naive-pivots") { PivotMode::Naive } else { PivotMode::Paper },
    }))
}

fn build_millisort(p: &ParamMap) -> Result<Box<dyn DynWorkload>> {
    Ok(Box::new(MilliSort {
        total_keys: p.u64("keys")? as usize,
        reduction_factor: p.u64("rf")? as usize,
        ..Default::default()
    }))
}

fn build_mergemin(p: &ParamMap) -> Result<Box<dyn DynWorkload>> {
    Ok(Box::new(MergeMin {
        values_per_core: p.u64("vpc")? as usize,
        incast: p.u64("incast")? as usize,
    }))
}

fn build_setalgebra(p: &ParamMap) -> Result<Box<dyn DynWorkload>> {
    Ok(Box::new(SetAlgebra {
        lists: p.u64("lists")? as usize,
        ids_per_core: p.u64("ids")? as usize,
        incast: p.u64("incast")? as usize,
        ..Default::default()
    }))
}

/// Look a workload up by name.
pub fn find(name: &str) -> Result<&'static WorkloadSpec> {
    WORKLOADS.iter().find(|w| w.name == name).ok_or_else(|| {
        anyhow!("unknown workload {name:?} (known: {})", names().join("|"))
    })
}

/// All registered workload names, in registry order.
pub fn names() -> Vec<&'static str> {
    WORKLOADS.iter().map(|w| w.name).collect()
}

/// Consume this workload's parameters from CLI `args` and resolve
/// defaults. Unrecognized arguments are left behind for the caller's
/// unconsumed-argument check; malformed values are errors.
pub fn parse_args(spec: &WorkloadSpec, args: &mut Args) -> Result<ParamMap> {
    let mut map = ParamMap::default();
    for p in spec.all_params() {
        match p.kind {
            ParamKind::U64 => {
                if let Some(v) = args.num_checked::<u64>(p.name)? {
                    map.set(p.name, ParamValue::U64(v));
                }
            }
            ParamKind::Flag => {
                if args.flag(p.name) {
                    map.set(p.name, ParamValue::Flag(true));
                }
            }
        }
    }
    resolve_defaults(spec, map)
}

/// Build a [`ParamMap`] from `(name, value)` pairs (tests, smoke and
/// conformance-tier runs), validating names against the spec and
/// resolving defaults. Flag parameters take 0/1 (any non-zero = set).
pub fn params_from_pairs(
    spec: &WorkloadSpec,
    pairs: &[(&'static str, u64)],
) -> Result<ParamMap> {
    let mut map = ParamMap::default();
    for (name, v) in pairs {
        let p = spec
            .all_params()
            .find(|p| p.name == *name)
            .ok_or_else(|| anyhow!("workload {} has no parameter {name:?}", spec.name))?;
        match p.kind {
            ParamKind::U64 => map.set(p.name, ParamValue::U64(*v)),
            ParamKind::Flag => map.set(p.name, ParamValue::Flag(*v != 0)),
        }
    }
    resolve_defaults(spec, map)
}

fn resolve_defaults(spec: &WorkloadSpec, mut map: ParamMap) -> Result<ParamMap> {
    for p in spec.all_params() {
        if map.contains(p.name) {
            continue;
        }
        let v = match p.default {
            ParamDefault::U64(v) => ParamValue::U64(v),
            ParamDefault::False => ParamValue::Flag(false),
            ParamDefault::FromParam(other) => ParamValue::U64(
                map.u64(other).with_context(|| {
                    format!("default for --{} follows --{other}", p.name)
                })?,
            ),
        };
        map.set(p.name, v);
    }
    Ok(map)
}

/// One usage line per workload, generated from the descriptors (keeps the
/// CLI help honest: a new registry row shows up here automatically).
/// `repro run <name> --help` prints the full [`describe`] listing.
pub fn cli_help() -> String {
    let mut out = String::new();
    for w in WORKLOADS {
        let mut line = format!("  repro run {:<11}", w.name);
        line += &format!("[--{} N]", w.nodes_param.name);
        for p in w.params {
            match p.kind {
                ParamKind::U64 => line += &format!(" [--{} N]", p.name),
                ParamKind::Flag => line += &format!(" [--{}]", p.name),
            }
        }
        line += " [--skew D] [--no-multicast] [--compute P] [--seed N] [--threads N]";
        out += &line;
        out.push('\n');
    }
    out += "  (`repro run <name> --help` prints every parameter descriptor)\n";
    out
}

/// Full parameter-descriptor listing for one workload (the
/// `repro run <name> --help` output): every typed registry descriptor
/// with its help text and default, plus the environment knobs shared by
/// all workloads.
pub fn describe(spec: &WorkloadSpec) -> String {
    let mut out = format!("{} — {}\n\nworkload parameters:\n", spec.name, spec.summary);
    for p in spec.all_params() {
        let arg = match p.kind {
            ParamKind::U64 => format!("--{} <N>", p.name),
            ParamKind::Flag => format!("--{}", p.name),
        };
        let default = match p.default {
            ParamDefault::U64(v) => format!("default {v}"),
            ParamDefault::FromParam(other) => format!("default follows --{other}"),
            ParamDefault::False => "flag, default off".to_string(),
        };
        out += &format!("  {arg:<22} {} ({default})\n", p.help);
    }
    out += "\nenvironment knobs (every workload):\n";
    for (name, help) in crate::perturb::ENV_AXES {
        out += &format!("  {:<22} {help}\n", format!("--{name} <V>"));
    }
    out += "  --no-multicast         degrade group sends to unicast loops (§6.2.3)\n";
    out += "  --compute <P>          data plane: native|radix|xla (default radix; \
            digests are plane-invariant)\n";
    out += "  --xla                  shorthand for --compute xla\n";
    out += "  --seed <N>             master seed (default 1)\n";
    out += "  --threads <N>          executor worker threads (1 = sequential, 0 = all \
            cores; identical results)\n";
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::from_vec(s.split_whitespace().map(str::to_string).collect())
    }

    #[test]
    fn find_resolves_all_registered_names() {
        for name in ["nanosort", "millisort", "mergemin", "setalgebra"] {
            assert!(find(name).is_ok(), "{name}");
        }
    }

    #[test]
    fn find_unknown_lists_known_names() {
        let err = find("bogosort").unwrap_err().to_string();
        assert!(err.contains("unknown workload"), "{err}");
        assert!(err.contains("nanosort") && err.contains("setalgebra"), "{err}");
    }

    #[test]
    fn defaults_resolve_without_cli_args() {
        let spec = find("nanosort").unwrap();
        let p = parse_args(spec, &mut args("")).unwrap();
        assert_eq!(p.u64("nodes").unwrap(), 4096);
        assert_eq!(p.u64("kpn").unwrap(), 16);
        assert_eq!(p.u64("incast").unwrap(), 16);
        assert!(!p.flag("values"));
    }

    #[test]
    fn incast_default_follows_buckets() {
        let spec = find("nanosort").unwrap();
        let p = parse_args(spec, &mut args("--buckets 4")).unwrap();
        assert_eq!(p.u64("incast").unwrap(), 4, "FromParam default");
        let p = parse_args(spec, &mut args("--buckets 4 --incast 2")).unwrap();
        assert_eq!(p.u64("incast").unwrap(), 2, "explicit value wins");
    }

    #[test]
    fn numeric_garbage_is_an_error() {
        let spec = find("mergemin").unwrap();
        let err = parse_args(spec, &mut args("--vpc banana")).unwrap_err();
        assert!(err.to_string().contains("--vpc"), "{err}");
    }

    #[test]
    fn trailing_valueless_param_is_an_error() {
        let spec = find("mergemin").unwrap();
        assert!(parse_args(spec, &mut args("--cores")).is_err());
    }

    #[test]
    fn unknown_args_left_for_the_caller() {
        let spec = find("mergemin").unwrap();
        let mut a = args("--vpc 32 --warp-drive 9");
        parse_args(spec, &mut a).unwrap();
        assert_eq!(a.rest(), ["--warp-drive", "9"]);
    }

    #[test]
    fn pairs_reject_unknown_params_and_accept_flags() {
        let spec = find("nanosort").unwrap();
        assert!(params_from_pairs(spec, &[("nope", 1)]).is_err());
        let p = params_from_pairs(spec, &[("nodes", 16), ("buckets", 4)]).unwrap();
        assert_eq!(p.u64("incast").unwrap(), 4);
        assert!(!p.flag("values"), "flags default off");
        // Flags take 0/1 in pair form (conformance tiers use this).
        let p = params_from_pairs(spec, &[("values", 1)]).unwrap();
        assert!(p.flag("values"));
        let p = params_from_pairs(spec, &[("values", 0)]).unwrap();
        assert!(!p.flag("values"));
    }

    #[test]
    fn every_smoke_spec_builds() {
        for spec in WORKLOADS {
            let p = params_from_pairs(spec, spec.smoke).unwrap();
            (spec.build)(&p).unwrap_or_else(|e| panic!("{}: {e:#}", spec.name));
        }
    }

    #[test]
    fn help_mentions_every_workload_and_its_fleet_flag() {
        let h = cli_help();
        for w in WORKLOADS {
            assert!(h.contains(w.name), "{}", w.name);
            assert!(h.contains(&format!("[--{} N]", w.nodes_param.name)));
        }
        assert!(h.contains("[--values]"), "flags render without N");
        assert!(h.contains("[--skew D]"), "perturbation knob surfaced");
        assert!(h.contains("[--compute P]"), "data-plane knob surfaced");
        assert!(h.contains("[--threads N]"), "executor knob surfaced");
        assert!(h.contains("--help"), "points at the descriptor listing");
    }

    #[test]
    fn describe_prints_every_descriptor_with_help_and_default() {
        for spec in WORKLOADS {
            let d = describe(spec);
            assert!(d.contains(spec.summary), "{}", spec.name);
            for p in spec.all_params() {
                assert!(d.contains(&format!("--{}", p.name)), "{}: --{}", spec.name, p.name);
                assert!(d.contains(p.help), "{}: help for --{}", spec.name, p.name);
            }
        }
        // Typed defaults render, including the FromParam chain.
        let d = describe(find("nanosort").unwrap());
        assert!(d.contains("default 4096"), "{d}");
        assert!(d.contains("default follows --buckets"), "{d}");
        assert!(d.contains("flag, default off"), "{d}");
        // Environment knobs are listed for every workload.
        for (name, _) in crate::perturb::ENV_AXES {
            assert!(d.contains(&format!("--{name}")), "env knob --{name}");
        }
        assert!(d.contains("--no-multicast") && d.contains("--xla") && d.contains("--seed"));
        assert!(d.contains("--threads"), "executor knob in the descriptor listing");
    }
}
