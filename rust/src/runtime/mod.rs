//! Runtime layer: load + execute AOT-compiled XLA artifacts via PJRT.
//!
//! `XlaEngine` (engine.rs) owns the PJRT CPU client and an executable cache;
//! `Manifest` (manifest.rs) is the shape contract written by `aot.py`.
//! This is the only module that touches the `xla` crate.

mod engine;
mod manifest;

pub use engine::{LoadedArtifact, MixedOutput, XlaEngine};
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};

use std::path::PathBuf;

/// `$NANOSORT_ARTIFACTS` if set, else `<workspace>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("NANOSORT_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // CARGO_MANIFEST_DIR points at the workspace root (Cargo.toml lives there).
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
