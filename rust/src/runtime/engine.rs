//! `XlaEngine`: the PJRT side of the three-layer stack.
//!
//! Loads HLO-text artifacts produced by `python/compile/aot.py`, compiles
//! them once on the PJRT CPU client, and executes them from the request
//! path. Python never runs here — the artifacts are self-contained.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax >= 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! # Build gating
//!
//! The `xla` crate (PJRT bindings) is not vendored in the default build,
//! so the real engine is compiled only under the `pjrt` cargo feature —
//! and that feature is a **re-vendoring seam**, not a working toggle:
//! enabling it also requires adding the `xla` crate to Cargo.toml on a
//! toolchain that has the bindings (see the `[features]` comment there).
//! Without it this module provides an API-identical stub whose
//! constructor fails with a clear error. Every caller already treats
//! "XLA unavailable" as a skip/fallback (tests skip, `--xla` runs fall
//! back or error out cleanly), so the native data plane — the oracle the
//! XLA plane is cross-checked against — carries the default build. The
//! stub is `Send + Sync` vacuously (its engine is never constructible);
//! the *real* PJRT client is confined to one thread, which is why the
//! scenario layer refuses the XLA plane with a threaded executor.

use anyhow::Result;

use super::manifest::{ArtifactSpec, Manifest};

/// Tuple-output element with its native dtype.
pub enum MixedOutput {
    U64(Vec<u64>),
    I32(Vec<i32>),
}

impl MixedOutput {
    pub fn as_u64(&self) -> &[u64] {
        match self {
            MixedOutput::U64(v) => v,
            _ => panic!("expected u64 output"),
        }
    }
    pub fn as_i32(&self) -> &[i32] {
        match self {
            MixedOutput::I32(v) => v,
            _ => panic!("expected i32 output"),
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{LoadedArtifact, XlaEngine};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::convert::Infallible;
    use std::path::Path;
    use std::sync::Arc;

    use anyhow::{bail, Result};

    use super::{ArtifactSpec, Manifest, MixedOutput};

    /// A compiled entry point plus its shape contract (stub: never
    /// constructed — the engine constructor fails first).
    pub struct LoadedArtifact {
        pub spec: ArtifactSpec,
        never: Infallible,
    }

    impl LoadedArtifact {
        /// Execute on u64 inputs; returns the flattened u64 output tensors.
        pub fn run_u64(&self, _inputs: &[&[u64]]) -> Result<Vec<Vec<u64>>> {
            match self.never {}
        }

        /// Execute and return outputs in their native dtypes.
        pub fn run_mixed(&self, _inputs: &[&[u64]]) -> Result<Vec<MixedOutput>> {
            match self.never {}
        }
    }

    /// PJRT client + lazily-compiled executable cache (stub: the `pjrt`
    /// feature is off, so opening always fails with a clear error).
    pub struct XlaEngine {
        never: Infallible,
    }

    impl XlaEngine {
        /// Open the artifacts directory (must contain `manifest.json`).
        pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
            bail!(
                "XLA/PJRT runtime unavailable: this build has no `xla` crate (artifacts \
                 dir {:?}); vendor the `xla` dependency and build with `--features pjrt` \
                 on a toolchain with the PJRT bindings (see Cargo.toml [features]), or \
                 use the native data plane",
                dir.as_ref()
            )
        }

        /// Open `$REPO/artifacts` (or `$NANOSORT_ARTIFACTS`).
        pub fn open_default() -> Result<Self> {
            Self::open(super::super::default_artifacts_dir())
        }

        pub fn platform_name(&self) -> String {
            match self.never {}
        }

        pub fn manifest(&self) -> &Manifest {
            match self.never {}
        }

        /// Names of all available artifacts.
        pub fn artifact_names(&self) -> Vec<String> {
            match self.never {}
        }

        /// Get (compiling on first use) the executable for `name`.
        pub fn load(&self, _name: &str) -> Result<Arc<LoadedArtifact>> {
            match self.never {}
        }

        /// Number of compiled executables currently cached.
        pub fn cached_count(&self) -> usize {
            match self.never {}
        }
    }
}

#[cfg(feature = "pjrt")]
pub use real::{LoadedArtifact, XlaEngine};

#[cfg(feature = "pjrt")]
mod real {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::{Arc, Mutex};

    use anyhow::{anyhow, bail, Context, Result};

    use super::{ArtifactSpec, Manifest, MixedOutput};

    /// A compiled entry point plus its shape contract.
    pub struct LoadedArtifact {
        pub spec: ArtifactSpec,
        exe: xla::PjRtLoadedExecutable,
    }

    impl LoadedArtifact {
        /// Execute on u64 inputs; returns the flattened u64 output tensors.
        ///
        /// `inputs` must match the manifest shapes exactly (row-major flat).
        pub fn run_u64(&self, inputs: &[&[u64]]) -> Result<Vec<Vec<u64>>> {
            let lits = self.make_literals(inputs)?;
            let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True: output is always a tuple.
            let parts = result.to_tuple()?;
            if parts.len() != self.spec.outputs.len() {
                bail!(
                    "{}: expected {} outputs, got {}",
                    self.spec.name,
                    self.spec.outputs.len(),
                    parts.len()
                );
            }
            parts.into_iter().map(|p| Ok(p.to_vec::<u64>()?)).collect()
        }

        /// Execute and return output `i` reinterpreted per the manifest
        /// dtype (e.g. i32 bucket ids).
        pub fn run_mixed(&self, inputs: &[&[u64]]) -> Result<Vec<MixedOutput>> {
            let lits = self.make_literals(inputs)?;
            let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
            let parts = result.to_tuple()?;
            parts
                .into_iter()
                .zip(&self.spec.outputs)
                .map(|(p, spec)| match spec.dtype.as_str() {
                    "uint64" => Ok(MixedOutput::U64(p.to_vec::<u64>()?)),
                    "int32" => Ok(MixedOutput::I32(p.to_vec::<i32>()?)),
                    other => Err(anyhow!("unsupported output dtype {other}")),
                })
                .collect()
        }

        fn make_literals(&self, inputs: &[&[u64]]) -> Result<Vec<xla::Literal>> {
            if inputs.len() != self.spec.inputs.len() {
                bail!(
                    "{}: expected {} inputs, got {}",
                    self.spec.name,
                    self.spec.inputs.len(),
                    inputs.len()
                );
            }
            inputs
                .iter()
                .zip(&self.spec.inputs)
                .map(|(data, spec)| {
                    if data.len() != spec.elements() {
                        bail!(
                            "{}: input shape {:?} needs {} elements, got {}",
                            self.spec.name,
                            spec.shape,
                            spec.elements(),
                            data.len()
                        );
                    }
                    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
                    Ok(xla::Literal::vec1(data).reshape(&dims)?)
                })
                .collect()
        }
    }

    /// PJRT client + lazily-compiled executable cache, keyed by artifact
    /// name. Compilation happens at most once per artifact per engine
    /// (the paper's "python runs once" rule).
    pub struct XlaEngine {
        dir: PathBuf,
        manifest: Manifest,
        client: xla::PjRtClient,
        cache: Mutex<HashMap<String, Arc<LoadedArtifact>>>,
    }

    impl XlaEngine {
        /// Open the artifacts directory (must contain `manifest.json`).
        pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
            let dir = dir.as_ref().to_path_buf();
            let manifest = Manifest::load(&dir)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self { dir, manifest, client, cache: Mutex::new(HashMap::new()) })
        }

        /// Open `$REPO/artifacts` (or `$NANOSORT_ARTIFACTS`).
        pub fn open_default() -> Result<Self> {
            Self::open(super::super::default_artifacts_dir())
        }

        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Names of all available artifacts.
        pub fn artifact_names(&self) -> Vec<String> {
            self.manifest.artifacts.iter().map(|a| a.name.clone()).collect()
        }

        /// Get (compiling on first use) the executable for `name`.
        pub fn load(&self, name: &str) -> Result<Arc<LoadedArtifact>> {
            if let Some(hit) = self.cache.lock().unwrap().get(name) {
                return Ok(hit.clone());
            }
            let spec = self
                .manifest
                .artifacts
                .iter()
                .find(|a| a.name == name)
                .ok_or_else(|| anyhow!("no artifact named {name} (run `make artifacts`?)"))?
                .clone();
            let path = self.manifest.path_of(&self.dir, &spec);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", spec.name))?;
            let loaded = Arc::new(LoadedArtifact { spec, exe });
            self.cache.lock().unwrap().insert(name.to_string(), loaded.clone());
            Ok(loaded)
        }

        /// Number of compiled executables currently cached.
        pub fn cached_count(&self) -> usize {
            self.cache.lock().unwrap().len()
        }
    }
}

/// Compile-time sanity: both engine variants expose the same surface.
#[allow(dead_code)]
fn _api_shape(engine: &XlaEngine) -> Result<()> {
    let _: String = engine.platform_name();
    let _: &Manifest = engine.manifest();
    let _: Vec<String> = engine.artifact_names();
    let _: usize = engine.cached_count();
    Ok(())
}
