//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust request path. `aot.py` writes `artifacts/manifest.tsv` (plus a
//! human-readable `manifest.json` twin) describing every lowered entry
//! point (name, shapes, dtypes, file); we parse the TSV here so executable
//! lookup never guesses shapes. TSV instead of JSON because the offline
//! build has no JSON dependency — the format is five tab-separated fields:
//! `name  entry  file  inputs  outputs`, with tensor lists encoded as
//! `dtype:dim,dim;dtype:dim`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One tensor's dtype + shape as recorded by the AOT compiler.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(s: &str) -> Result<Self> {
        let (dtype, dims) = s
            .split_once(':')
            .with_context(|| format!("tensor spec {s:?} missing ':'"))?;
        let shape = if dims.is_empty() {
            Vec::new()
        } else {
            dims.split(',')
                .map(|d| d.parse::<usize>().with_context(|| format!("bad dim {d:?}")))
                .collect::<Result<_>>()?
        };
        Ok(TensorSpec { dtype: dtype.to_string(), shape })
    }
}

fn parse_tensor_list(s: &str) -> Result<Vec<TensorSpec>> {
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(';').map(TensorSpec::parse).collect()
}

/// One AOT-compiled entry point (one `.hlo.txt` file).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Unique artifact name, e.g. `sort_block_b1_n64`.
    pub name: String,
    /// The L2 entry point it was lowered from, e.g. `sort_block`.
    pub entry: String,
    /// File name relative to the artifacts directory.
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed `manifest.tsv`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `manifest.tsv` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let mut lines = text.lines();
        let header = lines.next().context("empty manifest")?;
        if !header.contains("format=hlo-text") || !header.contains("key_dtype=u64") {
            bail!("unsupported manifest header {header:?}");
        }
        let mut artifacts = Vec::new();
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() != 5 {
                bail!("manifest line {} has {} fields, want 5", i + 2, fields.len());
            }
            artifacts.push(ArtifactSpec {
                name: fields[0].to_string(),
                entry: fields[1].to_string(),
                file: fields[2].to_string(),
                inputs: parse_tensor_list(fields[3])
                    .with_context(|| format!("inputs of {}", fields[0]))?,
                outputs: parse_tensor_list(fields[4])
                    .with_context(|| format!("outputs of {}", fields[0]))?,
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Manifest { artifacts })
    }

    /// Index artifacts by name.
    pub fn by_name(&self) -> HashMap<&str, &ArtifactSpec> {
        self.artifacts.iter().map(|a| (a.name.as_str(), a)).collect()
    }

    /// Resolve the on-disk path of an artifact.
    pub fn path_of(&self, dir: &Path, spec: &ArtifactSpec) -> PathBuf {
        dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_real_manifest() {
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.artifacts.is_empty());
        let idx = m.by_name();
        let sort = idx.get("sort_block_b1_n64").expect("sort_block_b1_n64 present");
        assert_eq!(sort.inputs[0].shape, vec![1, 64]);
        assert_eq!(sort.outputs[0].shape, vec![1, 64]);
        assert_eq!(sort.inputs[0].dtype, "uint64");
        for a in &m.artifacts {
            assert!(dir.join(&a.file).exists(), "missing artifact file {}", a.file);
        }
    }

    #[test]
    fn tensor_spec_parse() {
        let t = TensorSpec::parse("uint64:4,16").unwrap();
        assert_eq!(t.dtype, "uint64");
        assert_eq!(t.shape, vec![4, 16]);
        assert_eq!(t.elements(), 64);
        let scalar = TensorSpec::parse("int32:").unwrap();
        assert_eq!(scalar.shape, Vec::<usize>::new());
        assert!(TensorSpec::parse("nocolon").is_err());
    }

    #[test]
    fn tensor_list_parse() {
        let l = parse_tensor_list("uint64:1,16;uint64:15").unwrap();
        assert_eq!(l.len(), 2);
        assert_eq!(l[1].shape, vec![15]);
        assert!(parse_tensor_list("").unwrap().is_empty());
    }
}
