//! Simulation-driven figures: MergeMin (Fig 4), pivot strategies (Fig 5),
//! MilliSort scaling (Figs 9/10), and the NanoSort knob/sensitivity studies
//! (Figs 11-15 + the §6.2.3 multicast experiment). Every simulated run
//! goes through the [`Scenario`] API.

use anyhow::Result;

use crate::algo::mergemin::MergeMin;
use crate::algo::millisort::MilliSort;
use crate::algo::nanosort::{
    pivot::{expected_bucket_fractions, Strategy},
    NanoSort, PivotMode,
};
use crate::coordinator::{f, RunOptions, Table};
use crate::net::NetConfig;
use crate::scenario::{RunReport, Scenario};

/// Run one NanoSort scenario with the standard option plumbing.
fn nanosort_run(
    opts: &RunOptions,
    workload: NanoSort,
    nodes: usize,
    net: NetConfig,
    seed: u64,
) -> Result<RunReport> {
    Scenario::new(workload)
        .nodes(nodes)
        .net(net)
        .compute(opts.compute)
        .seed(seed)
        .run()
}

/// Ablation (extension): the §4.2 pivot correction measured end-to-end —
/// PivotSelect vs naive uniform pivots, final skew and runtime per depth.
pub fn fig_ablation(opts: &RunOptions) -> Result<Table> {
    let mut t = Table::new(
        "Ablation — PivotSelect vs naive pivots (16 keys/core, b=16)",
        &["nodes", "depth", "mode", "skew", "runtime_us"],
    );
    let node_list: &[usize] = if opts.quick { &[256] } else { &[256, 4096, 65_536] };
    for &nodes in node_list {
        for (mode, name) in [(PivotMode::Paper, "paper"), (PivotMode::Naive, "naive")] {
            // Average skew over a few seeds (skew is the noisy metric).
            let runs = 3;
            let mut skew_acc = 0.0;
            let mut rt_acc = 0.0;
            let mut depth = 0;
            for s in 0..runs {
                let r = nanosort_run(
                    opts,
                    NanoSort { pivot_mode: mode, ..Default::default() },
                    nodes,
                    NetConfig::default(),
                    opts.seed + s,
                )?;
                assert!(r.validation.ok());
                depth = r.metric_u64("depth").unwrap_or(0);
                skew_acc += r.metric_f64("skew").unwrap_or(1.0);
                rt_acc += r.runtime().as_us_f64();
            }
            t.row(vec![
                nodes.to_string(),
                depth.to_string(),
                name.into(),
                f(skew_acc / runs as f64),
                f(rt_acc / runs as f64),
            ]);
        }
    }
    t.note("paper §4.2: naive pivots' median-vs-mean gap compounds per recursion level");
    Ok(t)
}

/// Fig 4: MergeMin runtime vs incast (64 cores, 128 values/core).
pub fn fig4(opts: &RunOptions) -> Result<Table> {
    let mut t = Table::new(
        "Fig 4 — MergeMin runtime vs incast (64 cores, 128 values/core)",
        &["incast", "runtime_ns", "correct"],
    );
    for incast in [1usize, 2, 4, 8, 16, 32, 64] {
        let r = Scenario::new(MergeMin { incast, ..Default::default() })
            .compute(opts.compute)
            .seed(opts.seed)
            .run()?;
        t.row(vec![
            incast.to_string(),
            f(r.summary.makespan.as_ns_f64()),
            r.validation.ok().to_string(),
        ]);
    }
    t.note("paper: sweet spot at incast 8 (~750 ns merge phase); extremes lose");
    Ok(t)
}

/// Fig 5: expected bucket-size fractions for the three pivot strategies
/// (b = 8 buckets, 8 keys per node).
pub fn fig5(opts: &RunOptions) -> Table {
    let b = 8;
    let trials = if opts.quick { 100 } else { 1000 };
    let mut t = Table::new(
        "Fig 5 — expected bucket fractions by pivot strategy (b=8, n=8)",
        &["bucket", "naive", "strategy2", "strategy3", "ideal"],
    );
    let naive = expected_bucket_fractions(Strategy::Naive, b, 101, trials, opts.seed);
    let s2 = expected_bucket_fractions(Strategy::Shifted, b, 101, trials, opts.seed);
    let s3 = expected_bucket_fractions(Strategy::Mixed, b, 101, trials, opts.seed);
    for i in 0..b {
        t.row(vec![
            (i + 1).to_string(),
            f(naive[i]),
            f(s2[i]),
            f(s3[i]),
            f(1.0 / b as f64),
        ]);
    }
    t.note("paper: naive shrinks edge buckets (median-vs-mean gap); strategy 3 ≈ uniform");
    t
}

/// Fig 9: MilliSort runtime vs cores (4,096 keys, incast 4).
pub fn fig9(opts: &RunOptions) -> Result<Table> {
    let mut t = Table::new(
        "Fig 9 — MilliSort runtime vs cores (4,096 keys, incast 4)",
        &["cores", "runtime_us", "correct"],
    );
    let cores_list: &[usize] = if opts.quick { &[16, 64] } else { &[16, 32, 64, 128, 256] };
    for &cores in cores_list {
        let r = Scenario::new(MilliSort::default())
            .nodes(cores)
            .compute(opts.compute)
            .seed(opts.seed)
            .run()?;
        t.row(vec![
            cores.to_string(),
            f(r.runtime().as_us_f64()),
            r.validation.ok().to_string(),
        ]);
    }
    t.note("paper: 61 µs @64 cores -> 400 µs @256 cores (poor scaling)");
    Ok(t)
}

/// Fig 10: MilliSort runtime vs reduction factor (128 cores, 4,096 keys).
pub fn fig10(opts: &RunOptions) -> Result<Table> {
    let mut t = Table::new(
        "Fig 10 — MilliSort runtime vs reduction factor (128 cores, 4,096 keys)",
        &["reduction_factor", "runtime_us", "correct"],
    );
    for rf in [2usize, 4, 8, 16, 32] {
        let r = Scenario::new(MilliSort { reduction_factor: rf, ..Default::default() })
            .nodes(128)
            .compute(opts.compute)
            .seed(opts.seed)
            .run()?;
        t.row(vec![
            rf.to_string(),
            f(r.runtime().as_us_f64()),
            r.validation.ok().to_string(),
        ]);
    }
    t.note("paper: larger incast => slower (each pivot sorter processes more)");
    Ok(t)
}

/// Fig 11: NanoSort vs bucket count — runtime (a) and traffic (b)
/// (4,096 cores, 32 keys/core).
pub fn fig11(opts: &RunOptions) -> Result<Vec<Table>> {
    let nodes = if opts.quick { 256 } else { 4096 };
    let mut a = Table::new(
        format!("Fig 11a — NanoSort runtime vs buckets ({nodes} cores, 32 keys/core)"),
        &["buckets", "runtime_us", "correct"],
    );
    let mut b_t = Table::new(
        format!("Fig 11b — network traffic vs buckets ({nodes} cores, 32 keys/core)"),
        &["buckets", "msgs_sent", "msgs_delivered", "wire_MB"],
    );
    for b in [4usize, 8, 16] {
        // nodes must be b^r: 4096 = 4^6 = 8^4 = 16^3 (quick: 256 = 4^4 = 16^2).
        if (nodes as f64).log(b as f64).fract() > 1e-9 {
            continue;
        }
        let r = nanosort_run(
            opts,
            NanoSort { keys_per_node: 32, buckets: b, median_incast: b, ..Default::default() },
            nodes,
            NetConfig::default(),
            opts.seed,
        )?;
        a.row(vec![
            b.to_string(),
            f(r.runtime().as_us_f64()),
            r.validation.ok().to_string(),
        ]);
        b_t.row(vec![
            b.to_string(),
            r.summary.net.msgs_sent.to_string(),
            r.summary.net.msgs_delivered.to_string(),
            f(r.summary.net.wire_bytes as f64 / 1e6),
        ]);
    }
    a.note("paper: 4/8/16 buckets perform similarly despite different traffic");
    Ok(vec![a, b_t])
}

/// Fig 12: NanoSort runtime vs total keys (4,096 cores).
pub fn fig12(opts: &RunOptions) -> Result<Table> {
    let nodes = if opts.quick { 256 } else { 4096 };
    let mut t = Table::new(
        format!("Fig 12 — NanoSort runtime vs keys ({nodes} cores, 16 buckets)"),
        &["total_keys", "keys_per_core", "runtime_us", "correct"],
    );
    for kpn in [4usize, 8, 16, 32, 64] {
        let r = nanosort_run(
            opts,
            NanoSort { keys_per_node: kpn, ..Default::default() },
            nodes,
            NetConfig::default(),
            opts.seed,
        )?;
        t.row(vec![
            (nodes * kpn).to_string(),
            kpn.to_string(),
            f(r.runtime().as_us_f64()),
            r.validation.ok().to_string(),
        ]);
    }
    t.note("paper: runtime grows ~linearly with keys per core");
    Ok(t)
}

/// Fig 13: final bucket skew vs keys per core (4,096 cores).
pub fn fig13(opts: &RunOptions) -> Result<Table> {
    let nodes = if opts.quick { 256 } else { 4096 };
    let mut t = Table::new(
        format!("Fig 13 — final skew vs keys per core ({nodes} cores, 16 buckets)"),
        &["keys_per_core", "skew_max_over_mean"],
    );
    for kpn in [4usize, 8, 16, 32, 64] {
        let r = nanosort_run(
            opts,
            NanoSort { keys_per_node: kpn, ..Default::default() },
            nodes,
            NetConfig::default(),
            opts.seed,
        )?;
        t.row(vec![kpn.to_string(), f(r.metric_f64("skew").unwrap_or(1.0))]);
    }
    t.note("paper: more keys/core => better pivot visibility => less skew");
    Ok(t)
}

/// Fig 14: effect of injected p99 tail latency (256 cores, 32 keys/core).
pub fn fig14(opts: &RunOptions) -> Result<Table> {
    let mut t = Table::new(
        "Fig 14 — runtime vs injected p99 latency (256 cores, 16 buckets, 32 keys/core)",
        &["p99_extra_ns", "runtime_us", "slowdown"],
    );
    let mut base_us = 0.0;
    for extra in [0u64, 500, 1000, 2000, 4000] {
        let net = NetConfig {
            tail_prob: (1, 100),
            tail_extra_ns: extra,
            ..NetConfig::default()
        };
        let r = nanosort_run(
            opts,
            NanoSort { keys_per_node: 32, ..Default::default() },
            256,
            net,
            opts.seed,
        )?;
        let us = r.runtime().as_us_f64();
        if extra == 0 {
            base_us = us;
        }
        t.row(vec![extra.to_string(), f(us), f(us / base_us)]);
    }
    t.note("paper: 4,000 ns p99 doubles runtime (26 µs -> 53 µs); tails matter");
    t.note("deviation: paper says 8 buckets/256 cores, but 256 is not a power of 8; we use b=16 (256 = 16^2)");
    Ok(t)
}

/// Fig 15: effect of switch latency (64 cores, 16 keys/core) —
/// runtime (a) and idle fraction (b).
pub fn fig15(opts: &RunOptions) -> Result<Vec<Table>> {
    let mut a = Table::new(
        "Fig 15a — NanoSort runtime vs switch latency (64 cores, 16 keys/core, 8 buckets)",
        &["switch_ns", "runtime_us"],
    );
    let mut b = Table::new(
        "Fig 15b — idle time vs switch latency",
        &["switch_ns", "mean_idle_us", "idle_fraction"],
    );
    for sw in [50u64, 100, 263, 500, 1000] {
        let net = NetConfig { switch_latency_ns: sw, ..NetConfig::default() };
        let r = nanosort_run(
            opts,
            NanoSort { keys_per_node: 16, buckets: 8, median_incast: 8, ..Default::default() },
            64,
            net,
            opts.seed,
        )?;
        let makespan = r.runtime().as_us_f64();
        let idle: f64 = r
            .summary
            .node_stats
            .iter()
            .map(|s| s.total_idle().as_us_f64())
            .sum::<f64>()
            / r.summary.node_stats.len() as f64;
        a.row(vec![sw.to_string(), f(makespan)]);
        b.row(vec![sw.to_string(), f(idle), f(idle / makespan)]);
    }
    a.note("paper: runtime rises with switch latency; cores spend the extra time idle");
    Ok(vec![a, b])
}

/// §6.2.3 multicast experiment: 4,096 cores with and without multicast.
pub fn fig_multicast(opts: &RunOptions) -> Result<Table> {
    let nodes = if opts.quick { 256 } else { 4096 };
    let mut t = Table::new(
        format!("§6.2.3 — multicast support on/off ({nodes} cores, 16 keys/core)"),
        &["multicast", "runtime_us", "msgs_sent", "sends_saved_pct"],
    );
    let mut base_msgs = 0u64;
    for mcast in [false, true] {
        let net = NetConfig { multicast: mcast, ..NetConfig::default() };
        let r = nanosort_run(opts, NanoSort::default(), nodes, net, opts.seed)?;
        if !mcast {
            base_msgs = r.summary.net.msgs_sent;
        }
        let saved = if mcast && base_msgs > 0 {
            100.0 * (base_msgs - r.summary.net.msgs_sent) as f64 / base_msgs as f64
        } else {
            0.0
        };
        t.row(vec![
            mcast.to_string(),
            f(r.runtime().as_us_f64()),
            r.summary.net.msgs_sent.to_string(),
            f(saved),
        ]);
    }
    t.note("paper: 96 µs -> 40 µs (2.4x), 18% fewer messages sent");
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> RunOptions {
        RunOptions { quick: true, ..Default::default() }
    }

    #[test]
    fn fig4_has_sweet_spot_shape() {
        let t = fig4(&quick()).unwrap();
        let times: Vec<f64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        // Middle incasts beat both extremes.
        let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(best < times[0] && best < *times.last().unwrap());
        assert!(t.rows.iter().all(|r| r[2] == "true"));
    }

    #[test]
    fn fig5_fractions_sum_to_one() {
        let t = fig5(&quick());
        for col in 1..4 {
            let s: f64 = t.rows.iter().map(|r| r[col].parse::<f64>().unwrap()).sum();
            // Cells are rounded to 4 decimals; allow rounding slack.
            assert!((s - 1.0).abs() < 1e-3, "col {col} sums to {s}");
        }
    }

    #[test]
    fn fig9_runtime_grows_with_cores() {
        let t = fig9(&quick()).unwrap();
        let times: Vec<f64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        assert!(times.last().unwrap() > times.first().unwrap());
    }

    #[test]
    fn fig14_tail_hurts() {
        let t = fig14(&quick()).unwrap();
        let slow: f64 = t.rows.last().unwrap()[2].parse().unwrap();
        assert!(slow > 1.1, "4,000 ns p99 slowdown = {slow}");
    }

    #[test]
    fn fig15_switch_latency_hurts() {
        let t = fig15(&quick()).unwrap();
        let a = &t[0];
        let first: f64 = a.rows.first().unwrap()[1].parse().unwrap();
        let last: f64 = a.rows.last().unwrap()[1].parse().unwrap();
        assert!(last > first);
    }

    #[test]
    fn multicast_helps() {
        let t = fig_multicast(&quick()).unwrap();
        let off: f64 = t.rows[0][1].parse().unwrap();
        let on: f64 = t.rows[1][1].parse().unwrap();
        assert!(on < off, "on={on} off={off}");
        let saved: f64 = t.rows[1][3].parse().unwrap();
        assert!(saved > 0.0);
    }
}
