//! Datacenter-scale experiments (§6.3): the 65,536-core / 1M-key headline,
//! the Fig 16 execution breakdown, and Table 2's per-core efficiency
//! comparison — all driven through the [`Scenario`] API.

use anyhow::Result;

use crate::algo::nanosort::NanoSort;
use crate::conformance::{self, Tier};
use crate::coordinator::{f, RunOptions, Table};
use crate::graysort::Throughput;
use crate::scenario::{registry, RunReport, Scenario};
use crate::sim::Time;
use crate::stats::Summary;

/// Keys per core in the headline configuration (1M total at 65,536
/// cores) — the single definition lives in [`crate::conformance`].
pub use crate::conformance::PAPER_KEYS_PER_NODE as HEADLINE_KEYS_PER_NODE;

/// The paper's headline workload: 16 keys per node, 16 buckets, GraySort
/// value redistribution included.
pub fn headline_workload() -> NanoSort {
    NanoSort {
        keys_per_node: HEADLINE_KEYS_PER_NODE,
        shuffle_values: true,
        ..Default::default()
    }
}

/// Headline fleet size: 65,536 cores (4,096 under `--quick`) — the same
/// shapes as the conformance `paper`/`mid` tiers.
pub fn headline_nodes(opts: &RunOptions) -> usize {
    if opts.quick {
        conformance::MID_NODES
    } else {
        conformance::PAPER_NODES
    }
}

fn run_headline_once(opts: &RunOptions, seed: u64) -> Result<RunReport> {
    Scenario::new(headline_workload())
        .nodes(headline_nodes(opts))
        .compute(opts.compute)
        .seed(seed)
        .run()
}

/// §6.3 headline: repeat the 1M-key sort `opts.runs` times and summarize.
pub fn headline(opts: &RunOptions) -> Result<Table> {
    let nodes = headline_nodes(opts);
    let mut t = Table::new(
        format!(
            "§6.3 headline — NanoSort {} keys on {} cores ({} runs)",
            nodes * HEADLINE_KEYS_PER_NODE,
            nodes,
            opts.runs
        ),
        &["run", "runtime_us", "correct", "skew", "msgs_sent"],
    );
    let mut times = Vec::new();
    for i in 0..opts.runs.max(1) {
        let r = run_headline_once(opts, opts.seed + i as u64)?;
        times.push(r.runtime().as_us_f64());
        t.row(vec![
            (i + 1).to_string(),
            f(r.runtime().as_us_f64()),
            r.validation.ok().to_string(),
            f(r.metric_f64("skew").unwrap_or(1.0)),
            r.summary.net.msgs_sent.to_string(),
        ]);
    }
    let s = Summary::of(&times);
    t.note(format!(
        "mean {:.1} µs, std {:.3} µs, max {:.1} µs over {} runs",
        s.mean, s.std, s.max, s.n
    ));
    t.note("paper: mean 68 µs (σ = 4.127 µs), all 10 runs < 78 µs");
    Ok(t)
}

/// Fig 16: per-stage busy (a) and idle (b) distributions across cores.
pub fn fig16(opts: &RunOptions) -> Result<Vec<Table>> {
    let r = run_headline_once(opts, opts.seed)?;
    let nodes = headline_nodes(opts);
    let depth = r.metric_u64("depth").unwrap_or(0) as usize;
    let mut a = Table::new(
        format!("Fig 16a — per-stage busy time across {nodes} cores"),
        &["stage", "mean_us", "p50_us", "p99_us", "max_us"],
    );
    let mut b = Table::new(
        "Fig 16b — per-stage idle time across cores",
        &["stage", "mean_us", "p50_us", "p99_us", "max_us"],
    );
    for stage in 0..=depth {
        let busy: Vec<f64> =
            r.summary.node_stats.iter().map(|s| s.busy[stage].as_us_f64()).collect();
        let idle: Vec<f64> =
            r.summary.node_stats.iter().map(|s| s.idle[stage].as_us_f64()).collect();
        let name = if stage == depth {
            "final+values".to_string()
        } else {
            format!("level {stage}")
        };
        let sb = Summary::of(&busy);
        let si = Summary::of(&idle);
        a.row(vec![name.clone(), f(sb.mean), f(sb.p50), f(sb.p99), f(sb.max)]);
        b.row(vec![name, f(si.mean), f(si.p50), f(si.p99), f(si.max)]);
    }
    a.note(format!(
        "runtime {:.1} µs, validation ok={}, utilization {:.1}%",
        r.runtime().as_us_f64(),
        r.validation.ok(),
        100.0 * r.summary.mean_utilization()
    ));
    a.note("paper: level 0 fastest/least variance; variance later is idle-time, not compute");
    Ok(vec![a, b])
}

/// Table 2: per-core sorting efficiency vs published systems.
pub fn table2(opts: &RunOptions) -> Result<Table> {
    let r = run_headline_once(opts, opts.seed)?;
    let nodes = headline_nodes(opts);
    let tput = Throughput {
        records: nodes * HEADLINE_KEYS_PER_NODE,
        cores: nodes,
        runtime: r.runtime(),
    };
    let mut t = Table::new(
        "Table 2 — per-core efficiency comparison",
        &["system", "cpu", "cores", "sort_us", "records_per_ms_per_core"],
    );
    t.row(vec![
        "NanoSort (ours)".into(),
        "RISC-V Rocket @3.2GHz (sim)".into(),
        nodes.to_string(),
        f(r.runtime().as_us_f64()),
        f(tput.records_per_ms_per_core()),
    ]);
    // Published reference rows (from the paper's Table 2).
    t.row(vec![
        "NanoSort (paper)".into(),
        "RISC-V Rocket @3.2GHz".into(),
        "65536".into(),
        "68".into(),
        "224".into(),
    ]);
    t.row(vec![
        "MilliSort".into(),
        "Xeon Gold 6148 @2.4GHz".into(),
        "2240".into(),
        "1000".into(),
        "1297".into(),
    ]);
    t.row(vec![
        "TencentSort".into(),
        "IBM POWER8 @2.9GHz".into(),
        "10240".into(),
        "n/a".into(),
        "1977".into(),
    ]);
    t.row(vec![
        "CloudRAMSort".into(),
        "Xeon X5680 @2.9GHz".into(),
        "3072".into(),
        "n/a".into(),
        "707".into(),
    ]);
    t.note("latency-vs-throughput trade-off: tight time budget costs per-core efficiency");
    t.note(format!("our aggregate bandwidth: {:.2} GB/s of 104 B records", tput.gb_per_s()));
    Ok(t)
}

/// Convenience for examples: total runtime of a headline-size run.
pub fn headline_runtime(opts: &RunOptions) -> Result<Time> {
    Ok(run_headline_once(opts, opts.seed)?.runtime())
}

/// `repro fig paperscale` — NanoSort's simulated runtime at each
/// conformance scale tier, printed next to the paper's 68 µs headline.
/// Under `--quick` only the smoke tier runs (mid and paper are sized
/// for the release profile; see the conformance tiering policy).
pub fn paperscale(opts: &RunOptions) -> Result<Table> {
    let spec = registry::find("nanosort")?;
    let tiers: &[Tier] = if opts.quick {
        &[Tier::Smoke]
    } else {
        &[Tier::Smoke, Tier::Mid, Tier::Paper]
    };
    let mut t = Table::new(
        "paperscale — NanoSort conformance tiers vs the paper headline",
        &["tier", "nodes", "keys", "simulated_us", "paper_us", "vs_paper", "wall_s"],
    );
    for &tier in tiers {
        let (r, wall) = conformance::run_tier(spec, tier, opts.compute, 1)?;
        anyhow::ensure!(
            r.validation.ok(),
            "tier {}: validation failed: {}",
            tier.name(),
            r.validation.detail
        );
        let keys =
            r.validation.sort.as_ref().map(|s| s.total_keys).unwrap_or(0);
        let us = r.runtime().as_us_f64();
        t.row(vec![
            tier.name().into(),
            r.nodes.to_string(),
            keys.to_string(),
            f(us),
            f(conformance::PAPER_RUNTIME_US),
            format!("{:.2}x", us / conformance::PAPER_RUNTIME_US),
            f(wall),
        ]);
    }
    t.note("paper §6.3: 1M keys on 65,536 cores in 68 µs mean (σ = 4.127 µs)");
    t.note("vs_paper compares each tier's simulated runtime to that headline figure");
    if opts.quick {
        t.note("--quick: smoke tier only; run `repro paper` for the full 65,536-core run");
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_headline_sorts() {
        let opts = RunOptions { quick: true, ..Default::default() };
        let t = headline(&opts).unwrap();
        assert!(t.rows.iter().all(|r| r[2] == "true"));
    }

    #[test]
    fn quick_fig16_stages_covered() {
        let opts = RunOptions { quick: true, ..Default::default() };
        let tables = fig16(&opts).unwrap();
        // quick config: 4096 = 16^3 -> stages 0..=3.
        assert_eq!(tables[0].rows.len(), 4);
        // Level 0 busy should have low variance relative to later stages
        // (paper's observation): check p99/mean closer to 1 at level 0.
        let level0_mean: f64 = tables[0].rows[0][1].parse().unwrap();
        assert!(level0_mean > 0.0);
    }

    #[test]
    fn quick_paperscale_reports_the_smoke_tier() {
        let opts = RunOptions { quick: true, ..Default::default() };
        let t = paperscale(&opts).unwrap();
        assert_eq!(t.rows.len(), 1, "--quick runs the smoke tier only");
        assert_eq!(t.rows[0][0], "smoke");
        // Smoke is the registry tuple: 16 cores × 8 keys.
        assert_eq!(t.rows[0][1], "16");
        assert_eq!(t.rows[0][2], "128");
        assert!(t.rows[0][5].ends_with('x'), "vs_paper ratio rendered");
    }

    #[test]
    fn quick_table2_has_our_row() {
        let opts = RunOptions { quick: true, ..Default::default() };
        let t = table2(&opts).unwrap();
        assert!(t.rows[0][0].contains("ours"));
        let tput: f64 = t.rows[0][4].parse().unwrap();
        assert!(tput > 0.0);
    }
}
