//! Cost-model microbenchmark figures (Table 1, Figs 1/2/3/6/7/8): these
//! evaluate the calibrated Rocket/nanoPU model directly, mirroring the
//! paper's single-core measurements.

use crate::algo::tree::AggTree;
use crate::coordinator::{f, Table};
use crate::cpu::{CoreModel, Temp, TABLE1_LATENCIES_NS};
use crate::net::NetConfig;
use crate::sim::Time;

/// Table 1: median wire-to-wire loopback latencies, plus our model's
/// realized loopback for comparison.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1 — median wire-to-wire loopback latency",
        &["system", "latency_ns"],
    );
    for (name, ns) in TABLE1_LATENCIES_NS {
        t.row(vec![name.into(), ns.to_string()]);
    }
    let core = CoreModel::default();
    let cfg = NetConfig::default();
    let model =
        (core.tx_time(8) + cfg.propagation(0, 0) + cfg.serialization(8) + core.rx_time(8))
            .as_ns_f64();
    t.row(vec!["(our model)".into(), f(model)]);
    t.note("paper Table 1; our fabric is calibrated to the nanoPU's 69 ns");
    t
}

/// Fig 1: operations that complete within 1 µs on a nanoPU core.
pub fn fig1() -> Table {
    let core = CoreModel::default();
    let cfg = NetConfig::default();
    let us = |c: u64| Time::from_cycles(c).as_us_f64();
    let mut t = Table::new(
        "Fig 1 — what fits in under 1 µs (3.2 GHz Rocket + nanoPU)",
        &["operation", "model_us", "under_1us"],
    );
    let rows: Vec<(&str, f64)> = vec![
        ("scan 1K 8-byte words in L1", us(core.scan_min_cycles(1024, Temp::Warm))),
        ("sort 40 8-byte keys", us(core.sort_cycles(40, Temp::Warm))),
        ("travel 300 m at light speed", 1.0), // physics, included for scale
        ("receive 2 KB on a 200 Gb/s NIC", cfg.serialization(2048).as_us_f64()),
        (
            "118 8-byte loopback nanoRequests",
            us(118 * (core.rx_cycles(8) + core.tx_cycles(8))),
        ),
    ];
    for (name, v) in rows {
        t.row(vec![name.into(), f(v), (v <= 1.05).to_string()]);
    }
    t.note("paper Fig 1 lists these as canonical sub-microsecond tasks");
    t
}

/// Fig 2: single-core min scan — time (a) and cache miss rate (b).
pub fn fig2() -> Table {
    let core = CoreModel::default();
    let mut t = Table::new(
        "Fig 2 — single-core MergeMin scan (cold cache)",
        &["values", "time_us", "l1_miss_rate"],
    );
    let mut n = 64u64;
    while n <= 8192 {
        let cycles = core.scan_min_cycles(n, Temp::Cold);
        let miss = core.cache.stream_miss_rate(n * 8, true);
        t.row(vec![n.to_string(), f(Time::from_cycles(cycles).as_us_f64()), f(miss)]);
        n *= 2;
    }
    t.note("paper anchor: 8,192 values ≈ 18 µs; miss rate rises with footprint");
    t
}

/// Fig 3: merge-tree shapes — incast vs depth (the schematic).
pub fn fig3() -> Table {
    let mut t = Table::new(
        "Fig 3 — lower incast => deeper work trees (64 cores)",
        &["incast", "depth", "root_incast_msgs"],
    );
    for incast in [2usize, 4, 8, 16, 64] {
        let tree = AggTree::new(64, incast);
        t.row(vec![
            incast.to_string(),
            tree.rounds().to_string(),
            tree.expected(0, 1).to_string(),
        ]);
    }
    t.row(vec!["1".into(), "63 (chain)".into(), "1".into()]);
    t
}

/// Fig 6: time for one core to receive N messages of various sizes.
pub fn fig6() -> Table {
    let core = CoreModel::default();
    let mut t = Table::new(
        "Fig 6 — time to receive N messages (nanoPU RX register interface)",
        &["messages", "16B_ns", "32B_ns", "64B_ns"],
    );
    for n in [1u64, 2, 4, 8, 16, 32, 64] {
        t.row(vec![
            n.to_string(),
            f(Time::from_cycles(n * core.rx_cycles(16)).as_ns_f64()),
            f(Time::from_cycles(n * core.rx_cycles(32)).as_ns_f64()),
            f(Time::from_cycles(n * core.rx_cycles(64)).as_ns_f64()),
        ]);
    }
    t.note("paper anchors: 1×16 B ≈ 8 ns; 64×16 B ≈ 400 ns");
    t
}

/// Fig 7: time for one core to send N messages.
pub fn fig7() -> Table {
    let core = CoreModel::default();
    let mut t = Table::new(
        "Fig 7 — time to send N messages (nanoPU TX register interface)",
        &["messages", "16B_ns", "32B_ns", "64B_ns"],
    );
    for n in [1u64, 2, 4, 8, 16, 32, 64] {
        t.row(vec![
            n.to_string(),
            f(Time::from_cycles(n * core.tx_cycles(16)).as_ns_f64()),
            f(Time::from_cycles(n * core.tx_cycles(32)).as_ns_f64()),
            f(Time::from_cycles(n * core.tx_cycles(64)).as_ns_f64()),
        ]);
    }
    t
}

/// Fig 8: single-core local sort time (cold cache).
pub fn fig8() -> Table {
    let core = CoreModel::default();
    let mut t = Table::new(
        "Fig 8 — single-core local sort (cold cache)",
        &["keys", "time_us"],
    );
    let mut n = 16u64;
    while n <= 4096 {
        t.row(vec![
            n.to_string(),
            f(Time::from_cycles(core.sort_cycles(n, Temp::Cold)).as_us_f64()),
        ]);
        n *= 2;
    }
    t.note("paper anchors: 1,024 keys > 30 µs; nanoTask-appropriate ≈ ≤64 keys");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_everything_under_a_microsecond() {
        let t = fig1();
        for row in &t.rows {
            assert_eq!(row[2], "true", "{} took {} µs", row[0], row[1]);
        }
    }

    #[test]
    fn fig2_monotone_time() {
        let t = fig2();
        let times: Vec<f64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        assert!(times.windows(2).all(|w| w[0] < w[1]));
        let last: f64 = *times.last().unwrap();
        assert!((16.0..20.0).contains(&last), "8192 values = {last} µs");
    }

    #[test]
    fn fig8_paper_anchor() {
        let t = fig8();
        let row_1024 = t.rows.iter().find(|r| r[0] == "1024").unwrap();
        let us: f64 = row_1024[1].parse().unwrap();
        assert!(us > 28.0, "sort 1024 = {us} µs");
    }

    #[test]
    fn fig3_depth_decreases_with_incast() {
        let t = fig3();
        let depths: Vec<u32> = t.rows[..5].iter().map(|r| r[1].parse().unwrap()).collect();
        assert!(depths.windows(2).all(|w| w[0] >= w[1]));
    }
}
