//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §4 maps each id to its modules). Invoke via
//! `repro fig <id>`; `repro fig all` runs everything.
//!
//! Absolute numbers come from our calibrated simulator, not the authors'
//! FireSim testbed — per the reproduction contract, the *shape* (who wins,
//! crossovers, scaling direction) is what each figure must match. Every
//! table carries the paper's reference values as notes.

mod datacenter;
mod micro;
mod sortfigs;

pub use datacenter::{
    headline_nodes, headline_runtime, headline_workload, HEADLINE_KEYS_PER_NODE,
};

use anyhow::{bail, Result};

use crate::coordinator::{RunOptions, Table};

/// All figure/table ids in paper order (plus the conformance-tier
/// `paperscale` summary, the sweep-driven `skewsweep`/`tailsweep`
/// sensitivity studies, the service-layer `loadsweep`, the host-kernel
/// `tunersweep`, and the host-memory `memsweep`).
pub const ALL_FIGURES: &[&str] = &[
    "table1", "1", "2", "3", "4", "5", "6", "7", "8", "9", "10", "11", "12", "13", "14",
    "15", "multicast", "16", "headline", "table2", "ablation", "paperscale", "skewsweep",
    "tailsweep", "loadsweep", "tunersweep", "memsweep",
];

/// Run one figure/table by id; returns the report tables.
pub fn run_figure(id: &str, opts: &RunOptions) -> Result<Vec<Table>> {
    Ok(match id {
        "table1" => vec![micro::table1()],
        "1" => vec![micro::fig1()],
        "2" => vec![micro::fig2()],
        "3" => vec![micro::fig3()],
        "4" => vec![sortfigs::fig4(opts)?],
        "5" => vec![sortfigs::fig5(opts)],
        "6" => vec![micro::fig6()],
        "7" => vec![micro::fig7()],
        "8" => vec![micro::fig8()],
        "9" => vec![sortfigs::fig9(opts)?],
        "10" => vec![sortfigs::fig10(opts)?],
        "11" => sortfigs::fig11(opts)?,
        "12" => vec![sortfigs::fig12(opts)?],
        "13" => vec![sortfigs::fig13(opts)?],
        "14" => vec![sortfigs::fig14(opts)?],
        "15" => sortfigs::fig15(opts)?,
        "multicast" => vec![sortfigs::fig_multicast(opts)?],
        "16" => datacenter::fig16(opts)?,
        "headline" => vec![datacenter::headline(opts)?],
        "table2" => vec![datacenter::table2(opts)?],
        "ablation" => vec![sortfigs::fig_ablation(opts)?],
        "paperscale" => vec![datacenter::paperscale(opts)?],
        "skewsweep" => vec![crate::perturb::sweep::skew_sweep_figure(opts)?],
        "tailsweep" => vec![crate::perturb::sweep::tail_sweep_figure(opts)?],
        "loadsweep" => vec![crate::service::loadsweep_figure(opts)?],
        "tunersweep" => vec![tunersweep(opts)?],
        "memsweep" => vec![memsweep(opts)?],
        other => bail!("unknown figure id {other:?}; ids: {}", ALL_FIGURES.join(", ")),
    })
}

/// `tunersweep`: the same NanoSort run under each forced kernel family
/// (`NANOSORT_TUNER` values), reporting host wall-clock per family with
/// the §8 invariant asserted on every row — a forced tuner must leave
/// the rendered report byte-identical to the auto reference.
fn tunersweep(opts: &RunOptions) -> Result<Table> {
    use std::sync::Arc;
    use std::time::Instant;

    use crate::algo::nanosort::NanoSort;
    use crate::compute::{RadixCompute, TunerOverride};
    use crate::coordinator::f;
    use crate::pool::WorkerPool;
    use crate::scenario::{RunReport, Scenario};
    use crate::sim::exec::resolve_threads;

    let nodes = if opts.quick { 256 } else { 4096 };
    let run = |force: Option<TunerOverride>, threads: usize| -> Result<(RunReport, f64)> {
        let pool = Arc::new(WorkerPool::new(threads));
        let t0 = Instant::now();
        let report = Scenario::new(NanoSort {
            keys_per_node: 16,
            buckets: 16,
            ..Default::default()
        })
        .nodes(nodes)
        .seed(opts.seed)
        .threads(threads)
        .pool(pool.clone())
        .compute_with(Arc::new(RadixCompute::forced(force, pool)))
        .run()?;
        Ok((report, t0.elapsed().as_secs_f64() * 1e3))
    };

    let (baseline, base_ms) = run(None, 1)?;
    let mut table = Table::new(
        format!("tunersweep — NanoSort nodes={nodes} kpn=16, host wall-clock per kernel family"),
        &["tuner", "threads", "wall_ms", "vs_auto", "digest"],
    );
    table.row(vec!["auto".into(), "1".into(), f(base_ms), "1.00x".into(), "ref".into()]);
    let rows = [
        (TunerOverride::Comparative, 1),
        (TunerOverride::Lsb, 1),
        (TunerOverride::Ska, 1),
        (TunerOverride::Par, resolve_threads(0)),
    ];
    for (force, threads) in rows {
        let (report, ms) = run(Some(force), threads)?;
        if report.render() != baseline.render() {
            bail!("tuner={} diverged from the auto reference report", force.name());
        }
        table.row(vec![
            force.name().into(),
            threads.to_string(),
            f(ms),
            format!("{:.2}x", base_ms / ms.max(1e-9)),
            "ok".into(),
        ]);
    }
    table.note("wall-clock is host-dependent; the digest column is the §8 invariant");
    table.note("simulated makespan is identical by construction — only host time varies");
    Ok(table)
}

/// `memsweep`: peak RSS and allocation count vs fleet size — the
/// memory-diet figure behind the hyper tiers. Cells run in **ascending**
/// node order because `VmHWM` is a process-lifetime high-water mark: a
/// cell's reading can only be attributed to that cell when everything
/// before it was smaller. Streamed input generation is on (the hyper-tier
/// configuration), so the footprint being measured is arenas + slots, not
/// a materialized key array.
fn memsweep(opts: &RunOptions) -> Result<Table> {
    use std::time::Instant;

    use crate::algo::nanosort::NanoSort;
    use crate::coordinator::f;
    use crate::mem::{alloc_count, peak_rss_mb};
    use crate::scenario::Scenario;

    // (nodes, buckets): nodes must be an exact bucket power.
    let cells: &[(usize, usize)] = if opts.quick {
        &[(256, 16), (1024, 4), (4096, 16)]
    } else {
        &[(4096, 16), (16_384, 4), (65_536, 16)]
    };
    let mut table = Table::new(
        "memsweep — host memory vs fleet size (kpn=16, streamed input; ascending sizes)"
            .to_string(),
        &["nodes", "keys", "peak_rss_mb", "allocs", "wall_ms"],
    );
    for &(nodes, buckets) in cells {
        let alloc_before = alloc_count();
        let t0 = Instant::now();
        let report = Scenario::new(NanoSort {
            keys_per_node: 16,
            buckets,
            ..Default::default()
        })
        .nodes(nodes)
        .seed(opts.seed)
        .stream_input()
        .run()?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let allocs = alloc_count().saturating_sub(alloc_before);
        anyhow::ensure!(report.validation.ok(), "memsweep nodes={nodes}: validation failed");
        table.row(vec![
            nodes.to_string(),
            (nodes * 16).to_string(),
            peak_rss_mb().map_or_else(|| "n/a".into(), |mb| mb.to_string()),
            allocs.to_string(),
            f(ms),
        ]);
    }
    table.note("peak_rss_mb is the process high-water mark (VmHWM): strictly monotone down the table");
    table.note("allocs is the heap-allocation delta per cell (counting global allocator)");
    table.note("sublinear-in-keys, tight-in-nodes is the claim: RSS growth should track nodes, not keys");
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RunOptions;

    /// Smoke: every cheap figure renders non-empty tables.
    #[test]
    fn cheap_figures_render() {
        let opts = RunOptions { quick: true, ..Default::default() };
        for id in [
            "table1", "1", "2", "3", "4", "6", "7", "8", "skewsweep", "tailsweep",
            "tunersweep", "memsweep",
        ] {
            let tables = run_figure(id, &opts).unwrap();
            assert!(!tables.is_empty(), "{id}");
            for t in &tables {
                assert!(!t.rows.is_empty(), "{id}");
                assert!(!t.render().is_empty());
            }
        }
    }

    #[test]
    fn unknown_figure_errors() {
        assert!(run_figure("nope", &RunOptions::default()).is_err());
    }
}
