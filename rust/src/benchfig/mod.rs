//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §4 maps each id to its modules). Invoke via
//! `repro fig <id>`; `repro fig all` runs everything.
//!
//! Absolute numbers come from our calibrated simulator, not the authors'
//! FireSim testbed — per the reproduction contract, the *shape* (who wins,
//! crossovers, scaling direction) is what each figure must match. Every
//! table carries the paper's reference values as notes.

mod datacenter;
mod micro;
mod sortfigs;

pub use datacenter::{
    headline_nodes, headline_runtime, headline_workload, HEADLINE_KEYS_PER_NODE,
};

use anyhow::{bail, Result};

use crate::coordinator::{RunOptions, Table};

/// All figure/table ids in paper order (plus the conformance-tier
/// `paperscale` summary, the sweep-driven `skewsweep`/`tailsweep`
/// sensitivity studies, and the service-layer `loadsweep`).
pub const ALL_FIGURES: &[&str] = &[
    "table1", "1", "2", "3", "4", "5", "6", "7", "8", "9", "10", "11", "12", "13", "14",
    "15", "multicast", "16", "headline", "table2", "ablation", "paperscale", "skewsweep",
    "tailsweep", "loadsweep",
];

/// Run one figure/table by id; returns the report tables.
pub fn run_figure(id: &str, opts: &RunOptions) -> Result<Vec<Table>> {
    Ok(match id {
        "table1" => vec![micro::table1()],
        "1" => vec![micro::fig1()],
        "2" => vec![micro::fig2()],
        "3" => vec![micro::fig3()],
        "4" => vec![sortfigs::fig4(opts)?],
        "5" => vec![sortfigs::fig5(opts)],
        "6" => vec![micro::fig6()],
        "7" => vec![micro::fig7()],
        "8" => vec![micro::fig8()],
        "9" => vec![sortfigs::fig9(opts)?],
        "10" => vec![sortfigs::fig10(opts)?],
        "11" => sortfigs::fig11(opts)?,
        "12" => vec![sortfigs::fig12(opts)?],
        "13" => vec![sortfigs::fig13(opts)?],
        "14" => vec![sortfigs::fig14(opts)?],
        "15" => sortfigs::fig15(opts)?,
        "multicast" => vec![sortfigs::fig_multicast(opts)?],
        "16" => datacenter::fig16(opts)?,
        "headline" => vec![datacenter::headline(opts)?],
        "table2" => vec![datacenter::table2(opts)?],
        "ablation" => vec![sortfigs::fig_ablation(opts)?],
        "paperscale" => vec![datacenter::paperscale(opts)?],
        "skewsweep" => vec![crate::perturb::sweep::skew_sweep_figure(opts)?],
        "tailsweep" => vec![crate::perturb::sweep::tail_sweep_figure(opts)?],
        "loadsweep" => vec![crate::service::loadsweep_figure(opts)?],
        other => bail!("unknown figure id {other:?}; ids: {}", ALL_FIGURES.join(", ")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RunOptions;

    /// Smoke: every cheap figure renders non-empty tables.
    #[test]
    fn cheap_figures_render() {
        let opts = RunOptions { quick: true, ..Default::default() };
        for id in ["table1", "1", "2", "3", "4", "6", "7", "8", "skewsweep", "tailsweep"] {
            let tables = run_figure(id, &opts).unwrap();
            assert!(!tables.is_empty(), "{id}");
            for t in &tables {
                assert!(!t.rows.is_empty(), "{id}");
                assert!(!t.render().is_empty());
            }
        }
    }

    #[test]
    fn unknown_figure_errors() {
        assert!(run_figure("nope", &RunOptions::default()).is_err());
    }
}
