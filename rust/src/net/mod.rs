//! Network substrate: the paper's §5.1 two-layer full-bisection fabric
//! ([`Topology`]), per-message latency/contention/multicast model
//! ([`Fabric`], [`NetConfig`]), and traffic accounting ([`NetStats`]).

mod fabric;
mod topology;

pub use fabric::{Fabric, NetConfig, NetStats};
pub use topology::{PathHops, Topology};
