//! Network substrate: the paper's §5.1 two-layer full-bisection fabric
//! ([`Topology`]), per-message latency/contention/multicast model
//! ([`Fabric`], [`NetConfig`]), and traffic accounting ([`NetStats`]).
//!
//! The fabric is split into a sender phase ([`TxLane`] → [`Flight`]) and
//! a destination phase ([`RxLane`]) so executor backends can shard
//! endpoint state by node range without changing results (DESIGN.md §7).

mod fabric;
mod topology;

pub use fabric::{Fabric, Flight, NetConfig, NetStats, RxLane, TxLane};
pub use topology::{PathHops, Topology};
