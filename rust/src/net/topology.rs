//! Two-layer full-bisection topology (paper §5.1): each leaf switch has 64
//! downlinks to nanoPU NICs and 64 uplinks to core (spine) switches.
//!
//! With full bisection the fabric core is non-blocking, so the latency of a
//! path is fully determined by its hop count; contention is modeled at the
//! endpoint links (see `fabric.rs`).

/// Static description of the leaf/spine fabric.
#[derive(Debug, Clone)]
pub struct Topology {
    /// Total number of nanoPU cores (one NIC per core).
    pub nodes: usize,
    /// Downlinks per leaf switch (64 in the paper).
    pub leaf_radix: usize,
}

/// Number of links and switches a message traverses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PathHops {
    pub links: u64,
    pub switches: u64,
}

impl Topology {
    pub fn new(nodes: usize, leaf_radix: usize) -> Self {
        assert!(nodes > 0 && leaf_radix > 0);
        Topology { nodes, leaf_radix }
    }

    /// Paper default: 64-port leaves.
    pub fn paper(nodes: usize) -> Self {
        Self::new(nodes, 64)
    }

    /// Leaf switch that `node` hangs off.
    pub fn leaf_of(&self, node: usize) -> usize {
        node / self.leaf_radix
    }

    /// Number of leaf switches.
    pub fn num_leaves(&self) -> usize {
        self.nodes.div_ceil(self.leaf_radix)
    }

    /// Hop count between two NICs.
    ///
    /// - loopback: NIC-internal, no fabric hops;
    /// - same leaf: NIC → leaf → NIC (2 links, 1 switch);
    /// - cross leaf: NIC → leaf → spine → leaf → NIC (4 links, 3 switches).
    pub fn hops(&self, src: usize, dst: usize) -> PathHops {
        if src == dst {
            PathHops { links: 0, switches: 0 }
        } else if self.leaf_of(src) == self.leaf_of(dst) {
            PathHops { links: 2, switches: 1 }
        } else {
            PathHops { links: 4, switches: 3 }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leaf_assignment() {
        let t = Topology::paper(256);
        assert_eq!(t.leaf_of(0), 0);
        assert_eq!(t.leaf_of(63), 0);
        assert_eq!(t.leaf_of(64), 1);
        assert_eq!(t.num_leaves(), 4);
    }

    #[test]
    fn ragged_last_leaf() {
        let t = Topology::paper(100);
        assert_eq!(t.num_leaves(), 2);
        assert_eq!(t.leaf_of(99), 1);
    }

    #[test]
    fn hop_counts() {
        let t = Topology::paper(65_536);
        assert_eq!(t.hops(5, 5), PathHops { links: 0, switches: 0 });
        assert_eq!(t.hops(0, 63), PathHops { links: 2, switches: 1 });
        assert_eq!(t.hops(0, 64), PathHops { links: 4, switches: 3 });
        assert_eq!(t.hops(1000, 60_000), PathHops { links: 4, switches: 3 });
    }

    #[test]
    fn hops_symmetric() {
        let t = Topology::paper(4096);
        for &(a, b) in &[(0usize, 1usize), (3, 700), (64, 127), (4000, 200)] {
            assert_eq!(t.hops(a, b), t.hops(b, a));
        }
    }

    /// Loopback is NIC-internal everywhere — including node 0, the exact
    /// leaf boundary, and the last node of a ragged fleet.
    #[test]
    fn hops_loopback_everywhere() {
        let t = Topology::paper(100);
        for n in [0usize, 63, 64, 99] {
            assert_eq!(t.hops(n, n), PathHops { links: 0, switches: 0 }, "node {n}");
        }
    }

    /// The same-leaf/cross-leaf boundary sits exactly at `leaf_radix`:
    /// 62→63 shares a leaf, 63→64 crosses, 64→65 shares the next leaf.
    #[test]
    fn hops_boundary_at_leaf_radix() {
        let t = Topology::paper(128);
        assert_eq!(t.hops(62, 63), PathHops { links: 2, switches: 1 });
        assert_eq!(t.hops(63, 64), PathHops { links: 4, switches: 3 });
        assert_eq!(t.hops(64, 65), PathHops { links: 2, switches: 1 });
        assert_eq!(t.hops(0, 127), PathHops { links: 4, switches: 3 });
    }

    /// A ragged last leaf (fleet not a multiple of the radix) still
    /// groups its members on one switch and crosses to every other leaf.
    #[test]
    fn hops_last_partial_leaf() {
        let t = Topology::paper(100); // leaves: [0..64), [64..100)
        assert_eq!(t.num_leaves(), 2);
        assert_eq!(t.hops(64, 99), PathHops { links: 2, switches: 1 });
        assert_eq!(t.hops(99, 0), PathHops { links: 4, switches: 3 });
        // Single-node "leaf": 128 nodes + 1 straggler node on leaf 2.
        let t = Topology::paper(129);
        assert_eq!(t.num_leaves(), 3);
        assert_eq!(t.leaf_of(128), 2);
        assert_eq!(t.hops(128, 128), PathHops { links: 0, switches: 0 });
        assert_eq!(t.hops(128, 127), PathHops { links: 4, switches: 3 });
    }

    /// Sub-radix fleets live on a single leaf: every non-loopback pair is
    /// one switch away.
    #[test]
    fn hops_single_leaf_fleet() {
        let t = Topology::paper(16);
        assert_eq!(t.num_leaves(), 1);
        assert_eq!(t.hops(0, 15), PathHops { links: 2, switches: 1 });
    }
}
