//! The network fabric: per-message latency, endpoint-link contention,
//! reliable multicast (paper §5.3), and tail-latency injection (Fig 14).
//!
//! Latency of a unicast message =
//!   NIC egress overhead + serialization + links·43 ns + switches·263 ns +
//!   NIC ingress overhead, with store-and-forward serialization on the
//!   destination link (which is what makes incast expensive) and an
//!   injected extra delay on a configurable fraction of messages (p99 tail).
//!
//! With full bisection (paper §5.1) the core is non-blocking, so contention
//! is modeled only at the endpoint links — source NIC egress and
//! destination leaf-downlink ingress — each a simple busy-until register.
//!
//! # Two-phase message model (execution-backend contract)
//!
//! Since the pluggable-executor refactor (DESIGN.md §7) a message crosses
//! the fabric in two phases so the simulation can be sharded across host
//! threads without changing results:
//!
//! 1. **Sender side** ([`Fabric::send`] / [`Fabric::mcast_leg`], state in
//!    [`TxLane`]): egress busy-until + serialization, the loss/RTO
//!    retransmit schedule, and the tail draw. All randomness comes from a
//!    **per-source-node `SplitMix64` stream** derived from the run seed —
//!    never from a shared draw order — so a node's outbound schedule is a
//!    pure function of (seed, node, its own send sequence). The result is
//!    a [`Flight`]: the candidate arrival time before destination-side
//!    contention, plus the tie-break key `(at, src, ctr)`.
//! 2. **Destination side** ([`Fabric::admit`], state in [`RxLane`]):
//!    oversubscribed-spine queueing and ingress store-and-forward, applied
//!    when the destination pops flights in canonical `(at, src, ctr)`
//!    order. Spine busy-until registers are keyed by **destination leaf**
//!    (the spine→leaf downlink), so they are owned by whichever shard owns
//!    that leaf.
//!
//! [`Fabric::min_latency`] is the conservative lookahead used by the
//! parallel executor's time windows: no flight can arrive earlier than
//! `ready + min_latency()` after the send that produced it.
//!
//! The classic `unicast`/`multicast` entry points remain for tests and
//! micro-benches; they run both phases back to back on a fabric-owned
//! lane pair covering every node.

use crate::sim::{SplitMix64, Time};

use super::topology::Topology;

/// Seed salt for the per-source-node network RNG streams.
const NET_SALT: u64 = 0x6e65_745f_7461_696c;

/// All network knobs (defaults = paper §5.1 constants).
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Per-link propagation latency, ns (paper: 43).
    pub link_latency_ns: u64,
    /// Per-switch latency, ns (paper: 263; Fig 15 sweeps this).
    pub switch_latency_ns: u64,
    /// Link bandwidth in Gbit/s (paper: 200).
    pub bandwidth_gbps: u64,
    /// Fixed NIC/MAC overhead per direction, ns. Calibrated so that the
    /// wire-to-wire loopback through a core ≈ 69 ns (Table 1).
    pub nic_overhead_ns: u64,
    /// Wire framing per message (Ethernet + nanoPU headers), bytes.
    pub header_bytes: u64,
    /// Switches replicate multicast packets (paper §5.3). When false,
    /// group sends degrade to sender-side unicast loops.
    pub multicast: bool,
    /// Fraction of messages (numerator / denominator) that suffer
    /// `tail_extra_ns` of additional latency (Fig 14 injects at p99).
    pub tail_prob: (u64, u64),
    /// Extra latency for tail-affected messages, ns.
    pub tail_extra_ns: u64,
    /// Per-delivery drop probability (numerator / denominator); default
    /// `(0, 1)` = the paper's lossless links. Each lost transmission
    /// attempt costs [`NetConfig::rto_ns`] at the sender before the
    /// packet is retransmitted; drops are deterministic via the sender
    /// node's seeded `SplitMix64` stream (and draw *nothing* from it when
    /// disabled, so lossless runs stay bit-identical).
    pub loss_prob: (u64, u64),
    /// Retransmit timeout, ns (only relevant when `loss_prob` is on).
    pub rto_ns: u64,
    /// Core oversubscription factor. `0` (default) is the paper's §5.1
    /// non-blocking full-bisection core; `f >= 1` gives each destination
    /// leaf only `leaf_radix / f` spine downlinks, each a
    /// store-and-forward busy-until register that cross-leaf packets into
    /// that leaf contend for (deterministic ECMP-style spine choice).
    pub oversub: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            link_latency_ns: 43,
            switch_latency_ns: 263,
            bandwidth_gbps: 200,
            nic_overhead_ns: 28,
            header_bytes: 24,
            multicast: true,
            tail_prob: (0, 100),
            tail_extra_ns: 0,
            loss_prob: (0, 1),
            rto_ns: 10_000,
            oversub: 0,
        }
    }
}

impl NetConfig {
    /// Serialization time of `bytes` (payload + header) at line rate.
    /// 200 Gbps = 0.04 ns/byte = 0.64 time-units/byte (exact on the grid
    /// for the default config).
    pub fn serialization(&self, payload_bytes: u64) -> Time {
        let bytes = payload_bytes + self.header_bytes;
        let bits = bytes * 8;
        // units = bits / (gbps) * 16 ; round up to the grid.
        Time((bits * 16).div_ceil(self.bandwidth_gbps))
    }

    /// Pure propagation latency (no serialization/contention) for a path.
    pub fn propagation(&self, links: u64, switches: u64) -> Time {
        Time::from_ns(
            2 * self.nic_overhead_ns
                + links * self.link_latency_ns
                + switches * self.switch_latency_ns,
        )
    }

    /// Spine downlink registers per destination leaf under this config
    /// (`0` = non-blocking core, no spine state at all).
    pub fn spines_per_leaf(&self, leaf_radix: usize) -> usize {
        if self.oversub > 0 {
            (leaf_radix as u64 / self.oversub).max(1) as usize
        } else {
            0
        }
    }
}

/// Traffic counters (Fig 11b and the §6.2.3 multicast experiment report
/// message counts).
///
/// Sender-side events (sends, multicasts, tail hits, retransmits) are
/// counted in phase 1; delivery counters in phase 2. Under the parallel
/// executor each shard keeps its own `NetStats` and the engine merges
/// them with [`NetStats::merge`] — all fields are order-independent sums.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Messages injected by senders (a multicast counts once).
    pub msgs_sent: u64,
    /// Messages delivered to receivers (a multicast counts per member).
    pub msgs_delivered: u64,
    /// Total payload bytes delivered.
    pub payload_bytes: u64,
    /// Total wire bytes (payload + headers) crossing the destination link.
    pub wire_bytes: u64,
    /// Messages that got the injected tail penalty.
    pub tail_hits: u64,
    /// Multicast sends (subset of msgs_sent).
    pub multicasts: u64,
    /// Transmission attempts lost and retransmitted (0 on lossless
    /// fabrics). Delivered/byte counters count the final delivery only.
    pub retransmits: u64,
}

impl NetStats {
    /// Fold another shard's counters into this one (commutative sums, so
    /// the merge is deterministic in any order; the engine still merges
    /// in canonical shard order).
    pub fn merge(&mut self, other: &NetStats) {
        self.msgs_sent += other.msgs_sent;
        self.msgs_delivered += other.msgs_delivered;
        self.payload_bytes += other.payload_bytes;
        self.wire_bytes += other.wire_bytes;
        self.tail_hits += other.tail_hits;
        self.multicasts += other.multicasts;
        self.retransmits += other.retransmits;
    }
}

/// Sender-side fabric state for a contiguous node range: one egress
/// busy-until register, one seeded RNG stream, and one send counter per
/// node. Owned by the shard that runs those nodes' handlers.
pub struct TxLane {
    base: usize,
    egress_free: Vec<Time>,
    rng: Vec<SplitMix64>,
    /// Per-source flight counter — the third component of the canonical
    /// event key `(at, src, ctr)`.
    ctr: Vec<u64>,
}

impl TxLane {
    /// Snapshot of one node's sender-side registers, for the optimistic
    /// executor's per-node rollback checkpoints (DESIGN.md §10).
    pub(crate) fn spec_save(&self, node: usize) -> (Time, SplitMix64, u64) {
        let s = node - self.base;
        (self.egress_free[s], self.rng[s].clone(), self.ctr[s])
    }

    pub(crate) fn spec_restore(&mut self, node: usize, saved: &(Time, SplitMix64, u64)) {
        let s = node - self.base;
        self.egress_free[s] = saved.0;
        self.rng[s] = saved.1.clone();
        self.ctr[s] = saved.2;
    }
}

/// Destination-side fabric state for a contiguous node range: ingress
/// busy-until per node plus the spine downlink registers of every leaf
/// the range covers (the range must cover whole leaves when
/// oversubscription is on — see [`Fabric::rx_lane`]).
pub struct RxLane {
    base: usize,
    ingress_free: Vec<Time>,
    /// First leaf covered by this lane.
    leaf_base: usize,
    /// Spine downlink registers per leaf (0 = non-blocking core).
    spines_per_leaf: usize,
    /// `spines_per_leaf` registers per covered leaf, leaf-major.
    spine_free: Vec<Time>,
}

impl RxLane {
    /// Snapshot of one node's ingress busy-until register (per-node
    /// rollback checkpoint, DESIGN.md §10).
    pub(crate) fn spec_save(&self, node: usize) -> Time {
        self.ingress_free[node - self.base]
    }

    pub(crate) fn spec_restore(&mut self, node: usize, t: Time) {
        self.ingress_free[node - self.base] = t;
    }

    /// Snapshot of every spine downlink register the lane covers into a
    /// caller-owned buffer. Empty unless the core is oversubscribed, so a
    /// wholesale copy per speculative burst is cheap — and writing into
    /// the `SpecLog`'s retained Vec (§Perf) means the snapshot allocates
    /// nothing after the first burst.
    pub(crate) fn spec_save_spines_into(&self, saved: &mut Vec<Time>) {
        saved.clear();
        saved.extend_from_slice(&self.spine_free);
    }

    pub(crate) fn spec_restore_spines(&mut self, saved: &[Time]) {
        self.spine_free.copy_from_slice(saved);
    }
}

/// One in-flight message leg after the sender-side phase: the candidate
/// arrival time (before destination contention), the canonical tie-break
/// key, and the spine-entry time for oversubscribed cores.
///
/// Node ids are stored as `u32` (§Scale: a `Transit` rides in every
/// event-queue entry, inbox slot, and speculation redo log — at the
/// hyper tier that is millions of live flights, and two `usize` ids per
/// flight were 8 wasted bytes each). The fabric API still speaks
/// `usize`; the cast happens only at Flight construction/consumption,
/// and `u32::MAX` nodes is ~4 × 10⁹ — four decades past the 2^20-node
/// hyper tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Flight {
    /// Candidate arrival at `dst` (propagation + tail + retransmits
    /// applied; destination queueing not yet).
    pub at: Time,
    pub src: u32,
    pub dst: u32,
    /// Source-local flight sequence number (unique per `src`).
    pub ctr: u64,
    /// When the packet reaches the spine layer (used only when the core
    /// is oversubscribed and the path crosses leaves).
    pub spine_at: Time,
    /// Whether the path crosses leaves (computed once on the send side;
    /// admission reuses it instead of re-deriving the hop count).
    pub cross_leaf: bool,
}

/// The fabric: topology + config + seed (immutable during a run), plus a
/// fabric-owned lane pair covering every node for the classic
/// immediate-admission API used by tests and micro-benches. The solo
/// lanes are built lazily on first classic-API use — engine runs build
/// their own per-shard lanes and must not pay O(nodes) for an unused
/// pair (65,536 RNG derivations at the paper tier).
pub struct Fabric {
    pub topo: Topology,
    pub cfg: NetConfig,
    seed: u64,
    stats: NetStats,
    solo: Option<Box<(TxLane, RxLane)>>,
}

impl Fabric {
    pub fn new(topo: Topology, cfg: NetConfig, seed: u64) -> Self {
        Fabric { topo, cfg, seed, stats: NetStats::default(), solo: None }
    }

    /// Lane pair for the classic immediate-admission API, built on first
    /// use.
    fn solo_lanes(&mut self) -> (&Topology, &NetConfig, &mut NetStats, &mut TxLane, &mut RxLane) {
        if self.solo.is_none() {
            self.solo = Some(Box::new((
                tx_lane_for(self.seed, 0..self.topo.nodes),
                rx_lane_for(&self.topo, &self.cfg, 0..self.topo.nodes),
            )));
        }
        let Fabric { topo, cfg, stats, solo, .. } = self;
        let (tx, rx) = &mut **solo.as_mut().expect("just built");
        (topo, cfg, stats, tx, rx)
    }

    pub fn stats(&self) -> &NetStats {
        &self.stats
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn multicast_supported(&self) -> bool {
        self.cfg.multicast
    }

    /// Conservative lower bound on `arrival − send-ready` over every
    /// possible message: minimum serialization (empty payload) plus
    /// loopback propagation (2×NIC overhead — the shortest path any
    /// self-send can take). This is the safe lookahead for the parallel
    /// executor's conservative time windows: an event processed at time
    /// `t` can only schedule new events at `≥ t + min_latency()`.
    ///
    /// Degenerate configs (zero NIC overhead *and* zero header bytes) can
    /// make this zero; the parallel executor then falls back to the
    /// sequential backend (DESIGN.md §7).
    pub fn min_latency(&self) -> Time {
        self.cfg.serialization(0) + self.cfg.propagation(0, 0)
    }

    /// Sender-side state for the nodes in `range` (engine/shard API).
    pub fn tx_lane(&self, range: std::ops::Range<usize>) -> TxLane {
        tx_lane_for(self.seed, range)
    }

    /// Destination-side state for the nodes in `range`. When the core is
    /// oversubscribed the range must start on a leaf boundary (spine
    /// downlink registers are per-leaf and must not straddle lanes).
    pub fn rx_lane(&self, range: std::ops::Range<usize>) -> RxLane {
        rx_lane_for(&self.topo, &self.cfg, range)
    }

    // ------------------------------------------------------- phase 1: send

    /// Sender side of one unicast: egress busy-until + serialization,
    /// then the per-source loss/RTO and tail draws and propagation.
    /// Returns the [`Flight`] to admit at the destination.
    pub fn send(
        &self,
        tx: &mut TxLane,
        stats: &mut NetStats,
        src: usize,
        dst: usize,
        payload_bytes: u64,
        ready: Time,
    ) -> Flight {
        send_impl(&self.topo, &self.cfg, tx, stats, src, dst, payload_bytes, ready)
    }

    /// Sender side of a multicast: the packet serializes **once** onto
    /// the source egress link (paper §5.3: switches cache + replicate).
    /// Returns the on-wire time to feed every member's [`Fabric::mcast_leg`].
    /// Panics if multicast is disabled — callers must degrade to unicast
    /// loops themselves (that asymmetry is exactly the §6.2.3 experiment).
    pub fn mcast_depart(
        &self,
        tx: &mut TxLane,
        stats: &mut NetStats,
        src: usize,
        payload_bytes: u64,
        ready: Time,
    ) -> Time {
        mcast_depart_impl(&self.cfg, tx, stats, src, payload_bytes, ready)
    }

    /// One member leg of a multicast (loss/tail drawn per member, in
    /// member order, from the source stream).
    pub fn mcast_leg(
        &self,
        tx: &mut TxLane,
        stats: &mut NetStats,
        src: usize,
        dst: usize,
        on_wire: Time,
    ) -> Flight {
        leg_impl(&self.topo, &self.cfg, tx, stats, src, dst, on_wire)
    }

    /// Mint a [`Flight`] for a core-local timer: `src` re-delivers to
    /// itself at exactly `at`, off the fabric. The flight consumes one
    /// slot of the source's send counter (so it orders canonically with
    /// real sends from the same node) but draws nothing from the RNG
    /// stream, counts no traffic, and never touches the egress register —
    /// a run's network physics are identical with or without timers.
    pub fn timer(&self, tx: &mut TxLane, src: usize, at: Time) -> Flight {
        let slot = src - tx.base;
        let ctr = tx.ctr[slot];
        tx.ctr[slot] += 1;
        Flight { at, src: src as u32, dst: src as u32, ctr, spine_at: at, cross_leaf: false }
    }

    // ------------------------------------------------------ phase 2: admit

    /// Destination side of one flight: oversubscribed-spine queueing (when
    /// configured and the path crosses leaves) plus ingress
    /// store-and-forward on the destination downlink. Flights **must** be
    /// admitted in canonical `(at, src, ctr)` order per destination lane —
    /// the executors' event queues guarantee it. Returns the delivery time.
    pub fn admit(
        &self,
        rx: &mut RxLane,
        stats: &mut NetStats,
        flight: &Flight,
        payload_bytes: u64,
    ) -> Time {
        admit_impl(&self.topo, &self.cfg, rx, stats, flight, payload_bytes)
    }

    // ------------------------------------- classic immediate-admission API

    /// Inject one unicast message at `depart_ready` (the moment the sender
    /// core hands it to the NIC) and admit it immediately. Returns the
    /// delivery time at `dst`. Test/bench convenience: the executors use
    /// the two-phase API and admit in canonical order instead.
    pub fn unicast(
        &mut self,
        src: usize,
        dst: usize,
        payload_bytes: u64,
        depart_ready: Time,
    ) -> Time {
        let (topo, cfg, stats, tx, rx) = self.solo_lanes();
        let flight = send_impl(topo, cfg, tx, stats, src, dst, payload_bytes, depart_ready);
        admit_impl(topo, cfg, rx, stats, &flight, payload_bytes)
    }

    /// Inject one multicast message to every node in `members` and admit
    /// each leg immediately. Returns per-member delivery times.
    pub fn multicast(
        &mut self,
        src: usize,
        members: &[usize],
        payload_bytes: u64,
        depart_ready: Time,
    ) -> Vec<(usize, Time)> {
        let mut out = Vec::with_capacity(members.len());
        self.multicast_into(src, members.iter().copied(), payload_bytes, depart_ready, &mut out);
        out
    }

    /// [`Fabric::multicast`] over any member iterator, appending the
    /// per-member delivery times to `out` — range-shaped groups (§Scale:
    /// 65,536-member level-0 groups) stream through without ever
    /// materializing a member list.
    pub fn multicast_into(
        &mut self,
        src: usize,
        members: impl IntoIterator<Item = usize>,
        payload_bytes: u64,
        depart_ready: Time,
        out: &mut Vec<(usize, Time)>,
    ) {
        let (topo, cfg, stats, tx, rx) = self.solo_lanes();
        let on_wire = mcast_depart_impl(cfg, tx, stats, src, payload_bytes, depart_ready);
        for dst in members {
            let flight = leg_impl(topo, cfg, tx, stats, src, dst, on_wire);
            let t = admit_impl(topo, cfg, rx, stats, &flight, payload_bytes);
            out.push((dst, t));
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn send_impl(
    topo: &Topology,
    cfg: &NetConfig,
    tx: &mut TxLane,
    stats: &mut NetStats,
    src: usize,
    dst: usize,
    payload_bytes: u64,
    ready: Time,
) -> Flight {
    stats.msgs_sent += 1;
    let ser = cfg.serialization(payload_bytes);
    let slot = src - tx.base;
    let depart = ready.max(tx.egress_free[slot]);
    tx.egress_free[slot] = depart + ser;
    leg_impl(topo, cfg, tx, stats, src, dst, depart + ser)
}

fn mcast_depart_impl(
    cfg: &NetConfig,
    tx: &mut TxLane,
    stats: &mut NetStats,
    src: usize,
    payload_bytes: u64,
    ready: Time,
) -> Time {
    assert!(cfg.multicast, "multicast disabled in this fabric");
    stats.msgs_sent += 1;
    stats.multicasts += 1;
    let ser = cfg.serialization(payload_bytes);
    let slot = src - tx.base;
    let depart = ready.max(tx.egress_free[slot]);
    tx.egress_free[slot] = depart + ser;
    depart + ser
}

/// From "fully on the wire at src" to the candidate arrival at dst.
fn leg_impl(
    topo: &Topology,
    cfg: &NetConfig,
    tx: &mut TxLane,
    stats: &mut NetStats,
    src: usize,
    dst: usize,
    on_wire: Time,
) -> Flight {
    let slot = src - tx.base;
    let hops = topo.hops(src, dst);
    let prop = cfg.propagation(hops.links, hops.switches);
    // Tail injection (perturbation, default off): drawn from the sender's
    // stream so the pattern is a pure function of (seed, src, send
    // sequence). Draws nothing when disabled.
    let (tn, td) = cfg.tail_prob;
    let tail = if tn > 0 && tx.rng[slot].chance(tn, td) {
        stats.tail_hits += 1;
        Time::from_ns(cfg.tail_extra_ns)
    } else {
        Time::ZERO
    };
    // Lossy link (perturbation, default off): each lost attempt costs one
    // retransmit timeout at the sender before the packet goes back on the
    // wire. Capped at 64 consecutive losses (p <= loss^64) to bound
    // pathological configurations.
    let (ln, ld) = cfg.loss_prob;
    let mut sent_at = on_wire;
    if ln > 0 {
        let mut attempts = 0;
        while attempts < 64 && tx.rng[slot].chance(ln, ld) {
            attempts += 1;
            stats.retransmits += 1;
            sent_at += Time::from_ns(cfg.rto_ns);
        }
    }
    let ctr = tx.ctr[slot];
    tx.ctr[slot] += 1;
    Flight {
        at: sent_at + prop + tail,
        src: src as u32,
        dst: dst as u32,
        ctr,
        // The packet reaches the spine roughly halfway along the path.
        spine_at: sent_at + Time(prop.0 / 2),
        cross_leaf: hops.switches >= 3,
    }
}

fn admit_impl(
    topo: &Topology,
    cfg: &NetConfig,
    rx: &mut RxLane,
    stats: &mut NetStats,
    flight: &Flight,
    payload_bytes: u64,
) -> Time {
    let ser = cfg.serialization(payload_bytes);
    let mut at = flight.at;
    if rx.spines_per_leaf > 0 && flight.cross_leaf {
        // Oversubscribed core (perturbation, default off): packets into
        // this leaf contend for its reduced set of spine downlink
        // registers instead of the non-blocking full-bisection core.
        let leaf = topo.leaf_of(flight.dst as usize);
        let s = ecmp_spine(flight.src as usize, flight.dst as usize, rx.spines_per_leaf);
        let reg = (leaf - rx.leaf_base) * rx.spines_per_leaf + s;
        let spine_start = flight.spine_at.max(rx.spine_free[reg]);
        rx.spine_free[reg] = spine_start + ser;
        at += spine_start.saturating_sub(flight.spine_at);
    }
    // Store-and-forward on the destination downlink: the message can only
    // start occupying it once the link is free.
    let slot = flight.dst as usize - rx.base;
    let start = at.max(rx.ingress_free[slot]);
    let arrival = start + ser;
    rx.ingress_free[slot] = arrival;
    stats.msgs_delivered += 1;
    stats.payload_bytes += payload_bytes;
    stats.wire_bytes += payload_bytes + cfg.header_bytes;
    arrival
}

fn tx_lane_for(seed: u64, range: std::ops::Range<usize>) -> TxLane {
    let n = range.len();
    let root = SplitMix64::new(seed ^ NET_SALT);
    // Per-node streams derived from the run seed and the absolute node
    // id, so a node's draw sequence is identical under any sharding.
    TxLane {
        base: range.start,
        egress_free: vec![Time::ZERO; n],
        rng: range.map(|node| root.derive(node as u64)).collect(),
        ctr: vec![0; n],
    }
}

fn rx_lane_for(topo: &Topology, cfg: &NetConfig, range: std::ops::Range<usize>) -> RxLane {
    let n = range.len();
    let spines_per_leaf = cfg.spines_per_leaf(topo.leaf_radix);
    let leaf_base = topo.leaf_of(range.start);
    let leaves = if n == 0 {
        0
    } else {
        assert!(
            spines_per_leaf == 0 || range.start % topo.leaf_radix == 0,
            "oversubscribed rx lanes must start on a leaf boundary"
        );
        topo.leaf_of(range.end - 1) - leaf_base + 1
    };
    RxLane {
        base: range.start,
        ingress_free: vec![Time::ZERO; n],
        leaf_base,
        spines_per_leaf,
        spine_free: vec![Time::ZERO; leaves * spines_per_leaf],
    }
}

/// Deterministic ECMP-style spine pick for a (src, dst) flow.
fn ecmp_spine(src: usize, dst: usize, spines: usize) -> usize {
    let mut h = (src as u64).wrapping_shl(32) ^ dst as u64;
    h = (h ^ (h >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    ((h ^ (h >> 31)) % spines as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric(nodes: usize) -> Fabric {
        Fabric::new(Topology::paper(nodes), NetConfig::default(), 1)
    }

    #[test]
    fn serialization_grid_exact() {
        let cfg = NetConfig::default();
        // 16 B payload + 24 B header = 40 B = 320 bits @200G = 1.6 ns.
        let t = cfg.serialization(16);
        assert_eq!(t.0, (320u64 * 16).div_ceil(200)); // 25.6 units -> 26
        assert!((t.as_ns_f64() - 1.6).abs() < 0.1);
    }

    #[test]
    fn loopback_near_69ns() {
        // Table 1: nanoPU wire-to-wire loopback ≈ 69 ns. Our split:
        // tx core cost + 2×NIC overhead + rx core cost ≈ 68—70 ns.
        let core = crate::cpu::CoreModel::default();
        let cfg = NetConfig::default();
        let total = core.tx_time(8)
            + cfg.propagation(0, 0)
            + cfg.serialization(8)
            + core.rx_time(8);
        let ns = total.as_ns_f64();
        assert!((60.0..78.0).contains(&ns), "loopback = {ns} ns");
    }

    #[test]
    fn min_latency_is_loopback_plus_header_serialization() {
        let f = fabric(64);
        let cfg = NetConfig::default();
        assert_eq!(f.min_latency(), cfg.serialization(0) + cfg.propagation(0, 0));
        assert!(f.min_latency() > Time::ZERO, "default config has positive lookahead");
        // Degenerate config: no NIC overhead, no header -> zero lookahead
        // (the parallel executor must fall back to sequential).
        let zero = NetConfig { nic_overhead_ns: 0, header_bytes: 0, ..NetConfig::default() };
        assert_eq!(Fabric::new(Topology::paper(4), zero, 1).min_latency(), Time::ZERO);
    }

    #[test]
    fn same_leaf_vs_cross_leaf() {
        let mut f = fabric(256);
        let t_same = f.unicast(0, 1, 16, Time::ZERO);
        let t_cross = f.unicast(0, 200, 16, Time::ZERO);
        // same leaf: 2 links + 1 switch; cross: 4 links + 3 switches
        let diff = t_cross.as_ns_f64() - t_same.as_ns_f64();
        assert!((diff - (2.0 * 43.0 + 2.0 * 263.0)).abs() < 2.0, "diff = {diff}");
    }

    #[test]
    fn ingress_contention_serializes_incast() {
        let mut f = fabric(128);
        // 64 senders hit node 0 simultaneously with 104 B records.
        let arrivals: Vec<Time> =
            (1..65).map(|s| f.unicast(s, 0, 104, Time::ZERO)).collect();
        let mut sorted = arrivals.clone();
        sorted.sort();
        assert_eq!(arrivals, sorted, "in-order handling");
        // Each message occupies the downlink for ser(104+24)=5.12 ns; the
        // last of 64 must be >= 63 serializations after the first.
        let span = arrivals[63].saturating_sub(arrivals[0]).as_ns_f64();
        assert!(span >= 63.0 * 5.0, "span = {span}");
    }

    #[test]
    fn egress_contention_serializes_fanout() {
        let mut f = fabric(128);
        let t1 = f.unicast(0, 1, 1000, Time::ZERO);
        let t2 = f.unicast(0, 2, 1000, Time::ZERO);
        // Second message waits behind the first on node 0's egress link.
        assert!(t2 > t1);
        let gap = t2.saturating_sub(t1).as_ns_f64();
        let ser = NetConfig::default().serialization(1000).as_ns_f64();
        assert!((gap - ser).abs() < 1.0, "gap {gap} vs ser {ser}");
    }

    /// The two-phase lane API and the classic immediate-admission path
    /// are the same physics: identical arrivals for the same sequence.
    #[test]
    fn lane_api_matches_solo_path() {
        let legs: &[(usize, usize, u64)] =
            &[(0, 1, 16), (2, 1, 104), (0, 200, 64), (5, 1, 8), (200, 0, 16)];
        let mut solo = fabric(256);
        let solo_arrivals: Vec<Time> = legs
            .iter()
            .enumerate()
            .map(|(i, &(s, d, b))| solo.unicast(s, d, b, Time::from_ns(i as u64)))
            .collect();

        let f = fabric(256);
        let mut tx = f.tx_lane(0..256);
        let mut rx = f.rx_lane(0..256);
        let mut stats = NetStats::default();
        let lane_arrivals: Vec<Time> = legs
            .iter()
            .enumerate()
            .map(|(i, &(s, d, b))| {
                let flight = f.send(&mut tx, &mut stats, s, d, b, Time::from_ns(i as u64));
                f.admit(&mut rx, &mut stats, &flight, b)
            })
            .collect();
        assert_eq!(solo_arrivals, lane_arrivals);
        assert_eq!(stats.msgs_sent, legs.len() as u64);
        assert_eq!(stats.msgs_delivered, legs.len() as u64);
    }

    /// Per-source RNG streams and send counters: one node's flight
    /// schedule is unaffected by what other nodes send in between — the
    /// property that makes sharded execution deterministic.
    #[test]
    fn flights_of_one_source_are_interleaving_independent() {
        let cfg = NetConfig {
            tail_prob: (1, 4),
            tail_extra_ns: 1_000,
            loss_prob: (1, 4),
            rto_ns: 2_000,
            ..NetConfig::default()
        };
        let run = |interleave: bool| -> Vec<Flight> {
            let f = Fabric::new(Topology::paper(128), cfg.clone(), 9);
            let mut tx = f.tx_lane(0..128);
            let mut stats = NetStats::default();
            let mut flights = Vec::new();
            for i in 0..50u64 {
                if interleave {
                    // Noise from another source between every send.
                    f.send(&mut tx, &mut stats, 7, 9, 64, Time::from_ns(i));
                }
                flights.push(f.send(&mut tx, &mut stats, 3, 5, 32, Time::from_ns(i)));
            }
            flights
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn flight_ctr_is_a_per_source_sequence() {
        let f = fabric(64);
        let mut tx = f.tx_lane(0..64);
        let mut stats = NetStats::default();
        let a = f.send(&mut tx, &mut stats, 1, 2, 8, Time::ZERO);
        let b = f.send(&mut tx, &mut stats, 1, 3, 8, Time::ZERO);
        let c = f.send(&mut tx, &mut stats, 2, 3, 8, Time::ZERO);
        assert_eq!((a.ctr, b.ctr), (0, 1), "per-source counter increments");
        assert_eq!(c.ctr, 0, "other sources have their own counter");
    }

    #[test]
    fn multicast_serializes_once_counts_once() {
        let mut f = fabric(256);
        let members: Vec<usize> = (1..100).collect();
        let deliveries = f.multicast(0, &members, 128, Time::ZERO);
        assert_eq!(deliveries.len(), 99);
        assert_eq!(f.stats().msgs_sent, 1);
        assert_eq!(f.stats().multicasts, 1);
        assert_eq!(f.stats().msgs_delivered, 99);
        // Sender egress used once: a follow-up unicast departs right after
        // ONE serialization, not 99.
        let t = f.unicast(0, 1, 128, Time::ZERO);
        let one_ser = NetConfig::default().serialization(128);
        let two_ser_ns = 2.0 * one_ser.as_ns_f64();
        assert!(
            t.as_ns_f64() < two_ser_ns + 800.0,
            "egress was serialized per member"
        );
    }

    #[test]
    fn multicast_into_matches_multicast_exactly() {
        let mk = || fabric(256);
        let members: Vec<usize> = (1..50).collect();
        let mut a = mk();
        let via_vec = a.multicast(0, &members, 64, Time::ZERO);
        let mut b = mk();
        let mut scratch = Vec::new();
        b.multicast_into(0, 1..50, 64, Time::ZERO, &mut scratch);
        assert_eq!(via_vec, scratch, "range iterator path must be identical");
        assert_eq!(a.stats().msgs_delivered, b.stats().msgs_delivered);
        // The scratch buffer appends, so callers can reuse it.
        scratch.clear();
        b.multicast_into(50, 51..60, 16, Time::ZERO, &mut scratch);
        assert_eq!(scratch.len(), 9);
    }

    #[test]
    #[should_panic(expected = "multicast disabled")]
    fn multicast_panics_when_disabled() {
        let cfg = NetConfig { multicast: false, ..NetConfig::default() };
        let mut f = Fabric::new(Topology::paper(64), cfg, 1);
        f.multicast(0, &[1, 2], 16, Time::ZERO);
    }

    #[test]
    fn tail_injection_rate() {
        let cfg = NetConfig {
            tail_prob: (1, 100),
            tail_extra_ns: 4000,
            ..NetConfig::default()
        };
        let mut f = Fabric::new(Topology::paper(64), cfg, 7);
        for i in 0..20_000 {
            f.unicast(i % 64, (i + 1) % 64, 16, Time::from_ns(i as u64));
        }
        let rate = f.stats().tail_hits as f64 / 20_000.0;
        assert!((0.005..0.02).contains(&rate), "tail rate = {rate}");
    }

    /// Property sweep: for random message sequences, every arrival is
    /// strictly after its hand-off (positive latency — the calendar queue
    /// in sim/exec relies on this), and counters conserve.
    #[test]
    fn property_arrivals_after_ready_and_counters_conserve() {
        use crate::sim::SplitMix64;
        let mut rng = SplitMix64::new(0xFAB);
        for trial in 0..20 {
            let nodes = 2 + rng.index(500);
            let cfg = if rng.chance(1, 2) {
                NetConfig { tail_prob: (1, 20), tail_extra_ns: 1000, ..NetConfig::default() }
            } else {
                NetConfig::default()
            };
            let mut f = Fabric::new(Topology::paper(nodes), cfg, trial);
            let msgs = 200;
            let mut now = Time::ZERO;
            for _ in 0..msgs {
                now += Time::from_ns(rng.next_below(50));
                let src = rng.index(nodes);
                let dst = rng.index(nodes);
                let bytes = 8 + rng.next_below(200);
                let arrival = f.unicast(src, dst, bytes, now);
                assert!(arrival > now, "arrival {arrival} !> ready {now}");
                // The lookahead contract: no arrival before ready + min_latency.
                assert!(arrival >= now + f.min_latency(), "lookahead violated");
            }
            let s = f.stats();
            assert_eq!(s.msgs_sent, msgs);
            assert_eq!(s.msgs_delivered, msgs);
            assert_eq!(s.wire_bytes, s.payload_bytes + msgs * 24);
        }
    }

    #[test]
    fn loss_injects_retransmit_delay_deterministically() {
        let mk = || {
            let cfg = NetConfig {
                loss_prob: (2000, 10_000), // 20%
                rto_ns: 5_000,
                ..NetConfig::default()
            };
            Fabric::new(Topology::paper(128), cfg, 9)
        };
        let run = |mut f: Fabric| -> (Vec<Time>, u64) {
            let arrivals = (0..2_000)
                .map(|i| f.unicast(i % 128, (i + 7) % 128, 64, Time::from_ns(i as u64)))
                .collect();
            (arrivals, f.stats().retransmits)
        };
        let (a, ra) = run(mk());
        let (b, rb) = run(mk());
        assert_eq!(a, b, "same seed + loss rate must replay identically");
        assert_eq!(ra, rb);
        // ~20% of 2,000 attempts lose at least once.
        assert!((200..1000).contains(&(ra as usize)), "retransmits = {ra}");
        // Retransmitted messages arrive an RTO multiple later.
        let lossless = {
            let mut f = fabric(128);
            (0..2_000)
                .map(|i| f.unicast(i % 128, (i + 7) % 128, 64, Time::from_ns(i as u64)))
                .collect::<Vec<Time>>()
        };
        assert!(a.iter().zip(&lossless).all(|(x, y)| x >= y));
        assert!(a.iter().zip(&lossless).any(|(x, y)| x > y));
    }

    #[test]
    fn disabled_loss_draws_nothing_from_the_rng_stream() {
        // Two fabrics, same seed, both with tail injection on; one also
        // carries a loss config with numerator 0. If the loss gate drew
        // from the per-node streams, the tail pattern (and arrivals)
        // would diverge.
        let tail_cfg = NetConfig {
            tail_prob: (1, 50),
            tail_extra_ns: 2_000,
            ..NetConfig::default()
        };
        let with_zero_loss = NetConfig {
            loss_prob: (0, 10_000),
            rto_ns: 99_999,
            ..tail_cfg.clone()
        };
        let run = |cfg: NetConfig| -> Vec<Time> {
            let mut f = Fabric::new(Topology::paper(64), cfg, 5);
            (0..500)
                .map(|i| f.unicast(i % 64, (i + 3) % 64, 32, Time::from_ns(i as u64)))
                .collect()
        };
        assert_eq!(run(tail_cfg), run(with_zero_loss));
    }

    #[test]
    fn oversubscription_queues_cross_leaf_traffic() {
        // 64-fold oversubscription leaves one spine downlink per leaf:
        // an incast burst into one leaf serializes through it.
        let cfg = NetConfig { oversub: 64, ..NetConfig::default() };
        let mut over = Fabric::new(Topology::paper(256), cfg.clone(), 1);
        let mut full = fabric(256);
        let arrivals = |f: &mut Fabric| {
            (0..64).map(|i| f.unicast(i, 128 + i, 256, Time::ZERO)).collect::<Vec<Time>>()
        };
        let a_over = arrivals(&mut over);
        let a_full = arrivals(&mut full);
        assert!(a_over.iter().zip(&a_full).all(|(o, f)| o >= f));
        assert!(
            a_over.last().unwrap() > a_full.last().unwrap(),
            "spine contention must delay the tail of an incast burst"
        );
        // Same-leaf traffic never touches a spine.
        let mut over = Fabric::new(Topology::paper(256), cfg, 1);
        let mut full = fabric(256);
        assert_eq!(over.unicast(0, 1, 64, Time::ZERO), full.unicast(0, 1, 64, Time::ZERO));
    }

    #[test]
    fn oversub_one_approximates_full_bisection_for_disjoint_flows() {
        // With the full spine count (oversub = 1) a single cross-leaf
        // message sees no added queueing.
        let cfg = NetConfig { oversub: 1, ..NetConfig::default() };
        let mut f1 = Fabric::new(Topology::paper(256), cfg, 1);
        let mut f0 = fabric(256);
        assert_eq!(f1.unicast(0, 200, 64, Time::ZERO), f0.unicast(0, 200, 64, Time::ZERO));
    }

    #[test]
    #[should_panic(expected = "leaf boundary")]
    fn oversubscribed_rx_lane_must_be_leaf_aligned() {
        let cfg = NetConfig { oversub: 64, ..NetConfig::default() };
        let f = Fabric::new(Topology::paper(256), cfg, 1);
        let _ = f.rx_lane(10..20);
    }

    #[test]
    fn counters_accumulate() {
        let mut f = fabric(64);
        f.unicast(0, 1, 16, Time::ZERO);
        f.unicast(1, 2, 104, Time::ZERO);
        let s = f.stats();
        assert_eq!(s.msgs_sent, 2);
        assert_eq!(s.msgs_delivered, 2);
        assert_eq!(s.payload_bytes, 120);
        assert_eq!(s.wire_bytes, 120 + 48);
    }

    #[test]
    fn netstats_merge_is_field_wise_sum() {
        let mut a = NetStats { msgs_sent: 1, msgs_delivered: 2, ..NetStats::default() };
        let b = NetStats { msgs_sent: 10, retransmits: 3, ..NetStats::default() };
        a.merge(&b);
        assert_eq!(a.msgs_sent, 11);
        assert_eq!(a.msgs_delivered, 2);
        assert_eq!(a.retransmits, 3);
    }
}
