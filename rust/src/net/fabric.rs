//! The network fabric: per-message latency, endpoint-link contention,
//! reliable multicast (paper §5.3), and tail-latency injection (Fig 14).
//!
//! Latency of a unicast message =
//!   NIC egress overhead + serialization + links·43 ns + switches·263 ns +
//!   NIC ingress overhead, with store-and-forward serialization on the
//!   destination link (which is what makes incast expensive) and an
//!   injected extra delay on a configurable fraction of messages (p99 tail).
//!
//! With full bisection (paper §5.1) the core is non-blocking, so contention
//! is modeled only at the endpoint links — source NIC egress and
//! destination leaf-downlink ingress — each a simple busy-until register.

use crate::sim::{SplitMix64, Time};

use super::topology::Topology;

/// All network knobs (defaults = paper §5.1 constants).
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Per-link propagation latency, ns (paper: 43).
    pub link_latency_ns: u64,
    /// Per-switch latency, ns (paper: 263; Fig 15 sweeps this).
    pub switch_latency_ns: u64,
    /// Link bandwidth in Gbit/s (paper: 200).
    pub bandwidth_gbps: u64,
    /// Fixed NIC/MAC overhead per direction, ns. Calibrated so that the
    /// wire-to-wire loopback through a core ≈ 69 ns (Table 1).
    pub nic_overhead_ns: u64,
    /// Wire framing per message (Ethernet + nanoPU headers), bytes.
    pub header_bytes: u64,
    /// Switches replicate multicast packets (paper §5.3). When false,
    /// group sends degrade to sender-side unicast loops.
    pub multicast: bool,
    /// Fraction of messages (numerator / denominator) that suffer
    /// `tail_extra_ns` of additional latency (Fig 14 injects at p99).
    pub tail_prob: (u64, u64),
    /// Extra latency for tail-affected messages, ns.
    pub tail_extra_ns: u64,
    /// Per-delivery drop probability (numerator / denominator); default
    /// `(0, 1)` = the paper's lossless links. Each lost transmission
    /// attempt costs [`NetConfig::rto_ns`] at the sender before the
    /// packet is retransmitted; drops are deterministic via the fabric's
    /// seeded `SplitMix64` (and draw *nothing* from it when disabled, so
    /// lossless runs stay bit-identical).
    pub loss_prob: (u64, u64),
    /// Retransmit timeout, ns (only relevant when `loss_prob` is on).
    pub rto_ns: u64,
    /// Core oversubscription factor. `0` (default) is the paper's §5.1
    /// non-blocking full-bisection core; `f >= 1` gives the fabric only
    /// `leaf_radix / f` spine paths, each a store-and-forward busy-until
    /// register that cross-leaf packets contend for (deterministic
    /// ECMP-style spine choice).
    pub oversub: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            link_latency_ns: 43,
            switch_latency_ns: 263,
            bandwidth_gbps: 200,
            nic_overhead_ns: 28,
            header_bytes: 24,
            multicast: true,
            tail_prob: (0, 100),
            tail_extra_ns: 0,
            loss_prob: (0, 1),
            rto_ns: 10_000,
            oversub: 0,
        }
    }
}

impl NetConfig {
    /// Serialization time of `bytes` (payload + header) at line rate.
    /// 200 Gbps = 0.04 ns/byte = 0.64 time-units/byte (exact on the grid
    /// for the default config).
    pub fn serialization(&self, payload_bytes: u64) -> Time {
        let bytes = payload_bytes + self.header_bytes;
        let bits = bytes * 8;
        // units = bits / (gbps) * 16 ; round up to the grid.
        Time((bits * 16).div_ceil(self.bandwidth_gbps))
    }

    /// Pure propagation latency (no serialization/contention) for a path.
    pub fn propagation(&self, links: u64, switches: u64) -> Time {
        Time::from_ns(
            2 * self.nic_overhead_ns
                + links * self.link_latency_ns
                + switches * self.switch_latency_ns,
        )
    }
}

/// Traffic counters (Fig 11b and the §6.2.3 multicast experiment report
/// message counts).
#[derive(Debug, Clone, Default)]
pub struct NetStats {
    /// Messages injected by senders (a multicast counts once).
    pub msgs_sent: u64,
    /// Messages delivered to receivers (a multicast counts per member).
    pub msgs_delivered: u64,
    /// Total payload bytes delivered.
    pub payload_bytes: u64,
    /// Total wire bytes (payload + headers) crossing the destination link.
    pub wire_bytes: u64,
    /// Messages that got the injected tail penalty.
    pub tail_hits: u64,
    /// Multicast sends (subset of msgs_sent).
    pub multicasts: u64,
    /// Transmission attempts lost and retransmitted (0 on lossless
    /// fabrics). Delivered/byte counters count the final delivery only.
    pub retransmits: u64,
}

/// The fabric: topology + config + endpoint-link occupancy + counters.
pub struct Fabric {
    pub topo: Topology,
    pub cfg: NetConfig,
    stats: NetStats,
    egress_free: Vec<Time>,
    ingress_free: Vec<Time>,
    /// Spine busy-until registers (empty unless `cfg.oversub > 0`).
    spine_free: Vec<Time>,
    rng: SplitMix64,
}

impl Fabric {
    pub fn new(topo: Topology, cfg: NetConfig, seed: u64) -> Self {
        let n = topo.nodes;
        let spines = if cfg.oversub > 0 {
            (topo.leaf_radix as u64 / cfg.oversub).max(1) as usize
        } else {
            0
        };
        Fabric {
            topo,
            cfg,
            stats: NetStats::default(),
            egress_free: vec![Time::ZERO; n],
            ingress_free: vec![Time::ZERO; n],
            spine_free: vec![Time::ZERO; spines],
            rng: SplitMix64::new(seed ^ 0x6e65_745f_7461_696c),
        }
    }

    pub fn stats(&self) -> &NetStats {
        &self.stats
    }

    pub fn multicast_supported(&self) -> bool {
        self.cfg.multicast
    }

    fn tail_penalty(&mut self) -> Time {
        let (num, den) = self.cfg.tail_prob;
        if num > 0 && self.rng.chance(num, den) {
            self.stats.tail_hits += 1;
            Time::from_ns(self.cfg.tail_extra_ns)
        } else {
            Time::ZERO
        }
    }

    /// Inject one unicast message at `depart_ready` (the moment the sender
    /// core hands it to the NIC). Returns the delivery time at `dst`.
    pub fn unicast(
        &mut self,
        src: usize,
        dst: usize,
        payload_bytes: u64,
        depart_ready: Time,
    ) -> Time {
        let arrival = self.route(src, dst, payload_bytes, depart_ready, true);
        self.stats.msgs_sent += 1;
        arrival
    }

    /// Inject one multicast message to every node in `members` (paper §5.3:
    /// switches cache + replicate, so the sender serializes once).
    /// Returns per-member delivery times. Panics if multicast is disabled —
    /// callers must degrade to unicast loops themselves (that asymmetry is
    /// exactly the §6.2.3 experiment).
    pub fn multicast(
        &mut self,
        src: usize,
        members: &[usize],
        payload_bytes: u64,
        depart_ready: Time,
    ) -> Vec<(usize, Time)> {
        let mut out = Vec::with_capacity(members.len());
        self.multicast_into(src, members.iter().copied(), payload_bytes, depart_ready, &mut out);
        out
    }

    /// [`Fabric::multicast`] over any member iterator, appending the
    /// per-member delivery times to `out` — the batched-injection path:
    /// the engine reuses one scratch buffer across all group sends, and
    /// range-shaped groups (§Scale: 65,536-member level-0 groups) stream
    /// through without ever materializing a member list.
    pub fn multicast_into(
        &mut self,
        src: usize,
        members: impl IntoIterator<Item = usize>,
        payload_bytes: u64,
        depart_ready: Time,
        out: &mut Vec<(usize, Time)>,
    ) {
        assert!(self.cfg.multicast, "multicast disabled in this fabric");
        self.stats.msgs_sent += 1;
        self.stats.multicasts += 1;
        // Sender serializes the packet once onto its egress link.
        let ser = self.cfg.serialization(payload_bytes);
        let depart = depart_ready.max(self.egress_free[src]);
        self.egress_free[src] = depart + ser;
        for dst in members {
            let t = self.deliver_leg(src, dst, payload_bytes, depart + ser);
            out.push((dst, t));
        }
    }

    /// Shared unicast path: egress serialization + propagation + ingress.
    fn route(
        &mut self,
        src: usize,
        dst: usize,
        payload_bytes: u64,
        ready: Time,
        _count: bool,
    ) -> Time {
        let ser = self.cfg.serialization(payload_bytes);
        let depart = ready.max(self.egress_free[src]);
        self.egress_free[src] = depart + ser;
        self.deliver_leg(src, dst, payload_bytes, depart + ser)
    }

    /// From "fully on the wire at src" to delivered at dst.
    fn deliver_leg(&mut self, src: usize, dst: usize, payload_bytes: u64, on_wire: Time) -> Time {
        let hops = self.topo.hops(src, dst);
        let prop = self.cfg.propagation(hops.links, hops.switches);
        let tail = self.tail_penalty();
        let ser = self.cfg.serialization(payload_bytes);
        // Lossy link (perturbation, default off): each lost attempt costs
        // one retransmit timeout at the sender before the packet goes
        // back on the wire. Drops draw from the fabric RNG only when the
        // knob is on, so lossless streams stay bit-identical. Capped at
        // 64 consecutive losses (p <= loss^64) to bound pathological
        // configurations.
        let (ln, ld) = self.cfg.loss_prob;
        let mut sent_at = on_wire;
        if ln > 0 {
            let mut attempts = 0;
            while attempts < 64 && self.rng.chance(ln, ld) {
                attempts += 1;
                self.stats.retransmits += 1;
                sent_at += Time::from_ns(self.cfg.rto_ns);
            }
        }
        let mut at = sent_at + prop + tail;
        // Oversubscribed core (perturbation, default off): cross-leaf
        // packets contend for a reduced set of spine busy-until
        // registers instead of the non-blocking full-bisection core.
        if !self.spine_free.is_empty() && hops.switches >= 3 {
            let s = ecmp_spine(src, dst, self.spine_free.len());
            // The packet reaches the spine roughly halfway along the
            // path; it occupies the spine for its serialization time.
            let at_spine = sent_at + Time(prop.0 / 2);
            let spine_start = at_spine.max(self.spine_free[s]);
            self.spine_free[s] = spine_start + ser;
            at += spine_start.saturating_sub(at_spine);
        }
        // Store-and-forward on the destination downlink: the message can
        // only start occupying it once the link is free.
        let start = at.max(self.ingress_free[dst]);
        let arrival = start + ser;
        self.ingress_free[dst] = arrival;
        self.stats.msgs_delivered += 1;
        self.stats.payload_bytes += payload_bytes;
        self.stats.wire_bytes += payload_bytes + self.cfg.header_bytes;
        arrival
    }
}

/// Deterministic ECMP-style spine pick for a (src, dst) flow.
fn ecmp_spine(src: usize, dst: usize, spines: usize) -> usize {
    let mut h = (src as u64).wrapping_shl(32) ^ dst as u64;
    h = (h ^ (h >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    ((h ^ (h >> 31)) % spines as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric(nodes: usize) -> Fabric {
        Fabric::new(Topology::paper(nodes), NetConfig::default(), 1)
    }

    #[test]
    fn serialization_grid_exact() {
        let cfg = NetConfig::default();
        // 16 B payload + 24 B header = 40 B = 320 bits @200G = 1.6 ns.
        let t = cfg.serialization(16);
        assert_eq!(t.0, (320u64 * 16).div_ceil(200)); // 25.6 units -> 26
        assert!((t.as_ns_f64() - 1.6).abs() < 0.1);
    }

    #[test]
    fn loopback_near_69ns() {
        // Table 1: nanoPU wire-to-wire loopback ≈ 69 ns. Our split:
        // tx core cost + 2×NIC overhead + rx core cost ≈ 68—70 ns.
        let core = crate::cpu::CoreModel::default();
        let cfg = NetConfig::default();
        let total = core.tx_time(8)
            + cfg.propagation(0, 0)
            + cfg.serialization(8)
            + core.rx_time(8);
        let ns = total.as_ns_f64();
        assert!((60.0..78.0).contains(&ns), "loopback = {ns} ns");
    }

    #[test]
    fn same_leaf_vs_cross_leaf() {
        let mut f = fabric(256);
        let t_same = f.unicast(0, 1, 16, Time::ZERO);
        let t_cross = f.unicast(0, 200, 16, Time::ZERO);
        // same leaf: 2 links + 1 switch; cross: 4 links + 3 switches
        let diff = t_cross.as_ns_f64() - t_same.as_ns_f64();
        assert!((diff - (2.0 * 43.0 + 2.0 * 263.0)).abs() < 2.0, "diff = {diff}");
    }

    #[test]
    fn ingress_contention_serializes_incast() {
        let mut f = fabric(128);
        // 64 senders hit node 0 simultaneously with 104 B records.
        let arrivals: Vec<Time> =
            (1..65).map(|s| f.unicast(s, 0, 104, Time::ZERO)).collect();
        let mut sorted = arrivals.clone();
        sorted.sort();
        assert_eq!(arrivals, sorted, "in-order handling");
        // Each message occupies the downlink for ser(104+24)=5.12 ns; the
        // last of 64 must be >= 63 serializations after the first.
        let span = arrivals[63].saturating_sub(arrivals[0]).as_ns_f64();
        assert!(span >= 63.0 * 5.0, "span = {span}");
    }

    #[test]
    fn egress_contention_serializes_fanout() {
        let mut f = fabric(128);
        let t1 = f.unicast(0, 1, 1000, Time::ZERO);
        let t2 = f.unicast(0, 2, 1000, Time::ZERO);
        // Second message waits behind the first on node 0's egress link.
        assert!(t2 > t1);
        let gap = t2.saturating_sub(t1).as_ns_f64();
        let ser = NetConfig::default().serialization(1000).as_ns_f64();
        assert!((gap - ser).abs() < 1.0, "gap {gap} vs ser {ser}");
    }

    #[test]
    fn multicast_serializes_once_counts_once() {
        let mut f = fabric(256);
        let members: Vec<usize> = (1..100).collect();
        let deliveries = f.multicast(0, &members, 128, Time::ZERO);
        assert_eq!(deliveries.len(), 99);
        assert_eq!(f.stats().msgs_sent, 1);
        assert_eq!(f.stats().multicasts, 1);
        assert_eq!(f.stats().msgs_delivered, 99);
        // Sender egress used once: a follow-up unicast departs right after
        // ONE serialization, not 99.
        let t = f.unicast(0, 1, 128, Time::ZERO);
        let one_ser = NetConfig::default().serialization(128);
        let two_ser_ns = 2.0 * one_ser.as_ns_f64();
        assert!(
            t.as_ns_f64() < two_ser_ns + 800.0,
            "egress was serialized per member"
        );
    }

    #[test]
    fn multicast_into_matches_multicast_exactly() {
        let mk = || fabric(256);
        let members: Vec<usize> = (1..50).collect();
        let mut a = mk();
        let via_vec = a.multicast(0, &members, 64, Time::ZERO);
        let mut b = mk();
        let mut scratch = Vec::new();
        b.multicast_into(0, 1..50, 64, Time::ZERO, &mut scratch);
        assert_eq!(via_vec, scratch, "range iterator path must be identical");
        assert_eq!(a.stats().msgs_delivered, b.stats().msgs_delivered);
        // The scratch buffer appends, so callers can reuse it.
        scratch.clear();
        b.multicast_into(50, 51..60, 16, Time::ZERO, &mut scratch);
        assert_eq!(scratch.len(), 9);
    }

    #[test]
    #[should_panic(expected = "multicast disabled")]
    fn multicast_panics_when_disabled() {
        let mut cfg = NetConfig::default();
        cfg.multicast = false;
        let mut f = Fabric::new(Topology::paper(64), cfg, 1);
        f.multicast(0, &[1, 2], 16, Time::ZERO);
    }

    #[test]
    fn tail_injection_rate() {
        let mut cfg = NetConfig::default();
        cfg.tail_prob = (1, 100);
        cfg.tail_extra_ns = 4000;
        let mut f = Fabric::new(Topology::paper(64), cfg, 7);
        for i in 0..20_000 {
            f.unicast(i % 64, (i + 1) % 64, 16, Time::from_ns(i as u64));
        }
        let rate = f.stats().tail_hits as f64 / 20_000.0;
        assert!((0.005..0.02).contains(&rate), "tail rate = {rate}");
    }

    /// Property sweep: for random message sequences, every arrival is
    /// strictly after its hand-off (positive latency — the calendar queue
    /// in sim/engine.rs relies on this), and counters conserve.
    #[test]
    fn property_arrivals_after_ready_and_counters_conserve() {
        use crate::sim::SplitMix64;
        let mut rng = SplitMix64::new(0xFAB);
        for trial in 0..20 {
            let nodes = 2 + rng.index(500);
            let mut cfg = NetConfig::default();
            if rng.chance(1, 2) {
                cfg.tail_prob = (1, 20);
                cfg.tail_extra_ns = 1000;
            }
            let mut f = Fabric::new(Topology::paper(nodes), cfg, trial);
            let msgs = 200;
            let mut now = Time::ZERO;
            for _ in 0..msgs {
                now += Time::from_ns(rng.next_below(50));
                let src = rng.index(nodes);
                let dst = rng.index(nodes);
                let bytes = 8 + rng.next_below(200);
                let arrival = f.unicast(src, dst, bytes, now);
                assert!(arrival > now, "arrival {arrival} !> ready {now}");
            }
            let s = f.stats();
            assert_eq!(s.msgs_sent, msgs);
            assert_eq!(s.msgs_delivered, msgs);
            assert_eq!(s.wire_bytes, s.payload_bytes + msgs * 24);
        }
    }

    #[test]
    fn loss_injects_retransmit_delay_deterministically() {
        let mk = || {
            let mut cfg = NetConfig::default();
            cfg.loss_prob = (2000, 10_000); // 20%
            cfg.rto_ns = 5_000;
            Fabric::new(Topology::paper(128), cfg, 9)
        };
        let run = |mut f: Fabric| -> (Vec<Time>, u64) {
            let arrivals = (0..2_000)
                .map(|i| f.unicast(i % 128, (i + 7) % 128, 64, Time::from_ns(i as u64)))
                .collect();
            (arrivals, f.stats().retransmits)
        };
        let (a, ra) = run(mk());
        let (b, rb) = run(mk());
        assert_eq!(a, b, "same seed + loss rate must replay identically");
        assert_eq!(ra, rb);
        // ~20% of 2,000 attempts lose at least once.
        assert!((200..1000).contains(&(ra as usize)), "retransmits = {ra}");
        // Retransmitted messages arrive an RTO multiple later.
        let lossless = {
            let mut f = fabric(128);
            (0..2_000)
                .map(|i| f.unicast(i % 128, (i + 7) % 128, 64, Time::from_ns(i as u64)))
                .collect::<Vec<Time>>()
        };
        assert!(a.iter().zip(&lossless).all(|(x, y)| x >= y));
        assert!(a.iter().zip(&lossless).any(|(x, y)| x > y));
    }

    #[test]
    fn disabled_loss_draws_nothing_from_the_rng_stream() {
        // Two fabrics, same seed, both with tail injection on; one also
        // carries a loss config with numerator 0. If the loss gate drew
        // from the RNG, the tail pattern (and arrivals) would diverge.
        let mut tail_cfg = NetConfig::default();
        tail_cfg.tail_prob = (1, 50);
        tail_cfg.tail_extra_ns = 2_000;
        let mut with_zero_loss = tail_cfg.clone();
        with_zero_loss.loss_prob = (0, 10_000);
        with_zero_loss.rto_ns = 99_999;
        let run = |cfg: NetConfig| -> Vec<Time> {
            let mut f = Fabric::new(Topology::paper(64), cfg, 5);
            (0..500).map(|i| f.unicast(i % 64, (i + 3) % 64, 32, Time::from_ns(i as u64))).collect()
        };
        assert_eq!(run(tail_cfg), run(with_zero_loss));
    }

    #[test]
    fn oversubscription_queues_cross_leaf_traffic() {
        // 64-fold oversubscription leaves a single spine register: many
        // simultaneous cross-leaf messages serialize through it.
        let mut cfg = NetConfig::default();
        cfg.oversub = 64;
        let mut over = Fabric::new(Topology::paper(256), cfg, 1);
        let mut full = fabric(256);
        let arrivals =
            |f: &mut Fabric| (0..64).map(|i| f.unicast(i, 128 + i, 256, Time::ZERO)).collect::<Vec<Time>>();
        let a_over = arrivals(&mut over);
        let a_full = arrivals(&mut full);
        assert!(a_over.iter().zip(&a_full).all(|(o, f)| o >= f));
        assert!(
            a_over.last().unwrap() > a_full.last().unwrap(),
            "spine contention must delay the tail of an incast burst"
        );
        // Same-leaf traffic never touches a spine.
        let mut cfg = NetConfig::default();
        cfg.oversub = 64;
        let mut over = Fabric::new(Topology::paper(256), cfg, 1);
        let mut full = fabric(256);
        assert_eq!(over.unicast(0, 1, 64, Time::ZERO), full.unicast(0, 1, 64, Time::ZERO));
    }

    #[test]
    fn oversub_one_approximates_full_bisection_for_disjoint_flows() {
        // With the full spine count (oversub = 1) a single cross-leaf
        // message sees no added queueing.
        let mut cfg = NetConfig::default();
        cfg.oversub = 1;
        let mut f1 = Fabric::new(Topology::paper(256), cfg, 1);
        let mut f0 = fabric(256);
        assert_eq!(f1.unicast(0, 200, 64, Time::ZERO), f0.unicast(0, 200, 64, Time::ZERO));
    }

    #[test]
    fn counters_accumulate() {
        let mut f = fabric(64);
        f.unicast(0, 1, 16, Time::ZERO);
        f.unicast(1, 2, 104, Time::ZERO);
        let s = f.stats();
        assert_eq!(s.msgs_sent, 2);
        assert_eq!(s.msgs_delivered, 2);
        assert_eq!(s.payload_bytes, 120);
        assert_eq!(s.wire_bytes, 120 + 48);
    }
}
