//! Cycle-calibrated model of the simulated nanoPU endpoint: the 3.2 GHz
//! in-order Rocket core ([`CoreModel`]) and its cache hierarchy
//! ([`CacheModel`]). All timing constants trace to a paper measurement
//! (DESIGN.md §6); calibration tests pin them.

mod cache;
mod rocket;

pub use cache::CacheModel;
pub use rocket::{CoreModel, Temp};

/// Table 1 of the paper: median wire-to-wire loopback latency (ns) of the
/// three end-host network stacks it compares. Used by `repro fig table1`.
pub const TABLE1_LATENCIES_NS: [(&str, u64); 3] =
    [("eRPC", 850), ("NeBuLa", 100), ("nanoPU", 69)];
