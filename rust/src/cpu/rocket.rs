//! Cycle cost model of the 3.2 GHz in-order RISC-V Rocket core (paper §5.1)
//! and the nanoPU register-file network interface (paper §2.1, Figs 6/7).
//!
//! Every constant is calibrated against a published measurement; the tests
//! at the bottom pin each anchor point so the calibration cannot drift.
//! See DESIGN.md §6 for the anchor table.

use super::cache::CacheModel;
use crate::sim::Time;

/// Cost model for node-local operations. All methods return *cycles*;
/// convert with [`Time::from_cycles`].
#[derive(Debug, Clone)]
pub struct CoreModel {
    pub cache: CacheModel,
    /// Cycles per element-comparison step of the local sort
    /// (n·log2(n) model). Calibrated: 1,024 keys ≈ 30 µs cold (Fig 8).
    pub sort_cycles_per_cmp: f64,
    /// Cycles per 8-byte word for a streaming scan (min/sum). Fig 1:
    /// "scan 1K 8B words in L1 cache" < 1 µs => ~3 cycles/word.
    pub scan_cycles_per_word: u64,
    /// Fixed cycles to receive one message through the nanoPU RX register
    /// interface (Fig 6: 64×16 B messages ≈ 400 ns => 20 cycles each).
    pub rx_fixed_cycles: u64,
    /// Additional RX cycles per 8-byte payload word.
    pub rx_word_cycles: u64,
    /// Fixed cycles to send one message (Fig 7; slightly cheaper than RX).
    pub tx_fixed_cycles: u64,
    /// Additional TX cycles per 8-byte payload word.
    pub tx_word_cycles: u64,
    /// Fixed per-task dispatch overhead (thread wakeup via the hardware
    /// scheduler; the nanoPU makes this tiny).
    pub task_dispatch_cycles: u64,
}

impl Default for CoreModel {
    fn default() -> Self {
        CoreModel {
            cache: CacheModel::default(),
            sort_cycles_per_cmp: 9.4,
            scan_cycles_per_word: 3,
            rx_fixed_cycles: 16,
            rx_word_cycles: 2,
            // Calibrated jointly with RX against Fig 1's "118 8-byte
            // loopback nanoRequests per µs": rx(8B)+tx(8B) = 27 cycles.
            tx_fixed_cycles: 7,
            tx_word_cycles: 2,
            task_dispatch_cycles: 10,
        }
    }
}

/// Cache temperature of an operation's input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Temp {
    /// Input already resident in L1 (typical nanoTask working set).
    Warm,
    /// Input must stream in from DRAM (the paper clears caches in Figs 2/8).
    Cold,
}

impl CoreModel {
    /// Cycles to comparison-sort `n` 8-byte keys locally.
    ///
    /// In-cache cost is `sort_cycles_per_cmp · n·log2(n)`; beyond L1 the
    /// merge passes re-stream the working set (cache model), and a cold
    /// start pays compulsory misses — reproducing the Fig 8 knee.
    pub fn sort_cycles(&self, n: u64, temp: Temp) -> u64 {
        if n <= 1 {
            return self.task_dispatch_cycles;
        }
        let logn = (64 - (n - 1).leading_zeros()) as u64; // ceil(log2 n)
        let cmp = (self.sort_cycles_per_cmp * (n * logn) as f64).ceil() as u64;
        let bytes = n * 8;
        let mut extra = 0;
        if temp == Temp::Cold {
            extra += self.cache.cold_stream_cycles(bytes);
        }
        // Each doubling of the working set beyond L1 adds one re-streamed
        // pass that no longer hits L1.
        let mut ws = bytes;
        while ws > self.cache.l1_bytes {
            extra += self.cache.repass_cycles(ws);
            ws /= 2;
        }
        cmp + extra + self.task_dispatch_cycles
    }

    /// Cycles to scan `n` 8-byte values keeping a running minimum (Fig 2).
    pub fn scan_min_cycles(&self, n: u64, temp: Temp) -> u64 {
        let bytes = n * 8;
        let mut cycles = self.scan_cycles_per_word * n + self.task_dispatch_cycles;
        if temp == Temp::Cold || bytes > self.cache.l1_bytes {
            cycles += self.cache.cold_stream_cycles(bytes);
        }
        cycles
    }

    /// Cycles to merge `k` already-received values into a running min
    /// (MergeMin's per-level reduce: registers + L1 only).
    pub fn merge_cycles(&self, k: u64) -> u64 {
        self.scan_cycles_per_word * k + self.task_dispatch_cycles
    }

    /// Cycles to compute bucket ids of `n` keys against `p` pivots
    /// (branch-free compare-sum, matching the L1 bucketize kernel).
    pub fn bucketize_cycles(&self, n: u64, p: u64) -> u64 {
        // One compare+add per (key, pivot) pair, 1 cycle each when
        // L1-resident, plus loop overhead.
        n * p + 2 * n + self.task_dispatch_cycles
    }

    /// Cycles for the element-wise median of `m` pivot vectors of length
    /// `p` (median-tree aggregation step).
    pub fn median_combine_cycles(&self, m: u64, p: u64) -> u64 {
        // Insertion into a tiny sorted buffer per column: ~m^2/4 + m per
        // column; all register/L1 resident.
        p * (m * m / 4 + m) + self.task_dispatch_cycles
    }

    /// Cycles to receive one message with `payload_bytes` of payload
    /// through the two-register interface.
    pub fn rx_cycles(&self, payload_bytes: u64) -> u64 {
        self.rx_fixed_cycles + self.rx_word_cycles * payload_bytes.div_ceil(8)
    }

    /// Cycles to send one message with `payload_bytes` of payload.
    pub fn tx_cycles(&self, payload_bytes: u64) -> u64 {
        self.tx_fixed_cycles + self.tx_word_cycles * payload_bytes.div_ceil(8)
    }

    /// Convenience: `Time` versions.
    pub fn rx_time(&self, payload_bytes: u64) -> Time {
        Time::from_cycles(self.rx_cycles(payload_bytes))
    }
    pub fn tx_time(&self, payload_bytes: u64) -> Time {
        Time::from_cycles(self.tx_cycles(payload_bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn us(cycles: u64) -> f64 {
        cycles as f64 / 3200.0 // cycles @3.2GHz -> µs
    }

    /// Fig 8 anchor: sorting 1,024 keys cold takes over 30 µs.
    #[test]
    fn anchor_fig8_sort_1k_cold() {
        let m = CoreModel::default();
        let t = us(m.sort_cycles(1024, Temp::Cold));
        assert!((28.0..36.0).contains(&t), "sort(1024) cold = {t} µs");
    }

    /// Fig 1 anchor: sorting 40 keys (warm) completes within 1 µs.
    #[test]
    fn anchor_fig1_sort_40_warm() {
        let m = CoreModel::default();
        let t = us(m.sort_cycles(40, Temp::Warm));
        assert!(t < 1.0, "sort(40) warm = {t} µs");
        // ... and 64 keys is still ~1 µs (paper §6.2.1: "at most 64 keys").
        assert!(us(m.sort_cycles(64, Temp::Warm)) < 1.3);
    }

    /// Fig 2 anchor: min of 8,192 values cold ≈ 18 µs.
    #[test]
    fn anchor_fig2_min_8k_cold() {
        let m = CoreModel::default();
        let t = us(m.scan_min_cycles(8192, Temp::Cold));
        assert!((16.0..20.0).contains(&t), "min(8192) cold = {t} µs");
    }

    /// Fig 1 anchor: scan 1K 8-byte words in L1 < 1 µs.
    #[test]
    fn anchor_fig1_scan_1k_warm() {
        let m = CoreModel::default();
        let t = us(m.scan_min_cycles(1024, Temp::Warm));
        assert!(t < 1.0, "scan(1024) warm = {t} µs");
    }

    /// Fig 6 anchors: one 16 B message ≈ 8 ns; 64 messages ≈ 400 ns.
    #[test]
    fn anchor_fig6_rx() {
        let m = CoreModel::default();
        let one = Time::from_cycles(m.rx_cycles(16)).as_ns_f64();
        assert!((5.0..9.0).contains(&one), "rx(16B) = {one} ns");
        let sixty_four = Time::from_cycles(64 * m.rx_cycles(16)).as_ns_f64();
        assert!((350.0..450.0).contains(&sixty_four), "rx 64 msgs = {sixty_four} ns");
    }

    /// Fig 1 anchor: 118 8-byte loopback nanoRequests per µs => RX+TX of an
    /// 8 B message must fit in ~27 cycles.
    #[test]
    fn anchor_fig1_loopback_rate() {
        let m = CoreModel::default();
        let per_req = m.rx_cycles(8) + m.tx_cycles(8);
        let reqs_per_us = 3200 / per_req;
        assert!((90..150).contains(&reqs_per_us), "loopback rate {reqs_per_us}/µs");
    }

    #[test]
    fn sort_cost_monotonic_in_n() {
        let m = CoreModel::default();
        let mut prev = 0;
        for n in [2u64, 16, 64, 256, 1024, 4096] {
            let c = m.sort_cycles(n, Temp::Cold);
            assert!(c > prev, "sort_cycles not monotonic at n={n}");
            prev = c;
        }
    }

    #[test]
    fn cold_dominates_warm() {
        let m = CoreModel::default();
        for n in [64u64, 1024, 4096] {
            assert!(m.sort_cycles(n, Temp::Cold) > m.sort_cycles(n, Temp::Warm));
            assert!(m.scan_min_cycles(n, Temp::Cold) >= m.scan_min_cycles(n, Temp::Warm));
        }
    }

    #[test]
    fn small_op_costs_positive() {
        let m = CoreModel::default();
        assert!(m.sort_cycles(0, Temp::Warm) > 0);
        assert!(m.sort_cycles(1, Temp::Warm) > 0);
        assert!(m.merge_cycles(1) > 0);
        assert!(m.bucketize_cycles(1, 1) > 0);
        assert!(m.median_combine_cycles(2, 1) > 0);
    }
}
