//! Cache-hierarchy cost model for the Rocket core (paper §5.1: 16 KB L1D,
//! 512 KB shared L2, 64 B lines, DRAM behind).
//!
//! This is an *analytic* model, not a tag-array simulator: the paper's
//! microbenchmarks stream over contiguous key/value arrays, so miss counts
//! are a function of working-set size and pass structure. The constants are
//! calibrated so the model pins the paper's anchor points (Fig 2: min of
//! 8,192 values ≈ 18 µs cold; Fig 8: sort of 1,024 keys ≈ 30 µs cold;
//! Fig 1: 1K-word L1-resident scan < 1 µs).

/// Geometry + latency parameters of the simulated memory hierarchy.
#[derive(Debug, Clone)]
pub struct CacheModel {
    /// L1 data cache capacity in bytes (Rocket default: 16 KB).
    pub l1_bytes: u64,
    /// Shared L2 capacity in bytes (512 KB).
    pub l2_bytes: u64,
    /// Line size in bytes.
    pub line_bytes: u64,
    /// Extra cycles per L1 miss that hits L2.
    pub l2_hit_cycles: u64,
    /// Extra cycles per L1 miss that goes to DRAM (cold/compulsory miss).
    pub dram_cycles: u64,
}

impl Default for CacheModel {
    fn default() -> Self {
        CacheModel {
            l1_bytes: 16 * 1024,
            l2_bytes: 512 * 1024,
            line_bytes: 64,
            // Calibrated: Fig 2 gives ~7 cycles/8B-word for a cold streaming
            // min over 64 KB => ~4 extra cycles/word => 32 cycles/line.
            l2_hit_cycles: 20,
            dram_cycles: 32,
        }
    }
}

impl CacheModel {
    /// Number of cache lines covering `bytes` of contiguous data.
    pub fn lines(&self, bytes: u64) -> u64 {
        bytes.div_ceil(self.line_bytes)
    }

    /// Cold-miss penalty for streaming `bytes` once from DRAM.
    pub fn cold_stream_cycles(&self, bytes: u64) -> u64 {
        self.lines(bytes) * self.dram_cycles
    }

    /// Penalty for one additional pass over `bytes` given the working set
    /// no longer fits in L1 (served from L2 if it fits there, else DRAM).
    pub fn repass_cycles(&self, bytes: u64) -> u64 {
        if bytes <= self.l1_bytes {
            0
        } else if bytes <= self.l2_bytes {
            self.lines(bytes) * self.l2_hit_cycles
        } else {
            self.lines(bytes) * self.dram_cycles
        }
    }

    /// Predicted L1 miss rate (misses per access) for a single cold
    /// streaming pass of 8-byte words over `bytes` — reproduces the shape
    /// of Fig 2b: one compulsory miss per line while streaming, and ~0 when
    /// the (warm) working set fits in L1.
    pub fn stream_miss_rate(&self, bytes: u64, cold: bool) -> f64 {
        let words = (bytes / 8).max(1);
        if cold || bytes > self.l1_bytes {
            self.lines(bytes) as f64 / words as f64
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_math() {
        let c = CacheModel::default();
        assert_eq!(c.lines(64), 1);
        assert_eq!(c.lines(65), 2);
        assert_eq!(c.lines(8 * 1024), 128);
    }

    #[test]
    fn cold_stream_calibration_fig2() {
        // Fig 2 anchor: min over 8,192 8B values (64 KB) cold ≈ 18 µs
        // = 57,600 cycles total; scan itself is 3 cyc/word = 24,576,
        // leaving ~33 k cycles of misses => ~32 cycles/line * 1,024 lines.
        let c = CacheModel::default();
        let penalty = c.cold_stream_cycles(64 * 1024);
        assert_eq!(penalty, 1024 * 32);
    }

    #[test]
    fn repass_tiers() {
        let c = CacheModel::default();
        assert_eq!(c.repass_cycles(8 * 1024), 0); // fits L1
        assert_eq!(c.repass_cycles(64 * 1024), 1024 * 20); // fits L2
        assert_eq!(c.repass_cycles(1024 * 1024), 16_384 * 32); // DRAM
    }

    #[test]
    fn miss_rate_shape() {
        let c = CacheModel::default();
        // Streaming cold: 1 miss per 8 words = 0.125.
        assert!((c.stream_miss_rate(64 * 1024, true) - 0.125).abs() < 1e-9);
        // Warm and L1-resident: ~0.
        assert_eq!(c.stream_miss_rate(4 * 1024, false), 0.0);
    }
}
