//! L3 coordinator: run configuration, data-plane selection, and report
//! rendering shared by the CLI (`repro`), the examples, and the benches.

mod report;

pub use report::{f, Table};

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::compute::{LocalCompute, NativeCompute, RadixCompute, XlaCompute};
use crate::pool::WorkerPool;

/// Which data plane executes node-local compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ComputeChoice {
    /// Pure-Rust comparison oracle (the differential-testing reference).
    Native,
    /// Count-then-scatter radix kernels (DESIGN.md §8); the default —
    /// digest-identical to the oracle, faster on the sort hot paths.
    #[default]
    Radix,
    /// The three-layer path: Pallas -> JAX -> HLO text -> PJRT.
    Xla,
}

impl ComputeChoice {
    /// Construct the data plane (shared across executor shards via
    /// `Arc` — see [`LocalCompute`]'s thread-safety contract) with a
    /// budget-1 worker pool: parallel kernels stay inline. XLA requires
    /// `make artifacts` to have run on a `pjrt`-featured build.
    pub fn build(self) -> Result<Arc<dyn LocalCompute>> {
        self.build_pooled(&Arc::new(WorkerPool::new(1)))
    }

    /// Construct the data plane sharing `pool` with the executor, so the
    /// radix plane's parallel kernels and the shard workers draw from one
    /// `--threads` budget ([`crate::pool`]). The other planes have no
    /// parallel kernels and ignore the pool.
    pub fn build_pooled(self, pool: &Arc<WorkerPool>) -> Result<Arc<dyn LocalCompute>> {
        Ok(match self {
            ComputeChoice::Native => Arc::new(NativeCompute),
            ComputeChoice::Radix => Arc::new(RadixCompute::with_pool(pool.clone())),
            ComputeChoice::Xla => Arc::new(XlaCompute::open_default()?),
        })
    }

    /// Parse the `--compute` knob value.
    pub fn parse(s: &str) -> Result<ComputeChoice> {
        match s {
            "native" => Ok(ComputeChoice::Native),
            "radix" => Ok(ComputeChoice::Radix),
            "xla" => Ok(ComputeChoice::Xla),
            other => bail!("unknown data plane {other:?} (known: native|radix|xla)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ComputeChoice::Native => "native",
            ComputeChoice::Radix => "radix",
            ComputeChoice::Xla => "xla",
        }
    }
}

/// Options shared by every figure/benchmark entry point.
#[derive(Debug, Clone)]
pub struct RunOptions {
    pub compute: ComputeChoice,
    pub seed: u64,
    /// Repetitions for runs that report averages (headline does 10).
    pub runs: usize,
    /// Shrink the heaviest experiments (CI-sized sweeps).
    pub quick: bool,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions { compute: ComputeChoice::default(), seed: 1, runs: 1, quick: false }
    }
}

/// Minimal CLI argument cursor (the offline registry has no clap; see
/// DESIGN.md "Dependency substitutions").
pub struct Args {
    items: Vec<String>,
}

impl Args {
    pub fn from_env() -> Self {
        Args { items: std::env::args().skip(1).collect() }
    }

    pub fn from_vec(items: Vec<String>) -> Self {
        Args { items }
    }

    /// Remove and return the first positional (non-flag) argument.
    pub fn positional(&mut self) -> Option<String> {
        let idx = self.items.iter().position(|a| !a.starts_with("--"))?;
        Some(self.items.remove(idx))
    }

    /// True if `--name` is present (consumes it).
    pub fn flag(&mut self, name: &str) -> bool {
        let want = format!("--{name}");
        if let Some(idx) = self.items.iter().position(|a| *a == want) {
            self.items.remove(idx);
            true
        } else {
            false
        }
    }

    /// Like [`Args::value`], but a trailing `--name` with no value is an
    /// error instead of a silent `None` (used by the workload registry).
    pub fn value_checked(&mut self, name: &str) -> Result<Option<String>> {
        let want = format!("--{name}");
        if self.items.last().map(|a| *a == want).unwrap_or(false) {
            bail!("--{name} expects a value");
        }
        Ok(self.value(name))
    }

    /// Value of `--name <value>` or `--name=<value>` (consumes both).
    pub fn value(&mut self, name: &str) -> Option<String> {
        let want = format!("--{name}");
        let prefix = format!("--{name}=");
        if let Some(idx) = self.items.iter().position(|a| *a == want) {
            self.items.remove(idx);
            if idx < self.items.len() {
                return Some(self.items.remove(idx));
            }
            return None;
        }
        if let Some(idx) = self.items.iter().position(|a| a.starts_with(&prefix)) {
            let item = self.items.remove(idx);
            return Some(item[prefix.len()..].to_string());
        }
        None
    }

    /// Parse `--name <n>` as a number.
    pub fn num<T: std::str::FromStr>(&mut self, name: &str) -> Option<T> {
        self.value(name).and_then(|v| v.parse().ok())
    }

    /// Like [`Args::num`], but a dangling `--name` or a malformed number
    /// is an error instead of a silent default.
    pub fn num_checked<T: std::str::FromStr>(&mut self, name: &str) -> Result<Option<T>> {
        match self.value_checked(name)? {
            None => Ok(None),
            Some(raw) => match raw.parse() {
                Ok(v) => Ok(Some(v)),
                Err(_) => bail!("--{name} expects a number, got {raw:?}"),
            },
        }
    }

    /// Remaining unconsumed arguments (for error reporting).
    pub fn rest(&self) -> &[String] {
        &self.items
    }

    /// Parse the data-plane selection: `--compute native|radix|xla`
    /// (default [`ComputeChoice::Radix`]), with `--xla` kept as the
    /// historical shorthand. Naming both is a conflict, not a silent
    /// precedence.
    pub fn compute_choice(&mut self) -> Result<ComputeChoice> {
        let named = self.value_checked("compute")?;
        let xla_flag = self.flag("xla");
        match (named, xla_flag) {
            (Some(v), false) => ComputeChoice::parse(&v),
            (None, true) => Ok(ComputeChoice::Xla),
            (None, false) => Ok(ComputeChoice::default()),
            (Some(v), true) => {
                bail!("--compute {v} conflicts with --xla; pass one of them")
            }
        }
    }

    /// Standard options block shared by subcommands. Dangling or
    /// malformed `--seed`/`--runs`/`--compute` values are errors,
    /// matching the strictness of registry workload parameters.
    pub fn run_options(&mut self) -> Result<RunOptions> {
        Ok(RunOptions {
            compute: self.compute_choice()?,
            seed: self.num_checked("seed")?.unwrap_or(1),
            runs: self.num_checked("runs")?.unwrap_or(1),
            quick: self.flag("quick"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::from_vec(s.split_whitespace().map(str::to_string).collect())
    }

    #[test]
    fn parses_positionals_and_flags() {
        let mut a = args("fig 9 --xla --seed 7 --runs=3");
        assert_eq!(a.positional().as_deref(), Some("fig"));
        assert_eq!(a.positional().as_deref(), Some("9"));
        let opts = a.run_options().unwrap();
        assert_eq!(opts.compute, ComputeChoice::Xla);
        assert_eq!(opts.seed, 7);
        assert_eq!(opts.runs, 3);
        assert!(!opts.quick);
        assert!(a.rest().is_empty());
    }

    #[test]
    fn missing_values_default() {
        let mut a = args("fig 4");
        a.positional();
        a.positional();
        let opts = a.run_options().unwrap();
        assert_eq!(opts.compute, ComputeChoice::Radix, "radix is the default plane");
        assert_eq!(opts.seed, 1);
    }

    #[test]
    fn offline_compute_planes_build() {
        assert!(ComputeChoice::Native.build().is_ok());
        assert!(ComputeChoice::Radix.build().is_ok());
    }

    #[test]
    fn compute_knob_parses_and_conflicts_with_xla_shorthand() {
        let opts = args("--compute native").run_options().unwrap();
        assert_eq!(opts.compute, ComputeChoice::Native);
        let opts = args("--compute radix").run_options().unwrap();
        assert_eq!(opts.compute, ComputeChoice::Radix);
        let opts = args("--compute xla").run_options().unwrap();
        assert_eq!(opts.compute, ComputeChoice::Xla);
        let err = args("--compute bogo").run_options().unwrap_err().to_string();
        assert!(err.contains("unknown data plane"), "{err}");
        let err = args("--compute radix --xla").run_options().unwrap_err().to_string();
        assert!(err.contains("conflicts"), "{err}");
        assert!(args("--compute").run_options().is_err(), "dangling value");
    }

    #[test]
    fn trailing_flag_with_no_value_is_silent_none_via_value() {
        // `value` keeps the historical lenient behavior...
        let mut a = args("run nanosort --seed");
        a.positional();
        a.positional();
        assert_eq!(a.value("seed"), None);
        assert!(a.rest().is_empty(), "the dangling flag is still consumed");
    }

    #[test]
    fn trailing_flag_with_no_value_errors_via_value_checked() {
        // ...while `value_checked` (the registry path) reports it.
        let mut a = args("--seed");
        let err = a.value_checked("seed").unwrap_err().to_string();
        assert!(err.contains("--seed expects a value"), "{err}");
    }

    #[test]
    fn value_checked_passes_through_normal_and_eq_forms() {
        let mut a = args("--seed 7");
        assert_eq!(a.value_checked("seed").unwrap().as_deref(), Some("7"));
        let mut a = args("--seed=8");
        assert_eq!(a.value_checked("seed").unwrap().as_deref(), Some("8"));
        let mut a = args("--runs 3");
        assert_eq!(a.value_checked("seed").unwrap(), None);
        assert_eq!(a.rest(), ["--runs", "3"]);
    }

    #[test]
    fn repeated_value_flags_consume_first_occurrence_only() {
        let mut a = args("--seed 1 --seed 2");
        assert_eq!(a.value("seed").as_deref(), Some("1"));
        // The repeat is left behind and surfaces as an unconsumed error.
        assert_eq!(a.rest(), ["--seed", "2"]);
    }

    #[test]
    fn repeated_boolean_flags_surface_as_unconsumed() {
        let mut a = args("fig 9 --xla --xla");
        a.positional();
        a.positional();
        let opts = a.run_options().unwrap();
        assert_eq!(opts.compute, ComputeChoice::Xla);
        assert_eq!(a.rest(), ["--xla"]);
    }

    #[test]
    fn malformed_numbers_fall_back_to_default_via_num() {
        let mut a = args("--seed banana");
        assert_eq!(a.num::<u64>("seed"), None);
        assert!(a.rest().is_empty(), "flag and value both consumed");
    }

    #[test]
    fn run_options_rejects_malformed_and_dangling_env_flags() {
        let err = args("--seed banana").run_options().unwrap_err().to_string();
        assert!(err.contains("--seed expects a number"), "{err}");
        assert!(args("--runs").run_options().is_err());
        let opts = args("--seed 9").run_options().unwrap();
        assert_eq!(opts.seed, 9);
    }

    #[test]
    fn positional_skips_flags() {
        let mut a = args("--xla run");
        assert_eq!(a.positional().as_deref(), Some("run"));
        assert_eq!(a.rest(), ["--xla"]);
        assert_eq!(a.positional(), None);
    }
}
