//! Text tables for figure/benchmark reports (and CSV export).

/// A titled table of string cells.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes (paper reference values, deviations).
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, note: impl Into<String>) -> &mut Self {
        self.notes.push(note.into());
        self
    }

    /// Render aligned text.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = format!("## {}\n", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        for note in &self.notes {
            out.push_str(&format!("  note: {note}\n"));
        }
        out
    }

    /// Render comma-separated values (no notes).
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with sensible precision for reports.
pub fn f(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 100.0 {
        format!("{v:.0}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "20000".into()]);
        t.note("hello");
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("note: hello"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new("Demo", &["x", "y"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "x,y\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        Table::new("t", &["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn float_formats() {
        assert_eq!(f(0.0), "0");
        assert_eq!(f(1234.0), "1234");
        assert_eq!(f(12.345), "12.35");
        assert_eq!(f(0.1234), "0.1234");
    }
}
