//! Count-then-scatter radix data plane (DESIGN.md §8).
//!
//! The sort-family workloads move uniform-ish u64 keys, which is exactly
//! the shape where counting kernels beat comparison sorts (hardware
//! sorting surveys and distributed radix partitioning both land here):
//!
//! - [`RadixCompute::sort`] / [`RadixCompute::sort_pairs`] — LSD radix
//!   over 8-bit digits, modeled on the `lsb_radix_sort` kernels of the
//!   ska-sort family: one histogram pass computes all eight digit
//!   distributions, trivial digits (every key shares the byte — common
//!   once keys are confined to a bucket's sub-range) are skipped, and the
//!   remaining digits scatter between the key buffer and one scratch
//!   buffer. LSD scatter is stable, which is what makes the pair kernel's
//!   tie-break ("equal keys keep input order") hold by construction.
//! - [`RadixCompute::partition`] / [`RadixCompute::partition_pairs`] —
//!   one tag+count pass, then a direct scatter into per-bucket buffers
//!   allocated at exact capacity (no push-time reallocation, no
//!   intermediate bucket-index `Vec` handed back to the caller).
//!
//! Small blocks fall back to comparison sorts: a counting pass over 256
//! buckets costs more than pdqsort below a few dozen keys, and the
//! simulated cores hold tens of keys per level at the paper tier. The
//! fallbacks preserve the same canonical outputs (`sort_unstable` on bare
//! u64s is indistinguishable from any other correct sort; the pair
//! fallback is std's stable sort), so the crossover is invisible in
//! digests — `rust/tests/compute.rs` pins radix-vs-oracle equality across
//! every input distribution and edge shape.

use super::{LocalCompute, NativeCompute};

/// Digit width of one LSD pass.
const RADIX_BITS: u32 = 8;
/// Buckets per pass (2^RADIX_BITS).
const BUCKETS: usize = 1 << RADIX_BITS;
/// LSD passes covering a u64.
const LEVELS: usize = (u64::BITS / RADIX_BITS) as usize;
/// Below this many elements, comparison sorts win over counting passes.
const SMALL_SORT: usize = 96;
/// Pivot-list length up to which the branchless linear scan beats binary
/// search for bucket tagging.
const LINEAR_SCAN_PIVOTS: usize = 32;

/// Radix-kernel implementation of [`LocalCompute`]; the default data
/// plane (`--compute radix`). Reductions (`min`, `median_combine`) have
/// no radix structure to exploit and delegate to the oracle.
#[derive(Debug, Clone, Default)]
pub struct RadixCompute;

#[inline]
fn digit(key: u64, level: usize) -> usize {
    ((key >> (RADIX_BITS * level as u32)) & (BUCKETS as u64 - 1)) as usize
}

/// Per-digit histograms for all eight levels in one pass over the data.
fn histograms<T, F: Fn(&T) -> u64>(items: &[T], key: F) -> Vec<[usize; BUCKETS]> {
    let mut counts = vec![[0usize; BUCKETS]; LEVELS];
    for item in items {
        let k = key(item);
        for (level, c) in counts.iter_mut().enumerate() {
            c[digit(k, level)] += 1;
        }
    }
    counts
}

/// Exclusive prefix sums of one digit histogram.
fn prefix_sums(counts: &[usize; BUCKETS]) -> [usize; BUCKETS] {
    let mut sums = [0usize; BUCKETS];
    let mut total = 0;
    for (s, &c) in sums.iter_mut().zip(counts.iter()) {
        *s = total;
        total += c;
    }
    sums
}

/// LSD radix sort of `items` by `key`, stable, skipping trivial digits.
fn lsd_sort<T: Copy + Default, F: Fn(&T) -> u64>(items: &mut Vec<T>, key: F) {
    let n = items.len();
    let counts = histograms(items, &key);
    let mut scratch: Vec<T> = Vec::new();
    for (level, c) in counts.iter().enumerate() {
        if c.iter().any(|&b| b == n) {
            continue; // every key shares this digit: the pass is a no-op
        }
        if scratch.is_empty() {
            scratch.resize(n, T::default());
        }
        let mut sums = prefix_sums(c);
        for item in items.iter() {
            let d = digit(key(item), level);
            scratch[sums[d]] = *item;
            sums[d] += 1;
        }
        std::mem::swap(items, &mut scratch);
    }
}

/// Bucket of `key` against sorted `pivots`: `|{i : pivots[i] <= key}|`.
/// Branchless linear scan for short pivot lists (NanoSort's b-1 = 15),
/// binary search for long ones (MilliSort's cores-1).
#[inline]
fn bucket_of(key: u64, pivots: &[u64]) -> usize {
    if pivots.len() <= LINEAR_SCAN_PIVOTS {
        pivots.iter().map(|&p| (p <= key) as usize).sum()
    } else {
        pivots.partition_point(|&p| p <= key)
    }
}

/// One tag+count pass, then scatter into exact-capacity bucket buffers.
fn partition_by<T: Copy, F: Fn(&T) -> u64>(
    items: &[T],
    pivots: &[u64],
    key: F,
) -> Vec<Vec<T>> {
    debug_assert!(pivots.windows(2).all(|w| w[0] <= w[1]));
    let b = pivots.len() + 1;
    let mut tags: Vec<u32> = Vec::with_capacity(items.len());
    let mut counts = vec![0usize; b];
    for item in items {
        let t = bucket_of(key(item), pivots);
        tags.push(t as u32);
        counts[t] += 1;
    }
    let mut out: Vec<Vec<T>> = counts.iter().map(|&c| Vec::with_capacity(c)).collect();
    for (item, &t) in items.iter().zip(&tags) {
        out[t as usize].push(*item);
    }
    out
}

impl LocalCompute for RadixCompute {
    fn sort(&self, keys: &mut Vec<u64>) {
        if keys.len() < SMALL_SORT {
            keys.sort_unstable();
        } else {
            lsd_sort(keys, |&k| k);
        }
    }

    fn sort_pairs(&self, pairs: &mut Vec<(u64, u64)>) {
        if pairs.len() < SMALL_SORT {
            pairs.sort_by_key(|p| p.0); // stable, matching the LSD path
        } else {
            lsd_sort(pairs, |p| p.0);
        }
    }

    fn min(&self, vals: &[u64]) -> Option<u64> {
        NativeCompute.min(vals)
    }

    fn bucketize(&self, keys: &[u64], pivots: &[u64]) -> Vec<u32> {
        debug_assert!(pivots.windows(2).all(|w| w[0] <= w[1]));
        keys.iter().map(|&k| bucket_of(k, pivots) as u32).collect()
    }

    fn partition(&self, keys: &[u64], pivots: &[u64]) -> Vec<Vec<u64>> {
        partition_by(keys, pivots, |&k| k)
    }

    fn partition_pairs(&self, pairs: &[(u64, u64)], pivots: &[u64]) -> Vec<Vec<(u64, u64)>> {
        partition_by(pairs, pivots, |p| p.0)
    }

    fn median_combine(&self, rows: &[Vec<u64>]) -> Vec<u64> {
        NativeCompute.median_combine(rows)
    }

    fn name(&self) -> &'static str {
        "radix"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::test_support::rand_keys;

    /// Force the radix path regardless of the small-input fallback.
    fn lsd_only(mut keys: Vec<u64>) -> Vec<u64> {
        lsd_sort(&mut keys, |&k| k);
        keys
    }

    #[test]
    fn lsd_sorts_across_sizes_and_patterns() {
        for n in [0usize, 1, 2, 3, SMALL_SORT - 1, SMALL_SORT, 1000, 4096] {
            let keys = rand_keys(n as u64 + 7, n);
            let mut expect = keys.clone();
            expect.sort_unstable();
            assert_eq!(lsd_only(keys), expect, "n={n}");
        }
        // Already-sorted, reversed, all-equal, and boundary values.
        let sorted: Vec<u64> = (0..500).collect();
        assert_eq!(lsd_only(sorted.clone()), sorted);
        let rev: Vec<u64> = (0..500).rev().collect();
        assert_eq!(lsd_only(rev), sorted);
        assert_eq!(lsd_only(vec![9; 300]), vec![9; 300]);
        let edges = vec![u64::MAX, 0, u64::MAX - 1, 1, u64::MAX, 1 << 63];
        let mut expect = edges.clone();
        expect.sort_unstable();
        assert_eq!(lsd_only(edges), expect);
    }

    #[test]
    fn trivial_digit_skip_is_exercised_and_exact() {
        // Keys confined to one byte of spread: 7 of 8 digit passes are
        // skipped, output must still be fully sorted.
        let keys: Vec<u64> = rand_keys(3, 600)
            .into_iter()
            .map(|k| 0xAB00_0000_0000_0000 | (k & 0xFF) << 8)
            .collect();
        let mut expect = keys.clone();
        expect.sort_unstable();
        assert_eq!(lsd_only(keys), expect);
    }

    #[test]
    fn sort_pairs_is_stable_above_and_below_the_crossover() {
        let rc = RadixCompute;
        for n in [10usize, SMALL_SORT, 800] {
            // Few distinct keys so every key value has many ties; the
            // payload records input position.
            let mut pairs: Vec<(u64, u64)> = rand_keys(n as u64, n)
                .into_iter()
                .enumerate()
                .map(|(i, k)| (k % 7, i as u64))
                .collect();
            let mut expect = pairs.clone();
            expect.sort_by_key(|p| p.0);
            rc.sort_pairs(&mut pairs);
            assert_eq!(pairs, expect, "n={n}");
        }
    }

    #[test]
    fn bucket_of_matches_partition_point_on_both_paths() {
        let mut short = rand_keys(11, LINEAR_SCAN_PIVOTS);
        short.sort_unstable();
        let mut long = rand_keys(12, LINEAR_SCAN_PIVOTS + 1);
        long.sort_unstable();
        for pivots in [&short, &long] {
            for &k in rand_keys(13, 200).iter().chain(pivots.iter()) {
                assert_eq!(
                    bucket_of(k, pivots),
                    pivots.partition_point(|&p| p <= k),
                    "k={k}"
                );
            }
        }
    }

    #[test]
    fn partition_scatters_in_input_order_with_exact_sizes() {
        let rc = RadixCompute;
        let pivots = vec![100u64, 200, 300];
        let keys = rand_keys(5, 400);
        let parts = rc.partition(&keys, &pivots);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts.iter().map(Vec::len).sum::<usize>(), keys.len());
        // Per-bucket subsequences appear in input order.
        for (b, part) in parts.iter().enumerate() {
            let expect: Vec<u64> = keys
                .iter()
                .copied()
                .filter(|&k| bucket_of(k, &pivots) == b)
                .collect();
            assert_eq!(part, &expect, "bucket {b}");
        }
    }
}
